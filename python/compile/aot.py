"""AOT compiler: lower L2/L1 jax functions to HLO **text** artifacts.

Run once at build time (`make artifacts`); the Rust binary is self-contained
afterwards. Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the `xla` 0.1.6 crate binds) rejects (`proto.id() <= INT_MAX`).
`HloModuleProto::from_text_file` re-assigns ids and round-trips cleanly.

Outputs under `artifacts/`:
  <model>.train.hlo.txt / <model>.eval.hlo.txt   per model config
  <op>.hlo.txt                                   per Pallas kernel op
  goldens/<name>.*.bin                           raw little-endian arrays
  manifest.json                                  everything Rust needs

Usage: cd python && python -m compile.aot --out ../artifacts [--full]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model
from .kernels.quantize import quantize_pallas
from .kernels.stats import stats_pallas


def to_hlo_text(fn, *arg_specs) -> str:
    """Lower a jax function to HLO text with return_tuple=True."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def _dump(path: str, arr: np.ndarray) -> None:
    np.ascontiguousarray(arr).tofile(path)


def _golden_batch(cfg: dict):
    """Deterministic batch for golden dumps (mirrored nowhere: stored as bins)."""
    rng = np.random.RandomState(cfg["seed"] + 9999)
    if cfg["kind"] == "mlp":
        x = rng.randn(cfg["batch"], cfg["input_dim"]).astype(np.float32)
        y = rng.randint(0, cfg["classes"], size=(cfg["batch"],)).astype(np.int32)
        return (x, y)
    tokens = rng.randint(0, cfg["vocab"], size=(cfg["batch"], cfg["seq_len"])).astype(np.int32)
    return (tokens,)


def build_models(out: str, full: bool) -> dict:
    entries = {}
    for name, cfg in configs.MODELS.items():
        if cfg.get("full_only") and not full:
            continue
        print(f"model {name}:")
        specs = model.specs_for(cfg)
        pcount = model.param_count(specs)
        flat_spec = jax.ShapeDtypeStruct((pcount,), jnp.float32)
        if cfg["kind"] == "mlp":
            batch_specs = (
                jax.ShapeDtypeStruct((cfg["batch"], cfg["input_dim"]), jnp.float32),
                jax.ShapeDtypeStruct((cfg["batch"],), jnp.int32),
            )
            eval_fn = model.make_mlp_eval_step(cfg, specs)
        else:
            batch_specs = (
                jax.ShapeDtypeStruct((cfg["batch"], cfg["seq_len"]), jnp.int32),
            )
            eval_fn = model.make_lm_eval_step(cfg, specs)
        train_fn = model.make_train_step(cfg, specs)

        train_file = f"{name}.train.hlo.txt"
        eval_file = f"{name}.eval.hlo.txt"
        _write(os.path.join(out, train_file), to_hlo_text(train_fn, flat_spec, *batch_specs))
        _write(os.path.join(out, eval_file), to_hlo_text(eval_fn, flat_spec, *batch_specs))

        goldens = None
        if cfg.get("goldens"):
            gdir = os.path.join(out, "goldens")
            flat = model.init_flat(specs, cfg["seed"])
            batch = _golden_batch(cfg)
            loss, grads = jax.jit(train_fn)(jnp.asarray(flat), *map(jnp.asarray, batch))
            goldens = {"params": f"goldens/{name}.params.bin"}
            _dump(os.path.join(out, goldens["params"]), flat)
            for i, b in enumerate(batch):
                key = f"in{i}"
                goldens[key] = f"goldens/{name}.{key}.bin"
                _dump(os.path.join(out, goldens[key]), np.asarray(b))
            goldens["loss"] = f"goldens/{name}.loss.bin"
            goldens["grads"] = f"goldens/{name}.grads.bin"
            _dump(os.path.join(out, goldens["loss"]), np.asarray(loss, np.float32))
            _dump(os.path.join(out, goldens["grads"]), np.asarray(grads, np.float32))

        entries[name] = {
            "kind": cfg["kind"],
            "config": {k: v for k, v in cfg.items() if k not in ("goldens", "full_only")},
            "param_count": pcount,
            "train_hlo": train_file,
            "eval_hlo": eval_file,
            "layout": [
                {"name": s.name, "shape": list(s.shape), "init": s.init, "std": s.std}
                for s in specs
            ],
            "goldens": goldens,
        }
    return entries


def build_quantize_ops(out: str) -> dict:
    entries = {}
    for name, op in configs.QUANTIZE_OPS.items():
        print(f"op {name}:")
        n, bucket, k, nt = op["n"], op["bucket"], op["k"], op["norm_type"]

        def fn(v, levels, u, _bucket=bucket, _nt=nt):
            return quantize_pallas(v, levels, u, _bucket, _nt)

        hlo_file = f"{name}.hlo.txt"
        _write(
            os.path.join(out, hlo_file),
            to_hlo_text(
                fn,
                jax.ShapeDtypeStruct((n,), jnp.float32),
                jax.ShapeDtypeStruct((k,), jnp.float32),
                jax.ShapeDtypeStruct((n,), jnp.float32),
            ),
        )

        goldens = None
        if op.get("goldens"):
            rng = np.random.RandomState(4242)
            v = rng.randn(n).astype(np.float32)
            u = rng.rand(n).astype(np.float32)
            # 3-bit NUQSGD-style exponential init levels for the golden run.
            levels = np.array([0.0] + [0.5 ** (k - 2 - j) for j in range(k - 1)], np.float32)
            qidx, norms = fn(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u))
            goldens = {key: f"goldens/{name}.{key}.bin" for key in ("v", "levels", "u", "qidx", "norms")}
            _dump(os.path.join(out, goldens["v"]), v)
            _dump(os.path.join(out, goldens["levels"]), levels)
            _dump(os.path.join(out, goldens["u"]), u)
            _dump(os.path.join(out, goldens["qidx"]), np.asarray(qidx))
            _dump(os.path.join(out, goldens["norms"]), np.asarray(norms))

        entries[name] = {**{kk: op[kk] for kk in ("n", "bucket", "k", "norm_type")},
                         "hlo": hlo_file, "goldens": goldens}
    return entries


def build_stats_ops(out: str) -> dict:
    entries = {}
    for name, op in configs.STATS_OPS.items():
        print(f"op {name}:")
        n, bucket, nt = op["n"], op["bucket"], op["norm_type"]

        def fn(v, _bucket=bucket, _nt=nt):
            return stats_pallas(v, _bucket, _nt)

        hlo_file = f"{name}.hlo.txt"
        _write(os.path.join(out, hlo_file),
               to_hlo_text(fn, jax.ShapeDtypeStruct((n,), jnp.float32)))

        goldens = None
        if op.get("goldens"):
            rng = np.random.RandomState(777)
            v = rng.randn(n).astype(np.float32)
            mu, sigma2, norms = fn(jnp.asarray(v))
            goldens = {key: f"goldens/{name}.{key}.bin" for key in ("v", "mu", "sigma2", "norms")}
            _dump(os.path.join(out, goldens["v"]), v)
            _dump(os.path.join(out, goldens["mu"]), np.asarray(mu))
            _dump(os.path.join(out, goldens["sigma2"]), np.asarray(sigma2))
            _dump(os.path.join(out, goldens["norms"]), np.asarray(norms))

        entries[name] = {**{kk: op[kk] for kk in ("n", "bucket", "norm_type")},
                         "hlo": hlo_file, "goldens": goldens}
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also build the ~100M-param lm_medium artifacts")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    os.makedirs(os.path.join(args.out, "goldens"), exist_ok=True)

    manifest = {
        "models": build_models(args.out, args.full),
        "quantize": build_quantize_ops(args.out),
        "stats": build_stats_ops(args.out),
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
