"""L2: JAX model definitions (build-time only; never on the request path).

Two model families, both exposed through a **flat f32 parameter vector** so
the Rust coordinator owns exactly one buffer per replica:

* `mlp` — ReLU MLP classifier (the CIFAR-10 ResNet stand-in, DESIGN.md §3).
* `lm`  — decoder-only transformer language model (the ImageNet stand-in
  and the end-to-end example workload).

The parameter *layout* (ordered (name, shape, init) list) is exported to
`artifacts/manifest.json`; `rust/src/model/init.rs` re-implements the same
initializers over the same layout so Rust can seed fresh replicas without
Python. Goldens dumped by aot.py pin the two implementations together.

Train steps are `f(params_flat, batch...) -> (loss, grads_flat)`, lowered
once to HLO text by aot.py and executed from Rust via PJRT.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "mlp_specs",
    "lm_specs",
    "specs_for",
    "param_count",
    "init_flat",
    "unflatten",
    "mlp_loss",
    "mlp_eval",
    "lm_loss",
    "make_train_step",
    "make_mlp_eval_step",
    "make_lm_eval_step",
]


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """One named parameter tensor in the flat layout.

    init kinds (mirrored in rust/src/model/init.rs):
      - "zeros", "ones"
      - "normal":  N(0, std^2)
      - "he":      N(0, 2 / fan_in) with fan_in = shape[0]
    """

    name: str
    shape: tuple[int, ...]
    init: str
    std: float = 0.0

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def mlp_specs(cfg: dict) -> list[ParamSpec]:
    dims = [cfg["input_dim"], *cfg["hidden"], cfg["classes"]]
    specs: list[ParamSpec] = []
    for i in range(len(dims) - 1):
        specs.append(ParamSpec(f"fc{i}.w", (dims[i], dims[i + 1]), "he"))
        specs.append(ParamSpec(f"fc{i}.b", (dims[i + 1],), "zeros"))
    return specs


def lm_specs(cfg: dict) -> list[ParamSpec]:
    v, d, t = cfg["vocab"], cfg["d_model"], cfg["seq_len"]
    nl = cfg["n_layers"]
    # GPT-2-style init: 0.02, residual projections scaled by 1/sqrt(2*nl).
    std, rstd = 0.02, 0.02 / math.sqrt(2.0 * nl)
    specs: list[ParamSpec] = [
        ParamSpec("embed", (v, d), "normal", std),
        ParamSpec("pos", (t, d), "normal", 0.01),
    ]
    for l in range(nl):
        p = f"blk{l}."
        specs += [
            ParamSpec(p + "ln1.g", (d,), "ones"),
            ParamSpec(p + "ln1.b", (d,), "zeros"),
            ParamSpec(p + "attn.wqkv", (d, 3 * d), "normal", std),
            ParamSpec(p + "attn.bqkv", (3 * d,), "zeros"),
            ParamSpec(p + "attn.wo", (d, d), "normal", rstd),
            ParamSpec(p + "attn.bo", (d,), "zeros"),
            ParamSpec(p + "ln2.g", (d,), "ones"),
            ParamSpec(p + "ln2.b", (d,), "zeros"),
            ParamSpec(p + "mlp.w1", (d, 4 * d), "normal", std),
            ParamSpec(p + "mlp.b1", (4 * d,), "zeros"),
            ParamSpec(p + "mlp.w2", (4 * d, d), "normal", rstd),
            ParamSpec(p + "mlp.b2", (d,), "zeros"),
        ]
    specs += [
        ParamSpec("lnf.g", (d,), "ones"),
        ParamSpec("lnf.b", (d,), "zeros"),
        ParamSpec("head", (d, v), "normal", std),
    ]
    return specs


def specs_for(cfg: dict) -> list[ParamSpec]:
    return mlp_specs(cfg) if cfg["kind"] == "mlp" else lm_specs(cfg)


def param_count(specs: list[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def init_flat(specs: list[ParamSpec], seed: int) -> np.ndarray:
    """Deterministic numpy init over the layout.

    Each tensor gets its own RandomState(seed + index); goldens dumped by
    aot.py pin the values for the Rust integration tests (Rust uses its own
    RNG for fresh seeds — statistically, not bitwise, identical).
    """
    out = np.empty(param_count(specs), dtype=np.float32)
    off = 0
    for i, s in enumerate(specs):
        rng = np.random.RandomState(seed + i)
        if s.init == "zeros":
            x = np.zeros(s.shape, np.float32)
        elif s.init == "ones":
            x = np.ones(s.shape, np.float32)
        elif s.init == "normal":
            x = rng.randn(*s.shape).astype(np.float32) * s.std
        elif s.init == "he":
            fan_in = s.shape[0]
            x = rng.randn(*s.shape).astype(np.float32) * math.sqrt(2.0 / fan_in)
        else:  # pragma: no cover
            raise ValueError(f"unknown init {s.init!r}")
        out[off : off + s.size] = x.ravel()
        off += s.size
    return out


def unflatten(flat: jnp.ndarray, specs: list[ParamSpec]) -> dict[str, jnp.ndarray]:
    params: dict[str, jnp.ndarray] = {}
    off = 0
    for s in specs:
        params[s.name] = flat[off : off + s.size].reshape(s.shape)
        off += s.size
    return params


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_apply(params: dict, cfg: dict, x: jnp.ndarray) -> jnp.ndarray:
    n_layers = len(cfg["hidden"]) + 1
    h = x
    for i in range(n_layers):
        h = h @ params[f"fc{i}.w"] + params[f"fc{i}.b"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def _xent(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def mlp_loss(flat: jnp.ndarray, specs, cfg: dict, x: jnp.ndarray, y: jnp.ndarray):
    params = unflatten(flat, specs)
    return _xent(mlp_apply(params, cfg, x), y)


def mlp_eval(flat: jnp.ndarray, specs, cfg: dict, x: jnp.ndarray, y: jnp.ndarray):
    params = unflatten(flat, specs)
    logits = mlp_apply(params, cfg, x)
    loss = _xent(logits, y)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------


def _layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _attention(x: jnp.ndarray, params: dict, prefix: str, n_heads: int) -> jnp.ndarray:
    b, t, d = x.shape
    hd = d // n_heads
    qkv = x @ params[prefix + "attn.wqkv"] + params[prefix + "attn.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ params[prefix + "attn.wo"] + params[prefix + "attn.bo"]


def lm_apply(params: dict, cfg: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    _, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][:t][None]
    for l in range(cfg["n_layers"]):
        p = f"blk{l}."
        h = _layer_norm(x, params[p + "ln1.g"], params[p + "ln1.b"])
        x = x + _attention(h, params, p, cfg["n_heads"])
        h = _layer_norm(x, params[p + "ln2.g"], params[p + "ln2.b"])
        h = jax.nn.gelu(h @ params[p + "mlp.w1"] + params[p + "mlp.b1"])
        x = x + h @ params[p + "mlp.w2"] + params[p + "mlp.b2"]
    x = _layer_norm(x, params["lnf.g"], params["lnf.b"])
    return x @ params["head"]


def lm_loss(flat: jnp.ndarray, specs, cfg: dict, tokens: jnp.ndarray):
    params = unflatten(flat, specs)
    logits = lm_apply(params, cfg, tokens)
    return _xent(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# Step builders (what aot.py lowers)
# ---------------------------------------------------------------------------


def make_train_step(cfg: dict, specs: list[ParamSpec]):
    """Fused fwd+bwd: (params_flat, batch...) -> (loss, grads_flat)."""
    if cfg["kind"] == "mlp":

        def step(flat, x, y):
            loss, g = jax.value_and_grad(lambda f: mlp_loss(f, specs, cfg, x, y))(flat)
            return loss, g

    else:

        def step(flat, tokens):
            loss, g = jax.value_and_grad(lambda f: lm_loss(f, specs, cfg, tokens))(flat)
            return loss, g

    return step


def make_mlp_eval_step(cfg: dict, specs: list[ParamSpec]):
    def step(flat, x, y):
        return mlp_eval(flat, specs, cfg, x, y)

    return step


def make_lm_eval_step(cfg: dict, specs: list[ParamSpec]):
    def step(flat, tokens):
        return (lm_loss(flat, specs, cfg, tokens),)

    return step
