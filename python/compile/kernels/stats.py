"""L1 Pallas kernel: per-bucket sufficient statistics (Appendix C/K).

At scheduled steps (Algorithm 1, line 4) each worker fits a mixture of
truncated normals to the distribution of normalized gradient coordinates.
The sufficient statistics per bucket are (mu, sigma^2, norm) of
r_i = |v_i| / ||v_bucket||. This kernel computes them fused, one bucket per
grid step (same VMEM-block mapping as quantize.py).

Must match `ref.stats_ref` exactly on identical inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stats_pallas"]


def _stats_kernel(v_ref, mu_ref, sigma2_ref, norm_ref, *, norm_type: str, bucket: int):
    v = v_ref[...]
    if norm_type == "l2":
        nrm = jnp.sqrt(jnp.sum(v * v))
    else:  # linf
        nrm = jnp.max(jnp.abs(v))
    denom = jnp.where(nrm > 0.0, nrm, 1.0)
    r = jnp.abs(v) / denom
    r = jnp.where(nrm > 0.0, r, 0.0)
    r = jnp.clip(r, 0.0, 1.0)
    mu = jnp.sum(r) / bucket
    sigma2 = jnp.maximum(jnp.sum(r * r) / bucket - mu * mu, 0.0)
    mu_ref[0] = mu
    sigma2_ref[0] = sigma2
    norm_ref[0] = nrm


@functools.partial(jax.jit, static_argnames=("bucket", "norm_type"))
def stats_pallas(v: jnp.ndarray, bucket: int, norm_type: str = "l2"):
    """Per-bucket (mu, sigma2, norm) of normalized coordinates of flat `v`."""
    n = v.shape[0]
    assert n % bucket == 0, "length must be a multiple of the bucket size"
    nb = n // bucket
    kernel = functools.partial(_stats_kernel, norm_type=norm_type, bucket=bucket)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bucket,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=True,
    )(v)
    return out[0], out[1], out[2]
