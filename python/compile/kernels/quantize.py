"""L1 Pallas kernel: bucketed stochastic gradient quantization (Section 3).

One grid step processes one bucket. TPU mapping (see DESIGN.md
§Hardware-Adaptation): a bucket is one VMEM block (the analogue of the
paper's CUDA threadblock over a bucket), the level table is tiny and lives
in the block alongside it (scalar-prefetch-like), and the level search is
branchless (a sum of compares against the broadcast level table) so it
vectorizes on the VPU — no warp shuffles needed. The kernel is pure
elementwise + small reductions; there is no MXU work, so the roofline is
memory-bound: ~1 load of v + u and ~0.25x store of qidx per coordinate.

`interpret=True` is mandatory here: real TPU lowering produces a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Interpret mode lowers
to plain HLO ops, which is exactly what the Rust runtime loads.

The kernel must match `ref.quantize_ref` exactly on identical inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_pallas"]


def _quantize_kernel(v_ref, levels_ref, u_ref, qidx_ref, norm_ref, *, norm_type: str, k: int):
    v = v_ref[...]
    u = u_ref[...]
    levels = levels_ref[...]

    if norm_type == "l2":
        nrm = jnp.sqrt(jnp.sum(v * v))
    else:  # linf
        nrm = jnp.max(jnp.abs(v))

    denom = jnp.where(nrm > 0.0, nrm, 1.0)
    r = jnp.abs(v) / denom
    r = jnp.where(nrm > 0.0, r, 0.0)
    r = jnp.clip(r, 0.0, 1.0)

    # Branchless level search: tau = (#levels <= r) - 1 in [0, k-2].
    cmp = (r[:, None] >= levels[None, :]).astype(jnp.int32)
    tau = jnp.sum(cmp, axis=1) - 1
    tau = jnp.clip(tau, 0, k - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    rho = (r - lo) / jnp.maximum(hi - lo, 1e-30)
    idx = tau + (u < rho).astype(jnp.int32)
    sign = jnp.where(v < 0.0, -1, 1)
    qidx_ref[...] = (sign * idx).astype(jnp.int8)
    norm_ref[0] = nrm


@functools.partial(jax.jit, static_argnames=("bucket", "norm_type"))
def quantize_pallas(
    v: jnp.ndarray,
    levels: jnp.ndarray,
    u: jnp.ndarray,
    bucket: int,
    norm_type: str = "l2",
):
    """Quantize flat f32 `v` (len N, multiple of `bucket`) against `levels`.

    Returns `(qidx int8[N], norms f32[N / bucket])`; see ref.quantize_ref.
    """
    n = v.shape[0]
    assert n % bucket == 0, "length must be a multiple of the bucket size"
    nb = n // bucket
    k = levels.shape[0]

    kernel = functools.partial(_quantize_kernel, norm_type=norm_type, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bucket,), lambda i: (i,)),  # v: one bucket per step
            pl.BlockSpec((k,), lambda i: (0,)),  # levels: replicated
            pl.BlockSpec((bucket,), lambda i: (i,)),  # u: one bucket per step
        ],
        out_specs=[
            pl.BlockSpec((bucket,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=True,
    )(v, levels, u)
