"""Pure-jnp reference oracles for the Pallas kernels.

These implement the paper's quantization (Section 3) and the sufficient
statistics used by the adaptive level optimizers (Appendix C/K) with plain
jax.numpy, bucket-parallel. The Pallas kernels in quantize.py / stats.py
must match these bit-for-bit on identical inputs (same f32 op order), and
the Rust `quant::quantizer` must match them up to norm-reduction rounding.

Conventions (shared with the Rust side — keep in sync with
`rust/src/quant/quantizer.rs`):

* `v` is a flat f32 vector whose length is a multiple of `bucket`.
* `levels` is the *magnitude* level vector `[0 = l_0 < l_1 < ... < l_{K-1} = 1]`
  (paper notation: K = s + 2). Signs are carried separately.
* `u` is a flat f32 vector of uniform[0,1) variates, one per coordinate,
  supplied by the caller so that quantization is a deterministic function
  of its inputs (no PRNG inside the kernel).
* The quantized representation is a signed level index `qidx` (int8,
  `sign(v_i) * idx_i`) plus one f32 norm per bucket.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "bucket_norms",
    "normalized_coords",
    "quantize_ref",
    "dequantize_ref",
    "stats_ref",
    "coord_variance_ref",
]


def bucket_norms(v: jnp.ndarray, bucket: int, norm_type: str) -> jnp.ndarray:
    """Per-bucket norm (L2 or Linf) of the flat vector `v`."""
    vb = v.reshape(-1, bucket)
    if norm_type == "l2":
        return jnp.sqrt(jnp.sum(vb * vb, axis=1))
    if norm_type == "linf":
        return jnp.max(jnp.abs(vb), axis=1)
    raise ValueError(f"unknown norm_type {norm_type!r}")


def normalized_coords(v: jnp.ndarray, bucket: int, norm_type: str) -> jnp.ndarray:
    """r_i = |v_i| / ||bucket(v_i)||, clipped to [0, 1]; 0 where the norm is 0."""
    vb = v.reshape(-1, bucket)
    norms = bucket_norms(v, bucket, norm_type)
    denom = jnp.where(norms > 0.0, norms, 1.0)
    r = jnp.abs(vb) / denom[:, None]
    r = jnp.where(norms[:, None] > 0.0, r, 0.0)
    return jnp.clip(r, 0.0, 1.0)


def quantize_ref(
    v: jnp.ndarray,
    levels: jnp.ndarray,
    u: jnp.ndarray,
    bucket: int,
    norm_type: str = "l2",
):
    """Stochastic quantization of Section 3.

    Returns `(qidx int8[N], norms f32[N / bucket])`.

    For each coordinate: find tau with l_tau <= r < l_{tau+1}, round up to
    tau+1 with probability rho = (r - l_tau) / (l_{tau+1} - l_tau) (i.e.
    when `u < rho`), else down to tau. The emitted symbol is the signed
    level index.
    """
    n = v.shape[0]
    assert n % bucket == 0, "length must be a multiple of the bucket size"
    nb = n // bucket
    vb = v.reshape(nb, bucket)
    ub = u.reshape(nb, bucket)
    norms = bucket_norms(v, bucket, norm_type)
    r = normalized_coords(v, bucket, norm_type)

    k = levels.shape[0]
    # tau = (#levels <= r) - 1, branchless; levels[0] == 0 so tau >= 0.
    cmp = (r[..., None] >= levels[None, None, :]).astype(jnp.int32)
    tau = jnp.sum(cmp, axis=-1) - 1
    tau = jnp.clip(tau, 0, k - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    rho = (r - lo) / jnp.maximum(hi - lo, 1e-30)
    idx = tau + (ub < rho).astype(jnp.int32)
    sign = jnp.where(vb < 0.0, -1, 1)
    qidx = (sign * idx).astype(jnp.int8).reshape(n)
    return qidx, norms


def dequantize_ref(
    qidx: jnp.ndarray,
    norms: jnp.ndarray,
    levels: jnp.ndarray,
    bucket: int,
) -> jnp.ndarray:
    """DECODE of Appendix D (minus the entropy coding): v_hat = sign * l_|idx| * norm."""
    q = qidx.astype(jnp.int32).reshape(-1, bucket)
    mag = levels[jnp.abs(q)]
    sgn = jnp.sign(q).astype(levels.dtype)
    return (sgn * mag * norms[:, None]).reshape(-1)


def stats_ref(v: jnp.ndarray, bucket: int, norm_type: str = "l2"):
    """Per-bucket sufficient statistics of the normalized coordinates.

    Returns `(mu f32[B], sigma2 f32[B], norms f32[B])` where mu/sigma2 are
    the population mean/variance of r within each bucket — exactly what the
    truncated-normal estimator in `rust/src/adaptive/estimator.rs` consumes.
    """
    norms = bucket_norms(v, bucket, norm_type)
    r = normalized_coords(v, bucket, norm_type)
    mu = jnp.mean(r, axis=1)
    sigma2 = jnp.mean(r * r, axis=1) - mu * mu
    sigma2 = jnp.maximum(sigma2, 0.0)
    return mu, sigma2, norms


def coord_variance_ref(r: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Exact per-coordinate quantization variance sigma^2(r) of Eq. (2):

    sigma^2(r) = (l_{tau+1} - r)(r - l_tau).
    """
    k = levels.shape[0]
    cmp = (r[..., None] >= levels[None, :]).astype(jnp.int32)
    tau = jnp.clip(jnp.sum(cmp, axis=-1) - 1, 0, k - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    return (hi - r) * (r - lo)
