"""Named model / kernel-artifact configurations shared by aot.py and tests.

The Rust side consumes these through `artifacts/manifest.json`; the names
here are the artifact base names. Keep in sync with DESIGN.md §5.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# Models.
#
# The paper trains ResNet-32/110 on CIFAR-10 and ResNet-18 on ImageNet.
# Per DESIGN.md §3 those are substituted with an MLP classifier on synthetic
# Gaussian blobs and a decoder-only transformer LM on a synthetic corpus.
# `lm_small` is the end-to-end example model; `lm_medium` approximates the
# brief's ~100M-parameter target and is built with `--full` only.
# ---------------------------------------------------------------------------

MODELS: dict[str, dict] = {
    # Fast cross-check model: goldens are dumped for this one.
    "mlp_tiny": {
        "kind": "mlp",
        "input_dim": 16,
        "hidden": [32, 32],
        "classes": 4,
        "batch": 8,
        "seed": 1234,
        "goldens": True,
    },
    # The CIFAR-10 stand-in used by the quickstart example.
    "mlp_small": {
        "kind": "mlp",
        "input_dim": 64,
        "hidden": [256, 256, 256],
        "classes": 10,
        "batch": 128,
        "seed": 1234,
        "goldens": False,
    },
    # LM used by python tests; goldens dumped.
    "lm_tiny": {
        "kind": "lm",
        "vocab": 256,
        "d_model": 64,
        "n_layers": 2,
        "n_heads": 2,
        "seq_len": 32,
        "batch": 4,
        "seed": 1234,
        "goldens": True,
    },
    # End-to-end training example (examples/train_lm.rs): ~5.8M params.
    "lm_small": {
        "kind": "lm",
        "vocab": 2048,
        "d_model": 256,
        "n_layers": 6,
        "n_heads": 8,
        "seq_len": 96,
        "batch": 8,
        "seed": 1234,
        "goldens": False,
    },
    # ~100M-parameter configuration (built with `aot.py --full` only;
    # too slow to *train* on CPU-PJRT, but compiles and loads).
    "lm_medium": {
        "kind": "lm",
        "vocab": 8192,
        "d_model": 768,
        "n_layers": 12,
        "n_heads": 12,
        "seq_len": 128,
        "batch": 4,
        "seed": 1234,
        "goldens": False,
        "full_only": True,
    },
}

# ---------------------------------------------------------------------------
# Kernel artifacts: standalone HLO for the Pallas quantize / stats kernels,
# loaded by the Rust runtime in integration tests and the quantize_hlo bench.
# `k` is the number of magnitude levels (2^(bits-1), DESIGN.md §6): 3 bits -> 4.
# ---------------------------------------------------------------------------

QUANTIZE_OPS: dict[str, dict] = {
    "quantize_tiny": {"n": 1024, "bucket": 64, "k": 4, "norm_type": "l2", "goldens": True},
    "quantize_tiny_linf": {"n": 1024, "bucket": 64, "k": 4, "norm_type": "linf", "goldens": True},
    "quantize_main": {"n": 65536, "bucket": 8192, "k": 4, "norm_type": "l2", "goldens": False},
}

STATS_OPS: dict[str, dict] = {
    "stats_tiny": {"n": 1024, "bucket": 64, "norm_type": "l2", "goldens": True},
    "stats_main": {"n": 65536, "bucket": 8192, "norm_type": "l2", "goldens": False},
}
