"""L2 model checks: layouts, shapes, gradient sanity, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


MLP = configs.MODELS["mlp_tiny"]
LM = configs.MODELS["lm_tiny"]


def _batch(cfg, seed=0):
    rng = np.random.RandomState(seed)
    if cfg["kind"] == "mlp":
        x = rng.randn(cfg["batch"], cfg["input_dim"]).astype(np.float32)
        y = rng.randint(0, cfg["classes"], size=(cfg["batch"],)).astype(np.int32)
        return (jnp.asarray(x), jnp.asarray(y))
    toks = rng.randint(0, cfg["vocab"], size=(cfg["batch"], cfg["seq_len"])).astype(np.int32)
    return (jnp.asarray(toks),)


@pytest.mark.parametrize("cfg", [MLP, LM], ids=["mlp", "lm"])
def test_layout_roundtrip(cfg):
    specs = model.specs_for(cfg)
    flat = model.init_flat(specs, 0)
    assert flat.shape == (model.param_count(specs),)
    params = model.unflatten(jnp.asarray(flat), specs)
    # Repack and compare.
    repacked = np.concatenate([np.asarray(params[s.name]).ravel() for s in specs])
    np.testing.assert_array_equal(repacked, flat)
    # Names unique, offsets contiguous.
    assert len({s.name for s in specs}) == len(specs)


@pytest.mark.parametrize("cfg", [MLP, LM], ids=["mlp", "lm"])
def test_train_step_shapes_and_finite(cfg):
    specs = model.specs_for(cfg)
    step = jax.jit(model.make_train_step(cfg, specs))
    flat = jnp.asarray(model.init_flat(specs, cfg["seed"]))
    loss, grads = step(flat, *_batch(cfg))
    assert loss.shape == ()
    assert grads.shape == flat.shape
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(grads)).all()


def test_init_determinism():
    specs = model.specs_for(LM)
    a = model.init_flat(specs, 42)
    b = model.init_flat(specs, 42)
    c = model.init_flat(specs, 43)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_mlp_loss_decreases_under_sgd():
    cfg = MLP
    specs = model.specs_for(cfg)
    step = jax.jit(model.make_train_step(cfg, specs))
    flat = jnp.asarray(model.init_flat(specs, 0))
    batch = _batch(cfg, seed=1)
    loss0, _ = step(flat, *batch)
    for _ in range(30):
        _, g = step(flat, *batch)
        flat = flat - 0.1 * g
    loss1, _ = step(flat, *batch)
    assert float(loss1) < float(loss0) * 0.5


def test_lm_loss_starts_near_uniform():
    cfg = LM
    specs = model.specs_for(cfg)
    flat = jnp.asarray(model.init_flat(specs, cfg["seed"]))
    loss = model.lm_loss(flat, specs, cfg, *_batch(cfg))
    assert abs(float(loss) - np.log(cfg["vocab"])) < 0.5


def test_lm_causality():
    """Changing a future token must not change past logits."""
    cfg = LM
    specs = model.specs_for(cfg)
    flat = jnp.asarray(model.init_flat(specs, cfg["seed"]))
    params = model.unflatten(flat, specs)
    (toks,) = _batch(cfg)
    logits_a = model.lm_apply(params, cfg, toks)
    toks_b = toks.at[:, -1].set((toks[:, -1] + 1) % cfg["vocab"])
    logits_b = model.lm_apply(params, cfg, toks_b)
    np.testing.assert_allclose(
        np.asarray(logits_a[:, :-1]), np.asarray(logits_b[:, :-1]), atol=1e-5
    )


def test_eval_steps():
    specs = model.specs_for(MLP)
    ev = jax.jit(model.make_mlp_eval_step(MLP, specs))
    flat = jnp.asarray(model.init_flat(specs, 0))
    loss, acc = ev(flat, *_batch(MLP))
    assert 0.0 <= float(acc) <= 1.0
    specs = model.specs_for(LM)
    ev = jax.jit(model.make_lm_eval_step(LM, specs))
    flat = jnp.asarray(model.init_flat(specs, 0))
    (loss,) = ev(flat, *_batch(LM))
    assert np.isfinite(float(loss))


def test_grads_match_finite_difference():
    cfg = MLP
    specs = model.specs_for(cfg)
    flat = jnp.asarray(model.init_flat(specs, 3))
    batch = _batch(cfg, seed=2)
    loss_fn = lambda f: model.mlp_loss(f, specs, cfg, *batch)
    g = jax.grad(loss_fn)(flat)
    rng = np.random.RandomState(0)
    idxs = rng.choice(flat.shape[0], size=5, replace=False)
    eps = 1e-3
    for i in idxs:
        e = jnp.zeros_like(flat).at[i].set(eps)
        fd = (float(loss_fn(flat + e)) - float(loss_fn(flat - e))) / (2 * eps)
        np.testing.assert_allclose(fd, float(g[i]), atol=2e-3)
