"""Pallas quantize kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes/buckets/levels/norms; statistical tests check the
paper's Section 3 properties (unbiasedness, Eq. (2) variance).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    quantize_ref,
    dequantize_ref,
    coord_variance_ref,
    bucket_norms,
)
from compile.kernels.quantize import quantize_pallas


def make_levels(k: int, kind: str) -> np.ndarray:
    """Magnitude levels [0 < ... < 1] of length k."""
    if kind == "uniform":
        return np.linspace(0.0, 1.0, k).astype(np.float32)
    # exponential, p = 0.5 (NUQSGD init)
    return np.array([0.0] + [0.5 ** (k - 2 - j) for j in range(k - 1)], np.float32)


def rand_inputs(rng, n):
    v = rng.randn(n).astype(np.float32)
    u = rng.rand(n).astype(np.float32)
    return v, u


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 8),
    bucket_log2=st.integers(2, 8),
    k=st.sampled_from([2, 3, 4, 5, 8, 16]),
    kind=st.sampled_from(["uniform", "exp"]),
    norm_type=st.sampled_from(["l2", "linf"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(nb, bucket_log2, k, kind, norm_type, seed):
    bucket = 1 << bucket_log2
    n = nb * bucket
    rng = np.random.RandomState(seed)
    v, u = rand_inputs(rng, n)
    levels = jnp.asarray(make_levels(k, kind))
    q_ref, n_ref = quantize_ref(jnp.asarray(v), levels, jnp.asarray(u), bucket, norm_type)
    q_pal, n_pal = quantize_pallas(jnp.asarray(v), levels, jnp.asarray(u), bucket, norm_type)
    q_ref, q_pal = np.asarray(q_ref), np.asarray(q_pal)
    if norm_type == "linf":
        # max is reduction-order independent -> bit-exact across layers.
        np.testing.assert_array_equal(q_ref, q_pal)
        np.testing.assert_array_equal(np.asarray(n_ref), np.asarray(n_pal))
    else:
        # L2 norms may differ in the last ulp (blocked vs 2D reduction
        # order); that can flip a coordinate sitting exactly on a level
        # boundary by at most one level, with vanishing probability.
        np.testing.assert_allclose(np.asarray(n_ref), np.asarray(n_pal), rtol=1e-6)
        diff = np.abs(q_ref.astype(np.int32) - q_pal.astype(np.int32))
        assert diff.max() <= 1
        assert (diff != 0).mean() <= 1e-3


@pytest.mark.parametrize("norm_type", ["l2", "linf"])
def test_deterministic(norm_type):
    rng = np.random.RandomState(3)
    v, u = rand_inputs(rng, 256)
    levels = jnp.asarray(make_levels(4, "exp"))
    a = quantize_pallas(jnp.asarray(v), levels, jnp.asarray(u), 64, norm_type)
    b = quantize_pallas(jnp.asarray(v), levels, jnp.asarray(u), 64, norm_type)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


@pytest.mark.parametrize("kind", ["uniform", "exp"])
def test_unbiased(kind):
    """E[Q(v)] = v (Theorem 2, first claim), tested over many random draws."""
    rng = np.random.RandomState(7)
    n, bucket, trials = 128, 64, 600
    v = rng.randn(n).astype(np.float32)
    levels = jnp.asarray(make_levels(4, kind))
    acc = np.zeros(n, np.float64)
    for _ in range(trials):
        u = rng.rand(n).astype(np.float32)
        q, norms = quantize_ref(jnp.asarray(v), levels, jnp.asarray(u), bucket, "l2")
        acc += np.asarray(dequantize_ref(q, norms, levels, bucket), np.float64)
    vhat = acc / trials
    # Monte-Carlo CI: per-coord std of q is <= norm/2; 600 trials -> ~4 sigma.
    norms = np.asarray(bucket_norms(jnp.asarray(v), bucket, "l2"))
    tol = 4.0 * norms.max() / np.sqrt(trials)
    np.testing.assert_allclose(vhat, v, atol=tol)


def test_empirical_variance_matches_eq2():
    """Var[q(r)] = (l_{tau+1} - r)(r - l_tau) per coordinate (Eq. 2)."""
    rng = np.random.RandomState(11)
    n, bucket, trials = 64, 64, 4000
    v = rng.randn(n).astype(np.float32)
    levels = jnp.asarray(make_levels(4, "uniform"))
    norms = np.asarray(bucket_norms(jnp.asarray(v), bucket, "l2"))
    r = np.abs(v) / norms[0]
    want = np.asarray(coord_variance_ref(jnp.asarray(r.astype(np.float32)), levels))
    acc = np.zeros(n, np.float64)
    for _ in range(trials):
        u = rng.rand(n).astype(np.float32)
        q, ns = quantize_ref(jnp.asarray(v), levels, jnp.asarray(u), bucket, "l2")
        d = np.asarray(dequantize_ref(q, ns, levels, bucket), np.float64)
        acc += (d - v) ** 2
    got = acc / trials / norms[0] ** 2
    np.testing.assert_allclose(got, want, atol=5e-2)


def test_output_in_level_set():
    rng = np.random.RandomState(13)
    v, u = rand_inputs(rng, 512)
    levels = make_levels(5, "exp")
    q, norms = quantize_ref(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u), 64, "l2")
    q = np.asarray(q)
    assert q.dtype == np.int8
    assert np.abs(q).max() <= len(levels) - 1
    d = np.asarray(dequantize_ref(jnp.asarray(q), norms, jnp.asarray(levels), 64))
    mags = np.abs(d.reshape(-1, 64)) / np.asarray(norms)[:, None]
    for m in np.unique(mags.round(6)):
        assert np.any(np.isclose(m, levels, atol=1e-5)), m


def test_zero_bucket():
    v = np.zeros(128, np.float32)
    u = np.full(128, 0.5, np.float32)
    levels = jnp.asarray(make_levels(4, "uniform"))
    q, norms = quantize_pallas(jnp.asarray(v), levels, jnp.asarray(u), 64, "l2")
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(norms) == 0.0)


def test_linf_extreme_coordinate_hits_top_level():
    """Under Linf, the max coordinate has r = 1 and must map to the top level."""
    rng = np.random.RandomState(17)
    v, u = rand_inputs(rng, 64)
    levels = make_levels(4, "uniform")
    q, _ = quantize_ref(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u), 64, "linf")
    i = int(np.argmax(np.abs(v)))
    assert abs(int(np.asarray(q)[i])) == len(levels) - 1
