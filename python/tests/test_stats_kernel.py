"""Pallas stats kernel vs oracle + basic statistical sanity."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import stats_ref, normalized_coords
from compile.kernels.stats import stats_pallas


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 8),
    bucket_log2=st.integers(2, 8),
    norm_type=st.sampled_from(["l2", "linf"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(nb, bucket_log2, norm_type, seed):
    bucket = 1 << bucket_log2
    n = nb * bucket
    v = np.random.RandomState(seed).randn(n).astype(np.float32)
    ref = stats_ref(jnp.asarray(v), bucket, norm_type)
    pal = stats_pallas(jnp.asarray(v), bucket, norm_type)
    for a, b in zip(ref, pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("norm_type", ["l2", "linf"])
def test_stats_against_numpy(norm_type):
    rng = np.random.RandomState(5)
    bucket = 128
    v = rng.randn(4 * bucket).astype(np.float32)
    mu, sigma2, norms = map(np.asarray, stats_pallas(jnp.asarray(v), bucket, norm_type))
    r = np.asarray(normalized_coords(jnp.asarray(v), bucket, norm_type))
    np.testing.assert_allclose(mu, r.mean(axis=1), rtol=1e-5)
    np.testing.assert_allclose(sigma2, r.var(axis=1), rtol=1e-4, atol=1e-7)
    if norm_type == "linf":
        np.testing.assert_allclose(norms, np.abs(v.reshape(4, -1)).max(axis=1))


def test_gaussian_bucket_moments():
    """For N(0,1) coords under L2 norm over a large bucket, r ~ |x|/sqrt(n):
    E[r] ~ sqrt(2/pi)/sqrt(n), Var[r] ~ (1 - 2/pi)/n."""
    rng = np.random.RandomState(6)
    bucket = 1 << 14
    v = rng.randn(bucket).astype(np.float32)
    mu, sigma2, _ = map(np.asarray, stats_pallas(jnp.asarray(v), bucket, "l2"))
    np.testing.assert_allclose(mu[0], np.sqrt(2 / np.pi) / np.sqrt(bucket), rtol=5e-2)
    np.testing.assert_allclose(sigma2[0], (1 - 2 / np.pi) / bucket, rtol=1e-1)


def test_zero_bucket():
    v = np.zeros(64, np.float32)
    mu, sigma2, norms = map(np.asarray, stats_pallas(jnp.asarray(v), 64, "l2"))
    assert mu[0] == 0 and sigma2[0] == 0 and norms[0] == 0
