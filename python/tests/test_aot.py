"""AOT path checks: HLO text emits, parses, and manifest is consistent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, configs, model
from compile.kernels.quantize import quantize_pallas


def test_to_hlo_text_mlp_train(tmp_path):
    cfg = configs.MODELS["mlp_tiny"]
    specs = model.specs_for(cfg)
    p = model.param_count(specs)
    text = aot.to_hlo_text(
        model.make_train_step(cfg, specs),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((cfg["batch"], cfg["input_dim"]), jnp.float32),
        jax.ShapeDtypeStruct((cfg["batch"],), jnp.int32),
    )
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Output must be a tuple of (loss, grads).
    assert f"f32[{p}]" in text


def test_to_hlo_text_pallas_quantize():
    text = aot.to_hlo_text(
        lambda v, l, u: quantize_pallas(v, l, u, 64, "l2"),
        jax.ShapeDtypeStruct((256,), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
        jax.ShapeDtypeStruct((256,), jnp.float32),
    )
    assert text.startswith("HloModule")
    # interpret=True must lower to plain HLO: no Mosaic custom-calls.
    assert "mosaic" not in text.lower()


def test_manifest_and_goldens(tmp_path, monkeypatch):
    """Run the full AOT build with tiny-only configs into a temp dir."""
    tiny_models = {k: v for k, v in configs.MODELS.items() if k == "mlp_tiny"}
    tiny_q = {k: v for k, v in configs.QUANTIZE_OPS.items() if k == "quantize_tiny"}
    tiny_s = {k: v for k, v in configs.STATS_OPS.items() if k == "stats_tiny"}
    monkeypatch.setattr(configs, "MODELS", tiny_models)
    monkeypatch.setattr(configs, "QUANTIZE_OPS", tiny_q)
    monkeypatch.setattr(configs, "STATS_OPS", tiny_s)

    out = str(tmp_path)
    os.makedirs(os.path.join(out, "goldens"), exist_ok=True)
    manifest = {
        "models": aot.build_models(out, full=False),
        "quantize": aot.build_quantize_ops(out),
        "stats": aot.build_stats_ops(out),
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    m = manifest["models"]["mlp_tiny"]
    # Layout sizes sum to param_count.
    total = sum(int(np.prod(e["shape"])) for e in m["layout"])
    assert total == m["param_count"]
    # Artifacts exist and parse as HLO text.
    for key in ("train_hlo", "eval_hlo"):
        path = os.path.join(out, m[key])
        assert os.path.exists(path)
        assert open(path).read(9) == "HloModule"
    # Goldens round-trip: loss recomputed from dumped params/batch matches.
    g = m["goldens"]
    flat = np.fromfile(os.path.join(out, g["params"]), np.float32)
    assert flat.shape[0] == m["param_count"]
    x = np.fromfile(os.path.join(out, g["in0"]), np.float32).reshape(
        m["config"]["batch"], m["config"]["input_dim"]
    )
    y = np.fromfile(os.path.join(out, g["in1"]), np.int32)
    loss = np.fromfile(os.path.join(out, g["loss"]), np.float32)[0]
    grads = np.fromfile(os.path.join(out, g["grads"]), np.float32)
    cfg = configs.MODELS["mlp_tiny"]
    specs = model.specs_for(cfg)
    step = jax.jit(model.make_train_step(cfg, specs))
    loss2, grads2 = step(jnp.asarray(flat), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(loss, float(loss2), rtol=1e-6)
    np.testing.assert_allclose(grads, np.asarray(grads2), rtol=1e-5, atol=1e-7)

    q = manifest["quantize"]["quantize_tiny"]
    qidx = np.fromfile(os.path.join(out, q["goldens"]["qidx"]), np.int8)
    assert qidx.shape[0] == q["n"]
    assert np.abs(qidx).max() <= q["k"] - 1
