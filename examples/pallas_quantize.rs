//! L1 showcase: run the AOT-compiled Pallas quantization kernel from Rust
//! via PJRT on a synthetic gradient and cross-check it against the native
//! Rust quantizer on the same inputs (same uniform variates).
//!
//!     make artifacts && cargo run --release --example pallas_quantize

use anyhow::Result;
use aqsgd::quant::{Levels, NormType, Quantizer};
use aqsgd::runtime::{Manifest, QuantizeOp, Runtime, StatsOp};
use aqsgd::util::Rng;
use std::time::Instant;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load_default()?;
    let op = &manifest.quantize["quantize_main"];
    let qop = QuantizeOp::load(&rt, op)?;
    let sop = StatsOp::load(&rt, &manifest.stats["stats_main"])?;
    println!(
        "Pallas quantize artifact: n={}, bucket={}, k={} ({} grid steps)",
        op.n,
        op.bucket,
        op.k,
        op.n / op.bucket
    );

    // Synthetic gradient + shared uniforms.
    let mut rng = Rng::new(1);
    let v: Vec<f32> = (0..op.n).map(|_| (rng.normal() * 0.01) as f32).collect();
    let mut u = vec![0.0f32; op.n];
    rng.fill_uniform_f32(&mut u);
    let levels = Levels::exponential(op.k, 0.5);
    let levels_f32 = levels.mags_f32();

    // Device-side (interpret-lowered Pallas via PJRT).
    let t0 = Instant::now();
    let (qidx_dev, norms_dev) = qop.run(&v, &levels_f32, &u)?;
    let t_dev = t0.elapsed();

    // Host-side (the coordinator's native quantizer), same uniforms.
    let quant = Quantizer::new(levels.clone(), NormType::L2, op.bucket);
    let t0 = Instant::now();
    let host = quant.quantize_with_u(&v, &u);
    let t_host = t0.elapsed();

    let mismatch = qidx_dev
        .iter()
        .zip(&host.qidx)
        .filter(|(a, b)| a != b)
        .count();
    println!(
        "symbols: {} device vs host mismatches out of {} ({:.5}%) — L2 last-ulp only",
        mismatch,
        op.n,
        100.0 * mismatch as f64 / op.n as f64
    );
    assert!((mismatch as f64 / op.n as f64) < 1e-3);
    for (a, b) in norms_dev.iter().zip(&host.norms) {
        assert!((a - b).abs() / b.abs().max(1e-20) < 1e-5);
    }

    // Device-side sufficient statistics (Algorithm 1, line 4).
    let (mu, s2, _norms) = sop.run(&v)?;
    println!(
        "stats kernel: first bucket mu={:.5} sigma2={:.3e} (expected ~{:.5} for N(0,0.01²))",
        mu[0],
        s2[0],
        (2.0 / std::f64::consts::PI).sqrt() / (op.bucket as f64).sqrt()
    );

    println!(
        "\ntiming on {} coords: device(interpret) {:?}, host {:?}",
        op.n, t_dev, t_host
    );
    println!("(interpret-mode wallclock is NOT a TPU proxy — see DESIGN.md §Perf)");
    println!("pallas_quantize OK — kernel and coordinator agree.");
    Ok(())
}
