//! Quickstart: the full three-layer stack in one page.
//!
//! Loads the AOT-compiled MLP (L2 JAX → HLO), runs a few data-parallel
//! steps with ALQ 3-bit adaptive quantization (L3 Rust: quantize →
//! Huffman encode → meter → decode → aggregate → momentum SGD), and
//! prints losses, communication bits, and the adapted levels.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use aqsgd::model::{HloMlpTask, TrainTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::Method;
use aqsgd::runtime::{Manifest, Runtime};
use aqsgd::sim::{Cluster, ClusterConfig, NetworkModel};

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load_default()?;
    println!("PJRT platform: {}", rt.platform());

    let workers = 4;
    let mut task = HloMlpTask::load(&rt, &manifest, "mlp_small", workers, 7)?;
    let d = task.param_count();
    println!("model: mlp_small ({d} params), {workers} workers, ALQ @ 3 bits\n");

    let iters = 60;
    let cfg = ClusterConfig {
        method: Method::Alq,
        workers,
        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
        bucket: 1024,
        iters,
        lr: LrSchedule::paper_default(0.05, iters),
        updates: UpdateSchedule::at(vec![2, 10], 25, 10),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 1,
        eval_every: 15,
        variance_every: 15,
        network: NetworkModel::paper_testbed(),
        parallel: aqsgd::exchange::ParallelMode::Auto,
        topology: aqsgd::exchange::TopologySpec::Flat,
        codec: aqsgd::quant::Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        pipeline: aqsgd::exchange::PipelineMode::Off,
        faults: aqsgd::sim::FaultPlan::default(),
    };
    let rec = Cluster::new(cfg).train(&mut task);

    println!("step   train-loss   bits(step)   ");
    for s in rec.steps.iter().step_by(10) {
        println!("{:>4}   {:>10.4}   {:>10}", s.step, s.train_loss, s.bits);
    }
    println!("\nevals (validation):");
    for (step, ev) in &rec.evals {
        println!("  step {step:>4}: loss {:.4}, acc {:.3}", ev.loss, ev.accuracy);
    }
    println!("\nfinal levels (adapted): {:?}", rec.final_levels.unwrap());
    println!(
        "total communication: {:.2} Mbit over {} steps ({:.1}% of fp32)",
        rec.comm_bits as f64 / 1e6,
        iters,
        100.0 * rec.comm_bits as f64 / (iters * workers * 32 * d) as f64
    );
    println!(
        "modelled comm time @1Gbit/s ring: {:.3}s (fp32 would be {:.3}s)",
        rec.comm_time,
        NetworkModel::paper_testbed().fp32_step_time(d, workers) * iters as f64
    );
    println!("\nquickstart OK — L1 kernel semantics + L2 HLO + L3 coordinator compose.");
    Ok(())
}
