//! End-to-end driver (DESIGN.md §6): train the AOT-compiled transformer
//! LM (~5.8M params) on the synthetic Markov corpus with M = 4 workers
//! under ALQ 3-bit quantization for a few hundred steps, logging the loss
//! curve. Proves all three layers compose on a real training workload:
//! JAX/Pallas-authored fwd+bwd executes via PJRT, the Rust coordinator
//! owns quantization, coding, adaptation, and optimization.
//!
//!     make artifacts && cargo run --release --example train_lm [-- steps]
//!
//! Results recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use aqsgd::metrics::Series;
use aqsgd::model::{HloLmTask, TrainTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::Method;
use aqsgd::runtime::{Manifest, Runtime};
use aqsgd::sim::{Cluster, ClusterConfig, NetworkModel};
use std::time::Instant;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let model = std::env::var("AQSGD_LM_MODEL").unwrap_or_else(|_| "lm_small".into());

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load_default()?;
    let workers = 4;
    println!("loading {model} …");
    let t_load = Instant::now();
    let mut task = HloLmTask::load(&rt, &manifest, &model, 99)?;
    let d = task.param_count();
    println!(
        "compiled in {:.1}s; {d} params, vocab {}, seq {}, batch {} × {workers} workers",
        t_load.elapsed().as_secs_f64(),
        task.entry().cfg("vocab"),
        task.entry().cfg("seq_len"),
        task.entry().cfg("batch"),
    );

    let cfg = ClusterConfig {
        method: Method::Alq,
        workers,
        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
        bucket: 8192, // the paper's ImageNet bucket size
        iters: steps,
        lr: LrSchedule {
            lr0: 3e-3 * 0.1, // effective: transformer wants ~3e-4-scale
            factor: 0.3,
            drops: vec![steps * 6 / 10, steps * 8 / 10],
        },
        updates: UpdateSchedule::at(vec![2, 20], steps / 5, 20),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 3,
        eval_every: (steps / 10).max(1),
        variance_every: 0,
        network: NetworkModel::paper_testbed(),
        parallel: aqsgd::exchange::ParallelMode::Auto,
        topology: aqsgd::exchange::TopologySpec::Flat,
        codec: aqsgd::quant::Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        pipeline: aqsgd::exchange::PipelineMode::Off,
        faults: aqsgd::sim::FaultPlan::default(),
    };

    println!("\ntraining {steps} steps with ALQ @ 3 bits, bucket 8192 …");
    let t0 = Instant::now();
    let rec = Cluster::new(cfg).train(&mut task);
    let wall = t0.elapsed().as_secs_f64();

    let mut s = Series::new("train_loss");
    for st in &rec.steps {
        s.push(st.step, st.train_loss);
    }
    let mut v = Series::new("val_loss");
    for (step, ev) in &rec.evals {
        v.push(*step, ev.loss);
    }
    let out = aqsgd::exp::common::out_dir().join("train_lm_loss.csv");
    Series::save_csv(&[s, v], &out)?;

    println!("\nstep   train-loss");
    for st in rec.steps.iter().step_by((steps / 15).max(1)) {
        println!("{:>5}  {:.4}", st.step, st.train_loss);
    }
    let first = rec.steps.first().unwrap().train_loss;
    let last_avg: f64 = rec.steps.iter().rev().take(10).map(|s| s.train_loss).sum::<f64>() / 10.0;
    println!("\nval losses: {:?}", rec.evals.iter().map(|(s, e)| (*s, (e.loss * 1e3).round() / 1e3)).collect::<Vec<_>>());
    println!("\nloss: {first:.3} → {last_avg:.3} (uniform would be ln(vocab) = {:.3})", (task.entry().cfg("vocab") as f64).ln());
    println!("levels adapted to: {:?}", rec.final_levels.unwrap());
    println!(
        "communication: {:.1} Mbit total = {:.1}% of fp32; {} level updates",
        rec.comm_bits as f64 / 1e6,
        100.0 * rec.comm_bits as f64 / (steps * workers * 32 * d) as f64,
        rec.level_updates
    );
    println!(
        "wall: {wall:.1}s ({:.2}s/step; codec {:.3}s total = {:.2}% of wall)",
        wall / steps as f64,
        rec.codec_seconds,
        100.0 * rec.codec_seconds / wall
    );
    println!("loss curve written to {out:?}");

    anyhow::ensure!(last_avg < first * 0.8, "LM failed to learn");
    println!("\ntrain_lm OK");
    Ok(())
}
