//! Distributed coordinator demo: a leader and M workers exchanging
//! Huffman-coded quantized gradients over loopback TCP (Algorithm 1,
//! wire-true). All replicas must end bit-identical.
//!
//!     cargo run --release --example cluster_demo

use anyhow::Result;
use aqsgd::coordinator::{run_worker, WorkerConfig};
use aqsgd::data::Blobs;
use aqsgd::model::{Mlp, MlpTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::Method;
use std::net::TcpListener;

fn main() -> Result<()> {
    let world = 4;
    let iters = 300;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("leader on {addr}, world {world}, {iters} steps, ALQ @ 3 bits");

    let leader = std::thread::spawn(move || {
        aqsgd::coordinator::leader::run_leader_on(listener, world, iters).unwrap()
    });

    let mut handles = Vec::new();
    for w in 0..world {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world,
                method: Method::Alq,
                bits: aqsgd::exchange::BitsPolicy::Fixed(3),
                bucket: 512,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::paper_default(iters),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 42,
                topology: aqsgd::exchange::TopologySpec::Flat,
                codec: aqsgd::quant::Codec::Huffman,
                quantize_impl: aqsgd::quant::QuantizeImpl::default(),
                pipeline: aqsgd::exchange::PipelineMode::Off,
                faults: aqsgd::sim::FaultPlan::default(),
            };
            let blobs = Blobs::generate(32, 10, 16384, 1024, 0.8, 7);
            let mut task = MlpTask::new(Mlp::new(vec![32, 128, 128, 10]), blobs, 16, world, 7);
            run_worker(&cfg, &mut task).unwrap()
        }));
    }

    let reports: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let relayed = leader.join().unwrap();

    println!("\nworker  val-acc  params-hash        sent-Mbit  level-updates");
    for (w, r) in reports.iter().enumerate() {
        println!(
            "{w:>6}  {:>7.4}  {:016x}  {:>9.2}  {:>13}",
            r.final_eval.accuracy,
            r.params_hash,
            r.sent_bits as f64 / 1e6,
            r.level_updates
        );
    }
    let h0 = reports[0].params_hash;
    assert!(
        reports.iter().all(|r| r.params_hash == h0),
        "replica divergence!"
    );
    println!("\nleader relayed {:.2} Mbit", relayed as f64 / 1e6);
    println!("all {world} replicas bit-identical ✓  final levels {:?}", reports[0].final_levels.as_ref().unwrap());
    Ok(())
}
