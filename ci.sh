#!/usr/bin/env bash
# CI entry point: format check, lints, tier-1 build+test, and a one-step
# training smoke run. Also usable locally: ./ci.sh
#
# fmt/clippy are skipped with a warning when the components are not
# installed (the offline build image ships only cargo+rustc).
set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n==> %s\n' "$*"; }

# fmt/clippy are advisory (report, don't gate): the tier-1 contract is
# build+test+smoke. Flip ADVISORY_LINTS=0 to make them hard failures.
ADVISORY_LINTS="${ADVISORY_LINTS:-1}"
lint() {
  if [ "$ADVISORY_LINTS" = "1" ]; then "$@" || step "advisory: '$*' reported issues"; else "$@"; fi
}

if cargo fmt --version >/dev/null 2>&1; then
  step "cargo fmt --check (advisory)"
  lint cargo fmt --all -- --check
else
  step "cargo fmt not installed — skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
  step "cargo clippy (advisory)"
  lint cargo clippy --all-targets
  # The exchange and quant trees are held to -D warnings: the bit-budget
  # refactor keeps rust/src/exchange/ clippy-clean and the hot-loop speed
  # pass extends that to rust/src/quant/; regressions in either gate.
  step "cargo clippy gate: rust/src/{exchange,quant} must be warning-free"
  clippy_out=$(cargo clippy --all-targets --message-format=short 2>&1 || true)
  if printf '%s\n' "$clippy_out" | grep -E '^rust/src/(exchange|quant)/[^ ]*: (warning|error)'; then
    echo "FAIL: clippy findings in rust/src/{exchange,quant} (held to -D warnings)"
    exit 1
  fi
else
  step "cargo clippy not installed — skipping lints"
fi

step "tier-1: cargo build --release"
cargo build --release

step "tier-1: cargo test -q"
cargo test -q

step "bench targets compile (cargo bench --no-run)"
cargo bench --no-run

step "bench smoke: emit + validate BENCH_hotloop.json"
# Small sizes/windows (BENCH_SMOKE=1): this checks the perf-artifact
# plumbing and the fast-path speed floors, not absolute numbers. The
# exchange bench runs last and validates every section landed; the
# encode bench asserts the >= 2x fast-vs-cursor bar on 4-bit
# fixed-width encode.
rm -f BENCH_hotloop.json
BENCH_SMOKE=1 BENCH_JSON=BENCH_hotloop.json cargo bench --bench quantize
BENCH_SMOKE=1 BENCH_JSON=BENCH_hotloop.json cargo bench --bench encode
BENCH_SMOKE=1 BENCH_JSON=BENCH_hotloop.json cargo bench --bench exchange
test -s BENCH_hotloop.json || { echo "FAIL: BENCH_hotloop.json missing or empty"; exit 1; }
grep -q '"schema":"aqsgd-bench-hotloop/v1"' BENCH_hotloop.json \
  || { echo "FAIL: BENCH_hotloop.json lacks the aqsgd-bench-hotloop/v1 schema tag"; exit 1; }

step "smoke: one-iteration training run (serial + parallel exchange)"
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --parallel off
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --parallel on

step "smoke: one-step hierarchical topology run"
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --topology tree:2

step "smoke: one-step sharded topology run with parallel lanes"
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --topology sharded:2 --parallel on

step "smoke: scheduled bit budget (width switches mid-run)"
./target/release/aqsgd train --iters 12 --seeds 1 --bucket 512 --bits-policy schedule:4@0,2@6

step "smoke: variance bit budget over the tree topology"
./target/release/aqsgd train --iters 12 --seeds 1 --bucket 512 --topology tree:2 --bits-policy variance:2-4

step "docs build (cargo doc --no-deps; gate: no missing_docs warnings)"
doc_out=$(cargo doc --no-deps 2>&1) || { printf '%s\n' "$doc_out"; exit 1; }
printf '%s\n' "$doc_out"
if printf '%s' "$doc_out" | grep -q "missing documentation"; then
  echo "FAIL: missing_docs warnings (the exchange tree is #![warn(missing_docs)])"
  exit 1
fi

step "ci.sh OK"
