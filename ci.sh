#!/usr/bin/env bash
# CI entry point: format check, lints, tier-1 build+test, and a one-step
# training smoke run. Also usable locally: ./ci.sh
#
# fmt/clippy are skipped with a warning when the components are not
# installed (the offline build image ships only cargo+rustc).
set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n==> %s\n' "$*"; }

# fmt/clippy are advisory (report, don't gate): the tier-1 contract is
# build+test+smoke. Flip ADVISORY_LINTS=0 to make them hard failures.
ADVISORY_LINTS="${ADVISORY_LINTS:-1}"
lint() {
  if [ "$ADVISORY_LINTS" = "1" ]; then "$@" || step "advisory: '$*' reported issues"; else "$@"; fi
}

if cargo fmt --version >/dev/null 2>&1; then
  step "cargo fmt --check (advisory)"
  lint cargo fmt --all -- --check
else
  step "cargo fmt not installed — skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
  step "cargo clippy (advisory)"
  lint cargo clippy --all-targets
  # The exchange, quant, and trace trees are held to -D warnings: the
  # bit-budget refactor keeps rust/src/exchange/ clippy-clean (which
  # covers the error-feedback + lazy-aggregation subsystem in
  # rust/src/exchange/feedback.rs), the hot-loop speed pass extends
  # that to rust/src/quant/, and the telemetry subsystem to
  # rust/src/trace/; regressions in any gate.
  step "cargo clippy gate: rust/src/{exchange,quant,trace} must be warning-free"
  clippy_out=$(cargo clippy --all-targets --message-format=short 2>&1 || true)
  if printf '%s\n' "$clippy_out" | grep -E '^rust/src/(exchange|quant|trace)/[^ ]*: (warning|error)'; then
    echo "FAIL: clippy findings in rust/src/{exchange,quant,trace} (held to -D warnings)"
    exit 1
  fi
else
  step "cargo clippy not installed — skipping lints"
fi

step "tier-1: cargo build --release"
cargo build --release

step "tier-1: cargo test -q"
cargo test -q

step "bench targets compile (cargo bench --no-run)"
cargo bench --no-run

step "perf trajectories are committed (BENCH_hotloop.json, BENCH_pipeline.json)"
# The perf-trajectory artifacts live in the repo root so regressions
# are reviewable diffs. Fail loudly BEFORE regeneration if either is
# missing — a bench refactor that silently stops emitting them would
# otherwise pass CI while erasing the trajectory.
test -s BENCH_hotloop.json || {
  echo "FAIL: BENCH_hotloop.json is missing from the repo root. Regenerate with the"
  echo "      bench-smoke chain below and commit the updated artifact."
  exit 1
}
test -s BENCH_pipeline.json || {
  echo "FAIL: BENCH_pipeline.json is missing from the repo root. Regenerate with"
  echo "      BENCH_SMOKE=1 BENCH_PIPELINE_JSON=BENCH_pipeline.json cargo bench --bench exchange"
  echo "      and commit the updated artifact."
  exit 1
}

step "bench smoke: emit + validate BENCH_hotloop.json + BENCH_pipeline.json"
# Small sizes/windows (BENCH_SMOKE=1): this checks the perf-artifact
# plumbing and the fast-path speed floors, not absolute numbers. The
# exchange bench runs last, validates every hotloop section landed, and
# emits the pipeline document (overlap ledger, TCP wire steps/s off vs
# overlap, stale:1); the encode bench asserts the >= 2x fast-vs-cursor
# bar on 4-bit fixed-width encode.
rm -f BENCH_hotloop.json BENCH_pipeline.json
BENCH_SMOKE=1 BENCH_JSON=BENCH_hotloop.json cargo bench --bench quantize
BENCH_SMOKE=1 BENCH_JSON=BENCH_hotloop.json cargo bench --bench encode
BENCH_SMOKE=1 BENCH_JSON=BENCH_hotloop.json BENCH_PIPELINE_JSON=BENCH_pipeline.json \
  cargo bench --bench exchange
test -s BENCH_hotloop.json || { echo "FAIL: BENCH_hotloop.json missing or empty"; exit 1; }
grep -q '"schema":"aqsgd-bench-hotloop/v1"' BENCH_hotloop.json \
  || { echo "FAIL: BENCH_hotloop.json lacks the aqsgd-bench-hotloop/v1 schema tag"; exit 1; }
test -s BENCH_pipeline.json || { echo "FAIL: BENCH_pipeline.json missing or empty"; exit 1; }
grep -q '"schema":"aqsgd-bench-pipeline/v1"' BENCH_pipeline.json \
  || { echo "FAIL: BENCH_pipeline.json lacks the aqsgd-bench-pipeline/v1 schema tag"; exit 1; }

step "smoke: one-iteration training run (serial + parallel exchange)"
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --parallel off
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --parallel on

step "smoke: one-step hierarchical topology run"
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --topology tree:2

step "smoke: one-step sharded topology run with parallel lanes"
./target/release/aqsgd train --iters 1 --seeds 1 --bucket 512 --topology sharded:2 --parallel on

step "smoke: pipelined exchange — overlap (bit-identical) and stale:1 (one step late)"
./target/release/aqsgd train --iters 2 --seeds 1 --bucket 512 --pipeline overlap
./target/release/aqsgd train --iters 2 --seeds 1 --bucket 512 --pipeline stale:1

step "smoke: error-feedback + lazy skip rounds in the sim"
# thresh:1e30 is unreachable, so every worker-step skips (the marker
# bits and skipped-frame count surface in the per-seed summary); the
# plain --error-feedback run keeps residual memory with every frame
# still sent.
./target/release/aqsgd train --iters 4 --seeds 1 --bucket 512 --error-feedback on
./target/release/aqsgd train --iters 4 --seeds 1 --bucket 512 \
  --error-feedback on --lazy thresh:1e30

step "smoke: scheduled bit budget (width switches mid-run)"
./target/release/aqsgd train --iters 12 --seeds 1 --bucket 512 --bits-policy schedule:4@0,2@6

step "smoke: variance bit budget over the tree topology"
./target/release/aqsgd train --iters 12 --seeds 1 --bucket 512 --topology tree:2 --bits-policy variance:2-4

step "smoke: traced train run + trace-summarize validation"
# The summarizer validates every line against the event schema and
# fails if any step's hop bits do not sum to the step total, so this
# smoke is a real end-to-end check of the telemetry contract.
rm -f trace_smoke.jsonl trace_smoke_summary.json
./target/release/aqsgd train --iters 6 --seeds 1 --bucket 512 \
  --bits-policy variance:2-4 --trace trace_smoke.jsonl:debug
./target/release/aqsgd trace-summarize trace_smoke.jsonl --json trace_smoke_summary.json
grep -q '"schema":"aqsgd-trace-summary/v1"' trace_smoke_summary.json \
  || { echo "FAIL: trace summary lacks the aqsgd-trace-summary/v1 schema tag"; exit 1; }

step "smoke: traced tree-over-TCP run (leader + 4 workers)"
rm -f trace_leader.jsonl trace_worker0.jsonl
./target/release/aqsgd leader --bind 127.0.0.1:7719 --world 4 --iters 4 \
  --topology tree:2 --trace trace_leader.jsonl:debug &
leader_pid=$!
sleep 1
./target/release/aqsgd worker --addr 127.0.0.1:7719 --worker 0 --world 4 --iters 4 \
  --topology tree:2 --trace trace_worker0.jsonl:debug &
worker_pids=($!)
for w in 1 2 3; do
  ./target/release/aqsgd worker --addr 127.0.0.1:7719 --worker "$w" --world 4 --iters 4 \
    --topology tree:2 &
  worker_pids+=($!)
done
for pid in "${worker_pids[@]}"; do wait "$pid"; done
wait "$leader_pid"
./target/release/aqsgd trace-summarize trace_leader.jsonl >/dev/null
./target/release/aqsgd trace-summarize trace_worker0.jsonl >/dev/null

step "smoke: elastic membership — kill 1 of 4 workers mid-run over TCP"
# Every worker gets the same fault plan and acts only on its own
# entries: worker 3 exits at the top of step 2, the leader detects the
# EOF, drops it (exactly one member_drop, survivor weights summing to
# 1), and the tree run completes on the remaining three workers.
rm -f trace_fault_leader.jsonl
./target/release/aqsgd leader --bind 127.0.0.1:7720 --world 4 --iters 6 \
  --topology tree:2 --trace trace_fault_leader.jsonl:info &
leader_pid=$!
sleep 1
worker_pids=()
for w in 0 1 2 3; do
  ./target/release/aqsgd worker --addr 127.0.0.1:7720 --worker "$w" --world 4 \
    --iters 6 --topology tree:2 --faults kill:3@2 &
  worker_pids+=($!)
done
for pid in "${worker_pids[@]}"; do wait "$pid"; done
wait "$leader_pid"
drops=$(grep -c '"e":"member_drop"' trace_fault_leader.jsonl || true)
[ "$drops" = "1" ] || { echo "FAIL: expected exactly one member_drop, got $drops"; exit 1; }
grep -q '"e":"member_drop".*"weight_sum":1' trace_fault_leader.jsonl \
  || { echo "FAIL: member_drop event lacks weight_sum 1"; exit 1; }
./target/release/aqsgd trace-summarize trace_fault_leader.jsonl >/dev/null

step "smoke: overlap pipeline over TCP (tree:2 leader + 4 workers, --pipeline overlap)"
# The worker accepts --pipeline overlap on any relay topology (it only
# double-buffers the sharded sender; elsewhere it is a structural
# no-op) — this pins the flag end to end through the real coordinator.
./target/release/aqsgd leader --bind 127.0.0.1:7721 --world 4 --iters 4 \
  --topology tree:2 &
leader_pid=$!
sleep 1
worker_pids=()
for w in 0 1 2 3; do
  ./target/release/aqsgd worker --addr 127.0.0.1:7721 --worker "$w" --world 4 \
    --iters 4 --topology tree:2 --pipeline overlap &
  worker_pids+=($!)
done
for pid in "${worker_pids[@]}"; do wait "$pid"; done
wait "$leader_pid"

step "smoke: error-feedback + lazy skip rounds over TCP (tree:2 leader + 4 workers)"
# Gating is per-worker local state, so workers need not agree on a lazy
# policy: worker 3 runs an unreachable threshold (skips every round,
# sending only 104-bit markers) while workers 0-2 send compensated
# frames. The leader needs no flag — it counts the markers, relays the
# surviving frames, and every skip event must report the senders'
# renormalized weights summing to exactly 1.
rm -f trace_lazy_leader.jsonl
./target/release/aqsgd leader --bind 127.0.0.1:7722 --world 4 --iters 4 \
  --topology tree:2 --trace trace_lazy_leader.jsonl:info &
leader_pid=$!
sleep 1
worker_pids=()
for w in 0 1 2; do
  ./target/release/aqsgd worker --addr 127.0.0.1:7722 --worker "$w" --world 4 \
    --iters 4 --topology tree:2 --error-feedback on &
  worker_pids+=($!)
done
./target/release/aqsgd worker --addr 127.0.0.1:7722 --worker 3 --world 4 \
  --iters 4 --topology tree:2 --error-feedback on --lazy thresh:1e30 &
worker_pids+=($!)
for pid in "${worker_pids[@]}"; do wait "$pid"; done
wait "$leader_pid"
skips=$(grep -c '"e":"skip"' trace_lazy_leader.jsonl || true)
[ "$skips" -ge 1 ] || { echo "FAIL: expected at least one skip event, got $skips"; exit 1; }
grep -q '"e":"skip".*"weight_sum":1' trace_lazy_leader.jsonl \
  || { echo "FAIL: skip event lacks weight_sum 1 (senders must renormalize)"; exit 1; }
./target/release/aqsgd trace-summarize trace_lazy_leader.jsonl >/dev/null

step "docs build (cargo doc --no-deps; gate: no missing_docs warnings)"
doc_out=$(cargo doc --no-deps 2>&1) || { printf '%s\n' "$doc_out"; exit 1; }
printf '%s\n' "$doc_out"
if printf '%s' "$doc_out" | grep -q "missing documentation"; then
  echo "FAIL: missing_docs warnings (the exchange tree is #![warn(missing_docs)])"
  exit 1
fi

step "ci.sh OK"
