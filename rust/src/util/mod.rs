//! Small shared utilities: deterministic RNG, numeric helpers.

pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

/// FNV-1a over raw bytes: the replica/parity fingerprint hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over the little-endian bit patterns of `params`: distributed
/// replicas and the sim/engine parity tests must agree on this exactly.
pub fn hash_params(params: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for p in params {
        for b in p.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Simpson-rule quadrature used by tests and by the histogram fallback.
pub fn simpson<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, n: usize) -> f64 {
    let n = if n % 2 == 0 { n } else { n + 1 };
    let h = (b - a) / n as f64;
    let mut s = f(a) + f(b);
    for i in 1..n {
        let w = if i % 2 == 1 { 4.0 } else { 2.0 };
        s += w * f(a + i as f64 * h);
    }
    s * h / 3.0
}

/// Bisection root finding for a monotone function: returns x in [lo, hi]
/// with f(x) ~ 0. `f(lo)` and `f(hi)` need not bracket strictly — the
/// nearest endpoint is returned if they do not.
pub fn bisect<F: Fn(f64) -> f64>(f: F, mut lo: f64, mut hi: f64, tol: f64, max_iter: usize) -> f64 {
    let flo = f(lo);
    let fhi = f(hi);
    if flo == 0.0 {
        return lo;
    }
    if fhi == 0.0 {
        return hi;
    }
    if flo.signum() == fhi.signum() {
        // No bracket: return the endpoint with the smaller |f|.
        return if flo.abs() <= fhi.abs() { lo } else { hi };
    }
    let rising = flo < 0.0;
    for _ in 0..max_iter {
        let mid = 0.5 * (lo + hi);
        if hi - lo < tol {
            return mid;
        }
        let fm = f(mid);
        if (fm < 0.0) == rising {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Known FNV-1a test vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hash_params_equals_fnv_over_le_bytes() {
        let params = [1.5f32, -0.25, 0.0, f32::MIN_POSITIVE];
        let mut bytes = Vec::new();
        for p in &params {
            bytes.extend_from_slice(&p.to_bits().to_le_bytes());
        }
        assert_eq!(hash_params(&params), fnv1a(&bytes));
        assert_ne!(hash_params(&params), hash_params(&params[..3]));
    }

    #[test]
    fn simpson_integrates_polynomials_exactly() {
        // Simpson is exact on cubics.
        let got = simpson(|x| x * x * x - 2.0 * x + 1.0, 0.0, 2.0, 10);
        let want = 4.0 - 4.0 + 2.0;
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn simpson_sin() {
        let got = simpson(f64::sin, 0.0, std::f64::consts::PI, 200);
        assert!((got - 2.0).abs() < 1e-8);
    }

    #[test]
    fn bisect_finds_sqrt2() {
        let x = bisect(|x| x * x - 2.0, 0.0, 2.0, 1e-12, 200);
        assert!((x - std::f64::consts::SQRT_2).abs() < 1e-10);
    }

    #[test]
    fn bisect_decreasing() {
        let x = bisect(|x| 1.0 - x, 0.0, 3.0, 1e-12, 200);
        assert!((x - 1.0).abs() < 1e-10);
    }

    #[test]
    fn bisect_no_bracket_returns_best_endpoint() {
        let x = bisect(|x| x + 10.0, 0.0, 1.0, 1e-12, 50);
        assert_eq!(x, 0.0);
    }
}
