//! Deterministic, dependency-free PRNG (xoshiro256**) for the whole stack.
//!
//! Every stochastic component (quantization draws, data generation, model
//! init) takes an explicit `Rng` so runs are reproducible per seed — the
//! paper reports mean ± std over seeds, and our experiment harness does
//! the same.

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1) with 53 random bits, as f64.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) with 24 random bits, as f32 (matches the f32
    /// `u` inputs the Pallas kernel consumes).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's method without the rejection step is fine here (n << 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fill a slice with uniform [0,1) f32s.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.f32();
        }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
