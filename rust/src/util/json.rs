//! Minimal JSON parser + serializer (RFC 8259 subset sufficient for
//! `manifest.json` and the `BENCH_*.json` perf artifacts).
//!
//! Dependency-free by necessity (the image vendors only the `xla` crate
//! closure); ~recursive-descent with proper string escapes and number
//! parsing. `Display` emits compact deterministic JSON (object keys are
//! sorted by the `BTreeMap`), so `parse(x.to_string()) == x` for every
//! finite tree.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but panics with a useful message (manifest is trusted).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing key {key:?} in {self:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Insert into an object (creating the map entry or overwriting it).
    /// Panics on non-objects — callers build documents top-down.
    pub fn insert(&mut self, key: &str, v: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), v);
            }
            other => panic!("Json::insert on non-object {other:?}"),
        }
    }

    /// Empty object — the usual starting point for building a document.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
}

impl fmt::Display for Json {
    /// Compact serialization. Numbers use the shortest round-trip `f64`
    /// form, except integral values in the exactly-representable range,
    /// which print without a fractional part; non-finite values have no
    /// JSON spelling and become `null`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_fmt(format_args!("{c}"))?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (manifest is ASCII).
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // UTF-8 passthrough.
                    let start = self.i;
                    let len = utf8_len(c);
                    if self.i + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    self.i += len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.req("c").as_str(), Some("x\ny"));
        let arr = j.req("a").as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert!(arr[2].req("b").is_null());
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""A\t\"\\""#).unwrap();
        assert_eq!(j.as_str(), Some("A\t\"\\"));
    }

    #[test]
    fn whitespace_everywhere() {
        let j = Json::parse(" { \"k\" :\n[ 1 ,\t2 ] } ").unwrap();
        assert_eq!(j.req("k").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn display_roundtrips() {
        let src = r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny\t\"q\\", "d": true, "e": -3}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
        // Deterministic + compact: sorted keys, no whitespace, bare ints.
        assert_eq!(
            out,
            r#"{"a":[1,2.5,{"b":null}],"c":"x\ny\t\"q\\","d":true,"e":-3}"#
        );
    }

    #[test]
    fn display_numbers() {
        assert_eq!(Json::Num(0.0).to_string(), "0");
        assert_eq!(Json::Num(-17.0).to_string(), "-17");
        assert_eq!(Json::Num(0.125).to_string(), "0.125");
        assert_eq!(Json::Num(1.5e300).to_string(), "1.5e300");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let n = Json::Num(1.5e300).to_string();
        assert_eq!(Json::parse(&n).unwrap(), Json::Num(1.5e300));
    }

    #[test]
    fn display_control_chars_roundtrip() {
        let j = Json::Str("a\u{1}b\u{8}c".into());
        assert_eq!(j.to_string(), "\"a\\u0001b\\u0008c\"");
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn insert_builds_objects() {
        let mut doc = Json::obj();
        doc.insert("schema", Json::Str("v1".into()));
        doc.insert("n", Json::Num(3.0));
        assert_eq!(doc.to_string(), r#"{"n":3,"schema":"v1"}"#);
        doc.insert("n", Json::Num(4.0));
        assert_eq!(doc.req("n").as_f64(), Some(4.0));
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("models").is_some());
            let models = j.req("models").as_obj().unwrap();
            assert!(models.contains_key("mlp_tiny"));
        }
    }
}
