//! # aqsgd — Adaptive Gradient Quantization for Data-Parallel SGD
//!
//! Production-quality reproduction of Faghri et al., *"Adaptive Gradient
//! Quantization for Data-Parallel SGD"* (NeurIPS 2020) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (build time): Pallas quantization / statistics kernels
//!   (`python/compile/kernels/`), AOT-lowered to HLO text.
//! * **L2** (build time): JAX model fwd/bwd (`python/compile/model.py`),
//!   AOT-lowered to HLO text.
//! * **L3** (run time, this crate): the data-parallel coordinator —
//!   bucketed stochastic quantization, entropy coding, the ALQ/AMQ
//!   adaptive level optimizers, baselines (QSGDinf/NUQSGD/TRN), the
//!   unified worker-parallel [`exchange`] engine driving both the
//!   M-worker cluster simulation and the TCP leader/worker runtime, and
//!   the experiment harness reproducing every table and figure.
//!
//! Python never runs on the request path: `runtime` loads the HLO
//! artifacts once via PJRT and executes them natively.
//!
//! See `DESIGN.md` for the module inventory and the experiment index.

pub mod adaptive;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exchange;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod opt;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod util;
