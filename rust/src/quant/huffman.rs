//! Canonical Huffman coding over level symbols (Appendix D).
//!
//! The paper uses Huffman codes over the quantization-level alphabet,
//! built from the symbol probabilities of Proposition 6 (closed form under
//! the fitted truncated-normal mixture) or from empirical counts. Codes
//! are canonical so the codebook is summarized by code lengths alone, and
//! decoding uses the standard first-code-per-length walk (fast, no tree).

use super::bitio::{BitReader, BitWriter};

/// Width of the one-shot decode table (codes ≤ this decode in one peek).
const TABLE_BITS: u32 = 11;

/// A canonical Huffman codebook over `n` symbols.
#[derive(Clone, Debug, PartialEq)]
pub struct HuffmanBook {
    /// Code length per symbol (0 = symbol absent from the alphabet).
    lens: Vec<u32>,
    /// Canonical code per symbol.
    codes: Vec<u32>,
    /// Stream-order (bit-reversed) code per symbol — the O(1) encode path.
    rcodes: Vec<u64>,
    /// Decode tables: symbols sorted by (len, symbol), first code and
    /// first index per length.
    sorted_symbols: Vec<u16>,
    first_code: Vec<u32>,  // per length 1..=max_len
    first_index: Vec<u32>, // per length
    max_len: u32,
    /// One-shot decode table over TABLE_BITS-bit peeks: (symbol, len),
    /// len == 0 ⇒ code longer than TABLE_BITS, fall back to the walk.
    table: Vec<(u16, u8)>,
}

impl HuffmanBook {
    /// Build from nonnegative weights (counts or probabilities).
    /// Zero-weight symbols get no code unless everything is zero, in which
    /// case a uniform fixed-length code is produced.
    pub fn from_weights(weights: &[f64]) -> Self {
        assert!(!weights.is_empty() && weights.len() <= u16::MAX as usize);
        assert!(weights.iter().all(|&w| w >= 0.0 && w.is_finite()));
        let n = weights.len();
        let total: f64 = weights.iter().sum();
        let weights: Vec<f64> = if total <= 0.0 {
            vec![1.0; n]
        } else {
            // Floor tiny positive weights at 1e-4 of the max so code depth
            // stays well under 32 bits even for pathologically skewed
            // distributions (Prop. 6 probabilities can underflow); the
            // expected-length impact is < 1e-3 bits.
            let floor = weights.iter().cloned().fold(0.0, f64::max) * 1e-4;
            weights
                .iter()
                .map(|&w| if w > 0.0 { w.max(floor) } else { 0.0 })
                .collect()
        };

        // Package-free Huffman over the present symbols.
        let lens = huffman_lengths(&weights);
        Self::from_lengths(lens)
    }

    /// Build directly from code lengths (canonical assignment).
    pub fn from_lengths(lens: Vec<u32>) -> Self {
        let n = lens.len();
        let max_len = lens.iter().copied().max().unwrap_or(0).max(1);
        // Kraft check.
        let kraft: f64 = lens
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-9, "invalid code lengths (Kraft {kraft})");

        // Sort symbols by (len, symbol); assign canonical codes.
        let mut order: Vec<u16> = (0..n as u16).filter(|&s| lens[s as usize] > 0).collect();
        order.sort_by_key(|&s| (lens[s as usize], s));

        let mut codes = vec![0u32; n];
        let mut first_code = vec![0u32; (max_len + 1) as usize];
        let mut first_index = vec![0u32; (max_len + 1) as usize];
        let mut code = 0u32;
        let mut prev_len = 0u32;
        for (i, &s) in order.iter().enumerate() {
            let l = lens[s as usize];
            code <<= l - prev_len;
            if l != prev_len {
                for fill in prev_len + 1..=l {
                    first_code[fill as usize] = code << 0;
                    first_index[fill as usize] = i as u32;
                }
                // first_code for length l is this code; lengths between
                // prev_len and l (exclusive) have no symbols: their
                // first_code is the shifted running code as well.
            }
            codes[s as usize] = code;
            code += 1;
            prev_len = l;
        }

        // Stream-order codes + the one-shot decode table.
        let mut rcodes = vec![0u64; n];
        let mut table = vec![(0u16, 0u8); 1usize << TABLE_BITS];
        for s in 0..n {
            let l = lens[s];
            if l == 0 {
                continue;
            }
            let rev = (codes[s] as u64).reverse_bits() >> (64 - l);
            rcodes[s] = rev;
            if l <= TABLE_BITS {
                // Every TABLE_BITS peek whose low l bits equal `rev`
                // decodes to s.
                let step = 1usize << l;
                let mut i = rev as usize;
                while i < table.len() {
                    table[i] = (s as u16, l as u8);
                    i += step;
                }
            }
        }
        HuffmanBook {
            lens,
            codes,
            rcodes,
            sorted_symbols: order,
            first_code,
            first_index,
            max_len,
            table,
        }
    }

    pub fn num_symbols(&self) -> usize {
        self.lens.len()
    }

    pub fn len_of(&self, sym: usize) -> u32 {
        self.lens[sym]
    }

    pub fn lengths(&self) -> &[u32] {
        &self.lens
    }

    /// Expected code length under `probs` (for Theorem 5 checks).
    pub fn expected_length(&self, probs: &[f64]) -> f64 {
        probs
            .iter()
            .zip(&self.lens)
            .map(|(&p, &l)| p * l as f64)
            .sum()
    }

    /// Stream-order code for a symbol (for fused sign+symbol pushes).
    #[inline]
    pub fn rcode(&self, sym: usize) -> u64 {
        self.rcodes[sym]
    }

    #[inline]
    pub fn encode(&self, sym: usize, w: &mut BitWriter) {
        debug_assert!(self.lens[sym] > 0, "symbol {sym} has no code");
        w.push_bits_lsb(self.rcodes[sym], self.lens[sym]);
    }

    /// Decode one symbol: one-table fast path, canonical walk fallback
    /// for codes longer than TABLE_BITS.
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> u16 {
        let peek = r.peek_bits(TABLE_BITS) as usize;
        let (sym, len) = self.table[peek];
        if len != 0 {
            r.consume(len as u32);
            return sym;
        }
        self.decode_slow(r)
    }

    #[cold]
    fn decode_slow(&self, r: &mut BitReader) -> u16 {
        let mut code = 0u32;
        let mut len = 0u32;
        loop {
            code = (code << 1) | r.read_bit() as u32;
            len += 1;
            debug_assert!(len <= self.max_len, "corrupt stream");
            // Count of codes of this length: difference of first_index.
            let fi = self.first_index[len as usize];
            let fc = self.first_code[len as usize];
            let count = self.count_at(len);
            if count > 0 && code >= fc && code - fc < count {
                return self.sorted_symbols[(fi + (code - fc)) as usize];
            }
        }
    }

    fn count_at(&self, len: u32) -> u32 {
        let fi = self.first_index[len as usize];
        let next = if (len as usize) + 1 < self.first_index.len() {
            self.first_index[len as usize + 1]
        } else {
            self.sorted_symbols.len() as u32
        };
        // Symbols with exactly this length: those in [fi, next) whose len == len.
        let mut c = 0;
        for i in fi..next {
            if self.lens[self.sorted_symbols[i as usize] as usize] == len {
                c += 1;
            } else {
                break;
            }
        }
        c
    }
}

/// Add-δ smoothing over symbol weights so every level symbol gets a
/// Huffman code: a symbol absent from one batch can still occur later in
/// the run, and — on the distributed path — codebooks derived from it
/// stay total and identical across replicas. Shared by the in-process
/// simulation and the TCP coordinator (it used to be copy-pasted in
/// both; keep this the only definition).
pub fn smooth_weights(weights: &[f64]) -> Vec<f64> {
    let total: f64 = weights.iter().sum();
    let delta = (total * 1e-4).max(1e-6);
    weights.iter().map(|w| w + delta).collect()
}

/// Classic two-queue Huffman code lengths from weights. Symbols with zero
/// weight get length 0 (absent). A single present symbol gets length 1.
fn huffman_lengths(weights: &[f64]) -> Vec<u32> {
    #[derive(Clone)]
    struct Node {
        w: f64,
        kids: Option<(usize, usize)>,
    }
    let mut nodes: Vec<Node> = Vec::new();
    let mut heap: std::collections::BinaryHeap<(std::cmp::Reverse<u64>, usize)> =
        std::collections::BinaryHeap::new();
    // Scale weights to u64 for a deterministic total order.
    let max_w = weights.iter().cloned().fold(0.0, f64::max).max(1e-300);
    let scale = (u64::MAX / 4) as f64 / max_w / weights.len().max(1) as f64;
    let mut present = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        if w > 0.0 {
            nodes.push(Node { w, kids: None });
            let key = ((w * scale) as u64).max(1);
            heap.push((std::cmp::Reverse(key), nodes.len() - 1));
            present += 1;
            let _ = i;
        } else {
            nodes.push(Node { w: 0.0, kids: None });
        }
    }
    let mut lens = vec![0u32; weights.len()];
    if present == 0 {
        return lens;
    }
    if present == 1 {
        let (_, idx) = heap.pop().unwrap();
        lens[idx] = 1;
        return lens;
    }
    // Merge.
    while heap.len() > 1 {
        let (std::cmp::Reverse(wa), a) = heap.pop().unwrap();
        let (std::cmp::Reverse(wb), b) = heap.pop().unwrap();
        nodes.push(Node {
            w: nodes[a].w + nodes[b].w,
            kids: Some((a, b)),
        });
        heap.push((std::cmp::Reverse(wa.saturating_add(wb)), nodes.len() - 1));
    }
    // Depth-first depth assignment.
    let root = heap.pop().unwrap().1;
    let mut stack = vec![(root, 0u32)];
    while let Some((idx, depth)) = stack.pop() {
        match nodes[idx].kids {
            Some((a, b)) => {
                stack.push((a, depth + 1));
                stack.push((b, depth + 1));
            }
            None => {
                lens[idx] = depth.max(1);
            }
        }
    }
    lens
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(book: &HuffmanBook, syms: &[u16]) {
        let mut w = BitWriter::new();
        for &s in syms {
            book.encode(s as usize, &mut w);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &s in syms {
            assert_eq!(book.decode(&mut r), s);
        }
    }

    #[test]
    fn two_symbols() {
        let book = HuffmanBook::from_weights(&[0.9, 0.1]);
        assert_eq!(book.len_of(0), 1);
        assert_eq!(book.len_of(1), 1);
        roundtrip(&book, &[0, 1, 1, 0, 0, 0, 1]);
    }

    #[test]
    fn skewed_distribution_short_codes_for_common() {
        let book = HuffmanBook::from_weights(&[100.0, 10.0, 5.0, 1.0]);
        assert!(book.len_of(0) <= book.len_of(1));
        assert!(book.len_of(1) <= book.len_of(3));
        roundtrip(&book, &[0, 3, 1, 2, 0, 0, 1, 3, 2, 0]);
    }

    #[test]
    fn uniform_distribution_balanced() {
        let book = HuffmanBook::from_weights(&[1.0; 8]);
        for s in 0..8 {
            assert_eq!(book.len_of(s), 3);
        }
        roundtrip(&book, &(0..8u16).collect::<Vec<_>>());
    }

    #[test]
    fn zero_weight_symbols_absent() {
        let book = HuffmanBook::from_weights(&[1.0, 0.0, 2.0, 0.0, 4.0]);
        assert_eq!(book.len_of(1), 0);
        assert_eq!(book.len_of(3), 0);
        roundtrip(&book, &[0, 2, 4, 4, 2, 0]);
    }

    #[test]
    fn all_zero_weights_fall_back_to_uniform() {
        let book = HuffmanBook::from_weights(&[0.0; 4]);
        roundtrip(&book, &[0, 1, 2, 3]);
    }

    #[test]
    fn single_symbol() {
        let book = HuffmanBook::from_weights(&[0.0, 5.0, 0.0]);
        assert_eq!(book.len_of(1), 1);
        roundtrip(&book, &[1, 1, 1]);
    }

    #[test]
    fn optimality_vs_entropy() {
        // Theorem 5: H(X) <= E[L] < H(X) + 1.
        let probs = [0.55, 0.25, 0.1, 0.05, 0.03, 0.02];
        let book = HuffmanBook::from_weights(&probs);
        let h: f64 = probs.iter().map(|&p| -p * p.log2()).sum();
        let el = book.expected_length(&probs);
        assert!(el >= h - 1e-9, "E[L]={el} < H={h}");
        assert!(el < h + 1.0, "E[L]={el} >= H+1={}", h + 1.0);
    }

    #[test]
    fn long_random_stream_roundtrip() {
        let mut rng = crate::util::Rng::new(42);
        let weights: Vec<f64> = (0..17).map(|i| 1.0 / (1 + i) as f64).collect();
        let book = HuffmanBook::from_weights(&weights);
        let syms: Vec<u16> = (0..10_000).map(|_| rng.below(17) as u16).collect();
        roundtrip(&book, &syms);
    }

    #[test]
    fn smoothing_makes_every_symbol_codable() {
        let smoothed = smooth_weights(&[100.0, 0.0, 3.0, 0.0]);
        assert!(smoothed.iter().all(|&w| w > 0.0));
        let book = HuffmanBook::from_weights(&smoothed);
        for s in 0..4 {
            assert!(book.len_of(s) > 0, "symbol {s} got no code");
        }
        // All-zero weights still smooth to a positive floor.
        assert!(smooth_weights(&[0.0; 3]).iter().all(|&w| w >= 1e-6));
    }

    #[test]
    fn kraft_holds() {
        let book = HuffmanBook::from_weights(&[3.0, 1.0, 1.0, 1.0, 0.5, 0.25]);
        let kraft: f64 = book
            .lengths()
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-12);
    }
}
