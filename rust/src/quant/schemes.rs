//! The method zoo of Section 5: baselines and adaptive schemes.
//!
//! | Method    | Levels                    | Norm | Adaptation            |
//! |-----------|---------------------------|------|-----------------------|
//! | SuperSGD  | — (full precision, M-way) | —    | —                     |
//! | SGD       | — (single worker)         | —    | —                     |
//! | QSGDinf   | uniform                   | L∞   | none                  |
//! | TRN       | ternary {−1,0,1} + clip   | L∞   | none                  |
//! | NUQSGD    | exponential p = 0.5       | L2   | none                  |
//! | ALQ       | free                      | L2   | CD, ‖v‖²-weighted     |
//! | ALQ-N     | free                      | L2   | CD, unweighted (Eq.3) |
//! | ALQ-G     | free                      | L2   | safeguarded GD        |
//! | ALQ-GN    | free                      | L2   | GD, unweighted        |
//! | AMQ       | exp multiplier, no zero   | L2   | GD on p, weighted     |
//! | AMQ-N     | exp multiplier, no zero   | L2   | GD on p, unweighted   |

use super::{Levels, NormType};

/// Every training/quantization method evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full-precision data-parallel SGD over M workers (upper bound).
    SuperSgd,
    /// Full-precision single-worker SGD (Fig. 5 reference).
    SingleSgd,
    /// Uniform levels under L∞ (QSGDinf) [20].
    QsgdInf,
    /// TernGrad: ternary levels under L∞ with 2.5σ clipping [15].
    Trn,
    /// NUQSGD: exponential levels p = 0.5 under L2 [21, 22].
    NuqSgd,
    Alq,
    AlqN,
    AlqG,
    AlqGN,
    Amq,
    AmqN,
}

/// How a method adapts its levels at update steps (Algorithm 1, line 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdaptKind {
    None,
    /// ALQ coordinate descent (Theorem 1 / Eq. 33).
    Cd,
    /// Safeguarded gradient descent on the levels (Eq. 7 / 36).
    Gd,
    /// AMQ: gradient descent on the exponential multiplier p (Eq. 8).
    Multiplier,
}

impl Method {
    /// All methods in the paper's presentation order.
    pub const ALL: [Method; 11] = [
        Method::SuperSgd,
        Method::SingleSgd,
        Method::NuqSgd,
        Method::QsgdInf,
        Method::Trn,
        Method::Alq,
        Method::AlqN,
        Method::AlqG,
        Method::AlqGN,
        Method::Amq,
        Method::AmqN,
    ];

    /// The quantized subset (everything that actually compresses).
    pub const QUANTIZED: [Method; 9] = [
        Method::NuqSgd,
        Method::QsgdInf,
        Method::Trn,
        Method::Alq,
        Method::AlqN,
        Method::AlqG,
        Method::AlqGN,
        Method::Amq,
        Method::AmqN,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::SuperSgd => "SuperSGD",
            Method::SingleSgd => "SGD",
            Method::QsgdInf => "QSGDinf",
            Method::Trn => "TRN",
            Method::NuqSgd => "NUQSGD",
            Method::Alq => "ALQ",
            Method::AlqN => "ALQ-N",
            Method::AlqG => "ALQ-G",
            Method::AlqGN => "ALQ-GN",
            Method::Amq => "AMQ",
            Method::AmqN => "AMQ-N",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.name().eq_ignore_ascii_case(s))
    }

    pub fn is_quantized(&self) -> bool {
        !matches!(self, Method::SuperSgd | Method::SingleSgd)
    }

    pub fn adapt_kind(&self) -> AdaptKind {
        match self {
            Method::Alq | Method::AlqN => AdaptKind::Cd,
            Method::AlqG | Method::AlqGN => AdaptKind::Gd,
            Method::Amq | Method::AmqN => AdaptKind::Multiplier,
            _ => AdaptKind::None,
        }
    }

    pub fn is_adaptive(&self) -> bool {
        self.adapt_kind() != AdaptKind::None
    }

    /// `-N` variants minimize the expected *normalized* variance (Eq. 3,
    /// uniform mixture weights); others weight by ‖v_n‖² (Eq. 10).
    pub fn weighted_mixture(&self) -> bool {
        matches!(self, Method::Alq | Method::AlqG | Method::Amq)
    }

    /// Bucket normalization. The paper's framework is general-L^q
    /// (Theorem 2); QSGDinf/TRN are defined with L∞ and NUQSGD with L2.
    /// We run the adaptive methods under L∞ as well: on this testbed's
    /// near-Gaussian synthetic gradients, L2-normalized coordinates
    /// concentrate at ~1/√bucket with an unbounded-ratio tail, which at 3
    /// bits leaves any 4-magnitude level set variance-dominated by the
    /// top bin — an artifact of the substitute workload, not of the
    /// method (deep-net gradients are heavy-tailed; see DESIGN.md §10).
    /// Under L∞ the adaptive-vs-fixed comparison reproduces the paper's
    /// shape, and ALQ/AMQ still optimize the exact variance objective.
    pub fn norm_type(&self) -> NormType {
        match self {
            Method::NuqSgd => NormType::L2,
            _ => NormType::Linf,
        }
    }

    /// TernGrad clips at 2.5σ before quantization (Appendix K.2).
    pub fn clip_factor(&self) -> Option<f32> {
        match self {
            Method::Trn => Some(2.5),
            _ => None,
        }
    }

    /// Initial level set for a bit budget. TRN ignores `bits` (always
    /// ternary); adaptive methods start from the NUQSGD exponential init
    /// (Section 3.1: "we initialize the levels with either uniform levels
    /// or exponentially spaced levels").
    pub fn initial_levels(&self, bits: u32) -> Option<Levels> {
        let k = Levels::mags_for_bits(bits);
        match self {
            Method::SuperSgd | Method::SingleSgd => None,
            Method::QsgdInf => Some(Levels::uniform(k)),
            Method::Trn => Some(Levels::ternary()),
            Method::NuqSgd => Some(Levels::exponential(k, 0.5)),
            Method::Alq | Method::AlqN | Method::AlqG | Method::AlqGN => {
                Some(Levels::exponential(k, 0.5))
            }
            Method::Amq | Method::AmqN => Some(Levels::amq(k, 0.5)),
        }
    }

    /// Effective bits for reporting: TRN is ternary regardless of budget.
    pub fn effective_bits(&self, bits: u32) -> u32 {
        match self {
            Method::Trn => 2,
            _ => bits,
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("alq-n"), Some(Method::AlqN));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn baselines_not_adaptive() {
        for m in [Method::QsgdInf, Method::Trn, Method::NuqSgd] {
            assert!(!m.is_adaptive());
            assert!(m.is_quantized());
        }
        for m in [Method::SuperSgd, Method::SingleSgd] {
            assert!(!m.is_quantized());
            assert!(m.initial_levels(3).is_none());
        }
    }

    #[test]
    fn level_shapes() {
        assert_eq!(Method::QsgdInf.initial_levels(3).unwrap().k(), 4);
        assert_eq!(Method::Trn.initial_levels(3).unwrap().k(), 2);
        assert_eq!(Method::NuqSgd.initial_levels(4).unwrap().k(), 8);
        let amq = Method::Amq.initial_levels(3).unwrap();
        assert!(!amq.has_zero());
        assert_eq!(amq.k(), 4);
    }

    #[test]
    fn norm_assignment() {
        assert_eq!(Method::QsgdInf.norm_type(), NormType::Linf);
        assert_eq!(Method::Trn.norm_type(), NormType::Linf);
        assert_eq!(Method::NuqSgd.norm_type(), NormType::L2);
        assert_eq!(Method::Alq.norm_type(), NormType::Linf);
        assert_eq!(Method::Amq.norm_type(), NormType::Linf);
    }

    #[test]
    fn mixture_weighting() {
        assert!(Method::Alq.weighted_mixture());
        assert!(!Method::AlqN.weighted_mixture());
        assert!(Method::Amq.weighted_mixture());
        assert!(!Method::AmqN.weighted_mixture());
    }

    #[test]
    fn trn_clips() {
        assert_eq!(Method::Trn.clip_factor(), Some(2.5));
        assert_eq!(Method::Alq.clip_factor(), None);
    }
}
