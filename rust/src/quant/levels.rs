//! Quantization level sets.
//!
//! A `Levels` holds the *magnitude* levels of the paper's notation
//! `0 = ℓ_0 < ℓ_1 < … < ℓ_{s+1} = 1` (signs carried separately), or — for
//! AMQ's symmetric exponential scheme (Section 3.3 / Appendix B.3.3) — a
//! zero-free set `p^s < … < p < 1` where the first bin `[−p^s, p^s]`
//! rounds stochastically between `±p^s`.

use crate::util::Rng;

/// Validated, sorted magnitude levels in (0, 1], optionally including 0.
#[derive(Clone, Debug, PartialEq)]
pub struct Levels {
    mags: Vec<f64>,
    has_zero: bool,
}

impl Levels {
    /// Arbitrary levels. `mags` must be strictly increasing, end at 1.0,
    /// and start at 0.0 iff `has_zero`.
    pub fn from_mags(mags: Vec<f64>, has_zero: bool) -> Self {
        assert!(mags.len() >= 2, "need at least two magnitude levels");
        assert!(
            mags.windows(2).all(|w| w[0] < w[1]),
            "levels must be strictly increasing: {mags:?}"
        );
        assert!(
            (mags[mags.len() - 1] - 1.0).abs() < 1e-12,
            "last level must be 1.0: {mags:?}"
        );
        if has_zero {
            assert_eq!(mags[0], 0.0, "first level must be 0.0: {mags:?}");
        } else {
            assert!(mags[0] > 0.0, "no-zero levels must start above 0: {mags:?}");
        }
        Levels { mags, has_zero }
    }

    /// Uniformly spaced `k` magnitudes including 0 and 1 (QSGD / QSGDinf).
    pub fn uniform(k: usize) -> Self {
        assert!(k >= 2);
        let mags = (0..k).map(|j| j as f64 / (k - 1) as f64).collect();
        Levels::from_mags(mags, true)
    }

    /// Exponentially spaced `{0, p^{k-2}, …, p, 1}` (NUQSGD with p = 0.5).
    pub fn exponential(k: usize, p: f64) -> Self {
        assert!(k >= 2);
        assert!(p > 0.0 && p < 1.0);
        let mut mags = vec![0.0];
        for j in (0..k - 1).rev() {
            mags.push(p.powi(j as i32));
        }
        Levels::from_mags(mags, true)
    }

    /// Ternary levels {−1, 0, 1} (TernGrad).
    pub fn ternary() -> Self {
        Levels::uniform(2)
    }

    /// AMQ's symmetric, zero-free exponential levels `[p^s, …, p, 1]`
    /// with `k` magnitudes (s = k − 1).
    pub fn amq(k: usize, p: f64) -> Self {
        assert!(k >= 1);
        assert!(p > 0.0 && p < 1.0);
        let mags = (0..k).rev().map(|j| p.powi(j as i32)).collect();
        Levels::from_mags(mags, false)
    }

    /// Number of magnitude levels the paper's `bits` hyperparameter maps
    /// to: `2^(bits-1)` (3 bits → 4 magnitudes; 2 bits → ternary).
    pub fn mags_for_bits(bits: u32) -> usize {
        assert!(bits >= 2 && bits <= 8, "bits must be in [2, 8], got {bits}");
        1usize << (bits - 1)
    }

    pub fn mags(&self) -> &[f64] {
        &self.mags
    }

    pub fn mags_f32(&self) -> Vec<f32> {
        self.mags.iter().map(|&x| x as f32).collect()
    }

    pub fn has_zero(&self) -> bool {
        self.has_zero
    }

    /// Number of magnitude levels K (= s + 2 when zero is included).
    pub fn k(&self) -> usize {
        self.mags.len()
    }

    /// Number of interior (adaptable) levels `s`.
    pub fn interior(&self) -> usize {
        if self.has_zero {
            self.mags.len().saturating_sub(2)
        } else {
            // first level is adaptable too; only the final 1.0 is pinned
            self.mags.len() - 1
        }
    }

    /// Number of distinct encoded symbols (magnitude indices).
    pub fn num_symbols(&self) -> usize {
        self.mags.len()
    }

    /// Number of distinct signed values representable.
    pub fn num_values(&self) -> usize {
        if self.has_zero {
            2 * self.mags.len() - 1
        } else {
            2 * self.mags.len()
        }
    }

    /// The largest ratio `ℓ_{j+1}/ℓ_j` over consecutive positive levels
    /// (the `j*` of Theorem 2).
    pub fn max_ratio(&self) -> f64 {
        let start = if self.has_zero { 1 } else { 0 };
        self.mags[start..]
            .windows(2)
            .map(|w| w[1] / w[0])
            .fold(1.0, f64::max)
    }

    /// Smallest positive level ℓ_1.
    pub fn smallest_positive(&self) -> f64 {
        if self.has_zero {
            self.mags[1]
        } else {
            self.mags[0]
        }
    }

    /// Replace interior levels, preserving endpoints and ordering.
    /// Values are clamped into a strictly increasing sequence.
    pub fn set_interior(&mut self, vals: &[f64]) {
        let k = self.k();
        if self.has_zero {
            assert_eq!(vals.len(), k - 2);
            for (i, &v) in vals.iter().enumerate() {
                self.mags[i + 1] = v;
            }
        } else {
            assert_eq!(vals.len(), k - 1);
            for (i, &v) in vals.iter().enumerate() {
                self.mags[i] = v;
            }
        }
        self.enforce_order();
    }

    /// Force strict monotonicity after an external update (guards the
    /// feasible set 𝓛 of Eq. 3 against floating-point ties).
    fn enforce_order(&mut self) {
        let eps = 1e-9;
        let lo = if self.has_zero { 1 } else { 0 };
        for i in lo..self.mags.len() - 1 {
            let prev = if i == 0 { 0.0 } else { self.mags[i - 1] };
            self.mags[i] = self.mags[i].max(prev + eps).min(1.0 - eps * (self.mags.len() - 1 - i) as f64);
        }
        let last = self.mags.len() - 1;
        self.mags[last] = 1.0;
    }

    /// Random perturbation of interior levels (used by convergence tests
    /// and the Fig. 8 experiment for random restarts).
    pub fn jitter(&self, rng: &mut Rng, scale: f64) -> Levels {
        let mut out = self.clone();
        let lo = if self.has_zero { 1 } else { 0 };
        for i in lo..out.mags.len() - 1 {
            out.mags[i] = (out.mags[i] + scale * (rng.f64() - 0.5)).clamp(1e-6, 1.0 - 1e-6);
        }
        out.mags[lo..].sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.enforce_order();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_levels() {
        let l = Levels::uniform(4);
        assert_eq!(l.mags(), &[0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]);
        assert!(l.has_zero());
        assert_eq!(l.interior(), 2);
        assert_eq!(l.num_values(), 7);
    }

    #[test]
    fn exponential_levels() {
        let l = Levels::exponential(4, 0.5);
        assert_eq!(l.mags(), &[0.0, 0.25, 0.5, 1.0]);
        assert_eq!(l.max_ratio(), 2.0);
        assert_eq!(l.smallest_positive(), 0.25);
    }

    #[test]
    fn ternary() {
        let l = Levels::ternary();
        assert_eq!(l.mags(), &[0.0, 1.0]);
        assert_eq!(l.num_values(), 3);
    }

    #[test]
    fn amq_levels() {
        let l = Levels::amq(4, 0.5);
        assert_eq!(l.mags(), &[0.125, 0.25, 0.5, 1.0]);
        assert!(!l.has_zero());
        assert_eq!(l.num_values(), 8);
        assert_eq!(l.interior(), 3);
    }

    #[test]
    fn bits_mapping() {
        assert_eq!(Levels::mags_for_bits(2), 2); // ternary
        assert_eq!(Levels::mags_for_bits(3), 4);
        assert_eq!(Levels::mags_for_bits(8), 128);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unsorted() {
        Levels::from_mags(vec![0.0, 0.5, 0.3, 1.0], true);
    }

    #[test]
    #[should_panic(expected = "last level")]
    fn rejects_bad_top() {
        Levels::from_mags(vec![0.0, 0.5], true);
    }

    #[test]
    fn set_interior_keeps_feasible() {
        let mut l = Levels::uniform(4);
        l.set_interior(&[0.9, 0.1]); // deliberately out of order
        let m = l.mags();
        assert!(m.windows(2).all(|w| w[0] < w[1]), "{m:?}");
        assert_eq!(m[0], 0.0);
        assert_eq!(m[3], 1.0);
    }

    #[test]
    fn jitter_stays_feasible() {
        let l = Levels::exponential(8, 0.5);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let j = l.jitter(&mut rng, 0.2);
            assert!(j.mags().windows(2).all(|w| w[0] < w[1]));
            assert_eq!(*j.mags().last().unwrap(), 1.0);
            assert_eq!(j.mags()[0], 0.0);
        }
    }
}
