//! Bucketed stochastic quantization — the L3 hot path.
//!
//! Semantics match `python/compile/kernels/ref.py::quantize_ref` (and thus
//! the L1 Pallas kernel) coordinate-for-coordinate when driven with the
//! same uniform variates. On top of the kernel semantics this adds:
//!
//! * network-wise bucketing with the last partial bucket carried in fp32
//!   (Appendix K: "We only transmit the last bucket in full precision if
//!   it is smaller than the specified bucket size");
//! * AMQ's zero-free symmetric first bin (Appendix B.3.3);
//! * optional TernGrad-style clipping at `c·σ` (Appendix K.2, Eq. 49);
//! * exact variance evaluation via Eq. (1)–(2) for the variance figures.

use super::{bucket_norm, Levels, NormType};
use crate::util::Rng;

/// A quantized gradient: signed level symbols for all full buckets, one
/// fp32 norm per bucket, and a raw fp32 tail (the trailing partial bucket).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedGrad {
    /// Signed symbol per coordinate of the full-bucket prefix.
    /// `has_zero`: symbol = sign·mag_index (0 encodes value 0).
    /// `!has_zero`: symbol = sign·(mag_index + 1) (never 0).
    pub qidx: Vec<i8>,
    /// Per-full-bucket norms.
    pub norms: Vec<f32>,
    /// Raw fp32 tail (len = n % bucket).
    pub tail: Vec<f32>,
    /// Bucket size used.
    pub bucket: usize,
}

impl QuantizedGrad {
    pub fn len(&self) -> usize {
        self.qidx.len() + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reusable scratch for [`Quantizer::quantize_into_with`]: one clip
/// buffer and one uniform-variates buffer shared across buckets — and
/// across steps when owned by an exchange lane — so the hot path does no
/// per-bucket allocation once warm.
#[derive(Clone, Debug, Default)]
pub struct QuantScratch {
    clipped: Vec<f32>,
    uniforms: Vec<f32>,
}

/// Stochastic quantizer for one scheme configuration.
#[derive(Clone, Debug)]
pub struct Quantizer {
    levels: Levels,
    mags: Vec<f32>,
    norm_type: NormType,
    bucket: usize,
    /// TernGrad clipping factor c (clip at c·σ before quantization).
    clip_factor: Option<f32>,
}

impl Quantizer {
    pub fn new(levels: Levels, norm_type: NormType, bucket: usize) -> Self {
        assert!(bucket >= 1);
        let mags = levels.mags_f32();
        Quantizer { levels, mags, norm_type, bucket, clip_factor: None }
    }

    pub fn with_clip(mut self, c: f32) -> Self {
        assert!(c > 0.0);
        self.clip_factor = Some(c);
        self
    }

    pub fn levels(&self) -> &Levels {
        &self.levels
    }

    /// Swap in adapted levels (Algorithm 1 line 4) without reallocating.
    pub fn set_levels(&mut self, levels: Levels) {
        self.mags = levels.mags_f32();
        self.levels = levels;
    }

    pub fn norm_type(&self) -> NormType {
        self.norm_type
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    pub fn clip_factor(&self) -> Option<f32> {
        self.clip_factor
    }

    /// Quantize `v`, drawing one uniform variate per coordinate from `rng`.
    pub fn quantize(&self, v: &[f32], rng: &mut Rng) -> QuantizedGrad {
        // Empty buffers: quantize_into's resize/extend does the one-shot
        // fill, with no redundant zero-init-then-overwrite.
        let mut q = QuantizedGrad {
            qidx: Vec::new(),
            norms: Vec::new(),
            tail: Vec::new(),
            bucket: self.bucket,
        };
        self.quantize_into(v, rng, &mut q);
        q
    }

    /// Quantize into a preallocated `QuantizedGrad` (no allocation when
    /// the shapes already match, aside from a transient local scratch —
    /// steady-state callers hold a [`QuantScratch`] and use
    /// [`Quantizer::quantize_into_with`]).
    pub fn quantize_into(&self, v: &[f32], rng: &mut Rng, out: &mut QuantizedGrad) {
        let mut scratch = QuantScratch::default();
        self.quantize_into_with(v, rng, &mut scratch, out);
    }

    /// The vectorizable fast path: identical draws, symbols, and
    /// subsequent RNG state to [`Quantizer::quantize_into_scalar`]
    /// (pinned by tests), but with the per-bucket uniforms drawn up
    /// front into `scratch` so the per-coordinate loop is a branch-light
    /// threshold sum the autovectorizer can chew in 8–16 coordinate
    /// chunks, and with the clip buffer reused across buckets and steps.
    pub fn quantize_into_with(
        &self,
        v: &[f32],
        rng: &mut Rng,
        scratch: &mut QuantScratch,
        out: &mut QuantizedGrad,
    ) {
        let nb = v.len() / self.bucket;
        let full = nb * self.bucket;
        out.qidx.resize(full, 0);
        out.norms.resize(nb, 0.0);
        out.tail.clear();
        out.tail.extend_from_slice(&v[full..]);
        out.bucket = self.bucket;

        let QuantScratch { clipped, uniforms } = scratch;
        uniforms.resize(self.bucket, 0.0);
        for b in 0..nb {
            let raw = &v[b * self.bucket..(b + 1) * self.bucket];
            let src: &[f32] = if let Some(c) = self.clip_factor {
                clip_bucket_into(raw, c, clipped);
                clipped
            } else {
                raw
            };
            let norm = bucket_norm(src, self.norm_type);
            out.norms[b] = norm;
            let dst = &mut out.qidx[b * self.bucket..(b + 1) * self.bucket];
            if norm == 0.0 {
                // All-zero bucket: symbol 0 (has_zero) / smallest mag with
                // random sign is unnecessary — keep deterministic floor.
                // Draw order matches the scalar path: no draws for the
                // has_zero fill, one per coordinate for the AMQ signs.
                if self.levels.has_zero() {
                    dst.fill(0);
                } else {
                    for d in dst.iter_mut() {
                        *d = if rng.f32() < 0.5 { 1 } else { -1 };
                    }
                }
                continue;
            }
            // One uniform per coordinate, same order the scalar path
            // draws them inline — the determinism contract.
            rng.fill_uniform_f32(uniforms);
            let inv = 1.0 / norm;
            self.quantize_bucket_fast(src, uniforms, dst, inv);
        }
    }

    /// The reference per-coordinate path: one inline `rng.f32()` draw and
    /// one `quantize_coord_*` call per coordinate. Kept as the semantics
    /// the fast path is pinned against (and as `--quantize-impl scalar`).
    pub fn quantize_into_scalar(&self, v: &[f32], rng: &mut Rng, out: &mut QuantizedGrad) {
        let nb = v.len() / self.bucket;
        let full = nb * self.bucket;
        out.qidx.resize(full, 0);
        out.norms.resize(nb, 0.0);
        out.tail.clear();
        out.tail.extend_from_slice(&v[full..]);
        out.bucket = self.bucket;

        let mut clipped_buf: Vec<f32> = Vec::new();
        for b in 0..nb {
            let raw = &v[b * self.bucket..(b + 1) * self.bucket];
            let src: &[f32] = if let Some(c) = self.clip_factor {
                clip_bucket_into(raw, c, &mut clipped_buf);
                &clipped_buf
            } else {
                raw
            };
            let norm = bucket_norm(src, self.norm_type);
            out.norms[b] = norm;
            let dst = &mut out.qidx[b * self.bucket..(b + 1) * self.bucket];
            if norm == 0.0 {
                if self.levels.has_zero() {
                    dst.fill(0);
                } else {
                    for d in dst.iter_mut() {
                        *d = if rng.f32() < 0.5 { 1 } else { -1 };
                    }
                }
                continue;
            }
            let inv = 1.0 / norm;
            if self.levels.has_zero() {
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = self.quantize_coord_zero(x, inv, rng.f32());
                }
            } else {
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = self.quantize_coord_nozero(x, inv, rng.f32());
                }
            }
        }
    }

    /// Dispatch one bucket to the branch-light kernel monomorphized for
    /// its level count (K ∈ 2..=8 covers bits ≤ 4; larger alphabets fall
    /// back to the binary-search coordinate path, fed the same pre-drawn
    /// uniforms so results stay bit-identical either way).
    fn quantize_bucket_fast(&self, src: &[f32], u: &[f32], dst: &mut [i8], inv: f32) {
        let k = self.mags.len();
        if self.levels.has_zero() {
            match k {
                2 => self.bucket_zero_fast::<2>(src, u, dst, inv),
                3 => self.bucket_zero_fast::<3>(src, u, dst, inv),
                4 => self.bucket_zero_fast::<4>(src, u, dst, inv),
                5 => self.bucket_zero_fast::<5>(src, u, dst, inv),
                6 => self.bucket_zero_fast::<6>(src, u, dst, inv),
                7 => self.bucket_zero_fast::<7>(src, u, dst, inv),
                8 => self.bucket_zero_fast::<8>(src, u, dst, inv),
                _ => {
                    for ((d, &x), &ui) in dst.iter_mut().zip(src).zip(u) {
                        *d = self.quantize_coord_zero_u(x, inv, ui);
                    }
                }
            }
        } else {
            match k {
                2 => self.bucket_nozero_fast::<2>(src, u, dst, inv),
                3 => self.bucket_nozero_fast::<3>(src, u, dst, inv),
                4 => self.bucket_nozero_fast::<4>(src, u, dst, inv),
                5 => self.bucket_nozero_fast::<5>(src, u, dst, inv),
                6 => self.bucket_nozero_fast::<6>(src, u, dst, inv),
                7 => self.bucket_nozero_fast::<7>(src, u, dst, inv),
                8 => self.bucket_nozero_fast::<8>(src, u, dst, inv),
                _ => {
                    for ((d, &x), &ui) in dst.iter_mut().zip(src).zip(u) {
                        *d = self.quantize_coord_nozero(x, inv, ui);
                    }
                }
            }
        }
    }

    /// Branch-light has_zero kernel: the level search is an unrolled
    /// threshold sum `tau = Σ_j [r ≥ ℓ_j]` over the K−2 interior levels —
    /// equivalent to the early-exit scan because the levels are sorted —
    /// so the loop body has no data-dependent branches.
    #[inline]
    fn bucket_zero_fast<const K: usize>(&self, src: &[f32], u: &[f32], dst: &mut [i8], inv: f32) {
        let mut m = [0f32; K];
        m.copy_from_slice(&self.mags[..K]);
        for ((d, &x), &ui) in dst.iter_mut().zip(src).zip(u) {
            let r = (x.abs() * inv).clamp(0.0, 1.0);
            let mut tau = 0usize;
            for &level in &m[1..K - 1] {
                tau += (r >= level) as usize;
            }
            let lo = m[tau];
            let hi = m[tau + 1];
            let rho = (r - lo) / (hi - lo).max(1e-30);
            let idx = tau + usize::from(ui < rho);
            let sign = if x < 0.0 { -1i8 } else { 1 };
            *d = sign * idx as i8;
        }
    }

    /// Branch-light AMQ kernel: both the first-bin and far-bin results
    /// are computed, then selected on `r < ℓ_1` — same draws and outputs
    /// as the early-return scalar path.
    #[inline]
    fn bucket_nozero_fast<const K: usize>(
        &self,
        src: &[f32],
        u: &[f32],
        dst: &mut [i8],
        inv: f32,
    ) {
        let mut m = [0f32; K];
        m.copy_from_slice(&self.mags[..K]);
        let l1 = m[0];
        for ((d, &x), &ui) in dst.iter_mut().zip(src).zip(u) {
            let theta = (x * inv).clamp(-1.0, 1.0);
            let r = theta.abs();
            let near = if ui < (theta + l1) / (2.0 * l1) { 1i8 } else { -1 };
            let mut tau = 0usize;
            for &level in &m[1..K - 1] {
                tau += (r >= level) as usize;
            }
            let lo = m[tau];
            let hi = m[tau + 1];
            let rho = (r - lo) / (hi - lo).max(1e-30);
            let idx = tau + usize::from(ui < rho);
            let sign = if theta < 0.0 { -1i8 } else { 1 };
            let far = sign * (idx as i8 + 1);
            *d = if r < l1 { near } else { far };
        }
    }

    /// Kernel-compatible path: consume caller-provided uniforms (used by
    /// the cross-layer bit-for-bit tests against the Pallas artifact).
    pub fn quantize_with_u(&self, v: &[f32], u: &[f32]) -> QuantizedGrad {
        assert_eq!(v.len(), u.len());
        assert!(self.clip_factor.is_none() && self.levels.has_zero());
        let nb = v.len() / self.bucket;
        let full = nb * self.bucket;
        let mut q = QuantizedGrad {
            qidx: vec![0; full],
            norms: vec![0.0; nb],
            tail: v[full..].to_vec(),
            bucket: self.bucket,
        };
        for b in 0..nb {
            let s = b * self.bucket;
            let src = &v[s..s + self.bucket];
            let norm = bucket_norm(src, self.norm_type);
            q.norms[b] = norm;
            if norm == 0.0 {
                continue;
            }
            let inv = 1.0 / norm;
            for i in 0..self.bucket {
                q.qidx[s + i] = self.quantize_coord_zero_u(src[i], inv, u[s + i]);
            }
        }
        q
    }

    #[inline]
    fn quantize_coord_zero(&self, x: f32, inv_norm: f32, u: f32) -> i8 {
        self.quantize_coord_zero_u(x, inv_norm, u)
    }

    /// Level search: linear scan for small K (branch-predictor friendly),
    /// binary search for K > 8 (the bits ≥ 5 regimes — §Perf).
    #[inline]
    fn find_tau(&self, r: f32) -> usize {
        let k = self.mags.len();
        if k <= 8 {
            let mut tau = 0usize;
            while tau + 2 < k && r >= self.mags[tau + 1] {
                tau += 1;
            }
            tau
        } else {
            // partition_point: first index with mags[i] > r.
            let idx = self.mags.partition_point(|&m| m <= r);
            idx.saturating_sub(1).min(k - 2)
        }
    }

    /// Matches the Pallas kernel: branchless-equivalent level search,
    /// round up with probability rho = (r - lo)/(hi - lo) when u < rho.
    #[inline]
    fn quantize_coord_zero_u(&self, x: f32, inv_norm: f32, u: f32) -> i8 {
        let r = (x.abs() * inv_norm).clamp(0.0, 1.0);
        let tau = self.find_tau(r);
        // tau in [0, k-2]; r may still be >= mags[tau+1] only when tau = k-2.
        let lo = self.mags[tau];
        let hi = self.mags[tau + 1];
        let rho = (r - lo) / (hi - lo).max(1e-30);
        let idx = tau + usize::from(u < rho);
        let sign = if x < 0.0 { -1 } else { 1 };
        (sign * idx as i8) as i8
    }

    /// AMQ path (Appendix B.3.3): first bin [−ℓ_1, ℓ_1] rounds between the
    /// two signed smallest levels; symbols are sign·(mag_index + 1).
    #[inline]
    fn quantize_coord_nozero(&self, x: f32, inv_norm: f32, u: f32) -> i8 {
        let theta = (x * inv_norm).clamp(-1.0, 1.0);
        let l1 = self.mags[0];
        let r = theta.abs();
        if r < l1 {
            // q = +l1 w.p. (theta + l1) / (2 l1), else −l1. Unbiased.
            let p_up = (theta + l1) / (2.0 * l1);
            return if u < p_up { 1 } else { -1 };
        }
        let tau = self.find_tau(r);
        let lo = self.mags[tau];
        let hi = self.mags[tau + 1];
        let rho = (r - lo) / (hi - lo).max(1e-30);
        let idx = tau + usize::from(u < rho);
        let sign = if theta < 0.0 { -1 } else { 1 };
        sign * (idx as i8 + 1)
    }

    /// Dequantize into `out` (len must equal `q.len()`).
    pub fn dequantize(&self, q: &QuantizedGrad, out: &mut [f32]) {
        assert_eq!(out.len(), q.len());
        let has_zero = self.levels.has_zero();
        for b in 0..q.norms.len() {
            let norm = q.norms[b];
            let s = b * q.bucket;
            for i in 0..q.bucket {
                let sym = q.qidx[s + i] as i32;
                let mag_idx = if has_zero {
                    sym.unsigned_abs() as usize
                } else if sym == 0 {
                    // only possible for zero-norm AMQ bucket; value 0
                    out[s + i] = 0.0;
                    continue;
                } else {
                    (sym.unsigned_abs() - 1) as usize
                };
                let val = self.mags[mag_idx] * norm;
                out[s + i] = if sym < 0 { -val } else { val };
            }
        }
        let full = q.qidx.len();
        out[full..].copy_from_slice(&q.tail);
    }

    /// Exact quantization MSE `E‖Q(v) − v‖²` of Eq. (1)–(2); when clipping
    /// is enabled this includes the clipping bias² (the estimator becomes
    /// biased; the paper's variance plots report MSE in that case too).
    pub fn exact_variance(&self, v: &[f32]) -> f64 {
        let (var, bias_sq) = self.exact_variance_parts(v);
        var + bias_sq
    }

    /// (stochastic variance, clipping bias²) decomposition of the MSE.
    /// Without clipping the second term is 0 and the first is Eq. (1).
    pub fn exact_variance_parts(&self, v: &[f32]) -> (f64, f64) {
        let nb = v.len() / self.bucket;
        let mut total = 0.0f64;
        let mut total_bias = 0.0f64;
        let mut clipped_buf: Vec<f32> = Vec::new();
        for b in 0..nb {
            let raw = &v[b * self.bucket..(b + 1) * self.bucket];
            let src: &[f32] = if let Some(c) = self.clip_factor {
                clip_bucket_into(raw, c, &mut clipped_buf);
                &clipped_buf
            } else {
                raw
            };
            let norm = bucket_norm(src, self.norm_type) as f64;
            if norm == 0.0 {
                continue;
            }
            let n2 = norm * norm;
            for (i, &x) in src.iter().enumerate() {
                let var = if self.levels.has_zero() {
                    let r = ((x.abs() as f64) / norm).clamp(0.0, 1.0);
                    let (lo, hi) = self.bin_of(r);
                    (hi - r) * (r - lo)
                } else {
                    let theta = ((x as f64) / norm).clamp(-1.0, 1.0);
                    let r = theta.abs();
                    let l1 = self.levels.mags()[0];
                    if r < l1 {
                        l1 * l1 - theta * theta
                    } else {
                        let (lo, hi) = self.bin_of(r);
                        (hi - r) * (r - lo)
                    }
                };
                // Clipping bias: E[q] = clip(x), so MSE adds (clip(x)-x)^2.
                let bias = (src[i] as f64) - (raw[i] as f64);
                total += n2 * var;
                total_bias += bias * bias;
            }
        }
        (total, total_bias)
    }

    #[inline]
    fn bin_of(&self, r: f64) -> (f64, f64) {
        let m = self.levels.mags();
        let k = m.len();
        let mut tau = 0usize;
        while tau + 2 < k && r >= m[tau + 1] {
            tau += 1;
        }
        (m[tau], m[tau + 1])
    }
}

/// TernGrad-style clipping (Eq. 49): clamp coordinates to ±c·σ where σ is
/// the standard deviation of the bucket's coordinates. Writes into a
/// caller-owned buffer so the hot path allocates nothing once warm.
fn clip_bucket_into(v: &[f32], c: f32, out: &mut Vec<f32>) {
    let n = v.len() as f64;
    let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var: f64 = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let lim = (c as f64 * var.sqrt()) as f32;
    out.clear();
    if lim == 0.0 {
        out.extend_from_slice(v);
        return;
    }
    out.extend(v.iter().map(|&x| x.clamp(-lim, lim)));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn roundtrip_values_in_level_set() {
        let q = Quantizer::new(Levels::exponential(4, 0.5), NormType::L2, 64);
        let v = randn(256, 1);
        let mut rng = Rng::new(2);
        let g = q.quantize(&v, &mut rng);
        let mut out = vec![0.0; 256];
        q.dequantize(&g, &mut out);
        for b in 0..4 {
            let norm = g.norms[b];
            for i in 0..64 {
                let mag = out[b * 64 + i].abs() / norm;
                assert!(
                    q.levels().mags().iter().any(|&m| (m - mag as f64).abs() < 1e-5),
                    "mag {mag} not in level set"
                );
            }
        }
    }

    #[test]
    fn partial_tail_is_exact() {
        let q = Quantizer::new(Levels::uniform(4), NormType::L2, 64);
        let v = randn(150, 3); // 2 full buckets + tail of 22
        let mut rng = Rng::new(4);
        let g = q.quantize(&v, &mut rng);
        assert_eq!(g.tail.len(), 22);
        let mut out = vec![0.0; 150];
        q.dequantize(&g, &mut out);
        assert_eq!(&out[128..], &v[128..]);
    }

    #[test]
    fn unbiased_has_zero() {
        let q = Quantizer::new(Levels::exponential(4, 0.5), NormType::L2, 32);
        let v = randn(32, 5);
        let mut rng = Rng::new(6);
        let trials = 4000;
        let mut acc = vec![0.0f64; 32];
        let mut out = vec![0.0f32; 32];
        for _ in 0..trials {
            let g = q.quantize(&v, &mut rng);
            q.dequantize(&g, &mut out);
            for i in 0..32 {
                acc[i] += out[i] as f64;
            }
        }
        let norm = bucket_norm(&v, NormType::L2) as f64;
        for i in 0..32 {
            let mean = acc[i] / trials as f64;
            let tol = 4.0 * norm / (trials as f64).sqrt();
            assert!(
                (mean - v[i] as f64).abs() < tol,
                "coord {i}: {mean} vs {}",
                v[i]
            );
        }
    }

    #[test]
    fn unbiased_amq_nozero() {
        let q = Quantizer::new(Levels::amq(4, 0.5), NormType::L2, 32);
        let v = randn(32, 7);
        let mut rng = Rng::new(8);
        let trials = 4000;
        let mut acc = vec![0.0f64; 32];
        let mut out = vec![0.0f32; 32];
        for _ in 0..trials {
            let g = q.quantize(&v, &mut rng);
            q.dequantize(&g, &mut out);
            for i in 0..32 {
                acc[i] += out[i] as f64;
            }
        }
        let norm = bucket_norm(&v, NormType::L2) as f64;
        for i in 0..32 {
            let mean = acc[i] / trials as f64;
            let tol = 4.0 * norm / (trials as f64).sqrt();
            assert!(
                (mean - v[i] as f64).abs() < tol,
                "coord {i}: {mean} vs {}",
                v[i]
            );
        }
    }

    #[test]
    fn exact_variance_matches_monte_carlo() {
        let q = Quantizer::new(Levels::uniform(4), NormType::L2, 64);
        let v = randn(64, 9);
        let want = q.exact_variance(&v);
        let mut rng = Rng::new(10);
        let trials = 6000;
        let mut acc = 0.0f64;
        let mut out = vec![0.0f32; 64];
        for _ in 0..trials {
            let g = q.quantize(&v, &mut rng);
            q.dequantize(&g, &mut out);
            acc += out
                .iter()
                .zip(&v)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        let got = acc / trials as f64;
        assert!(
            (got - want).abs() / want.max(1e-12) < 0.1,
            "{got} vs {want}"
        );
    }

    #[test]
    fn exact_variance_amq_matches_monte_carlo() {
        let q = Quantizer::new(Levels::amq(4, 0.5), NormType::L2, 64);
        let v = randn(64, 11);
        let want = q.exact_variance(&v);
        let mut rng = Rng::new(12);
        let trials = 6000;
        let mut acc = 0.0f64;
        let mut out = vec![0.0f32; 64];
        for _ in 0..trials {
            let g = q.quantize(&v, &mut rng);
            q.dequantize(&g, &mut out);
            acc += out
                .iter()
                .zip(&v)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        let got = acc / trials as f64;
        assert!(
            (got - want).abs() / want.max(1e-12) < 0.1,
            "{got} vs {want}"
        );
    }

    #[test]
    fn clipping_reduces_extreme_variance() {
        // A bucket with one huge outlier: clipping shrinks the Linf norm
        // and thus the *stochastic* quantization variance of everyone
        // else, at the cost of a bias on the outlier (TernGrad's trade).
        let mut v = vec![0.01f32; 256];
        v[0] = 100.0;
        let plain = Quantizer::new(Levels::ternary(), NormType::Linf, 256);
        let clipped = plain.clone().with_clip(2.5);
        let (var_p, bias_p) = plain.exact_variance_parts(&v);
        let (var_c, bias_c) = clipped.exact_variance_parts(&v);
        assert_eq!(bias_p, 0.0);
        assert!(var_c < var_p, "stochastic variance {var_c} !< {var_p}");
        assert!(bias_c > 0.0);
    }

    #[test]
    fn zero_vector() {
        let q = Quantizer::new(Levels::uniform(4), NormType::L2, 16);
        let v = vec![0.0f32; 32];
        let mut rng = Rng::new(13);
        let g = q.quantize(&v, &mut rng);
        assert!(g.qidx.iter().all(|&s| s == 0));
        let mut out = vec![1.0f32; 32];
        q.dequantize(&g, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fast_path_matches_scalar_bit_for_bit() {
        // Every level family × K (monomorphized 2..=8 plus the >8
        // fallback) × clip setting, on data with a zero bucket and a
        // tail: identical symbols, norms, tails, AND subsequent RNG
        // state — the determinism contract of quantize_into_with.
        let families: Vec<Levels> = vec![
            Levels::ternary(),
            Levels::uniform(4),
            Levels::exponential(8, 0.5),
            Levels::exponential(16, 0.5),
            Levels::uniform(128),
            Levels::amq(2, 0.5),
            Levels::amq(4, 0.5),
            Levels::amq(8, 0.5),
            Levels::amq(16, 0.9),
        ];
        for (fi, levels) in families.into_iter().enumerate() {
            for clip in [None, Some(2.5f32)] {
                for norm_type in [NormType::L2, NormType::Linf] {
                    let mut q = Quantizer::new(levels.clone(), norm_type, 32);
                    if let Some(c) = clip {
                        q = q.with_clip(c);
                    }
                    let mut v = randn(170, 40 + fi as u64); // 5 buckets + tail 10
                    for x in &mut v[32..64] {
                        *x = 0.0; // zero-norm bucket: distinct draw rules
                    }
                    let mut rng_fast = Rng::new(1000 + fi as u64);
                    let mut rng_scalar = rng_fast.clone();
                    let mut fast = QuantizedGrad {
                        qidx: vec![],
                        norms: vec![],
                        tail: vec![],
                        bucket: 0,
                    };
                    let mut scalar = fast.clone();
                    let mut scratch = QuantScratch::default();
                    q.quantize_into_with(&v, &mut rng_fast, &mut scratch, &mut fast);
                    q.quantize_into_scalar(&v, &mut rng_scalar, &mut scalar);
                    assert_eq!(fast, scalar, "family {fi} clip {clip:?} {norm_type:?}");
                    assert_eq!(
                        rng_fast.next_u64(),
                        rng_scalar.next_u64(),
                        "RNG state diverged: family {fi} clip {clip:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn scratch_reuse_across_calls_is_transparent() {
        let q = Quantizer::new(Levels::exponential(8, 0.5), NormType::L2, 64).with_clip(3.0);
        let mut scratch = QuantScratch::default();
        let mut with_reuse = QuantizedGrad {
            qidx: vec![],
            norms: vec![],
            tail: vec![],
            bucket: 0,
        };
        for step in 0..5u64 {
            let v = randn(300, 50 + step);
            let mut rng_a = Rng::new(60 + step);
            let mut rng_b = rng_a.clone();
            q.quantize_into_with(&v, &mut rng_a, &mut scratch, &mut with_reuse);
            let fresh = q.quantize(&v, &mut rng_b);
            assert_eq!(with_reuse, fresh, "step {step}");
        }
    }

    #[test]
    fn matches_kernel_semantics_with_u() {
        // Same math as ref.py on a hand-checkable case.
        let q = Quantizer::new(Levels::uniform(3), NormType::Linf, 4);
        let v = [0.5f32, -1.0, 0.2, 0.75];
        // norms: linf = 1.0; r = [0.5, 1.0, 0.2, 0.75]; levels [0, .5, 1]
        let u = [0.9f32, 0.5, 0.39, 0.51];
        // r=0.5 -> tau=1 (r>=0.5), rho=0 -> idx=1; r=1.0 -> tau=1, rho=1, u<1 -> idx 2 (sign -)
        // r=0.2 -> tau=0, rho=0.4, u=0.39<0.4 -> idx 1; r=0.75: tau=1, rho=.5, u=.51 -> idx 1
        let g = q.quantize_with_u(&v, &u);
        assert_eq!(g.qidx, vec![1, -2, 1, 1]);
    }
}
