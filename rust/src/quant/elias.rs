//! Elias integer codes — the coding scheme of the original QSGD [20].
//!
//! QSGD encodes a quantized gradient as the Elias-coded positions of the
//! nonzero coordinates plus their level indices; the paper's Appendix D
//! replaces this with Huffman codes over the level alphabet. We implement
//! both so the choice is an ablation: `encode_qsgd_style` (Elias-γ run
//! lengths + Elias-γ magnitudes + sign bits) vs `encode` (Huffman).
//! A test shows Huffman wins whenever the level distribution is skewed —
//! the regime adaptive levels create — while Elias needs no codebook.

use super::bitio::{BitReader, BitWriter};
use super::quantizer::QuantizedGrad;
use super::Levels;

/// Elias-γ code of n ≥ 1: ⌊log₂n⌋ zeros, then n's bits MSB-first.
pub fn gamma_encode(n: u64, w: &mut BitWriter) {
    debug_assert!(n >= 1);
    let bits = 64 - n.leading_zeros();
    if bits <= 29 {
        // Fused push (2·bits−1 ≤ 57 accumulator bits): in stream order
        // the code is the bit-reversal of n shifted past the leading
        // zeros. Bit-identical to the per-bit loop below, pinned by test.
        let rev = n.reverse_bits() >> (64 - bits);
        w.push_bits_lsb(rev << (bits - 1), 2 * bits - 1);
        return;
    }
    // Per-bit fallback for n ≥ 2^29 (outside the fused range).
    for _ in 0..bits - 1 {
        w.push_bit(false);
    }
    for i in (0..bits).rev() {
        w.push_bit((n >> i) & 1 == 1);
    }
}

pub fn gamma_decode(r: &mut BitReader) -> u64 {
    let mut zeros = 0u32;
    while !r.read_bit() {
        zeros += 1;
        debug_assert!(zeros < 64, "corrupt gamma code");
    }
    let mut n = 1u64;
    for _ in 0..zeros {
        n = (n << 1) | r.read_bit() as u64;
    }
    n
}

/// Elias-δ code of n ≥ 1: γ(1 + ⌊log₂n⌋) then the low bits of n.
pub fn delta_encode(n: u64, w: &mut BitWriter) {
    debug_assert!(n >= 1);
    let bits = 64 - n.leading_zeros();
    gamma_encode(bits as u64, w);
    if bits < 2 {
        return;
    }
    if bits - 1 <= 57 {
        // Fused push of the low bits−1 bits MSB-first: reversing n and
        // keeping the top bits−1 reversed bits drops the leading one and
        // lands them in stream order.
        let rev = n.reverse_bits() >> (64 - (bits - 1));
        w.push_bits_lsb(rev, bits - 1);
    } else {
        // Per-bit fallback for n ≥ 2^58.
        for i in (0..bits - 1).rev() {
            w.push_bit((n >> i) & 1 == 1);
        }
    }
}

pub fn delta_decode(r: &mut BitReader) -> u64 {
    let bits = gamma_decode(r) as u32;
    let mut n = 1u64;
    for _ in 0..bits - 1 {
        n = (n << 1) | r.read_bit() as u64;
    }
    n
}

/// QSGD-style sparse encoding: per bucket, fp32 norm, then for each
/// nonzero coordinate the γ-coded gap to the previous nonzero, the
/// γ-coded magnitude index, and a sign bit. Returns total bits.
pub fn encode_qsgd_style(q: &QuantizedGrad, levels: &Levels, w: &mut BitWriter) -> u64 {
    encode_qsgd_style_range(q, levels, 0..q.norms.len(), true, w)
}

/// Bucket-range variant of [`encode_qsgd_style`] (the sharded topology's
/// per-shard frames): encodes buckets `[buckets.start, buckets.end)`
/// plus, iff `include_tail`, the fp32 tail. Like the Huffman layout the
/// Elias stream is bucket-major, so shard frames of a bucket-aligned
/// partition concatenate to exactly the whole-frame bits.
pub fn encode_qsgd_style_range(
    q: &QuantizedGrad,
    levels: &Levels,
    buckets: std::ops::Range<usize>,
    include_tail: bool,
    w: &mut BitWriter,
) -> u64 {
    assert!(levels.has_zero(), "sparse coding needs a zero symbol");
    let start = w.bits_written();
    for b in buckets {
        let norm = q.norms[b];
        w.push_f32(norm);
        let syms = &q.qidx[b * q.bucket..(b + 1) * q.bucket];
        let mut last = 0usize; // gap baseline (1-indexed gaps)
        let mut nnz = 0u64;
        // Count first so the decoder knows when to stop.
        for &s in syms {
            if s != 0 {
                nnz += 1;
            }
        }
        gamma_encode(nnz + 1, w);
        for (i, &s) in syms.iter().enumerate() {
            if s == 0 {
                continue;
            }
            gamma_encode((i - last + 1) as u64, w);
            gamma_encode(s.unsigned_abs() as u64, w);
            w.push_bit(s < 0);
            last = i + 1;
        }
    }
    if include_tail {
        for &t in &q.tail {
            w.push_f32(t);
        }
    }
    w.bits_written() - start
}

/// Inverse of [`encode_qsgd_style`].
pub fn decode_qsgd_style(
    bytes: &[u8],
    n_full: usize,
    n_tail: usize,
    bucket: usize,
) -> QuantizedGrad {
    let mut q = QuantizedGrad {
        qidx: Vec::new(),
        norms: Vec::new(),
        tail: Vec::new(),
        bucket,
    };
    decode_qsgd_style_into(bytes, n_full, n_tail, bucket, &mut q);
    q
}

/// Decode into a reusable buffer (the exchange lanes' hot path — no
/// allocation once warm, mirroring `quant::decode_view_into`).
pub fn decode_qsgd_style_into(
    bytes: &[u8],
    n_full: usize,
    n_tail: usize,
    bucket: usize,
    q: &mut QuantizedGrad,
) {
    let mut r = BitReader::new(bytes);
    let nb = if bucket == 0 { 0 } else { n_full / bucket };
    q.qidx.clear();
    q.qidx.resize(n_full, 0);
    q.norms.clear();
    q.norms.resize(nb, 0.0);
    q.tail.clear();
    q.tail.resize(n_tail, 0.0);
    q.bucket = bucket;
    for b in 0..nb {
        q.norms[b] = r.read_f32();
        let nnz = gamma_decode(&mut r) - 1;
        let mut pos = 0usize;
        for _ in 0..nnz {
            let gap = gamma_decode(&mut r) as usize;
            pos += gap - 1;
            let mag = gamma_decode(&mut r) as i32;
            let neg = r.read_bit();
            q.qidx[b * bucket + pos] = if neg { -mag } else { mag } as i8;
            pos += 1;
        }
    }
    for t in q.tail.iter_mut() {
        *t = r.read_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{encode, symbol_counts, HuffmanBook, NormType, Quantizer};
    use crate::util::Rng;

    #[test]
    fn gamma_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 3, 7, 8, 100, 1023, 1 << 40];
        for &v in &vals {
            gamma_encode(v, &mut w);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(gamma_decode(&mut r), v);
        }
    }

    #[test]
    fn delta_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 15, 16, 17, 12345, u32::MAX as u64];
        for &v in &vals {
            delta_encode(v, &mut w);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(delta_decode(&mut r), v);
        }
    }

    #[test]
    fn gamma_lengths() {
        // γ(1) = 1 bit; γ(n) = 2⌊log₂n⌋+1 bits.
        let mut w = BitWriter::new();
        gamma_encode(1, &mut w);
        assert_eq!(w.bits_written(), 1);
        let mut w = BitWriter::new();
        gamma_encode(8, &mut w);
        assert_eq!(w.bits_written(), 7);
    }

    #[test]
    fn property_random_roundtrip() {
        let mut rng = Rng::new(8);
        let mut w = BitWriter::new();
        let vals: Vec<u64> = (0..5000).map(|_| 1 + (rng.next_u64() >> (rng.below(60) as u32))).collect();
        for &v in &vals {
            gamma_encode(v, &mut w);
            delta_encode(v, &mut w);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(gamma_decode(&mut r), v);
            assert_eq!(delta_decode(&mut r), v);
        }
    }

    /// Per-bit reference encoders: the semantics the fused pushes in
    /// [`gamma_encode`] / [`delta_encode`] are pinned against.
    fn gamma_encode_ref(n: u64, w: &mut BitWriter) {
        let bits = 64 - n.leading_zeros();
        for _ in 0..bits - 1 {
            w.push_bit(false);
        }
        for i in (0..bits).rev() {
            w.push_bit((n >> i) & 1 == 1);
        }
    }

    fn delta_encode_ref(n: u64, w: &mut BitWriter) {
        let bits = 64 - n.leading_zeros();
        gamma_encode_ref(bits as u64, w);
        for i in (0..bits.saturating_sub(1)).rev() {
            w.push_bit((n >> i) & 1 == 1);
        }
    }

    #[test]
    fn fused_codes_bit_identical_to_per_bit_reference() {
        let mut vals: Vec<u64> = vec![1, 2, 3, 7, 8, 100, 1023, 12345];
        // Fused/fallback boundaries: 2^28..2^30 (γ), 2^57..2^59 (δ low bits).
        for shift in [28u32, 29, 30, 57, 58, 59, 63] {
            vals.push((1u64 << shift) - 1);
            vals.push(1u64 << shift);
            vals.push((1u64 << shift) + 1);
        }
        vals.push(u64::MAX);
        let mut rng = Rng::new(21);
        for _ in 0..500 {
            vals.push(1 + (rng.next_u64() >> (rng.below(63) as u32)));
        }
        for align in [0u32, 1, 3, 7] {
            let mut fused = BitWriter::new();
            let mut reference = BitWriter::new();
            if align > 0 {
                fused.push_bits_lsb(1, align);
                reference.push_bits_lsb(1, align);
            }
            for &v in &vals {
                gamma_encode(v, &mut fused);
                gamma_encode_ref(v, &mut reference);
                delta_encode(v, &mut fused);
                delta_encode_ref(v, &mut reference);
            }
            assert_eq!(fused.bits_written(), reference.bits_written());
            assert_eq!(fused.finish(), reference.finish(), "align {align}");
        }
    }

    #[test]
    fn qsgd_style_roundtrip() {
        let levels = Levels::exponential(4, 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 64);
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..500).map(|_| (rng.normal() * 0.01) as f32).collect();
        let q = quant.quantize(&v, &mut rng);
        let mut w = BitWriter::new();
        encode_qsgd_style(&q, &levels, &mut w);
        let bytes = w.finish();
        let got = decode_qsgd_style(&bytes, q.qidx.len(), q.tail.len(), 64);
        assert_eq!(got, q);
    }

    #[test]
    fn qsgd_style_shard_frames_concatenate_and_decode() {
        let levels = Levels::exponential(4, 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 64);
        let mut rng = Rng::new(11);
        let v: Vec<f32> = (0..700).map(|_| (rng.normal() * 0.01) as f32).collect(); // 10 buckets + tail 60
        let q = quant.quantize(&v, &mut rng);
        let mut w = BitWriter::new();
        let whole = encode_qsgd_style(&q, &levels, &mut w);
        for shards in [2usize, 3, 5] {
            let nb = q.norms.len();
            let mut total = 0u64;
            for s in 0..shards {
                let lo = s * nb / shards;
                let hi = (s + 1) * nb / shards;
                let last = s + 1 == shards;
                let mut sw = BitWriter::new();
                let bits = encode_qsgd_style_range(&q, &levels, lo..hi, last, &mut sw);
                total += bits;
                let bytes = sw.finish();
                let mut dec = QuantizedGrad {
                    qidx: Vec::new(),
                    norms: Vec::new(),
                    tail: Vec::new(),
                    bucket: 0,
                };
                decode_qsgd_style_into(
                    &bytes,
                    (hi - lo) * q.bucket,
                    if last { q.tail.len() } else { 0 },
                    q.bucket,
                    &mut dec,
                );
                assert_eq!(&dec.qidx[..], &q.qidx[lo * q.bucket..hi * q.bucket]);
                assert_eq!(&dec.norms[..], &q.norms[lo..hi]);
                if last {
                    assert_eq!(dec.tail, q.tail);
                }
            }
            assert_eq!(total, whole, "{shards} shards");
        }
    }

    /// The codec tradeoff the paper's Appendix D navigates: Huffman wins
    /// in the dense regime (L∞ norms — most coordinates nonzero), Elias
    /// run-length wins in the ultra-sparse regime (L2 norms with huge
    /// buckets, where almost every symbol is 0 — the original QSGD
    /// setting). Both directions asserted.
    #[test]
    fn huffman_vs_elias_regimes() {
        let mut rng = Rng::new(10);
        let v: Vec<f32> = (0..65536).map(|_| (rng.normal() * 0.01) as f32).collect();
        let levels = Levels::exponential(4, 0.5);

        // Dense regime: Linf.
        let quant = Quantizer::new(levels.clone(), NormType::Linf, 8192);
        let q = quant.quantize(&v, &mut rng);
        let book = HuffmanBook::from_weights(
            &symbol_counts(&q, &levels).iter().map(|c| c + 1.0).collect::<Vec<_>>(),
        );
        let huff = encode(&q, &levels, &book).bits;
        let mut w = BitWriter::new();
        let elias = encode_qsgd_style(&q, &levels, &mut w);
        assert!(
            huff < elias,
            "dense: huffman {huff} should beat elias {elias}"
        );

        // Ultra-sparse regime: L2 (nearly all symbols zero).
        let quant = Quantizer::new(levels.clone(), NormType::L2, 8192);
        let q = quant.quantize(&v, &mut rng);
        let book = HuffmanBook::from_weights(
            &symbol_counts(&q, &levels).iter().map(|c| c + 1.0).collect::<Vec<_>>(),
        );
        let huff = encode(&q, &levels, &book).bits;
        let mut w = BitWriter::new();
        let elias = encode_qsgd_style(&q, &levels, &mut w);
        assert!(
            elias < huff,
            "sparse: elias {elias} should beat huffman {huff}"
        );
        // Both crush raw fp32.
        assert!(huff < 65536 * 8);
    }
}
