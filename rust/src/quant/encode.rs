//! ENCODE / DECODE of Appendix D with exact bit accounting.
//!
//! Wire layout per gradient (metadata — n, bucket size, levels, codebook —
//! is negotiated out of band, as in the paper where every worker derives
//! the same codebook from the shared levels and statistics):
//!
//! ```text
//! for each full bucket:
//!     norm: f32 (32 bits)                          | "b bits" of Thm. 3
//!     for each coordinate:
//!         Huffman(|symbol|)                        | H(L) term
//!         sign bit (present iff value can be ±)    | the "+1" term
//! tail coordinates: raw f32 each                   | App. K partial bucket
//! ```

use super::bitio::{BitReader, BitWriter};
use super::huffman::HuffmanBook;
use super::quantizer::QuantizedGrad;
use super::Levels;

/// An encoded gradient plus its exact size in bits (the communication
/// meter the network model charges).
#[derive(Clone, Debug)]
pub struct EncodedGrad {
    pub bytes: Vec<u8>,
    pub bits: u64,
    /// Number of full-bucket coordinates (needed to decode).
    pub n_full: usize,
    /// Tail length.
    pub n_tail: usize,
    pub bucket: usize,
}

impl EncodedGrad {
    /// Total payload in bytes (rounded up).
    pub fn byte_len(&self) -> usize {
        self.bits.div_ceil(8) as usize
    }

    /// Borrow as a zero-copy frame.
    pub fn view(&self) -> EncodedView<'_> {
        EncodedView {
            bytes: &self.bytes,
            bits: self.bits,
            n_full: self.n_full,
            n_tail: self.n_tail,
            bucket: self.bucket,
        }
    }
}

/// A borrowed encoded frame: same shape metadata as [`EncodedGrad`], but
/// the payload is a slice. This is the hot-path decode handle — the sim
/// loopback decodes straight out of each lane's bit writer and the TCP
/// worker straight out of the received wire frame, with no byte clone.
#[derive(Clone, Copy, Debug)]
pub struct EncodedView<'a> {
    pub bytes: &'a [u8],
    pub bits: u64,
    pub n_full: usize,
    pub n_tail: usize,
    pub bucket: usize,
}

/// Build the Huffman book for a level set from symbol probabilities
/// (Prop. 6 closed forms live in `adaptive::objective::symbol_probs`).
pub fn book_for(levels: &Levels, probs: &[f64]) -> HuffmanBook {
    assert_eq!(probs.len(), levels.num_symbols());
    HuffmanBook::from_weights(probs)
}

/// Width (bits per coordinate record) at which a level-family × book pair
/// admits the fixed-width fast path: every `Huffman(|symbol|)` + sign
/// record shares one length in {1, 2, 3, 4, 8}. `None` ⇒ bit-cursor path.
///
/// A "record" is exactly the bits the cursor path emits per coordinate:
/// for `has_zero` families magnitude 0 carries no sign bit (record length
/// `len_of(0)`), every other magnitude is `len_of(m) + 1`; zero-free
/// families always append the sign (`len_of(m) + 1`).
pub fn fixed_width(levels: &Levels, book: &HuffmanBook) -> Option<u32> {
    let k = levels.num_symbols();
    if k == 0 || book.num_symbols() < k {
        return None;
    }
    // Raw symbols must fit i8 when the table maps ±(mag+1).
    if !levels.has_zero() && k > 127 {
        return None;
    }
    let has_zero = levels.has_zero();
    let rec_len = |mag: usize| {
        let l = book.len_of(mag);
        if l == 0 {
            0 // absent symbol: no total fixed-width code
        } else if has_zero && mag == 0 {
            l
        } else {
            l + 1
        }
    };
    let width = rec_len(0);
    if !matches!(width, 1 | 2 | 3 | 4 | 8) {
        return None;
    }
    if (1..k).all(|m| rec_len(m) == width) {
        Some(width)
    } else {
        None
    }
}

/// Precomputed fixed-width record tables for the pow-2 fast path.
///
/// `enc` maps a raw symbol byte (`s as u8`) to its stream-order record;
/// `dec` maps a record back to the symbol the cursor decoder would
/// produce. Records are distinct because equal-length Huffman codes are
/// distinct (prefix-free) and the sign bit extends a complete code.
struct Pow2Book {
    width: u32,
    enc: Vec<u64>,
    dec: Vec<i8>,
}

impl Pow2Book {
    /// Build the tables when [`fixed_width`] applies.
    fn detect(levels: &Levels, book: &HuffmanBook) -> Option<Pow2Book> {
        let width = fixed_width(levels, book)?;
        let k = levels.num_symbols() as i32;
        let has_zero = levels.has_zero();
        let mut enc = vec![0u64; 256];
        let mut dec = vec![0i8; 1usize << width];
        let symbols: Vec<i32> = if has_zero {
            ((1 - k)..k).collect()
        } else {
            // Includes 0: zero-norm AMQ buckets store 0 symbols, which the
            // cursor path encodes as (mag 0, sign +) — an alias of +1.
            (-k..=k).collect()
        };
        for s in symbols {
            let (record, decoded) = if has_zero {
                let mag = s.unsigned_abs() as usize;
                let rec = if mag == 0 {
                    book.rcode(0)
                } else {
                    book.rcode(mag) | ((s < 0) as u64) << book.len_of(mag)
                };
                (rec, s as i8)
            } else {
                let mag = (s.unsigned_abs() as usize).saturating_sub(1);
                let rec = book.rcode(mag) | ((s < 0) as u64) << book.len_of(mag);
                // The cursor decoder maps this record to ±(mag + 1).
                let d = if s < 0 { -(mag as i32 + 1) } else { mag as i32 + 1 };
                (rec, d as i8)
            };
            debug_assert!(record < (1u64 << width));
            enc[(s as i8) as u8 as usize] = record;
            dec[record as usize] = decoded;
        }
        Some(Pow2Book { width, enc, dec })
    }

    /// Encode one bucket's symbols, whole `u64` lanes at a time —
    /// bit-identical to the per-symbol fused cursor pushes. Width-3
    /// lanes hold 21 records (63 bits) and are split across two
    /// accumulator pushes; every other width fills the u64 exactly.
    #[inline]
    fn encode_bucket(&self, syms: &[i8], w: &mut BitWriter) {
        let per = (64 / self.width) as usize;
        let mut chunks = syms.chunks_exact(per);
        for chunk in &mut chunks {
            let mut lane = 0u64;
            for (i, &s) in chunk.iter().enumerate() {
                lane |= self.enc[s as u8 as usize] << (i as u32 * self.width);
            }
            if self.width == 3 {
                w.push_bits_lsb(lane & 0xFFFF_FFFF, 32);
                w.push_bits_lsb(lane >> 32, 31);
            } else {
                w.push_u64_lsb(lane);
            }
        }
        for &s in chunks.remainder() {
            w.push_bits_lsb(self.enc[s as u8 as usize], self.width);
        }
    }

    /// Decode one bucket's symbols, whole `u64` lanes at a time.
    #[inline]
    fn decode_bucket(&self, out: &mut [i8], r: &mut BitReader) {
        let per = (64 / self.width) as usize;
        let mask = (1u64 << self.width) - 1;
        let mut chunks = out.chunks_exact_mut(per);
        for chunk in &mut chunks {
            let mut lane = if self.width == 3 {
                let lo = r.peek_bits(32);
                r.consume(32);
                let hi = r.peek_bits(31);
                r.consume(31);
                lo | (hi << 32)
            } else {
                r.read_u64_lsb()
            };
            for s in chunk.iter_mut() {
                *s = self.dec[(lane & mask) as usize];
                lane >>= self.width;
            }
        }
        for s in chunks.into_remainder() {
            *s = self.dec[r.peek_bits(self.width) as usize];
            r.consume(self.width);
        }
    }
}

/// Encode a quantized gradient.
pub fn encode(q: &QuantizedGrad, levels: &Levels, book: &HuffmanBook) -> EncodedGrad {
    let mut w = BitWriter::new();
    encode_into(q, levels, book, &mut w);
    let bits = w.bits_written();
    EncodedGrad {
        bytes: w.finish(),
        bits,
        n_full: q.qidx.len(),
        n_tail: q.tail.len(),
        bucket: q.bucket,
    }
}

/// Encode into a reusable writer (hot path). Returns bits written.
pub fn encode_into(
    q: &QuantizedGrad,
    levels: &Levels,
    book: &HuffmanBook,
    w: &mut BitWriter,
) -> u64 {
    encode_buckets_into(q, levels, book, 0..q.norms.len(), true, w)
}

/// Encode a bucket-aligned slice of a quantized gradient: buckets
/// `[buckets.start, buckets.end)` plus, iff `include_tail`, the fp32
/// tail. Because the wire layout is strictly bucket-major, the shard
/// frames of a bucket-aligned partition concatenate to exactly the bits
/// of the whole-frame [`encode_into`] — the invariant the sharded
/// exchange topology's bit accounting rests on (asserted in
/// `rust/tests/topology_parity.rs`).
pub fn encode_buckets_into(
    q: &QuantizedGrad,
    levels: &Levels,
    book: &HuffmanBook,
    buckets: std::ops::Range<usize>,
    include_tail: bool,
    w: &mut BitWriter,
) -> u64 {
    match Pow2Book::detect(levels, book) {
        Some(fast) => {
            let start = w.bits_written();
            for b in buckets {
                w.push_f32(q.norms[b]);
                fast.encode_bucket(&q.qidx[b * q.bucket..(b + 1) * q.bucket], w);
            }
            if include_tail {
                for &t in &q.tail {
                    w.push_f32(t);
                }
            }
            w.bits_written() - start
        }
        None => encode_buckets_into_cursor(q, levels, book, buckets, include_tail, w),
    }
}

/// The reference bit-cursor encode path: one fused `push_bits_lsb` per
/// coordinate. [`encode_buckets_into`] dispatches away from this only
/// when [`fixed_width`] holds, and the fast path is pinned bit-identical
/// to this one by tests — keep it as the semantics of the wire format.
pub fn encode_buckets_into_cursor(
    q: &QuantizedGrad,
    levels: &Levels,
    book: &HuffmanBook,
    buckets: std::ops::Range<usize>,
    include_tail: bool,
    w: &mut BitWriter,
) -> u64 {
    let start = w.bits_written();
    let has_zero = levels.has_zero();
    for b in buckets {
        let norm = q.norms[b];
        w.push_f32(norm);
        let syms = &q.qidx[b * q.bucket..(b + 1) * q.bucket];
        if has_zero {
            for &s in syms {
                let mag = s.unsigned_abs() as usize;
                // Fused symbol+sign push (one shift/or on the hot path).
                let len = book.len_of(mag);
                if mag != 0 {
                    w.push_bits_lsb(book.rcode(mag) | ((s < 0) as u64) << len, len + 1);
                } else {
                    w.push_bits_lsb(book.rcode(mag), len);
                }
            }
        } else {
            for &s in syms {
                // Zero-norm AMQ buckets store 0 symbols; map to mag 0, sign +.
                let mag = (s.unsigned_abs() as usize).saturating_sub(1);
                let len = book.len_of(mag);
                w.push_bits_lsb(book.rcode(mag) | ((s < 0) as u64) << len, len + 1);
            }
        }
    }
    if include_tail {
        for &t in &q.tail {
            w.push_f32(t);
        }
    }
    w.bits_written() - start
}

/// Decode an encoded gradient back to symbols + norms + tail.
pub fn decode(e: &EncodedGrad, levels: &Levels, book: &HuffmanBook) -> QuantizedGrad {
    let mut q = QuantizedGrad {
        qidx: Vec::new(),
        norms: Vec::new(),
        tail: Vec::new(),
        bucket: e.bucket,
    };
    decode_into(e, levels, book, &mut q);
    q
}

/// Decode into a reusable buffer (hot path: zero allocation once warm).
pub fn decode_into(e: &EncodedGrad, levels: &Levels, book: &HuffmanBook, q: &mut QuantizedGrad) {
    decode_view_into(e.view(), levels, book, q)
}

/// Decode a borrowed frame into a reusable buffer (the zero-copy variant
/// every decode path funnels through).
pub fn decode_view_into(
    e: EncodedView<'_>,
    levels: &Levels,
    book: &HuffmanBook,
    q: &mut QuantizedGrad,
) {
    match Pow2Book::detect(levels, book) {
        Some(fast) => {
            let mut r = BitReader::new(e.bytes);
            let nb = prepare_decode(e, q);
            for b in 0..nb {
                q.norms[b] = r.read_f32();
                fast.decode_bucket(&mut q.qidx[b * e.bucket..(b + 1) * e.bucket], &mut r);
            }
            for t in q.tail.iter_mut() {
                *t = r.read_f32();
            }
        }
        None => decode_view_into_cursor(e, levels, book, q),
    }
}

/// Size the output buffers for a frame; returns the bucket count.
fn prepare_decode(e: EncodedView<'_>, q: &mut QuantizedGrad) -> usize {
    let nb = if e.bucket == 0 { 0 } else { e.n_full / e.bucket };
    q.qidx.clear();
    q.qidx.resize(e.n_full, 0);
    q.norms.clear();
    q.norms.resize(nb, 0.0);
    q.tail.clear();
    q.tail.resize(e.n_tail, 0.0);
    q.bucket = e.bucket;
    nb
}

/// The reference bit-cursor decode path (see
/// [`encode_buckets_into_cursor`]); the fixed-width decode table is
/// pinned against this per-record walk.
pub fn decode_view_into_cursor(
    e: EncodedView<'_>,
    levels: &Levels,
    book: &HuffmanBook,
    q: &mut QuantizedGrad,
) {
    let mut r = BitReader::new(e.bytes);
    let nb = prepare_decode(e, q);
    let has_zero = levels.has_zero();
    for b in 0..nb {
        q.norms[b] = r.read_f32();
        for i in 0..e.bucket {
            let mag = book.decode(&mut r) as i32;
            let sym = if has_zero {
                if mag == 0 {
                    0
                } else {
                    let neg = r.read_bit();
                    if neg {
                        -mag
                    } else {
                        mag
                    }
                }
            } else {
                let neg = r.read_bit();
                let v = mag + 1;
                if neg {
                    -v
                } else {
                    v
                }
            };
            q.qidx[b * e.bucket + i] = sym as i8;
        }
    }
    for t in q.tail.iter_mut() {
        *t = r.read_f32();
    }
}

/// Empirical symbol counts of a quantized gradient (codebook input when
/// coding against measured frequencies rather than the model of Prop. 6).
pub fn symbol_counts(q: &QuantizedGrad, levels: &Levels) -> Vec<f64> {
    let mut counts = vec![0.0f64; levels.num_symbols()];
    let has_zero = levels.has_zero();
    for &s in &q.qidx {
        let mag = if has_zero {
            s.unsigned_abs() as usize
        } else {
            (s.unsigned_abs() as usize).saturating_sub(1)
        };
        counts[mag] += 1.0;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{NormType, Quantizer};
    use crate::util::Rng;

    fn roundtrip_case(levels: Levels, norm: NormType, n: usize, bucket: usize, seed: u64) {
        let quant = Quantizer::new(levels.clone(), norm, bucket);
        let mut rng = Rng::new(seed);
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let q = quant.quantize(&v, &mut rng);
        let counts = symbol_counts(&q, &levels);
        let book = HuffmanBook::from_weights(&counts);
        let e = encode(&q, &levels, &book);
        let q2 = decode(&e, &levels, &book);
        assert_eq!(q, q2);
    }

    #[test]
    fn roundtrip_uniform_levels() {
        roundtrip_case(Levels::uniform(4), NormType::Linf, 1024, 128, 1);
    }

    #[test]
    fn roundtrip_exponential_levels() {
        roundtrip_case(Levels::exponential(8, 0.5), NormType::L2, 500, 64, 2);
    }

    #[test]
    fn roundtrip_amq_nozero() {
        roundtrip_case(Levels::amq(4, 0.5), NormType::L2, 300, 32, 3);
    }

    #[test]
    fn roundtrip_ternary_with_tail() {
        roundtrip_case(Levels::ternary(), NormType::Linf, 130, 64, 4);
    }

    #[test]
    fn bits_accounting_exact() {
        let levels = Levels::uniform(4);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 64);
        let mut rng = Rng::new(5);
        let v: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let q = quant.quantize(&v, &mut rng);
        let book = HuffmanBook::from_weights(&symbol_counts(&q, &levels));
        let e = encode(&q, &levels, &book);
        // Recompute expected bits by hand.
        let mut want = 0u64;
        for b in 0..2 {
            want += 32;
            for i in 0..64 {
                let s = q.qidx[b * 64 + i];
                let mag = s.unsigned_abs() as usize;
                want += book.len_of(mag) as u64;
                if mag != 0 {
                    want += 1;
                }
            }
        }
        assert_eq!(e.bits, want);
        assert!(e.bytes.len() == e.byte_len());
    }

    #[test]
    fn compression_beats_fp32_at_3_bits() {
        let levels = Levels::exponential(4, 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 256);
        let mut rng = Rng::new(6);
        let v: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let q = quant.quantize(&v, &mut rng);
        let book = HuffmanBook::from_weights(&symbol_counts(&q, &levels));
        let e = encode(&q, &levels, &book);
        let fp32_bits = 32 * 4096;
        assert!(
            (e.bits as f64) < 0.2 * fp32_bits as f64,
            "3-bit encoding should be <20% of fp32, got {}",
            e.bits as f64 / fp32_bits as f64
        );
    }

    #[test]
    fn view_decode_matches_owned_decode() {
        let levels = Levels::exponential(4, 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 64);
        let mut rng = Rng::new(7);
        let v: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
        let q = quant.quantize(&v, &mut rng);
        let book = HuffmanBook::from_weights(&symbol_counts(&q, &levels));
        let e = encode(&q, &levels, &book);
        let owned = decode(&e, &levels, &book);
        let mut via_view = QuantizedGrad {
            qidx: vec![],
            norms: vec![],
            tail: vec![],
            bucket: 0,
        };
        decode_view_into(e.view(), &levels, &book, &mut via_view);
        assert_eq!(owned, via_view);
    }

    #[test]
    fn shard_frames_concatenate_to_whole_frame_bits() {
        use super::super::bitio::BitWriter;
        let levels = Levels::exponential(4, 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 32);
        let mut rng = Rng::new(8);
        let v: Vec<f32> = (0..330).map(|_| rng.normal() as f32).collect(); // 10 buckets + tail 10
        let q = quant.quantize(&v, &mut rng);
        let book = HuffmanBook::from_weights(&symbol_counts(&q, &levels));
        let whole = encode(&q, &levels, &book);
        for shards in [1usize, 2, 3, 4, 10] {
            let nb = q.norms.len();
            let mut total = 0u64;
            for s in 0..shards {
                let lo = s * nb / shards;
                let hi = (s + 1) * nb / shards;
                let mut w = BitWriter::new();
                let bits =
                    encode_buckets_into(&q, &levels, &book, lo..hi, s + 1 == shards, &mut w);
                // Each shard frame is independently decodable.
                let view = EncodedView {
                    bytes: w.finish_ref(),
                    bits,
                    n_full: (hi - lo) * q.bucket,
                    n_tail: if s + 1 == shards { q.tail.len() } else { 0 },
                    bucket: q.bucket,
                };
                let mut dec = QuantizedGrad {
                    qidx: vec![],
                    norms: vec![],
                    tail: vec![],
                    bucket: 0,
                };
                decode_view_into(view, &levels, &book, &mut dec);
                assert_eq!(&dec.qidx[..], &q.qidx[lo * q.bucket..hi * q.bucket]);
                assert_eq!(&dec.norms[..], &q.norms[lo..hi]);
                total += bits;
            }
            assert_eq!(total, whole.bits, "{shards} shards");
        }
    }

    /// Books that trigger the fixed-width fast path, with the level
    /// family each pairs with.
    fn fixed_width_cases() -> Vec<(Levels, HuffmanBook, u32)> {
        vec![
            // AMQ (zero-free): uniform 8-symbol book → 3-bit codes + sign.
            (Levels::amq(8, 0.5), HuffmanBook::from_weights(&[1.0; 8]), 4),
            // has_zero: mag 0 has no sign bit, so its code is one longer.
            (
                Levels::exponential(8, 0.5),
                HuffmanBook::from_lengths(vec![4, 3, 3, 3, 3, 3, 3, 3]),
                4,
            ),
            // AMQ 2-symbol: 1-bit codes + sign.
            (Levels::amq(2, 0.5), HuffmanBook::from_weights(&[1.0; 2]), 2),
            // AMQ 4-symbol: 2-bit codes + sign → 3-bit records, the
            // 21-records-per-lane odd width.
            (Levels::amq(4, 0.5), HuffmanBook::from_weights(&[1.0; 4]), 3),
            // has_zero at width 3: mag 0 takes the lone 3-bit code, the
            // other magnitudes 2-bit codes + sign.
            (
                Levels::exponential(4, 0.5),
                HuffmanBook::from_lengths(vec![3, 2, 2, 2]),
                3,
            ),
            // has_zero 128-symbol: 7-bit codes + sign, 8-bit mag-0 code.
            (Levels::exponential(128, 0.5), {
                let mut lens = vec![7u32; 128];
                lens[0] = 8;
                HuffmanBook::from_lengths(lens)
            }, 8),
        ]
    }

    #[test]
    fn fixed_width_detection() {
        for (levels, book, want) in fixed_width_cases() {
            assert_eq!(fixed_width(&levels, &book), Some(want));
        }
        // Skewed books have variable record lengths → cursor path.
        let levels = Levels::exponential(4, 0.5);
        let book = HuffmanBook::from_weights(&[100.0, 10.0, 5.0, 1.0]);
        assert_eq!(fixed_width(&levels, &book), None);
        // Uniform has_zero book: mag-0 records are 1 bit shorter.
        let book = HuffmanBook::from_weights(&[1.0; 4]);
        assert_eq!(fixed_width(&levels, &book), None);
        // Mixed record lengths (3 symbols → lens {1,2,2} + sign): no
        // single width, even though 3-bit records are now supported.
        let levels = Levels::amq(3, 0.5);
        let book = HuffmanBook::from_weights(&[1.0; 3]);
        assert_eq!(fixed_width(&levels, &book), None);
    }

    #[test]
    fn fast_encode_bit_identical_to_cursor() {
        for (case, (levels, book, width)) in fixed_width_cases().into_iter().enumerate() {
            let quant = Quantizer::new(levels.clone(), NormType::L2, 32);
            let mut rng = Rng::new(100 + case as u64);
            // 11 buckets + 7-coord tail, with one all-zero bucket so the
            // zero-norm symbol conventions are exercised on both paths.
            let mut v: Vec<f32> = (0..359).map(|_| rng.normal() as f32).collect();
            for x in &mut v[64..96] {
                *x = 0.0;
            }
            let q = quant.quantize(&v, &mut rng);
            let fast = encode(&q, &levels, &book);
            let mut w = BitWriter::new();
            let bits =
                encode_buckets_into_cursor(&q, &levels, &book, 0..q.norms.len(), true, &mut w);
            assert_eq!(fast.bits, bits, "case {case} width {width}");
            assert_eq!(fast.bytes, w.finish(), "case {case} width {width}");
        }
    }

    #[test]
    fn fast_decode_matches_cursor_decode() {
        for (case, (levels, book, _)) in fixed_width_cases().into_iter().enumerate() {
            let quant = Quantizer::new(levels.clone(), NormType::Linf, 32);
            let mut rng = Rng::new(200 + case as u64);
            let mut v: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
            for x in &mut v[32..64] {
                *x = 0.0;
            }
            let q = quant.quantize(&v, &mut rng);
            let e = encode(&q, &levels, &book);
            let via_fast = decode(&e, &levels, &book);
            let mut via_cursor = QuantizedGrad {
                qidx: vec![],
                norms: vec![],
                tail: vec![],
                bucket: 0,
            };
            decode_view_into_cursor(e.view(), &levels, &book, &mut via_cursor);
            assert_eq!(via_fast, via_cursor, "case {case}");
        }
    }

    #[test]
    fn fast_path_roundtrips() {
        for (case, (levels, book, _)) in fixed_width_cases().into_iter().enumerate() {
            let quant = Quantizer::new(levels.clone(), NormType::L2, 64);
            let mut rng = Rng::new(300 + case as u64);
            let v: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
            let q = quant.quantize(&v, &mut rng);
            let e = encode(&q, &levels, &book);
            let q2 = decode(&e, &levels, &book);
            assert_eq!(q, q2, "case {case}");
        }
    }

    #[test]
    fn empty_gradient() {
        let levels = Levels::uniform(4);
        let q = QuantizedGrad {
            qidx: vec![],
            norms: vec![],
            tail: vec![],
            bucket: 64,
        };
        let book = HuffmanBook::from_weights(&[1.0; 4]);
        let e = encode(&q, &levels, &book);
        assert_eq!(e.bits, 0);
        let q2 = decode(&e, &levels, &book);
        assert_eq!(q, q2);
    }
}
