//! Executable theory: Theorem 2 (variance bound), Theorem 3 (code-length
//! bound), Lemma 2's K_p, and the Proposition 7 variance gap. Used by the
//! property tests ("empirical variance ≤ ε_Q‖v‖²", "measured bits ≤ bound")
//! and the theory-validation experiment.

use super::Levels;

/// K_p of Lemma 2 / Theorem 2: K_p = (1/(2−p)) ((1−p)/(2−p))^{1−p}.
pub fn k_p(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0);
    (1.0 / (2.0 - p)) * ((1.0 - p) / (2.0 - p)).powf(1.0 - p)
}

/// ε_Q of Theorem 2 for `L^q` normalization in dimension `d`:
///
/// ε_Q = (ρ−1)²/(4ρ) + inf_{0<p<1} K_p ℓ₁^{2−p} d^{(2−p)/min(q,2)}
/// with ρ = max_j ℓ_{j+1}/ℓ_j over positive levels.
///
/// For zero-free (AMQ) level sets Theorem 9 applies instead:
/// ε_Q = ℓ₁² d^{2/min(q,2)} + (ρ−1)²/(4ρ).
pub fn epsilon_q(levels: &Levels, d: usize, q_norm: f64) -> f64 {
    let rho = levels.max_ratio();
    let ratio_term = (rho - 1.0).powi(2) / (4.0 * rho);
    let l1 = levels.smallest_positive();
    let dq = (q_norm.min(2.0)).max(1.0);
    if !levels.has_zero() {
        // Theorem 9.
        return l1 * l1 * (d as f64).powf(2.0 / dq) + ratio_term;
    }
    // Grid-minimize over p in (0,1).
    let mut best = f64::INFINITY;
    for i in 1..200 {
        let p = i as f64 / 200.0;
        let term = k_p(p) * l1.powf(2.0 - p) * (d as f64).powf((2.0 - p) / dq);
        best = best.min(term);
    }
    ratio_term + best
}

/// Entropy (bits) of a probability vector.
pub fn entropy_bits(probs: &[f64]) -> f64 {
    probs
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

/// Theorem 3 code-length bound (expected bits to transmit one quantized
/// vector): `b + n_{ℓ₁,d} + d (H(L) + 1)` where
/// `n_{ℓ₁,d} = min(ℓ₁^{−q} + d^{1−1/q}/ℓ₁, d)` and `b` = 32 (fp32 norm).
pub fn code_length_bound(levels: &Levels, d: usize, q_norm: f64, symbol_probs: &[f64]) -> f64 {
    let b = 32.0;
    let l1 = levels.smallest_positive();
    let n_l1 = (l1.powf(-q_norm) + (d as f64).powf(1.0 - 1.0 / q_norm) / l1).min(d as f64);
    let h = entropy_bits(symbol_probs).min((levels.num_symbols() as f64).log2());
    b + n_l1 + d as f64 * (h + 1.0)
}

/// Proposition 7's point: the per-coordinate gap between worst-case-
/// optimal levels (b̂ = 1/2 for a single level) and distribution-optimal
/// levels scales the total gap by d. Returns the per-coordinate expected
/// variance of a single level `b` under a distribution `F` restricted to
/// [0, 1]: `Q(b) = ∫_0^b (b−r) r dF + ∫_b^1 (1−r)(r−b) dF`.
pub fn single_level_variance<D: crate::stats::Dist>(dist: &D, b: f64) -> f64 {
    // ∫_0^b (b−r) r dF = b·M1[0,b] − M2[0,b]
    let m1a = dist.partial_mean(0.0, b);
    let m2a = dist.partial_mean_sq(0.0, b);
    let first = b * m1a - m2a;
    // ∫_b^1 (1−r)(r−b) dF = −M2[b,1] + (1+b) M1[b,1] − b·ΔF
    let m1b = dist.partial_mean(b, 1.0);
    let m2b = dist.partial_mean_sq(b, 1.0);
    let df = dist.cdf(1.0) - dist.cdf(b);
    let second = -m2b + (1.0 + b) * m1b - b * df;
    first + second
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{NormType, Quantizer};
    use crate::stats::{Dist, TruncNormal};
    use crate::util::Rng;

    #[test]
    fn k_p_shape() {
        // K_p is the max of θ^{1/p−1} − θ^{2/p−1} on (0,1): in (0, 1).
        for p in [0.1, 0.5, 0.9] {
            let k = k_p(p);
            assert!(k > 0.0 && k < 1.0, "K_{p} = {k}");
        }
        // Verify against direct maximization for p = 0.5.
        let p = 0.5;
        let direct = (0..10_000)
            .map(|i| {
                let theta = (i + 1) as f64 / 10_001.0;
                theta.powf(1.0 / p - 1.0) - theta.powf(2.0 / p - 1.0)
            })
            .fold(0.0, f64::max);
        assert!((k_p(p) - direct).abs() < 1e-4);
    }

    #[test]
    fn variance_bound_holds_empirically() {
        // E‖Q(v)−v‖² ≤ ε_Q ‖v‖₂² for random vectors, exact variance form.
        let mut rng = Rng::new(21);
        for (levels, q_norm, nt) in [
            (Levels::uniform(4), f64::INFINITY, NormType::Linf),
            (Levels::exponential(4, 0.5), 2.0, NormType::L2),
            (Levels::exponential(8, 0.5), 2.0, NormType::L2),
            (Levels::amq(4, 0.5), 2.0, NormType::L2),
        ] {
            let d = 256;
            let quant = Quantizer::new(levels.clone(), nt, d);
            let eps = epsilon_q(&levels, d, if q_norm.is_finite() { q_norm } else { 100.0 });
            for _ in 0..10 {
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let var = quant.exact_variance(&v);
                let l2: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum();
                assert!(
                    var <= eps * l2 + 1e-9,
                    "levels {:?}: var {var} > eps {eps} * |v|2 {l2}",
                    levels.mags()
                );
            }
        }
    }

    #[test]
    fn code_length_bound_holds_empirically() {
        use crate::quant::{encode, symbol_counts, HuffmanBook};
        let levels = Levels::exponential(4, 0.5);
        let d = 1024;
        let quant = Quantizer::new(levels.clone(), NormType::L2, d);
        let mut rng = Rng::new(22);
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let q = quant.quantize(&v, &mut rng);
        let counts = symbol_counts(&q, &levels);
        let total: f64 = counts.iter().sum();
        let probs: Vec<f64> = counts.iter().map(|c| c / total).collect();
        let book = HuffmanBook::from_weights(&counts);
        let e = encode(&q, &levels, &book);
        let bound = code_length_bound(&levels, d, 2.0, &probs);
        assert!(
            (e.bits as f64) <= bound,
            "measured {} > bound {bound}",
            e.bits
        );
    }

    #[test]
    fn entropy_sanity() {
        assert!((entropy_bits(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!(entropy_bits(&[1.0, 0.0]).abs() < 1e-12);
        assert!((entropy_bits(&[0.25; 4]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_level_optimum_beats_half() {
        // Corollary 2: b* = F^{-1}(1 − E[R]); for a concentrated
        // distribution near 0 this beats the worst-case choice 1/2.
        let t = TruncNormal::unit(0.05, 0.05);
        let er = t.partial_mean(0.0, 1.0);
        let b_star = t.inv_cdf(1.0 - er);
        let v_star = single_level_variance(&t, b_star);
        let v_half = single_level_variance(&t, 0.5);
        assert!(
            v_star < v_half,
            "optimal {v_star} should beat worst-case {v_half}"
        );
        // And b* should satisfy first-order optimality approximately.
        let eps = 1e-4;
        let dv = (single_level_variance(&t, b_star + eps)
            - single_level_variance(&t, b_star - eps))
            / (2.0 * eps);
        assert!(dv.abs() < 1e-3, "dQ/db at b* = {dv}");
    }

    #[test]
    fn epsilon_decreases_with_more_levels() {
        // Thm 2 remark: with the max ratio held, more levels shrink ℓ₁ and
        // the bound... (uniform levels: ratio shrinks too).
        let e4 = epsilon_q(&Levels::uniform(4), 1024, 100.0);
        let e8 = epsilon_q(&Levels::uniform(8), 1024, 100.0);
        assert!(e8 < e4);
    }
}
