//! Gradient quantization stack (Section 3 + Appendix D).
//!
//! * [`levels`] — validated quantization level sets (uniform, exponential,
//!   ternary, AMQ's symmetric no-zero exponential, arbitrary adaptive).
//! * [`quantizer`] — bucketed stochastic rounding + dequantization +
//!   exact per-vector variance (Eq. 1–2).
//! * [`bitio`] / [`huffman`] / [`encode`] — the ENCODE/DECODE pipeline of
//!   Appendix D: fp32 bucket norms + Huffman-coded level symbols + sign
//!   bits, with exact bit accounting for the communication model.
//! * [`schemes`] — the method zoo: SuperSGD, QSGDinf, TRN, NUQSGD, and the
//!   adaptive ALQ/ALQ-N/ALQ-G/AMQ/AMQ-N configurations.
//! * [`theory`] — Theorem 2 variance bound ε_Q and Theorem 3 code-length
//!   bound, used by tests and the theory-validation experiments.

pub mod bitio;
pub mod elias;
pub mod encode;
pub mod huffman;
pub mod levels;
pub mod quantizer;
pub mod schemes;
pub mod theory;

pub use encode::{
    decode, decode_into, decode_view_into, decode_view_into_cursor, encode, encode_buckets_into,
    encode_buckets_into_cursor, encode_into, fixed_width, symbol_counts, EncodedGrad, EncodedView,
};
pub use huffman::{smooth_weights, HuffmanBook};
pub use levels::Levels;
pub use quantizer::{QuantScratch, QuantizedGrad, Quantizer};
pub use schemes::Method;

/// Entropy coder for the quantized symbol stream. The paper's Appendix D
/// argues for Huffman codes over the level alphabet; the original QSGD
/// [20] used Elias integer codes over nonzero positions. Both are
/// implemented ([`huffman`] / [`elias`]) and selectable per run
/// (`--codec`), so the coding choice is a runnable ablation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    /// Canonical Huffman over level symbols (Appendix D; needs a shared
    /// codebook, wins whenever the symbol distribution is skewed).
    #[default]
    Huffman,
    /// Elias-γ gap/magnitude coding of nonzeros (QSGD-style; needs no
    /// codebook but a zero level, wins in the ultra-sparse regime).
    Elias,
}

impl Codec {
    pub fn parse(s: &str) -> Option<Codec> {
        match s.to_ascii_lowercase().as_str() {
            "huffman" => Some(Codec::Huffman),
            "elias" => Some(Codec::Elias),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::Huffman => "huffman",
            Codec::Elias => "elias",
        }
    }
}

/// Which stochastic-rounding implementation the exchange lanes drive
/// (`--quantize-impl scalar|fast|pallas`). All three share the RNG draw
/// contract (one uniform per coordinate in a nonzero-norm bucket), so
/// `Scalar` and `Fast` are bit-identical; `Pallas` offloads to the L1
/// quantize kernel when the `pjrt` runtime and artifacts are available
/// and silently falls back to `Fast` otherwise.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantizeImpl {
    /// The seed per-coordinate scalar loop (the parity oracle).
    Scalar,
    /// Branch-light bucket-sliced kernels with a reusable scratch
    /// (bit-identical to `Scalar`; the default).
    #[default]
    Fast,
    /// The AOT-compiled Pallas/XLA quantize kernel via PJRT, inheriting
    /// the lane fan-out; downgraded to `Fast` when unavailable.
    Pallas,
}

impl QuantizeImpl {
    /// Parse a CLI value (`scalar|fast|pallas`).
    pub fn parse(s: &str) -> Option<QuantizeImpl> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(QuantizeImpl::Scalar),
            "fast" => Some(QuantizeImpl::Fast),
            "pallas" => Some(QuantizeImpl::Pallas),
            _ => None,
        }
    }

    /// Canonical lowercase name for logs and banners.
    pub fn name(self) -> &'static str {
        match self {
            QuantizeImpl::Scalar => "scalar",
            QuantizeImpl::Fast => "fast",
            QuantizeImpl::Pallas => "pallas",
        }
    }
}

/// Normalization applied per bucket before quantization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormType {
    /// Euclidean norm (QSGD, NUQSGD, ALQ, AMQ).
    L2,
    /// Max norm (QSGDinf, TernGrad).
    Linf,
}

/// Per-bucket norm, matching `python/compile/kernels/ref.py::bucket_norms`.
#[inline]
pub fn bucket_norm(v: &[f32], norm_type: NormType) -> f32 {
    match norm_type {
        NormType::L2 => {
            // f64 accumulation: cheap and removes reduction-order drift
            // against the XLA-side pairwise sum (see python tests).
            let s: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
            (s as f32).sqrt()
        }
        NormType::Linf => v.iter().fold(0.0f32, |m, &x| m.max(x.abs())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_parses() {
        assert_eq!(Codec::parse("huffman"), Some(Codec::Huffman));
        assert_eq!(Codec::parse("Elias"), Some(Codec::Elias));
        assert_eq!(Codec::parse("arithmetic"), None);
        assert_eq!(Codec::default().name(), "huffman");
    }

    #[test]
    fn quantize_impl_parses() {
        assert_eq!(QuantizeImpl::parse("scalar"), Some(QuantizeImpl::Scalar));
        assert_eq!(QuantizeImpl::parse("Fast"), Some(QuantizeImpl::Fast));
        assert_eq!(QuantizeImpl::parse("PALLAS"), Some(QuantizeImpl::Pallas));
        assert_eq!(QuantizeImpl::parse("simd"), None);
        assert_eq!(QuantizeImpl::default().name(), "fast");
    }

    #[test]
    fn norms() {
        assert!((bucket_norm(&[3.0, -4.0], NormType::L2) - 5.0).abs() < 1e-6);
        assert_eq!(bucket_norm(&[3.0, -4.0], NormType::Linf), 4.0);
        assert_eq!(bucket_norm(&[], NormType::Linf), 0.0);
        assert_eq!(bucket_norm(&[0.0; 4], NormType::L2), 0.0);
    }
}
