//! Bit-level writer/reader for the gradient codec (Appendix D).
//!
//! LSB-first packing: the first bit written is the least significant bit
//! of the first byte. Codes are written most-significant-code-bit first
//! (canonical Huffman order); the fast path [`BitWriter::push_bits_lsb`]
//! takes *stream-order* (bit-reversed) chunks so a whole symbol is one
//! shift+or — the §Perf pass replaced per-bit loops with this.

/// Append-only bit writer over a reusable byte buffer.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Staged bits (low `nacc` bits valid, stream order).
    acc: u64,
    nacc: u32,
    bits_written: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset for reuse without freeing capacity (hot-path requirement).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.nacc = 0;
        self.bits_written = 0;
    }

    #[inline]
    fn flush_bytes(&mut self) {
        while self.nacc >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nacc -= 8;
        }
    }

    /// Push `len` bits already in *stream order* (bit 0 first). O(1).
    #[inline]
    pub fn push_bits_lsb(&mut self, chunk: u64, len: u32) {
        debug_assert!(len <= 57, "chunk too wide for the accumulator");
        debug_assert!(len == 64 || chunk < (1u64 << len));
        self.acc |= chunk << self.nacc;
        self.nacc += len;
        self.bits_written += len as u64;
        self.flush_bytes();
    }

    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.push_bits_lsb(bit as u64, 1);
    }

    /// Push the low `len` bits of `code`, most significant first
    /// (canonical Huffman convention).
    #[inline]
    pub fn push_code(&mut self, code: u32, len: u32) {
        let rev = (code as u64).reverse_bits() >> (64 - len.max(1));
        self.push_bits_lsb(if len == 0 { 0 } else { rev }, len);
    }

    /// Push 32 raw bits (LSB-first within the value), used for fp32 norms.
    #[inline]
    pub fn push_u32(&mut self, v: u32) {
        self.push_bits_lsb(v as u64, 32);
    }

    #[inline]
    pub fn push_f32(&mut self, v: f32) {
        self.push_u32(v.to_bits());
    }

    /// Push 64 bits already in stream order, bypassing the per-push
    /// shift/mask dance. Relies on the `nacc < 8` invariant that
    /// [`BitWriter::flush_bytes`] maintains: the staged bits plus the
    /// chunk always cover at least 8 whole bytes, appended in a single
    /// `extend_from_slice` instead of eight byte pushes.
    #[inline]
    pub fn push_u64_lsb(&mut self, chunk: u64) {
        debug_assert!(self.nacc < 8, "flush_bytes invariant violated");
        let combined = self.acc | (chunk << self.nacc);
        // flush_bytes emits the low byte first, i.e. little-endian order.
        self.buf.extend_from_slice(&combined.to_le_bytes());
        self.acc = if self.nacc == 0 {
            0
        } else {
            chunk >> (64 - self.nacc)
        };
        self.bits_written += 64;
    }

    /// Pack `syms` at a fixed `width` ∈ {1, 2, 3, 4, 8} bits each, whole
    /// `u64` lanes (`64/width` symbols) at a time. Bit-identical to
    /// calling [`BitWriter::push_bits_lsb`] per symbol — pinned by the
    /// exhaustive property test below. Symbols must already fit in
    /// `width` bits.
    ///
    /// Width 3 is the odd one out: 21 symbols fill only 63 bits, so its
    /// lane is split across two accumulator pushes (32 + 31) instead of
    /// the whole-u64 append — still one shift+or per symbol.
    pub fn pack_pow2(&mut self, width: u32, syms: &[u64]) {
        assert!(
            matches!(width, 1 | 2 | 3 | 4 | 8),
            "fixed lane width must be 1/2/3/4/8"
        );
        let per = (64 / width) as usize;
        let mut chunks = syms.chunks_exact(per);
        for chunk in &mut chunks {
            let mut lane = 0u64;
            for (i, &s) in chunk.iter().enumerate() {
                debug_assert!(s < (1u64 << width));
                lane |= s << (i as u32 * width);
            }
            if width == 3 {
                self.push_bits_lsb(lane & 0xFFFF_FFFF, 32);
                self.push_bits_lsb(lane >> 32, 31);
            } else {
                self.push_u64_lsb(lane);
            }
        }
        for &s in chunks.remainder() {
            self.push_bits_lsb(s, width);
        }
    }

    pub fn bits_written(&self) -> u64 {
        self.bits_written
    }

    /// Flush and return the packed bytes.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nacc > 0 {
            self.buf.push(self.acc as u8);
        }
        self.buf
    }

    /// Flush into the internal buffer and borrow it (reusable variant).
    pub fn finish_ref(&mut self) -> &[u8] {
        if self.nacc > 0 {
            self.buf.push(self.acc as u8);
            self.acc = 0;
            self.nacc = 0;
        }
        &self.buf
    }

    /// The packed bytes without flushing: only complete after a
    /// [`BitWriter::finish_ref`] with no pushes since. This is the
    /// zero-copy handle the exchange lanes decode from.
    pub fn bytes(&self) -> &[u8] {
        debug_assert_eq!(self.nacc, 0, "bytes() before finish_ref()");
        &self.buf
    }
}

/// Bit reader matching `BitWriter`'s layout, with a refillable u64
/// buffer so symbol decode is a peek + table lookup.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next unread byte.
    pos: usize,
    /// Bits consumed overall.
    consumed: u64,
    /// Buffered bits (low `nbits` valid, stream order).
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader {
            buf,
            pos: 0,
            consumed: 0,
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.buf.len() {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Peek up to 32 bits (stream order); missing past-the-end bits are 0.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 32);
        if self.nbits < n {
            self.refill();
        }
        self.acc & ((1u64 << n) - 1)
    }

    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(self.nbits >= n || self.pos >= self.buf.len());
        self.acc >>= n;
        self.nbits = self.nbits.saturating_sub(n);
        self.consumed += n as u64;
    }

    #[inline]
    pub fn read_bit(&mut self) -> bool {
        let b = self.peek_bits(1) == 1;
        self.consume(1);
        b
    }

    #[inline]
    pub fn read_u32(&mut self) -> u32 {
        let v = self.peek_bits(32) as u32;
        self.consume(32);
        v
    }

    #[inline]
    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_u32())
    }

    /// Read 64 bits in stream order as two 32-bit halves through the
    /// peek/consume cursor (missing past-the-end bits are 0).
    #[inline]
    pub fn read_u64_lsb(&mut self) -> u64 {
        let lo = self.peek_bits(32);
        self.consume(32);
        let hi = self.peek_bits(32);
        self.consume(32);
        lo | (hi << 32)
    }

    /// Inverse of [`BitWriter::pack_pow2`]: fill `out` with fixed-width
    /// symbols, whole `u64` lanes at a time (63-bit lanes for width 3).
    pub fn unpack_pow2(&mut self, width: u32, out: &mut [u64]) {
        assert!(
            matches!(width, 1 | 2 | 3 | 4 | 8),
            "fixed lane width must be 1/2/3/4/8"
        );
        let per = (64 / width) as usize;
        let mask = (1u64 << width) - 1;
        let mut chunks = out.chunks_exact_mut(per);
        for chunk in &mut chunks {
            let mut lane = if width == 3 {
                let lo = self.peek_bits(32);
                self.consume(32);
                let hi = self.peek_bits(31);
                self.consume(31);
                lo | (hi << 32)
            } else {
                self.read_u64_lsb()
            };
            for s in chunk.iter_mut() {
                *s = lane & mask;
                lane >>= width;
            }
        }
        for s in chunks.into_remainder() {
            *s = self.peek_bits(width);
            self.consume(width);
        }
    }

    pub fn bits_read(&self) -> u64 {
        self.consumed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.push_bit(b);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), b);
        }
    }

    #[test]
    fn code_roundtrip_msb_first() {
        let mut w = BitWriter::new();
        w.push_code(0b1011, 4);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert!(!r.read_bit());
        assert!(r.read_bit());
        assert!(r.read_bit());
    }

    #[test]
    fn f32_roundtrip_aligned_and_unaligned() {
        let vals = [0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE, -0.0];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.push_f32(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.read_f32().to_bits(), v.to_bits());
        }
        let mut w = BitWriter::new();
        w.push_bit(true);
        for &v in &vals {
            w.push_f32(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        for &v in &vals {
            assert_eq!(r.read_f32().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn bits_written_counts() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        w.push_u32(42);
        w.push_code(0b111, 3);
        assert_eq!(w.bits_written(), 36);
    }

    #[test]
    fn clear_reuses() {
        let mut w = BitWriter::new();
        w.push_u32(7);
        let _ = w.finish_ref();
        w.clear();
        w.push_bit(true);
        assert_eq!(w.bits_written(), 1);
        let b = w.finish_ref();
        assert_eq!(b, &[1u8]);
    }

    #[test]
    fn push_bits_lsb_matches_per_bit() {
        let mut a = BitWriter::new();
        let mut b = BitWriter::new();
        // Stream-order chunk 0b1101 (bit0=1 first) == pushes 1,0,1,1.
        a.push_bits_lsb(0b1101, 4);
        for bit in [true, false, true, true] {
            b.push_bit(bit);
        }
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn peek_and_consume() {
        let mut w = BitWriter::new();
        w.push_bits_lsb(0b1010_1100, 8);
        w.push_u32(0xDEADBEEF);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek_bits(4), 0b1100);
        r.consume(4);
        assert_eq!(r.peek_bits(4), 0b1010);
        r.consume(4);
        assert_eq!(r.read_u32(), 0xDEADBEEF);
        assert_eq!(r.bits_read(), 40);
    }

    #[test]
    fn peek_past_end_is_zero() {
        let bytes = [0xFFu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek_bits(16), 0x00FF);
    }

    #[test]
    fn push_u64_lsb_matches_cursor_at_every_alignment() {
        let mut rng = crate::util::Rng::new(11);
        for align in 0..8u32 {
            for _ in 0..50 {
                let chunk = rng.next_u64();
                let prefix = rng.next_u64() & ((1u64 << align.max(1)) - 1);
                let mut a = BitWriter::new();
                let mut b = BitWriter::new();
                if align > 0 {
                    a.push_bits_lsb(prefix, align);
                    b.push_bits_lsb(prefix, align);
                }
                a.push_u64_lsb(chunk);
                b.push_bits_lsb(chunk & 0xFFFF_FFFF, 32);
                b.push_bits_lsb(chunk >> 32, 32);
                assert_eq!(a.bits_written(), b.bits_written());
                assert_eq!(a.finish(), b.finish(), "align {align}");
            }
        }
    }

    #[test]
    fn pack_pow2_matches_cursor_exhaustively() {
        let mut rng = crate::util::Rng::new(12);
        for width in [1u32, 2, 3, 4, 8] {
            let per = (64 / width) as usize;
            let lens: Vec<usize> = (0..=2 * per + 3)
                .chain([5 * per - 1, 5 * per, 5 * per + 1])
                .collect();
            for &len in &lens {
                for align in [0u32, 1, 3, 7] {
                    let syms: Vec<u64> = (0..len)
                        .map(|_| rng.next_u64() & ((1u64 << width) - 1))
                        .collect();
                    let mut fast = BitWriter::new();
                    let mut cursor = BitWriter::new();
                    if align > 0 {
                        fast.push_bits_lsb(1, align);
                        cursor.push_bits_lsb(1, align);
                    }
                    fast.pack_pow2(width, &syms);
                    for &s in &syms {
                        cursor.push_bits_lsb(s, width);
                    }
                    assert_eq!(fast.bits_written(), cursor.bits_written());
                    assert_eq!(
                        fast.finish(),
                        cursor.finish(),
                        "width {width} len {len} align {align}"
                    );
                }
            }
        }
    }

    #[test]
    fn pack_pow2_roundtrips_through_unpack() {
        let mut rng = crate::util::Rng::new(13);
        for width in [1u32, 2, 3, 4, 8] {
            let per = (64 / width) as usize;
            for len in [0, 1, per - 1, per, per + 1, 3 * per + 2] {
                let syms: Vec<u64> = (0..len)
                    .map(|_| rng.next_u64() & ((1u64 << width) - 1))
                    .collect();
                let mut w = BitWriter::new();
                w.push_bits_lsb(0b101, 3); // misalign by 3 bits
                w.pack_pow2(width, &syms);
                w.push_u32(0xC0FFEE); // sentinel: cursor must land exactly here
                let bytes = w.finish();
                let mut r = BitReader::new(&bytes);
                assert_eq!(r.peek_bits(3), 0b101);
                r.consume(3);
                let mut out = vec![0u64; len];
                r.unpack_pow2(width, &mut out);
                assert_eq!(out, syms, "width {width} len {len}");
                assert_eq!(r.read_u32(), 0xC0FFEE);
            }
        }
    }

    #[test]
    fn long_random_stream() {
        let mut rng = crate::util::Rng::new(1);
        let items: Vec<(u64, u32)> = (0..5000)
            .map(|_| {
                let len = 1 + rng.below(20) as u32;
                (rng.next_u64() & ((1 << len) - 1), len)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(chunk, len) in &items {
            w.push_bits_lsb(chunk, len);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(chunk, len) in &items {
            assert_eq!(r.peek_bits(len), chunk);
            r.consume(len);
        }
    }
}
