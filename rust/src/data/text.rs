//! Synthetic token corpus for the LM workload (the ImageNet stand-in).
//!
//! A first-order Markov chain with Zipf-distributed stationary marginals:
//! learnable structure (bigram statistics) so the transformer's loss
//! drops well below the unigram entropy, yet unbounded data like a real
//! corpus stream. Deterministic per seed.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    /// Per-state cumulative transition distributions (vocab × branching).
    successors: Vec<u32>,
    branching: usize,
}

impl Corpus {
    /// Each token can be followed by one of `branching` successors chosen
    /// Zipf-ishly at construction; the successor picked at generation is
    /// skewed so bigram entropy ≈ log2(branching) * 0.7 bits.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Corpus {
        assert!(vocab >= 2 && branching >= 2);
        let mut rng = Rng::new(seed);
        let mut successors = Vec::with_capacity(vocab * branching);
        for _ in 0..vocab {
            for _ in 0..branching {
                // Zipf-flavoured marginal: bias toward low token ids.
                let z = rng.f64();
                let tok = ((vocab as f64).powf(z) - 1.0) as usize % vocab;
                successors.push(tok as u32);
            }
        }
        Corpus {
            vocab,
            successors,
            branching,
        }
    }

    /// Generate `len` tokens for (worker, stream) deterministically.
    pub fn generate(&self, worker: usize, stream: u64, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(
            stream
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(worker as u64),
        );
        let mut out = Vec::with_capacity(len);
        let mut cur = rng.below(self.vocab);
        for _ in 0..len {
            out.push(cur as i32);
            // Skewed successor choice: geometric-ish over the branch list.
            let mut b = 0;
            while b + 1 < self.branching && rng.f64() < 0.45 {
                b += 1;
            }
            cur = self.successors[cur * self.branching + b] as usize;
        }
        out
    }

    /// A batch of `batch` sequences of length `seq` for worker at step.
    pub fn batch(&self, worker: usize, step: usize, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let stream = (step as u64) << 20 | (b as u64);
            out.extend(self.generate(worker, stream, seq));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c = Corpus::new(128, 4, 1);
        assert_eq!(c.generate(0, 7, 50), c.generate(0, 7, 50));
        assert_ne!(c.generate(0, 7, 50), c.generate(1, 7, 50));
        assert_ne!(c.generate(0, 7, 50), c.generate(0, 8, 50));
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::new(64, 4, 2);
        let toks = c.batch(0, 0, 4, 32);
        assert_eq!(toks.len(), 4 * 32);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn has_learnable_bigram_structure() {
        // Conditional entropy H(next | cur) must be far below H(next):
        // that's what the LM can learn.
        let c = Corpus::new(64, 4, 3);
        let toks = c.generate(0, 0, 200_000);
        let mut uni = vec![0f64; 64];
        let mut bi = vec![0f64; 64 * 64];
        for w in toks.windows(2) {
            uni[w[0] as usize] += 1.0;
            bi[w[0] as usize * 64 + w[1] as usize] += 1.0;
        }
        let n: f64 = uni.iter().sum();
        let h_uni: f64 = uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| {
                let p = c / n;
                -p * p.log2()
            })
            .sum();
        let mut h_cond = 0.0;
        for cur in 0..64 {
            let row = &bi[cur * 64..(cur + 1) * 64];
            let tot: f64 = row.iter().sum();
            if tot == 0.0 {
                continue;
            }
            let h: f64 = row
                .iter()
                .filter(|&&c| c > 0.0)
                .map(|&c| {
                    let p = c / tot;
                    -p * p.log2()
                })
                .sum();
            h_cond += (tot / n) * h;
        }
        assert!(
            h_cond < 0.7 * h_uni,
            "H(next|cur) {h_cond} should be well under H(next) {h_uni}"
        );
        assert!(h_cond > 0.5, "not deterministic either: {h_cond}");
    }
}
