//! Gaussian-blob classification data — the CIFAR-10 stand-in.
//!
//! C class centroids drawn on a sphere; samples are centroid + isotropic
//! noise, plus a small fraction of label noise so the task is not
//! separable (otherwise every method trivially reaches 100% and the
//! quantization comparison degenerates). Deterministic per seed.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Blobs {
    pub dim: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<u32>,
    pub val_x: Vec<f32>,
    pub val_y: Vec<u32>,
}

impl Blobs {
    /// `noise` is the per-dimension sample std relative to unit-norm
    /// centroids; ~1.0 gives a task where a good MLP lands at 85–95%.
    pub fn generate(
        dim: usize,
        classes: usize,
        n_train: usize,
        n_val: usize,
        noise: f64,
        seed: u64,
    ) -> Blobs {
        let mut rng = Rng::new(seed);
        // Centroids: random directions at radius 2 — pairwise separation
        // ≈ 2√2, so unit noise gives a Bayes accuracy in the 75–90% range
        // (hard enough that quantization noise matters, per §5).
        let radius = 2.0;
        let mut centroids = vec![0.0f64; classes * dim];
        for c in 0..classes {
            let row = &mut centroids[c * dim..(c + 1) * dim];
            let mut norm = 0.0;
            for v in row.iter_mut() {
                *v = rng.normal();
                norm += *v * *v;
            }
            let norm = norm.sqrt().max(1e-9);
            for v in row.iter_mut() {
                *v *= radius / norm;
            }
        }
        let label_noise = 0.08;
        let gen = |n: usize, rng: &mut Rng| {
            let mut x = Vec::with_capacity(n * dim);
            let mut y = Vec::with_capacity(n);
            for _ in 0..n {
                let c = rng.below(classes);
                let row = &centroids[c * dim..(c + 1) * dim];
                for &m in row {
                    x.push((m + noise * rng.normal()) as f32);
                }
                let label = if rng.f64() < label_noise {
                    rng.below(classes) as u32
                } else {
                    c as u32
                };
                y.push(label);
            }
            (x, y)
        };
        let (train_x, train_y) = gen(n_train, &mut rng);
        let (val_x, val_y) = gen(n_val, &mut rng);
        Blobs {
            dim,
            classes,
            train_x,
            train_y,
            val_x,
            val_y,
        }
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn val_set(&self) -> (&[f32], &[u32]) {
        (&self.val_x, &self.val_y)
    }

    /// Sample a minibatch from worker `w`'s contiguous shard of the
    /// training set (data-parallel sharding).
    pub fn sample_train_shard(
        &self,
        worker: usize,
        workers: usize,
        batch: usize,
        rng: &mut Rng,
        x_out: &mut Vec<f32>,
        y_out: &mut Vec<u32>,
    ) {
        let n = self.n_train();
        let shard = n / workers;
        let start = worker * shard;
        let len = if worker == workers - 1 { n - start } else { shard };
        x_out.clear();
        y_out.clear();
        for _ in 0..batch {
            let i = start + rng.below(len);
            x_out.extend_from_slice(&self.train_x[i * self.dim..(i + 1) * self.dim]);
            y_out.push(self.train_y[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Blobs::generate(8, 4, 100, 20, 1.0, 3);
        let b = Blobs::generate(8, 4, 100, 20, 1.0, 3);
        let c = Blobs::generate(8, 4, 100, 20, 1.0, 4);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.val_y, b.val_y);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn shapes() {
        let b = Blobs::generate(16, 10, 500, 100, 1.0, 1);
        assert_eq!(b.train_x.len(), 500 * 16);
        assert_eq!(b.train_y.len(), 500);
        assert_eq!(b.val_x.len(), 100 * 16);
        assert!(b.train_y.iter().all(|&y| y < 10));
    }

    #[test]
    fn all_classes_present() {
        let b = Blobs::generate(8, 4, 1000, 100, 1.0, 2);
        for c in 0..4u32 {
            assert!(b.train_y.contains(&c));
        }
    }

    #[test]
    fn shards_are_disjoint_ranges() {
        let b = Blobs::generate(4, 2, 100, 10, 1.0, 5);
        let mut rng = Rng::new(0);
        let (mut x0, mut y0) = (Vec::new(), Vec::new());
        b.sample_train_shard(0, 4, 200, &mut rng, &mut x0, &mut y0);
        // Every sampled row from shard 0 must exist in rows 0..25.
        for k in 0..y0.len() {
            let row = &x0[k * 4..(k + 1) * 4];
            let found = (0..25).any(|i| &b.train_x[i * 4..(i + 1) * 4] == row);
            assert!(found, "sample {k} escaped its shard");
        }
    }

    #[test]
    fn task_is_learnable_but_not_trivial() {
        // Nearest-centroid achievable accuracy should be well above chance
        // but below 100% (noise + label noise).
        let b = Blobs::generate(8, 4, 400, 400, 1.0, 7);
        // Estimate class means from train data.
        let mut means = vec![0.0f64; 4 * 8];
        let mut counts = [0usize; 4];
        for i in 0..b.n_train() {
            let c = b.train_y[i] as usize;
            counts[c] += 1;
            for d in 0..8 {
                means[c * 8 + d] += b.train_x[i * 8 + d] as f64;
            }
        }
        for c in 0..4 {
            for d in 0..8 {
                means[c * 8 + d] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..b.val_y.len() {
            let mut best = (0, f64::INFINITY);
            for c in 0..4 {
                let mut d2 = 0.0;
                for d in 0..8 {
                    let diff = b.val_x[i * 8 + d] as f64 - means[c * 8 + d];
                    d2 += diff * diff;
                }
                if d2 < best.1 {
                    best = (c, d2);
                }
            }
            if best.0 as u32 == b.val_y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / b.val_y.len() as f64;
        assert!(acc > 0.5, "learnable: {acc}");
        assert!(acc < 0.999, "not trivial: {acc}");
    }
}
