//! Synthetic datasets (the CIFAR-10 / ImageNet substitutes; DESIGN.md §3).

pub mod synth;
pub mod text;

pub use synth::Blobs;
pub use text::Corpus;
