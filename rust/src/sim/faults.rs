//! Deterministic fault scripts for elastic-membership runs.
//!
//! A [`FaultPlan`] is a seedless, fully scripted schedule of membership
//! churn and network misbehaviour, parsed from `--faults SPEC`:
//!
//! * `kill:W@S` — worker `W` leaves permanently at the top of step `S`
//!   (it contributes nothing from step `S` onward).
//! * `join:W@S` — worker `W` is a standby replica until step `S`: it
//!   mirrors the model lockstep but sends no frames before `S`.
//! * `delay:W@S:MS` — worker `W`'s frame for step `S` is late by `MS`
//!   milliseconds (charged to the simulated network clock; realized as
//!   a real sleep over TCP, where it exercises the leader's
//!   timeout-and-retry path).
//!
//! Events are comma-separated (`kill:1@3,join:2@8`); the literal `none`
//! is the empty plan. The same plan drives both the in-process
//! simulator and a loopback TCP cluster, which is what lets
//! `tests/fault_parity.rs` pin sim ≡ TCP under identical churn.

use std::fmt;

/// What happens to a worker at a scheduled step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Permanent departure at the top of the step.
    Kill,
    /// Late frame: the worker's step contribution lags by this many
    /// milliseconds.
    Delay(u64),
    /// Standby replica activates at this step.
    Join,
}

impl FaultKind {
    /// Stable ordering rank used by the canonical event sort.
    fn rank(self) -> u8 {
        match self {
            FaultKind::Join => 0,
            FaultKind::Delay(_) => 1,
            FaultKind::Kill => 2,
        }
    }
}

/// One scripted fault: `kind` applied to `worker` at `step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Target worker id (validated against the world size at run setup).
    pub worker: usize,
    /// Training step the fault fires at.
    pub step: usize,
    /// What happens.
    pub kind: FaultKind,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            FaultKind::Kill => write!(f, "kill:{}@{}", self.worker, self.step),
            FaultKind::Join => write!(f, "join:{}@{}", self.worker, self.step),
            FaultKind::Delay(ms) => write!(f, "delay:{}@{}:{}", self.worker, self.step, ms),
        }
    }
}

/// A deterministic, order-canonical schedule of [`FaultEvent`]s.
///
/// The default plan is empty (no faults); `parse("none")` also yields
/// it. Events are kept sorted by `(step, worker, kind)` so
/// `parse(name()) == self` holds for every valid plan.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a `--faults` spec. Returns a human-readable error for
    /// malformed specs (empty string, unknown kinds, bad numbers,
    /// duplicate `(worker, step)` pairs, or a rejoin-after-kill).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err("empty fault spec (use 'none' for no faults)".into());
        }
        if spec == "none" {
            return Ok(FaultPlan::default());
        }
        let mut events = Vec::new();
        for item in spec.split(',') {
            events.push(parse_event(item.trim())?);
        }
        let mut plan = FaultPlan { events };
        plan.events.sort_by_key(|e| (e.step, e.worker, e.kind.rank()));
        plan.check()?;
        Ok(plan)
    }

    /// Structural validity: no duplicate `(worker, step)`, at most one
    /// kill and one join per worker, and no join scheduled at or after
    /// a kill (a dead worker cannot rejoin — over TCP its process is
    /// gone).
    fn check(&self) -> Result<(), String> {
        for (i, a) in self.events.iter().enumerate() {
            for b in &self.events[i + 1..] {
                if a.worker == b.worker && a.step == b.step {
                    return Err(format!(
                        "duplicate fault for worker {} at step {}",
                        a.worker, a.step
                    ));
                }
            }
        }
        let world = self.events.iter().map(|e| e.worker + 1).max().unwrap_or(0);
        for w in 0..world {
            let kills: Vec<usize> = self
                .events
                .iter()
                .filter(|e| e.worker == w && e.kind == FaultKind::Kill)
                .map(|e| e.step)
                .collect();
            let joins: Vec<usize> = self
                .events
                .iter()
                .filter(|e| e.worker == w && e.kind == FaultKind::Join)
                .map(|e| e.step)
                .collect();
            if kills.len() > 1 {
                return Err(format!("worker {w} has more than one kill fault"));
            }
            if joins.len() > 1 {
                return Err(format!("worker {w} has more than one join fault"));
            }
            if let (Some(&kill), Some(&join)) = (kills.first(), joins.first()) {
                if join >= kill {
                    return Err(format!(
                        "worker {w} cannot rejoin after a kill (join@{join} is not before kill@{kill})"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Canonical spec string; `parse(name()) == self` for every valid
    /// plan (the empty plan prints `none`).
    pub fn name(&self) -> String {
        if self.events.is_empty() {
            return "none".into();
        }
        self.events
            .iter()
            .map(|e| e.to_string())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// True when the plan schedules nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// All scripted events in canonical `(step, worker, kind)` order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Reject plans that target workers outside `0..world`.
    pub fn validate(&self, world: usize) -> Result<(), String> {
        for e in &self.events {
            if e.worker >= world {
                return Err(format!(
                    "fault '{e}' targets worker {} but the run has {world} workers",
                    e.worker
                ));
            }
        }
        Ok(())
    }

    /// Workers that start the run as standby replicas (they have a
    /// `join` event, so they are inactive until it fires).
    pub fn initially_inactive(&self) -> Vec<usize> {
        self.events
            .iter()
            .filter(|e| e.kind == FaultKind::Join)
            .map(|e| e.worker)
            .collect()
    }

    /// Workers killed at the top of `step`.
    pub fn kills_at(&self, step: usize) -> Vec<usize> {
        self.events
            .iter()
            .filter(|e| e.step == step && e.kind == FaultKind::Kill)
            .map(|e| e.worker)
            .collect()
    }

    /// Workers joining at the top of `step`.
    pub fn joins_at(&self, step: usize) -> Vec<usize> {
        self.events
            .iter()
            .filter(|e| e.step == step && e.kind == FaultKind::Join)
            .map(|e| e.worker)
            .collect()
    }

    /// `(worker, ms)` delays scheduled for `step`.
    pub fn delays_at(&self, step: usize) -> Vec<(usize, u64)> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::Delay(ms) if e.step == step => Some((e.worker, ms)),
                _ => None,
            })
            .collect()
    }

    /// The step `worker` is killed at, if any.
    pub fn kill_step(&self, worker: usize) -> Option<usize> {
        self.events
            .iter()
            .find(|e| e.worker == worker && e.kind == FaultKind::Kill)
            .map(|e| e.step)
    }

    /// The step `worker` joins at, if any.
    pub fn join_step(&self, worker: usize) -> Option<usize> {
        self.events
            .iter()
            .find(|e| e.worker == worker && e.kind == FaultKind::Join)
            .map(|e| e.step)
    }

    /// The delay (ms) scheduled for `worker` at `step`, if any.
    pub fn delay_ms(&self, worker: usize, step: usize) -> Option<u64> {
        self.events.iter().find_map(|e| match e.kind {
            FaultKind::Delay(ms) if e.worker == worker && e.step == step => Some(ms),
            _ => None,
        })
    }
}

fn parse_event(item: &str) -> Result<FaultEvent, String> {
    if item.is_empty() {
        return Err("empty fault entry (stray comma?)".into());
    }
    let (kind, rest) = item
        .split_once(':')
        .ok_or_else(|| format!("fault '{item}' is missing ':worker@step'"))?;
    let (worker_s, tail) = rest
        .split_once('@')
        .ok_or_else(|| format!("fault '{item}' is missing '@step'"))?;
    let worker: usize = worker_s
        .parse()
        .map_err(|_| format!("fault '{item}' has an invalid worker id '{worker_s}'"))?;
    match kind {
        "kill" | "join" => {
            let step: usize = tail
                .parse()
                .map_err(|_| format!("fault '{item}' has an invalid step '{tail}'"))?;
            let kind = if kind == "kill" { FaultKind::Kill } else { FaultKind::Join };
            Ok(FaultEvent { worker, step, kind })
        }
        "delay" => {
            let (step_s, ms_s) = tail
                .split_once(':')
                .ok_or_else(|| format!("delay fault '{item}' is missing ':ms'"))?;
            let step: usize = step_s
                .parse()
                .map_err(|_| format!("fault '{item}' has an invalid step '{step_s}'"))?;
            let ms: u64 = ms_s
                .parse()
                .map_err(|_| format!("fault '{item}' has an invalid delay '{ms_s}'"))?;
            Ok(FaultEvent {
                worker,
                step,
                kind: FaultKind::Delay(ms),
            })
        }
        other => Err(format!("unknown fault kind '{other}' (expected kill|delay|join)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_canonicalizes() {
        let plan = FaultPlan::parse("kill:1@3,join:2@1,delay:0@3:250").unwrap();
        assert_eq!(plan.events().len(), 3);
        // Canonical order is (step, worker, kind).
        assert_eq!(plan.name(), "join:2@1,delay:0@3:250,kill:1@3");
        assert_eq!(FaultPlan::parse(&plan.name()).unwrap(), plan);
        assert_eq!(plan.kills_at(3), vec![1]);
        assert_eq!(plan.joins_at(1), vec![2]);
        assert_eq!(plan.delays_at(3), vec![(0, 250)]);
        assert_eq!(plan.kill_step(1), Some(3));
        assert_eq!(plan.join_step(2), Some(1));
        assert_eq!(plan.delay_ms(0, 3), Some(250));
        assert_eq!(plan.initially_inactive(), vec![2]);
    }

    #[test]
    fn none_is_the_empty_plan() {
        let plan = FaultPlan::parse("none").unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::default());
        assert_eq!(plan.name(), "none");
        assert_eq!(FaultPlan::parse(&plan.name()).unwrap(), plan);
    }

    #[test]
    fn rejects_malformed_specs() {
        for (spec, needle) in [
            ("", "empty fault spec"),
            ("   ", "empty fault spec"),
            ("kill:1@3,", "empty fault entry"),
            ("kill", "missing ':worker@step'"),
            ("kill:1", "missing '@step'"),
            ("kill:x@3", "invalid worker id"),
            ("kill:1@x", "invalid step"),
            ("delay:1@3", "missing ':ms'"),
            ("delay:1@3:x", "invalid delay"),
            ("zap:1@3", "unknown fault kind 'zap'"),
            ("kill:1@3,delay:1@3:10", "duplicate fault for worker 1 at step 3"),
            ("kill:1@3,kill:1@5", "more than one kill"),
            ("join:1@2,join:1@5", "more than one join"),
            ("kill:1@3,join:1@8", "cannot rejoin after a kill"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(
                err.contains(needle),
                "spec {spec:?}: error {err:?} lacks {needle:?}"
            );
        }
    }

    #[test]
    fn validate_bounds_workers() {
        let plan = FaultPlan::parse("kill:3@2").unwrap();
        assert!(plan.validate(4).is_ok());
        let err = plan.validate(3).unwrap_err();
        assert!(err.contains("worker 3"), "{err}");
    }
}
