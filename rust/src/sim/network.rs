//! α-β communication cost model.
//!
//! The paper's testbed constrains the network to 1 Gbit/s (Appendix K.3);
//! we regenerate the timing tables by charging *measured encoded bits*
//! against this analytical model instead of wall-clocking V100 nodes.
//!
//! `time(msg) = α + bits / β` per message; a step's communication is the
//! all-to-all exchange of every worker's encoded gradient under the
//! chosen topology.

/// Broadcast topology for the gradient exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every worker sends its gradient to all M−1 peers, all links active
    /// in parallel: time = (M−1) · max_bits / β + α·(M−1).
    FlatAllToAll,
    /// Ring all-gather: 2(M−1) stages of (1/M of the payload), which for
    /// identical payload sizes is time = 2·(M−1)/M · total_bits/β.
    Ring,
}

/// Analytical network.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Bandwidth in bits per second.
    pub beta: f64,
    pub topology: Topology,
}

impl NetworkModel {
    /// The paper's constrained testbed: 1 Gbit/s, 50 µs latency.
    pub fn paper_testbed() -> Self {
        NetworkModel {
            alpha: 50e-6,
            beta: 1e9,
            topology: Topology::Ring,
        }
    }

    /// Communication time for one synchronous step in which each of the
    /// `m` workers contributes an encoded gradient of `bits_per_worker`.
    pub fn step_time(&self, bits_per_worker: &[u64]) -> f64 {
        let m = bits_per_worker.len();
        if m <= 1 {
            return 0.0;
        }
        match self.topology {
            Topology::FlatAllToAll => {
                let max_bits = *bits_per_worker.iter().max().unwrap() as f64;
                (m as f64 - 1.0) * (self.alpha + max_bits / self.beta)
            }
            Topology::Ring => {
                // Bandwidth-optimal all-reduce: 2(M−1) stages of payload/M.
                let max_bits = *bits_per_worker.iter().max().unwrap() as f64;
                let stages = 2.0 * (m as f64 - 1.0);
                stages * self.alpha + (stages / m as f64) * max_bits / self.beta
            }
        }
    }

    /// Time to exchange full-precision gradients of `d` f32 coords.
    pub fn fp32_step_time(&self, d: usize, m: usize) -> f64 {
        self.step_time(&vec![32 * d as u64; m])
    }

    /// One message of `bits` over one link: α + bits/β. The primitive
    /// the executable topology schedules charge per hop
    /// (`exchange::topology`), in contrast to the closed-form
    /// [`NetworkModel::step_time`] used by the flat engine.
    pub fn link_time(&self, bits: u64) -> f64 {
        self.alpha + bits as f64 / self.beta
    }

    /// Serialized fan-in (or fan-out) of `n` messages of worst-case
    /// size `max_bits` through a single endpoint: n · (α + max/β).
    pub fn fan_time(&self, n: usize, max_bits: u64) -> f64 {
        n as f64 * self.link_time(max_bits)
    }
}

/// Running communication meter for a training run.
#[derive(Clone, Debug, Default)]
pub struct Meter {
    pub total_bits: u64,
    pub total_time: f64,
    /// Measured gradient-compute wall seconds charged alongside the
    /// modeled communication (`sim::Cluster::train` reports its compute
    /// phase here so step wall time can be reconstructed honestly).
    pub compute_seconds: f64,
    /// Modeled communication seconds hidden behind overlapped work by
    /// an active `--pipeline` schedule. Never exceeds `total_time`;
    /// always 0 for `--pipeline off`.
    pub hidden_seconds: f64,
    pub steps: u64,
}

impl Meter {
    pub fn record(&mut self, net: &NetworkModel, bits_per_worker: &[u64]) {
        self.total_bits += bits_per_worker.iter().sum::<u64>();
        self.total_time += net.step_time(bits_per_worker);
        self.steps += 1;
    }

    /// Record a step whose bits and seconds were already metered per hop
    /// by an executable topology schedule (the analytical closed-form
    /// path is [`Meter::record`]).
    pub fn record_raw(&mut self, bits: u64, seconds: f64) {
        self.total_bits += bits;
        self.total_time += seconds;
        self.steps += 1;
    }

    /// Charge extra modeled wall time without a step or any bits — used
    /// by fault injection (`delay:W@S:MS`) to stretch a straggler's
    /// step.
    pub fn add_seconds(&mut self, seconds: f64) {
        self.total_time += seconds;
    }

    /// Charge measured gradient-compute wall seconds (kept out of
    /// `total_time`, whose semantics stay pure modeled communication).
    pub fn record_compute(&mut self, seconds: f64) {
        self.compute_seconds += seconds;
    }

    /// Mark `seconds` of already-recorded communication time as hidden
    /// behind overlapped work (an active `--pipeline` schedule).
    /// Clamped so hidden time never exceeds the recorded total.
    pub fn hide(&mut self, seconds: f64) {
        self.hidden_seconds = (self.hidden_seconds + seconds.max(0.0)).min(self.total_time);
    }

    /// End-to-end modeled wall time: compute plus the communication
    /// that could not be hidden behind it — `max(compute, comm)` plus
    /// the unhidden remainder, accumulated per step.
    pub fn wall_time(&self) -> f64 {
        self.compute_seconds + self.total_time - self.hidden_seconds
    }

    pub fn bits_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.total_bits as f64 / self.steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_free() {
        let n = NetworkModel::paper_testbed();
        assert_eq!(n.step_time(&[1_000_000]), 0.0);
    }

    #[test]
    fn more_bits_more_time() {
        let n = NetworkModel::paper_testbed();
        let t1 = n.step_time(&[1_000_000; 4]);
        let t2 = n.step_time(&[4_000_000; 4]);
        assert!(t2 > t1 * 2.0);
    }

    #[test]
    fn compression_ratio_shows_up() {
        // 3-bit encoding ~ 4/32 of fp32 time at large payloads.
        let n = NetworkModel {
            alpha: 0.0,
            beta: 1e9,
            topology: Topology::Ring,
        };
        let d = 10_000_000usize;
        let fp32 = n.fp32_step_time(d, 4);
        let q3 = n.step_time(&[4 * d as u64; 4]);
        assert!((q3 / fp32 - 4.0 / 32.0).abs() < 1e-9);
    }

    #[test]
    fn flat_scales_with_m() {
        let n = NetworkModel {
            alpha: 0.0,
            beta: 1e9,
            topology: Topology::FlatAllToAll,
        };
        let t4 = n.step_time(&[1_000_000; 4]);
        let t8 = n.step_time(&[1_000_000; 8]);
        assert!((t8 / t4 - 7.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn ring_near_bandwidth_optimal() {
        // Ring: per-worker time ≈ 2·total_own_bytes/β regardless of M.
        let n = NetworkModel {
            alpha: 0.0,
            beta: 1e9,
            topology: Topology::Ring,
        };
        let t4 = n.step_time(&[8_000_000; 4]);
        let t16 = n.step_time(&[8_000_000; 16]);
        assert!(t16 < t4 * 1.4, "{t16} vs {t4}");
    }

    #[test]
    fn flat_all_to_all_matches_hand_computation() {
        // M = 4 workers of 1 Mbit each on the paper testbed
        // (α = 50 µs, β = 1 Gbit/s):
        //   (M−1) · (α + bits/β) = 3 · (50e-6 + 1e6/1e9) = 3.15 ms.
        let n = NetworkModel {
            alpha: 50e-6,
            beta: 1e9,
            topology: Topology::FlatAllToAll,
        };
        let t = n.step_time(&[1_000_000; 4]);
        assert!((t - 3.15e-3).abs() < 1e-12, "{t}");
        // Heterogeneous payloads are charged at the straggler's size.
        let t = n.step_time(&[1_000_000, 250_000, 500_000, 100_000]);
        assert!((t - 3.15e-3).abs() < 1e-12, "{t}");
    }

    #[test]
    fn ring_matches_hand_computation() {
        // Ring, M = 4, 1 Mbit payloads: 2(M−1) = 6 stages of payload/M:
        //   6·α + (6/4)·1e6/1e9 = 3.0e-4 + 1.5e-3 = 1.8 ms.
        let n = NetworkModel {
            alpha: 50e-6,
            beta: 1e9,
            topology: Topology::Ring,
        };
        let t = n.step_time(&[1_000_000; 4]);
        assert!((t - 1.8e-3).abs() < 1e-12, "{t}");
    }

    #[test]
    fn link_and_fan_time_primitives() {
        let n = NetworkModel {
            alpha: 50e-6,
            beta: 1e9,
            topology: Topology::FlatAllToAll,
        };
        // α + bits/β = 50e-6 + 1e-3.
        assert!((n.link_time(1_000_000) - 1.05e-3).abs() < 1e-15);
        // 3 serialized messages through one endpoint.
        assert!((n.fan_time(3, 1_000_000) - 3.15e-3).abs() < 1e-15);
        assert_eq!(n.fan_time(0, 1_000_000), 0.0);
        // The flat closed form is exactly a fan over M−1 links.
        assert_eq!(n.step_time(&[1_000_000; 4]), n.fan_time(3, 1_000_000));
    }

    #[test]
    fn meter_record_raw_accumulates() {
        let mut m = Meter::default();
        m.record_raw(1000, 0.25);
        m.record_raw(500, 0.5);
        assert_eq!(m.total_bits, 1500);
        assert_eq!(m.steps, 2);
        assert!((m.total_time - 0.75).abs() < 1e-15);
    }

    #[test]
    fn meter_pipeline_ledger() {
        let mut m = Meter::default();
        m.record_raw(1000, 2.0);
        m.record_compute(3.0);
        // Nothing hidden yet: wall time is plain compute + comm.
        assert!((m.wall_time() - 5.0).abs() < 1e-15);
        m.hide(0.5);
        assert!((m.hidden_seconds - 0.5).abs() < 1e-15);
        assert!((m.wall_time() - 4.5).abs() < 1e-15);
        // Hiding clamps at the recorded communication total.
        m.hide(100.0);
        assert!((m.hidden_seconds - 2.0).abs() < 1e-15);
        assert!((m.wall_time() - 3.0).abs() < 1e-15);
        // Negative requests are inert.
        m.hide(-1.0);
        assert!((m.hidden_seconds - 2.0).abs() < 1e-15);
    }

    #[test]
    fn meter_accumulates() {
        let n = NetworkModel::paper_testbed();
        let mut m = Meter::default();
        m.record(&n, &[100; 4]);
        m.record(&n, &[300; 4]);
        assert_eq!(m.total_bits, 1600);
        assert_eq!(m.steps, 2);
        assert!((m.bits_per_step() - 800.0).abs() < 1e-12);
    }
}
