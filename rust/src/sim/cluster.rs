//! The M-worker data-parallel training loop (Algorithm 1).
//!
//! Mirrors the paper's evaluation protocol: M workers compute stochastic
//! gradients on their own data shards; quantized methods physically
//! quantize → entropy-encode → meter bits → decode → aggregate; the model
//! is updated with (momentum) SGD; at the update schedule 𝒰 the adaptive
//! methods re-fit the coordinate distribution and re-optimize levels (and
//! every method refreshes its Huffman codebook).
//!
//! Single-process simulation of the M workers — exactly the paper's own
//! methodology ("we simulate training with 4-GPUs on a single GPU by
//! quantizing and dequantizing the gradient from 4 mini-batches"), plus
//! real bit accounting. The wire-true distributed version lives in
//! `crate::coordinator`.

use crate::adaptive::{update_levels, Estimator};
use crate::model::{EvalResult, TrainTask};
use crate::opt::{LrSchedule, Optimizer, Sgd, Umsgd, UpdateSchedule};
use crate::quant::{
    symbol_counts, HuffmanBook, Method, QuantizedGrad, Quantizer,
};
use crate::sim::network::{Meter, NetworkModel};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub method: Method,
    pub workers: usize,
    pub bits: u32,
    pub bucket: usize,
    pub iters: usize,
    pub lr: LrSchedule,
    pub updates: UpdateSchedule,
    /// Heavy-ball momentum (0.0 disables; paper uses 0.9).
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Evaluate every this many steps (0 = final eval only).
    pub eval_every: usize,
    /// Record gradient/quantization variance every this many steps (0 = off).
    pub variance_every: usize,
    pub network: NetworkModel,
}

impl ClusterConfig {
    /// Table 3-shaped defaults scaled to a small horizon.
    pub fn paper_default(method: Method, iters: usize) -> Self {
        ClusterConfig {
            method,
            workers: 4,
            bits: 3,
            bucket: 8192,
            iters,
            lr: LrSchedule::paper_default(0.1, iters),
            updates: UpdateSchedule::paper_default(iters),
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 1,
            eval_every: (iters / 20).max(1),
            variance_every: 0,
            network: NetworkModel::paper_testbed(),
        }
    }
}

/// Per-recorded-step statistics.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step: usize,
    pub train_loss: f64,
    pub lr: f32,
    /// Encoded bits across all workers this step (0 for full precision…
    /// which is charged as 32·d·M).
    pub bits: u64,
}

/// Variance sample (Figs. 1/4/5): per-coordinate averages.
#[derive(Clone, Copy, Debug)]
pub struct VarianceSample {
    pub step: usize,
    /// Sampling variance of a single worker's gradient (the "SGD" line).
    pub sgd_var: f64,
    /// Exact quantization variance of the aggregated estimate.
    pub quant_var: f64,
    /// Variance of the final update direction:
    /// sampling/M (+ quantization/M² summed over workers).
    pub total_var: f64,
}

/// Everything a training run produces.
#[derive(Clone, Debug)]
pub struct TrainRecord {
    pub method: Method,
    pub steps: Vec<StepStats>,
    pub evals: Vec<(usize, EvalResult)>,
    pub final_eval: EvalResult,
    pub final_levels: Option<Vec<f64>>,
    pub variance: Vec<VarianceSample>,
    pub comm_bits: u64,
    pub comm_time: f64,
    /// Wall time spent inside quantize+encode+decode (the codec hot path).
    pub codec_seconds: f64,
    /// Number of level updates performed.
    pub level_updates: usize,
}

/// Add-δ smoothing so every level symbol gets a Huffman code (a symbol
/// absent from one batch can still occur later in the run).
fn smooth(weights: &[f64]) -> Vec<f64> {
    let total: f64 = weights.iter().sum();
    let delta = (total * 1e-4).max(1e-6);
    weights.iter().map(|w| w + delta).collect()
}

/// The simulated cluster.
pub struct Cluster {
    cfg: ClusterConfig,
    quantizer: Option<Quantizer>,
    book: Option<HuffmanBook>,
    sym_counts: Vec<f64>,
    estimator: Option<Estimator>,
    rngs: Vec<Rng>,
    meter: Meter,
    /// Reused codec buffers (hot loop is allocation-free once warm).
    writer: crate::quant::bitio::BitWriter,
    dec_buf: QuantizedGrad,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let mut seeder = Rng::new(cfg.seed);
        let rngs = (0..cfg.workers).map(|w| seeder.fork(w as u64)).collect();
        let quantizer = cfg.method.initial_levels(cfg.bits).map(|levels| {
            let mut q = Quantizer::new(levels, cfg.method.norm_type(), cfg.bucket);
            if let Some(c) = cfg.method.clip_factor() {
                q = q.with_clip(c);
            }
            q
        });
        let estimator = quantizer.as_ref().map(|q| {
            Estimator::new(
                cfg.bucket,
                q.norm_type(),
                // App. K: 20 components for CIFAR-scale runs.
                20,
            )
        });
        let sym_counts = quantizer
            .as_ref()
            .map(|q| vec![0.0; q.levels().num_symbols()])
            .unwrap_or_default();
        Cluster {
            quantizer,
            book: None,
            sym_counts,
            estimator,
            rngs,
            meter: Meter::default(),
            writer: crate::quant::bitio::BitWriter::new(),
            dec_buf: QuantizedGrad {
                qidx: Vec::new(),
                norms: Vec::new(),
                tail: Vec::new(),
                bucket: cfg.bucket,
            },
            cfg,
        }
    }

    pub fn quantizer(&self) -> Option<&Quantizer> {
        self.quantizer.as_ref()
    }

    /// Force TernGrad-style c·σ clipping on the quantizer regardless of
    /// method (the Appendix K.2 / Fig. 14 ablation).
    pub fn force_clip(&mut self, c: f32) {
        if let Some(q) = self.quantizer.take() {
            self.quantizer = Some(q.with_clip(c));
        }
    }

    /// Run the full training loop on `task`.
    pub fn train(&mut self, task: &mut dyn TrainTask) -> TrainRecord {
        let d = task.param_count();
        let m = self.cfg.workers;
        let mut params = task.init_params(self.cfg.seed ^ 0xA5A5);
        let mut optimizer: Box<dyn Optimizer> = if self.cfg.momentum > 0.0 {
            Box::new(Umsgd::heavy_ball(self.cfg.momentum, self.cfg.weight_decay))
        } else {
            Box::new(Sgd::new(self.cfg.weight_decay))
        };

        let active_workers = if self.cfg.method == Method::SingleSgd { 1 } else { m };
        let mut grads: Vec<Vec<f32>> = vec![vec![0.0; d]; active_workers];
        let mut ghat = vec![0.0f32; d];
        let mut agg = vec![0.0f32; d];
        let mut qbuf = QuantizedGrad {
            qidx: Vec::new(),
            norms: Vec::new(),
            tail: Vec::new(),
            bucket: self.cfg.bucket,
        };
        let mut bits_per_worker = vec![0u64; active_workers];

        let mut rec = TrainRecord {
            method: self.cfg.method,
            steps: Vec::new(),
            evals: Vec::new(),
            final_eval: EvalResult::default(),
            final_levels: None,
            variance: Vec::new(),
            comm_bits: 0,
            comm_time: 0.0,
            codec_seconds: 0.0,
            level_updates: 0,
        };

        for step in 0..self.cfg.iters {
            // 1. Local gradients.
            let mut mean_loss = 0.0f64;
            for w in 0..active_workers {
                let loss = task.grad(&params, w, step, &mut grads[w]);
                mean_loss += loss as f64 / active_workers as f64;
            }

            // 2. Level adaptation + codebook refresh (Algorithm 1 line 4).
            if self.quantizer.is_some() && self.cfg.updates.is_update_step(step) {
                self.adapt(&grads);
                rec.level_updates += 1;
            }

            // 3. Quantize → encode → meter → decode → aggregate.
            agg.fill(0.0);
            let mut step_bits = 0u64;
            if let Some(q) = &self.quantizer {
                let t0 = std::time::Instant::now();
                let inv_workers = 1.0 / active_workers as f32;
                for w in 0..active_workers {
                    q.quantize_into(&grads[w], &mut self.rngs[w], &mut qbuf);
                    // Lazily build the codebook from the first gradient's
                    // empirical symbol distribution (smoothed: every
                    // symbol needs a code — later steps may emit symbols
                    // unseen in the first batch).
                    if self.book.is_none() {
                        let counts = symbol_counts(&qbuf, q.levels());
                        self.book = Some(HuffmanBook::from_weights(&smooth(&counts)));
                    }
                    // Codebook-refresh statistics: sampling every 10th
                    // step is plenty (a full counting pass per worker-step
                    // was ~25% of codec time — §Perf).
                    if step % 10 == 0 {
                        for (c, n) in self
                            .sym_counts
                            .iter_mut()
                            .zip(symbol_counts(&qbuf, q.levels()))
                        {
                            *c += n;
                        }
                    }
                    let book = self.book.as_ref().unwrap();
                    // Reused writer/decode buffers: zero allocation once warm.
                    self.writer.clear();
                    let bits = crate::quant::encode_into(&qbuf, q.levels(), book, &mut self.writer);
                    let enc = crate::quant::EncodedGrad {
                        bytes: self.writer.finish_ref().to_vec(),
                        bits,
                        n_full: qbuf.qidx.len(),
                        n_tail: qbuf.tail.len(),
                        bucket: qbuf.bucket,
                    };
                    bits_per_worker[w] = enc.bits + enc.n_tail as u64 * 32;
                    step_bits += bits_per_worker[w];
                    crate::quant::decode_into(&enc, q.levels(), book, &mut self.dec_buf);
                    q.dequantize(&self.dec_buf, &mut ghat);
                    for (a, &g) in agg.iter_mut().zip(&ghat) {
                        *a += g * inv_workers;
                    }
                }
                rec.codec_seconds += t0.elapsed().as_secs_f64();
            } else {
                for w in 0..active_workers {
                    bits_per_worker[w] = 32 * d as u64;
                    step_bits += bits_per_worker[w];
                    for (a, &g) in agg.iter_mut().zip(&grads[w]) {
                        *a += g / active_workers as f32;
                    }
                }
            }
            self.meter
                .record(&self.cfg.network, &bits_per_worker[..active_workers]);

            // 4. Variance telemetry (Figs. 1/4/5).
            if self.cfg.variance_every > 0 && step % self.cfg.variance_every == 0 {
                rec.variance
                    .push(self.variance_sample(step, &grads, active_workers, d));
            }

            // 5. Update.
            let lr = self.cfg.lr.lr(step);
            optimizer.step(&mut params, &agg, lr);

            rec.steps.push(StepStats {
                step,
                train_loss: mean_loss,
                lr,
                bits: step_bits,
            });

            if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                rec.evals.push((step + 1, task.eval(&params)));
            }
        }

        rec.final_eval = task.eval(&params);
        rec.final_levels = self
            .quantizer
            .as_ref()
            .map(|q| q.levels().mags().to_vec());
        rec.comm_bits = self.meter.total_bits;
        rec.comm_time = self.meter.total_time;
        rec
    }

    /// Fit the distribution and update levels + codebook.
    fn adapt(&mut self, grads: &[Vec<f32>]) {
        let (Some(q), Some(est)) = (&mut self.quantizer, &mut self.estimator) else {
            return;
        };
        est.clear();
        for g in grads {
            est.observe(g);
        }
        let mut rng = self.rngs[0].fork(0xE57);
        if self.cfg.method.is_adaptive() {
            if let Some(mix) = est.fit(self.cfg.method.weighted_mixture(), &mut rng) {
                let new_levels = update_levels(self.cfg.method, q.levels(), &mix);
                q.set_levels(new_levels);
                // Model-based codebook (Prop. 6) for the new levels.
                let probs = crate::adaptive::objective::symbol_probs(&mix, q.levels());
                self.book = Some(HuffmanBook::from_weights(&smooth(&probs)));
                self.sym_counts = vec![0.0; q.levels().num_symbols()];
                return;
            }
        }
        // Non-adaptive (or estimator empty): refresh the codebook from the
        // empirical symbol counts accumulated since the last refresh.
        if self.sym_counts.iter().sum::<f64>() > 0.0 {
            self.book = Some(HuffmanBook::from_weights(&smooth(&self.sym_counts)));
            for c in self.sym_counts.iter_mut() {
                *c = 0.0;
            }
        }
    }

    fn variance_sample(
        &self,
        step: usize,
        grads: &[Vec<f32>],
        active_workers: usize,
        d: usize,
    ) -> VarianceSample {
        // Sampling variance across workers (unbiased, per coordinate).
        let mut sgd_var = 0.0f64;
        if active_workers > 1 {
            for i in 0..d {
                let mean: f64 = grads[..active_workers]
                    .iter()
                    .map(|g| g[i] as f64)
                    .sum::<f64>()
                    / active_workers as f64;
                let ss: f64 = grads[..active_workers]
                    .iter()
                    .map(|g| (g[i] as f64 - mean).powi(2))
                    .sum();
                sgd_var += ss / (active_workers as f64 - 1.0);
            }
            sgd_var /= d as f64;
        }
        // Exact quantization variance of the mean estimate.
        let quant_var = if let Some(q) = &self.quantizer {
            let sum: f64 = grads[..active_workers]
                .iter()
                .map(|g| q.exact_variance(g))
                .sum();
            sum / (active_workers as f64).powi(2) / d as f64
        } else {
            0.0
        };
        let total_var = sgd_var / active_workers as f64 + quant_var;
        VarianceSample {
            step,
            sgd_var,
            quant_var,
            total_var,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Blobs;
    use crate::model::{Mlp, MlpTask};

    fn task(workers: usize, seed: u64) -> MlpTask {
        let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, seed);
        MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, workers, seed)
    }

    fn small_cfg(method: Method, iters: usize) -> ClusterConfig {
        let mut cfg = ClusterConfig::paper_default(method, iters);
        cfg.bucket = 128;
        cfg.eval_every = 0;
        cfg
    }

    #[test]
    fn supersgd_matches_serial_mean() {
        // One step of SuperSGD must equal the average of per-worker grads
        // applied via the same optimizer (pure aggregation check).
        let mut cfg = small_cfg(Method::SuperSgd, 1);
        cfg.momentum = 0.0;
        cfg.weight_decay = 0.0;
        let mut t = task(4, 3);
        let params = t.init_params(cfg.seed ^ 0xA5A5);
        // Manual average.
        let d = t.param_count();
        let mut manual = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        for w in 0..4 {
            t.grad(&params, w, 0, &mut g);
            for (m, &x) in manual.iter_mut().zip(&g) {
                *m += x / 4.0;
            }
        }
        let lr = cfg.lr.lr(0);
        let want: Vec<f32> = params
            .iter()
            .zip(&manual)
            .map(|(p, g)| p - lr * g)
            .collect();

        let mut cluster = Cluster::new(cfg);
        let mut t2 = task(4, 3);
        let rec = cluster.train(&mut t2);
        assert_eq!(rec.steps.len(), 1);
        // Train again reading out params via a fresh eval on a task whose
        // gradient at step 0 equals `manual`… instead, verify the recorded
        // loss matches and rely on determinism for the rest.
        let _ = want;
        assert!(rec.steps[0].train_loss > 0.0);
        assert_eq!(rec.comm_bits, 4 * 32 * d as u64);
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut cfg = small_cfg(Method::Alq, 30);
            cfg.seed = seed;
            cfg.variance_every = 10;
            let mut cluster = Cluster::new(cfg);
            cluster.train(&mut task(4, 3))
        };
        let a = run(5);
        let b = run(5);
        let c = run(6);
        assert_eq!(a.final_eval.accuracy, b.final_eval.accuracy);
        assert_eq!(a.comm_bits, b.comm_bits);
        assert_eq!(a.final_levels, b.final_levels);
        assert_ne!(
            (a.comm_bits, a.final_eval.loss.to_bits()),
            (c.comm_bits, c.final_eval.loss.to_bits())
        );
    }

    #[test]
    fn quantized_training_learns() {
        for method in [Method::QsgdInf, Method::Alq, Method::Amq] {
            let mut cfg = small_cfg(method, 400);
            cfg.updates = UpdateSchedule::at(vec![1, 25], 100, 25);
            let mut cluster = Cluster::new(cfg);
            let rec = cluster.train(&mut task(4, 7));
            assert!(
                rec.final_eval.accuracy > 0.65,
                "{method}: acc {}",
                rec.final_eval.accuracy
            );
        }
    }

    #[test]
    fn quantized_uses_fewer_bits_than_fp32() {
        let mut cfg = small_cfg(Method::NuqSgd, 10);
        cfg.momentum = 0.0;
        let mut cluster = Cluster::new(cfg);
        let mut t = task(4, 9);
        let d = t.param_count();
        let rec = cluster.train(&mut t);
        let fp32_bits = 10u64 * 4 * 32 * d as u64;
        assert!(
            rec.comm_bits < fp32_bits / 4,
            "{} vs fp32 {}",
            rec.comm_bits,
            fp32_bits
        );
    }

    #[test]
    fn adaptive_updates_move_levels() {
        let mut cfg = small_cfg(Method::Alq, 60);
        cfg.updates = UpdateSchedule::at(vec![5], usize::MAX, usize::MAX);
        let init = Method::Alq.initial_levels(3).unwrap();
        let mut cluster = Cluster::new(cfg);
        let rec = cluster.train(&mut task(4, 11));
        assert_eq!(rec.level_updates, 1);
        let final_levels = rec.final_levels.unwrap();
        assert_ne!(final_levels, init.mags().to_vec());
    }

    #[test]
    fn variance_telemetry_sane() {
        let mut cfg = small_cfg(Method::QsgdInf, 30);
        cfg.variance_every = 10;
        let mut cluster = Cluster::new(cfg);
        let rec = cluster.train(&mut task(4, 13));
        assert_eq!(rec.variance.len(), 3);
        for v in &rec.variance {
            assert!(v.sgd_var > 0.0);
            assert!(v.quant_var > 0.0);
            assert!(v.total_var >= v.sgd_var / 4.0);
        }
        // SuperSGD: no quantization variance.
        let mut cfg = small_cfg(Method::SuperSgd, 30);
        cfg.variance_every = 10;
        let rec = Cluster::new(cfg).train(&mut task(4, 13));
        assert!(rec.variance.iter().all(|v| v.quant_var == 0.0));
    }

    #[test]
    fn single_sgd_computes_one_gradient() {
        let mut cfg = small_cfg(Method::SingleSgd, 5);
        cfg.momentum = 0.0;
        let mut t = task(4, 15);
        let d = t.param_count();
        let rec = Cluster::new(cfg).train(&mut t);
        // One worker, no peers: bits metered per step = 32·d.
        assert_eq!(rec.comm_bits, 5 * 32 * d as u64);
        assert_eq!(rec.comm_time, 0.0, "single worker pays no comm time");
    }
}
