//! The M-worker data-parallel training loop (Algorithm 1).
//!
//! Mirrors the paper's evaluation protocol: M workers compute stochastic
//! gradients on their own data shards; quantized methods physically
//! quantize → entropy-encode → meter bits → decode → aggregate; the model
//! is updated with (momentum) SGD; at the update schedule 𝒰 the adaptive
//! methods re-fit the coordinate distribution and re-optimize levels (and
//! every method refreshes its Huffman codebook).
//!
//! Single-process simulation of the M workers — exactly the paper's own
//! methodology ("we simulate training with 4-GPUs on a single GPU by
//! quantizing and dequantizing the gradient from 4 mini-batches"), plus
//! real bit accounting. The whole codec path is delegated to the
//! exchange backend the configured `--topology` selects (the flat
//! engine, sharded leaders, a two-level tree, or ring all-reduce —
//! `crate::exchange::topology`), all sharing one
//! [`crate::exchange::BackendCore`]; `--parallel` fans the flat worker
//! lanes, the sharded shard-leader lanes, and the tree group reductions
//! out across threads without changing a single bit of the run
//! (DESIGN.md §8).

use crate::exchange::{
    make_backend, BitsPolicy, CodecPhase, ExchangeBackend, ExchangeConfig, LazyPolicy,
    ParallelMode, PipelineMode, TopologySpec,
};
use crate::model::{EvalResult, TrainTask};
use crate::opt::{LrSchedule, Optimizer, Sgd, Umsgd, UpdateSchedule};
use crate::quant::{Codec, Method, QuantizeImpl, Quantizer};
use crate::sim::faults::FaultPlan;
use crate::sim::network::NetworkModel;
use crate::trace::{Level, Tracer};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub method: Method,
    pub workers: usize,
    /// Bit-budget policy (`--bits B` is shorthand for `fixed:B`;
    /// `--bits-policy` selects `schedule:…` or `variance`).
    pub bits: BitsPolicy,
    pub bucket: usize,
    pub iters: usize,
    pub lr: LrSchedule,
    pub updates: UpdateSchedule,
    /// Heavy-ball momentum (0.0 disables; paper uses 0.9).
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Evaluate every this many steps (0 = final eval only).
    pub eval_every: usize,
    /// Record gradient/quantization variance every this many steps (0 = off).
    pub variance_every: usize,
    pub network: NetworkModel,
    /// Lane scheduling inside the exchange backend (applies to flat,
    /// sharded, and tree; the ring schedule is inherently serial).
    pub parallel: ParallelMode,
    /// Pipeline schedule (`--pipeline off|overlap|stale:1`): `overlap`
    /// hides wire time behind encode inside a step (bit-identical to
    /// `off`); `stale:1` computes step t+1's gradients while step t's
    /// exchange completes and applies the aggregate one step late.
    pub pipeline: PipelineMode,
    /// Exchange schedule (`--topology flat|sharded:S|tree:G|ring`).
    pub topology: TopologySpec,
    /// Entropy coder for the symbol stream (`--codec huffman|elias`).
    pub codec: Codec,
    /// Lane quantization implementation
    /// (`--quantize-impl scalar|fast|pallas`).
    pub quantize_impl: QuantizeImpl,
    /// Deterministic fault plan (`--faults kill:W@S,delay:W@S:MS,join:W@S`;
    /// empty = no faults). Kills and joins mutate the membership before
    /// the step's gradients; delays charge straggler seconds to the
    /// meter.
    pub faults: FaultPlan,
    /// Error-feedback residual memory (`--error-feedback on|off`): each
    /// worker adds its residual to the gradient before quantization and
    /// keeps the decode error for the next step. Unsupported over
    /// `--topology ring` (partials are re-quantized per stage).
    pub error_feedback: bool,
    /// Lazy skip-round policy (`--lazy off|thresh:T|laq:C@K`): a worker
    /// whose message fails the send rule transmits a skip marker instead
    /// of a frame that step.
    pub lazy: LazyPolicy,
}

impl ClusterConfig {
    /// Table 3-shaped defaults scaled to a small horizon.
    pub fn paper_default(method: Method, iters: usize) -> Self {
        ClusterConfig {
            method,
            workers: 4,
            bits: BitsPolicy::Fixed(3),
            bucket: 8192,
            iters,
            lr: LrSchedule::paper_default(0.1, iters),
            updates: UpdateSchedule::paper_default(iters),
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 1,
            eval_every: (iters / 20).max(1),
            variance_every: 0,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Auto,
            pipeline: PipelineMode::Off,
            topology: TopologySpec::Flat,
            codec: Codec::Huffman,
            quantize_impl: QuantizeImpl::default(),
            faults: FaultPlan::default(),
            error_feedback: false,
            lazy: LazyPolicy::Off,
        }
    }

    fn exchange(&self) -> ExchangeConfig {
        ExchangeConfig {
            method: self.method,
            workers: self.workers,
            bits: self.bits.clone(),
            bucket: self.bucket,
            seed: self.seed,
            network: self.network,
            parallel: self.parallel,
            codec: self.codec,
            quantize_impl: self.quantize_impl,
        }
    }
}

/// Per-recorded-step statistics.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step: usize,
    pub train_loss: f64,
    pub lr: f32,
    /// Encoded bits across all workers this step (0 for full precision…
    /// which is charged as 32·d·M).
    pub bits: u64,
    /// Quantization bit-width this step ran at (the bit controller's
    /// per-step choice; 32 for full precision).
    pub width: u32,
    /// Active-membership bitmask this step (bit w set ⇔ worker w
    /// contributed to the aggregate). All-ones for fault-free runs.
    pub active: u64,
    /// Sent-frame bitmask this step (bit w set ⇔ worker w sent an
    /// encoded frame rather than a skip marker). Equals `active` unless
    /// a `--lazy` policy skipped someone; part of the sim ≡ TCP parity
    /// projection.
    pub sent: u64,
    /// FNV-1a over the parameter bits after this step's update — the
    /// per-step replica fingerprint fault-parity tests project on.
    pub params_hash: u64,
}

/// Variance sample (Figs. 1/4/5): per-coordinate averages.
#[derive(Clone, Copy, Debug)]
pub struct VarianceSample {
    pub step: usize,
    /// Sampling variance of a single worker's gradient (the "SGD" line).
    pub sgd_var: f64,
    /// Exact quantization variance of the aggregated estimate.
    pub quant_var: f64,
    /// Variance of the final update direction:
    /// sampling/M (+ quantization/M² summed over workers).
    pub total_var: f64,
}

/// Everything a training run produces.
#[derive(Clone, Debug)]
pub struct TrainRecord {
    pub method: Method,
    pub steps: Vec<StepStats>,
    pub evals: Vec<(usize, EvalResult)>,
    pub final_eval: EvalResult,
    pub final_levels: Option<Vec<f64>>,
    pub variance: Vec<VarianceSample>,
    pub comm_bits: u64,
    pub comm_time: f64,
    /// Measured wall seconds of the local-gradient compute phase,
    /// summed over steps.
    pub compute_time: f64,
    /// Modeled communication seconds hidden behind overlapped work by
    /// the configured `--pipeline` schedule (0 for `off`).
    pub hidden_time: f64,
    /// Wall time spent inside quantize+encode+decode (the codec hot path).
    pub codec_seconds: f64,
    /// Per-phase split of `codec_seconds` (quantize vs encode vs decode;
    /// per-lane sums, so totals can exceed wall time under `--parallel`).
    pub codec_phase: CodecPhase,
    /// Number of level updates performed.
    pub level_updates: usize,
    /// Worker-steps that sent only a skip marker instead of a frame
    /// (0 unless a `--lazy` policy is active) — the realized zero-frame
    /// savings the `exp` tables report.
    pub skipped_frames: u64,
    /// FNV-1a over the final parameter bits (parity fingerprint shared
    /// with the distributed workers' replica hash).
    pub params_hash: u64,
}

impl TrainRecord {
    /// End-to-end modeled wall time of the run: compute plus the
    /// communication that could not be hidden behind it — per-step
    /// `max(compute, comm)` plus the unhidden remainder, accumulated
    /// (see [`crate::sim::network::Meter::wall_time`]).
    pub fn wall_time(&self) -> f64 {
        self.compute_time + self.comm_time - self.hidden_time
    }
}

/// The simulated cluster: local gradients + optimizer around the
/// exchange backend the configured topology selects (the flat engine,
/// sharded leaders, a two-level tree, or ring all-reduce — see
/// `exchange::topology`).
pub struct Cluster {
    cfg: ClusterConfig,
    engine: Box<dyn ExchangeBackend>,
    tracer: Tracer,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        // `RunConfig::validate` rejects this at the CLI; assert for
        // programmatic construction too — ring re-quantizes partials per
        // stage, so there is no per-worker decode error to feed back.
        assert!(
            !(cfg.error_feedback && cfg.topology == TopologySpec::Ring),
            "--error-feedback is unsupported over --topology ring"
        );
        let mut engine = make_backend(cfg.exchange(), cfg.topology);
        engine.core_mut().set_pipeline(cfg.pipeline);
        engine.core_mut().set_error_feedback(cfg.error_feedback);
        engine.core_mut().set_lazy(cfg.lazy);
        // Workers with a `join:W@S` fault start as standby: their lane
        // exists (they compute gradients and track the replica) but they
        // are outside the active set until their join step.
        for w in cfg.faults.initially_inactive() {
            engine.core_mut().membership_mut().deactivate_from_start(w);
        }
        Cluster {
            cfg,
            engine,
            tracer: Tracer::disabled(),
        }
    }

    /// Attach a tracer; the exchange backend inherits it, so per-step
    /// phase/hop/width events flow to the same sink as run lifecycle.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.engine.core_mut().set_tracer(tracer.clone());
        self.tracer = tracer;
    }

    pub fn quantizer(&self) -> Option<&Quantizer> {
        self.engine.quantizer()
    }

    /// Force TernGrad-style c·σ clipping on the quantizer regardless of
    /// method (the Appendix K.2 / Fig. 14 ablation).
    pub fn force_clip(&mut self, c: f32) {
        self.engine.force_clip(c);
    }

    /// Run the full training loop on `task`.
    pub fn train(&mut self, task: &mut dyn TrainTask) -> TrainRecord {
        let d = task.param_count();
        let mut params = task.init_params(self.cfg.seed ^ 0xA5A5);
        let mut optimizer: Box<dyn Optimizer> = if self.cfg.momentum > 0.0 {
            Box::new(Umsgd::heavy_ball(self.cfg.momentum, self.cfg.weight_decay))
        } else {
            Box::new(Sgd::new(self.cfg.weight_decay))
        };

        let active_workers = self.engine.active_workers();
        let mut grads: Vec<Vec<f32>> = vec![vec![0.0; d]; active_workers];
        let mut agg = vec![0.0f32; d];

        let mut rec = TrainRecord {
            method: self.cfg.method,
            steps: Vec::new(),
            evals: Vec::new(),
            final_eval: EvalResult::default(),
            final_levels: None,
            variance: Vec::new(),
            comm_bits: 0,
            comm_time: 0.0,
            compute_time: 0.0,
            hidden_time: 0.0,
            codec_seconds: 0.0,
            codec_phase: CodecPhase::default(),
            level_updates: 0,
            skipped_frames: 0,
            params_hash: 0,
        };

        // stale:1 double buffer: the aggregate (and the lr of its step)
        // waiting to be applied one step late, plus the previous step's
        // modeled comm seconds that this step's compute overlaps.
        let stale = self.cfg.pipeline == PipelineMode::Stale;
        let mut pending: Option<(Vec<f32>, f32)> = None;
        let mut prev_comm_seconds = 0.0f64;

        self.tracer.event(Level::Info, "run_start", |o| {
            o.insert("runtime", Json::Str("sim".into()));
            o.insert("method", Json::Str(self.cfg.method.name().into()));
            o.insert("topology", Json::Str(self.cfg.topology.name()));
            o.insert("policy", Json::Str(self.cfg.bits.name()));
            o.insert("codec", Json::Str(self.cfg.codec.name().into()));
            o.insert("workers", Json::Num(self.cfg.workers as f64));
            o.insert("bucket", Json::Num(self.cfg.bucket as f64));
            o.insert("seed", Json::Num(self.cfg.seed as f64));
            o.insert("parallel", Json::Str(self.cfg.parallel.name().into()));
            o.insert("pipeline", Json::Str(self.cfg.pipeline.name().into()));
            o.insert(
                "error_feedback",
                Json::Bool(self.cfg.error_feedback),
            );
            o.insert("lazy", Json::Str(self.cfg.lazy.name()));
        });

        for step in 0..self.cfg.iters {
            // 0. Membership churn from the fault plan, applied before the
            // step's gradients so the step runs against the new active
            // set (joins before kills, matching the plan's canonical
            // within-step order).
            for w in self.cfg.faults.joins_at(step) {
                self.engine.core_mut().join_worker(step, w);
            }
            for w in self.cfg.faults.kills_at(step) {
                self.engine.core_mut().drop_worker(step, w);
            }
            for (w, ms) in self.cfg.faults.delays_at(step) {
                // A straggler stretches the step's modeled wall time but
                // moves no extra bits; delays on inactive workers are
                // inert.
                if self.engine.core().membership().is_active(w) {
                    self.engine.core_mut().meter_mut().add_seconds(ms as f64 / 1000.0);
                }
            }

            // 1. Local gradients (the compute phase; wall-clocked so
            // pipelined schedules can hide communication behind it).
            let t_compute = std::time::Instant::now();
            let mut mean_loss = 0.0f64;
            for (w, grad) in grads.iter_mut().enumerate() {
                let loss = task.grad(&params, w, step, grad);
                mean_loss += loss as f64 / active_workers as f64;
            }
            let compute_seconds = t_compute.elapsed().as_secs_f64();
            self.engine
                .core_mut()
                .meter_mut()
                .record_compute(compute_seconds);
            if self.tracer.on(Level::Debug) {
                self.tracer.event(Level::Debug, "phase", |o| {
                    o.insert("step", Json::Num(step as f64));
                    o.insert("phase", Json::Str("compute".into()));
                    o.insert("wall_seconds", Json::Num(compute_seconds));
                });
            }
            if stale && step > 0 {
                // Step t−1's exchange completes while this step's
                // gradients compute: up to this step's compute wall
                // time of its modeled comm seconds is hidden.
                self.engine
                    .core_mut()
                    .meter_mut()
                    .hide(compute_seconds.min(prev_comm_seconds));
            }

            // 2. Level adaptation + codebook refresh (Algorithm 1 line 4).
            if self.engine.is_quantized() && self.cfg.updates.is_update_step(step) {
                self.engine.adapt(&grads);
                rec.level_updates += 1;
            }

            // 3. Quantize → encode → meter → decode → aggregate, fanned
            // out across the worker lanes by the exchange engine.
            let comm_before = self.engine.meter().total_time;
            let step_bits = self.engine.exchange(step, &grads, &mut agg);
            prev_comm_seconds = self.engine.meter().total_time - comm_before;

            // 4. Variance telemetry (Figs. 1/4/5).
            if self.cfg.variance_every > 0 && step % self.cfg.variance_every == 0 {
                rec.variance
                    .push(self.variance_sample(step, &grads, active_workers, d));
            }

            // 5. Update. Under stale:1 the aggregate lands one step
            // late: apply step t−1's buffered exchange (at its own lr),
            // then buffer this step's — classic pipelined-SGD
            // staleness, double-buffered through `pending`.
            let lr = self.cfg.lr.lr(step);
            if stale {
                if let Some((stale_agg, stale_lr)) = pending.take() {
                    optimizer.step(&mut params, &stale_agg, stale_lr);
                }
                pending = Some((agg.clone(), lr));
            } else {
                optimizer.step(&mut params, &agg, lr);
            }

            rec.skipped_frames += self.engine.core().skipped_count() as u64;
            rec.steps.push(StepStats {
                step,
                train_loss: mean_loss,
                lr,
                bits: step_bits,
                width: self.engine.step_width(),
                active: self.engine.core().membership().active_mask(),
                sent: self.engine.core().sent_mask(),
                params_hash: crate::util::hash_params(&params),
            });

            if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                rec.evals.push((step + 1, task.eval(&params)));
            }
        }

        // Drain the stale pipeline: the last step's exchange still has
        // to land, so every run applies exactly `iters` updates.
        if let Some((stale_agg, stale_lr)) = pending {
            optimizer.step(&mut params, &stale_agg, stale_lr);
        }

        rec.final_eval = task.eval(&params);
        rec.final_levels = self.engine.final_levels();
        rec.comm_bits = self.engine.meter().total_bits;
        rec.comm_time = self.engine.meter().total_time;
        rec.compute_time = self.engine.meter().compute_seconds;
        rec.hidden_time = self.engine.meter().hidden_seconds;
        rec.codec_seconds = self.engine.codec_seconds();
        rec.codec_phase = self.engine.codec_phase();
        rec.params_hash = crate::util::hash_params(&params);
        self.tracer.event(Level::Info, "run_end", |o| {
            o.insert("steps", Json::Num(rec.steps.len() as f64));
            o.insert("total_bits", Json::Num(rec.comm_bits as f64));
        });
        rec
    }

    fn variance_sample(
        &self,
        step: usize,
        grads: &[Vec<f32>],
        active_workers: usize,
        d: usize,
    ) -> VarianceSample {
        // Sampling variance across workers (unbiased, per coordinate).
        let mut sgd_var = 0.0f64;
        if active_workers > 1 {
            for i in 0..d {
                let mean: f64 = grads[..active_workers]
                    .iter()
                    .map(|g| g[i] as f64)
                    .sum::<f64>()
                    / active_workers as f64;
                let ss: f64 = grads[..active_workers]
                    .iter()
                    .map(|g| (g[i] as f64 - mean).powi(2))
                    .sum();
                sgd_var += ss / (active_workers as f64 - 1.0);
            }
            sgd_var /= d as f64;
        }
        // Exact quantization variance of the mean estimate.
        let quant_var = if let Some(q) = self.engine.quantizer() {
            let sum: f64 = grads[..active_workers]
                .iter()
                .map(|g| q.exact_variance(g))
                .sum();
            sum / (active_workers as f64).powi(2) / d as f64
        } else {
            0.0
        };
        let total_var = sgd_var / active_workers as f64 + quant_var;
        VarianceSample {
            step,
            sgd_var,
            quant_var,
            total_var,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Blobs;
    use crate::model::{Mlp, MlpTask};

    fn task(workers: usize, seed: u64) -> MlpTask {
        let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, seed);
        MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, workers, seed)
    }

    fn small_cfg(method: Method, iters: usize) -> ClusterConfig {
        let mut cfg = ClusterConfig::paper_default(method, iters);
        cfg.bucket = 128;
        cfg.eval_every = 0;
        cfg
    }

    #[test]
    fn supersgd_matches_serial_mean() {
        // One step of SuperSGD must equal the average of per-worker grads
        // applied via the same optimizer (pure aggregation check).
        let mut cfg = small_cfg(Method::SuperSgd, 1);
        cfg.momentum = 0.0;
        cfg.weight_decay = 0.0;
        let mut t = task(4, 3);
        let params = t.init_params(cfg.seed ^ 0xA5A5);
        // Manual average.
        let d = t.param_count();
        let mut manual = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        for w in 0..4 {
            t.grad(&params, w, 0, &mut g);
            for (m, &x) in manual.iter_mut().zip(&g) {
                *m += x / 4.0;
            }
        }
        let lr = cfg.lr.lr(0);
        let want: Vec<f32> = params
            .iter()
            .zip(&manual)
            .map(|(p, g)| p - lr * g)
            .collect();

        let mut cluster = Cluster::new(cfg);
        let mut t2 = task(4, 3);
        let rec = cluster.train(&mut t2);
        assert_eq!(rec.steps.len(), 1);
        // The engine's aggregation order matches the manual loop exactly,
        // so the one-step parameters agree bit for bit.
        assert_eq!(rec.params_hash, crate::util::hash_params(&want));
        assert!(rec.steps[0].train_loss > 0.0);
        assert_eq!(rec.comm_bits, 4 * 32 * d as u64);
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut cfg = small_cfg(Method::Alq, 30);
            cfg.seed = seed;
            cfg.variance_every = 10;
            let mut cluster = Cluster::new(cfg);
            cluster.train(&mut task(4, 3))
        };
        let a = run(5);
        let b = run(5);
        let c = run(6);
        assert_eq!(a.final_eval.accuracy, b.final_eval.accuracy);
        assert_eq!(a.comm_bits, b.comm_bits);
        assert_eq!(a.final_levels, b.final_levels);
        assert_eq!(a.params_hash, b.params_hash);
        assert_ne!(
            (a.comm_bits, a.final_eval.loss.to_bits()),
            (c.comm_bits, c.final_eval.loss.to_bits())
        );
    }

    #[test]
    fn quantized_training_learns() {
        for method in [Method::QsgdInf, Method::Alq, Method::Amq] {
            let mut cfg = small_cfg(method, 400);
            cfg.updates = UpdateSchedule::at(vec![1, 25], 100, 25);
            let mut cluster = Cluster::new(cfg);
            let rec = cluster.train(&mut task(4, 7));
            assert!(
                rec.final_eval.accuracy > 0.65,
                "{method}: acc {}",
                rec.final_eval.accuracy
            );
        }
    }

    #[test]
    fn quantized_uses_fewer_bits_than_fp32() {
        let mut cfg = small_cfg(Method::NuqSgd, 10);
        cfg.momentum = 0.0;
        let mut cluster = Cluster::new(cfg);
        let mut t = task(4, 9);
        let d = t.param_count();
        let rec = cluster.train(&mut t);
        let fp32_bits = 10u64 * 4 * 32 * d as u64;
        assert!(
            rec.comm_bits < fp32_bits / 4,
            "{} vs fp32 {}",
            rec.comm_bits,
            fp32_bits
        );
    }

    #[test]
    fn adaptive_updates_move_levels() {
        let mut cfg = small_cfg(Method::Alq, 60);
        cfg.updates = UpdateSchedule::at(vec![5], usize::MAX, usize::MAX);
        let init = Method::Alq.initial_levels(3).unwrap();
        let mut cluster = Cluster::new(cfg);
        let rec = cluster.train(&mut task(4, 11));
        assert_eq!(rec.level_updates, 1);
        let final_levels = rec.final_levels.unwrap();
        assert_ne!(final_levels, init.mags().to_vec());
    }

    #[test]
    fn variance_telemetry_sane() {
        let mut cfg = small_cfg(Method::QsgdInf, 30);
        cfg.variance_every = 10;
        let mut cluster = Cluster::new(cfg);
        let rec = cluster.train(&mut task(4, 13));
        assert_eq!(rec.variance.len(), 3);
        for v in &rec.variance {
            assert!(v.sgd_var > 0.0);
            assert!(v.quant_var > 0.0);
            assert!(v.total_var >= v.sgd_var / 4.0);
        }
        // SuperSGD: no quantization variance.
        let mut cfg = small_cfg(Method::SuperSgd, 30);
        cfg.variance_every = 10;
        let rec = Cluster::new(cfg).train(&mut task(4, 13));
        assert!(rec.variance.iter().all(|v| v.quant_var == 0.0));
    }

    #[test]
    fn every_topology_trains_and_meters() {
        // Full parity is asserted in rust/tests/topology_parity.rs; here
        // each backend must run end to end with positive bit accounting.
        for topo in [
            TopologySpec::Flat,
            TopologySpec::Sharded(2),
            TopologySpec::Tree(2),
            TopologySpec::Ring,
        ] {
            let mut cfg = small_cfg(Method::QsgdInf, 10);
            cfg.topology = topo;
            let rec = Cluster::new(cfg).train(&mut task(4, 17));
            assert!(rec.comm_bits > 0, "{}", topo.name());
            assert!(rec.comm_time > 0.0, "{}", topo.name());
            assert!(rec.steps.iter().all(|s| s.bits > 0), "{}", topo.name());
        }
    }

    #[test]
    fn elias_codec_selectable_and_value_identical() {
        let mut cfg = small_cfg(Method::NuqSgd, 20);
        cfg.codec = Codec::Elias;
        let elias = Cluster::new(cfg.clone()).train(&mut task(4, 19));
        cfg.codec = Codec::Huffman;
        let huff = Cluster::new(cfg).train(&mut task(4, 19));
        // Same quantization draws → identical decoded values → identical
        // training trajectory; only the coded bits differ.
        assert_eq!(elias.params_hash, huff.params_hash);
        assert_ne!(elias.comm_bits, huff.comm_bits);
    }

    #[test]
    fn bits_policies_record_per_step_widths_and_meter_actual_bits() {
        // fixed: constant width on every step record.
        let mut cfg = small_cfg(Method::QsgdInf, 8);
        cfg.bits = BitsPolicy::Fixed(3);
        let rec = Cluster::new(cfg).train(&mut task(4, 21));
        assert!(rec.steps.iter().all(|s| s.width == 3));

        // schedule: the width moves exactly at the segment boundary and
        // the per-step metered bits move with it.
        let mut cfg = small_cfg(Method::QsgdInf, 12);
        cfg.bits = BitsPolicy::parse("schedule:2@0,4@6").unwrap();
        let rec = Cluster::new(cfg).train(&mut task(4, 21));
        assert!(rec.steps[..6].iter().all(|s| s.width == 2));
        assert!(rec.steps[6..].iter().all(|s| s.width == 4));
        let narrow: u64 = rec.steps[..6].iter().map(|s| s.bits).sum();
        let wide: u64 = rec.steps[6..].iter().map(|s| s.bits).sum();
        assert!(wide > narrow, "4-bit steps must meter more bits: {narrow} vs {wide}");
        assert_eq!(rec.comm_bits, narrow + wide);

        // variance: stays inside its declared range and is a pure
        // function of the seed.
        let run = || {
            let mut cfg = small_cfg(Method::Alq, 30);
            cfg.bits = BitsPolicy::parse("variance:2-4").unwrap();
            Cluster::new(cfg).train(&mut task(4, 23))
        };
        let a = run();
        let b = run();
        assert!(a.steps.iter().all(|s| (2..=4).contains(&s.width)));
        assert_eq!(a.params_hash, b.params_hash);
        assert_eq!(
            a.steps.iter().map(|s| s.width).collect::<Vec<_>>(),
            b.steps.iter().map(|s| s.width).collect::<Vec<_>>()
        );

        // Full precision reports width 32.
        let rec = Cluster::new(small_cfg(Method::SuperSgd, 3)).train(&mut task(4, 21));
        assert!(rec.steps.iter().all(|s| s.width == 32));
    }

    #[test]
    fn overlap_pipeline_is_bit_identical_to_off_and_hides_time() {
        let run = |pipeline: PipelineMode| {
            let mut cfg = small_cfg(Method::Alq, 30);
            cfg.pipeline = pipeline;
            Cluster::new(cfg).train(&mut task(4, 27))
        };
        let off = run(PipelineMode::Off);
        let overlap = run(PipelineMode::Overlap);
        // Bit-identical run: same per-step bits, same per-step replica
        // hashes, same final parameters and meter bits.
        assert_eq!(off.params_hash, overlap.params_hash);
        assert_eq!(off.comm_bits, overlap.comm_bits);
        assert_eq!(
            off.steps
                .iter()
                .map(|s| (s.bits, s.params_hash))
                .collect::<Vec<_>>(),
            overlap
                .steps
                .iter()
                .map(|s| (s.bits, s.params_hash))
                .collect::<Vec<_>>()
        );
        // Modeled comm time is untouched; only the hidden ledger moves.
        assert_eq!(off.comm_time.to_bits(), overlap.comm_time.to_bits());
        assert_eq!(off.hidden_time, 0.0);
        assert!(overlap.hidden_time > 0.0, "overlap hid nothing");
        assert!(overlap.hidden_time <= overlap.comm_time + 1e-12);
        assert!(overlap.wall_time() < overlap.compute_time + overlap.comm_time);
    }

    #[test]
    fn stale_pipeline_is_deterministic_and_lags_one_step() {
        let run = || {
            let mut cfg = small_cfg(Method::Alq, 30);
            cfg.pipeline = PipelineMode::Stale;
            Cluster::new(cfg).train(&mut task(4, 27))
        };
        let a = run();
        let b = run();
        // Per-seed deterministic trajectory of its own.
        assert_eq!(a.params_hash, b.params_hash);
        assert_eq!(a.comm_bits, b.comm_bits);
        assert_eq!(
            a.steps.iter().map(|s| s.params_hash).collect::<Vec<_>>(),
            b.steps.iter().map(|s| s.params_hash).collect::<Vec<_>>()
        );
        let off = Cluster::new(small_cfg(Method::Alq, 30)).train(&mut task(4, 27));
        // Step 0 sees identical parameters (no update has landed yet in
        // either schedule), so its gradients and bits agree; from step 1
        // the lagged replica diverges.
        assert_eq!(a.steps[0].bits, off.steps[0].bits);
        assert_ne!(a.params_hash, off.params_hash);
        assert_ne!(a.steps[0].params_hash, off.steps[0].params_hash);
        // The overlapped compute hides some of the previous step's comm.
        assert!(a.hidden_time > 0.0, "stale:1 hid nothing");
        assert!(a.hidden_time <= a.comm_time + 1e-12);
    }

    #[test]
    fn quantize_impl_scalar_matches_fast_trajectory() {
        // End-to-end pin of the ISSUE 6 tentpole contract: the scalar
        // reference and the vectorizable fast path draw the same RNG
        // stream, so whole training runs are bit-identical.
        let run = |imp: QuantizeImpl| {
            let mut cfg = small_cfg(Method::Alq, 20);
            cfg.quantize_impl = imp;
            Cluster::new(cfg).train(&mut task(4, 25))
        };
        let scalar = run(QuantizeImpl::Scalar);
        let fast = run(QuantizeImpl::Fast);
        assert_eq!(scalar.params_hash, fast.params_hash);
        assert_eq!(scalar.comm_bits, fast.comm_bits);
        assert_eq!(scalar.final_levels, fast.final_levels);
    }

    #[test]
    fn lazy_threshold_skips_frames_and_stays_deterministic() {
        // An absurdly high threshold silences every worker: all frames
        // become skip markers, the sent mask empties, and the meter
        // charges exactly the marker bits.
        let mut cfg = small_cfg(Method::QsgdInf, 6);
        cfg.lazy = LazyPolicy::Thresh(1e30);
        let rec = Cluster::new(cfg).train(&mut task(4, 31));
        assert_eq!(rec.skipped_frames, 6 * 4);
        assert!(rec.steps.iter().all(|s| s.sent == 0));
        assert!(rec.steps.iter().all(|s| s.active == 0b1111));
        assert!(rec
            .steps
            .iter()
            .all(|s| s.bits == 4 * crate::exchange::SKIP_MARKER_BITS));

        // A tiny threshold skips nobody and the sent mask tracks the
        // active mask exactly.
        let mut cfg = small_cfg(Method::QsgdInf, 6);
        cfg.lazy = LazyPolicy::Thresh(1e-30);
        let rec = Cluster::new(cfg).train(&mut task(4, 31));
        assert_eq!(rec.skipped_frames, 0);
        assert!(rec.steps.iter().all(|s| s.sent == s.active));

        // LAQ skip plans are a pure function of the seed.
        let run = || {
            let mut cfg = small_cfg(Method::Alq, 30);
            cfg.lazy = LazyPolicy::parse("laq:0.5@8").unwrap();
            Cluster::new(cfg).train(&mut task(4, 33))
        };
        let a = run();
        let b = run();
        assert_eq!(a.params_hash, b.params_hash);
        assert_eq!(a.skipped_frames, b.skipped_frames);
        assert_eq!(
            a.steps.iter().map(|s| s.sent).collect::<Vec<_>>(),
            b.steps.iter().map(|s| s.sent).collect::<Vec<_>>()
        );
    }

    #[test]
    fn error_feedback_learns_at_two_bits_and_composes_with_lazy() {
        // Feedback at width 2 (the ternary floor) must still train.
        let mut cfg = small_cfg(Method::Alq, 400);
        cfg.bits = BitsPolicy::Fixed(2);
        cfg.error_feedback = true;
        cfg.updates = UpdateSchedule::at(vec![1, 25], 100, 25);
        let rec = Cluster::new(cfg).train(&mut task(4, 7));
        assert!(
            rec.final_eval.accuracy > 0.65,
            "feedback@2bit acc {}",
            rec.final_eval.accuracy
        );

        // Feedback + LAQ together: deterministic, and skipped messages
        // are absorbed (not lost) by the residual.
        let run = || {
            let mut cfg = small_cfg(Method::QsgdInf, 30);
            cfg.error_feedback = true;
            cfg.lazy = LazyPolicy::parse("laq:1.0@4").unwrap();
            Cluster::new(cfg).train(&mut task(4, 35))
        };
        let a = run();
        let b = run();
        assert_eq!(a.params_hash, b.params_hash);
        assert_eq!(a.skipped_frames, b.skipped_frames);
    }

    #[test]
    fn feedback_and_lazy_off_matches_the_plain_run_bit_for_bit() {
        // The off/off determinism contract at the cluster level: the
        // explicit defaults and an untouched config produce the same
        // trajectory, bits, and hop accounting.
        let base = Cluster::new(small_cfg(Method::Alq, 25)).train(&mut task(4, 37));
        let mut cfg = small_cfg(Method::Alq, 25);
        cfg.error_feedback = false;
        cfg.lazy = LazyPolicy::Off;
        let explicit = Cluster::new(cfg).train(&mut task(4, 37));
        assert_eq!(base.params_hash, explicit.params_hash);
        assert_eq!(base.comm_bits, explicit.comm_bits);
        assert_eq!(base.skipped_frames, 0);
        assert!(base.steps.iter().all(|s| s.sent == s.active));
    }

    #[test]
    #[should_panic(expected = "unsupported over --topology ring")]
    fn error_feedback_over_ring_is_rejected() {
        let mut cfg = small_cfg(Method::QsgdInf, 2);
        cfg.topology = TopologySpec::Ring;
        cfg.error_feedback = true;
        let _ = Cluster::new(cfg);
    }

    #[test]
    fn single_sgd_computes_one_gradient() {
        let mut cfg = small_cfg(Method::SingleSgd, 5);
        cfg.momentum = 0.0;
        let mut t = task(4, 15);
        let d = t.param_count();
        let rec = Cluster::new(cfg).train(&mut t);
        // One worker, no peers: bits metered per step = 32·d.
        assert_eq!(rec.comm_bits, 5 * 32 * d as u64);
        assert_eq!(rec.comm_time, 0.0, "single worker pays no comm time");
    }
}
