//! Data-parallel simulation: the M-worker cluster and the communication
//! cost model (DESIGN.md §Hardware-Adaptation).

pub mod cluster;
pub mod faults;
pub mod network;

pub use cluster::{Cluster, ClusterConfig, StepStats, TrainRecord, VarianceSample};
pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use network::{NetworkModel, Topology};
