//! Elastic membership: the active worker set every topology inherits.
//!
//! A [`Membership`] tracks which of the configured `world` workers are
//! currently participating, each worker's aggregation share, and the
//! join/leave epochs. All four topology backends read the active set
//! through [`super::BackendCore`], so churn (driven by a
//! `sim::FaultPlan` or by the TCP leader's timeout-and-drop path)
//! changes *who* is averaged without touching any schedule's code path.
//!
//! Invariants (DESIGN.md §Membership):
//!
//! * The active set only changes at step boundaries, never mid-step.
//! * Weights are shares normalized over the active set, so
//!   [`Membership::weight_sum`] is exactly 1.0 whenever anyone is
//!   active — survivors absorb a dropped worker's share instead of
//!   silently down-scaling the mean.
//! * A worker that leaves never rejoins (its join epoch is recorded
//!   once; `left_at` is terminal).

/// The active set, per-worker shares, and join/leave epochs for one run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    active: Vec<bool>,
    /// Step each worker became active (0 for founding members).
    joined_at: Vec<usize>,
    /// Step each worker left, once it has (terminal).
    left_at: Vec<Option<usize>>,
    /// Aggregation shares; uniform today, but the weighting rule is
    /// written against shares so heterogeneous contributions slot in.
    shares: Vec<u32>,
}

impl Membership {
    /// A full-strength membership: all `world` workers active from
    /// step 0 with uniform shares.
    pub fn new(world: usize) -> Self {
        Membership {
            active: vec![true; world],
            joined_at: vec![0; world],
            left_at: vec![None; world],
            shares: vec![1; world],
        }
    }

    /// The configured world size (active or not).
    pub fn world(&self) -> usize {
        self.active.len()
    }

    /// Whether `worker` currently participates in aggregation.
    pub fn is_active(&self, worker: usize) -> bool {
        self.active[worker]
    }

    /// Number of currently active workers.
    pub fn n_active(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Ascending ids of the currently active workers.
    pub fn active_ids(&self) -> Vec<usize> {
        (0..self.active.len()).filter(|&w| self.active[w]).collect()
    }

    /// The active set as a bitmask (bit `w` set ⇔ worker `w` active).
    /// Worlds are far below 64 workers throughout the repo.
    pub fn active_mask(&self) -> u64 {
        self.active_ids().iter().fold(0u64, |m, &w| m | (1u64 << w))
    }

    /// Mark `worker` as a standby replica before the run starts (it has
    /// a pending `join` fault). Records no leave epoch: the worker has
    /// simply not joined yet.
    pub fn deactivate_from_start(&mut self, worker: usize) {
        self.active[worker] = false;
    }

    /// Activate `worker` at `step` (a standby replica joining).
    pub fn activate(&mut self, worker: usize, step: usize) {
        self.active[worker] = true;
        self.joined_at[worker] = step;
    }

    /// Permanently remove `worker` at `step`.
    pub fn deactivate(&mut self, worker: usize, step: usize) {
        self.active[worker] = false;
        self.left_at[worker] = Some(step);
    }

    /// The step `worker` became (or will have become) active.
    pub fn joined_at(&self, worker: usize) -> usize {
        self.joined_at[worker]
    }

    /// The step `worker` left, if it has.
    pub fn left_at(&self, worker: usize) -> Option<usize> {
        self.left_at[worker]
    }

    /// `worker`'s normalized aggregation weight: its share over the
    /// active total (0 when inactive).
    pub fn weight(&self, worker: usize) -> f32 {
        if !self.active[worker] {
            return 0.0;
        }
        let total: u32 = self
            .active
            .iter()
            .zip(&self.shares)
            .filter_map(|(&a, &s)| a.then_some(s))
            .sum();
        self.shares[worker] as f32 / total as f32
    }

    /// Σ weights over the active set: exactly 1.0 whenever any worker
    /// is active (0.0 for an empty set). The weighted-partial-
    /// aggregation invariant the CI fault smoke asserts.
    pub fn weight_sum(&self) -> f32 {
        if self.n_active() == 0 {
            return 0.0;
        }
        let total: u32 = self
            .active
            .iter()
            .zip(&self.shares)
            .filter_map(|(&a, &s)| a.then_some(s))
            .sum();
        total as f32 / total as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_strength_defaults() {
        let m = Membership::new(4);
        assert_eq!(m.world(), 4);
        assert_eq!(m.n_active(), 4);
        assert_eq!(m.active_ids(), vec![0, 1, 2, 3]);
        assert_eq!(m.active_mask(), 0b1111);
        assert_eq!(m.weight_sum(), 1.0);
        assert_eq!(m.weight(2), 0.25);
    }

    #[test]
    fn drop_and_join_keep_weights_normalized() {
        let mut m = Membership::new(4);
        m.deactivate(1, 3);
        assert_eq!(m.n_active(), 3);
        assert_eq!(m.active_mask(), 0b1101);
        assert_eq!(m.left_at(1), Some(3));
        assert_eq!(m.weight(1), 0.0);
        assert_eq!(m.weight_sum(), 1.0, "survivors absorb the dropped share");
        assert!((m.weight(0) - 1.0 / 3.0).abs() < 1e-7);

        let mut m = Membership::new(4);
        m.deactivate_from_start(2);
        assert_eq!(m.n_active(), 3);
        assert_eq!(m.left_at(2), None, "standby, not departed");
        m.activate(2, 5);
        assert_eq!(m.joined_at(2), 5);
        assert_eq!(m.n_active(), 4);
        assert_eq!(m.weight_sum(), 1.0);
    }

    #[test]
    fn empty_active_set_has_zero_weight() {
        let mut m = Membership::new(1);
        m.deactivate(0, 0);
        assert_eq!(m.n_active(), 0);
        assert_eq!(m.weight_sum(), 0.0);
        assert_eq!(m.active_mask(), 0);
    }
}
