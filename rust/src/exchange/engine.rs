//! The M-lane in-process exchange engine: worker fan-out across OS
//! threads with a bit-for-bit deterministic reduction.

use super::session::{CodecSession, ExchangeLane};
use super::topology::Hop;
use super::ExchangeBackend;
use crate::quant::{Codec, Method, Quantizer};
use crate::sim::network::{Meter, NetworkModel};
use crate::util::Rng;

/// How the engine schedules worker lanes within one exchange.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ParallelMode {
    /// Fan out when it should pay off: ≥ 2 lanes and a gradient large
    /// enough that per-lane codec work dwarfs thread-spawn cost.
    #[default]
    Auto,
    /// One lane at a time (the seed behavior; also the parity oracle).
    Serial,
    /// Always fan out, regardless of size.
    Parallel,
}

impl ParallelMode {
    pub fn parse(s: &str) -> Option<ParallelMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(ParallelMode::Auto),
            "on" | "parallel" => Some(ParallelMode::Parallel),
            "off" | "serial" => Some(ParallelMode::Serial),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ParallelMode::Auto => "auto",
            ParallelMode::Serial => "serial",
            ParallelMode::Parallel => "parallel",
        }
    }
}

/// Coordinate count below which `Auto` stays serial: spawning a scoped
/// thread costs ~tens of µs, and quantize+code of fewer coordinates is
/// cheaper than that (DESIGN.md §Perf).
const AUTO_PARALLEL_MIN_COORDS: usize = 32_768;

/// Everything the engine needs to stand up a simulated exchange.
#[derive(Clone, Debug)]
pub struct ExchangeConfig {
    pub method: Method,
    pub workers: usize,
    pub bits: u32,
    pub bucket: usize,
    pub seed: u64,
    pub network: NetworkModel,
    pub parallel: ParallelMode,
    /// Entropy coder for the symbol stream (`--codec huffman|elias`).
    pub codec: Codec,
}

/// The unified in-process exchange: owns the codec session, one lane and
/// one RNG stream per worker, and the communication meter.
///
/// Determinism contract: per-worker RNG streams are forked exactly as
/// the seed serial loop forked them, each lane consumes only its own
/// stream, and the float aggregation runs on the main thread in fixed
/// worker order — so serial and parallel schedules produce bit-identical
/// runs (see `rust/tests/exchange_parity.rs`).
pub struct GradientExchange {
    cfg: ExchangeConfig,
    session: CodecSession,
    rngs: Vec<Rng>,
    lanes: Vec<ExchangeLane>,
    bits_scratch: Vec<u64>,
    meter: Meter,
    codec_seconds: f64,
    hops: Vec<Hop>,
}

impl GradientExchange {
    pub fn new(cfg: ExchangeConfig) -> Self {
        let mut seeder = Rng::new(cfg.seed);
        // One stream per *configured* worker even when fewer lanes are
        // active, so a seed maps to the same per-worker randomness
        // regardless of method (and identically to the seed loop).
        let rngs: Vec<Rng> = (0..cfg.workers).map(|w| seeder.fork(w as u64)).collect();
        let session = CodecSession::new(cfg.method, cfg.bits, cfg.bucket).with_codec(cfg.codec);
        let active = if cfg.method == Method::SingleSgd {
            1
        } else {
            cfg.workers
        };
        let lanes = (0..active).map(|_| ExchangeLane::new(cfg.bucket)).collect();
        GradientExchange {
            session,
            rngs,
            lanes,
            bits_scratch: vec![0; active],
            meter: Meter::default(),
            codec_seconds: 0.0,
            hops: Vec::new(),
            cfg,
        }
    }

    /// Lanes that actually compute and communicate (1 for SingleSGD).
    pub fn active_workers(&self) -> usize {
        self.lanes.len()
    }

    pub fn session(&self) -> &CodecSession {
        &self.session
    }

    pub fn is_quantized(&self) -> bool {
        self.session.is_quantized()
    }

    pub fn force_clip(&mut self, c: f32) {
        self.session.force_clip(c);
    }

    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Wall time spent inside quantize+encode+decode (the codec hot
    /// path; the parallel region is charged at its wall time).
    pub fn codec_seconds(&self) -> f64 {
        self.codec_seconds
    }

    pub fn final_levels(&self) -> Option<Vec<f64>> {
        self.session.final_levels()
    }

    /// Encoded bits per worker for the last exchange.
    pub fn bits_per_worker(&self) -> &[u64] {
        &self.bits_scratch
    }

    fn use_parallel(&self, d: usize) -> bool {
        match self.cfg.parallel {
            ParallelMode::Serial => false,
            ParallelMode::Parallel => self.lanes.len() > 1,
            ParallelMode::Auto => self.lanes.len() > 1 && d >= AUTO_PARALLEL_MIN_COORDS,
        }
    }

    /// The flat schedule is one hop: every worker's frame crosses the
    /// fabric once. Returns the hop's α-β seconds so the caller can feed
    /// the meter without recomputing the closed form.
    fn record_flat_hop(&mut self, step_bits: u64) -> f64 {
        let seconds = self.cfg.network.step_time(&self.bits_scratch);
        self.hops.clear();
        self.hops.push(Hop {
            label: "all-to-all".to_string(),
            bits: step_bits,
            seconds,
        });
        seconds
    }
}

/// One lane's codec work for a step. Free function so the parallel and
/// serial schedules run literally the same code.
fn run_lane(
    session: &CodecSession,
    lane: &mut ExchangeLane,
    rng: &mut Rng,
    grad: &[f32],
    skip_quantize: bool,
    sample_counts: bool,
) {
    if !skip_quantize {
        lane.quantize(session, grad, rng);
    }
    if sample_counts {
        lane.count_symbols(session);
    }
    lane.encode(session);
    lane.decode_own(session);
}

impl GradientExchange {
    /// One synchronous exchange: quantize → entropy-encode → meter →
    /// decode → aggregate the mean estimate into `agg`. Returns the
    /// step's total encoded bits.
    pub fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.lanes.len();
        // Hard assert: with fewer gradients the zip would silently skip
        // lanes while the reduction still added their stale estimates.
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);

        if !self.session.is_quantized() {
            // Full precision is charged at 32·d per worker.
            let mut step_bits = 0u64;
            for (w, grad) in grads.iter().take(m).enumerate() {
                self.bits_scratch[w] = 32 * grad.len() as u64;
                step_bits += self.bits_scratch[w];
                for (a, &g) in agg.iter_mut().zip(grad) {
                    *a += g / m as f32;
                }
            }
            let seconds = self.record_flat_hop(step_bits);
            self.meter.record_raw(step_bits, seconds);
            return step_bits;
        }

        let t0 = std::time::Instant::now();
        // Lazy codebook: built from the first gradient's empirical symbol
        // distribution before any lane encodes (skipped entirely by
        // codebook-free coders).
        let mut lane0_quantized = false;
        if self.session.needs_book() && self.session.book().is_none() {
            self.lanes[0].quantize(&self.session, &grads[0], &mut self.rngs[0]);
            self.session.build_empirical_book(self.lanes[0].quantized());
            lane0_quantized = true;
        }
        let sample_counts = self.session.needs_book() && step % 10 == 0;

        if self.use_parallel(grads[0].len()) {
            let session = &self.session;
            std::thread::scope(|scope| {
                for (w, ((lane, rng), grad)) in self
                    .lanes
                    .iter_mut()
                    .zip(self.rngs.iter_mut())
                    .zip(grads)
                    .enumerate()
                {
                    let skip = w == 0 && lane0_quantized;
                    scope.spawn(move || {
                        run_lane(session, lane, rng, grad, skip, sample_counts)
                    });
                }
            });
        } else {
            for (w, ((lane, rng), grad)) in self
                .lanes
                .iter_mut()
                .zip(self.rngs.iter_mut())
                .zip(grads)
                .enumerate()
            {
                let skip = w == 0 && lane0_quantized;
                run_lane(&self.session, lane, rng, grad, skip, sample_counts);
            }
        }

        // Deterministic reduction: fixed worker order on the main
        // thread, so the f32 accumulation matches the serial loop
        // bit-for-bit no matter how the lanes were scheduled.
        let inv = 1.0 / m as f32;
        let mut step_bits = 0u64;
        for (w, lane) in self.lanes.iter().enumerate() {
            self.bits_scratch[w] = lane.bits();
            step_bits += self.bits_scratch[w];
            if sample_counts {
                self.session.accumulate_counts(lane.counts());
            }
            for (a, &g) in agg.iter_mut().zip(lane.ghat()) {
                *a += g * inv;
            }
        }
        self.codec_seconds += t0.elapsed().as_secs_f64();
        let seconds = self.record_flat_hop(step_bits);
        self.meter.record_raw(step_bits, seconds);
        step_bits
    }

    /// Algorithm 1 line 4 at the update schedule: re-fit the
    /// distribution, re-optimize levels, refresh the codebook (adaptive
    /// methods) or rebuild it from the sampled empirical counts
    /// (non-adaptive). No-op for full precision.
    pub fn adapt(&mut self, grads: &[Vec<f32>]) {
        if !self.session.is_quantized() {
            return;
        }
        // Same stream the seed loop drew its subsample seed from.
        let mut rng = self.rngs[0].fork(0xE57);
        if !self.session.adapt(grads.iter().map(|g| g.as_slice()), &mut rng) {
            self.session.refresh_book_from_counts();
        }
    }

    pub fn quantizer(&self) -> Option<&Quantizer> {
        self.session.quantizer()
    }
}

impl ExchangeBackend for GradientExchange {
    fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        GradientExchange::exchange(self, step, grads, agg)
    }

    fn adapt(&mut self, grads: &[Vec<f32>]) {
        GradientExchange::adapt(self, grads)
    }

    fn quantizer(&self) -> Option<&Quantizer> {
        GradientExchange::quantizer(self)
    }

    fn active_workers(&self) -> usize {
        GradientExchange::active_workers(self)
    }

    fn is_quantized(&self) -> bool {
        GradientExchange::is_quantized(self)
    }

    fn force_clip(&mut self, c: f32) {
        GradientExchange::force_clip(self, c)
    }

    fn meter(&self) -> &Meter {
        GradientExchange::meter(self)
    }

    fn codec_seconds(&self) -> f64 {
        GradientExchange::codec_seconds(self)
    }

    fn final_levels(&self) -> Option<Vec<f64>> {
        GradientExchange::final_levels(self)
    }

    fn last_hops(&self) -> &[Hop] {
        &self.hops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NetworkModel;

    fn config(method: Method, workers: usize, parallel: ParallelMode) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: 3,
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel,
            codec: Codec::Huffman,
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn serial_and_parallel_schedules_are_bit_identical() {
        let d = 1000;
        let g = grads(4, d, 1);
        let mut serial = GradientExchange::new(config(Method::Alq, 4, ParallelMode::Serial));
        let mut parallel = GradientExchange::new(config(Method::Alq, 4, ParallelMode::Parallel));
        let mut agg_s = vec![0.0f32; d];
        let mut agg_p = vec![0.0f32; d];
        for step in 0..12 {
            if step == 5 {
                serial.adapt(&g);
                parallel.adapt(&g);
            }
            let bs = serial.exchange(step, &g, &mut agg_s);
            let bp = parallel.exchange(step, &g, &mut agg_p);
            assert_eq!(bs, bp, "step {step} bits");
            assert_eq!(serial.bits_per_worker(), parallel.bits_per_worker());
            let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = agg_p.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "step {step} aggregate");
        }
        assert_eq!(serial.final_levels(), parallel.final_levels());
        assert_eq!(serial.meter().total_bits, parallel.meter().total_bits);
    }

    #[test]
    fn full_precision_charges_32d_per_worker() {
        let d = 333;
        let g = grads(3, d, 2);
        let mut eng = GradientExchange::new(config(Method::SuperSgd, 3, ParallelMode::Auto));
        let mut agg = vec![0.0f32; d];
        let bits = eng.exchange(0, &g, &mut agg);
        assert_eq!(bits, 3 * 32 * d as u64);
        // Aggregate is the plain mean.
        for i in 0..d {
            let want = (g[0][i] / 3.0) + (g[1][i] / 3.0) + (g[2][i] / 3.0);
            assert_eq!(agg[i].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn single_sgd_runs_one_lane() {
        let d = 256;
        let g = grads(4, d, 3);
        let mut eng = GradientExchange::new(config(Method::SingleSgd, 4, ParallelMode::Auto));
        assert_eq!(eng.active_workers(), 1);
        let mut agg = vec![0.0f32; d];
        let bits = eng.exchange(0, &g, &mut agg);
        assert_eq!(bits, 32 * d as u64);
        // One worker pays no communication time.
        assert_eq!(eng.meter().total_time, 0.0);
    }

    #[test]
    fn quantized_exchange_meters_fewer_bits_than_fp32() {
        let d = 4096;
        let g = grads(4, d, 4);
        let mut eng = GradientExchange::new(config(Method::NuqSgd, 4, ParallelMode::Auto));
        let mut agg = vec![0.0f32; d];
        let mut total = 0u64;
        for step in 0..5 {
            total += eng.exchange(step, &g, &mut agg);
        }
        assert!(total > 0);
        assert!(total < 5 * 4 * 32 * d as u64 / 4, "{total}");
        assert!(eng.codec_seconds() > 0.0);
    }

    #[test]
    fn parallel_mode_parses() {
        assert_eq!(ParallelMode::parse("auto"), Some(ParallelMode::Auto));
        assert_eq!(ParallelMode::parse("ON"), Some(ParallelMode::Parallel));
        assert_eq!(ParallelMode::parse("off"), Some(ParallelMode::Serial));
        assert_eq!(ParallelMode::parse("serial"), Some(ParallelMode::Serial));
        assert_eq!(ParallelMode::parse("nope"), None);
        assert_eq!(ParallelMode::default().name(), "auto");
    }
}
