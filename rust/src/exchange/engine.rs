//! The flat all-to-all exchange engine: M worker lanes over a shared
//! [`BackendCore`], fanned out across OS threads with a bit-for-bit
//! deterministic reduction.
//!
//! # Schedule
//!
//! One hop: every worker quantizes, entropy-encodes, and
//! loopback-decodes its own gradient (the shared member stage,
//! [`BackendCore::member_stage`]); the decoded estimates are then
//! reduced per coordinate in worker order 0..M on the calling thread.
//! This is the paper's Algorithm 1 all-to-all and the reference schedule
//! every other topology is measured against.
//!
//! # Hop structure
//!
//! A single `"all-to-all"` [`Hop`](super::topology::Hop) carrying every
//! worker frame once; its α-β seconds come from the analytical
//! [`NetworkModel::step_time`] closed form.
//!
//! # Determinism
//!
//! Per-worker RNG streams are forked exactly as the seed serial loop
//! forked them (by the embedded [`BackendCore`]), each lane consumes
//! only its own stream, and the float aggregation runs on the calling
//! thread in fixed worker order — so serial and parallel schedules
//! produce bit-identical runs (`rust/tests/exchange_parity.rs`, and the
//! cross-backend contract in DESIGN.md §8).

use super::budget::BitsPolicy;
use super::session::CodecSession;
use super::topology::core::BackendCore;
use super::topology::Hop;
use super::ExchangeBackend;
use crate::quant::{Codec, Method, QuantizeImpl, Quantizer};
use crate::sim::network::{Meter, NetworkModel};

/// How a backend schedules its independent lane tasks within one
/// exchange (`--parallel auto|on|off`). Applies to the flat engine's M
/// worker lanes, the sharded backend's S shard-leader lanes, and the
/// tree backend's member + per-group leader stages; the ring schedule is
/// inherently serial (see `topology::ring`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ParallelMode {
    /// Fan out when it should pay off: ≥ 2 lanes and a gradient large
    /// enough that per-lane codec work dwarfs thread-spawn cost.
    #[default]
    Auto,
    /// One lane at a time (the seed behavior; also the parity oracle).
    Serial,
    /// Always fan out, regardless of size.
    Parallel,
}

impl ParallelMode {
    /// Parse a CLI value (`auto|on|parallel|off|serial`).
    pub fn parse(s: &str) -> Option<ParallelMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(ParallelMode::Auto),
            "on" | "parallel" => Some(ParallelMode::Parallel),
            "off" | "serial" => Some(ParallelMode::Serial),
            _ => None,
        }
    }

    /// Canonical lowercase name for logs and banners.
    pub fn name(self) -> &'static str {
        match self {
            ParallelMode::Auto => "auto",
            ParallelMode::Serial => "serial",
            ParallelMode::Parallel => "parallel",
        }
    }
}

/// How a step's communication is scheduled against the work around it
/// (`--pipeline off|overlap|stale:1`).
///
/// All three modes move exactly the same bits: `Overlap` changes only
/// *when* already-encoded frames sit on the wire relative to the
/// remaining encode work, and `Stale` changes only *when* the aggregate
/// is applied. The determinism contract (DESIGN.md §8) therefore holds
/// per mode: `Overlap` is bit-identical to `Off`, and `Stale` is a
/// per-seed deterministic trajectory of its own.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PipelineMode {
    /// Strictly serial phases: compute → quantize → encode → wire →
    /// decode → apply (the seed behavior; also the parity oracle).
    #[default]
    Off,
    /// Overlap encode with wire I/O inside a step: frame k is on the
    /// wire while bucket-range k+1 encodes. In the simulation the
    /// modeled wire seconds hidden behind encode wall time are credited
    /// to [`crate::sim::network::Meter::hide`]; on the TCP path the
    /// worker really does hand frame k to a sender thread while
    /// encoding shard k+1 (`coordinator::worker`). Byte-identical
    /// frames in identical order either way.
    Overlap,
    /// Classic pipelined-SGD staleness, depth 1: `sim::Cluster::train`
    /// computes step t+1's gradients while step t's exchange completes
    /// and applies the aggregate one step late. Simulation-only.
    Stale,
}

impl PipelineMode {
    /// Parse a CLI value (`off|overlap|stale:1`). Only staleness depth 1
    /// is supported; any other depth is rejected.
    pub fn parse(s: &str) -> Option<PipelineMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(PipelineMode::Off),
            "overlap" => Some(PipelineMode::Overlap),
            "stale:1" => Some(PipelineMode::Stale),
            _ => None,
        }
    }

    /// Canonical lowercase name for logs and banners.
    pub fn name(self) -> &'static str {
        match self {
            PipelineMode::Off => "off",
            PipelineMode::Overlap => "overlap",
            PipelineMode::Stale => "stale:1",
        }
    }
}

/// Everything a backend needs to stand up a simulated exchange.
#[derive(Clone, Debug)]
pub struct ExchangeConfig {
    /// The quantization method (`Method::SuperSgd` = full precision).
    pub method: Method,
    /// Configured worker count M (RNG streams are forked for all of
    /// them even when SingleSGD collapses to one active lane).
    pub workers: usize,
    /// The bit-budget policy (`--bits-policy fixed:B|schedule:…|variance`).
    /// `BitsPolicy::Fixed(B)` reproduces the historical constant-width
    /// behavior bit for bit; the other policies move the quantization
    /// width per step through the backend's embedded bit controller
    /// (`exchange::budget`).
    pub bits: BitsPolicy,
    /// Bucket size (coordinates per normalization bucket).
    pub bucket: usize,
    /// Run seed; every stochastic draw forks from it.
    pub seed: u64,
    /// The α-β communication model hop seconds are charged against.
    pub network: NetworkModel,
    /// Lane scheduling within one exchange (`--parallel auto|on|off`).
    pub parallel: ParallelMode,
    /// Entropy coder for the symbol stream (`--codec huffman|elias`).
    pub codec: Codec,
    /// Lane quantization implementation
    /// (`--quantize-impl scalar|fast|pallas`).
    pub quantize_impl: QuantizeImpl,
}

/// The flat in-process exchange backend (`--topology flat`): one
/// reusable codec lane per active worker around the shared
/// [`BackendCore`].
pub struct GradientExchange {
    core: BackendCore,
    lanes: Vec<super::session::ExchangeLane>,
    bits_scratch: Vec<u64>,
}

impl GradientExchange {
    /// Stand up the engine: the shared core plus one codec lane and one
    /// bit counter per active worker.
    pub fn new(cfg: ExchangeConfig) -> Self {
        let core = BackendCore::new(cfg);
        let lanes = core.new_lanes();
        let bits_scratch = vec![0; lanes.len()];
        GradientExchange {
            core,
            lanes,
            bits_scratch,
        }
    }

    /// Lanes that actually compute and communicate (1 for SingleSGD).
    pub fn active_workers(&self) -> usize {
        self.lanes.len()
    }

    /// The engine's codec session (shared with the TCP coordinator
    /// path).
    pub fn session(&self) -> &CodecSession {
        self.core.session()
    }

    /// Whether this exchange quantizes at all.
    pub fn is_quantized(&self) -> bool {
        self.core.is_quantized()
    }

    /// Force TernGrad-style c·σ clipping regardless of method (the
    /// Appendix K.2 / Fig. 14 ablation).
    pub fn force_clip(&mut self, c: f32) {
        self.core.force_clip(c);
    }

    /// The running communication meter (total bits + modeled seconds).
    pub fn meter(&self) -> &Meter {
        self.core.meter()
    }

    /// Wall time spent inside quantize+encode+decode (the codec hot
    /// path; the parallel region is charged at its wall time).
    pub fn codec_seconds(&self) -> f64 {
        self.core.codec_seconds()
    }

    /// The final (possibly adapted) quantization level magnitudes.
    pub fn final_levels(&self) -> Option<Vec<f64>> {
        self.core.final_levels()
    }

    /// Encoded bits per worker for the last exchange.
    pub fn bits_per_worker(&self) -> &[u64] {
        &self.bits_scratch
    }

    /// The live quantizer, if this exchange quantizes at all.
    pub fn quantizer(&self) -> Option<&Quantizer> {
        self.core.quantizer()
    }

    /// Re-fit the coordinate distribution and re-optimize levels and
    /// codebook (Algorithm 1 line 4; see [`BackendCore::adapt`]).
    pub fn adapt(&mut self, grads: &[Vec<f32>]) {
        self.core.adapt(grads);
    }

    /// One synchronous exchange: select the step's width via the bit
    /// controller, then quantize → entropy-encode → meter → decode →
    /// aggregate the mean estimate into `agg`. Returns the step's total
    /// encoded bits.
    pub fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        ExchangeBackend::exchange(self, step, grads, agg)
    }

    /// The flat schedule body (width already selected by
    /// [`BackendCore::begin_step`] through the trait's `exchange`
    /// wrapper).
    fn run_schedule_impl(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.lanes.len();
        // Hard assert: with fewer gradients the zip would silently skip
        // lanes while the reduction still added their stale estimates.
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);
        let net = self.core.cfg().network;
        // The step's frame plan: at full strength with feedback and lazy
        // off this is the active set (and at no churn, 0..M), and the
        // schedule below is byte-identical to the fixed-membership one;
        // under churn or skip rounds only sending lanes contribute
        // frames and weight. Skip markers are charged once for every
        // topology by `finish_step`.
        let ids = self.core.sent_ids();
        let n = ids.len();
        if n == 0 {
            return self.core.finish_step(Vec::new(), 0, 0.0);
        }
        self.bits_scratch.iter_mut().for_each(|b| *b = 0);

        if !self.core.is_quantized() {
            // Full precision is charged at 32·d per worker; the outgoing
            // message is the feedback-corrected gradient when residual
            // memory is on (and the residual then settles to zero —
            // lossless frames carry it exactly).
            let mut step_bits = 0u64;
            for &w in &ids {
                let grad = self.core.outgoing(w, grads);
                self.bits_scratch[w] = 32 * grad.len() as u64;
                step_bits += self.bits_scratch[w];
                for (a, &g) in agg.iter_mut().zip(grad) {
                    *a += g / n as f32;
                }
            }
            let active_bits: Vec<u64> = ids.iter().map(|&w| self.bits_scratch[w]).collect();
            let seconds = net.step_time(&active_bits);
            return self.core.finish_step(
                vec![Hop {
                    label: "all-to-all".to_string(),
                    bits: step_bits,
                    seconds,
                }],
                step_bits,
                seconds,
            );
        }

        let t0 = std::time::Instant::now();
        // Quantize + sampled counts + encode + loopback-decode, fanned
        // out by the shared member stage.
        self.core.member_stage(&mut self.lanes, grads, step, true);

        // Deterministic reduction: fixed worker order on the calling
        // thread, so the f32 accumulation matches the serial loop
        // bit-for-bit no matter how the lanes were scheduled.
        let t_agg = std::time::Instant::now();
        let inv = 1.0 / n as f32;
        let mut step_bits = 0u64;
        for &w in &ids {
            let lane = &self.lanes[w];
            self.bits_scratch[w] = lane.bits();
            step_bits += self.bits_scratch[w];
            for (a, &g) in agg.iter_mut().zip(lane.ghat()) {
                *a += g * inv;
            }
        }
        self.core
            .trace_phase("aggregate", t_agg.elapsed().as_secs_f64());
        self.core.add_codec_seconds(t0.elapsed().as_secs_f64());
        // The flat schedule is one hop: every active worker's frame
        // crosses the fabric once, at the analytical closed-form step
        // time.
        let active_bits: Vec<u64> = ids.iter().map(|&w| self.bits_scratch[w]).collect();
        let seconds = net.step_time(&active_bits);
        self.core.finish_step(
            vec![Hop {
                label: "all-to-all".to_string(),
                bits: step_bits,
                seconds,
            }],
            step_bits,
            seconds,
        )
    }
}

impl ExchangeBackend for GradientExchange {
    fn core(&self) -> &BackendCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BackendCore {
        &mut self.core
    }

    fn run_schedule(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.run_schedule_impl(step, grads, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NetworkModel;
    use crate::util::Rng;

    fn config(method: Method, workers: usize, parallel: ParallelMode) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: BitsPolicy::Fixed(3),
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel,
            codec: Codec::Huffman,
            quantize_impl: QuantizeImpl::default(),
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn serial_and_parallel_schedules_are_bit_identical() {
        let d = 1000;
        let g = grads(4, d, 1);
        let mut serial = GradientExchange::new(config(Method::Alq, 4, ParallelMode::Serial));
        let mut parallel = GradientExchange::new(config(Method::Alq, 4, ParallelMode::Parallel));
        let mut agg_s = vec![0.0f32; d];
        let mut agg_p = vec![0.0f32; d];
        for step in 0..12 {
            if step == 5 {
                serial.adapt(&g);
                parallel.adapt(&g);
            }
            let bs = serial.exchange(step, &g, &mut agg_s);
            let bp = parallel.exchange(step, &g, &mut agg_p);
            assert_eq!(bs, bp, "step {step} bits");
            assert_eq!(serial.bits_per_worker(), parallel.bits_per_worker());
            let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = agg_p.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "step {step} aggregate");
        }
        assert_eq!(serial.final_levels(), parallel.final_levels());
        assert_eq!(serial.meter().total_bits, parallel.meter().total_bits);
    }

    #[test]
    fn full_precision_charges_32d_per_worker() {
        let d = 333;
        let g = grads(3, d, 2);
        let mut eng = GradientExchange::new(config(Method::SuperSgd, 3, ParallelMode::Auto));
        let mut agg = vec![0.0f32; d];
        let bits = eng.exchange(0, &g, &mut agg);
        assert_eq!(bits, 3 * 32 * d as u64);
        // Aggregate is the plain mean.
        for i in 0..d {
            let want = (g[0][i] / 3.0) + (g[1][i] / 3.0) + (g[2][i] / 3.0);
            assert_eq!(agg[i].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn single_sgd_runs_one_lane() {
        let d = 256;
        let g = grads(4, d, 3);
        let mut eng = GradientExchange::new(config(Method::SingleSgd, 4, ParallelMode::Auto));
        assert_eq!(eng.active_workers(), 1);
        let mut agg = vec![0.0f32; d];
        let bits = eng.exchange(0, &g, &mut agg);
        assert_eq!(bits, 32 * d as u64);
        // One worker pays no communication time.
        assert_eq!(eng.meter().total_time, 0.0);
    }

    #[test]
    fn quantized_exchange_meters_fewer_bits_than_fp32() {
        let d = 4096;
        let g = grads(4, d, 4);
        let mut eng = GradientExchange::new(config(Method::NuqSgd, 4, ParallelMode::Auto));
        let mut agg = vec![0.0f32; d];
        let mut total = 0u64;
        for step in 0..5 {
            total += eng.exchange(step, &g, &mut agg);
        }
        assert!(total > 0);
        assert!(total < 5 * 4 * 32 * d as u64 / 4, "{total}");
        assert!(eng.codec_seconds() > 0.0);
    }

    #[test]
    fn flat_reports_a_single_all_to_all_hop() {
        let d = 512;
        let g = grads(4, d, 5);
        let mut eng = GradientExchange::new(config(Method::QsgdInf, 4, ParallelMode::Auto));
        let mut agg = vec![0.0f32; d];
        let bits = eng.exchange(0, &g, &mut agg);
        let hops = ExchangeBackend::last_hops(&eng);
        assert_eq!(hops.len(), 1);
        assert_eq!(hops[0].label, "all-to-all");
        assert_eq!(hops[0].bits, bits);
    }

    #[test]
    fn schedule_policy_switches_the_engine_width_mid_run() {
        let d = 2048;
        let g = grads(4, d, 6);
        let mut cfg = config(Method::QsgdInf, 4, ParallelMode::Serial);
        cfg.bits = BitsPolicy::parse("schedule:2@0,4@3").unwrap();
        let mut eng = GradientExchange::new(cfg);
        let mut agg = vec![0.0f32; d];
        let mut bits_at = Vec::new();
        for step in 0..6 {
            bits_at.push(eng.exchange(step, &g, &mut agg));
            let want = if step < 3 { 2 } else { 4 };
            assert_eq!(ExchangeBackend::step_width(&eng), want, "step {step}");
        }
        // Wider symbols cost more payload bits on the same gradients.
        assert!(
            bits_at[5] > bits_at[2],
            "4-bit frames should outweigh 2-bit frames: {bits_at:?}"
        );
        // The meter charged the actual per-step bits.
        assert_eq!(
            ExchangeBackend::meter(&eng).total_bits,
            bits_at.iter().sum::<u64>()
        );
    }

    #[test]
    fn parallel_mode_parses() {
        assert_eq!(ParallelMode::parse("auto"), Some(ParallelMode::Auto));
        assert_eq!(ParallelMode::parse("ON"), Some(ParallelMode::Parallel));
        assert_eq!(ParallelMode::parse("off"), Some(ParallelMode::Serial));
        assert_eq!(ParallelMode::parse("serial"), Some(ParallelMode::Serial));
        assert_eq!(ParallelMode::parse("nope"), None);
        assert_eq!(ParallelMode::default().name(), "auto");
    }

    #[test]
    fn pipeline_mode_parses() {
        assert_eq!(PipelineMode::parse("off"), Some(PipelineMode::Off));
        assert_eq!(PipelineMode::parse("OVERLAP"), Some(PipelineMode::Overlap));
        assert_eq!(PipelineMode::parse("stale:1"), Some(PipelineMode::Stale));
        // Only depth-1 staleness exists; other depths are rejected, not
        // silently clamped.
        assert_eq!(PipelineMode::parse("stale:2"), None);
        assert_eq!(PipelineMode::parse("stale"), None);
        assert_eq!(PipelineMode::parse("async"), None);
        assert_eq!(PipelineMode::default(), PipelineMode::Off);
        assert_eq!(PipelineMode::Stale.name(), "stale:1");
    }
}
