//! Dynamic bit budgets: the per-step width controller and the per-width
//! quantizer/codebook bank every exchange backend inherits.
//!
//! The paper's thesis is that gradient statistics drift during training
//! and the quantizer should adapt. Until this module existed only the
//! *levels* adapted (ALQ/AMQ); the bit-width itself was a constant
//! threaded through [`super::CodecSession`] and every backend. DQ-SGD
//! (PAPERS.md) shows the right bit budget also changes over training,
//! and QSGD's variance bound gives the signal to drive it. This module
//! supplies:
//!
//! * [`BitsPolicy`] — the CLI-selectable policy
//!   (`--bits-policy fixed:B | schedule:B1@s1,B2@s2,... | variance[:MIN-MAX[@T]]`);
//! * [`BitController`] — the per-step width decision, driven by the
//!   normalized quantization-variance estimate the quantizer already
//!   evaluates in closed form (Eq. 1–2) and, for adaptive methods, the
//!   per-width Ψ(ℓ) predictions of the fitted mixture;
//! * [`QuantizerBank`] — one pre-built quantizer + codebook +
//!   symbol-count slot per reachable width, so switching widths mid-run
//!   is an O(1) index move with no allocation and no history
//!   contamination across widths.
//!
//! # Determinism contract (DESIGN.md §8, bit-budget row)
//!
//! `fixed:B` must be bit-identical to the pre-refactor constant-width
//! path: a fixed policy builds a one-slot bank, the controller returns a
//! constant, and no extra RNG is consumed anywhere — asserted against
//! the seed-loop oracle in `rust/tests/exchange_parity.rs` and across
//! topologies in `rust/tests/topology_parity.rs`. Dynamic policies are
//! deterministic per seed: the variance signal is a closed-form
//! evaluation (no sampling), and all width decisions happen on the
//! calling thread before any lane fans out.

use crate::adaptive::objective::{psi, symbol_probs};
use crate::adaptive::update_levels;
use crate::quant::{
    smooth_weights, symbol_counts, Codec, HuffmanBook, Method, QuantizedGrad, Quantizer,
};
use crate::stats::Mixture;
use crate::trace::{Level, Tracer};
use crate::util::json::Json;

/// Bounds of the paper's `bits` hyperparameter (`Levels::mags_for_bits`).
const MIN_WIDTH: u32 = 2;
/// Upper bound of the representable widths.
const MAX_WIDTH: u32 = 8;

/// EMA smoothing factor for the variance controller's observed signal.
const EMA_ALPHA: f64 = 0.2;
/// Hysteresis: only shrink the width when the predicted variance clears
/// the target by this margin, so the controller cannot oscillate on a
/// signal that sits near the threshold.
const DOWN_MARGIN: f64 = 0.7;

/// How a run chooses its quantization bit-width per step
/// (`--bits-policy`). `fixed:B` reproduces the historical constant-width
/// behavior bit for bit; the other policies move the width over training
/// and meter the actual per-step bits.
#[derive(Clone, Debug, PartialEq)]
pub enum BitsPolicy {
    /// Constant width B every step (the pre-refactor behavior).
    Fixed(u32),
    /// Piecewise-constant widths: `(start_step, bits)` segments in
    /// ascending step order, first segment at step 0.
    Schedule(Vec<(usize, u32)>),
    /// Adaptive width driven by the per-step quantization-variance
    /// estimate (see [`VarianceSpec`]).
    Variance(VarianceSpec),
}

/// Parameters of the adaptive `variance` policy: keep the normalized
/// quantization variance `E‖Q(v)−v‖² / ‖v‖²` near `target` using the
/// narrowest width in `[min_bits, max_bits]` predicted to satisfy it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VarianceSpec {
    /// Narrowest width the controller may select.
    pub min_bits: u32,
    /// Widest width the controller may select (also the starting width).
    pub max_bits: u32,
    /// Target normalized quantization variance.
    pub target: f64,
}

impl Default for VarianceSpec {
    fn default() -> Self {
        VarianceSpec {
            min_bits: 2,
            max_bits: 4,
            target: 0.25,
        }
    }
}

/// Reject a width outside the representable `[MIN_WIDTH, MAX_WIDTH]`
/// range with a message naming both the width and the bounds.
fn check_width(bits: u32) -> Result<(), String> {
    if (MIN_WIDTH..=MAX_WIDTH).contains(&bits) {
        Ok(())
    } else {
        Err(format!(
            "width {bits} is out of range [{MIN_WIDTH}, {MAX_WIDTH}]"
        ))
    }
}

impl BitsPolicy {
    /// Parse a CLI value:
    /// `fixed:B`, `schedule:B1@s1,B2@s2,...` (s1 must be 0, steps
    /// strictly increasing), `variance`, `variance:MIN-MAX`, or
    /// `variance:MIN-MAX@TARGET`. Widths must lie in [2, 8].
    /// `None` on any malformation; [`BitsPolicy::parse_strict`] reports
    /// *why* a spec was rejected.
    pub fn parse(s: &str) -> Option<BitsPolicy> {
        Self::parse_strict(s).ok()
    }

    /// [`BitsPolicy::parse`] with diagnostics: the same grammar, but a
    /// rejection explains itself (empty spec, out-of-range width,
    /// duplicate or out-of-order schedule steps, malformed segment).
    pub fn parse_strict(s: &str) -> Result<BitsPolicy, String> {
        let s = s.trim().to_ascii_lowercase();
        if s.is_empty() {
            return Err(
                "empty bits policy (expected fixed:B | schedule:B1@s1,... | variance[:MIN-MAX[@T]])"
                    .to_string(),
            );
        }
        if let Some(rest) = s.strip_prefix("fixed:") {
            let bits: u32 = rest
                .parse()
                .map_err(|_| format!("invalid width {rest:?} in fixed policy"))?;
            check_width(bits)?;
            return Ok(BitsPolicy::Fixed(bits));
        }
        if let Some(rest) = s.strip_prefix("schedule:") {
            if rest.is_empty() {
                return Err("empty schedule (expected B1@s1,B2@s2,...)".to_string());
            }
            let mut segments: Vec<(usize, u32)> = Vec::new();
            for seg in rest.split(',') {
                let (bits, step) = seg
                    .split_once('@')
                    .ok_or_else(|| format!("schedule segment {seg:?} is missing '@step'"))?;
                let bits: u32 = bits
                    .parse()
                    .map_err(|_| format!("invalid width {bits:?} in schedule segment {seg:?}"))?;
                let step: usize = step
                    .parse()
                    .map_err(|_| format!("invalid step {step:?} in schedule segment {seg:?}"))?;
                check_width(bits)?;
                if let Some(&(prev, _)) = segments.last() {
                    if step == prev {
                        return Err(format!("duplicate step {step} in schedule"));
                    }
                    if step < prev {
                        return Err(format!(
                            "schedule steps must be strictly increasing (step {step} after {prev})"
                        ));
                    }
                }
                segments.push((step, bits));
            }
            if segments.first().map(|&(s0, _)| s0) != Some(0) {
                return Err("schedule must start with a segment at step 0".to_string());
            }
            return Ok(BitsPolicy::Schedule(segments));
        }
        if s == "variance" {
            return Ok(BitsPolicy::Variance(VarianceSpec::default()));
        }
        if let Some(rest) = s.strip_prefix("variance:") {
            let (range, target) = match rest.split_once('@') {
                Some((r, t)) => (r, Some(t)),
                None => (rest, None),
            };
            let (lo, hi) = range
                .split_once('-')
                .ok_or_else(|| format!("variance range {range:?} is missing '-' (expected MIN-MAX)"))?;
            let min_bits: u32 = lo
                .parse()
                .map_err(|_| format!("invalid width {lo:?} in variance range"))?;
            let max_bits: u32 = hi
                .parse()
                .map_err(|_| format!("invalid width {hi:?} in variance range"))?;
            check_width(min_bits)?;
            check_width(max_bits)?;
            if min_bits > max_bits {
                return Err(format!("inverted variance range {min_bits}-{max_bits}"));
            }
            let target = match target {
                Some(t) => {
                    let t: f64 = t
                        .parse()
                        .map_err(|_| format!("invalid variance target {t:?}"))?;
                    if !t.is_finite() || t <= 0.0 {
                        return Err(format!(
                            "variance target must be positive and finite, got {t}"
                        ));
                    }
                    t
                }
                None => VarianceSpec::default().target,
            };
            return Ok(BitsPolicy::Variance(VarianceSpec {
                min_bits,
                max_bits,
                target,
            }));
        }
        Err(format!(
            "unknown bits policy {s:?} (expected fixed:B | schedule:B1@s1,... | variance[:MIN-MAX[@T]])"
        ))
    }

    /// Canonical lowercase name for logs and banners (re-parses to an
    /// equal policy).
    pub fn name(&self) -> String {
        match self {
            BitsPolicy::Fixed(b) => format!("fixed:{b}"),
            BitsPolicy::Schedule(segs) => {
                let parts: Vec<String> =
                    segs.iter().map(|&(s, b)| format!("{b}@{s}")).collect();
                format!("schedule:{}", parts.join(","))
            }
            BitsPolicy::Variance(v) => {
                format!("variance:{}-{}@{}", v.min_bits, v.max_bits, v.target)
            }
        }
    }

    /// The width the run starts at (step 0, before any observation).
    pub fn initial_bits(&self) -> u32 {
        match self {
            BitsPolicy::Fixed(b) => *b,
            BitsPolicy::Schedule(segs) => segs[0].1,
            BitsPolicy::Variance(v) => v.max_bits,
        }
    }

    /// Every width this policy can reach, ascending and deduplicated —
    /// the widths the [`QuantizerBank`] pre-builds.
    pub fn widths(&self) -> Vec<u32> {
        let mut w: Vec<u32> = match self {
            BitsPolicy::Fixed(b) => vec![*b],
            BitsPolicy::Schedule(segs) => segs.iter().map(|&(_, b)| b).collect(),
            BitsPolicy::Variance(v) => (v.min_bits..=v.max_bits).collect(),
        };
        w.sort_unstable();
        w.dedup();
        w
    }

    /// Whether this is the inert constant-width policy.
    pub fn is_fixed(&self) -> bool {
        matches!(self, BitsPolicy::Fixed(_))
    }

    /// Instantiate the per-run controller for this policy.
    pub fn controller(&self) -> Box<dyn BitController> {
        match self {
            BitsPolicy::Fixed(b) => Box::new(FixedBits { bits: *b }),
            BitsPolicy::Schedule(segs) => Box::new(ScheduledBits {
                segments: segs.clone(),
            }),
            BitsPolicy::Variance(spec) => Box::new(VarianceBits {
                spec: *spec,
                cur: spec.max_bits,
                ema: None,
                profile: Vec::new(),
            }),
        }
    }
}

impl std::fmt::Display for BitsPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// The per-step width decision. One controller instance lives in each
/// [`super::BackendCore`] (sim) or TCP worker; all observations and
/// decisions run on the calling thread before any lane fans out, so the
/// chosen widths are deterministic per seed.
pub trait BitController: Send {
    /// The width to quantize with at `step`. Called exactly once per
    /// step, after the step's observations.
    fn bits_for_step(&mut self, step: usize) -> u32;

    /// Whether this controller consumes the per-step variance signal
    /// (policies that don't skip the O(d) closed-form evaluation
    /// entirely, keeping `fixed:B` at zero overhead).
    fn wants_variance(&self) -> bool {
        false
    }

    /// Feed one step's measured normalized quantization variance
    /// `E‖Q(v)−v‖² / ‖v‖²` of a representative gradient under the
    /// *current* width.
    fn observe_variance(&mut self, _step: usize, _normalized: f64) {}

    /// Feed the per-width expected-variance profile `(bits, Ψ(ℓ_bits))`
    /// the adaptive estimators compute at each level update (used to
    /// predict how the variance moves across widths; non-adaptive
    /// methods never produce one and the controller falls back to the
    /// QSGD scaling law).
    fn observe_width_profile(&mut self, _profile: &[(u32, f64)]) {}

    /// Append the controller's internal state to a `bit_decision` trace
    /// event (the "what did the controller see" record). Stateless
    /// controllers add nothing.
    fn trace_state(&self, _out: &mut Json) {}
}

/// `fixed:B` — the inert controller; the whole dynamic machinery reduces
/// to a constant.
#[derive(Clone, Debug)]
struct FixedBits {
    bits: u32,
}

impl BitController for FixedBits {
    fn bits_for_step(&mut self, _step: usize) -> u32 {
        self.bits
    }
}

/// `schedule:B1@s1,...` — piecewise-constant widths over steps.
#[derive(Clone, Debug)]
struct ScheduledBits {
    segments: Vec<(usize, u32)>,
}

impl BitController for ScheduledBits {
    fn bits_for_step(&mut self, step: usize) -> u32 {
        let mut bits = self.segments[0].1;
        for &(start, b) in &self.segments {
            if step >= start {
                bits = b;
            } else {
                break;
            }
        }
        bits
    }
}

/// `variance[:MIN-MAX[@T]]` — grow/shrink the width so the normalized
/// quantization variance tracks the target.
///
/// The controller smooths the measured signal with an EMA, predicts the
/// variance each candidate width would produce — from the adaptive
/// estimators' per-width Ψ profile when one exists, otherwise from
/// QSGD's scaling law (doubling the level count quarters the variance,
/// i.e. ×4 per bit) — and selects the narrowest width predicted at or
/// under target, with a shrink-side hysteresis margin so it cannot
/// oscillate.
#[derive(Clone, Debug)]
struct VarianceBits {
    spec: VarianceSpec,
    cur: u32,
    ema: Option<f64>,
    profile: Vec<(u32, f64)>,
}

impl VarianceBits {
    /// Predicted normalized variance at width `w`, given the smoothed
    /// observation `ema` made at the current width.
    fn predict(&self, w: u32, ema: f64) -> f64 {
        let lookup = |bits: u32| -> Option<f64> {
            self.profile
                .iter()
                .find(|&&(b, _)| b == bits)
                .map(|&(_, p)| p)
        };
        if let (Some(pw), Some(pc)) = (lookup(w), lookup(self.cur)) {
            if pc > 0.0 && pw > 0.0 {
                return ema * pw / pc;
            }
        }
        // QSGD variance-bound scaling: one extra bit doubles the level
        // count and quarters the variance.
        ema * 4f64.powi(self.cur as i32 - w as i32)
    }
}

impl BitController for VarianceBits {
    fn wants_variance(&self) -> bool {
        true
    }

    fn observe_variance(&mut self, _step: usize, normalized: f64) {
        let prev = self.ema.unwrap_or(normalized);
        self.ema = Some((1.0 - EMA_ALPHA) * prev + EMA_ALPHA * normalized);
    }

    fn observe_width_profile(&mut self, profile: &[(u32, f64)]) {
        self.profile = profile.to_vec();
    }

    fn trace_state(&self, out: &mut Json) {
        out.insert("target", Json::Num(self.spec.target));
        out.insert("min_width", Json::Num(self.spec.min_bits as f64));
        out.insert("max_width", Json::Num(self.spec.max_bits as f64));
        out.insert("down_margin", Json::Num(DOWN_MARGIN));
        if let Some(e) = self.ema {
            out.insert("ema", Json::Num(e));
        }
        if !self.profile.is_empty() {
            out.insert(
                "psi_profile",
                Json::Arr(
                    self.profile
                        .iter()
                        .map(|&(b, p)| Json::Arr(vec![Json::Num(b as f64), Json::Num(p)]))
                        .collect(),
                ),
            );
        }
    }

    fn bits_for_step(&mut self, _step: usize) -> u32 {
        let Some(ema) = self.ema else {
            return self.cur;
        };
        if ema > self.spec.target && self.cur < self.spec.max_bits {
            // Too noisy: widen until predicted back under target.
            let mut w = self.cur;
            while w < self.spec.max_bits && self.predict(w, ema) > self.spec.target {
                w += 1;
            }
            self.cur = w;
        } else {
            // Room to save bits: shrink to the narrowest width whose
            // prediction clears the target with margin.
            let mut best = self.cur;
            let mut w = self.cur;
            while w > self.spec.min_bits {
                w -= 1;
                if self.predict(w, ema) <= DOWN_MARGIN * self.spec.target {
                    best = w;
                } else {
                    break;
                }
            }
            self.cur = best;
        }
        self.cur
    }
}

/// The normalized quantization-variance signal the `variance` policy
/// consumes: the exact Eq. (1)–(2) variance of quantizing `grad`,
/// normalized by the gradient's energy. `None` when the gradient is
/// identically zero (no signal).
pub fn normalized_variance(q: &Quantizer, grad: &[f32]) -> Option<f64> {
    let energy: f64 = grad.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if energy <= 0.0 {
        return None;
    }
    Some(q.exact_variance(grad) / energy)
}

/// One step of the controller protocol, shared verbatim by the sim's
/// `BackendCore::begin_step` and the TCP worker so the two runtimes
/// cannot drift: observe the representative gradient's normalized
/// variance (only when the policy consumes the signal — zero overhead
/// for `fixed:B`/`schedule`), ask the controller for the step's width,
/// switch the session's bank slot (O(1)), and return the width. Callers
/// guard the full-precision case (no quantizer → no width).
///
/// Because this is the single shared decision point, it is also the
/// single instrumentation point: an enabled `tracer` records one
/// `bit_decision` event per step — the observed normalized variance
/// (when the policy consumes it), the previous and chosen widths, and
/// whatever internal state the controller exposes via
/// [`BitController::trace_state`] (EMA, target, Ψ profile, hysteresis
/// margin for the `variance` policy).
pub fn select_width(
    ctl: &mut dyn BitController,
    session: &mut super::session::CodecSession,
    step: usize,
    grad: &[f32],
    tracer: &Tracer,
) -> u32 {
    debug_assert!(session.is_quantized(), "select_width on full precision");
    let mut observed = None;
    if ctl.wants_variance() {
        if let Some(q) = session.quantizer() {
            if let Some(v) = normalized_variance(q, grad) {
                ctl.observe_variance(step, v);
                observed = Some(v);
            }
        }
    }
    let prev = session.active_bits();
    let bits = ctl.bits_for_step(step);
    session.set_active_bits(bits);
    tracer.event(Level::Info, "bit_decision", |o| {
        o.insert("step", Json::Num(step as f64));
        o.insert("width", Json::Num(bits as f64));
        if let Some(p) = prev {
            o.insert("prev_width", Json::Num(p as f64));
        }
        if let Some(v) = observed {
            o.insert("observed_variance", Json::Num(v));
        }
        ctl.trace_state(o);
    });
    bits
}

/// One pre-built codec state per reachable width: the quantizer (levels
/// adapt per width), the Huffman codebook slot, and the sampled
/// symbol-count refresh statistics.
///
/// Pre-building every slot at construction is what makes a mid-run width
/// switch O(1) and deterministic: activating a width is an index move,
/// and a slot's state is a function of the *shared* adaptation history
/// (every level update re-optimizes every slot from the same fitted
/// mixture), never of which steps happened to run at which width — so
/// switching away and back cannot contaminate a width's levels or
/// model-based codebook (`rust/src/exchange/session.rs` tests).
#[derive(Clone, Debug)]
pub struct QuantizerBank {
    slots: Vec<WidthSlot>,
    active: usize,
}

/// Per-width codec state (one bank slot).
#[derive(Clone, Debug)]
struct WidthSlot {
    bits: u32,
    quantizer: Quantizer,
    book: Option<HuffmanBook>,
    sym_counts: Vec<f64>,
}

impl QuantizerBank {
    /// Build one slot per policy width, active at the policy's initial
    /// width. `None` for full-precision methods (no quantizer at any
    /// width).
    pub fn new(method: Method, policy: &BitsPolicy, bucket: usize) -> Option<QuantizerBank> {
        let mut slots = Vec::new();
        for bits in policy.widths() {
            let levels = method.initial_levels(bits)?;
            let mut quantizer = Quantizer::new(levels, method.norm_type(), bucket);
            if let Some(c) = method.clip_factor() {
                quantizer = quantizer.with_clip(c);
            }
            let n = quantizer.levels().num_symbols();
            slots.push(WidthSlot {
                bits,
                quantizer,
                book: None,
                sym_counts: vec![0.0; n],
            });
        }
        let start = policy.initial_bits();
        let active = slots.iter().position(|s| s.bits == start)?;
        Some(QuantizerBank { slots, active })
    }

    /// The currently active width.
    pub fn active_bits(&self) -> u32 {
        self.slots[self.active].bits
    }

    /// Whether the bank holds a slot for `bits`.
    pub fn has_width(&self, bits: u32) -> bool {
        self.slots.iter().any(|s| s.bits == bits)
    }

    /// Every width in the bank, ascending.
    pub fn widths(&self) -> Vec<u32> {
        self.slots.iter().map(|s| s.bits).collect()
    }

    /// Switch the active width — an O(1) index move. Panics on a width
    /// the policy never declared (a controller bug, not a data error).
    pub fn activate(&mut self, bits: u32) {
        self.active = self
            .slots
            .iter()
            .position(|s| s.bits == bits)
            .unwrap_or_else(|| panic!("width {bits} is not in the quantizer bank"));
    }

    /// The active slot's quantizer.
    pub fn quantizer(&self) -> &Quantizer {
        &self.slots[self.active].quantizer
    }

    /// The quantizer for `bits`, if the bank holds that width.
    pub fn quantizer_at(&self, bits: u32) -> Option<&Quantizer> {
        self.slots
            .iter()
            .find(|s| s.bits == bits)
            .map(|s| &s.quantizer)
    }

    /// The active slot's codebook, once one exists.
    pub fn book(&self) -> Option<&HuffmanBook> {
        self.slots[self.active].book.as_ref()
    }

    /// The codebook for `bits`, once one exists.
    pub fn book_at(&self, bits: u32) -> Option<&HuffmanBook> {
        self.slots
            .iter()
            .find(|s| s.bits == bits)
            .and_then(|s| s.book.as_ref())
    }

    /// The (possibly adapted) level magnitudes for `bits`.
    pub fn levels_at(&self, bits: u32) -> Option<Vec<f64>> {
        self.quantizer_at(bits)
            .map(|q| q.levels().mags().to_vec())
    }

    /// Uniform initial codebooks for every slot: identical on every
    /// replica by construction (the TCP path's requirement, now per
    /// width so replicas agree on every reachable width's first book).
    pub fn init_uniform_books(&mut self) {
        for slot in self.slots.iter_mut() {
            slot.book = Some(HuffmanBook::from_weights(&vec![
                1.0;
                slot.quantizer
                    .levels()
                    .num_symbols()
            ]));
        }
    }

    /// Lazily build the *active* slot's codebook from the first
    /// quantized gradient's empirical symbol distribution (smoothed).
    /// No-op once that slot has a book.
    pub fn install_empirical_book(&mut self, first: &QuantizedGrad) {
        let slot = &mut self.slots[self.active];
        if slot.book.is_some() {
            return;
        }
        let counts = symbol_counts(first, slot.quantizer.levels());
        slot.book = Some(HuffmanBook::from_weights(&smooth_weights(&counts)));
    }

    /// Fold one lane's sampled symbol histogram into the active slot's
    /// refresh statistics.
    pub fn accumulate_counts(&mut self, counts: &[f64]) {
        let slot = &mut self.slots[self.active];
        for (c, n) in slot.sym_counts.iter_mut().zip(counts) {
            *c += n;
        }
    }

    /// Refresh every slot that accumulated symbol counts since its last
    /// refresh (the non-adaptive codebook update at the schedule 𝒰);
    /// slots with nothing accumulated keep their book.
    pub fn refresh_from_counts(&mut self) {
        for slot in self.slots.iter_mut() {
            if slot.sym_counts.iter().sum::<f64>() > 0.0 {
                slot.book = Some(HuffmanBook::from_weights(&smooth_weights(&slot.sym_counts)));
                for c in slot.sym_counts.iter_mut() {
                    *c = 0.0;
                }
            }
        }
    }

    /// Algorithm 1 line 4 across the whole bank: re-optimize every
    /// width's levels from the one fitted mixture, install the
    /// model-based (Prop. 6) codebook per width (Huffman only), and
    /// reset the refresh statistics. Returns the per-width expected
    /// variance profile `(bits, Ψ(ℓ_bits))` — the prediction the
    /// `variance` controller consumes.
    pub fn adapt_all(&mut self, method: Method, mix: &Mixture, codec: Codec) -> Vec<(u32, f64)> {
        let mut profile = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter_mut() {
            let new_levels = update_levels(method, slot.quantizer.levels(), mix);
            slot.quantizer.set_levels(new_levels);
            if codec == Codec::Huffman {
                let probs = symbol_probs(mix, slot.quantizer.levels());
                slot.book = Some(HuffmanBook::from_weights(&smooth_weights(&probs)));
            }
            slot.sym_counts = vec![0.0; slot.quantizer.levels().num_symbols()];
            profile.push((slot.bits, psi(mix, slot.quantizer.levels())));
        }
        profile
    }

    /// Force TernGrad-style c·σ clipping on every slot (the Appendix
    /// K.2 / Fig. 14 ablation).
    pub fn force_clip(&mut self, c: f32) {
        for slot in self.slots.iter_mut() {
            slot.quantizer = slot.quantizer.clone().with_clip(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrips() {
        for s in [
            "fixed:3",
            "fixed:8",
            "schedule:4@0,3@100,2@500",
            "variance:2-4@0.25",
        ] {
            let p = BitsPolicy::parse(s).unwrap();
            assert_eq!(BitsPolicy::parse(&p.name()), Some(p.clone()), "{s}");
        }
        assert_eq!(
            BitsPolicy::parse("variance"),
            Some(BitsPolicy::Variance(VarianceSpec::default()))
        );
        assert_eq!(
            BitsPolicy::parse("VARIANCE:3-5"),
            Some(BitsPolicy::Variance(VarianceSpec {
                min_bits: 3,
                max_bits: 5,
                target: VarianceSpec::default().target,
            }))
        );
    }

    #[test]
    fn policy_parse_rejects_malformed() {
        for s in [
            "fixed:1",          // below the representable range
            "fixed:9",          // above it
            "fixed:",           // no width
            "schedule:3@5",     // first segment must start at 0
            "schedule:3@0,4@0", // steps must strictly increase
            "schedule:9@0",     // width out of range
            "schedule:",        // empty
            "variance:4-2",     // inverted range
            "variance:2-9",     // out of range
            "variance:2-4@0",   // target must be positive
            "variance:2-4@-1",
            "bogus",
            "3",
        ] {
            assert_eq!(BitsPolicy::parse(s), None, "{s:?} should not parse");
        }
    }

    #[test]
    fn policy_parse_strict_explains_rejections() {
        for (spec, needle) in [
            ("", "empty bits policy"),
            ("   ", "empty bits policy"),
            ("fixed:9", "out of range"),
            ("fixed:x", "invalid width"),
            ("schedule:", "empty schedule"),
            ("schedule:3@0,4@0", "duplicate step 0"),
            ("schedule:3@0,4@10,2@5", "strictly increasing"),
            ("schedule:3@5", "start with a segment at step 0"),
            ("schedule:3", "missing '@step'"),
            ("variance:4-2", "inverted variance range"),
            ("variance:2-4@0", "must be positive"),
            ("variance:24", "missing '-'"),
            ("bogus", "unknown bits policy"),
        ] {
            let err = BitsPolicy::parse_strict(spec).unwrap_err();
            assert!(err.contains(needle), "{spec:?}: {err:?} lacks {needle:?}");
        }
        // The strict and lossy parsers agree on acceptance.
        for spec in ["fixed:3", "schedule:4@0,2@9", "variance:2-4@0.25"] {
            assert_eq!(
                BitsPolicy::parse(spec),
                BitsPolicy::parse_strict(spec).ok(),
                "{spec}"
            );
        }
    }

    #[test]
    fn policy_widths_and_initial_bits() {
        let p = BitsPolicy::parse("schedule:4@0,2@10,4@20").unwrap();
        assert_eq!(p.widths(), vec![2, 4]);
        assert_eq!(p.initial_bits(), 4);
        let p = BitsPolicy::parse("variance:2-5").unwrap();
        assert_eq!(p.widths(), vec![2, 3, 4, 5]);
        assert_eq!(p.initial_bits(), 5);
        let p = BitsPolicy::Fixed(3);
        assert_eq!(p.widths(), vec![3]);
        assert_eq!(p.initial_bits(), 3);
        assert!(p.is_fixed());
    }

    #[test]
    fn fixed_controller_is_constant_and_blind() {
        let mut c = BitsPolicy::Fixed(3).controller();
        assert!(!c.wants_variance());
        c.observe_variance(0, 123.0);
        for step in 0..100 {
            assert_eq!(c.bits_for_step(step), 3);
        }
    }

    #[test]
    fn schedule_controller_switches_at_segment_starts() {
        let mut c = BitsPolicy::parse("schedule:4@0,3@10,2@25").unwrap().controller();
        assert_eq!(c.bits_for_step(0), 4);
        assert_eq!(c.bits_for_step(9), 4);
        assert_eq!(c.bits_for_step(10), 3);
        assert_eq!(c.bits_for_step(24), 3);
        assert_eq!(c.bits_for_step(25), 2);
        assert_eq!(c.bits_for_step(1_000_000), 2);
    }

    #[test]
    fn variance_controller_shrinks_on_calm_signal_and_grows_on_noise() {
        let spec = VarianceSpec {
            min_bits: 2,
            max_bits: 4,
            target: 0.25,
        };
        let mut c = BitsPolicy::Variance(spec).controller();
        assert!(c.wants_variance());
        // No observation yet: stays at the starting (max) width.
        assert_eq!(c.bits_for_step(0), 4);
        // Extremely calm signal: even ×16 (two widths down) clears the
        // margin, so the controller walks to the floor.
        for step in 1..20 {
            c.observe_variance(step, 1e-4);
            assert!(c.bits_for_step(step) >= 2);
        }
        assert_eq!(c.bits_for_step(20), 2);
        // Signal explodes: the controller climbs back up.
        for step in 21..60 {
            c.observe_variance(step, 10.0);
        }
        assert_eq!(c.bits_for_step(60), 4);
    }

    #[test]
    fn variance_controller_uses_the_width_profile_when_present() {
        let spec = VarianceSpec {
            min_bits: 2,
            max_bits: 4,
            target: 0.25,
        };
        let mut c = BitsPolicy::Variance(spec).controller();
        // Profile says width 2 is barely worse than width 4 (adapted
        // levels), so a moderately calm signal that the ×4-per-bit
        // fallback would keep at 3+ bits drops straight to 2.
        c.observe_width_profile(&[(2, 0.011), (3, 0.0105), (4, 0.01)]);
        for step in 0..30 {
            c.observe_variance(step, 0.12);
        }
        assert_eq!(c.bits_for_step(30), 2);
    }

    #[test]
    fn variance_controller_is_deterministic() {
        let run = || {
            let mut c = BitsPolicy::parse("variance:2-4@0.2").unwrap().controller();
            let mut widths = Vec::new();
            for step in 0..50 {
                c.observe_variance(step, 0.3 / (1.0 + step as f64 * 0.1));
                widths.push(c.bits_for_step(step));
            }
            widths
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn bank_prebuilds_every_width_and_activates_in_o1() {
        let policy = BitsPolicy::parse("variance:2-4").unwrap();
        let mut bank = QuantizerBank::new(Method::Alq, &policy, 64).unwrap();
        assert_eq!(bank.widths(), vec![2, 3, 4]);
        assert_eq!(bank.active_bits(), 4);
        assert!(bank.has_width(2) && !bank.has_width(5));
        bank.activate(2);
        assert_eq!(bank.active_bits(), 2);
        assert_eq!(bank.quantizer().levels().num_symbols(), 2);
        bank.activate(4);
        assert_eq!(bank.quantizer().levels().num_symbols(), 8);
        // Per-width quantizers are independent objects.
        assert_eq!(bank.quantizer_at(3).unwrap().levels().num_symbols(), 4);
        assert_eq!(bank.levels_at(2).unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "not in the quantizer bank")]
    fn bank_rejects_undeclared_widths() {
        let mut bank =
            QuantizerBank::new(Method::Alq, &BitsPolicy::Fixed(3), 64).unwrap();
        bank.activate(5);
    }

    #[test]
    fn bank_is_none_for_full_precision() {
        assert!(QuantizerBank::new(Method::SuperSgd, &BitsPolicy::Fixed(3), 64).is_none());
        assert!(QuantizerBank::new(Method::SingleSgd, &BitsPolicy::Fixed(3), 64).is_none());
    }

    #[test]
    fn uniform_books_cover_every_slot() {
        let policy = BitsPolicy::parse("schedule:3@0,4@10").unwrap();
        let mut bank = QuantizerBank::new(Method::Alq, &policy, 64).unwrap();
        assert!(bank.book().is_none());
        bank.init_uniform_books();
        assert!(bank.book_at(3).is_some());
        assert!(bank.book_at(4).is_some());
        // Replica independence: a second bank builds the same books.
        let mut other = QuantizerBank::new(Method::Alq, &policy, 64).unwrap();
        other.init_uniform_books();
        assert_eq!(bank.book_at(3), other.book_at(3));
        assert_eq!(bank.book_at(4), other.book_at(4));
    }

    #[test]
    fn select_width_drives_the_session_bank() {
        use super::super::session::CodecSession;
        let policy = BitsPolicy::parse("schedule:3@0,2@4").unwrap();
        let mut s = CodecSession::with_policy(Method::QsgdInf, &policy, 64);
        let mut ctl = policy.controller();
        let g = [0.1f32; 64];
        let off = Tracer::disabled();
        assert_eq!(select_width(ctl.as_mut(), &mut s, 0, &g, &off), 3);
        assert_eq!(s.active_bits(), Some(3));
        assert_eq!(select_width(ctl.as_mut(), &mut s, 4, &g, &off), 2);
        assert_eq!(s.active_bits(), Some(2));
    }

    #[test]
    fn select_width_emits_bit_decision_with_controller_state() {
        use super::super::session::CodecSession;
        let policy = BitsPolicy::parse("variance:2-4").unwrap();
        let mut s = CodecSession::with_policy(Method::Alq, &policy, 64);
        let mut ctl = policy.controller();
        let (tracer, buf) = Tracer::memory(Level::Info);
        let g = [0.1f32; 64];
        let w = select_width(ctl.as_mut(), &mut s, 0, &g, &tracer);
        let text = buf.lock().unwrap().clone();
        assert!(text.contains(r#""e":"bit_decision""#), "{text}");
        assert!(text.contains(&format!("\"width\":{w}")));
        assert!(text.contains("\"observed_variance\":"));
        assert!(text.contains("\"target\":"));
        assert!(text.contains("\"ema\":"));
        assert!(text.contains("\"prev_width\":4"));
    }

    #[test]
    fn normalized_variance_is_scale_free_and_none_on_zero() {
        let q = Quantizer::new(
            crate::quant::Levels::exponential(4, 0.5),
            crate::quant::NormType::Linf,
            64,
        );
        let mut rng = crate::util::Rng::new(7);
        let g: Vec<f32> = (0..256).map(|_| (rng.normal() * 0.1) as f32).collect();
        let v = normalized_variance(&q, &g).unwrap();
        assert!(v > 0.0);
        let g2: Vec<f32> = g.iter().map(|&x| x * 100.0).collect();
        let v2 = normalized_variance(&q, &g2).unwrap();
        assert!((v - v2).abs() / v < 1e-3, "{v} vs {v2}");
        assert!(normalized_variance(&q, &[0.0f32; 64]).is_none());
    }
}
