//! Codec session + per-worker lanes: the state and buffers behind one
//! method's quantize/encode/decode path, shared by the in-process
//! engine and the TCP coordinator.

use super::budget::{BitsPolicy, QuantizerBank};
use crate::adaptive::Estimator;
use crate::quant::bitio::{BitReader, BitWriter};
use crate::quant::elias::{decode_qsgd_style_into, encode_qsgd_style, encode_qsgd_style_range};
use crate::quant::{
    Codec, EncodedView, HuffmanBook, Method, QuantScratch, QuantizeImpl, QuantizedGrad, Quantizer,
};
use crate::runtime::PallasQuantize;
use crate::util::Rng;
use std::ops::Range;
use std::sync::Arc;

/// App. K: mixture components retained for CIFAR-scale runs.
const MAX_MIXTURE_COMPONENTS: usize = 20;

/// One method's codec state: the per-width [`QuantizerBank`] (quantizer,
/// Huffman codebook lifecycle, and symbol-count refresh statistics per
/// reachable bit-width), the active width, and the distribution
/// estimator driving ALQ/AMQ level adaptation.
///
/// A codebook has three sources, all smoothed with
/// [`crate::quant::smooth_weights`] so every symbol stays codable:
/// * **lazy empirical** — built from the first quantized gradient's
///   symbol histogram at that width
///   ([`CodecSession::build_empirical_book`], the sim path);
/// * **uniform** — identical on every replica by construction, for
///   every reachable width ([`CodecSession::init_uniform_book`], the
///   distributed path, where no replica may depend on another's first
///   batch);
/// * **model-based** — Prop. 6 closed-form symbol probabilities under
///   the fitted mixture, installed *per width* on every successful
///   level update ([`CodecSession::adapt`]), or refreshed from the
///   sampled empirical counts for non-adaptive methods
///   ([`CodecSession::refresh_book_from_counts`]).
///
/// With a `fixed:B` policy the bank holds one slot and every method
/// below reduces exactly to the historical single-width behavior
/// (`rust/tests/exchange_parity.rs` pins this against the seed loop).
#[derive(Clone, Debug)]
pub struct CodecSession {
    method: Method,
    bucket: usize,
    codec: Codec,
    bank: Option<QuantizerBank>,
    estimator: Option<Estimator>,
    /// Per-width `(bits, Ψ)` expected-variance profile from the last
    /// successful level update (consumed by the `variance` policy).
    width_profile: Vec<(u32, f64)>,
    /// Which stochastic-rounding implementation the lanes drive
    /// (`--quantize-impl`), after any Pallas → Fast downgrade.
    quantize_impl: QuantizeImpl,
    /// The compiled Pallas kernel, shared across lanes; present only
    /// when `quantize_impl` is `Pallas` and construction succeeded.
    pallas: Option<Arc<PallasQuantize>>,
}

impl CodecSession {
    /// Stand up one method's codec state at a single fixed width — the
    /// historical constructor, equivalent to
    /// [`CodecSession::with_policy`] over `fixed:bits`.
    pub fn new(method: Method, bits: u32, bucket: usize) -> Self {
        CodecSession::with_policy(method, &BitsPolicy::Fixed(bits), bucket)
    }

    /// Stand up one method's codec state over every width the bit
    /// policy can reach: one pre-built bank slot per width (none for
    /// full precision), the mixture estimator, and empty codebook
    /// slots. The session starts at the policy's initial width.
    pub fn with_policy(method: Method, policy: &BitsPolicy, bucket: usize) -> Self {
        let bank = QuantizerBank::new(method, policy, bucket);
        let estimator = bank
            .as_ref()
            .map(|b| Estimator::new(bucket, b.quantizer().norm_type(), MAX_MIXTURE_COMPONENTS));
        CodecSession {
            method,
            bucket,
            codec: Codec::Huffman,
            bank,
            estimator,
            width_profile: Vec::new(),
            quantize_impl: QuantizeImpl::default(),
            pallas: None,
        }
    }

    /// Select the lane quantization implementation (`--quantize-impl
    /// scalar|fast|pallas`). `Pallas` stands up the PJRT client and
    /// compiles the kernel once, right here; when that fails (the
    /// `pjrt` feature is off, artifacts are absent) the session reports
    /// the downgrade once through [`crate::trace::warn`] (stderr plus
    /// the installed tracer's `warning` event) and downgrades to the
    /// bit-identical host `Fast` path so every configuration still runs
    /// everywhere.
    pub fn with_quantize_impl(mut self, imp: QuantizeImpl) -> Self {
        self.quantize_impl = imp;
        self.pallas = None;
        if imp == QuantizeImpl::Pallas && self.bank.is_some() {
            match PallasQuantize::try_new() {
                Ok(dev) => self.pallas = Some(Arc::new(dev)),
                Err(e) => {
                    crate::trace::warn(
                        "pallas",
                        &format!(
                            "--quantize-impl pallas unavailable ({e:#}); \
                             falling back to the fast host path"
                        ),
                    );
                    self.quantize_impl = QuantizeImpl::Fast;
                }
            }
        }
        self
    }

    /// The selected quantization implementation (after any downgrade).
    pub fn quantize_impl(&self) -> QuantizeImpl {
        self.quantize_impl
    }

    /// The shared Pallas kernel handle, when `--quantize-impl pallas`
    /// is live on this session.
    pub fn pallas_op(&self) -> Option<&PallasQuantize> {
        self.pallas.as_deref()
    }

    /// Select the entropy coder (the QSGD-style coding ablation). Elias
    /// coding runs books-free but needs a zero level to run-length over —
    /// the no-zero AMQ level family must keep Huffman (validated again at
    /// config parse time). Zero-ness is a property of the method's level
    /// family, so checking the active width covers every bank slot.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        if codec == Codec::Elias {
            if let Some(q) = self.quantizer() {
                assert!(
                    q.levels().has_zero(),
                    "elias coding needs a zero level; {} has none",
                    self.method
                );
            }
        }
        self.codec = codec;
        self
    }

    /// The selected entropy coder.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Whether this session's coder needs a Huffman codebook at all
    /// (Elias coding is codebook-free; so is full precision).
    pub fn needs_book(&self) -> bool {
        self.bank.is_some() && self.codec == Codec::Huffman
    }

    /// The quantization method this session codes for.
    pub fn method(&self) -> Method {
        self.method
    }

    /// The bucket size (coordinates per normalization bucket).
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// The live quantizer at the active width, if this session
    /// quantizes at all.
    pub fn quantizer(&self) -> Option<&Quantizer> {
        self.bank.as_ref().map(|b| b.quantizer())
    }

    /// The quantizer for an explicit width (decoding a peer frame that
    /// self-describes its width on the wire).
    pub fn quantizer_at(&self, bits: u32) -> Option<&Quantizer> {
        self.bank.as_ref().and_then(|b| b.quantizer_at(bits))
    }

    /// Whether this session quantizes at all (full-precision methods
    /// carry raw fp32 and never touch the codebook).
    pub fn is_quantized(&self) -> bool {
        self.bank.is_some()
    }

    /// The active width's Huffman codebook, once one exists.
    pub fn book(&self) -> Option<&HuffmanBook> {
        self.bank.as_ref().and_then(|b| b.book())
    }

    /// The codebook for an explicit width, once one exists.
    pub fn book_at(&self, bits: u32) -> Option<&HuffmanBook> {
        self.bank.as_ref().and_then(|b| b.book_at(bits))
    }

    /// The active quantization width, `None` for full precision.
    pub fn active_bits(&self) -> Option<u32> {
        self.bank.as_ref().map(|b| b.active_bits())
    }

    /// Whether the session's bank holds a slot for `bits` (i.e. the bit
    /// policy declared that width reachable).
    pub fn has_width(&self, bits: u32) -> bool {
        self.bank.as_ref().is_some_and(|b| b.has_width(bits))
    }

    /// Every width the session's bank pre-built, ascending (empty for
    /// full precision).
    pub fn widths(&self) -> Vec<u32> {
        self.bank.as_ref().map(|b| b.widths()).unwrap_or_default()
    }

    /// Switch the active width — an O(1) bank index move. No-op for
    /// full precision; panics on a width the policy never declared.
    pub fn set_active_bits(&mut self, bits: u32) {
        if let Some(bank) = &mut self.bank {
            bank.activate(bits);
        }
    }

    /// The current (possibly adapted) quantization level magnitudes at
    /// the active width.
    pub fn final_levels(&self) -> Option<Vec<f64>> {
        self.quantizer().map(|q| q.levels().mags().to_vec())
    }

    /// The current level magnitudes at an explicit width.
    pub fn final_levels_at(&self, bits: u32) -> Option<Vec<f64>> {
        self.bank.as_ref().and_then(|b| b.levels_at(bits))
    }

    /// The per-width `(bits, Ψ)` expected-variance profile of the last
    /// successful level update (empty before the first, and always for
    /// non-adaptive methods).
    pub fn width_profile(&self) -> &[(u32, f64)] {
        &self.width_profile
    }

    /// Force TernGrad-style c·σ clipping regardless of method, on every
    /// bank width (the Appendix K.2 / Fig. 14 ablation).
    pub fn force_clip(&mut self, c: f32) {
        if let Some(bank) = &mut self.bank {
            bank.force_clip(c);
        }
    }

    /// Uniform initial codebooks for every reachable width: identical
    /// on every replica by construction (the TCP path's requirement).
    /// No-op for codebook-free coders.
    pub fn init_uniform_book(&mut self) {
        if !self.needs_book() {
            return;
        }
        if let Some(bank) = &mut self.bank {
            bank.init_uniform_books();
        }
    }

    /// Lazily build the active width's codebook from the first
    /// quantized gradient's empirical symbol distribution (smoothed:
    /// later steps may emit symbols unseen in the first batch). No-op
    /// once that width has a book (or for codebook-free coders).
    pub fn build_empirical_book(&mut self, first: &QuantizedGrad) {
        if !self.needs_book() {
            return;
        }
        if let Some(bank) = &mut self.bank {
            bank.install_empirical_book(first);
        }
    }

    /// Fold one lane's sampled symbol histogram into the active width's
    /// refresh statistics.
    pub fn accumulate_counts(&mut self, counts: &[f64]) {
        if let Some(bank) = &mut self.bank {
            bank.accumulate_counts(counts);
        }
    }

    /// Refresh the codebooks from the empirical symbol counts
    /// accumulated since the last refresh (the non-adaptive methods'
    /// codebook update at the schedule 𝒰), per width. No-op for widths
    /// where nothing was accumulated.
    pub fn refresh_book_from_counts(&mut self) {
        if !self.needs_book() {
            return;
        }
        if let Some(bank) = &mut self.bank {
            bank.refresh_from_counts();
        }
    }

    /// Algorithm 1 line 4 for adaptive methods: fit the truncated-normal
    /// mixture to the observed gradients once, then re-optimize the
    /// levels and install the model-based codebook (Prop. 6) for *every*
    /// bank width from that one fit — so a width's adapted state depends
    /// only on the shared adaptation history, never on which steps ran
    /// at which width. Also records the per-width Ψ profile for the
    /// `variance` bit controller. Returns true iff the levels were
    /// updated; non-adaptive methods (and an empty fit) return false so
    /// the caller can fall back to
    /// [`CodecSession::refresh_book_from_counts`].
    pub fn adapt<'a, I>(&mut self, grads: I, rng: &mut Rng) -> bool
    where
        I: IntoIterator<Item = &'a [f32]>,
    {
        let (Some(bank), Some(est)) = (&mut self.bank, &mut self.estimator) else {
            return false;
        };
        if !self.method.is_adaptive() {
            // Non-adaptive methods never fit the mixture; skip the
            // O(d) sufficient-statistics pass entirely.
            return false;
        }
        est.clear();
        for g in grads {
            est.observe(g);
        }
        let Some(mix) = est.fit(self.method.weighted_mixture(), rng) else {
            return false;
        };
        self.width_profile = bank.adapt_all(self.method, &mix, self.codec);
        true
    }
}

/// One worker's reusable codec buffers. Everything here is scratch that
/// survives across steps so the hot loop is allocation-free once warm;
/// the encoded frame is borrowed out of the writer via [`EncodedView`]
/// rather than cloned.
#[derive(Debug)]
pub struct ExchangeLane {
    qbuf: QuantizedGrad,
    writer: BitWriter,
    dec_buf: QuantizedGrad,
    ghat: Vec<f32>,
    counts: Vec<f64>,
    bits: u64,
    n_full: usize,
    n_tail: usize,
    /// Fast-path quantizer scratch (clip + uniforms), reused per step.
    scratch: QuantScratch,
    /// Whole-gradient uniforms buffer for the Pallas device path.
    u_buf: Vec<f32>,
}

impl ExchangeLane {
    /// Allocate an empty lane for gradients bucketed at `bucket`
    /// coordinates (buffers grow on first use and are reused after).
    pub fn new(bucket: usize) -> Self {
        let empty = || QuantizedGrad {
            qidx: Vec::new(),
            norms: Vec::new(),
            tail: Vec::new(),
            bucket,
        };
        ExchangeLane {
            qbuf: empty(),
            writer: BitWriter::new(),
            dec_buf: empty(),
            ghat: Vec::new(),
            counts: Vec::new(),
            bits: 0,
            n_full: 0,
            n_tail: 0,
            scratch: QuantScratch::default(),
            u_buf: Vec::new(),
        }
    }

    /// Draw this worker's stochastic quantization of `grad` at the
    /// session's active width, through the session's selected
    /// implementation (`--quantize-impl`): the scalar reference loop,
    /// the bit-identical vectorizable fast path over the lane's reusable
    /// scratch, or the Pallas kernel (which draws the same one uniform
    /// per coordinate but consumes them device-side; incompatible
    /// shapes/configs fall back to the fast path per call).
    pub fn quantize(&mut self, s: &CodecSession, grad: &[f32], rng: &mut Rng) {
        let q = s
            .quantizer()
            .expect("quantize on a full-precision session");
        match s.quantize_impl() {
            QuantizeImpl::Scalar => q.quantize_into_scalar(grad, rng, &mut self.qbuf),
            QuantizeImpl::Fast => {
                q.quantize_into_with(grad, rng, &mut self.scratch, &mut self.qbuf)
            }
            QuantizeImpl::Pallas => {
                if let Some(dev) = s.pallas_op() {
                    if dev.compatible(q, grad.len()) {
                        self.u_buf.resize(grad.len(), 0.0);
                        rng.fill_uniform_f32(&mut self.u_buf);
                        if dev.run_into(q, grad, &self.u_buf, &mut self.qbuf).is_ok() {
                            return;
                        }
                    }
                }
                q.quantize_into_with(grad, rng, &mut self.scratch, &mut self.qbuf)
            }
        }
    }

    /// The last quantization (feeds the lazy codebook build).
    pub fn quantized(&self) -> &QuantizedGrad {
        &self.qbuf
    }

    /// Record this lane's symbol histogram (the sampled codebook-refresh
    /// statistic; a full counting pass per worker-step was ~25% of codec
    /// time — DESIGN.md §Perf).
    pub fn count_symbols(&mut self, s: &CodecSession) {
        let q = s.quantizer().expect("counts on a full-precision session");
        self.counts = crate::quant::symbol_counts(&self.qbuf, q.levels());
    }

    /// The last sampled symbol histogram.
    pub fn counts(&self) -> &[f64] {
        &self.counts
    }

    /// Entropy-encode the lane's quantized gradient into the reusable
    /// writer with the session's coder (Huffman symbols or Elias-γ runs)
    /// at the session's active width.
    /// Returns the exact payload bits — the figure the network model is
    /// charged.
    pub fn encode(&mut self, s: &CodecSession) -> u64 {
        let q = s.quantizer().expect("encode on a full-precision session");
        self.writer.clear();
        self.bits = match s.codec() {
            Codec::Huffman => {
                let book = s.book().expect("codebook not initialized");
                crate::quant::encode_into(&self.qbuf, q.levels(), book, &mut self.writer)
            }
            Codec::Elias => encode_qsgd_style(&self.qbuf, q.levels(), &mut self.writer),
        };
        self.n_full = self.qbuf.qidx.len();
        self.n_tail = self.qbuf.tail.len();
        self.writer.finish_ref();
        self.bits
    }

    /// Encode one bucket-aligned shard of the lane's quantized gradient
    /// into an external writer (the sharded topology's per-shard frames).
    /// Bucket-aligned shard frames concatenate to exactly the bits of
    /// [`ExchangeLane::encode`]'s whole frame. Returns the shard's bits.
    pub fn encode_shard_into(
        &self,
        s: &CodecSession,
        buckets: Range<usize>,
        include_tail: bool,
        w: &mut BitWriter,
    ) -> u64 {
        let q = s
            .quantizer()
            .expect("shard encode on a full-precision session");
        match s.codec() {
            Codec::Huffman => {
                let book = s.book().expect("codebook not initialized");
                crate::quant::encode_buckets_into(
                    &self.qbuf,
                    q.levels(),
                    book,
                    buckets,
                    include_tail,
                    w,
                )
            }
            Codec::Elias => {
                encode_qsgd_style_range(&self.qbuf, q.levels(), buckets, include_tail, w)
            }
        }
    }

    /// Tail length of the last quantization (shard-frame metadata).
    pub fn tail_len(&self) -> usize {
        self.qbuf.tail.len()
    }

    /// Full-precision "encoding": the raw fp32 coordinates ride in the
    /// tail slot of the frame (32·d bits, byte-compatible with what the
    /// codec path emits for an all-tail gradient).
    pub fn encode_raw(&mut self, grad: &[f32]) -> u64 {
        self.writer.clear();
        for &g in grad {
            self.writer.push_f32(g);
        }
        self.bits = self.writer.bits_written();
        self.n_full = 0;
        self.n_tail = grad.len();
        self.writer.finish_ref();
        self.bits
    }

    /// Bits of the last encode.
    pub fn bits(&self) -> u64 {
        self.bits
    }

    /// Borrow the last encoded frame (valid until the next encode).
    pub fn encoded(&self) -> EncodedView<'_> {
        EncodedView {
            bytes: self.writer.bytes(),
            bits: self.bits,
            n_full: self.n_full,
            n_tail: self.n_tail,
            bucket: self.qbuf.bucket,
        }
    }

    /// Decode an encoded frame (own or a peer's) produced at the
    /// session's *active* width and dequantize into the lane's `ghat`;
    /// returns the estimate.
    pub fn decode_to_ghat(&mut self, s: &CodecSession, view: EncodedView<'_>) -> &[f32] {
        let width = s.active_bits();
        self.decode_dispatch(s, width, view)
    }

    /// Decode a frame produced at an explicit width (the TCP path,
    /// where every wire frame self-describes the width it was encoded
    /// at so replicas decode with the right bank slot).
    pub fn decode_to_ghat_at(
        &mut self,
        s: &CodecSession,
        bits: u32,
        view: EncodedView<'_>,
    ) -> &[f32] {
        let width = if s.is_quantized() { Some(bits) } else { None };
        self.decode_dispatch(s, width, view)
    }

    fn decode_dispatch(
        &mut self,
        s: &CodecSession,
        width: Option<u32>,
        view: EncodedView<'_>,
    ) -> &[f32] {
        if let Some(bits) = width {
            decode_frame_into(view, s, bits, &mut self.dec_buf, &mut self.ghat);
        } else {
            // Full precision: the payload is the raw fp32 stream.
            let n = view.n_full + view.n_tail;
            if self.ghat.len() != n {
                self.ghat.resize(n, 0.0);
            }
            let mut r = BitReader::new(view.bytes);
            for x in self.ghat.iter_mut() {
                *x = r.read_f32();
            }
        }
        &self.ghat
    }

    /// Decode the lane's own freshly-encoded frame — the simulated
    /// loopback: every peer would decode these exact bytes, so decoding
    /// once here is the paper's "simulate M GPUs on one" methodology
    /// with real bit accounting.
    pub fn decode_own(&mut self, s: &CodecSession) {
        let bits = s
            .active_bits()
            .expect("loopback decode on a full-precision session");
        let view = EncodedView {
            bytes: self.writer.bytes(),
            bits: self.bits,
            n_full: self.n_full,
            n_tail: self.n_tail,
            bucket: self.qbuf.bucket,
        };
        decode_frame_into(view, s, bits, &mut self.dec_buf, &mut self.ghat);
    }

    /// The dequantized gradient estimate of the last decode.
    pub fn ghat(&self) -> &[f32] {
        &self.ghat
    }
}

/// The single quantized-frame decode path: resize the estimate buffer,
/// decode symbols + norms + tail with the session's coder at the
/// frame's width, dequantize.
/// Free function over the lane's disjoint fields so `decode_own` (which
/// also borrows the lane's writer for the view) and the `decode_to_ghat`
/// entry points share one copy.
fn decode_frame_into(
    view: EncodedView<'_>,
    s: &CodecSession,
    width: u32,
    dec_buf: &mut QuantizedGrad,
    ghat: &mut Vec<f32>,
) {
    let q = s
        .quantizer_at(width)
        .unwrap_or_else(|| panic!("frame decode needs a quantizer at width {width}"));
    let n = view.n_full + view.n_tail;
    if ghat.len() != n {
        ghat.resize(n, 0.0);
    }
    match s.codec() {
        Codec::Huffman => {
            let book = s.book_at(width).expect("codebook not initialized");
            crate::quant::decode_view_into(view, q.levels(), book, dec_buf);
        }
        Codec::Elias => {
            decode_qsgd_style_into(view.bytes, view.n_full, view.n_tail, view.bucket, dec_buf);
        }
    }
    q.dequantize(dec_buf, ghat);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::decode;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.normal() * 0.1) as f32).collect()
    }

    #[test]
    fn lane_roundtrip_matches_owned_pipeline() {
        let mut s = CodecSession::new(Method::Alq, 3, 64);
        let grad = randn(300, 1);
        let mut lane = ExchangeLane::new(64);
        let mut rng = Rng::new(2);
        lane.quantize(&s, &grad, &mut rng);
        s.build_empirical_book(lane.quantized());
        let bits = lane.encode(&s);
        assert!(bits > 0);
        assert_eq!(bits, lane.encoded().bits);

        // Owned-path reference on the same quantization.
        let q = s.quantizer().unwrap();
        let book = s.book().unwrap();
        let e = crate::quant::encode(lane.quantized(), q.levels(), book);
        assert_eq!(e.bits, bits);
        assert_eq!(e.bytes, lane.encoded().bytes);
        let dec = decode(&e, q.levels(), book);
        let mut want = vec![0.0f32; grad.len()];
        q.dequantize(&dec, &mut want);

        lane.decode_own(&s);
        assert_eq!(lane.ghat(), &want[..]);
        // Tail is carried exactly.
        assert_eq!(&lane.ghat()[256..], &grad[256..]);
    }

    /// ISSUE 6 tentpole: the lane's `--quantize-impl scalar` and `fast`
    /// paths draw the same uniforms and emit the same symbols, norms,
    /// and post-call RNG state — so every parity golden holds with the
    /// fast path enabled (the default).
    #[test]
    fn scalar_and_fast_lane_quantization_are_bit_identical() {
        for method in [Method::Alq, Method::Amq, Method::Trn, Method::QsgdInf] {
            let s_scalar =
                CodecSession::new(method, 3, 32).with_quantize_impl(QuantizeImpl::Scalar);
            let s_fast = CodecSession::new(method, 3, 32).with_quantize_impl(QuantizeImpl::Fast);
            assert_eq!(s_scalar.quantize_impl(), QuantizeImpl::Scalar);
            assert_eq!(s_fast.quantize_impl(), QuantizeImpl::Fast);
            let mut lane_s = ExchangeLane::new(32);
            let mut lane_f = ExchangeLane::new(32);
            let mut rng_s = Rng::new(40);
            let mut rng_f = Rng::new(40);
            for step in 0..4 {
                let mut grad = randn(170, 50 + step);
                // A zero bucket exercises the draw-free / sign-only arm.
                for x in &mut grad[32..64] {
                    *x = 0.0;
                }
                lane_s.quantize(&s_scalar, &grad, &mut rng_s);
                lane_f.quantize(&s_fast, &grad, &mut rng_f);
                assert_eq!(lane_s.quantized(), lane_f.quantized(), "{method} step {step}");
                assert_eq!(rng_s.next_u64(), rng_f.next_u64(), "{method} step {step} rng");
            }
        }
    }

    /// Without the PJRT runtime the Pallas implementation downgrades to
    /// the fast host path at session construction and keeps running.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pallas_impl_downgrades_to_fast_without_a_runtime() {
        let s = CodecSession::new(Method::Alq, 3, 64).with_quantize_impl(QuantizeImpl::Pallas);
        assert_eq!(s.quantize_impl(), QuantizeImpl::Fast);
        assert!(s.pallas_op().is_none());
        let grad = randn(256, 33);
        let mut lane = ExchangeLane::new(64);
        let mut rng = Rng::new(34);
        lane.quantize(&s, &grad, &mut rng);
        assert_eq!(lane.quantized().qidx.len(), 256);
    }

    #[test]
    fn lane_buffers_are_reused_across_steps() {
        let mut s = CodecSession::new(Method::QsgdInf, 3, 32);
        let mut lane = ExchangeLane::new(32);
        let mut rng = Rng::new(3);
        let mut last_bits = 0;
        for step in 0..5 {
            let grad = randn(128, 10 + step);
            lane.quantize(&s, &grad, &mut rng);
            s.build_empirical_book(lane.quantized());
            last_bits = lane.encode(&s);
            lane.decode_own(&s);
            assert_eq!(lane.ghat().len(), 128);
        }
        assert!(last_bits > 0);
    }

    #[test]
    fn raw_encoding_roundtrips_without_quantizer() {
        let s = CodecSession::new(Method::SuperSgd, 3, 32);
        assert!(!s.is_quantized());
        assert_eq!(s.active_bits(), None);
        let grad = randn(100, 4);
        let mut lane = ExchangeLane::new(32);
        let bits = lane.encode_raw(&grad);
        assert_eq!(bits, 32 * 100);
        let view = lane.encoded();
        assert_eq!((view.n_full, view.n_tail), (0, 100));
        let mut peer = ExchangeLane::new(32);
        let got = peer.decode_to_ghat(&s, view);
        assert_eq!(got, &grad[..]);
    }

    #[test]
    fn elias_lane_roundtrip_matches_huffman_values() {
        // Same RNG → same symbols → identical decoded estimates; only the
        // bit counts differ between the coders.
        let grad = randn(300, 21);
        let mut s_h = CodecSession::new(Method::NuqSgd, 3, 64);
        let s_e = CodecSession::new(Method::NuqSgd, 3, 64).with_codec(Codec::Elias);
        assert!(!s_e.needs_book());
        assert!(s_h.needs_book());
        let mut lane_h = ExchangeLane::new(64);
        let mut lane_e = ExchangeLane::new(64);
        let mut rng_h = Rng::new(22);
        let mut rng_e = Rng::new(22);
        lane_h.quantize(&s_h, &grad, &mut rng_h);
        lane_e.quantize(&s_e, &grad, &mut rng_e);
        s_h.build_empirical_book(lane_h.quantized());
        let bits_h = lane_h.encode(&s_h);
        let bits_e = lane_e.encode(&s_e);
        assert!(bits_h > 0 && bits_e > 0);
        assert_ne!(bits_h, bits_e, "coders should produce different frames");
        lane_h.decode_own(&s_h);
        lane_e.decode_own(&s_e);
        assert_eq!(lane_h.ghat(), lane_e.ghat());
        // The Elias session never builds a book.
        assert!(s_e.book().is_none());
    }

    #[test]
    #[should_panic(expected = "zero level")]
    fn elias_rejects_no_zero_levels() {
        // AMQ's symmetric no-zero family cannot run-length encode.
        let _ = CodecSession::new(Method::Amq, 3, 64).with_codec(Codec::Elias);
    }

    #[test]
    fn uniform_book_is_replica_independent() {
        let mut a = CodecSession::new(Method::Alq, 3, 64);
        let mut b = CodecSession::new(Method::Alq, 3, 64);
        a.init_uniform_book();
        b.init_uniform_book();
        assert_eq!(a.book().unwrap(), b.book().unwrap());
    }

    #[test]
    fn adapt_moves_levels_and_installs_model_book() {
        let mut s = CodecSession::new(Method::Alq, 3, 64);
        s.init_uniform_book();
        let before_levels = s.final_levels().unwrap();
        let before_book = s.book().unwrap().clone();
        let grads: Vec<Vec<f32>> = (0..4).map(|i| randn(640, 20 + i)).collect();
        let mut rng = Rng::new(5);
        assert!(s.adapt(grads.iter().map(|g| g.as_slice()), &mut rng));
        assert_ne!(s.final_levels().unwrap(), before_levels);
        assert_ne!(s.book().unwrap(), &before_book);
        // The fixed-width session records a one-entry Ψ profile.
        assert_eq!(s.width_profile().len(), 1);
        assert_eq!(s.width_profile()[0].0, 3);
        assert!(s.width_profile()[0].1 > 0.0);
    }

    #[test]
    fn non_adaptive_adapt_refreshes_from_counts_only() {
        let mut s = CodecSession::new(Method::NuqSgd, 3, 64);
        let grad = randn(640, 6);
        let mut lane = ExchangeLane::new(64);
        let mut rng = Rng::new(7);
        lane.quantize(&s, &grad, &mut rng);
        s.build_empirical_book(lane.quantized());
        let levels_before = s.final_levels().unwrap();
        lane.count_symbols(&s);
        let counts = lane.counts().to_vec();
        s.accumulate_counts(&counts);
        assert!(!s.adapt(std::iter::once(&grad[..]), &mut rng));
        s.refresh_book_from_counts();
        // Levels untouched; book exists; counts were consumed (a second
        // refresh with nothing accumulated keeps the book).
        assert_eq!(s.final_levels().unwrap(), levels_before);
        let book = s.book().unwrap().clone();
        s.refresh_book_from_counts();
        assert_eq!(s.book().unwrap(), &book);
    }

    #[test]
    fn width_switch_roundtrips_at_both_widths() {
        // A two-width session encodes/decodes correctly at whichever
        // width is active, and an explicit-width decode matches the
        // frame's width even after the active width moved on.
        let policy = BitsPolicy::parse("schedule:3@0,4@10").unwrap();
        let mut s = CodecSession::with_policy(Method::QsgdInf, &policy, 64);
        s.init_uniform_book();
        assert_eq!(s.active_bits(), Some(3));
        assert_eq!(s.widths(), vec![3, 4]);
        let grad = randn(320, 9);
        let mut lane = ExchangeLane::new(64);
        let mut rng = Rng::new(10);

        lane.quantize(&s, &grad, &mut rng);
        let bits3 = lane.encode(&s);
        lane.decode_own(&s);
        let ghat3 = lane.ghat().to_vec();

        // Re-encode the same frame bytes through a peer lane pinned at
        // width 3 while the session is active at width 4.
        let frame: Vec<u8> = lane.encoded().bytes.to_vec();
        let view = EncodedView {
            bytes: &frame,
            bits: bits3,
            n_full: 320,
            n_tail: 0,
            bucket: 64,
        };
        s.set_active_bits(4);
        assert_eq!(s.active_bits(), Some(4));
        let mut peer = ExchangeLane::new(64);
        let got = peer.decode_to_ghat_at(&s, 3, view);
        assert_eq!(got, &ghat3[..]);

        // And the session now quantizes with 8 magnitudes.
        lane.quantize(&s, &grad, &mut rng);
        s.build_empirical_book(lane.quantized());
        let bits4 = lane.encode(&s);
        assert!(bits4 > 0);
        lane.decode_own(&s);
        assert_eq!(lane.ghat().len(), grad.len());
    }

    /// QuantizerBank determinism (ISSUE 4 satellite): switching widths
    /// mid-run and back yields the same per-width levels and codebooks
    /// as a session that stayed pinned at that width the whole time,
    /// for both the Huffman and Elias coders — a width's adapted state
    /// is a function of the shared adaptation history only.
    #[test]
    fn bank_width_switching_matches_fresh_sessions_at_each_width() {
        for codec in [Codec::Huffman, Codec::Elias] {
            let policy = BitsPolicy::parse("schedule:3@0,4@5").unwrap();
            let mut switching =
                CodecSession::with_policy(Method::Alq, &policy, 64).with_codec(codec);
            let mut fixed3 = CodecSession::new(Method::Alq, 3, 64).with_codec(codec);
            let mut fixed4 = CodecSession::new(Method::Alq, 4, 64).with_codec(codec);
            for s in [&mut switching, &mut fixed3, &mut fixed4] {
                s.init_uniform_book();
            }
            // Two adaptation rounds on shared data, with a width switch
            // and switch-back in between on the banked session.
            for (round, seed) in [(0u64, 100u64), (1, 200)] {
                let grads: Vec<Vec<f32>> =
                    (0..4).map(|i| randn(640, seed + i)).collect();
                switching.set_active_bits(if round == 0 { 4 } else { 3 });
                for s in [&mut switching, &mut fixed3, &mut fixed4] {
                    let mut rng = Rng::new(777 + round);
                    assert!(s.adapt(grads.iter().map(|g| g.as_slice()), &mut rng));
                }
            }
            switching.set_active_bits(3);
            assert_eq!(
                switching.final_levels_at(3),
                fixed3.final_levels(),
                "{codec:?} width-3 levels"
            );
            assert_eq!(
                switching.final_levels_at(4),
                fixed4.final_levels(),
                "{codec:?} width-4 levels"
            );
            if codec == Codec::Huffman {
                assert_eq!(switching.book_at(3), fixed3.book(), "{codec:?} width-3 book");
                assert_eq!(switching.book_at(4), fixed4.book(), "{codec:?} width-4 book");
            } else {
                // Elias is codebook-free at every width.
                assert!(switching.book_at(3).is_none() && switching.book_at(4).is_none());
            }
        }
    }
}
