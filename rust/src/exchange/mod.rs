//! The unified gradient-exchange engine (Algorithm 1's communication
//! path, DESIGN.md §7) and its executable topology schedules.
//!
//! The paper's pipeline — quantize → entropy-encode → meter → decode →
//! aggregate → adapt levels — used to be implemented twice: inline in
//! `sim::Cluster::train` and again in `coordinator::worker`, each with
//! its own codebook lifecycle and smoothing. This module is the single
//! implementation both topologies now drive:
//!
//! * [`CodecSession`] — one method's codec state: the quantizer, the
//!   Huffman codebook lifecycle (lazy empirical build, sampled
//!   symbol-count refresh, model-based Prop. 6 books after level
//!   updates, add-δ smoothing via [`crate::quant::smooth_weights`]),
//!   and the mixture estimator behind ALQ/AMQ adaptation.
//! * [`ExchangeLane`] — one worker's reusable codec buffers (quantized
//!   symbols, bit writer, decode scratch, dequantized estimate). The
//!   hot loop is allocation-free once warm, and the sim loopback
//!   decodes straight out of the lane's writer through
//!   [`crate::quant::EncodedView`] — no per-step ciphertext clone.
//! * [`BackendCore`] — the state block every backend embeds: the codec
//!   session, the per-worker RNG fork pattern, the meter, per-hop
//!   accounting, codec wall-time, the SingleSGD lane collapse, and the
//!   generalized `std::thread::scope` lane fan-out ([`ParallelMode`],
//!   CLI `--parallel`). The determinism contract is stated once, in
//!   DESIGN.md §8, and enforced here instead of being restated per
//!   backend.
//! * [`budget`] — the dynamic bit-budget subsystem ([`BitsPolicy`],
//!   [`BitController`], [`QuantizerBank`], CLI `--bits-policy`): the
//!   per-step quantization width lives here, selected once per step in
//!   the [`ExchangeBackend::exchange`] wrapper and inherited by every
//!   topology through `core()` with zero per-backend code.
//! * [`feedback`] — error-feedback residual memory + the lazy
//!   skip-round policy ([`ErrorFeedback`], [`LazyPolicy`], CLI
//!   `--error-feedback` / `--lazy`): frames become optional per worker
//!   per step, planned once in [`BackendCore::begin_step`] and
//!   inherited by every topology through the core's sent-set.
//! * [`GradientExchange`] — the flat M-lane engine (the reference
//!   schedule). The [`topology`] subsystem provides the non-flat
//!   executable schedules — sharded leaders, hierarchical two-level
//!   trees, ring all-reduce — behind the same [`ExchangeBackend`] trait
//!   (`--topology flat|sharded:S|tree:G|ring`).
//!
//! The TCP coordinator reuses [`CodecSession`] + [`ExchangeLane`]
//! directly (its "exchange" is the leader relay), so both the simulated
//! and wire-true runtimes share quantization, coding, codebooks, and
//! adaptation by construction.
#![warn(missing_docs)]

pub mod budget;
pub mod engine;
pub mod feedback;
pub mod membership;
pub mod session;
pub mod topology;

pub use budget::{BitController, BitsPolicy, QuantizerBank, VarianceSpec};
pub use engine::{ExchangeConfig, GradientExchange, ParallelMode, PipelineMode};
pub use feedback::{ErrorFeedback, LazyPolicy, LazyWorker, SKIP_MARKER_BITS};
pub use membership::Membership;
pub use session::{CodecSession, ExchangeLane};
pub use topology::core::{BackendCore, CodecPhase};
pub use topology::{make_backend, Hop, TopologySpec};

use crate::quant::Quantizer;
use crate::sim::network::Meter;

/// A synchronous collective exchange of per-worker gradients: everything
/// between "local gradients are ready" and "the mean estimate is in
/// `agg`" (Algorithm 1 lines 5–9), with exact bit accounting.
///
/// Implementors are the flat engine ([`GradientExchange`]) and the
/// [`topology`] schedules. Each embeds a [`BackendCore`] and implements
/// only its schedule ([`ExchangeBackend::exchange`]); the shared state
/// and the determinism contract (DESIGN.md §8) come from the default
/// methods delegating to the core. `Send` so a boxed backend can train
/// inside a spawned thread (the multi-replica tests).
pub trait ExchangeBackend: Send {
    /// The embedded shared state block (session, RNG forks, meter,
    /// hops, codec time, lane collapse).
    fn core(&self) -> &BackendCore;

    /// Mutable access to the embedded shared state block.
    fn core_mut(&mut self) -> &mut BackendCore;

    /// Run the backend's schedule for one step, with the step's
    /// quantization width already selected on the session. Backends
    /// implement only this; the bit-budget machinery lives in the
    /// [`ExchangeBackend::exchange`] wrapper so every topology inherits
    /// it with zero per-backend code.
    fn run_schedule(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64;

    /// Exchange one step's gradients; writes the aggregated mean
    /// estimate into `agg` and returns the step's total encoded bits.
    /// First lets the embedded bit controller pick the step's width
    /// ([`BackendCore::begin_step`] — observation + O(1) bank switch,
    /// a no-op for `fixed:B`), then runs the schedule.
    fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.core_mut().begin_step(step, grads);
        self.run_schedule(step, grads, agg)
    }

    /// The quantization width the last exchange ran at (32 for full
    /// precision).
    fn step_width(&self) -> u32 {
        self.core().step_width()
    }

    /// Re-fit the coordinate distribution and re-optimize levels and
    /// codebook (Algorithm 1 line 4; a no-op for full precision).
    /// Identical for every backend — see [`BackendCore::adapt`].
    fn adapt(&mut self, grads: &[Vec<f32>]) {
        self.core_mut().adapt(grads)
    }

    /// The live quantizer, if this exchange quantizes at all.
    fn quantizer(&self) -> Option<&Quantizer> {
        self.core().quantizer()
    }

    /// Lanes that actually compute and communicate (1 for SingleSGD).
    fn active_workers(&self) -> usize {
        self.core().active_workers()
    }

    /// Whether this backend quantizes at all.
    fn is_quantized(&self) -> bool {
        self.core().is_quantized()
    }

    /// Force TernGrad-style c·σ clipping regardless of method (the
    /// Appendix K.2 / Fig. 14 ablation).
    fn force_clip(&mut self, c: f32) {
        self.core_mut().force_clip(c)
    }

    /// The running communication meter (total bits + modeled seconds).
    fn meter(&self) -> &Meter {
        self.core().meter()
    }

    /// Wall time spent inside quantize+encode+decode (the codec hot
    /// path).
    fn codec_seconds(&self) -> f64 {
        self.core().codec_seconds()
    }

    /// Cumulative per-phase codec time (quantize vs encode vs decode —
    /// the un-opaqued split of [`ExchangeBackend::codec_seconds`]; see
    /// [`CodecPhase`] for attribution caveats).
    fn codec_phase(&self) -> CodecPhase {
        self.core().codec_phase()
    }

    /// The final (possibly adapted) quantization level magnitudes.
    fn final_levels(&self) -> Option<Vec<f64>> {
        self.core().final_levels()
    }

    /// Per-hop accounting of the last exchange, always in schedule
    /// order (never thread-completion order). Invariant (asserted in
    /// `rust/tests/topology_parity.rs` and debug-asserted by
    /// [`BackendCore::finish_step`]): Σ hop bits equals the step total
    /// returned by [`ExchangeBackend::exchange`] — every encoded frame
    /// is charged on every hop it traverses, and nothing else is.
    fn last_hops(&self) -> &[Hop] {
        self.core().last_hops()
    }
}
