//! The unified gradient-exchange engine (Algorithm 1's communication
//! path, DESIGN.md §7).
//!
//! The paper's pipeline — quantize → entropy-encode → meter → decode →
//! aggregate → adapt levels — used to be implemented twice: inline in
//! `sim::Cluster::train` and again in `coordinator::worker`, each with
//! its own codebook lifecycle and smoothing. This module is the single
//! implementation both topologies now drive:
//!
//! * [`CodecSession`] — one method's codec state: the quantizer, the
//!   Huffman codebook lifecycle (lazy empirical build, sampled
//!   symbol-count refresh, model-based Prop. 6 books after level
//!   updates, add-δ smoothing via [`crate::quant::smooth_weights`]),
//!   and the mixture estimator behind ALQ/AMQ adaptation.
//! * [`ExchangeLane`] — one worker's reusable codec buffers (quantized
//!   symbols, bit writer, decode scratch, dequantized estimate). The
//!   hot loop is allocation-free once warm, and the sim loopback
//!   decodes straight out of the lane's writer through
//!   [`crate::quant::EncodedView`] — no per-step ciphertext clone.
//! * [`GradientExchange`] — the M-lane in-process engine: fans the
//!   lanes out across OS threads ([`ParallelMode`]) while keeping the
//!   float reduction order — and therefore every bit of the run —
//!   identical to the serial loop.
//!
//! The TCP coordinator reuses [`CodecSession`] + [`ExchangeLane`]
//! directly (its "exchange" is the leader relay), so both topologies
//! share quantization, coding, codebooks, and adaptation by
//! construction. The [`topology`] subsystem provides the non-flat
//! executable schedules — sharded leaders, hierarchical two-level
//! trees, ring all-reduce — behind the same [`ExchangeBackend`] trait
//! (`--topology flat|sharded:S|tree:G|ring`).

pub mod engine;
pub mod session;
pub mod topology;

pub use engine::{ExchangeConfig, GradientExchange, ParallelMode};
pub use session::{CodecSession, ExchangeLane};
pub use topology::{make_backend, Hop, TopologySpec};

use crate::quant::Quantizer;
use crate::sim::network::Meter;

/// A synchronous collective exchange of per-worker gradients: everything
/// between "local gradients are ready" and "the mean estimate is in
/// `agg`" (Algorithm 1 lines 5–9), with exact bit accounting.
///
/// Implementors are the flat engine ([`GradientExchange`]) and the
/// [`topology`] schedules; `Send` so a boxed backend can train inside a
/// spawned thread (the multi-replica tests).
pub trait ExchangeBackend: Send {
    /// Exchange one step's gradients; writes the aggregated mean
    /// estimate into `agg` and returns the step's total encoded bits.
    fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64;

    /// Re-fit the coordinate distribution and re-optimize levels and
    /// codebook (Algorithm 1 line 4; a no-op for full precision).
    fn adapt(&mut self, grads: &[Vec<f32>]);

    /// The live quantizer, if this exchange quantizes at all.
    fn quantizer(&self) -> Option<&Quantizer>;

    /// Lanes that actually compute and communicate (1 for SingleSGD).
    fn active_workers(&self) -> usize;

    /// Whether this backend quantizes at all.
    fn is_quantized(&self) -> bool;

    /// Force TernGrad-style c·σ clipping regardless of method (the
    /// Appendix K.2 / Fig. 14 ablation).
    fn force_clip(&mut self, c: f32);

    /// The running communication meter (total bits + modeled seconds).
    fn meter(&self) -> &Meter;

    /// Wall time spent inside quantize+encode+decode (the codec hot
    /// path).
    fn codec_seconds(&self) -> f64;

    /// The final (possibly adapted) quantization level magnitudes.
    fn final_levels(&self) -> Option<Vec<f64>>;

    /// Per-hop accounting of the last exchange. Invariant (asserted in
    /// `rust/tests/topology_parity.rs`): Σ hop bits equals the step
    /// total returned by [`ExchangeBackend::exchange`] — every encoded
    /// frame is charged on every hop it traverses, and nothing else is.
    fn last_hops(&self) -> &[Hop];
}
