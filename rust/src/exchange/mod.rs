//! The unified gradient-exchange engine (Algorithm 1's communication
//! path, DESIGN.md §7).
//!
//! The paper's pipeline — quantize → entropy-encode → meter → decode →
//! aggregate → adapt levels — used to be implemented twice: inline in
//! `sim::Cluster::train` and again in `coordinator::worker`, each with
//! its own codebook lifecycle and smoothing. This module is the single
//! implementation both topologies now drive:
//!
//! * [`CodecSession`] — one method's codec state: the quantizer, the
//!   Huffman codebook lifecycle (lazy empirical build, sampled
//!   symbol-count refresh, model-based Prop. 6 books after level
//!   updates, add-δ smoothing via [`crate::quant::smooth_weights`]),
//!   and the mixture estimator behind ALQ/AMQ adaptation.
//! * [`ExchangeLane`] — one worker's reusable codec buffers (quantized
//!   symbols, bit writer, decode scratch, dequantized estimate). The
//!   hot loop is allocation-free once warm, and the sim loopback
//!   decodes straight out of the lane's writer through
//!   [`crate::quant::EncodedView`] — no per-step ciphertext clone.
//! * [`GradientExchange`] — the M-lane in-process engine: fans the
//!   lanes out across OS threads ([`ParallelMode`]) while keeping the
//!   float reduction order — and therefore every bit of the run —
//!   identical to the serial loop.
//!
//! The TCP coordinator reuses [`CodecSession`] + [`ExchangeLane`]
//! directly (its "exchange" is the leader relay), so both topologies
//! share quantization, coding, codebooks, and adaptation by
//! construction. Future backends (sharded leaders, async exchange)
//! implement [`ExchangeBackend`].

pub mod engine;
pub mod session;

pub use engine::{ExchangeConfig, GradientExchange, ParallelMode};
pub use session::{CodecSession, ExchangeLane};

use crate::quant::Quantizer;

/// A synchronous collective exchange of per-worker gradients: everything
/// between "local gradients are ready" and "the mean estimate is in
/// `agg`" (Algorithm 1 lines 5–9), with exact bit accounting.
pub trait ExchangeBackend {
    /// Exchange one step's gradients; writes the aggregated mean
    /// estimate into `agg` and returns the step's total encoded bits.
    fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64;

    /// Re-fit the coordinate distribution and re-optimize levels and
    /// codebook (Algorithm 1 line 4; a no-op for full precision).
    fn adapt(&mut self, grads: &[Vec<f32>]);

    /// The live quantizer, if this exchange quantizes at all.
    fn quantizer(&self) -> Option<&Quantizer>;
}
