//! Ring all-reduce over encoded chunks — the analytical
//! `sim::network::Topology::Ring` formula turned into an actual,
//! executable schedule.
//!
//! # Schedule
//!
//! The parameters are split into M bucket-aligned chunks (the fp32 tail
//! rides with the last chunk). The classic 2(M−1)-stage schedule runs
//! for real, with quantized payloads on every link:
//!
//! * **reduce-scatter** (M−1 stages): at stage t, worker w quantizes its
//!   current partial sum of chunk (w−t mod M) with its own RNG stream,
//!   encodes it, and sends it to worker w+1, which decodes and
//!   accumulates. After M−1 stages worker (c−1 mod M) owns the fully
//!   reduced chunk c.
//! * **all-gather** (M−1 stages): each owner re-quantizes its reduced
//!   chunk mean once; the M final chunk frames then circle the ring,
//!   every worker forwarding what it holds, until everyone has all
//!   chunks. The simulation decodes each final frame once (the loopback
//!   convention: every replica would decode these exact bytes).
//!
//! # Hop structure
//!
//! Each of the 2(M−1) stages is one [`Hop`] (`"reduce-scatter[t]"` then
//! `"all-gather[u]"`, in stage order): its bits are the chunk frames on
//! the wire that stage (relays included — ring genuinely retransmits),
//! its seconds one parallel link round `α + max/β`. That reproduces the
//! analytical ring cost shape `2(M−1)·α + 2(M−1)/M·payload/β` from
//! measured frames instead of a formula.
//!
//! # Why ring stays serial under `--parallel`
//!
//! Unlike the flat/sharded/tree lane stages, the ring schedule is not a
//! set of independent lane tasks, so the generalized
//! [`super::core::fan_out`] does not apply:
//!
//! * the 2(M−1) stages form a strict sequential dependency chain —
//!   stage t+1 consumes the partial sums stage t produced, so only the
//!   links *within* one stage could ever run concurrently;
//! * within a stage, every transfer may mutate the shared
//!   [`super::super::CodecSession`]: the lazy empirical codebook is
//!   built from the first chunk frame encountered, and the every-10th
//!   step symbol-count sampling folds each chunk's histogram into the
//!   session — both order-sensitive session writes, not read-only lane
//!   work;
//! * each stage moves only d/M coordinates per link, so the per-stage
//!   codec work is far below the spawn-amortization threshold that
//!   makes fan-out pay elsewhere.
//!
//! The parallelism that matters for ring — all links active at once —
//! is already charged in the α-β time model: each stage's [`Hop`]
//! seconds are one parallel link round, not M serialized sends.
//!
//! The same dependency chain makes `--pipeline overlap` structurally
//! inert here: stage t+1's encode consumes the partial sums stage t's
//! wire transfer delivered, so there is no encode that could run while
//! a frame is in flight. Ring therefore reports nothing to the pipeline
//! encode ledger and hides zero seconds — `overlap` runs are still
//! bit-identical to `off` (nothing moves), they just gain no wall time.
//!
//! # Determinism
//!
//! Partial sums are re-quantized at every reduce-scatter hop, so
//! quantization noise compounds along the ring — the documented, honest
//! cost of quantized ring all-reduce. Runs are bit-deterministic per
//! seed (`rust/tests/topology_parity.rs` asserts the golden), but
//! distinct from the flat engine's fixed point.

use super::super::engine::ExchangeConfig;
use super::super::session::ExchangeLane;
use super::super::ExchangeBackend;
use super::core::BackendCore;
use super::Hop;

/// The ring all-reduce exchange backend (`--topology ring`).
pub struct RingExchange {
    core: BackendCore,
    /// Per-worker working copy of the gradient being ring-reduced.
    partials: Vec<Vec<f32>>,
    /// Scratch codec lane for the chunk in flight.
    chunk_lane: ExchangeLane,
    /// Scratch lane decoding received chunk frames.
    dec_lane: ExchangeLane,
    /// Scratch: a reduced chunk scaled to the mean.
    mean_buf: Vec<f32>,
}

impl RingExchange {
    /// Stand up the backend over the shared exchange config (the ring
    /// has no tunable arity: every active worker is a ring node).
    pub fn new(cfg: ExchangeConfig) -> Self {
        let bucket = cfg.bucket;
        let core = BackendCore::new(cfg);
        let active = core.active_workers();
        RingExchange {
            core,
            partials: vec![Vec::new(); active],
            chunk_lane: ExchangeLane::new(bucket),
            dec_lane: ExchangeLane::new(bucket),
            mean_buf: Vec::new(),
        }
    }

    /// Coordinate range of ring chunk `c` (bucket-aligned; the tail
    /// rides with the last chunk).
    fn chunk_coords(
        c: usize,
        m: usize,
        nb: usize,
        bucket: usize,
        d: usize,
    ) -> std::ops::Range<usize> {
        let lo = (c * nb / m) * bucket;
        let hi = if c + 1 == m {
            d
        } else {
            ((c + 1) * nb / m) * bucket
        };
        lo..hi
    }

    fn exchange_impl(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.partials.len();
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);
        // The ring is formed over this step's senders (active members
        // minus lazy skips — a skipped worker is not a ring node this
        // step): position i on the ring is worker `ids[i]`, and chunks
        // split the parameter vector `n` ways (not `m`), so a shrunken
        // ring stays a valid 2(n−1)-stage schedule. Error-feedback is
        // unsupported over ring — partials are re-quantized per stage,
        // so no per-worker decode error exists to settle a residual
        // against; `RunConfig::validate` rejects the combination and
        // `sim::Cluster::new` asserts it.
        let ids = self.core.sent_ids();
        let n = ids.len();
        if n == 0 {
            return self.core.finish_step(Vec::new(), 0, 0.0);
        }
        let d = agg.len();
        let net = self.core.cfg().network;
        let (session, rngs) = self.core.codec_mut();
        let bucket = session.bucket();
        let nb = d / bucket;
        let quantized = session.is_quantized();
        // Sampled symbol-count refresh on the same cadence as the other
        // topologies (every 10th step), measured on the chunk frames the
        // ring actually codes, so refresh_book_from_counts() has real
        // statistics for non-adaptive methods.
        let sample_counts = session.needs_book() && step % 10 == 0;
        let t0 = std::time::Instant::now();

        // Each worker starts from its own raw gradient; a worker's own
        // contribution never crosses a link, so it is never quantized —
        // the real ring semantics.
        for (p, g) in self.partials.iter_mut().zip(grads) {
            p.clear();
            p.extend_from_slice(g);
        }

        let mut hops: Vec<Hop> = Vec::with_capacity(2 * n.saturating_sub(1));
        let mut step_bits = 0u64;
        let mut step_seconds = 0.0f64;

        // Reduce-scatter: N−1 stages, every link active in parallel.
        for t in 0..n.saturating_sub(1) {
            let mut stage_bits = 0u64;
            let mut stage_max = 0u64;
            for (i, &w) in ids.iter().enumerate() {
                let c = (i + n - t) % n;
                let r = ids[(i + 1) % n];
                let range = Self::chunk_coords(c, n, nb, bucket, d);
                let bits = if quantized {
                    self.chunk_lane.quantize(
                        session,
                        &self.partials[w][range.clone()],
                        &mut rngs[w],
                    );
                    if session.needs_book() && session.book().is_none() {
                        session.build_empirical_book(self.chunk_lane.quantized());
                    }
                    if sample_counts {
                        self.chunk_lane.count_symbols(session);
                        session.accumulate_counts(self.chunk_lane.counts());
                    }
                    let bits = self.chunk_lane.encode(session);
                    let view = self.chunk_lane.encoded();
                    self.dec_lane.decode_to_ghat(session, view);
                    let dst = &mut self.partials[r][range.clone()];
                    for (a, &g) in dst.iter_mut().zip(self.dec_lane.ghat()) {
                        *a += g;
                    }
                    bits
                } else {
                    for i in range.clone() {
                        let v = self.partials[w][i];
                        self.partials[r][i] += v;
                    }
                    32 * range.len() as u64
                };
                stage_bits += bits;
                stage_max = stage_max.max(bits);
            }
            let seconds = net.link_time(stage_max);
            step_bits += stage_bits;
            step_seconds += seconds;
            hops.push(Hop {
                label: format!("reduce-scatter[{t}]"),
                bits: stage_bits,
                seconds,
            });
        }

        // Finalize: chunk owners scale to the mean, re-quantize once, and
        // the reduced frames circle the ring N−1 more stages.
        let inv = 1.0 / n as f32;
        let mut final_bits = 0u64;
        let mut final_max = 0u64;
        for c in 0..n {
            let o = ids[(c + n - 1) % n];
            let range = Self::chunk_coords(c, n, nb, bucket, d);
            let bits = if quantized {
                self.mean_buf.clear();
                self.mean_buf
                    .extend(self.partials[o][range.clone()].iter().map(|&x| x * inv));
                self.chunk_lane
                    .quantize(session, &self.mean_buf, &mut rngs[o]);
                // Degenerate rings (M = 1) skip reduce-scatter, so the
                // lazy book may not exist yet.
                if session.needs_book() && session.book().is_none() {
                    session.build_empirical_book(self.chunk_lane.quantized());
                }
                if sample_counts {
                    self.chunk_lane.count_symbols(session);
                    session.accumulate_counts(self.chunk_lane.counts());
                }
                let bits = self.chunk_lane.encode(session);
                let view = self.chunk_lane.encoded();
                let ghat = self.dec_lane.decode_to_ghat(session, view);
                agg[range.clone()].copy_from_slice(ghat);
                bits
            } else {
                let src = &self.partials[o];
                for i in range.clone() {
                    agg[i] = src[i] * inv;
                }
                32 * range.len() as u64
            };
            final_bits += bits;
            final_max = final_max.max(bits);
        }
        if n == 1 {
            // Degenerate single-worker ring: nothing crosses a link.
            hops.push(Hop {
                label: "loopback".to_string(),
                bits: final_bits,
                seconds: 0.0,
            });
            step_bits += final_bits;
        } else {
            for u in 0..n - 1 {
                let seconds = net.link_time(final_max);
                step_bits += final_bits;
                step_seconds += seconds;
                hops.push(Hop {
                    label: format!("all-gather[{u}]"),
                    bits: final_bits,
                    seconds,
                });
            }
        }

        if quantized {
            self.core.add_codec_seconds(t0.elapsed().as_secs_f64());
        }
        self.core.finish_step(hops, step_bits, step_seconds)
    }
}

impl ExchangeBackend for RingExchange {
    fn core(&self) -> &BackendCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BackendCore {
        &mut self.core
    }

    fn run_schedule(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.exchange_impl(step, grads, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::ParallelMode;
    use super::*;
    use crate::quant::{Codec, Method};
    use crate::sim::NetworkModel;
    use crate::util::Rng;

    fn config(method: Method, workers: usize) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: crate::exchange::BitsPolicy::Fixed(3),
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
            quantize_impl: crate::quant::QuantizeImpl::default(),
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn ring_has_2m_minus_2_stages_summing_to_step_total() {
        let d = 1024; // 16 buckets, no tail
        for m in [2usize, 4, 8] {
            let g = grads(m, d, 1);
            let mut ring = RingExchange::new(config(Method::NuqSgd, m));
            let mut agg = vec![0.0f32; d];
            let bits = ExchangeBackend::exchange(&mut ring, 0, &g, &mut agg);
            let hops = ring.last_hops();
            assert_eq!(hops.len(), 2 * (m - 1), "M={m}");
            assert_eq!(hops.iter().map(|h| h.bits).sum::<u64>(), bits, "M={m}");
            assert!(agg.iter().any(|&x| x != 0.0));
        }
    }

    #[test]
    fn fp32_ring_reduces_to_the_exact_mean_shape() {
        let d = 200; // 3 buckets + tail 8
        let m = 4;
        let g = grads(m, d, 2);
        let mut ring = RingExchange::new(config(Method::SuperSgd, m));
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut ring, 0, &g, &mut agg);
        // fp32 ring: every stage carries 32 bits/coord of the full d.
        assert_eq!(bits, 2 * (m as u64 - 1) * 32 * d as u64);
        for i in 0..d {
            let want = (g[0][i] + g[1][i] + g[2][i] + g[3][i]) / 4.0;
            assert!((agg[i] - want).abs() < 1e-5, "coord {i}: {} vs {want}", agg[i]);
        }
    }

    #[test]
    fn quantized_ring_is_deterministic_and_unbiased_enough_to_track() {
        let d = 640;
        let m = 4;
        let g = grads(m, d, 3);
        let run = || {
            let mut ring = RingExchange::new(config(Method::QsgdInf, m));
            let mut agg = vec![0.0f32; d];
            let mut total = 0u64;
            for step in 0..4 {
                total += ExchangeBackend::exchange(&mut ring, step, &g, &mut agg);
            }
            (total, agg.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
        };
        let (ba, aa) = run();
        let (bb, ab) = run();
        assert_eq!(ba, bb);
        assert_eq!(aa, ab);
        // The ring estimate tracks the true mean within quantization
        // noise: correlation with the exact mean must be clearly
        // positive.
        let mut ring = RingExchange::new(config(Method::QsgdInf, m));
        let mut agg = vec![0.0f32; d];
        ExchangeBackend::exchange(&mut ring, 0, &g, &mut agg);
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..d {
            let want = (g[0][i] + g[1][i] + g[2][i] + g[3][i]) as f64 / 4.0;
            dot += want * agg[i] as f64;
            na += want * want;
            nb += (agg[i] as f64).powi(2);
        }
        let corr = dot / (na.sqrt() * nb.sqrt()).max(1e-30);
        assert!(corr > 0.5, "ring estimate decorrelated: {corr}");
    }

    #[test]
    fn ring_schedule_ignores_parallel_mode_bit_for_bit() {
        // The ring schedule is serial by structure (see the module
        // docs); `--parallel on` must not change a single bit.
        let d = 640;
        let m = 4;
        let g = grads(m, d, 7);
        let mut cfg_p = config(Method::QsgdInf, m);
        cfg_p.parallel = ParallelMode::Parallel;
        let mut serial = RingExchange::new(config(Method::QsgdInf, m));
        let mut parallel = RingExchange::new(cfg_p);
        let mut agg_s = vec![0.0f32; d];
        let mut agg_p = vec![0.0f32; d];
        for step in 0..4 {
            let bs = ExchangeBackend::exchange(&mut serial, step, &g, &mut agg_s);
            let bp = ExchangeBackend::exchange(&mut parallel, step, &g, &mut agg_p);
            assert_eq!(bs, bp);
            let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = agg_p.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "step {step}");
        }
    }

    #[test]
    fn single_quantized_worker_ring_builds_its_book() {
        // M = 1 skips reduce-scatter; the finalize encode must still
        // bootstrap the lazy empirical codebook.
        let d = 256;
        let g = grads(1, d, 5);
        let mut ring = RingExchange::new(config(Method::NuqSgd, 1));
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut ring, 0, &g, &mut agg);
        assert!(bits > 0);
        assert_eq!(ring.last_hops().len(), 1);
    }

    #[test]
    fn single_worker_ring_is_free() {
        let d = 256;
        let g = grads(1, d, 4);
        let mut ring = RingExchange::new(config(Method::SingleSgd, 1));
        assert_eq!(ExchangeBackend::active_workers(&ring), 1);
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut ring, 0, &g, &mut agg);
        assert_eq!(bits, 32 * d as u64);
        assert_eq!(ring.meter().total_time, 0.0);
    }
}
