//! Sharded leader lanes: S parameter shards, each gathered and reduced
//! by its own leader.
//!
//! The parameters are partitioned into S bucket-aligned shards
//! ([`super::shard_buckets`]; the fp32 tail rides with the last shard).
//! Every worker quantizes its full gradient exactly as the flat engine
//! does (same per-worker RNG fork pattern, same codebook lifecycle),
//! then encodes one frame *per shard*; leader lane `s` decodes the M
//! shard-`s` frames and reduces its slice of the aggregate in worker
//! order.
//!
//! Because the wire layout is bucket-major, the S shard frames of a
//! worker concatenate to exactly the bits of its whole-frame encode, and
//! because each coordinate is still reduced in worker order 0..M with
//! the same decoded values, the aggregate — and therefore the entire
//! training run — is bit-identical to the flat engine. Sharding changes
//! *routing* (S parallel leader lanes instead of one all-to-all), not
//! payload or numerics. `rust/tests/topology_parity.rs` asserts
//! `params_hash`, per-step bits, and total bits match flat exactly.

use super::super::engine::ExchangeConfig;
use super::super::session::{CodecSession, ExchangeLane};
use super::super::ExchangeBackend;
use super::{shard_buckets, Hop};
use crate::quant::bitio::BitWriter;
use crate::quant::{EncodedView, Method, Quantizer};
use crate::sim::network::Meter;
use crate::util::Rng;

/// The sharded-leader exchange backend (`--topology sharded:S`).
pub struct ShardedExchange {
    cfg: ExchangeConfig,
    shards: usize,
    session: CodecSession,
    rngs: Vec<Rng>,
    lanes: Vec<ExchangeLane>,
    /// Scratch lane decoding shard frames on behalf of the leaders.
    dec_lane: ExchangeLane,
    /// Scratch writer for per-shard frames (one in flight at a time).
    writer: BitWriter,
    bits_scratch: Vec<u64>,
    hops: Vec<Hop>,
    meter: Meter,
    codec_seconds: f64,
}

impl ShardedExchange {
    pub fn new(cfg: ExchangeConfig, shards: usize) -> Self {
        assert!(shards >= 1, "sharded topology needs at least one shard");
        let mut seeder = Rng::new(cfg.seed);
        // Identical fork pattern to the flat engine: the determinism
        // contract that makes sharded ≡ flat bit-for-bit.
        let rngs: Vec<Rng> = (0..cfg.workers).map(|w| seeder.fork(w as u64)).collect();
        let session = CodecSession::new(cfg.method, cfg.bits, cfg.bucket).with_codec(cfg.codec);
        let active = if cfg.method == Method::SingleSgd {
            1
        } else {
            cfg.workers
        };
        let lanes = (0..active).map(|_| ExchangeLane::new(cfg.bucket)).collect();
        ShardedExchange {
            shards,
            session,
            rngs,
            lanes,
            dec_lane: ExchangeLane::new(cfg.bucket),
            writer: BitWriter::new(),
            bits_scratch: vec![0; active],
            hops: Vec::new(),
            meter: Meter::default(),
            codec_seconds: 0.0,
            cfg,
        }
    }

    /// Encoded bits per worker for the last exchange (Σ over its shard
    /// frames — equal to the flat engine's whole-frame figure).
    pub fn bits_per_worker(&self) -> &[u64] {
        &self.bits_scratch
    }

    fn exchange_impl(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.lanes.len();
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);
        let net = self.cfg.network;

        if !self.session.is_quantized() {
            // Full precision: 32·d per worker, reduced in worker order
            // exactly as the flat engine does; shards split the fp32
            // payload coordinate-evenly for the hop accounting.
            let d = agg.len();
            let mut step_bits = 0u64;
            for (w, grad) in grads.iter().take(m).enumerate() {
                self.bits_scratch[w] = 32 * grad.len() as u64;
                step_bits += self.bits_scratch[w];
                for (a, &g) in agg.iter_mut().zip(grad) {
                    *a += g / m as f32;
                }
            }
            self.hops.clear();
            let mut step_seconds = 0.0f64;
            for s in 0..self.shards {
                let lo = s * d / self.shards;
                let hi = (s + 1) * d / self.shards;
                let per_worker = 32 * (hi - lo) as u64;
                let hop_bits = per_worker * m as u64;
                let seconds = net.fan_time(m.saturating_sub(1), per_worker)
                    + net.fan_time(m.saturating_sub(1), hop_bits);
                step_seconds = step_seconds.max(seconds);
                self.hops.push(Hop {
                    label: format!("shard{s}"),
                    bits: hop_bits,
                    seconds,
                });
            }
            self.meter.record_raw(step_bits, step_seconds);
            return step_bits;
        }

        let t0 = std::time::Instant::now();
        // Codebook lifecycle identical to the flat engine: lazy empirical
        // book from lane 0's first quantization, sampled symbol counts
        // every 10th step.
        let mut lane0_quantized = false;
        if self.session.needs_book() && self.session.book().is_none() {
            self.lanes[0].quantize(&self.session, &grads[0], &mut self.rngs[0]);
            self.session.build_empirical_book(self.lanes[0].quantized());
            lane0_quantized = true;
        }
        let sample_counts = self.session.needs_book() && step % 10 == 0;

        for (w, ((lane, rng), grad)) in self
            .lanes
            .iter_mut()
            .zip(self.rngs.iter_mut())
            .zip(grads)
            .enumerate()
        {
            if !(w == 0 && lane0_quantized) {
                lane.quantize(&self.session, grad, rng);
            }
            if sample_counts {
                lane.count_symbols(&self.session);
            }
        }
        if sample_counts {
            // Same worker-order f64 accumulation as the flat engine, so
            // refreshed codebooks stay bit-identical across topologies.
            for w in 0..m {
                self.session.accumulate_counts(self.lanes[w].counts());
            }
        }

        let bucket = self.session.bucket();
        let nb = self.lanes[0].quantized().norms.len();
        let inv = 1.0 / m as f32;
        for b in self.bits_scratch.iter_mut() {
            *b = 0;
        }
        let mut step_bits = 0u64;
        let mut step_seconds = 0.0f64;
        self.hops.clear();

        for s in 0..self.shards {
            let buckets = shard_buckets(nb, self.shards, s);
            let include_tail = s + 1 == self.shards;
            let coord_lo = buckets.start * bucket;
            let n_full = buckets.len() * bucket;
            let mut hop_bits = 0u64;
            let mut max_bits = 0u64;
            for w in 0..m {
                self.writer.clear();
                let bits = self.lanes[w].encode_shard_into(
                    &self.session,
                    buckets.clone(),
                    include_tail,
                    &mut self.writer,
                );
                self.writer.finish_ref();
                let n_tail = if include_tail {
                    self.lanes[w].tail_len()
                } else {
                    0
                };
                let view = EncodedView {
                    bytes: self.writer.bytes(),
                    bits,
                    n_full,
                    n_tail,
                    bucket,
                };
                // Leader lane s decodes and reduces its shard, still in
                // worker order — per-coordinate float ops identical to
                // the flat reduction.
                let ghat = self.dec_lane.decode_to_ghat(&self.session, view);
                for (a, &g) in agg[coord_lo..coord_lo + n_full + n_tail]
                    .iter_mut()
                    .zip(ghat)
                {
                    *a += g * inv;
                }
                self.bits_scratch[w] += bits;
                hop_bits += bits;
                max_bits = max_bits.max(bits);
            }
            step_bits += hop_bits;
            // Leader s: serialized fan-in of M−1 shard frames, then a
            // serialized fan-out relaying the shard's frames down. The S
            // leader lanes run in parallel → the step pays the slowest.
            let seconds = net.fan_time(m.saturating_sub(1), max_bits)
                + net.fan_time(m.saturating_sub(1), hop_bits);
            step_seconds = step_seconds.max(seconds);
            self.hops.push(Hop {
                label: format!("shard{s}"),
                bits: hop_bits,
                seconds,
            });
        }

        self.codec_seconds += t0.elapsed().as_secs_f64();
        self.meter.record_raw(step_bits, step_seconds);
        step_bits
    }
}

impl ExchangeBackend for ShardedExchange {
    fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.exchange_impl(step, grads, agg)
    }

    fn adapt(&mut self, grads: &[Vec<f32>]) {
        if !self.session.is_quantized() {
            return;
        }
        // Same stream the flat engine draws its subsample seed from.
        let mut rng = self.rngs[0].fork(0xE57);
        if !self.session.adapt(grads.iter().map(|g| g.as_slice()), &mut rng) {
            self.session.refresh_book_from_counts();
        }
    }

    fn quantizer(&self) -> Option<&Quantizer> {
        self.session.quantizer()
    }

    fn active_workers(&self) -> usize {
        self.lanes.len()
    }

    fn is_quantized(&self) -> bool {
        self.session.is_quantized()
    }

    fn force_clip(&mut self, c: f32) {
        self.session.force_clip(c);
    }

    fn meter(&self) -> &Meter {
        &self.meter
    }

    fn codec_seconds(&self) -> f64 {
        self.codec_seconds
    }

    fn final_levels(&self) -> Option<Vec<f64>> {
        self.session.final_levels()
    }

    fn last_hops(&self) -> &[Hop] {
        &self.hops
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::{GradientExchange, ParallelMode};
    use super::*;
    use crate::quant::Codec;
    use crate::sim::NetworkModel;

    fn config(method: Method, workers: usize) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: 3,
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn sharded_aggregate_and_bits_match_flat_exactly() {
        let d = 1000; // 15 buckets + tail of 40
        let g = grads(4, d, 1);
        for shards in [1usize, 2, 3, 5] {
            let mut flat = GradientExchange::new(config(Method::Alq, 4));
            let mut shrd = ShardedExchange::new(config(Method::Alq, 4), shards);
            let mut agg_f = vec![0.0f32; d];
            let mut agg_s = vec![0.0f32; d];
            for step in 0..12 {
                if step == 5 {
                    ExchangeBackend::adapt(&mut flat, &g);
                    shrd.adapt(&g);
                }
                let bf = flat.exchange(step, &g, &mut agg_f);
                let bs = ExchangeBackend::exchange(&mut shrd, step, &g, &mut agg_s);
                assert_eq!(bf, bs, "shards={shards} step={step} bits");
                assert_eq!(flat.bits_per_worker(), shrd.bits_per_worker());
                let fb: Vec<u32> = agg_f.iter().map(|x| x.to_bits()).collect();
                let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
                assert_eq!(fb, sb, "shards={shards} step={step} aggregate");
            }
            assert_eq!(
                ExchangeBackend::final_levels(&shrd),
                flat.final_levels(),
                "shards={shards}"
            );
            assert_eq!(shrd.meter().total_bits, flat.meter().total_bits);
        }
    }

    #[test]
    fn hop_bits_sum_to_step_total() {
        let d = 2000;
        let g = grads(4, d, 2);
        let mut shrd = ShardedExchange::new(config(Method::NuqSgd, 4), 3);
        let mut agg = vec![0.0f32; d];
        for step in 0..5 {
            let bits = ExchangeBackend::exchange(&mut shrd, step, &g, &mut agg);
            let hop_sum: u64 = shrd.last_hops().iter().map(|h| h.bits).sum();
            assert_eq!(hop_sum, bits, "step {step}");
            assert_eq!(shrd.last_hops().len(), 3);
        }
    }

    #[test]
    fn full_precision_sharded_matches_flat_mean() {
        let d = 333;
        let g = grads(3, d, 3);
        let mut flat = GradientExchange::new(config(Method::SuperSgd, 3));
        let mut shrd = ShardedExchange::new(config(Method::SuperSgd, 3), 2);
        let mut agg_f = vec![0.0f32; d];
        let mut agg_s = vec![0.0f32; d];
        let bf = flat.exchange(0, &g, &mut agg_f);
        let bs = ExchangeBackend::exchange(&mut shrd, 0, &g, &mut agg_s);
        assert_eq!(bf, bs);
        assert_eq!(bs, 3 * 32 * d as u64);
        for i in 0..d {
            assert_eq!(agg_f[i].to_bits(), agg_s[i].to_bits());
        }
        let hop_sum: u64 = shrd.last_hops().iter().map(|h| h.bits).sum();
        assert_eq!(hop_sum, bs);
    }
}
