//! Sharded leader lanes: S parameter shards, each gathered and reduced
//! by its own leader.
//!
//! # Schedule
//!
//! The parameters are partitioned into S bucket-aligned shards
//! ([`super::shard_buckets`]; the fp32 tail rides with the last shard).
//! Every worker quantizes its full gradient exactly as the flat engine
//! does (the shared member stage,
//! [`super::core::BackendCore::member_stage`] — same per-worker RNG fork
//! pattern, same codebook lifecycle), then encodes one frame *per
//! shard*; leader lane `s` decodes the M shard-`s` frames and reduces
//! its slice of the aggregate in worker order.
//!
//! # Hop structure
//!
//! One [`Hop`] per shard (`"shard0"`, `"shard1"`, …, in shard order): a
//! serialized fan-in of M−1 shard frames into the leader plus a
//! serialized fan-out relaying them down. The S leader lanes run
//! concurrently, so the step's modeled time is the slowest shard's, and
//! Σ shard-hop bits equals the flat engine's step total exactly.
//!
//! # Determinism
//!
//! Because the wire layout is bucket-major, the S shard frames of a
//! worker concatenate to exactly the bits of its whole-frame encode, and
//! because each coordinate is still reduced in worker order 0..M with
//! the same decoded values, the aggregate — and therefore the entire
//! training run — is bit-identical to the flat engine. Sharding changes
//! *routing* (S parallel leader lanes instead of one all-to-all), not
//! payload or numerics. Under `--parallel`, the member stage fans out
//! across worker lanes and the S shard-leader lanes fan out across
//! threads ([`super::core::fan_out`]); each shard reduces a disjoint
//! slice of the aggregate in worker order, so parallel and serial
//! schedules are bit-identical too. `rust/tests/topology_parity.rs`
//! asserts `params_hash`, per-step bits, and total bits match flat
//! exactly in both modes.

use super::super::engine::ExchangeConfig;
use super::super::ExchangeBackend;
use super::core::{fan_out, BackendCore};
use super::{shard_buckets, Hop};
use crate::quant::bitio::BitWriter;
use crate::quant::EncodedView;

/// Per-shard leader scratch: a frame writer and a decode lane, owned by
/// exactly one shard lane so the S lanes can run on separate threads.
struct ShardScratch {
    writer: BitWriter,
    dec: crate::exchange::ExchangeLane,
}

/// The sharded-leader exchange backend (`--topology sharded:S`).
pub struct ShardedExchange {
    core: BackendCore,
    shards: usize,
    lanes: Vec<crate::exchange::ExchangeLane>,
    /// One scratch per shard so the shard-leader lanes can fan out.
    scratch: Vec<ShardScratch>,
    bits_scratch: Vec<u64>,
}

impl ShardedExchange {
    /// Stand up the backend with `shards` leader lanes over the shared
    /// exchange config.
    pub fn new(cfg: ExchangeConfig, shards: usize) -> Self {
        assert!(shards >= 1, "sharded topology needs at least one shard");
        let bucket = cfg.bucket;
        // Identical core to the flat engine (RNG fork pattern, codebook
        // lifecycle): the determinism contract that makes sharded ≡ flat
        // bit-for-bit.
        let core = BackendCore::new(cfg);
        let lanes = core.new_lanes();
        let bits_scratch = vec![0; lanes.len()];
        let scratch = (0..shards)
            .map(|_| ShardScratch {
                writer: BitWriter::new(),
                dec: crate::exchange::ExchangeLane::new(bucket),
            })
            .collect();
        ShardedExchange {
            core,
            shards,
            lanes,
            scratch,
            bits_scratch,
        }
    }

    /// Encoded bits per worker for the last exchange (Σ over its shard
    /// frames — equal to the flat engine's whole-frame figure).
    pub fn bits_per_worker(&self) -> &[u64] {
        &self.bits_scratch
    }

    fn exchange_impl(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.lanes.len();
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);
        let net = self.core.cfg().network;
        let shards = self.shards;
        // The step's frame plan: 0..M at full strength with feedback and
        // lazy off (byte-identical to the fixed-membership schedule), a
        // subset under churn or skip rounds. Skip markers are charged by
        // `finish_step`.
        let ids = self.core.sent_ids();
        let n = ids.len();
        if n == 0 {
            return self.core.finish_step(Vec::new(), 0, 0.0);
        }
        self.bits_scratch.iter_mut().for_each(|b| *b = 0);

        if !self.core.is_quantized() {
            // Full precision: 32·d per sending worker, reduced in worker
            // order exactly as the flat engine does; shards split the
            // fp32 payload coordinate-evenly for the hop accounting.
            let d = agg.len();
            let mut step_bits = 0u64;
            for &w in &ids {
                let grad = self.core.outgoing(w, grads);
                self.bits_scratch[w] = 32 * grad.len() as u64;
                step_bits += self.bits_scratch[w];
                for (a, &g) in agg.iter_mut().zip(grad) {
                    *a += g / n as f32;
                }
            }
            let mut hops = Vec::with_capacity(shards);
            let mut step_seconds = 0.0f64;
            for s in 0..shards {
                let lo = s * d / shards;
                let hi = (s + 1) * d / shards;
                let per_worker = 32 * (hi - lo) as u64;
                let hop_bits = per_worker * n as u64;
                let seconds = net.fan_time(n.saturating_sub(1), per_worker)
                    + net.fan_time(n.saturating_sub(1), hop_bits);
                step_seconds = step_seconds.max(seconds);
                hops.push(Hop {
                    label: format!("shard{s}"),
                    bits: hop_bits,
                    seconds,
                });
            }
            return self.core.finish_step(hops, step_bits, step_seconds);
        }

        let t0 = std::time::Instant::now();
        // Member stage (quantize + sampled counts, no whole-frame
        // encode): identical to the flat engine by construction.
        self.core.member_stage(&mut self.lanes, grads, step, false);

        let bucket = self.core.session().bucket();
        let nb = self.lanes[ids[0]].quantized().norms.len();
        let d = agg.len();
        let inv = 1.0 / n as f32;

        // Split the aggregate into the S disjoint shard slices, in
        // shard (schedule) order.
        let mut parts: Vec<&mut [f32]> = Vec::with_capacity(shards);
        {
            let mut rest: &mut [f32] = agg;
            let mut consumed = 0usize;
            for s in 0..shards {
                let buckets = shard_buckets(nb, shards, s);
                let hi = if s + 1 == shards {
                    d
                } else {
                    buckets.end * bucket
                };
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(hi - consumed);
                parts.push(head);
                rest = tail;
                consumed = hi;
            }
        }

        // Shard-leader lanes: each encodes, decodes, and reduces its own
        // disjoint slice — embarrassingly parallel, reduction still in
        // worker order 0..M per coordinate inside each shard.
        let par = self.core.use_parallel(shards, (m * d) / shards);
        let session = self.core.session();
        let lanes = &self.lanes;
        let mut tasks: Vec<(&mut ShardScratch, &mut [f32])> =
            self.scratch.iter_mut().zip(parts).collect();
        let results = fan_out(par, &mut tasks, |s, task| {
            let (scratch, out) = task;
            let buckets = shard_buckets(nb, shards, s);
            let include_tail = s + 1 == shards;
            let n_full = buckets.len() * bucket;
            let mut per_worker = vec![0u64; m];
            let mut hop_bits = 0u64;
            let mut max_bits = 0u64;
            let mut encode_seconds = 0.0f64;
            for &w in &ids {
                let lane = &lanes[w];
                scratch.writer.clear();
                let t_enc = std::time::Instant::now();
                let bits = lane.encode_shard_into(
                    session,
                    buckets.clone(),
                    include_tail,
                    &mut scratch.writer,
                );
                scratch.writer.finish_ref();
                encode_seconds += t_enc.elapsed().as_secs_f64();
                let n_tail = if include_tail { lane.tail_len() } else { 0 };
                let view = EncodedView {
                    bytes: scratch.writer.bytes(),
                    bits,
                    n_full,
                    n_tail,
                    bucket,
                };
                // Leader lane s decodes and reduces its shard, still in
                // worker order — per-coordinate float ops identical to
                // the flat reduction.
                let ghat = scratch.dec.decode_to_ghat(session, view);
                for (a, &g) in out.iter_mut().zip(ghat) {
                    *a += g * inv;
                }
                per_worker[w] = bits;
                hop_bits += bits;
                max_bits = max_bits.max(bits);
            }
            (per_worker, hop_bits, max_bits, encode_seconds)
        });
        drop(tasks);

        // Fold the per-shard results back in shard (schedule) order —
        // hop records never depend on thread-completion order.
        let mut step_bits = 0u64;
        let mut step_seconds = 0.0f64;
        let mut encode_total = 0.0f64;
        let mut hops = Vec::with_capacity(shards);
        for (s, (per_worker, hop_bits, max_bits, encode_seconds)) in
            results.into_iter().enumerate()
        {
            encode_total += encode_seconds;
            for (acc, bits) in self.bits_scratch.iter_mut().zip(per_worker) {
                *acc += bits;
            }
            step_bits += hop_bits;
            // Leader s: serialized fan-in of N−1 shard frames (N active
            // members), then a serialized fan-out relaying the shard's
            // frames down. The S leader lanes run in parallel → the step
            // pays the slowest.
            let seconds = net.fan_time(n.saturating_sub(1), max_bits)
                + net.fan_time(n.saturating_sub(1), hop_bits);
            step_seconds = step_seconds.max(seconds);
            hops.push(Hop {
                label: format!("shard{s}"),
                bits: hop_bits,
                seconds,
            });
        }

        self.core.add_codec_seconds(t0.elapsed().as_secs_f64());
        // The per-shard encode runs outside the member stage, so report
        // it to the pipeline ledger: under `--pipeline overlap`, frame k
        // sits on the wire while bucket-range k+1 encodes, and this is
        // the wall time the hidden-communication credit is bounded by.
        self.core.note_encode_seconds(encode_total);
        self.core.finish_step(hops, step_bits, step_seconds)
    }
}

impl ExchangeBackend for ShardedExchange {
    fn core(&self) -> &BackendCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BackendCore {
        &mut self.core
    }

    fn run_schedule(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.exchange_impl(step, grads, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::{GradientExchange, ParallelMode};
    use super::*;
    use crate::quant::{Codec, Method};
    use crate::sim::NetworkModel;
    use crate::util::Rng;

    fn config(method: Method, workers: usize) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: crate::exchange::BitsPolicy::Fixed(3),
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
            quantize_impl: crate::quant::QuantizeImpl::default(),
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn sharded_aggregate_and_bits_match_flat_exactly() {
        let d = 1000; // 15 buckets + tail of 40
        let g = grads(4, d, 1);
        for shards in [1usize, 2, 3, 5] {
            let mut flat = GradientExchange::new(config(Method::Alq, 4));
            let mut shrd = ShardedExchange::new(config(Method::Alq, 4), shards);
            let mut agg_f = vec![0.0f32; d];
            let mut agg_s = vec![0.0f32; d];
            for step in 0..12 {
                if step == 5 {
                    ExchangeBackend::adapt(&mut flat, &g);
                    shrd.adapt(&g);
                }
                let bf = flat.exchange(step, &g, &mut agg_f);
                let bs = ExchangeBackend::exchange(&mut shrd, step, &g, &mut agg_s);
                assert_eq!(bf, bs, "shards={shards} step={step} bits");
                assert_eq!(flat.bits_per_worker(), shrd.bits_per_worker());
                let fb: Vec<u32> = agg_f.iter().map(|x| x.to_bits()).collect();
                let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
                assert_eq!(fb, sb, "shards={shards} step={step} aggregate");
            }
            assert_eq!(
                ExchangeBackend::final_levels(&shrd),
                flat.final_levels(),
                "shards={shards}"
            );
            assert_eq!(shrd.meter().total_bits, flat.meter().total_bits);
        }
    }

    #[test]
    fn parallel_shard_lanes_match_serial_bit_for_bit() {
        let d = 1000;
        let g = grads(4, d, 6);
        let mut cfg_p = config(Method::Alq, 4);
        cfg_p.parallel = ParallelMode::Parallel;
        let mut serial = ShardedExchange::new(config(Method::Alq, 4), 3);
        let mut parallel = ShardedExchange::new(cfg_p, 3);
        let mut agg_s = vec![0.0f32; d];
        let mut agg_p = vec![0.0f32; d];
        for step in 0..12 {
            if step == 5 {
                serial.adapt(&g);
                parallel.adapt(&g);
            }
            let bs = ExchangeBackend::exchange(&mut serial, step, &g, &mut agg_s);
            let bp = ExchangeBackend::exchange(&mut parallel, step, &g, &mut agg_p);
            assert_eq!(bs, bp, "step {step} bits");
            assert_eq!(serial.bits_per_worker(), parallel.bits_per_worker());
            let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = agg_p.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "step {step} aggregate");
            // Hop records stay in shard (schedule) order under the
            // parallel fan-out.
            let labels: Vec<&str> = parallel.last_hops().iter().map(|h| h.label.as_str()).collect();
            assert_eq!(labels, ["shard0", "shard1", "shard2"]);
        }
        assert_eq!(
            serial.meter().total_bits,
            parallel.meter().total_bits
        );
    }

    #[test]
    fn hop_bits_sum_to_step_total() {
        let d = 2000;
        let g = grads(4, d, 2);
        let mut shrd = ShardedExchange::new(config(Method::NuqSgd, 4), 3);
        let mut agg = vec![0.0f32; d];
        for step in 0..5 {
            let bits = ExchangeBackend::exchange(&mut shrd, step, &g, &mut agg);
            let hop_sum: u64 = shrd.last_hops().iter().map(|h| h.bits).sum();
            assert_eq!(hop_sum, bits, "step {step}");
            assert_eq!(shrd.last_hops().len(), 3);
        }
    }

    #[test]
    fn full_precision_sharded_matches_flat_mean() {
        let d = 333;
        let g = grads(3, d, 3);
        let mut flat = GradientExchange::new(config(Method::SuperSgd, 3));
        let mut shrd = ShardedExchange::new(config(Method::SuperSgd, 3), 2);
        let mut agg_f = vec![0.0f32; d];
        let mut agg_s = vec![0.0f32; d];
        let bf = flat.exchange(0, &g, &mut agg_f);
        let bs = ExchangeBackend::exchange(&mut shrd, 0, &g, &mut agg_s);
        assert_eq!(bf, bs);
        assert_eq!(bs, 3 * 32 * d as u64);
        for i in 0..d {
            assert_eq!(agg_f[i].to_bits(), agg_s[i].to_bits());
        }
        let hop_sum: u64 = shrd.last_hops().iter().map(|h| h.bits).sum();
        assert_eq!(hop_sum, bs);
    }
}
