//! Hierarchical two-level tree: G groups reduce locally, group leaders
//! exchange encoded partial aggregates, then broadcast down.
//!
//! # Schedule
//!
//! Quantized payloads at every hop, per-hop bit metering:
//!
//! 1. **up** — every worker quantizes + encodes its gradient (the shared
//!    member stage, [`super::core::BackendCore::member_stage`] —
//!    identical per-worker RNG fork pattern and codebook lifecycle as
//!    the flat engine); each group leader decodes its members' frames
//!    and forms the group's partial mean contribution `Σ ĝ_w / M`.
//! 2. **xchg** — each leader *re-quantizes* its partial aggregate with
//!    its own RNG stream, encodes it, and the G leaders exchange these
//!    frames all-to-all.
//! 3. **down** — the G leader frames are broadcast to every member; all
//!    workers decode them and sum the G partials into the aggregate.
//!
//! # Hop structure
//!
//! Three [`Hop`]s in schedule order: `"up"` (M member frames),
//! `"leader-xchg"` (G re-quantized partial frames), `"down"` (the same G
//! frames broadcast). The top level carries G frames instead of M — the
//! schedule the QSGD lineage prescribes once M outgrows one switch.
//!
//! # Determinism
//!
//! The up-level re-quantization necessarily changes the reduction
//! numerics relative to the flat all-to-all (Σ_g Q(Σ_{w∈g} ĝ_w/M)
//! instead of Σ_w ĝ_w/M), so the tree's determinism contract is a
//! per-seed `params_hash` golden — bit-identical across runs and
//! replicas, but a *different* fixed point than flat. Under
//! `--parallel`, the member stage fans out across worker lanes and the
//! G per-group reductions fan out across threads
//! ([`super::core::fan_out`]): each group reduces its members in member
//! order on its own thread and quantizes with its own leader stream,
//! and the down-level sum runs on the calling thread in group order —
//! so parallel and serial schedules are bit-identical
//! (`rust/tests/topology_parity.rs`).

use super::super::engine::ExchangeConfig;
use super::super::session::ExchangeLane;
use super::super::ExchangeBackend;
use super::core::{disjoint_mut, fan_out, BackendCore};
use super::{group_members, Hop};
use crate::util::Rng;

/// The two-level tree exchange backend (`--topology tree:G`).
pub struct HierarchicalExchange {
    core: BackendCore,
    groups: usize,
    lanes: Vec<ExchangeLane>,
    /// One codec lane per group leader (partial-aggregate frames).
    leader_lanes: Vec<ExchangeLane>,
    /// One partial-mean buffer per group so group reductions can fan
    /// out across threads.
    partials: Vec<Vec<f32>>,
}

impl HierarchicalExchange {
    /// Stand up the backend with `groups` leader groups over the shared
    /// exchange config.
    pub fn new(cfg: ExchangeConfig, groups: usize) -> Self {
        assert!(groups >= 1, "tree topology needs at least one group");
        let bucket = cfg.bucket;
        let core = BackendCore::new(cfg);
        let active = core.active_workers();
        // A group needs at least one member; SingleSGD collapses to one
        // lane, so clamp rather than reject (config validation already
        // rejects tree:G > workers at the CLI).
        let groups = groups.min(active);
        let lanes = core.new_lanes();
        let leader_lanes = (0..groups).map(|_| ExchangeLane::new(bucket)).collect();
        HierarchicalExchange {
            core,
            groups,
            lanes,
            leader_lanes,
            partials: vec![Vec::new(); groups],
        }
    }

    fn exchange_impl(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.lanes.len();
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);
        let d = agg.len();
        let net = self.core.cfg().network;
        let groups = self.groups;
        // The step's frame plan (active members minus lazy skips),
        // projected onto the *fixed* group partition over the configured
        // lanes: membership and skip rounds change who participates in
        // each group, never the partition itself. Groups whose members
        // all dropped or skipped contribute no leader frame; skip
        // markers are charged by `finish_step`.
        let ids = self.core.sent_ids();
        let n = ids.len();
        if n == 0 {
            return self.core.finish_step(Vec::new(), 0, 0.0);
        }
        let group_ids: Vec<Vec<usize>> = (0..groups)
            .map(|g| {
                let r = group_members(m, groups, g);
                ids.iter().copied().filter(|w| r.contains(w)).collect()
            })
            .collect();
        let present: Vec<usize> = (0..groups).filter(|&g| !group_ids[g].is_empty()).collect();
        let inv = 1.0 / n as f32;
        for p in self.partials.iter_mut() {
            if p.len() != d {
                p.resize(d, 0.0);
            }
        }

        if !self.core.is_quantized() {
            // Full precision: raw fp32 frames up, fp32 partials across
            // and down. The two-level association (Σ_g (Σ_{w∈g} g/N))
            // differs from flat's flat sum — the same schedule change the
            // quantized path makes, without codec noise.
            for &g in &present {
                self.partials[0].fill(0.0);
                for &w in &group_ids[g] {
                    let grad = self.core.outgoing(w, grads);
                    for (p, &x) in self.partials[0].iter_mut().zip(grad) {
                        *p += x * inv;
                    }
                }
                for (a, &p) in agg.iter_mut().zip(&self.partials[0]) {
                    *a += p;
                }
            }
            let up_bits = 32 * d as u64 * n as u64;
            let lead_bits = 32 * d as u64 * present.len() as u64;
            let (up_s, xchg_s, down_s) = self.fp_hop_seconds(m, groups, 32 * d as u64, lead_bits);
            let step_bits = up_bits + 2 * lead_bits;
            return self.core.finish_step(
                level_hops(up_bits, lead_bits, up_s, xchg_s, down_s),
                step_bits,
                up_s + xchg_s + down_s,
            );
        }

        let t0 = std::time::Instant::now();
        // 1. up — every member quantizes, encodes, and (loopback-)decodes
        // its own frame via the shared member stage; the codebook
        // lifecycle is identical to the flat engine.
        self.core.member_stage(&mut self.lanes, grads, step, true);
        let up_bits: u64 = ids.iter().map(|&w| self.lanes[w].bits()).sum();

        // 2. xchg — leaders re-quantize group partials and exchange.
        // Each *present* group's leader is its first active member (the
        // fixed `members.start` at full strength). Each group owns its
        // partial buffer, leader lane, and leader RNG stream, so the
        // reductions fan out across threads; the per-group member
        // reduction stays in member order.
        let par = self.core.use_parallel(present.len(), d);
        let (session, rngs) = self.core.session_and_rngs_mut();
        let lanes = &self.lanes;
        let leader_rngs = disjoint_mut(rngs, present.iter().map(|&g| group_ids[g][0]));
        let partials = disjoint_mut(&mut self.partials, present.iter().copied());
        let leader_lanes = disjoint_mut(&mut self.leader_lanes, present.iter().copied());
        let mut tasks: Vec<(&mut Vec<f32>, &mut ExchangeLane, &mut Rng, &[usize])> = partials
            .into_iter()
            .zip(leader_lanes)
            .zip(leader_rngs)
            .zip(present.iter())
            .map(|(((partial, lane), rng), &g)| (partial, lane, rng, group_ids[g].as_slice()))
            .collect();
        let results = fan_out(par, &mut tasks, |_g, task| {
            let (partial, lane, rng, members) = task;
            partial.fill(0.0);
            let mut max_member_bits = 0u64;
            for &w in members.iter() {
                let member = &lanes[w];
                max_member_bits = max_member_bits.max(member.bits());
                for (p, &x) in partial.iter_mut().zip(member.ghat()) {
                    *p += x * inv;
                }
            }
            // The leader's own RNG stream draws the partial's
            // quantization noise; only the ciphertext is shared.
            lane.quantize(session, &partial[..], rng);
            let t_enc = std::time::Instant::now();
            let bits = lane.encode(session);
            let encode_seconds = t_enc.elapsed().as_secs_f64();
            lane.decode_own(session);
            (bits, max_member_bits, members.len(), encode_seconds)
        });
        drop(tasks);

        // Fold results back in group (schedule) order. The leader
        // re-encode runs outside the member stage, so its wall time is
        // reported to the pipeline ledger separately (what `--pipeline
        // overlap` can hide wire seconds behind).
        let mut lead_bits = 0u64;
        let mut max_lead_bits = 0u64;
        let mut up_seconds = 0.0f64;
        let mut leader_encode_seconds = 0.0f64;
        for &(bits, max_member_bits, n_members, encode_seconds) in &results {
            lead_bits += bits;
            max_lead_bits = max_lead_bits.max(bits);
            leader_encode_seconds += encode_seconds;
            up_seconds =
                up_seconds.max(net.fan_time(n_members.saturating_sub(1), max_member_bits));
        }
        self.core.note_encode_seconds(leader_encode_seconds);

        // 3. down — every worker sums the decoded leader partials of the
        // present groups in group order on the calling thread; the sim
        // performs the reduction once (all replicas would compute
        // exactly this sum from exactly these frames).
        for &g in &present {
            for (a, &x) in agg.iter_mut().zip(self.leader_lanes[g].ghat()) {
                *a += x;
            }
        }

        let xchg_seconds = net.fan_time(present.len().saturating_sub(1), max_lead_bits);
        let mut down_seconds = 0.0f64;
        for &g in &present {
            down_seconds =
                down_seconds.max(net.fan_time(group_ids[g].len().saturating_sub(1), lead_bits));
        }
        let step_bits = up_bits + 2 * lead_bits;
        self.core.add_codec_seconds(t0.elapsed().as_secs_f64());
        self.core.finish_step(
            level_hops(up_bits, lead_bits, up_seconds, xchg_seconds, down_seconds),
            step_bits,
            up_seconds + xchg_seconds + down_seconds,
        )
    }

    /// Analytical hop times for the fp32 path (same shapes as the
    /// quantized path, uniform frame sizes).
    fn fp_hop_seconds(
        &self,
        m: usize,
        groups: usize,
        frame_bits: u64,
        lead_total: u64,
    ) -> (f64, f64, f64) {
        let net = &self.core.cfg().network;
        let mut up = 0.0f64;
        let mut down = 0.0f64;
        for g in 0..groups {
            let members = group_members(m, groups, g);
            up = up.max(net.fan_time(members.len().saturating_sub(1), frame_bits));
            down = down.max(net.fan_time(members.len().saturating_sub(1), lead_total));
        }
        let xchg = net.fan_time(groups.saturating_sub(1), frame_bits);
        (up, xchg, down)
    }
}

/// The tree's three hops in schedule order: up, leader-xchg, down.
fn level_hops(up: u64, lead: u64, up_s: f64, xchg_s: f64, down_s: f64) -> Vec<Hop> {
    vec![
        Hop {
            label: "up".to_string(),
            bits: up,
            seconds: up_s,
        },
        Hop {
            label: "leader-xchg".to_string(),
            bits: lead,
            seconds: xchg_s,
        },
        Hop {
            label: "down".to_string(),
            bits: lead,
            seconds: down_s,
        },
    ]
}

impl ExchangeBackend for HierarchicalExchange {
    fn core(&self) -> &BackendCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BackendCore {
        &mut self.core
    }

    fn run_schedule(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.exchange_impl(step, grads, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::ParallelMode;
    use super::*;
    use crate::quant::{Codec, Method};
    use crate::sim::NetworkModel;

    fn config(method: Method, workers: usize) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: crate::exchange::BitsPolicy::Fixed(3),
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
            quantize_impl: crate::quant::QuantizeImpl::default(),
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn hop_bits_sum_to_step_total_and_leaders_compress() {
        let d = 1000;
        let g = grads(4, d, 1);
        let mut tree = HierarchicalExchange::new(config(Method::Alq, 4), 2);
        let mut agg = vec![0.0f32; d];
        for step in 0..6 {
            let bits = ExchangeBackend::exchange(&mut tree, step, &g, &mut agg);
            let hops = tree.last_hops();
            assert_eq!(hops.len(), 3);
            assert_eq!(hops.iter().map(|h| h.bits).sum::<u64>(), bits);
            // 2 leader frames cross the top level instead of 4 member
            // frames: the tree's raison d'être.
            assert!(hops[1].bits < hops[0].bits, "step {step}");
            assert_eq!(hops[1].bits, hops[2].bits);
        }
    }

    #[test]
    fn parallel_group_reductions_match_serial_bit_for_bit() {
        let d = 900;
        let g = grads(6, d, 5);
        let mut cfg_p = config(Method::Alq, 6);
        cfg_p.parallel = ParallelMode::Parallel;
        let mut serial = HierarchicalExchange::new(config(Method::Alq, 6), 3);
        let mut parallel = HierarchicalExchange::new(cfg_p, 3);
        let mut agg_s = vec![0.0f32; d];
        let mut agg_p = vec![0.0f32; d];
        for step in 0..12 {
            if step == 5 {
                serial.adapt(&g);
                parallel.adapt(&g);
            }
            let bs = ExchangeBackend::exchange(&mut serial, step, &g, &mut agg_s);
            let bp = ExchangeBackend::exchange(&mut parallel, step, &g, &mut agg_p);
            assert_eq!(bs, bp, "step {step} bits");
            let sb: Vec<u32> = agg_s.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = agg_p.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "step {step} aggregate");
            // Hop records stay in level (schedule) order.
            let labels: Vec<&str> = parallel.last_hops().iter().map(|h| h.label.as_str()).collect();
            assert_eq!(labels, ["up", "leader-xchg", "down"]);
        }
        assert_eq!(
            ExchangeBackend::final_levels(&serial),
            ExchangeBackend::final_levels(&parallel)
        );
        assert_eq!(serial.meter().total_bits, parallel.meter().total_bits);
    }

    #[test]
    fn deterministic_per_seed_but_distinct_from_flat() {
        use super::super::super::engine::GradientExchange;
        let d = 600;
        let g = grads(4, d, 2);
        let run = || {
            let mut tree = HierarchicalExchange::new(config(Method::NuqSgd, 4), 2);
            let mut agg = vec![0.0f32; d];
            let mut total = 0u64;
            for step in 0..5 {
                total += ExchangeBackend::exchange(&mut tree, step, &g, &mut agg);
            }
            (total, agg.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
        };
        let (bits_a, agg_a) = run();
        let (bits_b, agg_b) = run();
        assert_eq!(bits_a, bits_b);
        assert_eq!(agg_a, agg_b);
        // Re-quantized partials ≠ flat's aggregate.
        let mut flat = GradientExchange::new(config(Method::NuqSgd, 4));
        let mut agg_f = vec![0.0f32; d];
        for step in 0..5 {
            flat.exchange(step, &g, &mut agg_f);
        }
        let agg_f: Vec<u32> = agg_f.iter().map(|x| x.to_bits()).collect();
        assert_ne!(agg_a, agg_f);
    }

    #[test]
    fn full_precision_tree_sums_partials() {
        let d = 256;
        let g = grads(4, d, 3);
        let mut tree = HierarchicalExchange::new(config(Method::SuperSgd, 4), 2);
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut tree, 0, &g, &mut agg);
        // up 4 frames + 2×2 leader frames of 32·d.
        assert_eq!(bits, (4 + 4) * 32 * d as u64);
        // Aggregate ≈ the mean (associativity differs, values agree).
        for i in 0..d {
            let want = (g[0][i] + g[1][i] + g[2][i] + g[3][i]) / 4.0;
            assert!((agg[i] - want).abs() < 1e-5, "coord {i}");
        }
    }

    #[test]
    fn group_of_one_is_allowed() {
        let d = 300;
        let g = grads(3, d, 4);
        let mut tree = HierarchicalExchange::new(config(Method::QsgdInf, 3), 3);
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut tree, 0, &g, &mut agg);
        assert!(bits > 0);
        assert_eq!(tree.last_hops().iter().map(|h| h.bits).sum::<u64>(), bits);
    }
}
