//! Hierarchical two-level tree: G groups reduce locally, group leaders
//! exchange encoded partial aggregates, then broadcast down.
//!
//! Schedule (quantized payloads at every hop, per-hop bit metering):
//!
//! 1. **up** — every worker quantizes + encodes its gradient (identical
//!    per-worker RNG fork pattern and codebook lifecycle as the flat
//!    engine); each group leader decodes its members' frames and forms
//!    the group's partial mean contribution `Σ ĝ_w / M`.
//! 2. **xchg** — each leader *re-quantizes* its partial aggregate with
//!    its own RNG stream, encodes it, and the G leaders exchange these
//!    frames all-to-all.
//! 3. **down** — the G leader frames are broadcast to every member; all
//!    workers decode them and sum the G partials into the aggregate.
//!
//! The up-level re-quantization necessarily changes the reduction
//! numerics relative to the flat all-to-all (Σ_g Q(Σ_{w∈g} ĝ_w/M)
//! instead of Σ_w ĝ_w/M), so the tree's determinism contract is a
//! per-seed `params_hash` golden — bit-identical across runs and
//! replicas, but a *different* fixed point than flat — asserted in
//! `rust/tests/topology_parity.rs`. In exchange, the bits crossing the
//! top level shrink from M to G frames: the schedule the QSGD lineage
//! prescribes once M outgrows one switch.

use super::super::engine::ExchangeConfig;
use super::super::session::{CodecSession, ExchangeLane};
use super::super::ExchangeBackend;
use super::{group_members, Hop};
use crate::quant::{Method, Quantizer};
use crate::sim::network::Meter;
use crate::util::Rng;

/// The two-level tree exchange backend (`--topology tree:G`).
pub struct HierarchicalExchange {
    cfg: ExchangeConfig,
    groups: usize,
    session: CodecSession,
    rngs: Vec<Rng>,
    lanes: Vec<ExchangeLane>,
    /// One codec lane per group leader (partial-aggregate frames).
    leader_lanes: Vec<ExchangeLane>,
    /// Scratch: one group's partial mean contribution.
    partial: Vec<f32>,
    hops: Vec<Hop>,
    meter: Meter,
    codec_seconds: f64,
}

impl HierarchicalExchange {
    pub fn new(cfg: ExchangeConfig, groups: usize) -> Self {
        assert!(groups >= 1, "tree topology needs at least one group");
        let mut seeder = Rng::new(cfg.seed);
        let rngs: Vec<Rng> = (0..cfg.workers).map(|w| seeder.fork(w as u64)).collect();
        let session = CodecSession::new(cfg.method, cfg.bits, cfg.bucket).with_codec(cfg.codec);
        let active = if cfg.method == Method::SingleSgd {
            1
        } else {
            cfg.workers
        };
        // A group needs at least one member; SingleSGD collapses to one
        // lane, so clamp rather than reject (config validation already
        // rejects tree:G > workers at the CLI).
        let groups = groups.min(active);
        let lanes = (0..active).map(|_| ExchangeLane::new(cfg.bucket)).collect();
        let leader_lanes = (0..groups).map(|_| ExchangeLane::new(cfg.bucket)).collect();
        HierarchicalExchange {
            groups,
            session,
            rngs,
            lanes,
            leader_lanes,
            partial: Vec::new(),
            hops: Vec::new(),
            meter: Meter::default(),
            codec_seconds: 0.0,
            cfg,
        }
    }

    fn exchange_impl(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        let m = self.lanes.len();
        assert!(
            grads.len() >= m,
            "exchange needs one gradient per active lane ({} < {m})",
            grads.len()
        );
        agg.fill(0.0);
        let d = agg.len();
        if self.partial.len() != d {
            self.partial.resize(d, 0.0);
        }
        let net = self.cfg.network;
        let groups = self.groups;
        let inv = 1.0 / m as f32;

        if !self.session.is_quantized() {
            // Full precision: raw fp32 frames up, fp32 partials across
            // and down. The two-level association (Σ_g (Σ_{w∈g} g/M))
            // differs from flat's flat sum — the same schedule change the
            // quantized path makes, without codec noise.
            for g in 0..groups {
                let members = group_members(m, groups, g);
                self.partial.fill(0.0);
                for w in members {
                    for (p, &x) in self.partial.iter_mut().zip(&grads[w]) {
                        *p += x * inv;
                    }
                }
                for (a, &p) in agg.iter_mut().zip(&self.partial) {
                    *a += p;
                }
            }
            let up_bits = 32 * d as u64 * m as u64;
            let lead_bits = 32 * d as u64 * groups as u64;
            let (up_s, xchg_s, down_s) = self.fp_hop_seconds(m, groups, 32 * d as u64, lead_bits);
            self.push_level_hops(up_bits, lead_bits, up_s, xchg_s, down_s);
            let step_bits = up_bits + 2 * lead_bits;
            self.meter.record_raw(step_bits, up_s + xchg_s + down_s);
            return step_bits;
        }

        let t0 = std::time::Instant::now();
        // Member stage: identical codebook lifecycle to the flat engine.
        let mut lane0_quantized = false;
        if self.session.needs_book() && self.session.book().is_none() {
            self.lanes[0].quantize(&self.session, &grads[0], &mut self.rngs[0]);
            self.session.build_empirical_book(self.lanes[0].quantized());
            lane0_quantized = true;
        }
        let sample_counts = self.session.needs_book() && step % 10 == 0;

        // 1. up — every member quantizes, encodes, and (loopback-)decodes
        // its own frame; the leader reduces the decoded estimates.
        let mut up_bits = 0u64;
        let mut up_seconds = 0.0f64;
        for (w, ((lane, rng), grad)) in self
            .lanes
            .iter_mut()
            .zip(self.rngs.iter_mut())
            .zip(grads)
            .enumerate()
        {
            if !(w == 0 && lane0_quantized) {
                lane.quantize(&self.session, grad, rng);
            }
            if sample_counts {
                lane.count_symbols(&self.session);
            }
            up_bits += lane.encode(&self.session);
            lane.decode_own(&self.session);
        }
        if sample_counts {
            for w in 0..m {
                self.session.accumulate_counts(self.lanes[w].counts());
            }
        }

        // 2. xchg — leaders re-quantize group partials and exchange.
        let mut lead_bits = 0u64;
        let mut max_lead_bits = 0u64;
        for g in 0..groups {
            let members = group_members(m, groups, g);
            let leader = members.start;
            self.partial.fill(0.0);
            let mut max_member_bits = 0u64;
            for w in members.clone() {
                max_member_bits = max_member_bits.max(self.lanes[w].bits());
                for (p, &x) in self.partial.iter_mut().zip(self.lanes[w].ghat()) {
                    *p += x * inv;
                }
            }
            up_seconds =
                up_seconds.max(net.fan_time(members.len().saturating_sub(1), max_member_bits));
            // The leader's own RNG stream draws the partial's
            // quantization noise; only the ciphertext is shared.
            self.leader_lanes[g].quantize(&self.session, &self.partial, &mut self.rngs[leader]);
            let bits = self.leader_lanes[g].encode(&self.session);
            self.leader_lanes[g].decode_own(&self.session);
            lead_bits += bits;
            max_lead_bits = max_lead_bits.max(bits);
        }

        // 3. down — every worker sums the decoded leader partials; the
        // sim performs the reduction once (all replicas would compute
        // exactly this sum from exactly these frames).
        for g in 0..groups {
            for (a, &x) in agg.iter_mut().zip(self.leader_lanes[g].ghat()) {
                *a += x;
            }
        }

        let xchg_seconds = net.fan_time(groups.saturating_sub(1), max_lead_bits);
        let mut down_seconds = 0.0f64;
        for g in 0..groups {
            let members = group_members(m, groups, g);
            down_seconds =
                down_seconds.max(net.fan_time(members.len().saturating_sub(1), lead_bits));
        }
        self.push_level_hops(up_bits, lead_bits, up_seconds, xchg_seconds, down_seconds);
        let step_bits = up_bits + 2 * lead_bits;
        self.codec_seconds += t0.elapsed().as_secs_f64();
        self.meter
            .record_raw(step_bits, up_seconds + xchg_seconds + down_seconds);
        step_bits
    }

    /// Analytical hop times for the fp32 path (same shapes as the
    /// quantized path, uniform frame sizes).
    fn fp_hop_seconds(
        &self,
        m: usize,
        groups: usize,
        frame_bits: u64,
        lead_total: u64,
    ) -> (f64, f64, f64) {
        let net = &self.cfg.network;
        let mut up = 0.0f64;
        let mut down = 0.0f64;
        for g in 0..groups {
            let members = group_members(m, groups, g);
            up = up.max(net.fan_time(members.len().saturating_sub(1), frame_bits));
            down = down.max(net.fan_time(members.len().saturating_sub(1), lead_total));
        }
        let xchg = net.fan_time(groups.saturating_sub(1), frame_bits);
        (up, xchg, down)
    }

    fn push_level_hops(&mut self, up: u64, lead: u64, up_s: f64, xchg_s: f64, down_s: f64) {
        self.hops.clear();
        self.hops.push(Hop {
            label: "up".to_string(),
            bits: up,
            seconds: up_s,
        });
        self.hops.push(Hop {
            label: "leader-xchg".to_string(),
            bits: lead,
            seconds: xchg_s,
        });
        self.hops.push(Hop {
            label: "down".to_string(),
            bits: lead,
            seconds: down_s,
        });
    }
}

impl ExchangeBackend for HierarchicalExchange {
    fn exchange(&mut self, step: usize, grads: &[Vec<f32>], agg: &mut [f32]) -> u64 {
        self.exchange_impl(step, grads, agg)
    }

    fn adapt(&mut self, grads: &[Vec<f32>]) {
        if !self.session.is_quantized() {
            return;
        }
        let mut rng = self.rngs[0].fork(0xE57);
        if !self.session.adapt(grads.iter().map(|g| g.as_slice()), &mut rng) {
            self.session.refresh_book_from_counts();
        }
    }

    fn quantizer(&self) -> Option<&Quantizer> {
        self.session.quantizer()
    }

    fn active_workers(&self) -> usize {
        self.lanes.len()
    }

    fn is_quantized(&self) -> bool {
        self.session.is_quantized()
    }

    fn force_clip(&mut self, c: f32) {
        self.session.force_clip(c);
    }

    fn meter(&self) -> &Meter {
        &self.meter
    }

    fn codec_seconds(&self) -> f64 {
        self.codec_seconds
    }

    fn final_levels(&self) -> Option<Vec<f64>> {
        self.session.final_levels()
    }

    fn last_hops(&self) -> &[Hop] {
        &self.hops
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::ParallelMode;
    use super::*;
    use crate::quant::Codec;
    use crate::sim::NetworkModel;

    fn config(method: Method, workers: usize) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: 3,
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
        }
    }

    fn grads(workers: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect()
    }

    #[test]
    fn hop_bits_sum_to_step_total_and_leaders_compress() {
        let d = 1000;
        let g = grads(4, d, 1);
        let mut tree = HierarchicalExchange::new(config(Method::Alq, 4), 2);
        let mut agg = vec![0.0f32; d];
        for step in 0..6 {
            let bits = ExchangeBackend::exchange(&mut tree, step, &g, &mut agg);
            let hops = tree.last_hops();
            assert_eq!(hops.len(), 3);
            assert_eq!(hops.iter().map(|h| h.bits).sum::<u64>(), bits);
            // 2 leader frames cross the top level instead of 4 member
            // frames: the tree's raison d'être.
            assert!(hops[1].bits < hops[0].bits, "step {step}");
            assert_eq!(hops[1].bits, hops[2].bits);
        }
    }

    #[test]
    fn deterministic_per_seed_but_distinct_from_flat() {
        use super::super::super::engine::GradientExchange;
        let d = 600;
        let g = grads(4, d, 2);
        let run = || {
            let mut tree = HierarchicalExchange::new(config(Method::NuqSgd, 4), 2);
            let mut agg = vec![0.0f32; d];
            let mut total = 0u64;
            for step in 0..5 {
                total += ExchangeBackend::exchange(&mut tree, step, &g, &mut agg);
            }
            (total, agg.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
        };
        let (bits_a, agg_a) = run();
        let (bits_b, agg_b) = run();
        assert_eq!(bits_a, bits_b);
        assert_eq!(agg_a, agg_b);
        // Re-quantized partials ≠ flat's aggregate.
        let mut flat = GradientExchange::new(config(Method::NuqSgd, 4));
        let mut agg_f = vec![0.0f32; d];
        for step in 0..5 {
            flat.exchange(step, &g, &mut agg_f);
        }
        let agg_f: Vec<u32> = agg_f.iter().map(|x| x.to_bits()).collect();
        assert_ne!(agg_a, agg_f);
    }

    #[test]
    fn full_precision_tree_sums_partials() {
        let d = 256;
        let g = grads(4, d, 3);
        let mut tree = HierarchicalExchange::new(config(Method::SuperSgd, 4), 2);
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut tree, 0, &g, &mut agg);
        // up 4 frames + 2×2 leader frames of 32·d.
        assert_eq!(bits, (4 + 4) * 32 * d as u64);
        // Aggregate ≈ the mean (associativity differs, values agree).
        for i in 0..d {
            let want = (g[0][i] + g[1][i] + g[2][i] + g[3][i]) / 4.0;
            assert!((agg[i] - want).abs() < 1e-5, "coord {i}");
        }
    }

    #[test]
    fn group_of_one_is_allowed() {
        let d = 300;
        let g = grads(3, d, 4);
        let mut tree = HierarchicalExchange::new(config(Method::QsgdInf, 3), 3);
        let mut agg = vec![0.0f32; d];
        let bits = ExchangeBackend::exchange(&mut tree, 0, &g, &mut agg);
        assert!(bits > 0);
        assert_eq!(
            tree.last_hops().iter().map(|h| h.bits).sum::<u64>(),
            bits
        );
    }
}
