//! [`BackendCore`] — the state and contract every exchange backend
//! embeds — plus the generalized lane fan-out primitives.
//!
//! Before this module existed, the four [`super::super::ExchangeBackend`]
//! implementations each restated the same block of state and invariants:
//! the [`CodecSession`], the per-worker RNG fork pattern, the [`Meter`],
//! per-hop accounting, codec wall-time, and the SingleSGD lane collapse.
//! That determinism contract (DESIGN.md §8) could drift four ways; now it
//! lives here once:
//!
//! * **RNG forks** — one stream per *configured* worker, forked as
//!   `Rng::new(seed).fork(w)` in worker order at construction, so a seed
//!   maps to the same per-worker randomness regardless of method,
//!   backend, or schedule. The level-update subsample stream is always
//!   `rngs[0].fork(0xE57)`.
//! * **SingleSGD lane collapse** — `Method::SingleSgd` runs one active
//!   lane no matter how many workers are configured; every backend gets
//!   the collapse from [`BackendCore::active_workers`].
//! * **Member stage** — the quantize → (sampled count) → encode →
//!   loopback-decode pass every gathered schedule starts with, including
//!   the lazy empirical codebook bootstrap from lane 0's first
//!   quantization and the every-10th-step symbol-count sampling
//!   ([`BackendCore::member_stage`]).
//! * **Hop + meter accounting** — [`BackendCore::finish_step`] installs
//!   the step's [`Hop`] records (always in schedule order — see
//!   [`fan_out`]) and feeds the [`Meter`], debug-asserting the hop-sum
//!   invariant Σ hop bits == step bits.
//!
//! # Parallel fan-out
//!
//! [`fan_out`] is the `std::thread::scope` worker fan-out that used to be
//! private to the flat engine, generalized so any backend can fan any
//! stage of independent lane tasks across OS threads: the flat engine's
//! M worker lanes, the sharded backend's S shard-leader lanes, and the
//! tree backend's G per-group reductions. Results land at their schedule
//! index, never in thread-completion order, so hop records and reduction
//! inputs are deterministic by construction; all floating-point
//! reductions stay on the calling thread in schedule order, which is why
//! `--parallel on` and `--parallel off` are bit-identical for every
//! backend (`rust/tests/topology_parity.rs`). The ring backend is the
//! exception and stays serial — see `ring.rs` for why its schedule
//! structure (a 2(M−1)-stage dependency chain that mutates the shared
//! session's codebook statistics mid-stage) admits no lane fan-out.

use super::super::budget::{select_width, BitController};
use super::super::engine::{ExchangeConfig, ParallelMode, PipelineMode};
use super::super::feedback::{ErrorFeedback, LazyPolicy, LazyWorker, SKIP_MARKER_BITS};
use super::super::membership::Membership;
use super::super::session::{CodecSession, ExchangeLane};
use super::Hop;
use crate::quant::{Method, Quantizer};
use crate::sim::network::Meter;
use crate::trace::{Level, Tracer};
use crate::util::json::Json;
use crate::util::Rng;
use std::time::Instant;

/// Coordinate count per lane below which `ParallelMode::Auto` stays
/// serial: spawning a scoped thread costs ~tens of µs, and quantize+code
/// of fewer coordinates is cheaper than that (DESIGN.md §Perf).
const AUTO_PARALLEL_MIN_COORDS: usize = 32_768;

/// Cumulative per-phase codec wall time, split the way `TrainRecord`
/// reports it (the un-opaqued view of `codec_seconds`).
///
/// Values are per-lane sums measured inside [`BackendCore::member_stage`]
/// — under parallel lanes they can exceed the region's wall time (which
/// is what `codec_seconds` charges). Schedule work a backend runs
/// *outside* the member stage (sharded/tree leader-side decode and
/// re-quantization, the whole ring schedule) is not attributed here;
/// those backends still report the total in `codec_seconds`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CodecPhase {
    /// Seconds spent quantizing (including sampled symbol counting).
    pub quantize: f64,
    /// Seconds spent entropy-encoding.
    pub encode: f64,
    /// Seconds spent decoding (the loopback decode of own frames).
    pub decode: f64,
}

/// The state block shared by every [`super::super::ExchangeBackend`]:
/// codec session, per-worker RNG streams, communication meter, per-hop
/// accounting, codec wall-time, and the SingleSGD lane collapse.
///
/// Backends embed a `BackendCore` and implement only their schedule
/// (`run_schedule()`); everything else — the per-step bit-budget
/// selection (`exchange()` → [`BackendCore::begin_step`]), `adapt`,
/// `quantizer`, `active_workers`, `is_quantized`, `force_clip`,
/// `meter`, `codec_seconds`, `final_levels`, `last_hops`, `step_width`
/// — is provided by the trait's default methods delegating here
/// (DESIGN.md §8).
pub struct BackendCore {
    cfg: ExchangeConfig,
    session: CodecSession,
    /// The per-step bit-width decision for the configured `BitsPolicy`
    /// (the inert constant for `fixed:B`).
    controller: Box<dyn BitController>,
    /// Width the current/last step quantizes at (32 for full precision).
    step_width: u32,
    rngs: Vec<Rng>,
    active: usize,
    /// The elastic active set over the `active` lanes: which lanes
    /// currently participate in aggregation, their weights, and their
    /// join/leave epochs. Full strength unless churn is injected
    /// (`sim::FaultPlan`, TCP timeout-and-drop).
    membership: Membership,
    /// Error-feedback residual memory (`--error-feedback on`); `None`
    /// keeps the pre-feedback bit-identical fast path.
    feedback: Option<ErrorFeedback>,
    /// The skip-round policy (`--lazy`); [`LazyPolicy::Off`] by default.
    lazy: LazyPolicy,
    /// Per-lane skip-rule state (LAQ reference + streak).
    lazy_workers: Vec<LazyWorker>,
    /// The step's frame plan: active lanes that send a frame this step,
    /// ascending. Equals the active set whenever feedback and lazy are
    /// both off (see [`BackendCore::plan_frames`]).
    sent: Vec<usize>,
    /// Active lanes that send only a skip marker this step, ascending.
    skipped: Vec<usize>,
    /// Scratch for the feedback settle dequantize in schedules that do
    /// not loopback-decode inside the member stage.
    ghat_scratch: Vec<f32>,
    meter: Meter,
    codec_seconds: f64,
    phase: CodecPhase,
    /// Pipeline schedule (`--pipeline off|overlap|stale:1`). `Overlap`
    /// makes [`BackendCore::finish_step`] credit the modeled wire
    /// seconds hidden behind the step's encode wall time; `Stale`'s
    /// hiding happens in `sim::Cluster::train` (compute overlaps the
    /// previous step's exchange). Neither moves a single bit.
    pipeline: PipelineMode,
    /// Encode wall seconds of the in-flight step — what `Overlap` can
    /// hide wire time behind. Reset by [`BackendCore::begin_step`],
    /// accumulated by the member stage and by backends whose encode runs
    /// outside it ([`BackendCore::note_encode_seconds`]).
    step_encode_seconds: f64,
    hops: Vec<Hop>,
    /// Telemetry handle (disabled by default; installed via
    /// [`BackendCore::set_tracer`]). All event emission happens on the
    /// calling thread in schedule order, which is what keeps traced
    /// event sequences bit-identical across `--parallel` modes.
    tracer: Tracer,
    /// The step `begin_step` last started — the step every event this
    /// core emits is stamped with.
    cur_step: usize,
}

impl BackendCore {
    /// Stand up the shared state: fork one RNG stream per configured
    /// worker (in worker order — the fork pattern every backend must
    /// preserve), build the codec session, and apply the SingleSGD lane
    /// collapse.
    pub fn new(cfg: ExchangeConfig) -> Self {
        let mut seeder = Rng::new(cfg.seed);
        // One stream per *configured* worker even when fewer lanes are
        // active, so a seed maps to the same per-worker randomness
        // regardless of method (and identically to the seed loop).
        let rngs: Vec<Rng> = (0..cfg.workers).map(|w| seeder.fork(w as u64)).collect();
        let session = CodecSession::with_policy(cfg.method, &cfg.bits, cfg.bucket)
            .with_codec(cfg.codec)
            .with_quantize_impl(cfg.quantize_impl);
        let controller = cfg.bits.controller();
        let step_width = session.active_bits().unwrap_or(32);
        let active = if cfg.method == Method::SingleSgd {
            1
        } else {
            cfg.workers
        };
        BackendCore {
            session,
            controller,
            step_width,
            rngs,
            membership: Membership::new(active),
            active,
            feedback: None,
            lazy: LazyPolicy::Off,
            lazy_workers: vec![LazyWorker::default(); active],
            sent: (0..active).collect(),
            skipped: Vec::new(),
            ghat_scratch: Vec::new(),
            meter: Meter::default(),
            codec_seconds: 0.0,
            phase: CodecPhase::default(),
            pipeline: PipelineMode::Off,
            step_encode_seconds: 0.0,
            hops: Vec::new(),
            tracer: Tracer::disabled(),
            cur_step: 0,
            cfg,
        }
    }

    /// Install the telemetry handle every subsequent step reports to
    /// (replacing the default disabled tracer).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// The telemetry handle (disabled unless one was installed).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Start one exchange step: feed the bit controller its per-step
    /// variance observation (only when the policy consumes one — the
    /// closed-form Eq. 1–2 evaluation is skipped entirely for `fixed:B`
    /// and `schedule`, keeping them at zero overhead), ask it for the
    /// step's width, and activate that width's bank slot (O(1)).
    ///
    /// Runs on the calling thread before any lane fans out, so width
    /// decisions are deterministic per seed and identical across
    /// `--parallel` modes.
    pub fn begin_step(&mut self, step: usize, grads: &[Vec<f32>]) {
        self.cur_step = step;
        self.step_encode_seconds = 0.0;
        if !self.session.is_quantized() {
            self.step_width = 32;
        } else {
            // The first active worker's gradient is the representative
            // observation (worker 0 at full strength — the same protocol
            // the TCP worker runs on its own gradient;
            // `budget::select_width` is the single shared implementation,
            // and the single `bit_decision` trace point). Width selection
            // observes the *raw* gradient, never the feedback-corrected
            // one, so `--error-feedback off --lazy off` trajectories and
            // width decisions are pinned bit-identical.
            let w0 = self.membership.active_ids().first().copied().unwrap_or(0);
            let grad = grads.get(w0).map(|g| g.as_slice()).unwrap_or_default();
            self.step_width = select_width(
                self.controller.as_mut(),
                &mut self.session,
                step,
                grad,
                &self.tracer,
            );
        }
        self.plan_frames(step, grads);
    }

    /// Partition the active set into this step's frame senders
    /// ([`BackendCore::sent_ids`]) and skip-marker senders: apply the
    /// error-feedback correction (residual + gradient) per active lane,
    /// ask the [`LazyPolicy`] whether the corrected message clears the
    /// send rule, and absorb skipped messages back into the residual.
    ///
    /// When feedback and lazy are both off this is a plan-copy of the
    /// active set and nothing else — no buffer copies, no events, no RNG
    /// draws — which is what keeps `--error-feedback off --lazy off`
    /// bit-identical to the pre-feedback engine.
    fn plan_frames(&mut self, step: usize, grads: &[Vec<f32>]) {
        self.skipped.clear();
        if self.feedback.is_none() && self.lazy.is_off() {
            self.sent = self.membership.active_ids();
            return;
        }
        self.sent.clear();
        let ids = self.membership.active_ids();
        let lossless = !self.session.is_quantized();
        for &w in &ids {
            if let Some(fb) = self.feedback.as_mut() {
                fb.correct(w, &grads[w]);
            }
            let msg: &[f32] = match self.feedback.as_ref() {
                Some(fb) => fb.corrected(w),
                None => &grads[w],
            };
            let send = self.lazy_workers[w].decide(&self.lazy, msg);
            if send {
                self.sent.push(w);
                if lossless {
                    // A full-precision frame carries the message exactly:
                    // the residual settles to zero without a decode.
                    if let Some(fb) = self.feedback.as_mut() {
                        fb.clear_residual(w);
                    }
                }
            } else {
                self.skipped.push(w);
                // A skipped message is not lost: with feedback on, the
                // whole corrected message becomes the next residual.
                if let Some(fb) = self.feedback.as_mut() {
                    fb.absorb(w);
                }
            }
        }
        if let Some(fb) = self.feedback.as_ref() {
            if self.tracer.on(Level::Debug) {
                for &w in &ids {
                    let norm = fb.residual_norm(w);
                    self.tracer.event(Level::Debug, "feedback_norm", |o| {
                        o.insert("step", Json::Num(step as f64));
                        o.insert("worker", Json::Num(w as f64));
                        o.insert("norm", Json::Num(norm));
                    });
                }
            }
        }
        // Senders keep their full (renormalized-to-1) aggregation
        // weight; a step where *everyone* skips aggregates nothing and
        // reports weight_sum 0 — `trace-summarize` surfaces both.
        let weight_sum = if self.sent.is_empty() { 0.0 } else { 1.0 };
        for &w in &self.skipped {
            self.tracer.event(Level::Info, "skip", |o| {
                o.insert("step", Json::Num(step as f64));
                o.insert("worker", Json::Num(w as f64));
                o.insert("bits", Json::Num(SKIP_MARKER_BITS as f64));
                o.insert("weight_sum", Json::Num(weight_sum));
            });
        }
    }

    /// Enable or disable error-feedback residual memory. Follows the
    /// [`BackendCore::set_pipeline`] setter pattern (not an
    /// [`ExchangeConfig`] field): `sim::Cluster::new` and run setup call
    /// it once before training. Unsupported over `ring` — rejected at
    /// `RunConfig::validate` and asserted by `Cluster::new`.
    pub fn set_error_feedback(&mut self, on: bool) {
        self.feedback = if on {
            Some(ErrorFeedback::new(self.active))
        } else {
            None
        };
    }

    /// Whether error-feedback residual memory is enabled.
    pub fn error_feedback(&self) -> bool {
        self.feedback.is_some()
    }

    /// Select the lazy skip-round policy (default [`LazyPolicy::Off`]).
    pub fn set_lazy(&mut self, lazy: LazyPolicy) {
        self.lazy = lazy;
    }

    /// The configured lazy skip-round policy.
    pub fn lazy(&self) -> LazyPolicy {
        self.lazy
    }

    /// The lanes sending a frame this step, ascending — the set every
    /// topology schedule quantizes, encodes, and aggregates over.
    /// Equals [`Membership::active_ids`] when feedback and lazy are off.
    pub fn sent_ids(&self) -> Vec<usize> {
        self.sent.clone()
    }

    /// Bitmask form of [`BackendCore::sent_ids`] (bit w ⇔ lane w sent a
    /// frame) — the projection the sim≡TCP parity tests compare.
    pub fn sent_mask(&self) -> u64 {
        self.sent.iter().fold(0u64, |m, &w| m | (1u64 << w))
    }

    /// How many active lanes sent only a skip marker this step.
    pub fn skipped_count(&self) -> usize {
        self.skipped.len()
    }

    /// The message lane `w` actually transmits this step: the
    /// feedback-corrected gradient when residual memory is on, the raw
    /// gradient otherwise. Valid after [`BackendCore::begin_step`] for
    /// lanes in the sent set.
    pub fn outgoing<'a>(&'a self, w: usize, grads: &'a [Vec<f32>]) -> &'a [f32] {
        match self.feedback.as_ref() {
            Some(fb) => fb.corrected(w),
            None => &grads[w],
        }
    }

    /// The quantization width the current/last step runs at (32 for
    /// full precision).
    pub fn step_width(&self) -> u32 {
        self.step_width
    }

    /// The exchange configuration this core was built from.
    pub fn cfg(&self) -> &ExchangeConfig {
        &self.cfg
    }

    /// Lanes that actually compute and communicate (1 for SingleSGD).
    /// This is the *configured* lane count; the churn-aware subset that
    /// participates in aggregation is [`BackendCore::membership`].
    pub fn active_workers(&self) -> usize {
        self.active
    }

    /// The elastic active set every topology schedule aggregates over.
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// Mutable membership access (run setup: standby marking for
    /// workers with a pending `join` fault).
    pub fn membership_mut(&mut self) -> &mut Membership {
        &mut self.membership
    }

    /// Permanently remove `worker` from the active set at the top of
    /// `step`: emits a `member_drop` event and a [`crate::trace::warn`]
    /// notice. Survivor weights renormalize to sum to exactly 1.
    pub fn drop_worker(&mut self, step: usize, worker: usize) {
        self.membership.deactivate(worker, step);
        let active = self.membership.n_active();
        let weight_sum = self.membership.weight_sum();
        self.tracer.event(Level::Info, "member_drop", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("worker", Json::Num(worker as f64));
            o.insert("active", Json::Num(active as f64));
            o.insert("weight_sum", Json::Num(f64::from(weight_sum)));
        });
        crate::trace::warn(
            "membership",
            &format!("worker {worker} dropped at step {step}; {active} active (weight_sum {weight_sum})"),
        );
    }

    /// Activate standby `worker` at the top of `step` (its scripted
    /// `join` fault fired): emits a `member_join` event.
    pub fn join_worker(&mut self, step: usize, worker: usize) {
        self.membership.activate(worker, step);
        let active = self.membership.n_active();
        let weight_sum = self.membership.weight_sum();
        self.tracer.event(Level::Info, "member_join", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("worker", Json::Num(worker as f64));
            o.insert("active", Json::Num(active as f64));
            o.insert("weight_sum", Json::Num(f64::from(weight_sum)));
        });
    }

    /// Allocate one reusable codec lane per active worker.
    pub fn new_lanes(&self) -> Vec<ExchangeLane> {
        (0..self.active)
            .map(|_| ExchangeLane::new(self.cfg.bucket))
            .collect()
    }

    /// The shared codec session (read-only).
    pub fn session(&self) -> &CodecSession {
        &self.session
    }

    /// Split borrow for schedule stages that encode against the session
    /// while drawing from worker RNG streams (the session stays
    /// read-only so it can be shared across fanned-out lanes).
    pub fn session_and_rngs_mut(&mut self) -> (&CodecSession, &mut [Rng]) {
        (&self.session, &mut self.rngs)
    }

    /// Split borrow for schedules that mutate the session mid-stage
    /// (the ring backend's lazy book build and count sampling happen on
    /// chunk frames inside its stages — one reason ring stays serial).
    pub fn codec_mut(&mut self) -> (&mut CodecSession, &mut [Rng]) {
        (&mut self.session, &mut self.rngs)
    }

    /// Whether this exchange quantizes at all.
    pub fn is_quantized(&self) -> bool {
        self.session.is_quantized()
    }

    /// The live quantizer, if this exchange quantizes at all.
    pub fn quantizer(&self) -> Option<&Quantizer> {
        self.session.quantizer()
    }

    /// Force TernGrad-style c·σ clipping regardless of method (the
    /// Appendix K.2 / Fig. 14 ablation).
    pub fn force_clip(&mut self, c: f32) {
        self.session.force_clip(c);
    }

    /// The final (possibly adapted) quantization level magnitudes.
    pub fn final_levels(&self) -> Option<Vec<f64>> {
        self.session.final_levels()
    }

    /// The running communication meter (total bits + modeled seconds).
    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Mutable meter access — fault injection charges straggler delays
    /// (`delay:W@S:MS`) here without a step or any bits.
    pub fn meter_mut(&mut self) -> &mut Meter {
        &mut self.meter
    }

    /// Wall time spent inside quantize+encode+decode so far.
    pub fn codec_seconds(&self) -> f64 {
        self.codec_seconds
    }

    /// Select the pipeline schedule (default [`PipelineMode::Off`]).
    /// `Overlap` only changes the [`Meter`]'s hidden-time accounting —
    /// frames, bits, hops, and the aggregate stay bit-identical.
    pub fn set_pipeline(&mut self, pipeline: PipelineMode) {
        self.pipeline = pipeline;
    }

    /// The configured pipeline schedule.
    pub fn pipeline(&self) -> PipelineMode {
        self.pipeline
    }

    /// Attribute encode wall seconds to the in-flight step. The member
    /// stage does this for every lane it encodes; backends whose encode
    /// runs outside it (the sharded per-shard encode, the tree leader
    /// re-encode) report theirs here so `Overlap` can hide wire time
    /// behind the full encode phase.
    pub fn note_encode_seconds(&mut self, seconds: f64) {
        self.step_encode_seconds += seconds;
    }

    /// Charge codec wall time (a parallel region charges its wall time,
    /// not the per-thread sum).
    pub fn add_codec_seconds(&mut self, seconds: f64) {
        self.codec_seconds += seconds;
    }

    /// Cumulative per-phase codec time (see [`CodecPhase`] for the
    /// attribution caveats).
    pub fn codec_phase(&self) -> CodecPhase {
        self.phase
    }

    /// Emit one `phase` span event for the current step (a wall-clock
    /// measurement, hence the `wall_seconds` key — masked by the
    /// determinism tests). Backends use this for schedule stages the
    /// core cannot see, e.g. the flat engine's aggregate reduction.
    pub fn trace_phase(&self, phase: &str, wall_seconds: f64) {
        let step = self.cur_step;
        self.tracer.event(Level::Debug, "phase", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("phase", Json::Str(phase.to_string()));
            o.insert("wall_seconds", Json::Num(wall_seconds));
        });
    }

    /// Per-hop accounting of the last exchange, in schedule order.
    pub fn last_hops(&self) -> &[Hop] {
        &self.hops
    }

    /// Install the step's hop records (schedule order) and feed the
    /// meter; returns the step's total bits, skip markers included —
    /// the value every backend returns from `exchange()`. Debug-asserts
    /// the hop-sum invariant: Σ hop bits equals that step total.
    ///
    /// Zero-bit participants are charged here, once for every topology:
    /// each lane the lazy policy silenced this step still transmits a
    /// [`SKIP_MARKER_BITS`]-bit marker frame, appended as one `skip` hop
    /// (n · marker bits, fan-in α-β seconds) so the hop-sum invariant
    /// holds on skip steps and the meter never under-reports the wire.
    ///
    /// This is the single trace point for per-hop records and the step
    /// total, inherited by every topology: one `hop` event per schedule
    /// hop and a `wire` phase span (both carrying the *modeled* α-β
    /// `seconds`, which are deterministic and stay unmasked), then the
    /// `step` roll-up event whose `bits` is exactly the `StepStats.bits`
    /// the sim records.
    pub fn finish_step(&mut self, hops: Vec<Hop>, step_bits: u64, step_seconds: f64) -> u64 {
        let mut hops = hops;
        let mut step_bits = step_bits;
        let mut step_seconds = step_seconds;
        let n_skipped = self.skipped.len();
        if n_skipped > 0 {
            let bits = n_skipped as u64 * SKIP_MARKER_BITS;
            let seconds = self.cfg.network.fan_time(n_skipped, SKIP_MARKER_BITS);
            hops.push(Hop {
                label: "skip".to_string(),
                bits,
                seconds,
            });
            step_bits += bits;
            step_seconds += seconds;
        }
        debug_assert_eq!(
            hops.iter().map(|h| h.bits).sum::<u64>(),
            step_bits,
            "hop-sum invariant violated"
        );
        let step = self.cur_step;
        if self.tracer.on(Level::Debug) {
            for (i, h) in hops.iter().enumerate() {
                self.tracer.event(Level::Debug, "hop", |o| {
                    o.insert("step", Json::Num(step as f64));
                    o.insert("index", Json::Num(i as f64));
                    o.insert("label", Json::Str(h.label.clone()));
                    o.insert("bits", Json::Num(h.bits as f64));
                    o.insert("seconds", Json::Num(h.seconds));
                });
            }
            self.tracer.event(Level::Debug, "phase", |o| {
                o.insert("step", Json::Num(step as f64));
                o.insert("phase", Json::Str("wire".to_string()));
                o.insert("seconds", Json::Num(step_seconds));
            });
        }
        let width = self.step_width;
        self.tracer.event(Level::Info, "step", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("bits", Json::Num(step_bits as f64));
            o.insert("width", Json::Num(width as f64));
        });
        self.hops = hops;
        self.meter.record_raw(step_bits, step_seconds);
        if self.pipeline == PipelineMode::Overlap {
            // Frame k sits on the wire while bucket-range k+1 encodes,
            // so up to the step's encode wall time of modeled wire
            // seconds is hidden; the remainder still serializes. This
            // touches only the meter's hidden-time ledger — bits, hops,
            // and `total_time` are untouched, which is why `overlap` is
            // bit-identical to `off` (DESIGN.md §Pipeline).
            self.meter.hide(self.step_encode_seconds.min(step_seconds));
        }
        self.step_encode_seconds = 0.0;
        step_bits
    }

    /// Algorithm 1 line 4 at the update schedule, identical for every
    /// backend: re-fit the distribution and re-optimize levels (adaptive
    /// methods, subsampling from the `rngs[0].fork(0xE57)` stream the
    /// seed loop used) or rebuild the codebook from the sampled
    /// empirical counts (non-adaptive). No-op for full precision.
    pub fn adapt(&mut self, grads: &[Vec<f32>]) {
        if !self.session.is_quantized() {
            return;
        }
        let t0 = Instant::now();
        let mut rng = self.rngs[0].fork(0xE57);
        // Only active members contribute to the fit: a dropped or
        // standby lane's gradients must not shape the shared levels.
        let ids = self.membership.active_ids();
        let updated = self
            .session
            .adapt(ids.iter().map(|&w| grads[w].as_slice()), &mut rng);
        if !updated {
            self.session.refresh_book_from_counts();
        } else {
            // A successful fit refreshed every bank width's levels and
            // produced the per-width Ψ profile; hand it to the bit
            // controller (a no-op for fixed/schedule policies).
            self.controller
                .observe_width_profile(self.session.width_profile());
        }
        let wall = t0.elapsed().as_secs_f64();
        let width = self.session.active_bits().unwrap_or(32);
        self.tracer.event(Level::Info, "adapt", |o| {
            o.insert("updated", Json::Bool(updated));
            o.insert("width", Json::Num(width as f64));
            o.insert("wall_seconds", Json::Num(wall));
        });
    }

    /// Whether a stage of `lanes` independent tasks, each touching about
    /// `lane_coords` coordinates of codec work, should fan out across
    /// threads under the configured [`ParallelMode`].
    pub fn use_parallel(&self, lanes: usize, lane_coords: usize) -> bool {
        match self.cfg.parallel {
            ParallelMode::Serial => false,
            ParallelMode::Parallel => lanes > 1,
            ParallelMode::Auto => lanes > 1 && lane_coords >= AUTO_PARALLEL_MIN_COORDS,
        }
    }

    /// The member stage every gathered schedule starts with: bootstrap
    /// the lazy empirical codebook from the first *sending* lane's first
    /// quantization if the coder needs one, quantize every sending lane
    /// from its own RNG stream (fanned out per
    /// [`BackendCore::use_parallel`]), sample symbol counts every 10th
    /// step, and — when `encode` is set — entropy-encode and
    /// loopback-decode each lane's frame. Lanes outside the sent set —
    /// dropped or standby members, and lanes the [`LazyPolicy`] skipped
    /// this step — are skipped entirely: they contribute no symbols, no
    /// counts, no frames, and consume no RNG draws (matching the TCP
    /// worker, which never quantizes a skipped step). Each lane
    /// quantizes its *outgoing* message — the feedback-corrected
    /// gradient when residual memory is on — and the decode error
    /// settles back into the residual before this returns. Sampled
    /// counts are folded into the session on the calling thread in
    /// worker order, so refreshed codebooks are bit-identical across
    /// schedules and modes.
    ///
    /// Must only be called on a quantized session.
    pub fn member_stage(
        &mut self,
        lanes: &mut [ExchangeLane],
        grads: &[Vec<f32>],
        step: usize,
        encode: bool,
    ) {
        let ids = self.sent.clone();
        let Some(&first) = ids.first() else { return };
        let mut first_quantized = false;
        if self.session.needs_book() && self.session.book().is_none() {
            let msg0: &[f32] = match self.feedback.as_ref() {
                Some(fb) => fb.corrected(first),
                None => &grads[first],
            };
            lanes[first].quantize(&self.session, msg0, &mut self.rngs[first]);
            self.session.build_empirical_book(lanes[first].quantized());
            first_quantized = true;
        }
        let sample_counts = self.session.needs_book() && step % 10 == 0;
        let parallel = self.use_parallel(ids.len(), grads.first().map_or(0, |g| g.len()));
        let timings = {
            let session = &self.session;
            let feedback = self.feedback.as_ref();
            let lane_refs = disjoint_mut(lanes, ids.iter().copied());
            let rng_refs = disjoint_mut(&mut self.rngs, ids.iter().copied());
            let mut tasks: Vec<(&mut ExchangeLane, &mut Rng, &[f32])> = lane_refs
                .into_iter()
                .zip(rng_refs)
                .zip(ids.iter())
                .map(|((lane, rng), &w)| {
                    let msg: &[f32] = match feedback {
                        Some(fb) => fb.corrected(w),
                        None => grads[w].as_slice(),
                    };
                    (lane, rng, msg)
                })
                .collect();
            fan_out(parallel, &mut tasks, |i, task| {
                let (lane, rng, grad) = task;
                let t0 = Instant::now();
                if !(i == 0 && first_quantized) {
                    lane.quantize(session, grad, rng);
                }
                if sample_counts {
                    lane.count_symbols(session);
                }
                let t_quantize = t0.elapsed().as_secs_f64();
                let (mut t_encode, mut t_decode) = (0.0, 0.0);
                if encode {
                    let t1 = Instant::now();
                    lane.encode(session);
                    t_encode = t1.elapsed().as_secs_f64();
                    let t2 = Instant::now();
                    lane.decode_own(session);
                    t_decode = t2.elapsed().as_secs_f64();
                }
                (t_quantize, t_encode, t_decode)
            })
        };
        if sample_counts {
            // Worker-order f64 accumulation on the calling thread, so
            // refreshed codebooks never depend on lane scheduling.
            for &w in &ids {
                self.session.accumulate_counts(lanes[w].counts());
            }
        }
        // Per-lane timings fold in worker order on the calling thread:
        // the per-phase attribution behind `codec_phase()` and the
        // member-stage span events. Which spans exist is structural
        // (quantize always, encode/decode iff this schedule encodes
        // here), never a function of measured time — so the masked
        // event sequence is identical across `--parallel` modes.
        let (mut t_q, mut t_e, mut t_d) = (0.0f64, 0.0f64, 0.0f64);
        for &(q, e, d) in &timings {
            t_q += q;
            t_e += e;
            t_d += d;
        }
        self.phase.quantize += t_q;
        self.phase.encode += t_e;
        self.phase.decode += t_d;
        self.step_encode_seconds += t_e;
        if self.tracer.on(Level::Debug) {
            self.trace_phase("quantize", t_q);
            if encode {
                self.trace_phase("encode", t_e);
                self.trace_phase("decode", t_d);
            }
        }
        // Settle each sender's residual against what receivers will
        // decode: residual ← corrected − ĝ. With a loopback decode the
        // lane's ĝ is exactly that; without one (the sharded schedule
        // encodes per-shard later), dequantizing the lane's symbols
        // yields the identical estimate, since entropy coding is
        // lossless over symbols.
        if self.feedback.is_some() {
            if encode {
                for &w in &ids {
                    let fb = self.feedback.as_mut().expect("feedback checked above");
                    fb.settle(w, lanes[w].ghat());
                }
            } else {
                let q = self
                    .session
                    .quantizer()
                    .expect("member_stage requires a quantized session");
                for &w in &ids {
                    self.ghat_scratch.resize(grads[w].len(), 0.0);
                    q.dequantize(lanes[w].quantized(), &mut self.ghat_scratch);
                    let fb = self.feedback.as_mut().expect("feedback checked above");
                    fb.settle(w, &self.ghat_scratch);
                }
            }
        }
    }
}

/// Run one independent task per schedule slot, fanned out across scoped
/// OS threads when `parallel` is set (serially in slot order otherwise),
/// and return each task's result **at its schedule index** — never in
/// thread-completion order.
///
/// This is the generalized form of the flat engine's worker fan-out:
/// tasks share only `Sync` state (the read-only [`CodecSession`]), own
/// their mutable lane state, and the caller performs every
/// floating-point reduction over the returned slots in schedule order —
/// which is what makes parallel and serial schedules bit-identical.
pub fn fan_out<T, R, F>(parallel: bool, tasks: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    if parallel && tasks.len() > 1 {
        let mut results: Vec<Option<R>> = Vec::with_capacity(tasks.len());
        results.resize_with(tasks.len(), || None);
        std::thread::scope(|scope| {
            for ((i, task), slot) in tasks.iter_mut().enumerate().zip(results.iter_mut()) {
                let f = &f;
                scope.spawn(move || *slot = Some(f(i, task)));
            }
        });
        results
            .into_iter()
            .map(|r| r.expect("fan-out task did not deliver a result"))
            .collect()
    } else {
        tasks.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect()
    }
}

/// Mutable references to the strictly increasing `idxs` of `slice`
/// (panics otherwise) — how a fanned-out stage hands each task its own
/// worker RNG stream (e.g. the tree backend's group leaders) without
/// aliasing.
pub fn disjoint_mut<'a, T>(
    slice: &'a mut [T],
    idxs: impl IntoIterator<Item = usize>,
) -> Vec<&'a mut T> {
    let mut out = Vec::new();
    let mut rest = slice;
    let mut base = 0usize;
    for i in idxs {
        assert!(i >= base, "disjoint_mut needs strictly increasing indices");
        let tail = std::mem::take(&mut rest).split_at_mut(i - base).1;
        let (first, tail) = tail
            .split_first_mut()
            .expect("disjoint_mut index out of bounds");
        out.push(first);
        rest = tail;
        base = i + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Codec;
    use crate::sim::NetworkModel;

    fn cfg(method: Method, workers: usize, parallel: ParallelMode) -> ExchangeConfig {
        ExchangeConfig {
            method,
            workers,
            bits: crate::exchange::BitsPolicy::Fixed(3),
            bucket: 64,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel,
            codec: Codec::Huffman,
            quantize_impl: crate::quant::QuantizeImpl::default(),
        }
    }

    #[test]
    fn single_sgd_collapses_to_one_lane() {
        let core = BackendCore::new(cfg(Method::SingleSgd, 4, ParallelMode::Auto));
        assert_eq!(core.active_workers(), 1);
        assert_eq!(core.new_lanes().len(), 1);
        let core = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Auto));
        assert_eq!(core.active_workers(), 4);
    }

    #[test]
    fn use_parallel_honors_mode_and_size() {
        let auto = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Auto));
        assert!(!auto.use_parallel(4, 1000));
        assert!(auto.use_parallel(4, AUTO_PARALLEL_MIN_COORDS));
        assert!(!auto.use_parallel(1, 1 << 20));
        let on = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Parallel));
        assert!(on.use_parallel(2, 1));
        assert!(!on.use_parallel(1, 1 << 20));
        let off = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Serial));
        assert!(!off.use_parallel(16, 1 << 20));
    }

    #[test]
    fn fan_out_results_land_at_schedule_indices() {
        let mut tasks: Vec<usize> = (0..8).collect();
        for parallel in [false, true] {
            let out = fan_out(parallel, &mut tasks, |i, t| {
                // Stagger completion so thread-finish order ≠ schedule
                // order in the parallel case.
                std::thread::sleep(std::time::Duration::from_micros(((8 - i) * 200) as u64));
                *t * 10 + i
            });
            assert_eq!(out, (0..8).map(|i| i * 11).collect::<Vec<_>>());
        }
    }

    #[test]
    fn disjoint_mut_hands_out_the_right_elements() {
        let mut v: Vec<u32> = (0..10).collect();
        let picks = disjoint_mut(&mut v, [1usize, 4, 9]);
        assert_eq!(picks.iter().map(|r| **r).collect::<Vec<_>>(), [1, 4, 9]);
        for r in picks {
            *r += 100;
        }
        assert_eq!(v[1], 101);
        assert_eq!(v[4], 104);
        assert_eq!(v[9], 109);
        assert_eq!(v[0], 0);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn disjoint_mut_rejects_unsorted_indices() {
        let mut v = [0u8; 4];
        let _ = disjoint_mut(&mut v, [2usize, 1]);
    }

    #[test]
    fn begin_step_moves_the_width_only_for_dynamic_policies() {
        let grads = vec![vec![0.1f32; 128]; 2];
        let mut c = cfg(Method::Alq, 2, ParallelMode::Serial);
        c.bits = crate::exchange::BitsPolicy::parse("schedule:3@0,2@5").unwrap();
        let mut core = BackendCore::new(c);
        core.begin_step(0, &grads);
        assert_eq!(core.step_width(), 3);
        assert_eq!(core.session().active_bits(), Some(3));
        core.begin_step(5, &grads);
        assert_eq!(core.step_width(), 2);
        assert_eq!(core.session().active_bits(), Some(2));
        // Fixed stays put; full precision reports 32.
        let mut fixed = BackendCore::new(cfg(Method::Alq, 2, ParallelMode::Serial));
        fixed.begin_step(0, &grads);
        assert_eq!(fixed.step_width(), 3);
        let mut fp = BackendCore::new(cfg(Method::SuperSgd, 2, ParallelMode::Serial));
        fp.begin_step(0, &grads);
        assert_eq!(fp.step_width(), 32);
    }

    #[test]
    fn overlap_pipeline_hides_wire_time_behind_encode() {
        let grads = vec![vec![0.1f32; 64]; 4];
        let mut core = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Auto));
        core.set_pipeline(PipelineMode::Overlap);
        assert_eq!(core.pipeline(), PipelineMode::Overlap);
        let hop = |bits, seconds| Hop {
            label: "a".to_string(),
            bits,
            seconds,
        };
        // Encode shorter than the wire: the whole encode is hidden.
        core.begin_step(0, &grads);
        core.note_encode_seconds(0.25);
        core.finish_step(vec![hop(10, 1.0)], 10, 1.0);
        assert!((core.meter().hidden_seconds - 0.25).abs() < 1e-12);
        // Encode longer than the wire: hiding clamps at the wire time,
        // and the ledger resets between steps.
        core.begin_step(1, &grads);
        core.note_encode_seconds(5.0);
        core.finish_step(vec![hop(10, 1.0)], 10, 1.0);
        assert!((core.meter().hidden_seconds - 1.25).abs() < 1e-12);
        // `total_time` is untouched by hiding.
        assert!((core.meter().total_time - 2.0).abs() < 1e-12);
        // `off` never hides, even with encode time on the ledger.
        let mut off = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Auto));
        off.begin_step(0, &grads);
        off.note_encode_seconds(0.25);
        off.finish_step(vec![hop(10, 1.0)], 10, 1.0);
        assert_eq!(off.meter().hidden_seconds, 0.0);
    }

    #[test]
    fn finish_step_installs_hops_and_meters() {
        let mut core = BackendCore::new(cfg(Method::Alq, 4, ParallelMode::Auto));
        core.finish_step(
            vec![
                Hop {
                    label: "a".to_string(),
                    bits: 60,
                    seconds: 0.5,
                },
                Hop {
                    label: "b".to_string(),
                    bits: 40,
                    seconds: 0.25,
                },
            ],
            100,
            0.75,
        );
        assert_eq!(core.last_hops().len(), 2);
        assert_eq!(core.meter().total_bits, 100);
        assert_eq!(core.meter().steps, 1);
    }
}
