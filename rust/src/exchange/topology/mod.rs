//! Executable exchange topologies (DESIGN.md §7-Topology).
//!
//! The flat engine ([`super::GradientExchange`]) realizes Algorithm 1's
//! all-to-all in one hop. This subsystem provides the schedules that
//! matter once M grows past a single switch — each one a *real,
//! executable* implementation of [`super::ExchangeBackend`] that moves
//! encoded frames hop by hop, not an analytical formula. All of them
//! embed the shared [`core::BackendCore`] (session, RNG forks, meter,
//! hop accounting, SingleSGD collapse — the DESIGN.md §8 determinism
//! contract) and differ only in their schedule:
//!
//! * [`ShardedExchange`] (`--topology sharded:S`) — parameters are
//!   partitioned into S bucket-aligned shards; each shard is gathered,
//!   decoded, and reduced by a different leader lane. Routing changes,
//!   payload content does not: the per-coordinate reduction order and
//!   every encoded bit are identical to the flat engine
//!   (`rust/tests/topology_parity.rs` asserts `params_hash` and
//!   per-step bits match flat exactly).
//! * [`HierarchicalExchange`] (`--topology tree:G`) — two-level tree: G
//!   groups reduce locally, group leaders exchange *re-quantized*
//!   partial aggregates, then broadcast down. Quantized payloads at
//!   every hop; the up-level re-quantization necessarily changes the
//!   reduction numerics, so its contract is a per-seed `params_hash`
//!   golden (deterministic, but distinct from flat).
//! * [`RingExchange`] (`--topology ring`) — bandwidth-optimal ring
//!   all-reduce over encoded chunks: M−1 reduce-scatter stages in which
//!   each worker re-quantizes and forwards a 1/M-sized partial sum, then
//!   M−1 all-gather stages relaying the reduced chunks. This turns the
//!   analytical `sim::network::Topology::Ring` formula into an actual
//!   schedule with the same 2(M−1)-stage shape.
//!
//! # Parallel lane fan-out
//!
//! `--parallel auto|on|off` applies to every gathered schedule, not just
//! flat: the member stage (all backends), the S shard-leader lanes
//! (sharded), and the G per-group leader reductions (tree) fan out via
//! [`core::fan_out`], with results and hop records always landing in
//! schedule order so parallel and serial runs are bit-identical
//! (`rust/tests/topology_parity.rs`). Ring stays serial by schedule
//! structure: its 2(M−1) stages form a sequential dependency chain and
//! mutate shared session statistics mid-stage (see `ring.rs`).
//!
//! # Metering contract
//!
//! Every backend reports per-hop [`Hop`] records. A hop's `bits` is the
//! total encoded payload that crosses links in that hop, and the step
//! total returned by `exchange()` is exactly Σ hop bits — a frame is
//! charged once per hop it traverses. Consequences:
//!
//! * flat and sharded charge each worker frame once (identical step
//!   totals — sharding only re-routes);
//! * tree charges member frames up, leader frames across, and leader
//!   frames again on the broadcast down (three hops);
//! * ring charges every stage's freshly encoded (or relayed) chunks —
//!   the classic 2(M−1)/M·payload per-link ring cost.
//!
//! Hop `seconds` charge the α-β [`crate::sim::NetworkModel`] per link:
//! serialized fan-in/out at endpoints, parallel links elsewhere. Hops
//! that run concurrently (the S shard lanes) contribute their max to
//! the step's time; sequential hops (tree levels, ring stages) sum.

pub mod core;
pub mod ring;
pub mod sharded;
pub mod tree;

pub use ring::RingExchange;
pub use sharded::ShardedExchange;
pub use tree::HierarchicalExchange;

use super::engine::{ExchangeConfig, GradientExchange};
use super::ExchangeBackend;

/// Which executable exchange schedule a run uses
/// (`--topology flat|sharded:S|tree:G|ring`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologySpec {
    /// The flat all-to-all engine (one hop; the paper's Algorithm 1).
    #[default]
    Flat,
    /// S shard leader lanes, each reducing a bucket-aligned slice of the
    /// parameters.
    Sharded(usize),
    /// G groups reducing locally under a two-level leader tree.
    Tree(usize),
    /// Ring all-reduce over encoded chunks (2(M−1) stages).
    Ring,
}

impl TopologySpec {
    /// Parse a CLI value (`flat`, `ring`, `sharded:S`, `tree:G`).
    pub fn parse(s: &str) -> Option<TopologySpec> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "flat" => return Some(TopologySpec::Flat),
            "ring" => return Some(TopologySpec::Ring),
            _ => {}
        }
        let (kind, n) = s.split_once(':')?;
        let n: usize = n.parse().ok()?;
        if n == 0 {
            return None;
        }
        match kind {
            "sharded" => Some(TopologySpec::Sharded(n)),
            "tree" => Some(TopologySpec::Tree(n)),
            _ => None,
        }
    }

    /// Canonical lowercase name for logs and banners.
    pub fn name(self) -> String {
        match self {
            TopologySpec::Flat => "flat".to_string(),
            TopologySpec::Sharded(s) => format!("sharded:{s}"),
            TopologySpec::Tree(g) => format!("tree:{g}"),
            TopologySpec::Ring => "ring".to_string(),
        }
    }
}

/// One hop of a topology's schedule: the encoded payload that crossed
/// links in the hop and the α-β time it was charged.
#[derive(Clone, Debug)]
pub struct Hop {
    /// Human-readable hop name ("shard2", "reduce-scatter[1]", …).
    pub label: String,
    /// Total encoded bits that crossed links in this hop.
    pub bits: u64,
    /// Modeled α-β seconds for this hop.
    pub seconds: f64,
}

/// Stand up the backend for a topology over the shared exchange config.
pub fn make_backend(cfg: ExchangeConfig, topo: TopologySpec) -> Box<dyn ExchangeBackend> {
    match topo {
        TopologySpec::Flat => Box::new(GradientExchange::new(cfg)),
        TopologySpec::Sharded(s) => Box::new(ShardedExchange::new(cfg, s)),
        TopologySpec::Tree(g) => Box::new(HierarchicalExchange::new(cfg, g)),
        TopologySpec::Ring => Box::new(RingExchange::new(cfg)),
    }
}

/// Bucket range owned by shard `s` of `shards` over `nb` full buckets
/// (shared by the sim backend and the TCP workers so both sides of the
/// wire agree on shard boundaries).
pub fn shard_buckets(nb: usize, shards: usize, s: usize) -> std::ops::Range<usize> {
    (s * nb / shards)..((s + 1) * nb / shards)
}

/// Worker range of group `g` of `groups` over `world` workers
/// (contiguous, sizes as even as possible; the group leader is the
/// first member).
pub fn group_members(world: usize, groups: usize, g: usize) -> std::ops::Range<usize> {
    (g * world / groups)..((g + 1) * world / groups)
}

/// Which group worker `w` belongs to.
pub fn group_of(w: usize, world: usize, groups: usize) -> usize {
    for g in 0..groups {
        if group_members(world, groups, g).contains(&w) {
            return g;
        }
    }
    unreachable!("worker {w} outside all {groups} groups of world {world}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses() {
        assert_eq!(TopologySpec::parse("flat"), Some(TopologySpec::Flat));
        assert_eq!(TopologySpec::parse("RING"), Some(TopologySpec::Ring));
        assert_eq!(
            TopologySpec::parse("sharded:4"),
            Some(TopologySpec::Sharded(4))
        );
        assert_eq!(TopologySpec::parse("tree:2"), Some(TopologySpec::Tree(2)));
        assert_eq!(TopologySpec::parse("sharded:0"), None);
        assert_eq!(TopologySpec::parse("tree"), None);
        assert_eq!(TopologySpec::parse("mesh:3"), None);
        assert_eq!(TopologySpec::default().name(), "flat");
        assert_eq!(TopologySpec::Sharded(8).name(), "sharded:8");
    }

    #[test]
    fn shard_partition_covers_buckets_exactly_once() {
        for (nb, shards) in [(10usize, 3usize), (4, 4), (2, 5), (0, 2), (7, 1)] {
            let mut covered = 0;
            for s in 0..shards {
                let r = shard_buckets(nb, shards, s);
                assert_eq!(r.start, covered, "nb={nb} shards={shards} s={s}");
                covered = r.end;
            }
            assert_eq!(covered, nb);
        }
    }

    #[test]
    fn group_partition_covers_workers_exactly_once() {
        for (world, groups) in [(8usize, 2usize), (8, 3), (4, 4), (5, 2), (6, 1)] {
            let mut covered = 0;
            for g in 0..groups {
                let r = group_members(world, groups, g);
                assert_eq!(r.start, covered);
                covered = r.end;
                for w in r.clone() {
                    assert_eq!(group_of(w, world, groups), g);
                }
            }
            assert_eq!(covered, world);
        }
    }
}
