//! Error-feedback residual memory + lazy-aggregation skip policy.
//!
//! Two composable mechanisms that make per-worker frames *optional*:
//!
//! * [`ErrorFeedback`] — the EF-SGD residual accumulator. Before
//!   quantizing, a worker adds the residual left over from previous
//!   steps to its raw gradient (`corrected = grad + residual`); after
//!   the exchange it stores what the wire failed to carry
//!   (`residual = corrected − ĝ` on a sent frame, `residual =
//!   corrected` on a skipped one). Nothing is ever silently dropped —
//!   a skipped or coarsely-quantized update is retransmitted, smeared
//!   over later steps, which is what makes aggressive 1–2 bit widths
//!   trainable.
//! * [`LazyPolicy`] / [`LazyWorker`] — the LAQ-style skip rule. A
//!   worker whose (corrected) update is small sends a
//!   [`SKIP_MARKER_BITS`]-bit skip marker instead of a frame; the
//!   survivors are aggregated with renormalized weights (the same
//!   partial-aggregation contract elastic membership uses, so "silent
//!   this step" rides the "absent this run" path).
//!
//! # Determinism contract
//!
//! Skip decisions are pure functions of the worker's own message and
//! its private `LazyWorker` state: norms accumulate sequentially in
//! `f64`, no RNG is consumed, and a skipped worker draws *nothing*
//! from its quantization stream — so the sim and the TCP runtime make
//! identical decisions on identical gradients, and `--error-feedback
//! off --lazy off` leaves every existing trajectory bit-identical
//! (the fast path never touches these types). See DESIGN.md §Feedback.

use std::fmt;

/// Wire cost charged for a skip marker, in bits: the `SkipGrad` frame
/// is `[tag u8][len u32][step u32][worker u32]` = 13 bytes on the TCP
/// wire, and the sim charges the same 104 bits so zero-frame steps
/// meter identically on both runtimes.
pub const SKIP_MARKER_BITS: u64 = 104;

/// When a worker may keep its update to itself (`--lazy`).
///
/// The grammar mirrors `--bits-policy`: `off`, `thresh:T`, or
/// `laq:C@K`, with [`LazyPolicy::parse`] accepting exactly what
/// [`LazyPolicy::name`] prints.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum LazyPolicy {
    /// Every active worker sends every step (the default; bit-identical
    /// to the pre-feedback engine).
    #[default]
    Off,
    /// Send iff the L2 norm of the outgoing message is at least `T`
    /// (stateless magnitude gate).
    Thresh(f64),
    /// LAQ reference-gradient rule: send iff the squared distance to
    /// the last *sent* message exceeds `C·‖reference‖²`, or `K`
    /// consecutive skips have accumulated (bounded staleness). The
    /// first step always sends (no reference yet).
    Laq {
        /// Gain on the reference-norm threshold (`C`).
        c: f64,
        /// Patience: maximum consecutive skips before a forced send.
        k: u32,
    },
}

impl LazyPolicy {
    /// Parse a `--lazy` spec; `None` on anything malformed.
    pub fn parse(s: &str) -> Option<LazyPolicy> {
        LazyPolicy::parse_strict(s).ok()
    }

    /// Parse a `--lazy` spec with a diagnostic explaining the rejection.
    pub fn parse_strict(s: &str) -> Result<LazyPolicy, String> {
        let s = s.trim().to_ascii_lowercase();
        if s.is_empty() {
            return Err("empty lazy policy (expected off | thresh:T | laq:C@K)".to_string());
        }
        if s == "off" {
            return Ok(LazyPolicy::Off);
        }
        if let Some(spec) = s.strip_prefix("thresh:") {
            let t: f64 = spec
                .parse()
                .map_err(|_| format!("invalid lazy threshold {spec:?}"))?;
            if !(t.is_finite() && t > 0.0) {
                return Err(format!(
                    "lazy threshold must be positive and finite, got {spec:?}"
                ));
            }
            return Ok(LazyPolicy::Thresh(t));
        }
        if let Some(spec) = s.strip_prefix("laq:") {
            let (c_str, k_str) = spec
                .split_once('@')
                .ok_or_else(|| format!("lazy policy {spec:?} missing '@K' patience"))?;
            let c: f64 = c_str
                .parse()
                .map_err(|_| format!("invalid laq gain {c_str:?}"))?;
            if !(c.is_finite() && c > 0.0) {
                return Err(format!("laq gain must be positive and finite, got {c_str:?}"));
            }
            let k: u32 = k_str
                .parse()
                .map_err(|_| format!("invalid laq patience {k_str:?}"))?;
            if k == 0 {
                return Err(format!("laq patience must be at least 1, got {k_str:?}"));
            }
            return Ok(LazyPolicy::Laq { c, k });
        }
        Err(format!(
            "unknown lazy policy {s:?} (expected off | thresh:T | laq:C@K)"
        ))
    }

    /// Canonical spec string; `LazyPolicy::parse(p.name()) == Some(p)`
    /// for every constructible policy (f64 `Display` is the shortest
    /// round-trippable decimal).
    pub fn name(&self) -> String {
        match self {
            LazyPolicy::Off => "off".to_string(),
            LazyPolicy::Thresh(t) => format!("thresh:{t}"),
            LazyPolicy::Laq { c, k } => format!("laq:{c}@{k}"),
        }
    }

    /// Whether this policy never skips (the bit-identity fast path).
    pub fn is_off(&self) -> bool {
        matches!(self, LazyPolicy::Off)
    }
}

impl fmt::Display for LazyPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// One worker's private skip-rule state (the LAQ reference message and
/// skip streak). Cheap and inert under `LazyPolicy::Off`/`Thresh`.
#[derive(Clone, Debug, Default)]
pub struct LazyWorker {
    /// The last message this worker actually sent (LAQ's comparison
    /// model); empty until the first send.
    reference: Vec<f32>,
    /// Consecutive skips since the last send.
    streak: u32,
}

impl LazyWorker {
    /// Decide whether to send `msg` this step under `policy`, updating
    /// the reference/streak state to match the decision. Returns `true`
    /// to send a frame, `false` to send a skip marker.
    pub fn decide(&mut self, policy: &LazyPolicy, msg: &[f32]) -> bool {
        let send = match policy {
            LazyPolicy::Off => true,
            LazyPolicy::Thresh(t) => norm2(msg).sqrt() >= *t,
            LazyPolicy::Laq { c, k } => {
                self.reference.is_empty()
                    || self.streak >= *k
                    || diff_norm2(msg, &self.reference) > c * norm2(&self.reference)
            }
        };
        if send {
            self.streak = 0;
            if matches!(policy, LazyPolicy::Laq { .. }) {
                self.reference.clear();
                self.reference.extend_from_slice(msg);
            }
        } else {
            self.streak = self.streak.saturating_add(1);
        }
        send
    }

    /// Consecutive skips since this worker last sent a frame.
    pub fn streak(&self) -> u32 {
        self.streak
    }
}

/// Per-worker error-feedback residual memory.
///
/// Buffers are lazily sized on first use, so a world-sized
/// `ErrorFeedback` costs nothing for workers that never participate.
/// All arithmetic is element-wise `f32` in coordinate order — identical
/// on the sim and TCP runtimes by construction.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    /// What the wire has not carried yet, per worker.
    residual: Vec<Vec<f32>>,
    /// This step's outgoing message per worker: `grad + residual`.
    corrected: Vec<Vec<f32>>,
}

impl ErrorFeedback {
    /// Residual memory for `world` workers, all starting at zero.
    pub fn new(world: usize) -> Self {
        ErrorFeedback {
            residual: vec![Vec::new(); world],
            corrected: vec![Vec::new(); world],
        }
    }

    /// Compute worker `w`'s outgoing message for this step:
    /// `corrected = grad + residual` (an empty residual reads as zero).
    pub fn correct(&mut self, w: usize, grad: &[f32]) {
        let out = &mut self.corrected[w];
        out.clear();
        out.extend_from_slice(grad);
        let res = &self.residual[w];
        debug_assert!(res.is_empty() || res.len() == grad.len());
        for (o, &r) in out.iter_mut().zip(res.iter()) {
            *o += r;
        }
    }

    /// Worker `w`'s corrected message from the last [`ErrorFeedback::correct`].
    pub fn corrected(&self, w: usize) -> &[f32] {
        &self.corrected[w]
    }

    /// Skip path: the whole corrected message becomes the residual
    /// (nothing crossed the wire, nothing is lost).
    pub fn absorb(&mut self, w: usize) {
        let res = &mut self.residual[w];
        res.clear();
        res.extend_from_slice(&self.corrected[w]);
    }

    /// Send path: store what quantization failed to carry,
    /// `residual = corrected − ĝ`.
    pub fn settle(&mut self, w: usize, ghat: &[f32]) {
        let cor = &self.corrected[w];
        assert_eq!(cor.len(), ghat.len(), "settle needs the decoded estimate");
        let res = &mut self.residual[w];
        res.clear();
        res.extend(cor.iter().zip(ghat).map(|(&c, &g)| c - g));
    }

    /// Send path for lossless (fp32) sessions: `ĝ == corrected`, so the
    /// residual is exactly zero.
    pub fn clear_residual(&mut self, w: usize) {
        self.residual[w].clear();
    }

    /// L2 norm of worker `w`'s current residual (telemetry:
    /// `feedback_norm` events).
    pub fn residual_norm(&self, w: usize) -> f64 {
        norm2(&self.residual[w]).sqrt()
    }
}

/// Σ x² accumulated sequentially in f64: deterministic across runtimes
/// and `--parallel` modes (skip decisions happen on the serial planning
/// path in both).
fn norm2(x: &[f32]) -> f64 {
    x.iter().fold(0.0f64, |acc, &v| acc + (v as f64) * (v as f64))
}

/// Σ (a−b)², sequential f64 (LAQ's distance to the reference message).
fn diff_norm2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .fold(0.0f64, |acc, (&x, &y)| acc + ((x - y) as f64) * ((x - y) as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_grammar_roundtrips() {
        for spec in ["off", "thresh:0.5", "thresh:12", "laq:0.1@4", "laq:2@1"] {
            let p = LazyPolicy::parse(spec).unwrap_or_else(|| panic!("parse {spec}"));
            assert_eq!(LazyPolicy::parse(&p.name()), Some(p), "{spec}");
        }
        assert_eq!(LazyPolicy::parse("OFF"), Some(LazyPolicy::Off));
        assert_eq!(LazyPolicy::parse(" thresh:1.5 "), Some(LazyPolicy::Thresh(1.5)));
    }

    #[test]
    fn policy_rejections_explain_themselves() {
        for (spec, needle) in [
            ("", "empty lazy policy"),
            ("thresh:", "invalid lazy threshold"),
            ("thresh:abc", "invalid lazy threshold"),
            ("thresh:-1", "must be positive"),
            ("thresh:inf", "must be positive and finite"),
            ("laq:0.5", "missing '@K'"),
            ("laq:x@3", "invalid laq gain"),
            ("laq:-2@3", "laq gain must be positive"),
            ("laq:0.5@x", "invalid laq patience"),
            ("laq:0.5@0", "patience must be at least 1"),
            ("always", "unknown lazy policy"),
        ] {
            let err = LazyPolicy::parse_strict(spec).unwrap_err();
            assert!(err.contains(needle), "{spec:?}: {err}");
            assert_eq!(LazyPolicy::parse(spec), None, "{spec:?}");
        }
    }

    #[test]
    fn thresh_gates_on_message_norm() {
        let mut w = LazyWorker::default();
        let policy = LazyPolicy::Thresh(1.0);
        assert!(w.decide(&policy, &[1.0, 0.0, 0.0])); // ‖msg‖ = 1 ≥ 1
        assert!(!w.decide(&policy, &[0.5, 0.5, 0.0])); // ‖msg‖ < 1
        assert_eq!(w.streak(), 1);
    }

    #[test]
    fn laq_sends_first_then_skips_until_drift_or_patience() {
        let mut w = LazyWorker::default();
        let policy = LazyPolicy::Laq { c: 0.25, k: 3 };
        let base = [1.0f32, 0.0, 0.0];
        assert!(w.decide(&policy, &base), "no reference yet: must send");
        // Same message: distance 0 ≤ 0.25·1 → skip, three times.
        assert!(!w.decide(&policy, &base));
        assert!(!w.decide(&policy, &base));
        assert!(!w.decide(&policy, &base));
        // Patience exhausted: forced send even with zero drift.
        assert!(w.decide(&policy, &base));
        // Large drift sends immediately.
        assert!(w.decide(&policy, &[2.0, 0.0, 0.0]));
    }

    #[test]
    fn feedback_residual_accumulates_and_settles() {
        let mut fb = ErrorFeedback::new(2);
        fb.correct(0, &[1.0, -2.0]);
        assert_eq!(fb.corrected(0), &[1.0, -2.0]);
        // Skip: whole message retained.
        fb.absorb(0);
        assert!((fb.residual_norm(0) - (5.0f64).sqrt()).abs() < 1e-12);
        // Next step the residual rides along.
        fb.correct(0, &[1.0, 1.0]);
        assert_eq!(fb.corrected(0), &[2.0, -1.0]);
        // Send: residual is the quantization error.
        fb.settle(0, &[1.5, -1.5]);
        fb.correct(0, &[0.0, 0.0]);
        assert_eq!(fb.corrected(0), &[0.5, 0.5]);
        // Lossless send: residual clears.
        fb.clear_residual(0);
        assert_eq!(fb.residual_norm(0), 0.0);
        // Worker 1 untouched throughout.
        assert_eq!(fb.residual_norm(1), 0.0);
    }

    #[test]
    fn skip_marker_is_the_wire_frame_size() {
        // [tag u8][len u32][step u32][worker u32] = 13 bytes.
        assert_eq!(SKIP_MARKER_BITS, 8 * 13);
    }
}
