//! Normal distribution N(μ, σ²) (Appendix A.1).

use super::special::{phi, phi_inv, phi_pdf};
use super::Dist;

/// Normal distribution with mean `mu` and standard deviation `sigma`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma > 0.0, "sigma must be positive, got {sigma}");
        Normal { mu, sigma }
    }

    #[inline]
    pub fn std(&self, x: f64) -> f64 {
        (x - self.mu) / self.sigma
    }
}

impl Dist for Normal {
    fn cdf(&self, x: f64) -> f64 {
        phi(self.std(x))
    }

    fn pdf(&self, x: f64) -> f64 {
        phi_pdf(self.std(x)) / self.sigma
    }

    /// ∫_c^d x dF = μ(Φ(d̃)−Φ(c̃)) − σ(φ(d̃)−φ(c̃)).
    fn partial_mean(&self, c: f64, d: f64) -> f64 {
        let (ct, dt) = (self.std(c), self.std(d));
        self.mu * (phi(dt) - phi(ct)) - self.sigma * (phi_pdf(dt) - phi_pdf(ct))
    }

    /// ∫_c^d x² dF = (μ²+σ²)ΔΦ + 2μσ(φ(c̃)−φ(d̃)) + σ²(c̃φ(c̃)−d̃φ(d̃)).
    fn partial_mean_sq(&self, c: f64, d: f64) -> f64 {
        let (ct, dt) = (self.std(c), self.std(d));
        let dphi = phi(dt) - phi(ct);
        (self.mu * self.mu + self.sigma * self.sigma) * dphi
            + 2.0 * self.mu * self.sigma * (phi_pdf(ct) - phi_pdf(dt))
            + self.sigma * self.sigma * (ct * phi_pdf(ct) - dt * phi_pdf(dt))
    }

    fn inv_cdf(&self, y: f64) -> f64 {
        self.mu + self.sigma * phi_inv(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::simpson;

    #[test]
    fn cdf_pdf_consistent() {
        let n = Normal::new(0.3, 0.7);
        let got = simpson(|x| n.pdf(x), -1.0, 1.2, 400);
        assert!((got - (n.cdf(1.2) - n.cdf(-1.0))).abs() < 1e-10);
    }

    #[test]
    fn partial_mean_matches_quadrature() {
        let n = Normal::new(0.1, 0.4);
        let got = n.partial_mean(-0.5, 0.8);
        let want = simpson(|x| x * n.pdf(x), -0.5, 0.8, 800);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn partial_mean_sq_matches_quadrature() {
        let n = Normal::new(-0.2, 0.6);
        let got = n.partial_mean_sq(-1.0, 1.0);
        let want = simpson(|x| x * x * n.pdf(x), -1.0, 1.0, 800);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn full_moments() {
        let n = Normal::new(1.5, 2.0);
        // Over (−∞, ∞): mean and second moment.
        let m1 = n.partial_mean(-60.0, 60.0);
        let m2 = n.partial_mean_sq(-60.0, 60.0);
        assert!((m1 - 1.5).abs() < 1e-12);
        assert!((m2 - (1.5f64.powi(2) + 4.0)).abs() < 1e-10);
    }

    #[test]
    fn inv_cdf_roundtrip() {
        let n = Normal::new(0.05, 0.01);
        for p in [0.01, 0.3, 0.5, 0.9, 0.999] {
            assert!((n.cdf(n.inv_cdf(p)) - p).abs() < 1e-11);
        }
    }
}
