//! Special functions: erf, erfc, Φ, Φ⁻¹ — from scratch, ~1e-14 accurate.
//!
//! erf uses the Maclaurin series for small |x| and a Lentz continued
//! fraction for erfc at large |x| (Numerical Recipes §6.2 structure);
//! Φ⁻¹ is Acklam's rational approximation polished with one Halley step
//! against our own Φ, giving ~1e-15 relative error.

use std::f64::consts::{FRAC_2_SQRT_PI, SQRT_2};

/// Error function.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x < 2.0 {
        erf_series(x)
    } else {
        1.0 - erfc_cf(x)
    }
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x < 2.0 {
        1.0 - erf_series(x)
    } else {
        erfc_cf(x)
    }
}

/// Maclaurin series: erf(x) = 2/√π Σ (-1)^n x^{2n+1} / (n! (2n+1)).
fn erf_series(x: f64) -> f64 {
    let x2 = x * x;
    let mut term = x; // x^{2n+1} / n!
    let mut sum = x;
    for n in 1..200 {
        term *= -x2 / n as f64;
        let add = term / (2 * n + 1) as f64;
        sum += add;
        if add.abs() < 1e-18 * sum.abs().max(1e-300) {
            break;
        }
    }
    FRAC_2_SQRT_PI * sum
}

/// Continued fraction for erfc(x), x >= 2 (Lentz's algorithm).
///
/// A&S 7.1.14: √π e^{x²} erfc(x) = 1/(x + 1/(2x + 2/(x + 3/(2x + 4/(x + …)))))
/// i.e. partial numerators a_n = n and denominators alternating 2x, x.
fn erfc_cf(x: f64) -> f64 {
    let tiny = 1e-300;
    let mut f = x.max(tiny); // b_0 = x
    let mut c = f;
    let mut d = 0.0;
    for n in 1..300 {
        let a = n as f64;
        let b = if n % 2 == 1 { 2.0 * x } else { x };
        d = b + a * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + a / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x * x).exp() / std::f64::consts::PI.sqrt() / f
}

/// Standard normal CDF Φ(x).
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / SQRT_2)
}

/// Standard normal PDF φ(x).
pub fn phi_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal quantile Φ⁻¹(p) (Acklam + one Halley refinement).
pub fn phi_inv(p: f64) -> f64 {
    if p <= 0.0 {
        return f64::NEG_INFINITY;
    }
    if p >= 1.0 {
        return f64::INFINITY;
    }
    // Acklam's coefficients.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    let x = if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley step: e = Φ(x) - p; x' = x - 2e/(2φ(x) + e x).
    let e = phi(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (0.5 * x * x).exp();
    x - u / (1.0 + 0.5 * x * u)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from standard tables / mpmath.
    const ERF_TABLE: &[(f64, f64)] = &[
        (0.0, 0.0),
        (0.1, 0.1124629160182849),
        (0.5, 0.5204998778130465),
        (1.0, 0.8427007929497149),
        (1.5, 0.9661051464753107),
        (2.0, 0.9953222650189527),
        (3.0, 0.9999779095030014),
        (4.0, 0.9999999845827421),
    ];

    #[test]
    fn erf_matches_table() {
        for &(x, want) in ERF_TABLE {
            let got = erf(x);
            assert!((got - want).abs() < 1e-13, "erf({x}) = {got}, want {want}");
            assert!((erf(-x) + want).abs() < 1e-13);
        }
    }

    #[test]
    fn erfc_complements() {
        for x in [-3.0, -1.0, 0.0, 0.5, 1.0, 2.5, 4.0, 6.0] {
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-13, "x={x}");
        }
    }

    #[test]
    fn erfc_large_x_positive() {
        // erfc(5) = 1.5374597944280349e-12 (mpmath).
        let got = erfc(5.0);
        assert!((got - 1.5374597944280349e-12).abs() / 1.54e-12 < 1e-10, "{got}");
    }

    #[test]
    fn phi_known_values() {
        assert!((phi(0.0) - 0.5).abs() < 1e-15);
        assert!((phi(1.959963984540054) - 0.975).abs() < 1e-12);
        assert!((phi(-1.0) - 0.15865525393145707).abs() < 1e-13);
        assert!((phi(2.326347874040841) - 0.99).abs() < 1e-12);
    }

    #[test]
    fn phi_inv_roundtrip() {
        for p in [1e-10, 1e-5, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0 - 1e-10] {
            let x = phi_inv(p);
            assert!((phi(x) - p).abs() < 1e-12, "p={p}, x={x}, phi={}", phi(x));
        }
    }

    #[test]
    fn phi_inv_known() {
        assert!((phi_inv(0.975) - 1.959963984540054).abs() < 1e-9);
        assert!(phi_inv(0.5).abs() < 1e-12);
    }

    #[test]
    fn pdf_integrates_to_cdf() {
        // ∫_{-1}^{1.5} φ = Φ(1.5) - Φ(-1)
        let got = crate::util::simpson(phi_pdf, -1.0, 1.5, 400);
        assert!((got - (phi(1.5) - phi(-1.0))).abs() < 1e-10);
    }
}
