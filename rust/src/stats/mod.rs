//! Probability substrate for the adaptive level optimizers.
//!
//! The paper models the distribution of normalized gradient coordinates
//! `r = |v_i| / ||v||` with (mixtures of) truncated normal distributions
//! (Section 3.4, Appendices A–C, K). Everything the optimizers need is the
//! closed-form integrals of those distributions; this module provides them
//! from scratch (no external math deps):
//!
//! * [`special`] — erf / erfc / Φ / Φ⁻¹.
//! * [`normal`] — the normal distribution.
//! * [`truncnorm`] — truncated normal with the paper's partial-moment
//!   closed forms (`∫ r dF`, `∫ r² dF`).
//! * [`mixture`] — weighted mixtures `F̄ = Σ γ_n F_n` (Eq. 10).
//! * [`histogram`] — nonparametric piecewise-uniform alternative (App. K
//!   notes the authors fall back to histograms when σ is tiny).
//! * [`moments`] — streaming per-bucket sufficient statistics.

pub mod histogram;
pub mod mixture;
pub mod moments;
pub mod normal;
pub mod special;
pub mod truncnorm;

pub use histogram::Histogram;
pub use mixture::Mixture;
pub use moments::{BucketStats, OnlineMoments};
pub use normal::Normal;
pub use truncnorm::TruncNormal;

/// A distribution of normalized coordinates supported on `[0, 1]`.
///
/// All the level-update rules (Theorem 1 / Eqs. 33–38) are written in terms
/// of these four primitives; `ALQ`, `GD` and `AMQ` are generic over them.
pub trait Dist {
    /// Cumulative distribution function F(x).
    fn cdf(&self, x: f64) -> f64;
    /// Density p(x).
    fn pdf(&self, x: f64) -> f64;
    /// Partial mean `∫_c^d r dF(r)`.
    fn partial_mean(&self, c: f64, d: f64) -> f64;
    /// Partial second moment `∫_c^d r² dF(r)`.
    fn partial_mean_sq(&self, c: f64, d: f64) -> f64;

    /// Inverse CDF via bisection on `[0, 1]` (override when closed-form).
    fn inv_cdf(&self, y: f64) -> f64 {
        let y = y.clamp(0.0, 1.0);
        crate::util::bisect(|x| self.cdf(x) - y, 0.0, 1.0, 1e-12, 200)
    }
}
