//! Weighted mixture of truncated normals — the paper's `F̄(r) = Σ γ_n F_n(r)`
//! (Section 3.4, Eq. 10).
//!
//! * For **expected variance** minimization (ALQ/AMQ), `γ_n ∝ ‖v_n‖²`.
//! * For **expected normalized variance** (ALQ-N/AMQ-N, Eq. 3), γ_n = 1/N.
//!
//! All `Dist` primitives are linear in the mixture, so the closed forms of
//! `TruncNormal` lift directly; the inverse CDF falls back to bisection.

use super::truncnorm::TruncNormal;
use super::Dist;

#[derive(Clone, Debug)]
pub struct Mixture {
    comps: Vec<TruncNormal>,
    weights: Vec<f64>,
}

impl Mixture {
    /// Build from components and unnormalized nonnegative weights.
    pub fn new(comps: Vec<TruncNormal>, weights: Vec<f64>) -> Self {
        assert_eq!(comps.len(), weights.len());
        assert!(!comps.is_empty(), "mixture needs at least one component");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        let weights = weights.iter().map(|w| w / total).collect();
        Mixture { comps, weights }
    }

    /// Uniform-weight mixture (the `-N` objective of Eq. 3).
    pub fn uniform(comps: Vec<TruncNormal>) -> Self {
        let n = comps.len();
        Self::new(comps, vec![1.0; n])
    }

    /// Single-component convenience.
    pub fn single(c: TruncNormal) -> Self {
        Self::new(vec![c], vec![1.0])
    }

    pub fn components(&self) -> &[TruncNormal] {
        &self.comps
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn len(&self) -> usize {
        self.comps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.comps.is_empty()
    }

    #[inline]
    fn sum<F: Fn(&TruncNormal) -> f64>(&self, f: F) -> f64 {
        self.comps
            .iter()
            .zip(&self.weights)
            .map(|(c, w)| w * f(c))
            .sum()
    }
}

impl Dist for Mixture {
    fn cdf(&self, x: f64) -> f64 {
        self.sum(|c| c.cdf(x))
    }

    fn pdf(&self, x: f64) -> f64 {
        self.sum(|c| c.pdf(x))
    }

    fn partial_mean(&self, c0: f64, d: f64) -> f64 {
        self.sum(|c| c.partial_mean(c0, d))
    }

    fn partial_mean_sq(&self, c0: f64, d: f64) -> f64 {
        self.sum(|c| c.partial_mean_sq(c0, d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::simpson;

    fn mix() -> Mixture {
        Mixture::new(
            vec![
                TruncNormal::unit(0.02, 0.01),
                TruncNormal::unit(0.10, 0.05),
                TruncNormal::unit(0.30, 0.20),
            ],
            vec![3.0, 2.0, 1.0],
        )
    }

    #[test]
    fn weights_normalized() {
        let m = mix();
        let s: f64 = m.weights().iter().sum();
        assert!((s - 1.0).abs() < 1e-15);
        assert!((m.weights()[0] - 0.5).abs() < 1e-15);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let m = mix();
        let mut prev = -1.0;
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            let f = m.cdf(x);
            assert!((0.0..=1.0).contains(&f));
            assert!(f >= prev - 1e-14);
            prev = f;
        }
        assert!(m.cdf(0.0).abs() < 1e-12);
        assert!((m.cdf(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pdf_matches_cdf() {
        let m = mix();
        let got = simpson(|x| m.pdf(x), 0.05, 0.7, 4000);
        assert!((got - (m.cdf(0.7) - m.cdf(0.05))).abs() < 1e-8);
    }

    #[test]
    fn partial_moments_match_quadrature() {
        let m = mix();
        let m1 = m.partial_mean(0.0, 0.5);
        let w1 = simpson(|x| x * m.pdf(x), 0.0, 0.5, 4000);
        assert!((m1 - w1).abs() < 1e-8, "{m1} vs {w1}");
        let m2 = m.partial_mean_sq(0.1, 0.9);
        let w2 = simpson(|x| x * x * m.pdf(x), 0.1, 0.9, 4000);
        assert!((m2 - w2).abs() < 1e-8, "{m2} vs {w2}");
    }

    #[test]
    fn inv_cdf_roundtrip_bisection() {
        let m = mix();
        for p in [0.01, 0.25, 0.5, 0.75, 0.99] {
            let x = m.inv_cdf(p);
            assert!((m.cdf(x) - p).abs() < 1e-9, "p={p}");
        }
    }

    #[test]
    fn single_equals_component() {
        let t = TruncNormal::unit(0.1, 0.05);
        let m = Mixture::single(t);
        for x in [0.0, 0.1, 0.5, 1.0] {
            assert_eq!(m.cdf(x), t.cdf(x));
        }
    }
}
