//! Streaming moment accumulators and per-bucket sufficient statistics.
//!
//! Algorithm 1 line 4: at update steps every worker computes sufficient
//! statistics of the normalized-coordinate distribution. The statistics per
//! bucket are (μ, σ², ‖v‖) — exactly what the L1 `stats` Pallas kernel
//! produces on-device; this is the host-side equivalent plus Welford
//! accumulators used by the variance-tracking experiments (Figs. 1/4/5).

/// Numerically stable online mean/variance (Welford).
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineMoments {
    n: u64,
    mean: f64,
    m2: f64,
}

impl OnlineMoments {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).max(0.0)
        }
    }

    pub fn merge(&mut self, o: &OnlineMoments) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = (self.n + o.n) as f64;
        let d = o.mean - self.mean;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64) / n;
        self.mean += d * o.n as f64 / n;
        self.n += o.n;
    }
}

/// Sufficient statistics of one bucket's normalized coordinates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BucketStats {
    /// Mean of r within the bucket.
    pub mu: f64,
    /// Population variance of r within the bucket.
    pub sigma2: f64,
    /// Bucket norm (the normalizer).
    pub norm: f64,
}

impl BucketStats {
    /// Compute (μ, σ², ‖·‖) of normalized coordinates for one bucket,
    /// matching `python/compile/kernels/stats.py` semantics.
    pub fn from_bucket(v: &[f32], norm_type: crate::quant::NormType) -> BucketStats {
        let norm = crate::quant::bucket_norm(v, norm_type) as f64;
        if norm == 0.0 {
            return BucketStats { mu: 0.0, sigma2: 0.0, norm: 0.0 };
        }
        let inv = 1.0 / norm;
        let n = v.len() as f64;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for &x in v {
            let r = (x.abs() as f64 * inv).clamp(0.0, 1.0);
            s1 += r;
            s2 += r * r;
        }
        let mu = s1 / n;
        BucketStats { mu, sigma2: (s2 / n - mu * mu).max(0.0), norm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::NormType;

    #[test]
    fn welford_matches_direct() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 7919) % 1000) as f64 / 999.0).collect();
        let mut m = OnlineMoments::new();
        for &x in &xs {
            m.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((m.mean() - mean).abs() < 1e-12);
        assert!((m.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge() {
        let mut a = OnlineMoments::new();
        let mut b = OnlineMoments::new();
        let mut all = OnlineMoments::new();
        let mut rng = crate::util::Rng::new(1);
        for i in 0..500 {
            let x = rng.normal();
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-12);
    }

    #[test]
    fn bucket_stats_l2() {
        let v = [3.0f32, -4.0];
        let s = BucketStats::from_bucket(&v, NormType::L2);
        assert!((s.norm - 5.0).abs() < 1e-6);
        // r = [0.6, 0.8]; mu = 0.7; var = 0.01
        assert!((s.mu - 0.7).abs() < 1e-6);
        assert!((s.sigma2 - 0.01).abs() < 1e-6);
    }

    #[test]
    fn bucket_stats_zero() {
        let v = [0.0f32; 8];
        let s = BucketStats::from_bucket(&v, NormType::L2);
        assert_eq!(s.norm, 0.0);
        assert_eq!(s.mu, 0.0);
    }

    #[test]
    fn bucket_stats_linf() {
        let v = [1.0f32, -2.0, 0.5, 0.0];
        let s = BucketStats::from_bucket(&v, NormType::Linf);
        assert!((s.norm - 2.0).abs() < 1e-9);
        let want_mu = (0.5 + 1.0 + 0.25 + 0.0) / 4.0;
        assert!((s.mu - want_mu).abs() < 1e-6);
    }
}
