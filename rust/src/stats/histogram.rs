//! Nonparametric histogram distribution on [0, 1].
//!
//! Appendix K: "we use histograms to model the distribution of gradients as
//! a weighted sum of truncated normals" — the histogram is both (a) the raw
//! accumulator the estimator fills from sampled coordinates, and (b) a
//! `Dist` in its own right (piecewise-uniform density), which gives an
//! assumption-free alternative to the truncated-normal mixture for ALQ.

use super::Dist;

#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bin counts (len = bins) over [0,1], plus total.
    counts: Vec<f64>,
    total: f64,
}

impl Histogram {
    pub fn new(bins: usize) -> Self {
        assert!(bins >= 1);
        Histogram {
            counts: vec![0.0; bins],
            total: 0.0,
        }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    pub fn total(&self) -> f64 {
        self.total
    }

    #[inline]
    pub fn add(&mut self, r: f64) {
        self.add_weighted(r, 1.0);
    }

    #[inline]
    pub fn add_weighted(&mut self, r: f64, w: f64) {
        let b = ((r.clamp(0.0, 1.0)) * self.counts.len() as f64) as usize;
        let b = b.min(self.counts.len() - 1);
        self.counts[b] += w;
        self.total += w;
    }

    pub fn add_slice(&mut self, rs: &[f32]) {
        for &r in rs {
            self.add(r as f64);
        }
    }

    /// Merge another histogram (same binning) into this one.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bins(), other.bins());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }

    fn width(&self) -> f64 {
        1.0 / self.counts.len() as f64
    }
}

impl Dist for Histogram {
    fn cdf(&self, x: f64) -> f64 {
        if self.total == 0.0 {
            return x.clamp(0.0, 1.0); // degenerate: uniform
        }
        let x = x.clamp(0.0, 1.0);
        let w = self.width();
        let full = (x / w) as usize;
        let full = full.min(self.bins());
        let mut acc: f64 = self.counts[..full].iter().sum();
        if full < self.bins() {
            let frac = (x - full as f64 * w) / w;
            acc += self.counts[full] * frac;
        }
        acc / self.total
    }

    fn pdf(&self, x: f64) -> f64 {
        if self.total == 0.0 {
            return if (0.0..=1.0).contains(&x) { 1.0 } else { 0.0 };
        }
        if !(0.0..=1.0).contains(&x) {
            return 0.0;
        }
        let b = ((x * self.bins() as f64) as usize).min(self.bins() - 1);
        self.counts[b] / (self.total * self.width())
    }

    /// Piecewise closed form: within a bin the density is constant, so
    /// `∫ r dF` over a sub-interval [c,d] of bin b is `p_b (d²−c²)/2`.
    fn partial_mean(&self, c: f64, d: f64) -> f64 {
        self.piecewise(c, d, |lo, hi| 0.5 * (hi * hi - lo * lo))
    }

    fn partial_mean_sq(&self, c: f64, d: f64) -> f64 {
        self.piecewise(c, d, |lo, hi| (hi * hi * hi - lo * lo * lo) / 3.0)
    }
}

impl Histogram {
    fn piecewise<F: Fn(f64, f64) -> f64>(&self, c: f64, d: f64, seg: F) -> f64 {
        let (c, d) = (c.clamp(0.0, 1.0), d.clamp(0.0, 1.0));
        if c >= d {
            return 0.0;
        }
        let w = self.width();
        let mut acc = 0.0;
        let b0 = ((c / w) as usize).min(self.bins() - 1);
        let b1 = ((d / w) as usize).min(self.bins() - 1);
        for b in b0..=b1 {
            let lo = (b as f64 * w).max(c);
            let hi = ((b + 1) as f64 * w).min(d);
            if hi > lo {
                acc += self.pdf((lo + hi) * 0.5) * seg(lo, hi);
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::simpson;

    fn sample_hist() -> Histogram {
        let mut h = Histogram::new(64);
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..20_000 {
            // half-normal-ish magnitudes
            h.add((rng.normal() * 0.1).abs().min(1.0));
        }
        h
    }

    #[test]
    fn cdf_properties() {
        let h = sample_hist();
        assert_eq!(h.cdf(0.0), 0.0);
        assert!((h.cdf(1.0) - 1.0).abs() < 1e-12);
        let mut prev = 0.0;
        for i in 0..=50 {
            let f = h.cdf(i as f64 / 50.0);
            assert!(f >= prev - 1e-12);
            prev = f;
        }
    }

    #[test]
    fn pdf_matches_cdf() {
        let h = sample_hist();
        let got = simpson(|x| h.pdf(x), 0.0, 0.31, 8000);
        assert!((got - h.cdf(0.31)).abs() < 2e-3, "{got} vs {}", h.cdf(0.31));
    }

    #[test]
    fn partial_moments_match_quadrature() {
        let h = sample_hist();
        let got = h.partial_mean(0.03, 0.4);
        let want = simpson(|x| x * h.pdf(x), 0.03, 0.4, 16000);
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        let got2 = h.partial_mean_sq(0.0, 1.0);
        let want2 = simpson(|x| x * x * h.pdf(x), 0.0, 1.0, 16000);
        assert!((got2 - want2).abs() < 1e-3);
    }

    #[test]
    fn empty_histogram_is_uniform() {
        let h = Histogram::new(8);
        assert!((h.cdf(0.5) - 0.5).abs() < 1e-12);
        assert!((h.partial_mean(0.0, 1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new(4);
        a.add(0.1);
        let mut b = Histogram::new(4);
        b.add(0.9);
        a.merge(&b);
        assert_eq!(a.total(), 2.0);
        assert!((a.cdf(0.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn inv_cdf_roundtrip() {
        let h = sample_hist();
        for p in [0.1, 0.5, 0.9] {
            let x = h.inv_cdf(p);
            assert!((h.cdf(x) - p).abs() < 1e-6);
        }
    }
}
