//! Truncated normal distribution on an interval (Appendix A.2) with the
//! closed-form partial moments the update rules of Appendices B–C use.
//!
//! With underlying N(μ, σ²) truncated to [a, b], Z = Φ(β) − Φ(α):
//!
//! * `F_T(x) = (Φ(x̃) − Φ(α)) / Z`
//! * `p_T(x) = φ(x̃) / (σ Z)`
//! * `∫_c^d r dF_T = μ (F_T(d) − F_T(c)) − σ² (p_T(d) − p_T(c))`  — the
//!   identity behind the paper's Eq. (25)/(34)-style closed forms.
//! * `∫_c^d r² dF_T = (μ²+σ²) ΔF_T + σ² ((c+μ) p_T(c) − (d+μ) p_T(d))`

use super::special::{phi, phi_inv, phi_pdf};
use super::Dist;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TruncNormal {
    pub mu: f64,
    pub sigma: f64,
    pub a: f64,
    pub b: f64,
    /// Z = Φ((b−μ)/σ) − Φ((a−μ)/σ), cached.
    z: f64,
    phi_a: f64,
}

impl TruncNormal {
    pub fn new(mu: f64, sigma: f64, a: f64, b: f64) -> Self {
        assert!(a < b, "need a < b, got [{a}, {b}]");
        assert!(sigma > 0.0, "sigma must be positive, got {sigma}");
        let phi_a = phi((a - mu) / sigma);
        let z = phi((b - mu) / sigma) - phi_a;
        // For extremely concentrated distributions Z can underflow; clamp
        // to keep the math finite (App. K notes this exact pitfall — the
        // estimator guards against it by flooring sigma upstream too).
        let z = z.max(1e-300);
        TruncNormal { mu, sigma, a, b, z, phi_a }
    }

    /// Truncated to the unit interval — the domain of normalized coords.
    pub fn unit(mu: f64, sigma: f64) -> Self {
        Self::new(mu, sigma, 0.0, 1.0)
    }

    #[inline]
    fn std(&self, x: f64) -> f64 {
        (x - self.mu) / self.sigma
    }

    #[inline]
    fn clamp(&self, x: f64) -> f64 {
        x.clamp(self.a, self.b)
    }
}

impl Dist for TruncNormal {
    fn cdf(&self, x: f64) -> f64 {
        if x <= self.a {
            return 0.0;
        }
        if x >= self.b {
            return 1.0;
        }
        ((phi(self.std(x)) - self.phi_a) / self.z).clamp(0.0, 1.0)
    }

    fn pdf(&self, x: f64) -> f64 {
        if x < self.a || x > self.b {
            return 0.0;
        }
        phi_pdf(self.std(x)) / (self.sigma * self.z)
    }

    fn partial_mean(&self, c: f64, d: f64) -> f64 {
        let (c, d) = (self.clamp(c), self.clamp(d));
        if c >= d {
            return 0.0;
        }
        self.mu * (self.cdf(d) - self.cdf(c))
            - self.sigma * self.sigma * (self.pdf(d) - self.pdf(c))
    }

    fn partial_mean_sq(&self, c: f64, d: f64) -> f64 {
        let (c, d) = (self.clamp(c), self.clamp(d));
        if c >= d {
            return 0.0;
        }
        let s2 = self.sigma * self.sigma;
        (self.mu * self.mu + s2) * (self.cdf(d) - self.cdf(c))
            + s2 * ((c + self.mu) * self.pdf(c) - (d + self.mu) * self.pdf(d))
    }

    /// Closed-form inverse (Eq. 18): F⁻¹(y) = μ + σ Φ⁻¹(Φ(α) + yZ).
    fn inv_cdf(&self, y: f64) -> f64 {
        let y = y.clamp(0.0, 1.0);
        let x = self.mu + self.sigma * phi_inv(self.phi_a + y * self.z);
        x.clamp(self.a, self.b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::simpson;

    fn dist() -> TruncNormal {
        TruncNormal::unit(0.02, 0.015)
    }

    #[test]
    fn cdf_endpoints() {
        let t = dist();
        assert_eq!(t.cdf(0.0), 0.0);
        assert_eq!(t.cdf(1.0), 1.0);
        assert_eq!(t.cdf(-0.5), 0.0);
        assert_eq!(t.cdf(1.5), 1.0);
    }

    #[test]
    fn pdf_integrates_to_one() {
        let t = dist();
        let got = simpson(|x| t.pdf(x), 0.0, 1.0, 4000);
        assert!((got - 1.0).abs() < 1e-8, "{got}");
    }

    #[test]
    fn cdf_matches_pdf_quadrature() {
        let t = TruncNormal::unit(0.3, 0.25);
        for d in [0.1, 0.3, 0.55, 0.9] {
            let got = simpson(|x| t.pdf(x), 0.0, d, 2000);
            assert!((got - t.cdf(d)).abs() < 1e-9, "d={d}");
        }
    }

    #[test]
    fn partial_mean_matches_quadrature() {
        let t = TruncNormal::unit(0.1, 0.2);
        let got = t.partial_mean(0.05, 0.6);
        let want = simpson(|x| x * t.pdf(x), 0.05, 0.6, 2000);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn partial_mean_sq_matches_quadrature() {
        let t = TruncNormal::unit(0.1, 0.2);
        let got = t.partial_mean_sq(0.0, 0.8);
        let want = simpson(|x| x * x * t.pdf(x), 0.0, 0.8, 2000);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn inv_cdf_roundtrip() {
        let t = dist();
        for p in [0.001, 0.1, 0.5, 0.9, 0.999] {
            let x = t.inv_cdf(p);
            assert!((0.0..=1.0).contains(&x));
            assert!((t.cdf(x) - p).abs() < 1e-10, "p={p}");
        }
    }

    #[test]
    fn concentrated_distribution_is_finite() {
        // The App. K pathology: tiny sigma far from the interval center.
        let t = TruncNormal::unit(0.9, 1e-6);
        assert!(t.cdf(0.5).is_finite());
        assert!(t.partial_mean(0.0, 1.0).is_finite());
        let m = t.partial_mean(0.0, 1.0);
        assert!((m - 0.9).abs() < 1e-3, "mean of concentrated ~ mu, got {m}");
    }

    #[test]
    fn mean_shifts_with_mu() {
        let lo = TruncNormal::unit(0.2, 0.1).partial_mean(0.0, 1.0);
        let hi = TruncNormal::unit(0.6, 0.1).partial_mean(0.0, 1.0);
        assert!(lo < hi);
    }
}
