//! Optimizers and schedules (Section 2, Appendix I).

pub mod momentum;
pub mod schedule;
pub mod sgd;

pub use momentum::Umsgd;
pub use schedule::{LrSchedule, UpdateSchedule};
pub use sgd::Sgd;

/// A parameter-update rule over flat vectors.
pub trait Optimizer {
    /// Apply one update with the aggregated gradient.
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32);
}
