//! Plain SGD with optional weight decay.

use super::Optimizer;

#[derive(Clone, Debug, Default)]
pub struct Sgd {
    pub weight_decay: f32,
}

impl Sgd {
    pub fn new(weight_decay: f32) -> Self {
        Sgd { weight_decay }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), grad.len());
        if self.weight_decay != 0.0 {
            for (p, &g) in params.iter_mut().zip(grad) {
                *p -= lr * (g + self.weight_decay * *p);
            }
        } else {
            for (p, &g) in params.iter_mut().zip(grad) {
                *p -= lr * g;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_step() {
        let mut o = Sgd::new(0.0);
        let mut p = vec![1.0f32, -2.0];
        o.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, -1.95]);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut o = Sgd::new(0.1);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.0], 0.5);
        assert!((p[0] - 0.95).abs() < 1e-7);
    }
}
