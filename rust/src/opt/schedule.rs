//! Schedules: step-decay learning rate (Table 3) and the level-update
//! schedule 𝒰 (Appendix K "Update Schedule": once at 100 and 2000, then
//! every 10K iterations — fractions scaled to the configured horizon).

/// Step-decay LR: `lr0 × factor^(#drops passed)`.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub lr0: f32,
    pub factor: f32,
    /// Iterations at which the LR drops (paper: 40K/60K of 80K total).
    pub drops: Vec<usize>,
}

impl LrSchedule {
    /// The paper's shape: drops at 50% and 75% of the horizon, ×0.1.
    pub fn paper_default(lr0: f32, total_iters: usize) -> Self {
        LrSchedule {
            lr0,
            factor: 0.1,
            drops: vec![total_iters * 56 / 100, total_iters * 75 / 100],
        }
    }

    pub fn lr(&self, step: usize) -> f32 {
        let passed = self.drops.iter().filter(|&&d| step >= d).count();
        self.lr0 * self.factor.powi(passed as i32)
    }
}

/// The level-update schedule 𝒰 of Algorithm 1.
#[derive(Clone, Debug)]
pub struct UpdateSchedule {
    points: Vec<usize>,
    every: usize,
    after: usize,
}

impl UpdateSchedule {
    /// Paper schedule scaled to `total_iters`: one-shot warmup updates at
    /// 100/80K and 2000/80K of the horizon, then periodically (10K/80K).
    pub fn paper_default(total_iters: usize) -> Self {
        let frac = |num: usize| (total_iters * num / 80_000).max(1);
        UpdateSchedule {
            points: vec![frac(100), frac(2000)],
            every: frac(10_000).max(2),
            after: frac(2000),
        }
    }

    /// Explicit schedule (for tests / ablations).
    pub fn at(points: Vec<usize>, every: usize, after: usize) -> Self {
        UpdateSchedule {
            points,
            every,
            after,
        }
    }

    pub fn never() -> Self {
        UpdateSchedule {
            points: vec![],
            every: usize::MAX,
            after: usize::MAX,
        }
    }

    pub fn is_update_step(&self, step: usize) -> bool {
        if self.points.contains(&step) {
            return true;
        }
        step > self.after && self.every != usize::MAX && step % self.every == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_drops() {
        let s = LrSchedule {
            lr0: 0.1,
            factor: 0.1,
            drops: vec![100, 200],
        };
        assert_eq!(s.lr(0), 0.1);
        assert_eq!(s.lr(99), 0.1);
        assert!((s.lr(100) - 0.01).abs() < 1e-9);
        assert!((s.lr(250) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn paper_default_shape() {
        let s = LrSchedule::paper_default(0.1, 80_000);
        assert_eq!(s.lr(44_000), 0.1);
        assert!((s.lr(45_000) - 0.01).abs() < 1e-9);
        assert!((s.lr(61_000) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn update_schedule_scales() {
        let u = UpdateSchedule::paper_default(80_000);
        assert!(u.is_update_step(100));
        assert!(u.is_update_step(2000));
        assert!(u.is_update_step(10_000));
        assert!(u.is_update_step(20_000));
        assert!(!u.is_update_step(5000));
        assert!(!u.is_update_step(101));
    }

    #[test]
    fn update_schedule_small_horizon() {
        let u = UpdateSchedule::paper_default(800);
        assert!(u.is_update_step(1));
        assert!(u.is_update_step(20));
        // Periodic updates appear after warmup.
        assert!((21..=400).any(|s| u.is_update_step(s)));
    }

    #[test]
    fn never_schedule() {
        let u = UpdateSchedule::never();
        assert!((0..10_000).all(|s| !u.is_update_step(s)));
    }
}
