//! Unified momentum SGD (Appendix I, Eq. 45).
//!
//! `y_{t+1} = w_t − α g`, `yˡ_{t+1} = w_t − l α g`,
//! `w_{t+1} = y_{t+1} + μ (yˡ_{t+1} − yˡ_t)`.
//! Heavy-ball (Polyak) is `l = 0`; Nesterov is `l = 1`.

use super::Optimizer;

#[derive(Clone, Debug)]
pub struct Umsgd {
    pub mu: f32,
    /// UMSGD interpolation: 0 = heavy ball, 1 = Nesterov.
    pub l: f32,
    pub weight_decay: f32,
    y_l_prev: Vec<f32>,
    initialized: bool,
}

impl Umsgd {
    pub fn new(mu: f32, l: f32, weight_decay: f32) -> Self {
        Umsgd {
            mu,
            l,
            weight_decay,
            y_l_prev: Vec::new(),
            initialized: false,
        }
    }

    /// Heavy-ball momentum (the paper's experimental setting, μ = 0.9).
    pub fn heavy_ball(mu: f32, weight_decay: f32) -> Self {
        Self::new(mu, 0.0, weight_decay)
    }

    pub fn nesterov(mu: f32, weight_decay: f32) -> Self {
        Self::new(mu, 1.0, weight_decay)
    }
}

impl Optimizer for Umsgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), grad.len());
        if !self.initialized {
            // yˡ_0 = w_0 (no momentum on the first step).
            self.y_l_prev = params.to_vec();
            self.initialized = true;
        }
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            let w = params[i];
            let y_next = w - lr * g;
            let y_l_next = w - self.l * lr * g;
            params[i] = y_next + self.mu * (y_l_next - self.y_l_prev[i]);
            self.y_l_prev[i] = y_l_next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Heavy-ball on an *ill-conditioned* quadratic converges faster than
    /// plain SGD (the classic motivation: momentum helps along the
    /// low-curvature direction while lr is capped by the high-curvature one).
    #[test]
    fn heavy_ball_accelerates_quadratic() {
        let eig = [2.0f32, 0.05, 0.02];
        let f_grad = move |w: &[f32]| -> Vec<f32> {
            w.iter().zip(eig).map(|(&x, e)| 2.0 * e * x).collect()
        };
        let run = |mut opt: Box<dyn FnMut(&mut Vec<f32>, &[f32])>| -> f32 {
            let mut w = vec![1.0f32, -2.0, 0.5];
            for _ in 0..120 {
                let g = f_grad(&w);
                opt(&mut w, &g);
            }
            w.iter().zip(eig).map(|(x, e)| e * x * x).sum()
        };
        let sgd_final = run(Box::new(|w, g| {
            let mut o = super::super::Sgd::new(0.0);
            use super::super::Optimizer;
            o.step(w, g, 0.05);
        }));
        let mut hb = Umsgd::heavy_ball(0.9, 0.0);
        let hb_final = run(Box::new(move |w, g| {
            use super::super::Optimizer;
            hb.step(w, g, 0.05);
        }));
        assert!(
            hb_final < sgd_final,
            "heavy ball {hb_final} should beat sgd {sgd_final}"
        );
    }

    /// First step of heavy ball equals plain SGD (yˡ_0 = w_0).
    #[test]
    fn first_step_matches_sgd() {
        let mut o = Umsgd::heavy_ball(0.9, 0.0);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.5], 0.1);
        assert!((p[0] - 0.95).abs() < 1e-7);
    }

    /// Heavy-ball recurrence: w_{t+1} = w_t − αg + μ(w_t − w_{t−1}).
    #[test]
    fn heavy_ball_recurrence() {
        let mut o = Umsgd::heavy_ball(0.5, 0.0);
        let mut w = vec![1.0f32];
        let mut hist = vec![w[0]];
        let grads = [0.2f32, -0.1, 0.3, 0.05];
        for &g in &grads {
            o.step(&mut w, &[g], 0.1);
            hist.push(w[0]);
        }
        // Reconstruct manually.
        let (mut a, mut b) = (1.0f32, 1.0f32); // w_{t-1}, w_t
        let mut manual = vec![1.0f32];
        for &g in &grads {
            let next = b - 0.1 * g + 0.5 * (b - a);
            a = b;
            b = next;
            manual.push(next);
        }
        for (x, y) in hist.iter().zip(&manual) {
            assert!((x - y).abs() < 1e-6, "{hist:?} vs {manual:?}");
        }
    }

    #[test]
    fn nesterov_differs_from_heavy_ball() {
        let mut hb = Umsgd::heavy_ball(0.9, 0.0);
        let mut nv = Umsgd::nesterov(0.9, 0.0);
        let mut w1 = vec![1.0f32];
        let mut w2 = vec![1.0f32];
        for g in [0.5f32, 0.4, 0.3] {
            hb.step(&mut w1, &[g], 0.1);
            nv.step(&mut w2, &[g], 0.1);
        }
        assert_ne!(w1, w2);
    }
}
