//! Trace post-processing: schema validation, wall-clock masking, and the
//! `trace-summarize` fold from a JSONL event stream into per-phase /
//! per-hop / per-width tables plus a machine-readable summary JSON.

use crate::metrics::Table;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Required-field kinds for schema validation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Field {
    /// Must be a JSON string.
    Str,
    /// Must be a JSON number.
    Num,
    /// Must be a JSON boolean.
    Bool,
    /// A number under this key *or* under `wall_` + this key (used for
    /// span durations that are wall-clock in some runtimes and modeled
    /// in others).
    NumOrWall,
}

/// One event type's schema: its `e` tag and required typed fields.
/// Extra fields are always allowed (they carry runtime-specific
/// context); missing or mistyped required fields fail validation.
pub struct EventSchema {
    /// Value of the event's `e` field.
    pub kind: &'static str,
    /// Required fields and their kinds.
    pub required: &'static [(&'static str, Field)],
}

/// Registry of every event type the tracer emits. `validate_event`
/// rejects unknown `e` tags, so this list *is* the schema contract the
/// determinism tests pin.
pub const EVENT_TYPES: &[EventSchema] = &[
    EventSchema {
        kind: "run_start",
        required: &[("runtime", Field::Str)],
    },
    EventSchema {
        kind: "bit_decision",
        required: &[("step", Field::Num), ("width", Field::Num)],
    },
    EventSchema {
        kind: "phase",
        required: &[
            ("step", Field::Num),
            ("phase", Field::Str),
            ("seconds", Field::NumOrWall),
        ],
    },
    EventSchema {
        kind: "hop",
        required: &[
            ("step", Field::Num),
            ("index", Field::Num),
            ("label", Field::Str),
            ("bits", Field::Num),
            ("seconds", Field::Num),
        ],
    },
    EventSchema {
        kind: "step",
        required: &[
            ("step", Field::Num),
            ("bits", Field::Num),
            ("width", Field::Num),
        ],
    },
    EventSchema {
        kind: "adapt",
        required: &[("updated", Field::Bool)],
    },
    EventSchema {
        kind: "warning",
        required: &[("component", Field::Str), ("message", Field::Str)],
    },
    EventSchema {
        kind: "connect",
        required: &[("worker", Field::Num), ("world", Field::Num)],
    },
    EventSchema {
        kind: "frame_send",
        required: &[
            ("step", Field::Num),
            ("kind", Field::Str),
            ("bytes", Field::Num),
            ("width", Field::Num),
        ],
    },
    EventSchema {
        kind: "frame_recv",
        required: &[
            ("step", Field::Num),
            ("kind", Field::Str),
            ("frames", Field::Num),
            ("bytes", Field::Num),
        ],
    },
    EventSchema {
        kind: "relay",
        required: &[
            ("step", Field::Num),
            ("frames", Field::Num),
            ("bits", Field::Num),
        ],
    },
    EventSchema {
        kind: "member_join",
        required: &[
            ("step", Field::Num),
            ("worker", Field::Num),
            ("active", Field::Num),
            ("weight_sum", Field::Num),
        ],
    },
    EventSchema {
        kind: "member_drop",
        required: &[
            ("step", Field::Num),
            ("worker", Field::Num),
            ("active", Field::Num),
            ("weight_sum", Field::Num),
        ],
    },
    EventSchema {
        kind: "timeout",
        required: &[
            ("step", Field::Num),
            ("worker", Field::Num),
            ("attempt", Field::Num),
            ("deadline_ms", Field::Num),
        ],
    },
    EventSchema {
        kind: "skip",
        required: &[
            ("step", Field::Num),
            ("worker", Field::Num),
            ("bits", Field::Num),
            ("weight_sum", Field::Num),
        ],
    },
    EventSchema {
        kind: "feedback_norm",
        required: &[
            ("step", Field::Num),
            ("worker", Field::Num),
            ("norm", Field::Num),
        ],
    },
    EventSchema {
        kind: "run_end",
        required: &[("steps", Field::Num), ("total_bits", Field::Num)],
    },
];

/// The phase names a `phase` event may carry. `compute` is the local
/// gradient phase (`sim::Cluster::train` / the TCP worker's backward
/// pass) — the span pipelined schedules hide communication behind.
pub const PHASES: &[&str] = &[
    "compute",
    "quantize",
    "encode",
    "wire",
    "decode",
    "aggregate",
    "adapt",
];

/// Validate one parsed event against [`EVENT_TYPES`]: must be an object
/// with a known `e` tag, a numeric `seq`, and every required field
/// present with the right type.
pub fn validate_event(ev: &Json) -> Result<(), String> {
    let obj = ev
        .as_obj()
        .ok_or_else(|| format!("event is not an object: {ev}"))?;
    let kind = ev
        .get("e")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("event lacks string \"e\": {ev}"))?;
    if ev.get("seq").and_then(|v| v.as_f64()).is_none() {
        return Err(format!("event lacks numeric \"seq\": {ev}"));
    }
    let schema = EVENT_TYPES
        .iter()
        .find(|s| s.kind == kind)
        .ok_or_else(|| format!("unknown event type {kind:?}: {ev}"))?;
    for (name, field) in schema.required {
        let ok = match field {
            Field::Str => matches!(obj.get(*name), Some(Json::Str(_))),
            Field::Num => matches!(obj.get(*name), Some(Json::Num(_))),
            Field::Bool => matches!(obj.get(*name), Some(Json::Bool(_))),
            Field::NumOrWall => {
                matches!(obj.get(*name), Some(Json::Num(_)))
                    || matches!(obj.get(format!("wall_{name}").as_str()), Some(Json::Num(_)))
            }
        };
        if !ok {
            return Err(format!("{kind}: missing/mistyped field {name:?}: {ev}"));
        }
    }
    if kind == "phase" {
        let p = obj.get("phase").and_then(|v| v.as_str()).unwrap_or("");
        if !PHASES.contains(&p) {
            return Err(format!("phase event with unknown phase {p:?}: {ev}"));
        }
    }
    Ok(())
}

/// Drop every field whose key starts with `wall_` (the only fields
/// allowed to carry wall-clock measurements). What remains is the
/// deterministic projection the parallel-mode bit-identity tests
/// compare.
pub fn mask_wall(ev: &mut Json) {
    if let Json::Obj(m) = ev {
        m.retain(|k, _| !k.starts_with("wall_"));
    }
}

/// Parse a JSONL trace, validate every event, mask wall-clock fields,
/// and return the canonical re-serialized lines. This is the projection
/// under which traced runs must be bit-identical across `--parallel
/// on|off` (DESIGN.md §Observability).
pub fn masked_lines(text: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut ev = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        validate_event(&ev).map_err(|e| format!("line {}: {e}", i + 1))?;
        mask_wall(&mut ev);
        out.push(ev.to_string());
    }
    Ok(out)
}

/// Per-step totals reconstructed from `step` events.
#[derive(Clone, Debug, PartialEq)]
pub struct StepRow {
    /// 1-based run index (increments at each `run_start`; 0 before any).
    pub run: usize,
    /// Step number within the run.
    pub step: usize,
    /// Total bits the step put on the wire.
    pub bits: u64,
    /// Quantization width used (32 = FP32).
    pub width: u32,
}

/// Accumulated totals for one phase name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseTotal {
    /// Number of `phase` events.
    pub events: usize,
    /// Summed span seconds (modeled `seconds` or measured
    /// `wall_seconds`, whichever each event carries).
    pub seconds: f64,
}

/// Accumulated totals for one hop label.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HopTotal {
    /// Number of `hop` events.
    pub events: usize,
    /// Summed hop bits.
    pub bits: u64,
    /// Summed modeled α-β seconds.
    pub seconds: f64,
}

/// Accumulated totals for one quantization width.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WidthTotal {
    /// Steps that ran at this width.
    pub steps: usize,
    /// Total bits those steps sent.
    pub bits: u64,
}

/// The fold of a whole trace file: everything `trace-summarize` prints.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// Total validated events.
    pub events: usize,
    /// Event count per `e` tag.
    pub by_type: BTreeMap<String, usize>,
    /// Per-step totals, in stream order.
    pub steps: Vec<StepRow>,
    /// Totals per phase name.
    pub phase_totals: BTreeMap<String, PhaseTotal>,
    /// Totals per hop label.
    pub hop_totals: BTreeMap<String, HopTotal>,
    /// Totals per quantization width.
    pub width_totals: BTreeMap<u32, WidthTotal>,
    /// `(component, message)` of every warning event.
    pub warnings: Vec<(String, String)>,
    /// Worker-steps that sent a skip marker instead of a frame
    /// (`skip` events — the `--lazy` zero-frame savings).
    pub skipped_frames: usize,
    /// Total skip-marker bits those markers put on the wire.
    pub skip_bits: u64,
    /// `feedback_norm` samples seen (Debug-level `--error-feedback`
    /// telemetry) and the largest residual ℓ₂ norm among them.
    pub feedback_events: usize,
    pub feedback_norm_max: f64,
    /// Steps whose `step.bits` ≠ Σ hop bits (should always be empty:
    /// `BackendCore::finish_step` debug-asserts the same invariant).
    pub hop_bits_mismatches: Vec<String>,
}

impl TraceSummary {
    /// Fold a JSONL trace (validating every line) into totals.
    pub fn from_jsonl(text: &str) -> Result<TraceSummary, String> {
        let mut s = TraceSummary::default();
        let mut run = 0usize;
        // Hop bits accumulated per step, awaiting that step's `step`
        // event (hops are always emitted before their step total).
        let mut pending_hops: BTreeMap<usize, u64> = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let ev = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
            validate_event(&ev).map_err(|e| format!("line {}: {e}", i + 1))?;
            let kind = ev.req("e").as_str().unwrap().to_string();
            s.events += 1;
            *s.by_type.entry(kind.clone()).or_insert(0) += 1;
            let num = |k: &str| ev.get(k).and_then(|v| v.as_f64());
            match kind.as_str() {
                "run_start" => {
                    run += 1;
                    pending_hops.clear();
                }
                "phase" => {
                    let name = ev.req("phase").as_str().unwrap().to_string();
                    let secs = num("seconds").or_else(|| num("wall_seconds")).unwrap_or(0.0);
                    let t = s.phase_totals.entry(name).or_default();
                    t.events += 1;
                    t.seconds += secs;
                }
                "hop" => {
                    let label = ev.req("label").as_str().unwrap().to_string();
                    let bits = num("bits").unwrap_or(0.0) as u64;
                    let t = s.hop_totals.entry(label).or_default();
                    t.events += 1;
                    t.bits += bits;
                    t.seconds += num("seconds").unwrap_or(0.0);
                    let step = num("step").unwrap_or(0.0) as usize;
                    *pending_hops.entry(step).or_insert(0) += bits;
                }
                "step" => {
                    let row = StepRow {
                        run,
                        step: num("step").unwrap_or(0.0) as usize,
                        bits: num("bits").unwrap_or(0.0) as u64,
                        width: num("width").unwrap_or(0.0) as u32,
                    };
                    if let Some(hop_bits) = pending_hops.remove(&row.step) {
                        if hop_bits != row.bits {
                            s.hop_bits_mismatches.push(format!(
                                "run {} step {}: step.bits={} but Σ hop bits={}",
                                row.run, row.step, row.bits, hop_bits
                            ));
                        }
                    }
                    let w = s.width_totals.entry(row.width).or_default();
                    w.steps += 1;
                    w.bits += row.bits;
                    s.steps.push(row);
                }
                "warning" => s.warnings.push((
                    ev.req("component").as_str().unwrap().to_string(),
                    ev.req("message").as_str().unwrap().to_string(),
                )),
                "skip" => {
                    s.skipped_frames += 1;
                    s.skip_bits += num("bits").unwrap_or(0.0) as u64;
                }
                "feedback_norm" => {
                    s.feedback_events += 1;
                    s.feedback_norm_max = s.feedback_norm_max.max(num("norm").unwrap_or(0.0));
                }
                _ => {}
            }
        }
        Ok(s)
    }

    /// Render the summary as `metrics::Table`s (what `trace-summarize`
    /// prints as markdown).
    pub fn tables(&self) -> Vec<Table> {
        let mut out = Vec::new();

        let mut t = Table::new("Events by type", &["Event", "Count"]);
        for (k, n) in &self.by_type {
            t.row(vec![k.clone(), n.to_string()]);
        }
        out.push(t);

        if !self.phase_totals.is_empty() {
            let mut t = Table::new("Per-phase time", &["Phase", "Spans", "Seconds"]);
            for (k, p) in &self.phase_totals {
                t.row(vec![
                    k.clone(),
                    p.events.to_string(),
                    format!("{:.6}", p.seconds),
                ]);
            }
            out.push(t);
        }

        if !self.hop_totals.is_empty() {
            let mut t = Table::new(
                "Per-hop traffic",
                &["Hop", "Count", "Bits", "Modeled seconds"],
            );
            for (k, h) in &self.hop_totals {
                t.row(vec![
                    k.clone(),
                    h.events.to_string(),
                    h.bits.to_string(),
                    format!("{:.6}", h.seconds),
                ]);
            }
            out.push(t);
        }

        if !self.width_totals.is_empty() {
            let mut t = Table::new("Per-width usage", &["Width (bits)", "Steps", "Bits sent"]);
            for (w, u) in &self.width_totals {
                t.row(vec![w.to_string(), u.steps.to_string(), u.bits.to_string()]);
            }
            out.push(t);
        }

        if self.skipped_frames > 0 {
            let mut t = Table::new("Skip rounds", &["Skipped frames", "Marker bits"]);
            t.row(vec![
                self.skipped_frames.to_string(),
                self.skip_bits.to_string(),
            ]);
            out.push(t);
        }

        if !self.warnings.is_empty() {
            let mut t = Table::new("Warnings", &["Component", "Message"]);
            for (c, m) in &self.warnings {
                t.row(vec![c.clone(), m.clone()]);
            }
            out.push(t);
        }

        out
    }

    /// Machine-readable summary document (`--json` output of
    /// `trace-summarize`).
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.insert("schema", Json::Str("aqsgd-trace-summary/v1".into()));
        doc.insert("events", Json::Num(self.events as f64));

        let mut by_type = Json::obj();
        for (k, n) in &self.by_type {
            by_type.insert(k, Json::Num(*n as f64));
        }
        doc.insert("by_type", by_type);

        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.insert("run", Json::Num(r.run as f64));
                o.insert("step", Json::Num(r.step as f64));
                o.insert("bits", Json::Num(r.bits as f64));
                o.insert("width", Json::Num(r.width as f64));
                o
            })
            .collect();
        doc.insert("steps", Json::Arr(steps));

        let mut phases = Json::obj();
        for (k, p) in &self.phase_totals {
            let mut o = Json::obj();
            o.insert("spans", Json::Num(p.events as f64));
            o.insert("seconds", Json::Num(p.seconds));
            phases.insert(k, o);
        }
        doc.insert("phases", phases);

        let mut hops = Json::obj();
        for (k, h) in &self.hop_totals {
            let mut o = Json::obj();
            o.insert("count", Json::Num(h.events as f64));
            o.insert("bits", Json::Num(h.bits as f64));
            o.insert("seconds", Json::Num(h.seconds));
            hops.insert(k, o);
        }
        doc.insert("hops", hops);

        let mut widths = Json::obj();
        for (w, u) in &self.width_totals {
            let mut o = Json::obj();
            o.insert("steps", Json::Num(u.steps as f64));
            o.insert("bits", Json::Num(u.bits as f64));
            widths.insert(&w.to_string(), o);
        }
        doc.insert("widths", widths);

        let mut skips = Json::obj();
        skips.insert("frames", Json::Num(self.skipped_frames as f64));
        skips.insert("marker_bits", Json::Num(self.skip_bits as f64));
        doc.insert("skips", skips);

        let mut feedback = Json::obj();
        feedback.insert("samples", Json::Num(self.feedback_events as f64));
        feedback.insert("max_norm", Json::Num(self.feedback_norm_max));
        doc.insert("feedback", feedback);

        let warnings: Vec<Json> = self
            .warnings
            .iter()
            .map(|(c, m)| {
                let mut o = Json::obj();
                o.insert("component", Json::Str(c.clone()));
                o.insert("message", Json::Str(m.clone()));
                o
            })
            .collect();
        doc.insert("warnings", Json::Arr(warnings));

        doc.insert(
            "hop_bits_mismatches",
            Json::Arr(
                self.hop_bits_mismatches
                    .iter()
                    .map(|m| Json::Str(m.clone()))
                    .collect(),
            ),
        );
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn validate_accepts_wellformed_and_rejects_unknown() {
        let ok = line(r#"{"e":"step","seq":4,"step":0,"bits":120,"width":3}"#);
        assert!(validate_event(&ok).is_ok());
        let unknown = line(r#"{"e":"mystery","seq":0}"#);
        assert!(validate_event(&unknown).is_err());
        let missing = line(r#"{"e":"step","seq":4,"step":0}"#);
        assert!(validate_event(&missing).is_err());
        let no_seq = line(r#"{"e":"warning","component":"x","message":"y"}"#);
        assert!(validate_event(&no_seq).is_err());
        let bad_phase = line(r#"{"e":"phase","seq":0,"step":0,"phase":"nope","seconds":1}"#);
        assert!(validate_event(&bad_phase).is_err());
    }

    #[test]
    fn validate_covers_membership_and_timeout_events() {
        let join =
            line(r#"{"e":"member_join","seq":0,"step":8,"worker":2,"active":4,"weight_sum":1}"#);
        assert!(validate_event(&join).is_ok());
        let drop =
            line(r#"{"e":"member_drop","seq":1,"step":3,"worker":1,"active":3,"weight_sum":1}"#);
        assert!(validate_event(&drop).is_ok());
        let timeout =
            line(r#"{"e":"timeout","seq":2,"step":3,"worker":1,"attempt":0,"deadline_ms":50}"#);
        assert!(validate_event(&timeout).is_ok());
        let missing = line(r#"{"e":"member_drop","seq":3,"step":3,"worker":1}"#);
        assert!(validate_event(&missing).is_err());
        let mistyped =
            line(r#"{"e":"timeout","seq":4,"step":3,"worker":1,"attempt":"x","deadline_ms":50}"#);
        assert!(validate_event(&mistyped).is_err());
    }

    #[test]
    fn validate_covers_skip_and_feedback_events() {
        let skip =
            line(r#"{"e":"skip","seq":0,"step":5,"worker":2,"bits":104,"weight_sum":1}"#);
        assert!(validate_event(&skip).is_ok());
        let fb = line(r#"{"e":"feedback_norm","seq":1,"step":5,"worker":2,"norm":0.25}"#);
        assert!(validate_event(&fb).is_ok());
        let missing = line(r#"{"e":"skip","seq":2,"step":5,"worker":2,"bits":104}"#);
        assert!(validate_event(&missing).is_err());
        let mistyped =
            line(r#"{"e":"feedback_norm","seq":3,"step":5,"worker":2,"norm":"big"}"#);
        assert!(validate_event(&mistyped).is_err());
    }

    #[test]
    fn summarize_folds_skip_rounds_and_feedback() {
        let trace = r#"{"e":"run_start","seq":0,"runtime":"sim"}
{"e":"feedback_norm","seq":1,"step":0,"worker":0,"norm":0.5}
{"e":"feedback_norm","seq":2,"step":0,"worker":1,"norm":2.0}
{"e":"skip","seq":3,"step":0,"worker":1,"bits":104,"weight_sum":1}
{"e":"hop","seq":4,"step":0,"index":0,"label":"all-to-all","bits":520,"seconds":0.5}
{"e":"hop","seq":5,"step":0,"index":1,"label":"skip","bits":104,"seconds":0.125}
{"e":"step","seq":6,"step":0,"bits":624,"width":3}
{"e":"skip","seq":7,"step":1,"worker":0,"bits":104,"weight_sum":0}
{"e":"skip","seq":8,"step":1,"worker":1,"bits":104,"weight_sum":0}
{"e":"hop","seq":9,"step":1,"index":0,"label":"skip","bits":208,"seconds":0.25}
{"e":"step","seq":10,"step":1,"bits":208,"width":3}
"#;
        let s = TraceSummary::from_jsonl(trace).unwrap();
        assert_eq!(s.skipped_frames, 3);
        assert_eq!(s.skip_bits, 312);
        assert_eq!(s.feedback_events, 2);
        assert!((s.feedback_norm_max - 2.0).abs() < 1e-12);
        // The skip hop participates in the hop-sum ≡ step-total
        // invariant: an all-skip step carries marker bits only.
        assert!(s.hop_bits_mismatches.is_empty(), "{:?}", s.hop_bits_mismatches);
        assert_eq!(s.hop_totals["skip"].bits, 312);
        assert!(s.tables().iter().any(|t| t.title == "Skip rounds"));
        let doc = s.to_json().to_string();
        assert!(doc.contains(r#""skips":{"frames":3,"marker_bits":312}"#), "{doc}");
        assert!(doc.contains(r#""feedback":{"max_norm":2,"samples":2}"#), "{doc}");
    }

    #[test]
    fn phase_accepts_wall_or_modeled_seconds() {
        let wall = line(r#"{"e":"phase","seq":0,"step":0,"phase":"encode","wall_seconds":0.1}"#);
        assert!(validate_event(&wall).is_ok());
        let compute =
            line(r#"{"e":"phase","seq":0,"step":0,"phase":"compute","wall_seconds":0.3}"#);
        assert!(validate_event(&compute).is_ok());
        let modeled = line(r#"{"e":"phase","seq":0,"step":0,"phase":"wire","seconds":0.2}"#);
        assert!(validate_event(&modeled).is_ok());
        let neither = line(r#"{"e":"phase","seq":0,"step":0,"phase":"wire"}"#);
        assert!(validate_event(&neither).is_err());
    }

    #[test]
    fn mask_wall_strips_only_wall_fields() {
        let mut ev =
            line(r#"{"e":"phase","seq":1,"step":0,"phase":"encode","wall_seconds":0.5,"x":2}"#);
        mask_wall(&mut ev);
        assert_eq!(
            ev.to_string(),
            r#"{"e":"phase","phase":"encode","seq":1,"step":0,"x":2}"#
        );
    }

    #[test]
    fn summarize_folds_steps_hops_phases() {
        let trace = r#"{"e":"run_start","seq":0,"runtime":"sim"}
{"e":"bit_decision","seq":1,"step":0,"width":3}
{"e":"phase","seq":2,"step":0,"phase":"quantize","wall_seconds":0.01}
{"e":"hop","seq":3,"step":0,"index":0,"label":"all-to-all","bits":100,"seconds":0.5}
{"e":"step","seq":4,"step":0,"bits":100,"width":3}
{"e":"hop","seq":5,"step":1,"index":0,"label":"all-to-all","bits":140,"seconds":0.6}
{"e":"step","seq":6,"step":1,"bits":140,"width":4}
{"e":"warning","seq":7,"component":"pallas","message":"downgraded"}
{"e":"run_end","seq":8,"steps":2,"total_bits":240}
"#;
        let s = TraceSummary::from_jsonl(trace).unwrap();
        assert_eq!(s.events, 9);
        assert_eq!(s.by_type["step"], 2);
        assert_eq!(
            s.steps,
            vec![
                StepRow {
                    run: 1,
                    step: 0,
                    bits: 100,
                    width: 3
                },
                StepRow {
                    run: 1,
                    step: 1,
                    bits: 140,
                    width: 4
                },
            ]
        );
        assert!(s.hop_bits_mismatches.is_empty());
        assert_eq!(s.hop_totals["all-to-all"].bits, 240);
        assert_eq!(s.width_totals[&3].steps, 1);
        assert_eq!(s.width_totals[&4].bits, 140);
        assert_eq!(s.warnings.len(), 1);
        assert!((s.phase_totals["quantize"].seconds - 0.01).abs() < 1e-12);
        let tables = s.tables();
        assert!(tables.iter().any(|t| t.title == "Per-width usage"));
        let doc = s.to_json().to_string();
        assert!(doc.contains(r#""schema":"aqsgd-trace-summary/v1""#));
    }

    #[test]
    fn summarize_flags_hop_bit_mismatch() {
        let trace = r#"{"e":"hop","seq":0,"step":0,"index":0,"label":"up","bits":90,"seconds":0.5}
{"e":"step","seq":1,"step":0,"bits":100,"width":3}
"#;
        let s = TraceSummary::from_jsonl(trace).unwrap();
        assert_eq!(s.hop_bits_mismatches.len(), 1);
        assert!(s.hop_bits_mismatches[0].contains("Σ hop bits=90"));
    }

    #[test]
    fn masked_lines_roundtrip() {
        let trace = "{\"e\":\"step\",\"seq\":0,\"step\":0,\"bits\":10,\"width\":2}\n\
                     {\"e\":\"adapt\",\"seq\":1,\"step\":0,\"updated\":true,\"wall_seconds\":0.3}\n";
        let lines = masked_lines(trace).unwrap();
        assert_eq!(lines.len(), 2);
        assert!(!lines[1].contains("wall_seconds"));
        assert!(masked_lines("{\"e\":\"zzz\",\"seq\":0}\n").is_err());
        assert!(masked_lines("not json\n").is_err());
    }
}
