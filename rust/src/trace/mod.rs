//! Structured run telemetry: a zero-dependency tracing subsystem shared
//! by the simulated cluster, the exchange backends, and the TCP
//! coordinator (`--trace <path>[:level]`).
//!
//! # Design
//!
//! A [`Tracer`] is a cheap handle (one `Option<Arc<..>>`): cloning it
//! costs a refcount, and a disabled tracer costs one branch per call
//! site — no event object is ever built unless the event's level is
//! enabled, which is what keeps the codec hot loop at zero overhead when
//! tracing is off (the ISSUE 7 `< 2%` budget on `BENCH_hotloop.json`).
//!
//! Events are typed JSON objects serialized one per line (JSONL) through
//! [`crate::util::json::Json`], whose `Display` is deterministic (sorted
//! keys, canonical numbers). Every event carries:
//!
//! * `e` — the event type (see [`summary::EVENT_TYPES`] for the schema),
//! * `seq` — a per-sink monotone sequence number.
//!
//! # Determinism contract
//!
//! All emission happens on the thread that owns the schedule — the
//! [`crate::exchange::BackendCore`] sequences events from parallel lanes
//! in schedule order (after `fan_out` returns results at schedule
//! indices), never in thread-completion order. Wall-clock measurements
//! are confined to fields whose key starts with `wall_`; with those
//! fields masked ([`summary::mask_wall`]), a `fixed:B` run's event
//! stream is bit-identical across `--parallel on|off`
//! (`rust/tests/trace_determinism.rs`). Modeled α-β times (hop seconds,
//! wire phase) are deterministic and stay unmasked under `seconds`.
//!
//! # Warnings
//!
//! Degradations that used to be stderr-only (`--quantize-impl pallas`
//! downgrades, artifact-skip notices) route through [`warn`], which
//! still prints to stderr *and* forwards a `warning` event to the
//! process-global tracer installed by [`install_global`] — so they are
//! machine-visible in the trace, not just console noise.
#![warn(missing_docs)]

pub mod summary;

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::{Arc, Mutex};

/// Event verbosity. `Warn` events are always emitted by an enabled
/// tracer; `Info` adds per-step decisions and lifecycle; `Debug` adds
/// per-phase spans, per-hop records, and per-frame wire events.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Degradations and anomalies only.
    Warn,
    /// Decisions + lifecycle (bit decisions, step totals, adapt, run
    /// start/end).
    Info,
    /// Full detail (phase spans, hops, wire frames). The default for
    /// `--trace <path>` without an explicit level.
    Debug,
}

impl Level {
    /// Parse a level name (`warn|info|debug`, case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// A parsed `--trace <path>[:level]` CLI value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceSpec {
    /// Destination JSONL file.
    pub path: String,
    /// Verbosity ceiling (default [`Level::Debug`]).
    pub level: Level,
}

impl TraceSpec {
    /// Parse `<path>[:level]`. A trailing `:warn|:info|:debug` names the
    /// level; any other `:suffix` is part of the path.
    pub fn parse(s: &str) -> Result<TraceSpec> {
        if let Some((path, suffix)) = s.rsplit_once(':') {
            if let Some(level) = Level::parse(suffix) {
                if path.is_empty() {
                    bail!("--trace {s:?}: empty path before :{suffix}");
                }
                return Ok(TraceSpec {
                    path: path.to_string(),
                    level,
                });
            }
        }
        if s.is_empty() {
            bail!("--trace needs a file path (<path>[:warn|info|debug])");
        }
        Ok(TraceSpec {
            path: s.to_string(),
            level: Level::Debug,
        })
    }

    /// Open the spec's file sink.
    pub fn tracer(&self) -> Result<Tracer> {
        Tracer::to_file(&self.path, self.level)
    }
}

/// Shared in-memory JSONL buffer (the test sink): lock and read the
/// accumulated lines.
pub type TraceBuffer = Arc<Mutex<String>>;

enum Sink {
    File(BufWriter<File>),
    Memory(TraceBuffer),
}

struct SinkState {
    seq: u64,
    out: Sink,
}

struct Inner {
    level: Level,
    sink: Mutex<SinkState>,
}

/// A cheap, cloneable telemetry handle. Disabled tracers ([`Tracer::disabled`])
/// are a no-op at every call site; enabled tracers serialize typed events
/// as deterministic JSONL.
#[derive(Clone)]
pub struct Tracer {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            None => write!(f, "Tracer(disabled)"),
            Some(i) => write!(f, "Tracer({})", i.level.name()),
        }
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::disabled()
    }
}

impl Tracer {
    /// The no-op tracer: every call site reduces to one branch.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    /// Trace to a JSONL file, creating (truncating) it.
    pub fn to_file(path: &str, level: Level) -> Result<Tracer> {
        let f = File::create(path).with_context(|| format!("creating trace file {path:?}"))?;
        Ok(Tracer {
            inner: Some(Arc::new(Inner {
                level,
                sink: Mutex::new(SinkState {
                    seq: 0,
                    out: Sink::File(BufWriter::new(f)),
                }),
            })),
        })
    }

    /// Trace into a shared in-memory buffer (tests): returns the tracer
    /// and the buffer its JSONL lines accumulate in.
    pub fn memory(level: Level) -> (Tracer, TraceBuffer) {
        let buf: TraceBuffer = Arc::new(Mutex::new(String::new()));
        let tracer = Tracer {
            inner: Some(Arc::new(Inner {
                level,
                sink: Mutex::new(SinkState {
                    seq: 0,
                    out: Sink::Memory(buf.clone()),
                }),
            })),
        };
        (tracer, buf)
    }

    /// Whether events at `level` would be emitted. Use to skip building
    /// expensive event payloads.
    pub fn on(&self, level: Level) -> bool {
        self.inner.as_ref().is_some_and(|i| level <= i.level)
    }

    /// Whether the tracer is enabled at all.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Emit one typed event. `fill` runs only when `level` is enabled,
    /// so a disabled tracer builds nothing. The event object gains `e`
    /// (the type) and `seq` (per-sink monotone counter).
    pub fn event<F: FnOnce(&mut Json)>(&self, level: Level, kind: &str, fill: F) {
        let Some(inner) = &self.inner else { return };
        if level > inner.level {
            return;
        }
        let mut o = Json::obj();
        fill(&mut o);
        o.insert("e", Json::Str(kind.to_string()));
        let mut sink = inner.sink.lock().expect("trace sink poisoned");
        o.insert("seq", Json::Num(sink.seq as f64));
        sink.seq += 1;
        let line = format!("{o}\n");
        match &mut sink.out {
            Sink::File(w) => {
                // Per-line flush: traces must survive abrupt exits and be
                // readable while the run is still going; trace-on runs
                // accept the syscall.
                let _ = w.write_all(line.as_bytes());
                let _ = w.flush();
            }
            Sink::Memory(buf) => buf.lock().expect("trace buffer poisoned").push_str(&line),
        }
    }

    /// Emit a `warning` event (always on for an enabled tracer).
    pub fn warn_event(&self, component: &str, message: &str) {
        self.event(Level::Warn, "warning", |o| {
            o.insert("component", Json::Str(component.to_string()));
            o.insert("message", Json::Str(message.to_string()));
        });
    }
}

static GLOBAL: Mutex<Option<Tracer>> = Mutex::new(None);

/// Install the process-global tracer [`warn`] forwards to (the CLI
/// installs the `--trace` tracer here so library-level degradations are
/// machine-visible).
pub fn install_global(t: Tracer) {
    *GLOBAL.lock().expect("global tracer poisoned") = Some(t);
}

/// Report a degradation: prints to stderr (the historical behavior) and
/// forwards a `warning` event to the global tracer when one is
/// installed. Components are short slugs (`pallas`, `artifacts`, …).
pub fn warn(component: &str, message: &str) {
    eprintln!("[aqsgd] {component}: {message}");
    if let Some(t) = GLOBAL.lock().expect("global tracer poisoned").as_ref() {
        t.warn_event(component, message);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("Debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), None);
        assert!(Level::Warn < Level::Info && Level::Info < Level::Debug);
    }

    #[test]
    fn trace_spec_parses_path_and_level() {
        let s = TraceSpec::parse("run.jsonl").unwrap();
        assert_eq!(s.path, "run.jsonl");
        assert_eq!(s.level, Level::Debug);
        let s = TraceSpec::parse("/tmp/t.jsonl:info").unwrap();
        assert_eq!(s.path, "/tmp/t.jsonl");
        assert_eq!(s.level, Level::Info);
        // A non-level suffix is part of the path.
        let s = TraceSpec::parse("dir:with:colons").unwrap();
        assert_eq!(s.path, "dir:with:colons");
        assert!(TraceSpec::parse("").is_err());
        assert!(TraceSpec::parse(":debug").is_err());
    }

    #[test]
    fn disabled_tracer_never_builds_events() {
        let t = Tracer::disabled();
        assert!(!t.on(Level::Warn));
        let mut built = false;
        t.event(Level::Warn, "warning", |_| built = true);
        assert!(!built);
    }

    #[test]
    fn memory_sink_emits_deterministic_jsonl_with_seq() {
        let (t, buf) = Tracer::memory(Level::Info);
        assert!(t.on(Level::Info) && !t.on(Level::Debug));
        t.event(Level::Info, "step", |o| {
            o.insert("step", Json::Num(0.0));
            o.insert("bits", Json::Num(120.0));
            o.insert("width", Json::Num(3.0));
        });
        t.event(Level::Debug, "hop", |o| {
            o.insert("step", Json::Num(0.0));
        });
        t.event(Level::Info, "step", |o| {
            o.insert("step", Json::Num(1.0));
            o.insert("bits", Json::Num(130.0));
            o.insert("width", Json::Num(3.0));
        });
        let text = buf.lock().unwrap().clone();
        let lines: Vec<&str> = text.lines().collect();
        // The debug hop was filtered; seq is monotone over emitted events.
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            r#"{"bits":120,"e":"step","seq":0,"step":0,"width":3}"#
        );
        assert!(lines[1].contains(r#""seq":1"#));
    }

    #[test]
    fn global_warn_routes_to_installed_tracer() {
        let (t, buf) = Tracer::memory(Level::Warn);
        install_global(t);
        warn("pallas", "downgrade test message");
        let text = buf.lock().unwrap().clone();
        assert!(text.contains(r#""e":"warning""#), "{text}");
        assert!(text.contains("downgrade test message"));
        assert!(text.contains(r#""component":"pallas""#));
        // Leave the slot empty for other tests.
        *GLOBAL.lock().unwrap() = None;
    }
}
