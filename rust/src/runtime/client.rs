//! PJRT CPU client wrapper.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids — see `/opt/xla-example/README.md` and
//! `python/compile/aot.py`.

use anyhow::{Context, Result};
use std::path::Path;

/// Owns the PJRT client; compile HLO text files into executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO text file.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(rt.platform().to_lowercase().contains("cpu")
            || rt.platform().to_lowercase().contains("host"));
    }

    #[test]
    fn compiles_every_artifact() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::trace::warn("artifacts", "skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        // Compile the cheap artifacts (tiny models + kernel ops); skip the
        // big LM train graphs here (covered by the e2e example).
        for name in ["mlp_tiny", "lm_tiny"] {
            let e = m.model(name).unwrap();
            rt.compile_hlo_text(&e.train_hlo).unwrap();
            rt.compile_hlo_text(&e.eval_hlo).unwrap();
        }
        for op in m.quantize.values() {
            rt.compile_hlo_text(&op.hlo).unwrap();
        }
        for op in m.stats.values() {
            rt.compile_hlo_text(&op.hlo).unwrap();
        }
    }
}
