//! Typed wrappers over the compiled artifacts.
//!
//! Every wrapper checks input shapes against the manifest, builds
//! `xla::Literal`s, executes, and unpacks the `return_tuple=True` output.

use super::artifacts::ModelEntry;
use super::client::Runtime;
use anyhow::{ensure, Result};

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e:?}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e:?}"))
}

/// Fused fwd+bwd step: `(params, batch…) → (loss, grads)`.
pub struct TrainStep {
    exe: xla::PjRtLoadedExecutable,
    entry: ModelEntry,
}

impl TrainStep {
    pub fn load(rt: &Runtime, entry: &ModelEntry) -> Result<TrainStep> {
        Ok(TrainStep {
            exe: rt.compile_hlo_text(&entry.train_hlo)?,
            entry: entry.clone(),
        })
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// MLP: batch = (x: f32[B·D] row-major, y: i32[B]).
    pub fn run_mlp(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        ensure!(self.entry.kind == "mlp");
        let b = self.entry.cfg("batch") as i64;
        let d = self.entry.cfg("input_dim") as i64;
        let args = [
            lit_f32(params, &[self.entry.param_count as i64])?,
            lit_f32(x, &[b, d])?,
            lit_i32(y, &[b])?,
        ];
        self.unpack(self.execute(&args)?)
    }

    /// LM: batch = tokens i32[B·T] row-major.
    pub fn run_lm(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        ensure!(self.entry.kind == "lm");
        let b = self.entry.cfg("batch") as i64;
        let t = self.entry.cfg("seq_len") as i64;
        let args = [
            lit_f32(params, &[self.entry.param_count as i64])?,
            lit_i32(tokens, &[b, t])?,
        ];
        self.unpack(self.execute(&args)?)
    }

    fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing train step: {e:?}"))?;
        out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))
    }

    fn unpack(&self, result: xla::Literal) -> Result<(f32, Vec<f32>)> {
        let (loss_l, grads_l) = result
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("unpacking (loss, grads): {e:?}"))?;
        let loss: f32 = loss_l
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?[0];
        let grads = grads_l
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        ensure!(grads.len() == self.entry.param_count);
        Ok((loss, grads))
    }
}

/// Eval step: MLP → (loss, acc); LM → (loss,).
pub struct EvalStep {
    exe: xla::PjRtLoadedExecutable,
    entry: ModelEntry,
}

impl EvalStep {
    pub fn load(rt: &Runtime, entry: &ModelEntry) -> Result<EvalStep> {
        Ok(EvalStep {
            exe: rt.compile_hlo_text(&entry.eval_hlo)?,
            entry: entry.clone(),
        })
    }

    pub fn run_mlp(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        ensure!(self.entry.kind == "mlp");
        let b = self.entry.cfg("batch") as i64;
        let d = self.entry.cfg("input_dim") as i64;
        let args = [
            lit_f32(params, &[self.entry.param_count as i64])?,
            lit_f32(x, &[b, d])?,
            lit_i32(y, &[b])?,
        ];
        let out = self.execute(&args)?;
        let (loss_l, acc_l) = out
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("unpack eval: {e:?}"))?;
        Ok((
            loss_l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0],
            acc_l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0],
        ))
    }

    pub fn run_lm(&self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        ensure!(self.entry.kind == "lm");
        let b = self.entry.cfg("batch") as i64;
        let t = self.entry.cfg("seq_len") as i64;
        let args = [
            lit_f32(params, &[self.entry.param_count as i64])?,
            lit_i32(tokens, &[b, t])?,
        ];
        let out = self.execute(&args)?;
        let loss_l = out
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("unpack eval: {e:?}"))?;
        Ok(loss_l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0])
    }

    fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing eval step: {e:?}"))?;
        out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))
    }
}

/// The Pallas quantize kernel artifact: `(v, levels, u) → (qidx, norms)`.
pub struct QuantizeOp {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    pub bucket: usize,
    pub k: usize,
}

impl QuantizeOp {
    pub fn load(rt: &Runtime, op: &super::artifacts::OpEntry) -> Result<QuantizeOp> {
        Ok(QuantizeOp {
            exe: rt.compile_hlo_text(&op.hlo)?,
            n: op.n,
            bucket: op.bucket,
            k: op.k,
        })
    }

    pub fn run(&self, v: &[f32], levels: &[f32], u: &[f32]) -> Result<(Vec<i8>, Vec<f32>)> {
        ensure!(v.len() == self.n && u.len() == self.n && levels.len() == self.k);
        let args = [
            lit_f32(v, &[self.n as i64])?,
            lit_f32(levels, &[self.k as i64])?,
            lit_f32(u, &[self.n as i64])?,
        ];
        let out = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("executing quantize op: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let (qidx_l, norms_l) = out
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("unpack quantize: {e:?}"))?;
        let qidx = qidx_l
            .to_vec::<i8>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let norms = norms_l
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok((qidx, norms))
    }
}

/// The Pallas stats kernel artifact: `v → (mu, sigma2, norms)`.
pub struct StatsOp {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    pub bucket: usize,
}

impl StatsOp {
    pub fn load(rt: &Runtime, op: &super::artifacts::OpEntry) -> Result<StatsOp> {
        Ok(StatsOp {
            exe: rt.compile_hlo_text(&op.hlo)?,
            n: op.n,
            bucket: op.bucket,
        })
    }

    pub fn run(&self, v: &[f32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        ensure!(v.len() == self.n);
        let args = [lit_f32(v, &[self.n as i64])?];
        let out = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("executing stats op: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let (mu, s2, norms) = out
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("unpack stats: {e:?}"))?;
        Ok((
            mu.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            s2.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            norms.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts, Manifest};

    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::trace::warn("artifacts", "skipping: no artifacts");
            return None;
        }
        Some((Runtime::cpu().unwrap(), Manifest::load(&dir).unwrap()))
    }

    #[test]
    fn mlp_train_step_matches_jax_goldens() {
        let Some((rt, m)) = setup() else { return };
        let entry = m.model("mlp_tiny").unwrap();
        let g = entry.goldens.as_ref().unwrap();
        let params = artifacts::read_f32(&g["params"]).unwrap();
        let x = artifacts::read_f32(&g["in0"]).unwrap();
        let y = artifacts::read_i32(&g["in1"]).unwrap();
        let want_loss = artifacts::read_f32(&g["loss"]).unwrap()[0];
        let want_grads = artifacts::read_f32(&g["grads"]).unwrap();

        let step = TrainStep::load(&rt, entry).unwrap();
        let (loss, grads) = step.run_mlp(&params, &x, &y).unwrap();
        assert!(
            (loss - want_loss).abs() / want_loss.abs().max(1e-6) < 1e-5,
            "loss {loss} vs golden {want_loss}"
        );
        assert_eq!(grads.len(), want_grads.len());
        let max_err = grads
            .iter()
            .zip(&want_grads)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "max grad err {max_err}");
    }

    #[test]
    fn lm_train_step_matches_jax_goldens() {
        let Some((rt, m)) = setup() else { return };
        let entry = m.model("lm_tiny").unwrap();
        let g = entry.goldens.as_ref().unwrap();
        let params = artifacts::read_f32(&g["params"]).unwrap();
        let tokens = artifacts::read_i32(&g["in0"]).unwrap();
        let want_loss = artifacts::read_f32(&g["loss"]).unwrap()[0];
        let want_grads = artifacts::read_f32(&g["grads"]).unwrap();

        let step = TrainStep::load(&rt, entry).unwrap();
        let (loss, grads) = step.run_lm(&params, &tokens).unwrap();
        assert!(
            (loss - want_loss).abs() / want_loss.abs().max(1e-6) < 1e-4,
            "loss {loss} vs golden {want_loss}"
        );
        // Grad elements are tiny; compare with absolute + relative slack.
        let mut worst = 0.0f32;
        for (a, b) in grads.iter().zip(&want_grads) {
            worst = worst.max((a - b).abs() / (b.abs() + 1e-4));
        }
        assert!(worst < 1e-2, "worst relative grad err {worst}");
    }

    #[test]
    fn quantize_op_matches_goldens_and_rust_quantizer() {
        let Some((rt, m)) = setup() else { return };
        // Linf variant: bit-exact against the Rust quantizer (max is
        // reduction-order independent).
        let op = &m.quantize["quantize_tiny_linf"];
        let g = op.goldens.as_ref().unwrap();
        let v = artifacts::read_f32(&g["v"]).unwrap();
        let levels = artifacts::read_f32(&g["levels"]).unwrap();
        let u = artifacts::read_f32(&g["u"]).unwrap();
        let want_qidx = artifacts::read_i8(&g["qidx"]).unwrap();
        let want_norms = artifacts::read_f32(&g["norms"]).unwrap();

        let qop = QuantizeOp::load(&rt, op).unwrap();
        let (qidx, norms) = qop.run(&v, &levels, &u).unwrap();
        assert_eq!(qidx, want_qidx, "HLO output vs python golden");
        assert_eq!(norms, want_norms);

        // Cross-layer: Rust quantizer on the same inputs.
        let rust_levels = crate::quant::Levels::from_mags(
            levels.iter().map(|&x| x as f64).collect(),
            true,
        );
        let quant = crate::quant::Quantizer::new(
            rust_levels,
            crate::quant::NormType::Linf,
            op.bucket,
        );
        let rq = quant.quantize_with_u(&v, &u);
        assert_eq!(rq.qidx, want_qidx, "rust quantizer vs pallas kernel");
        assert_eq!(rq.norms, want_norms);
    }

    #[test]
    fn quantize_op_l2_close_to_rust_quantizer() {
        let Some((rt, m)) = setup() else { return };
        let op = &m.quantize["quantize_tiny"];
        let g = op.goldens.as_ref().unwrap();
        let v = artifacts::read_f32(&g["v"]).unwrap();
        let levels = artifacts::read_f32(&g["levels"]).unwrap();
        let u = artifacts::read_f32(&g["u"]).unwrap();
        let qop = QuantizeOp::load(&rt, op).unwrap();
        let (qidx, norms) = qop.run(&v, &levels, &u).unwrap();

        let rust_levels = crate::quant::Levels::from_mags(
            levels.iter().map(|&x| x as f64).collect(),
            true,
        );
        let quant =
            crate::quant::Quantizer::new(rust_levels, crate::quant::NormType::L2, op.bucket);
        let rq = quant.quantize_with_u(&v, &u);
        // L2 norms can differ in the final ulp between reduction orders.
        for (a, b) in rq.norms.iter().zip(&norms) {
            assert!((a - b).abs() / b.abs().max(1e-20) < 1e-6);
        }
        let mismatches = rq
            .qidx
            .iter()
            .zip(&qidx)
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            (mismatches as f64) < 1e-3 * qidx.len() as f64 + 1.0,
            "{mismatches} mismatching symbols"
        );
    }

    #[test]
    fn stats_op_matches_goldens_and_host_stats() {
        let Some((rt, m)) = setup() else { return };
        let op = &m.stats["stats_tiny"];
        let g = op.goldens.as_ref().unwrap();
        let v = artifacts::read_f32(&g["v"]).unwrap();
        let want_mu = artifacts::read_f32(&g["mu"]).unwrap();
        let want_s2 = artifacts::read_f32(&g["sigma2"]).unwrap();
        let want_norms = artifacts::read_f32(&g["norms"]).unwrap();

        let sop = StatsOp::load(&rt, op).unwrap();
        let (mu, s2, norms) = sop.run(&v).unwrap();
        // jax (xla_extension in the Python env) and our PJRT (0.5.1) fuse
        // reductions differently -> last-ulp drift; compare with tolerance.
        let close = |a: &[f32], b: &[f32], tol: f32| {
            a.iter()
                .zip(b)
                .all(|(x, y)| (x - y).abs() <= tol * y.abs().max(1e-6))
        };
        assert!(close(&mu, &want_mu, 1e-5));
        assert!(close(&s2, &want_s2, 1e-4));
        assert!(close(&norms, &want_norms, 1e-5));

        // Host path agrees within f32 tolerance.
        for b in 0..op.n / op.bucket {
            let s = crate::stats::BucketStats::from_bucket(
                &v[b * op.bucket..(b + 1) * op.bucket],
                crate::quant::NormType::L2,
            );
            assert!((s.mu - mu[b] as f64).abs() < 1e-6);
            assert!((s.sigma2 - s2[b] as f64).abs() < 1e-6);
            assert!((s.norm - norms[b] as f64).abs() < 1e-4);
        }
    }
}
