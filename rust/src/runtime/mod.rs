//! PJRT runtime: load and execute the AOT HLO artifacts from Rust.
//!
//! Python runs once at build time (`make artifacts`); afterwards this
//! module is the only consumer of the outputs:
//!
//! * [`artifacts`] — `manifest.json` (parsed with the built-in JSON
//!   parser) + raw `.bin` golden tensors.
//! * [`client`] — `PjRtClient` wrapper: HLO text → compile → executable.
//! * [`executable`] — typed entry points (`TrainStep`, `EvalStep`,
//!   `QuantizeOp`, `StatsOp`) with shape checking against the manifest.
//!
//! The PJRT-backed modules are gated behind the `pjrt` cargo feature
//! (the vendored `xla` crate); without it a stub with the same surface
//! is compiled so the rest of the stack builds and tests everywhere.

pub mod artifacts;
pub mod pallas;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use artifacts::{LayoutEntry, Manifest, ModelEntry};
pub use pallas::PallasQuantize;
#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use executable::{EvalStep, QuantizeOp, StatsOp, TrainStep};
#[cfg(not(feature = "pjrt"))]
pub use stub::{EvalStep, QuantizeOp, Runtime, StatsOp, TrainStep};
