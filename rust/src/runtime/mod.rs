//! PJRT runtime: load and execute the AOT HLO artifacts from Rust.
//!
//! Python runs once at build time (`make artifacts`); afterwards this
//! module is the only consumer of the outputs:
//!
//! * [`artifacts`] — `manifest.json` (parsed with the built-in JSON
//!   parser) + raw `.bin` golden tensors.
//! * [`client`] — `PjRtClient` wrapper: HLO text → compile → executable.
//! * [`executable`] — typed entry points (`TrainStep`, `EvalStep`,
//!   `QuantizeOp`, `StatsOp`) with shape checking against the manifest.

pub mod artifacts;
pub mod client;
pub mod executable;

pub use artifacts::{LayoutEntry, Manifest, ModelEntry};
pub use client::Runtime;
pub use executable::{EvalStep, QuantizeOp, StatsOp, TrainStep};
