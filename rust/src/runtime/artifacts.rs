//! Artifact manifest: what `python/compile/aot.py` produced.

use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named parameter tensor in the flat layout (mirrors
/// `python/compile/model.py::ParamSpec`).
#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub std: f64,
}

impl LayoutEntry {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A model whose train/eval steps were AOT-compiled.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String, // "mlp" | "lm"
    pub param_count: usize,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub layout: Vec<LayoutEntry>,
    pub config: BTreeMap<String, f64>,
    pub goldens: Option<BTreeMap<String, PathBuf>>,
}

impl ModelEntry {
    pub fn cfg(&self, key: &str) -> usize {
        *self
            .config
            .get(key)
            .unwrap_or_else(|| panic!("model {} missing config key {key}", self.name))
            as usize
    }
}

/// A standalone Pallas kernel artifact.
#[derive(Clone, Debug)]
pub struct OpEntry {
    pub name: String,
    pub n: usize,
    pub bucket: usize,
    /// Number of magnitude levels (quantize ops only; 0 for stats).
    pub k: usize,
    pub norm_type: String,
    pub hlo: PathBuf,
    pub goldens: Option<BTreeMap<String, PathBuf>>,
}

/// Parsed `artifacts/manifest.json` with resolved paths.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    pub quantize: BTreeMap<String, OpEntry>,
    pub stats: BTreeMap<String, OpEntry>,
}

impl Manifest {
    /// Default artifacts directory: `$AQSGD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("AQSGD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
            })
    }

    pub fn load_default() -> Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let goldens_of = |entry: &Json| -> Option<BTreeMap<String, PathBuf>> {
            let g = entry.get("goldens")?;
            let obj = g.as_obj()?;
            Some(
                obj.iter()
                    .map(|(k, v)| (k.clone(), dir.join(v.as_str().unwrap())))
                    .collect(),
            )
        };

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").as_obj().context("models")? {
            let layout = m
                .req("layout")
                .as_arr()
                .context("layout")?
                .iter()
                .map(|e| LayoutEntry {
                    name: e.req("name").as_str().unwrap().to_string(),
                    shape: e
                        .req("shape")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    init: e.req("init").as_str().unwrap().to_string(),
                    std: e.req("std").as_f64().unwrap(),
                })
                .collect();
            let config = m
                .req("config")
                .as_obj()
                .unwrap()
                .iter()
                .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                .collect();
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    kind: m.req("kind").as_str().unwrap().to_string(),
                    param_count: m.req("param_count").as_usize().unwrap(),
                    train_hlo: dir.join(m.req("train_hlo").as_str().unwrap()),
                    eval_hlo: dir.join(m.req("eval_hlo").as_str().unwrap()),
                    layout,
                    config,
                    goldens: goldens_of(m),
                },
            );
        }

        let parse_ops = |key: &str| -> Result<BTreeMap<String, OpEntry>> {
            let mut out = BTreeMap::new();
            for (name, o) in j.req(key).as_obj().context("ops")? {
                out.insert(
                    name.clone(),
                    OpEntry {
                        name: name.clone(),
                        n: o.req("n").as_usize().unwrap(),
                        bucket: o.req("bucket").as_usize().unwrap(),
                        k: o.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                        norm_type: o.req("norm_type").as_str().unwrap().to_string(),
                        hlo: dir.join(o.req("hlo").as_str().unwrap()),
                        goldens: goldens_of(o),
                    },
                );
            }
            Ok(out)
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            quantize: parse_ops("quantize")?,
            stats: parse_ops("stats")?,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model {name} not in manifest (have: {:?})", self.models.keys()))
    }
}

// ---------------------------------------------------------------------------
// Raw golden tensors.
// ---------------------------------------------------------------------------

pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{path:?} not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{path:?} not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn read_i8(path: &Path) -> Result<Vec<i8>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    Ok(bytes.iter().map(|&b| b as i8).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_manifest() {
        if !have_artifacts() {
            crate::trace::warn("artifacts", "skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load_default().unwrap();
        let tiny = m.model("mlp_tiny").unwrap();
        assert_eq!(tiny.kind, "mlp");
        let total: usize = tiny.layout.iter().map(|e| e.size()).sum();
        assert_eq!(total, tiny.param_count);
        assert!(tiny.train_hlo.exists());
        assert!(tiny.eval_hlo.exists());
        assert!(m.quantize.contains_key("quantize_tiny"));
        assert!(m.stats.contains_key("stats_tiny"));
    }

    #[test]
    fn goldens_readable() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load_default().unwrap();
        let tiny = m.model("mlp_tiny").unwrap();
        let g = tiny.goldens.as_ref().expect("mlp_tiny has goldens");
        let params = read_f32(&g["params"]).unwrap();
        assert_eq!(params.len(), tiny.param_count);
        let loss = read_f32(&g["loss"]).unwrap();
        assert_eq!(loss.len(), 1);
        assert!(loss[0].is_finite() && loss[0] > 0.0);
        let y = read_i32(&g["in1"]).unwrap();
        assert_eq!(y.len(), tiny.cfg("batch"));
    }
}
