//! Stub runtime used when the `pjrt` feature is off.
//!
//! The offline image vendors the `xla` crate closure, but plain source
//! checkouts (and CI) have no xla_extension. This module mirrors the
//! public surface of [`super::client`] / [`super::executable`] so every
//! consumer compiles unchanged; constructors return a descriptive error,
//! and callers that guard on artifact presence (all of them) skip
//! gracefully. Build with `--features pjrt` (plus the vendored `xla`
//! dependency — see Cargo.toml) for the real PJRT path.

use super::artifacts::{ModelEntry, OpEntry};
use anyhow::{bail, Result};
use std::path::Path;

fn unavailable<T>(what: &str) -> Result<T> {
    bail!(
        "{what} requires the PJRT runtime; this build has the `pjrt` \
         feature disabled (see Cargo.toml)"
    )
}

/// Stub for the PJRT client wrapper. [`Runtime::cpu`] always errors, so
/// values of this type are never constructed.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        unavailable("creating a PJRT client")
    }

    pub fn platform(&self) -> String {
        "stub (pjrt disabled)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile_hlo_text(&self, path: &Path) -> Result<()> {
        unavailable(&format!("compiling HLO text {path:?}"))
    }
}

/// Stub fused fwd+bwd step.
pub struct TrainStep {
    entry: ModelEntry,
}

impl TrainStep {
    pub fn load(_rt: &Runtime, entry: &ModelEntry) -> Result<TrainStep> {
        unavailable(&format!("loading train step for {:?}", entry.kind))
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn run_mlp(&self, _params: &[f32], _x: &[f32], _y: &[i32]) -> Result<(f32, Vec<f32>)> {
        unavailable("running an mlp train step")
    }

    pub fn run_lm(&self, _params: &[f32], _tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        unavailable("running an lm train step")
    }
}

/// Stub eval step.
pub struct EvalStep {
    entry: ModelEntry,
}

impl EvalStep {
    pub fn load(_rt: &Runtime, entry: &ModelEntry) -> Result<EvalStep> {
        unavailable(&format!("loading eval step for {:?}", entry.kind))
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn run_mlp(&self, _params: &[f32], _x: &[f32], _y: &[i32]) -> Result<(f32, f32)> {
        unavailable("running an mlp eval step")
    }

    pub fn run_lm(&self, _params: &[f32], _tokens: &[i32]) -> Result<f32> {
        unavailable("running an lm eval step")
    }
}

/// Stub Pallas quantize artifact.
pub struct QuantizeOp {
    pub n: usize,
    pub bucket: usize,
    pub k: usize,
}

impl QuantizeOp {
    pub fn load(_rt: &Runtime, _op: &OpEntry) -> Result<QuantizeOp> {
        unavailable("loading the quantize kernel artifact")
    }

    pub fn run(&self, _v: &[f32], _levels: &[f32], _u: &[f32]) -> Result<(Vec<i8>, Vec<f32>)> {
        unavailable("running the quantize kernel")
    }
}

/// Stub Pallas stats artifact.
pub struct StatsOp {
    pub n: usize,
    pub bucket: usize,
}

impl StatsOp {
    pub fn load(_rt: &Runtime, _op: &OpEntry) -> Result<StatsOp> {
        unavailable("loading the stats kernel artifact")
    }

    #[allow(clippy::type_complexity)]
    pub fn run(&self, _v: &[f32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        unavailable("running the stats kernel")
    }
}
