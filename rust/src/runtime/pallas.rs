//! Lane-facing handle for the L1 Pallas quantize kernel
//! (`--quantize-impl pallas`).
//!
//! [`PallasQuantize::try_new`] stands up the PJRT client and compiles the
//! manifest's main quantize artifact once; the exchange layer shares the
//! handle across lanes behind an `Arc` so the device path inherits the
//! lane fan-out. Construction errors — the `pjrt` feature is off (the
//! stub [`Runtime`] always errors), artifacts are absent, compilation
//! fails — are returned to the caller, which downgrades the session to
//! the fast host path with a one-time warning. A live handle still only
//! covers gradients that match the AOT-fixed shape and kernel semantics
//! (see [`PallasQuantize::compatible`]); incompatible calls fall back
//! per-call.

use super::{Manifest, QuantizeOp, Runtime};
use crate::quant::{NormType, QuantizedGrad, Quantizer};
use anyhow::{bail, Context, Result};
use std::fmt;

/// A compiled, ready-to-run quantize kernel plus the client that owns
/// its device buffers.
pub struct PallasQuantize {
    // The PJRT client must outlive the loaded executable.
    _rt: Runtime,
    op: QuantizeOp,
}

impl fmt::Debug for PallasQuantize {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PallasQuantize")
            .field("n", &self.op.n)
            .field("bucket", &self.op.bucket)
            .field("k", &self.op.k)
            .finish()
    }
}

impl PallasQuantize {
    /// Create the PJRT client, load the default artifact manifest, and
    /// compile its `quantize_main` op. Every failure mode (stub runtime,
    /// missing artifacts, bad HLO) surfaces as an error the session
    /// layer turns into a fast-path downgrade.
    pub fn try_new() -> Result<PallasQuantize> {
        let rt = Runtime::cpu().context("pallas quantize: creating the PJRT client")?;
        let manifest =
            Manifest::load_default().context("pallas quantize: loading the artifact manifest")?;
        let entry = match manifest.quantize.get("quantize_main") {
            Some(e) => e,
            None => bail!("pallas quantize: manifest has no `quantize_main` op"),
        };
        let op = QuantizeOp::load(&rt, entry).context("pallas quantize: compiling the HLO")?;
        Ok(PallasQuantize { _rt: rt, op })
    }

    /// Coordinate count the artifact was AOT-compiled for.
    pub fn n(&self) -> usize {
        self.op.n
    }

    /// Whether this artifact can stand in for `q` on a gradient of `len`
    /// coordinates: the AOT shape matches and the kernel's fixed
    /// semantics (zero level, L2 bucket norms, no clipping) apply.
    pub fn compatible(&self, q: &Quantizer, len: usize) -> bool {
        len == self.op.n
            && q.bucket() == self.op.bucket
            && q.levels().k() == self.op.k
            && q.levels().has_zero()
            && q.norm_type() == NormType::L2
            && q.clip_factor().is_none()
    }

    /// Run the kernel on one gradient with caller-supplied uniform
    /// variates (one per coordinate), writing symbols, norms, and the
    /// raw tail into `out`. Semantics match
    /// [`Quantizer::quantize_with_u`] on the same inputs.
    pub fn run_into(
        &self,
        q: &Quantizer,
        v: &[f32],
        u: &[f32],
        out: &mut QuantizedGrad,
    ) -> Result<()> {
        if !self.compatible(q, v.len()) {
            bail!(
                "pallas quantize: artifact (n={}, bucket={}, k={}) does not cover this call",
                self.op.n,
                self.op.bucket,
                self.op.k
            );
        }
        let levels = q.levels().mags_f32();
        let (qidx, norms) = self.op.run(v, &levels, u)?;
        let nb = self.op.n / self.op.bucket;
        let full = nb * self.op.bucket;
        out.qidx.clear();
        out.qidx.extend_from_slice(&qidx[..full]);
        out.norms = norms;
        out.tail.clear();
        out.tail.extend_from_slice(&v[full..]);
        out.bucket = self.op.bucket;
        Ok(())
    }
}
