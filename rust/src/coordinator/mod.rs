//! Wire-true distributed data-parallel runtime (Algorithm 1 over TCP).
//!
//! The [`crate::sim::Cluster`] simulates M workers in-process (the paper's
//! own evaluation methodology); this module is the production topology:
//! one **leader** process relaying encoded gradients between M **worker**
//! processes over length-prefixed TCP frames.
//!
//! Synchronization model:
//! * Workers compute, quantize, entropy-encode, and send their gradient;
//!   the leader barriers on all M, then broadcasts the concatenation.
//! * Every worker decodes all M gradients, aggregates, and applies the
//!   same optimizer step — replicas stay **bit-identical** (asserted in
//!   tests) because quantization randomness is per-worker-seeded and the
//!   exchanged ciphertext is identical.
//! * At update steps (𝒰 of Algorithm 1), each worker re-fits the level
//!   optimizer on the *decoded* gradients of the previous exchange —
//!   identical inputs ⇒ identical adapted levels, no extra round-trips
//!   (this is the paper's "processors update their compression schemes
//!   in parallel").
//!
//! Beyond the flat relay, the leader and workers speak the sharded and
//! hierarchical schedules of `exchange::topology` (`--topology
//! sharded:S|tree:G`): S shard relay lanes with bit-identical-to-flat
//! replicas, or a two-level tree whose group leaders re-quantize and
//! relay partial aggregates (replica-identical, per-seed golden).
//!
//! Membership is **elastic**: the leader tracks the active worker set
//! per step, drops workers that miss their per-frame deadline (bounded
//! retries with doubling timeouts, [`ElasticPolicy`]) or hang up, and
//! activates scheduled late joiners. Every broadcast names its senders
//! and the post-transition active set, so survivors renormalize to a
//! weighted partial aggregate (each survivor contributes `1/n_active`)
//! without any out-of-band signaling. Deterministic churn is injected
//! with `--faults` (see [`crate::sim::FaultPlan`]).

pub mod leader;
pub mod messages;
pub mod worker;

pub use leader::{
    run_leader, run_leader_elastic, run_leader_traced, ElasticPolicy, LeaderConfig, LeaderReport,
    LeaderStepRecord,
};
pub use worker::{
    run_worker, run_worker_traced, WorkerConfig, WorkerReport, WorkerStepRecord,
};
