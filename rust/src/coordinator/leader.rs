//! The leader: a barrier + relay for encoded gradients, with elastic
//! membership.
//!
//! The leader never decodes gradients — it is a pure switchboard, so its
//! per-step cost is O(total encoded bytes). All model math stays on the
//! workers (mirroring the decentralized all-to-all of the paper, with the
//! leader standing in for the interconnect).
//!
//! Three relay modes mirror the sim's exchange topologies
//! (`--topology`, see `exchange::topology`):
//!
//! * **flat** — barrier on the active workers' `Grad` frames, broadcast
//!   `AllGrads`.
//! * **sharded:S** — S relay lanes: drain every active worker's S
//!   `ShardGrad` frames (workers send all their shards up front), then
//!   broadcast one `AllShardGrads` per shard. Draining fully before
//!   broadcasting keeps the write/read transition one-directional — no
//!   worker-writing-while-leader-writing cycle, so large frames cannot
//!   deadlock on socket buffers. Workers decode every peer's shards,
//!   so replicas stay bit-identical to the flat relay.
//! * **tree:G** — collect the active workers' `Grad` frames, hand each
//!   non-empty group's first active member (the group leader) its
//!   members' frames, collect the partial-aggregate `LeaderGrad`
//!   frames, broadcast `AllLeaderGrads` to everyone. All replicas
//!   aggregate the same decoded partials, so they stay bit-identical
//!   to each other (though not to the flat run — the partials are
//!   re-quantized).
//!
//! # Elastic membership (timeout-and-drop)
//!
//! Every per-worker receive runs under a per-frame deadline
//! ([`ElasticPolicy::deadline_ms`], 0 = block forever). A deadline miss
//! emits a `timeout` trace event and retries with a doubled deadline,
//! up to [`ElasticPolicy::retries`] extra attempts; exhaustion — or a
//! clean EOF, or any socket error — drops the worker (`member_drop`
//! event + `trace::warn` notice) and the relay continues with the
//! survivors. Every broadcast carries the frame senders (`members`) and
//! the post-transition active set (`active`), so receivers aggregate
//! exactly the surviving contributions and weight by `1/members.len()`
//! (== `1/active.len()` whenever `--lazy` is off) — weighted partial
//! aggregation as a protocol-level contract (survivor weights always
//! sum to 1).
//!
//! Late joiners announce their join step in `Hello` (they connect up
//! front, replicate silently from step 0, and start sending at their
//! join step — the leader activates them there with a `member_join`
//! event).
//!
//! # Lazy aggregation (`--lazy`)
//!
//! An active worker whose update is below its `--lazy` gate sends a
//! 13-byte [`Msg::Skip`] marker instead of a frame. The leader counts
//! the marker toward the barrier (the worker is alive, not dropped),
//! emits a `skip` event, charges [`SKIP_MARKER_BITS`], and excludes
//! the worker from the broadcast's `members` — so receivers renormalize
//! over the senders exactly as they do for dropped workers. Skip
//! markers are never relayed downstream.

use super::messages::{Msg, WireGrad};
use crate::exchange::topology::{group_members, TopologySpec};
use crate::exchange::SKIP_MARKER_BITS;
use crate::trace::{Level, Tracer};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct LeaderConfig {
    /// Bind address, e.g. "127.0.0.1:7700". Port 0 picks a free port.
    pub bind: String,
    pub world: usize,
    pub steps: usize,
    /// Relay schedule (flat | sharded:S | tree:G; ring is sim-only).
    pub topology: TopologySpec,
    /// Timeout-and-drop policy for per-worker receives.
    pub elastic: ElasticPolicy,
}

/// Per-frame deadline + bounded-retry policy for the elastic relay.
#[derive(Clone, Copy, Debug)]
pub struct ElasticPolicy {
    /// Per-frame receive deadline in milliseconds; 0 blocks forever
    /// (no timeout-and-drop, the pre-elastic behavior).
    pub deadline_ms: u64,
    /// Extra attempts after the first deadline miss; the deadline
    /// doubles on every retry (exponential backoff).
    pub retries: u32,
}

impl Default for ElasticPolicy {
    fn default() -> Self {
        ElasticPolicy {
            deadline_ms: 5000,
            retries: 3,
        }
    }
}

/// Per-step relay record: the post-transition active set and the
/// payload bits barriered this step — the leader-side projection the
/// fault-parity tests compare against the sim's `StepStats`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaderStepRecord {
    pub step: u32,
    /// Bit w set ⇔ worker w was active after this step's joins/drops.
    pub active_mask: u64,
    /// Payload bits received (relayed upward) this step.
    pub bits: u64,
}

/// Everything an elastic leader run produces.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    /// Total relayed payload bits across the run.
    pub total_bits: u64,
    /// One record per step, in step order.
    pub steps: Vec<LeaderStepRecord>,
}

type Conn = (BufReader<TcpStream>, TcpStream);

/// Run the leader until `steps` exchanges have completed.
/// Returns total relayed payload bits.
pub fn run_leader(cfg: &LeaderConfig) -> Result<u64> {
    run_leader_traced(cfg, &Tracer::disabled())
}

/// [`run_leader`] with structured telemetry (`--trace`): connect
/// lifecycle plus per-step relay records (frames, bits, latency).
pub fn run_leader_traced(cfg: &LeaderConfig, tracer: &Tracer) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.bind).context("leader bind")?;
    run_leader_elastic(listener, cfg.world, cfg.steps, cfg.topology, cfg.elastic, tracer)
        .map(|r| r.total_bits)
}

/// Flat leader loop over an already-bound listener (lets tests use
/// port 0); kept as the default-topology entry point.
pub fn run_leader_on(listener: TcpListener, world: usize, steps: usize) -> Result<u64> {
    run_leader_topo(listener, world, steps, TopologySpec::Flat)
}

/// Leader loop over an already-bound listener with an explicit relay
/// topology.
pub fn run_leader_topo(
    listener: TcpListener,
    world: usize,
    steps: usize,
    topology: TopologySpec,
) -> Result<u64> {
    run_leader_topo_traced(listener, world, steps, topology, &Tracer::disabled())
}

/// [`run_leader_topo`] with structured telemetry.
pub fn run_leader_topo_traced(
    listener: TcpListener,
    world: usize,
    steps: usize,
    topology: TopologySpec,
    tracer: &Tracer,
) -> Result<u64> {
    run_leader_elastic(
        listener,
        world,
        steps,
        topology,
        ElasticPolicy::default(),
        tracer,
    )
    .map(|r| r.total_bits)
}

/// The full elastic leader loop: timeout-and-drop relay with per-step
/// membership records. All other entry points delegate here.
pub fn run_leader_elastic(
    listener: TcpListener,
    world: usize,
    steps: usize,
    topology: TopologySpec,
    policy: ElasticPolicy,
    tracer: &Tracer,
) -> Result<LeaderReport> {
    tracer.event(Level::Info, "run_start", |o| {
        o.insert("runtime", Json::Str("leader".into()));
        o.insert("world", Json::Num(world as f64));
        o.insert("steps", Json::Num(steps as f64));
        o.insert("topology", Json::Str(topology.name()));
    });
    let mut conns: Vec<Option<Conn>> = (0..world).map(|_| None).collect();
    let mut join_step = vec![0usize; world];
    for _ in 0..world {
        let (stream, _) = listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        match Msg::read_from(&mut reader)? {
            Msg::Hello { worker, world: w, join } => {
                if w as usize != world {
                    bail!("worker announced world {w}, leader has {world}");
                }
                let slot = worker as usize;
                if slot >= world || conns[slot].is_some() {
                    bail!("bad or duplicate worker id {worker}");
                }
                tracer.event(Level::Info, "connect", |o| {
                    o.insert("worker", Json::Num(f64::from(worker)));
                    o.insert("world", Json::Num(world as f64));
                });
                join_step[slot] = join as usize;
                conns[slot] = Some((reader, stream));
            }
            other => bail!("expected Hello, got {other:?}"),
        }
    }

    let active = (0..world).map(|w| join_step[w] == 0).collect();
    let mut st = ElasticState {
        conns,
        active,
        join_step,
        bits: 0,
        records: Vec::with_capacity(steps),
    };

    match topology {
        TopologySpec::Flat => relay_flat(&mut st, steps, policy, tracer)?,
        TopologySpec::Sharded(s) => relay_sharded(&mut st, steps, s, policy, tracer)?,
        TopologySpec::Tree(g) => {
            if g > world {
                bail!("tree:{g} needs at most {world} groups");
            }
            relay_tree(&mut st, steps, g, policy, tracer)?
        }
        TopologySpec::Ring => {
            bail!("ring is a simulation schedule; the TCP relay supports flat|sharded:S|tree:G")
        }
    };
    for conn in st.conns.iter_mut().flatten() {
        Msg::Done.write_to(&mut conn.1).ok();
    }
    tracer.event(Level::Info, "run_end", |o| {
        o.insert("steps", Json::Num(steps as f64));
        o.insert("total_bits", Json::Num(st.bits as f64));
    });
    Ok(LeaderReport {
        total_bits: st.bits,
        steps: st.records,
    })
}

/// Leader-side membership + connection state for one elastic run.
struct ElasticState {
    conns: Vec<Option<Conn>>,
    active: Vec<bool>,
    join_step: Vec<usize>,
    bits: u64,
    records: Vec<LeaderStepRecord>,
}

impl ElasticState {
    /// Activate scheduled joiners whose join step is `step` (founding
    /// members are active from the start and never pass through here).
    fn begin_step(&mut self, step: usize, tracer: &Tracer) {
        for w in 0..self.active.len() {
            if self.join_step[w] == step && step > 0 && !self.active[w] && self.conns[w].is_some() {
                self.active[w] = true;
                let n = self.n_active();
                tracer.event(Level::Info, "member_join", |o| {
                    o.insert("step", Json::Num(step as f64));
                    o.insert("worker", Json::Num(w as f64));
                    o.insert("active", Json::Num(n as f64));
                    o.insert("weight_sum", Json::Num(1.0));
                });
            }
        }
    }

    fn n_active(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Active worker ids, ascending.
    fn active_ids(&self) -> Vec<u32> {
        (0..self.active.len() as u32)
            .filter(|&w| self.active[w as usize])
            .collect()
    }

    fn active_mask(&self) -> u64 {
        self.active
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .fold(0u64, |m, (w, _)| m | (1u64 << w))
    }

    /// Drop a worker from the relay: close its slot, shrink the active
    /// set, emit the `member_drop` event, and warn. Idempotent.
    fn drop_worker(&mut self, step: usize, w: usize, reason: &str, tracer: &Tracer) {
        let was_active = self.active[w];
        self.conns[w] = None;
        self.active[w] = false;
        if !was_active {
            return;
        }
        let n = self.n_active();
        tracer.event(Level::Info, "member_drop", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("worker", Json::Num(w as f64));
            o.insert("active", Json::Num(n as f64));
            o.insert("weight_sum", Json::Num(1.0));
        });
        crate::trace::warn(
            "leader",
            &format!("worker {w} dropped at step {step} ({reason}); {n} active"),
        );
    }

    /// Receive one frame from worker `w` under the timeout-and-drop
    /// policy. Returns `Ok(None)` when the worker was dropped instead
    /// (deadline exhausted, EOF, or socket error); protocol violations
    /// from a live worker still fail the run.
    fn recv(
        &mut self,
        step: usize,
        w: usize,
        policy: ElasticPolicy,
        tracer: &Tracer,
    ) -> Result<Option<Msg>> {
        enum Wait {
            Eof,
            Ready,
            Timeout,
            Error,
        }
        if self.conns[w].is_none() {
            return Ok(None);
        }
        if policy.deadline_ms == 0 {
            // Pre-elastic blocking behavior: any read error is fatal.
            let conn = self.conns[w].as_mut().expect("conn present");
            return Msg::read_from(&mut conn.0).map(Some);
        }
        let mut deadline_ms = policy.deadline_ms;
        for attempt in 0..=policy.retries {
            // A non-consuming readiness wait: BufReader::fill_buf
            // returns buffered or freshly-read bytes without consuming
            // them, `Ok(&[])` on clean EOF, and a WouldBlock/TimedOut
            // error on deadline miss — so a timed-out wait never
            // desyncs mid-frame.
            let wait = {
                let conn = self.conns[w].as_mut().expect("conn present");
                conn.1
                    .set_read_timeout(Some(Duration::from_millis(deadline_ms)))
                    .ok();
                match conn.0.fill_buf() {
                    Ok(buf) if buf.is_empty() => Wait::Eof,
                    Ok(_) => Wait::Ready,
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        Wait::Timeout
                    }
                    Err(_) => Wait::Error,
                }
            };
            match wait {
                Wait::Eof => {
                    self.drop_worker(step, w, "connection closed", tracer);
                    return Ok(None);
                }
                Wait::Ready => {
                    let conn = self.conns[w].as_mut().expect("conn present");
                    return match Msg::read_from(&mut conn.0) {
                        Ok(msg) => Ok(Some(msg)),
                        Err(_) => {
                            self.drop_worker(step, w, "read error", tracer);
                            Ok(None)
                        }
                    };
                }
                Wait::Timeout => {
                    tracer.event(Level::Info, "timeout", |o| {
                        o.insert("step", Json::Num(step as f64));
                        o.insert("worker", Json::Num(w as f64));
                        o.insert("attempt", Json::Num(f64::from(attempt)));
                        o.insert("deadline_ms", Json::Num(deadline_ms as f64));
                    });
                    crate::trace::warn(
                        "leader",
                        &format!(
                            "worker {w} missed the {deadline_ms}ms deadline at step {step} \
                             (attempt {attempt})"
                        ),
                    );
                    deadline_ms = deadline_ms.saturating_mul(2);
                }
                Wait::Error => {
                    self.drop_worker(step, w, "socket error", tracer);
                    return Ok(None);
                }
            }
        }
        self.drop_worker(step, w, "deadline exhausted", tracer);
        Ok(None)
    }

    /// Broadcast a message to every connected worker (active and
    /// standby — late joiners replicate from the broadcasts). A write
    /// error drops the worker.
    fn broadcast(&mut self, step: usize, msg: &Msg, tracer: &Tracer) {
        for w in 0..self.conns.len() {
            let ok = match self.conns[w].as_mut() {
                Some(conn) => msg.write_to(&mut conn.1).is_ok(),
                None => continue,
            };
            if !ok {
                self.drop_worker(step, w, "write error", tracer);
            }
        }
    }

    fn finish_step(&mut self, step: usize, step_bits: u64) {
        self.bits += step_bits;
        self.records.push(LeaderStepRecord {
            step: step as u32,
            active_mask: self.active_mask(),
            bits: step_bits,
        });
    }
}

/// Per-step `relay` record: frames barriered + payload bits moved this
/// step, with the step's wall-clock relay latency.
fn trace_relay(tracer: &Tracer, step: usize, frames: usize, bits: u64, t0: Instant) {
    tracer.event(Level::Debug, "relay", |o| {
        o.insert("step", Json::Num(step as f64));
        o.insert("frames", Json::Num(frames as f64));
        o.insert("bits", Json::Num(bits as f64));
        o.insert("wall_seconds", Json::Num(t0.elapsed().as_secs_f64()));
    });
}

/// Barrier on the expected senders' `Grad` frames; returns the senders
/// and their frames, in ascending worker order, plus the workers that
/// sent a lazy [`Msg::Skip`] marker instead, with drops applied.
fn barrier_grads(
    st: &mut ElasticState,
    step: usize,
    policy: ElasticPolicy,
    tracer: &Tracer,
) -> Result<(Vec<u32>, Vec<WireGrad>, Vec<u32>)> {
    let expected = st.active_ids();
    let mut members = Vec::with_capacity(expected.len());
    let mut grads = Vec::with_capacity(expected.len());
    let mut skipped: Vec<u32> = Vec::new();
    for w in expected {
        match st.recv(step, w as usize, policy, tracer)? {
            Some(Msg::Grad { step: s, grad }) => {
                if s as usize != step {
                    bail!("worker {w} sent step {s}, expected {step}");
                }
                members.push(w);
                grads.push(grad);
            }
            Some(Msg::Skip { step: s, worker: ww }) => {
                if s as usize != step || ww != w {
                    bail!("worker {w} sent skip for step {s}/worker {ww}, expected {step}/{w}");
                }
                skipped.push(w);
            }
            Some(other) => bail!("expected Grad, got {other:?}"),
            None => {} // dropped
        }
    }
    trace_skips(tracer, step, &members, &skipped);
    Ok((members, grads, skipped))
}

/// One `skip` event per zero-frame worker, mirroring the sim's
/// planning-path events: the survivors' renormalized weights sum to 1
/// (0 when every sender skipped and the step moves no frames at all).
fn trace_skips(tracer: &Tracer, step: usize, members: &[u32], skipped: &[u32]) {
    let weight_sum = if members.is_empty() { 0.0 } else { 1.0 };
    for &w in skipped {
        tracer.event(Level::Info, "skip", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("worker", Json::Num(f64::from(w)));
            o.insert("bits", Json::Num(SKIP_MARKER_BITS as f64));
            o.insert("weight_sum", Json::Num(weight_sum));
        });
    }
}

fn relay_flat(
    st: &mut ElasticState,
    steps: usize,
    policy: ElasticPolicy,
    tracer: &Tracer,
) -> Result<()> {
    for step in 0..steps {
        let t0 = Instant::now();
        st.begin_step(step, tracer);
        let (members, grads, skipped) = barrier_grads(st, step, policy, tracer)?;
        let step_bits: u64 =
            grads.iter().map(|g| g.bits).sum::<u64>() + skipped.len() as u64 * SKIP_MARKER_BITS;
        let frames = grads.len();
        let all = Msg::AllGrads {
            step: step as u32,
            members,
            active: st.active_ids(),
            grads,
        };
        st.broadcast(step, &all, tracer);
        trace_relay(tracer, step, frames, step_bits, t0);
        st.finish_step(step, step_bits);
    }
    Ok(())
}

fn relay_sharded(
    st: &mut ElasticState,
    steps: usize,
    shards: usize,
    policy: ElasticPolicy,
    tracer: &Tracer,
) -> Result<()> {
    for step in 0..steps {
        let t0 = Instant::now();
        st.begin_step(step, tracer);
        // Drain every expected worker's full shard set before writing
        // anything: workers write all S frames then switch to reading,
        // so reading everything first makes the socket flow
        // one-directional and immune to buffer-full deadlocks at any
        // frame size. A worker that drops mid-set contributes nothing
        // this step (its partial shards are discarded — receivers need
        // a worker's full shard set to use any of it).
        let expected = st.active_ids();
        let mut members: Vec<u32> = Vec::with_capacity(expected.len());
        let mut frames: Vec<Vec<WireGrad>> = Vec::with_capacity(expected.len());
        let mut skipped: Vec<u32> = Vec::new();
        'worker: for w in expected {
            let mut set = Vec::with_capacity(shards);
            for shard in 0..shards {
                match st.recv(step, w as usize, policy, tracer)? {
                    Some(Msg::ShardGrad {
                        step: s,
                        shard: sh,
                        grad,
                    }) => {
                        if s as usize != step || sh as usize != shard {
                            bail!("worker {w} sent step {s} shard {sh}, expected {step}/{shard}");
                        }
                        set.push(grad);
                    }
                    // A lazy skipper ships ONE marker for the whole
                    // shard set, in place of its first shard frame.
                    Some(Msg::Skip { step: s, worker: ww }) if shard == 0 => {
                        if s as usize != step || ww != w {
                            bail!(
                                "worker {w} sent skip for step {s}/worker {ww}, \
                                 expected {step}/{w}"
                            );
                        }
                        skipped.push(w);
                        continue 'worker;
                    }
                    Some(other) => bail!("expected ShardGrad, got {other:?}"),
                    None => continue 'worker, // dropped; discard partial set
                }
            }
            members.push(w);
            frames.push(set);
        }
        trace_skips(tracer, step, &members, &skipped);
        let step_bits: u64 = frames.iter().flatten().map(|g| g.bits).sum::<u64>()
            + skipped.len() as u64 * SKIP_MARKER_BITS;
        let n_frames = frames.len() * shards;
        let active = st.active_ids();
        // Pop each worker's shard frames off the back (so the per-shard
        // broadcasts own their frames without cloning payloads), then
        // send in ascending shard order — the order workers read them.
        let mut shard_msgs: Vec<Msg> = Vec::with_capacity(shards);
        for shard in (0..shards).rev() {
            let grads: Vec<WireGrad> = frames
                .iter_mut()
                .map(|set| set.pop().expect("full shard set"))
                .collect();
            shard_msgs.push(Msg::AllShardGrads {
                step: step as u32,
                shard: shard as u32,
                members: members.clone(),
                active: active.clone(),
                grads,
            });
        }
        shard_msgs.reverse();
        for msg in &shard_msgs {
            st.broadcast(step, msg, tracer);
        }
        trace_relay(tracer, step, n_frames, step_bits, t0);
        st.finish_step(step, step_bits);
    }
    Ok(())
}

fn relay_tree(
    st: &mut ElasticState,
    steps: usize,
    groups: usize,
    policy: ElasticPolicy,
    tracer: &Tracer,
) -> Result<()> {
    let world = st.conns.len();
    for step in 0..steps {
        let t0 = Instant::now();
        st.begin_step(step, tracer);
        // 1. Barrier on the active workers' frames (lazy skippers
        // send markers and stay out of `members`).
        let (members, grads, skipped) = barrier_grads(st, step, policy, tracer)?;
        let up_bits: u64 =
            grads.iter().map(|g| g.bits).sum::<u64>() + skipped.len() as u64 * SKIP_MARKER_BITS;

        // 2. Hand each non-empty group's first active member (the
        // group leader under churn) its members' frames.
        let mut group_leaders: Vec<(u32, u32)> = Vec::with_capacity(groups); // (group, leader)
        for g in 0..groups {
            let range = group_members(world, groups, g);
            let idx: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(_, &w)| range.contains(&(w as usize)))
                .map(|(i, _)| i)
                .collect();
            let Some(&first) = idx.first() else {
                continue; // no active member: the group is silent this step
            };
            let leader = members[first] as usize;
            let msg = Msg::AllGrads {
                step: step as u32,
                members: idx.iter().map(|&i| members[i]).collect(),
                // The group leader scales its partial by the *global*
                // sender count, so this hop carries the global senders
                // (identical to the active set when lazy is off — the
                // post-barrier invariant active_ids() == members).
                active: members.clone(),
                grads: idx.iter().map(|&i| grads[i].clone()).collect(),
            };
            let ok = match st.conns[leader].as_mut() {
                Some(conn) => msg.write_to(&mut conn.1).is_ok(),
                None => false,
            };
            if ok {
                group_leaders.push((g as u32, leader as u32));
            } else {
                st.drop_worker(step, leader, "write error", tracer);
            }
        }

        // 3. Collect the partial-aggregate frames from the group
        // leaders that got their frames; a leader dying here silences
        // its group for this step (drop-and-continue).
        let mut lead_groups: Vec<u32> = Vec::with_capacity(group_leaders.len());
        let mut lead: Vec<WireGrad> = Vec::with_capacity(group_leaders.len());
        let mut lead_bits = 0u64;
        for (g, leader) in group_leaders {
            match st.recv(step, leader as usize, policy, tracer)? {
                Some(Msg::LeaderGrad {
                    step: s,
                    group,
                    grad,
                }) => {
                    if s as usize != step || group != g {
                        bail!("leader {leader} sent step {s} group {group}, expected {step}/{g}");
                    }
                    lead_bits += grad.bits;
                    lead_groups.push(g);
                    lead.push(grad);
                }
                Some(other) => bail!("expected LeaderGrad, got {other:?}"),
                None => {} // dropped; the group is silent this step
            }
        }

        // 4. Broadcast the partials down to everyone.
        let n_frames = members.len() + lead.len();
        let all = Msg::AllLeaderGrads {
            step: step as u32,
            groups: lead_groups,
            members: members.clone(),
            active: st.active_ids(),
            grads: lead,
        };
        st.broadcast(step, &all, tracer);
        let step_bits = up_bits + lead_bits;
        trace_relay(tracer, step, n_frames, step_bits, t0);
        st.finish_step(step, step_bits);
    }
    Ok(())
}
