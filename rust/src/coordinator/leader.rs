//! The leader: a barrier + relay for encoded gradients.
//!
//! The leader never decodes gradients — it is a pure switchboard, so its
//! per-step cost is O(total encoded bytes). All model math stays on the
//! workers (mirroring the decentralized all-to-all of the paper, with the
//! leader standing in for the interconnect).

use super::messages::{Msg, WireGrad};
use anyhow::{bail, Context, Result};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};

#[derive(Clone, Debug)]
pub struct LeaderConfig {
    /// Bind address, e.g. "127.0.0.1:7700". Port 0 picks a free port.
    pub bind: String,
    pub world: usize,
    pub steps: usize,
}

/// Run the leader until `steps` exchanges have completed.
/// Returns total relayed payload bits.
pub fn run_leader(cfg: &LeaderConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.bind).context("leader bind")?;
    run_leader_on(listener, cfg.world, cfg.steps)
}

/// Leader loop over an already-bound listener (lets tests use port 0).
pub fn run_leader_on(listener: TcpListener, world: usize, steps: usize) -> Result<u64> {
    let mut conns: Vec<Option<(BufReader<TcpStream>, TcpStream)>> = (0..world).map(|_| None).collect();
    for _ in 0..world {
        let (stream, _) = listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        match Msg::read_from(&mut reader)? {
            Msg::Hello { worker, world: w } => {
                if w as usize != world {
                    bail!("worker announced world {w}, leader has {world}");
                }
                let slot = worker as usize;
                if slot >= world || conns[slot].is_some() {
                    bail!("bad or duplicate worker id {worker}");
                }
                conns[slot] = Some((reader, stream));
            }
            other => bail!("expected Hello, got {other:?}"),
        }
    }
    let mut conns: Vec<(BufReader<TcpStream>, TcpStream)> =
        conns.into_iter().map(|c| c.unwrap()).collect();

    let mut relayed_bits = 0u64;
    for step in 0..steps {
        let mut grads: Vec<Option<WireGrad>> = vec![None; conns.len()];
        for (w, (reader, _)) in conns.iter_mut().enumerate() {
            match Msg::read_from(reader)? {
                Msg::Grad { step: s, grad } => {
                    if s as usize != step {
                        bail!("worker {w} sent step {s}, expected {step}");
                    }
                    relayed_bits += grad.bits;
                    grads[w] = Some(grad);
                }
                other => bail!("expected Grad, got {other:?}"),
            }
        }
        let all = Msg::AllGrads {
            step: step as u32,
            grads: grads.into_iter().map(|g| g.unwrap()).collect(),
        };
        for (_, stream) in conns.iter_mut() {
            all.write_to(stream)?;
        }
    }
    for (_, stream) in conns.iter_mut() {
        Msg::Done.write_to(stream)?;
    }
    Ok(relayed_bits)
}
