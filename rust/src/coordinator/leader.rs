//! The leader: a barrier + relay for encoded gradients.
//!
//! The leader never decodes gradients — it is a pure switchboard, so its
//! per-step cost is O(total encoded bytes). All model math stays on the
//! workers (mirroring the decentralized all-to-all of the paper, with the
//! leader standing in for the interconnect).
//!
//! Three relay modes mirror the sim's exchange topologies
//! (`--topology`, see `exchange::topology`):
//!
//! * **flat** — barrier on M `Grad` frames, broadcast `AllGrads`.
//! * **sharded:S** — S relay lanes: drain every worker's S `ShardGrad`
//!   frames (workers send all their shards up front), then broadcast
//!   one `AllShardGrads` per shard. Draining fully before broadcasting
//!   keeps the write/read transition one-directional — no
//!   worker-writing-while-leader-writing cycle, so large frames cannot
//!   deadlock on socket buffers. Workers decode every peer's shards,
//!   so replicas stay bit-identical to the flat relay.
//! * **tree:G** — collect all M `Grad` frames, hand each group leader
//!   its members' frames, collect the G `LeaderGrad` partial-aggregate
//!   frames, broadcast `AllLeaderGrads` to everyone. All replicas
//!   aggregate the same G decoded partials, so they stay bit-identical
//!   to each other (though not to the flat run — the partials are
//!   re-quantized).

use super::messages::{Msg, WireGrad};
use crate::exchange::topology::{group_members, TopologySpec};
use crate::trace::{Level, Tracer};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct LeaderConfig {
    /// Bind address, e.g. "127.0.0.1:7700". Port 0 picks a free port.
    pub bind: String,
    pub world: usize,
    pub steps: usize,
    /// Relay schedule (flat | sharded:S | tree:G; ring is sim-only).
    pub topology: TopologySpec,
}

type Conn = (BufReader<TcpStream>, TcpStream);

/// Run the leader until `steps` exchanges have completed.
/// Returns total relayed payload bits.
pub fn run_leader(cfg: &LeaderConfig) -> Result<u64> {
    run_leader_traced(cfg, &Tracer::disabled())
}

/// [`run_leader`] with structured telemetry (`--trace`): connect
/// lifecycle plus per-step relay records (frames, bits, latency).
pub fn run_leader_traced(cfg: &LeaderConfig, tracer: &Tracer) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.bind).context("leader bind")?;
    run_leader_topo_traced(listener, cfg.world, cfg.steps, cfg.topology, tracer)
}

/// Flat leader loop over an already-bound listener (lets tests use
/// port 0); kept as the default-topology entry point.
pub fn run_leader_on(listener: TcpListener, world: usize, steps: usize) -> Result<u64> {
    run_leader_topo(listener, world, steps, TopologySpec::Flat)
}

/// Leader loop over an already-bound listener with an explicit relay
/// topology.
pub fn run_leader_topo(
    listener: TcpListener,
    world: usize,
    steps: usize,
    topology: TopologySpec,
) -> Result<u64> {
    run_leader_topo_traced(listener, world, steps, topology, &Tracer::disabled())
}

/// [`run_leader_topo`] with structured telemetry.
pub fn run_leader_topo_traced(
    listener: TcpListener,
    world: usize,
    steps: usize,
    topology: TopologySpec,
    tracer: &Tracer,
) -> Result<u64> {
    tracer.event(Level::Info, "run_start", |o| {
        o.insert("runtime", Json::Str("leader".into()));
        o.insert("world", Json::Num(world as f64));
        o.insert("steps", Json::Num(steps as f64));
        o.insert("topology", Json::Str(topology.name()));
    });
    let mut conns: Vec<Option<Conn>> = (0..world).map(|_| None).collect();
    for _ in 0..world {
        let (stream, _) = listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        match Msg::read_from(&mut reader)? {
            Msg::Hello { worker, world: w } => {
                if w as usize != world {
                    bail!("worker announced world {w}, leader has {world}");
                }
                let slot = worker as usize;
                if slot >= world || conns[slot].is_some() {
                    bail!("bad or duplicate worker id {worker}");
                }
                tracer.event(Level::Info, "connect", |o| {
                    o.insert("worker", Json::Num(f64::from(worker)));
                    o.insert("world", Json::Num(world as f64));
                });
                conns[slot] = Some((reader, stream));
            }
            other => bail!("expected Hello, got {other:?}"),
        }
    }
    let mut conns: Vec<Conn> = conns.into_iter().map(|c| c.unwrap()).collect();

    let relayed = match topology {
        TopologySpec::Flat => relay_flat(&mut conns, steps, tracer)?,
        TopologySpec::Sharded(s) => relay_sharded(&mut conns, steps, s, tracer)?,
        TopologySpec::Tree(g) => {
            if g > world {
                bail!("tree:{g} needs at most {world} groups");
            }
            relay_tree(&mut conns, steps, g, tracer)?
        }
        TopologySpec::Ring => {
            bail!("ring is a simulation schedule; the TCP relay supports flat|sharded:S|tree:G")
        }
    };
    for (_, stream) in conns.iter_mut() {
        Msg::Done.write_to(stream)?;
    }
    tracer.event(Level::Info, "run_end", |o| {
        o.insert("steps", Json::Num(steps as f64));
        o.insert("total_bits", Json::Num(relayed as f64));
    });
    Ok(relayed)
}

/// Per-step `relay` record: frames barriered + payload bits moved this
/// step, with the step's wall-clock relay latency.
fn trace_relay(tracer: &Tracer, step: usize, frames: usize, bits: u64, t0: Instant) {
    tracer.event(Level::Debug, "relay", |o| {
        o.insert("step", Json::Num(step as f64));
        o.insert("frames", Json::Num(frames as f64));
        o.insert("bits", Json::Num(bits as f64));
        o.insert("wall_seconds", Json::Num(t0.elapsed().as_secs_f64()));
    });
}

fn relay_flat(conns: &mut [Conn], steps: usize, tracer: &Tracer) -> Result<u64> {
    let mut relayed_bits = 0u64;
    for step in 0..steps {
        let t0 = Instant::now();
        let step_bits0 = relayed_bits;
        let mut grads: Vec<Option<WireGrad>> = vec![None; conns.len()];
        for (w, (reader, _)) in conns.iter_mut().enumerate() {
            match Msg::read_from(reader)? {
                Msg::Grad { step: s, grad } => {
                    if s as usize != step {
                        bail!("worker {w} sent step {s}, expected {step}");
                    }
                    relayed_bits += grad.bits;
                    grads[w] = Some(grad);
                }
                other => bail!("expected Grad, got {other:?}"),
            }
        }
        let all = Msg::AllGrads {
            step: step as u32,
            grads: grads.into_iter().map(|g| g.unwrap()).collect(),
        };
        for (_, stream) in conns.iter_mut() {
            all.write_to(stream)?;
        }
        trace_relay(tracer, step, conns.len(), relayed_bits - step_bits0, t0);
    }
    Ok(relayed_bits)
}

fn relay_sharded(conns: &mut [Conn], steps: usize, shards: usize, tracer: &Tracer) -> Result<u64> {
    let mut relayed_bits = 0u64;
    for step in 0..steps {
        let t0 = Instant::now();
        let step_bits0 = relayed_bits;
        // Drain every worker's full shard set before writing anything:
        // workers write all S frames then switch to reading, so reading
        // everything first makes the socket flow one-directional and
        // immune to buffer-full deadlocks at any frame size.
        let mut frames: Vec<Vec<Option<WireGrad>>> =
            (0..shards).map(|_| vec![None; conns.len()]).collect();
        for (w, (reader, _)) in conns.iter_mut().enumerate() {
            for shard in 0..shards {
                match Msg::read_from(reader)? {
                    Msg::ShardGrad {
                        step: s,
                        shard: sh,
                        grad,
                    } => {
                        if s as usize != step || sh as usize != shard {
                            bail!(
                                "worker {w} sent step {s} shard {sh}, expected {step}/{shard}"
                            );
                        }
                        relayed_bits += grad.bits;
                        frames[shard][w] = Some(grad);
                    }
                    other => bail!("expected ShardGrad, got {other:?}"),
                }
            }
        }
        for (shard, grads) in frames.into_iter().enumerate() {
            let all = Msg::AllShardGrads {
                step: step as u32,
                shard: shard as u32,
                grads: grads.into_iter().map(|g| g.unwrap()).collect(),
            };
            for (_, stream) in conns.iter_mut() {
                all.write_to(stream)?;
            }
        }
        trace_relay(tracer, step, conns.len() * shards, relayed_bits - step_bits0, t0);
    }
    Ok(relayed_bits)
}

fn relay_tree(conns: &mut [Conn], steps: usize, groups: usize, tracer: &Tracer) -> Result<u64> {
    let world = conns.len();
    let mut relayed_bits = 0u64;
    for step in 0..steps {
        let t0 = Instant::now();
        let step_bits0 = relayed_bits;
        // 1. Barrier on every worker's frame.
        let mut grads: Vec<Option<WireGrad>> = vec![None; world];
        for (w, (reader, _)) in conns.iter_mut().enumerate() {
            match Msg::read_from(reader)? {
                Msg::Grad { step: s, grad } => {
                    if s as usize != step {
                        bail!("worker {w} sent step {s}, expected {step}");
                    }
                    relayed_bits += grad.bits;
                    grads[w] = Some(grad);
                }
                other => bail!("expected Grad, got {other:?}"),
            }
        }
        let grads: Vec<WireGrad> = grads.into_iter().map(|g| g.unwrap()).collect();

        // 2. Hand each group leader its members' frames.
        for g in 0..groups {
            let members = group_members(world, groups, g);
            let leader = members.start;
            let msg = Msg::AllGrads {
                step: step as u32,
                grads: grads[members].to_vec(),
            };
            msg.write_to(&mut conns[leader].1)?;
        }

        // 3. Collect the G partial-aggregate frames.
        let mut lead: Vec<Option<WireGrad>> = vec![None; groups];
        for g in 0..groups {
            let leader = group_members(world, groups, g).start;
            match Msg::read_from(&mut conns[leader].0)? {
                Msg::LeaderGrad {
                    step: s,
                    group,
                    grad,
                } => {
                    if s as usize != step || group as usize != g {
                        bail!("leader {leader} sent step {s} group {group}, expected {step}/{g}");
                    }
                    relayed_bits += grad.bits;
                    lead[g] = Some(grad);
                }
                other => bail!("expected LeaderGrad, got {other:?}"),
            }
        }

        // 4. Broadcast the partials down to everyone.
        let all = Msg::AllLeaderGrads {
            step: step as u32,
            grads: lead.into_iter().map(|g| g.unwrap()).collect(),
        };
        for (_, stream) in conns.iter_mut() {
            all.write_to(stream)?;
        }
        trace_relay(tracer, step, world + groups, relayed_bits - step_bits0, t0);
    }
    Ok(relayed_bits)
}
