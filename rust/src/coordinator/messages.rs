//! Length-prefixed wire frames (dependency-free serialization).
//!
//! Frame layout: `[tag: u8][len: u32 LE][payload: len bytes]`.

use crate::quant::{EncodedGrad, EncodedView};
use anyhow::{bail, Result};
use std::io::{Read, Write};

/// `WireGrad::width` value for raw fp32 frames (no quantizer).
pub const WIDTH_FP32: u32 = 0;

#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker announces itself: (worker_id, world_size, join_step).
    /// `join` is the step at which the worker enters the active set
    /// (0 = founding member, active from the first step).
    Hello { worker: u32, world: u32, join: u32 },
    /// One encoded gradient for a step.
    Grad { step: u32, grad: WireGrad },
    /// Leader broadcast for a step: `grads[i]` is the frame sent by
    /// worker `members[i]`; `active` is the full active worker set
    /// after this step's membership transitions (joins, drops). Every
    /// receiver aggregates over `members` and weights by
    /// `active.len()`, so partial aggregation under churn is a
    /// protocol-level contract, not a per-worker heuristic.
    AllGrads {
        step: u32,
        members: Vec<u32>,
        active: Vec<u32>,
        grads: Vec<WireGrad>,
    },
    /// One bucket-aligned shard of a worker's encoded gradient
    /// (sharded leader mode: the relay barriers and broadcasts per
    /// shard lane).
    ShardGrad { step: u32, shard: u32, grad: WireGrad },
    /// Relay broadcast: every surviving worker's frame for one shard
    /// (`grads[i]` from worker `members[i]`; `active` as in
    /// [`Msg::AllGrads`]).
    AllShardGrads {
        step: u32,
        shard: u32,
        members: Vec<u32>,
        active: Vec<u32>,
        grads: Vec<WireGrad>,
    },
    /// A group leader's encoded partial aggregate (hierarchical mode).
    LeaderGrad { step: u32, group: u32, grad: WireGrad },
    /// Relay broadcast: `grads[i]` is the partial aggregate of group
    /// `groups[i]` (groups with no active member are absent; `active`
    /// as in [`Msg::AllGrads`]). `members` lists the *global* workers
    /// whose frames were folded into the partials — under `--lazy`,
    /// receivers weight by `1/members.len()`, the senders-only count.
    AllLeaderGrads {
        step: u32,
        groups: Vec<u32>,
        members: Vec<u32>,
        active: Vec<u32>,
        grads: Vec<WireGrad>,
    },
    /// Lazy-aggregation skip marker: the worker is alive and at the
    /// barrier for `step`, but its update is below the `--lazy` gate so
    /// it ships no frame. The leader counts it toward the barrier and
    /// excludes it from the broadcast's `members`; this frame is never
    /// relayed. Wire cost is `SKIP_MARKER_BITS` (13 bytes) on both
    /// runtimes.
    Skip { step: u32, worker: u32 },
    /// Orderly end of training.
    Done,
}

/// Serializable form of [`EncodedGrad`], plus the quantization width
/// the frame was encoded at. Piggybacking the width on the frame is
/// what lets a dynamic `--bits-policy` run over the relay with no extra
/// round-trip: the leader stays a dumb switchboard, and every receiver
/// decodes each peer frame with the bank slot the frame names.
#[derive(Clone, Debug, PartialEq)]
pub struct WireGrad {
    pub bits: u64,
    pub n_full: u32,
    pub n_tail: u32,
    pub bucket: u32,
    /// Quantization bit-width of this frame ([`WIDTH_FP32`] for raw
    /// fp32 payloads). Metadata, not charged as payload bits.
    pub width: u32,
    pub bytes: Vec<u8>,
}

impl WireGrad {
    /// Owned conversion (clones the payload). Hot paths should use
    /// [`WireGrad::view`] / [`WireGrad::from_view`] instead — the worker
    /// decodes received frames in place.
    pub fn to_encoded(&self) -> EncodedGrad {
        EncodedGrad {
            bytes: self.bytes.clone(),
            bits: self.bits,
            n_full: self.n_full as usize,
            n_tail: self.n_tail as usize,
            bucket: self.bucket as usize,
        }
    }

    /// Zero-copy frame over the received payload (the decode hot path —
    /// no byte clone per peer gradient).
    pub fn view(&self) -> EncodedView<'_> {
        EncodedView {
            bytes: &self.bytes,
            bits: self.bits,
            n_full: self.n_full as usize,
            n_tail: self.n_tail as usize,
            bucket: self.bucket as usize,
        }
    }

    /// Build a wire frame from a borrowed encoded frame (the one copy
    /// the wire inherently needs: the frame must own its payload),
    /// stamped with the width it was encoded at.
    pub fn from_view(v: EncodedView<'_>, width: u32) -> WireGrad {
        WireGrad {
            bits: v.bits,
            n_full: v.n_full as u32,
            n_tail: v.n_tail as u32,
            bucket: v.bucket as u32,
            width,
            bytes: v.bytes.to_vec(),
        }
    }
}

const TAG_HELLO: u8 = 1;
const TAG_GRAD: u8 = 2;
const TAG_ALL: u8 = 3;
const TAG_DONE: u8 = 4;
const TAG_SHARD: u8 = 5;
const TAG_ALL_SHARD: u8 = 6;
const TAG_LEADER: u8 = 7;
const TAG_ALL_LEADER: u8 = 8;
const TAG_SKIP: u8 = 9;

struct Buf(Vec<u8>);

impl Buf {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.0.extend_from_slice(b);
    }
    fn grad(&mut self, g: &WireGrad) {
        self.u64(g.bits);
        self.u32(g.n_full);
        self.u32(g.n_tail);
        self.u32(g.bucket);
        self.u32(g.width);
        self.bytes(&g.bytes);
    }
    fn ids(&mut self, ids: &[u32]) {
        self.u32(ids.len() as u32);
        for &id in ids {
            self.u32(id);
        }
    }
}

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn u32(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated frame");
        }
        let v = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into()?);
        self.i += 4;
        Ok(v)
    }
    fn u64(&mut self) -> Result<u64> {
        if self.i + 8 > self.b.len() {
            bail!("truncated frame");
        }
        let v = u64::from_le_bytes(self.b[self.i..self.i + 8].try_into()?);
        self.i += 8;
        Ok(v)
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if self.i + n > self.b.len() {
            bail!("truncated frame payload");
        }
        let v = self.b[self.i..self.i + n].to_vec();
        self.i += n;
        Ok(v)
    }
    fn grad(&mut self) -> Result<WireGrad> {
        Ok(WireGrad {
            bits: self.u64()?,
            n_full: self.u32()?,
            n_tail: self.u32()?,
            bucket: self.u32()?,
            width: self.u32()?,
            bytes: self.bytes()?,
        })
    }
    fn ids(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(self.u32()?);
        }
        Ok(ids)
    }
}

impl Msg {
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let (tag, payload) = match self {
            Msg::Hello { worker, world, join } => {
                let mut b = Buf(Vec::with_capacity(12));
                b.u32(*worker);
                b.u32(*world);
                b.u32(*join);
                (TAG_HELLO, b.0)
            }
            Msg::Grad { step, grad } => {
                let mut b = Buf(Vec::with_capacity(24 + grad.bytes.len()));
                b.u32(*step);
                b.grad(grad);
                (TAG_GRAD, b.0)
            }
            Msg::AllGrads {
                step,
                members,
                active,
                grads,
            } => {
                let mut b = Buf(Vec::new());
                b.u32(*step);
                b.ids(members);
                b.ids(active);
                b.u32(grads.len() as u32);
                for g in grads {
                    b.grad(g);
                }
                (TAG_ALL, b.0)
            }
            Msg::ShardGrad { step, shard, grad } => {
                let mut b = Buf(Vec::with_capacity(28 + grad.bytes.len()));
                b.u32(*step);
                b.u32(*shard);
                b.grad(grad);
                (TAG_SHARD, b.0)
            }
            Msg::AllShardGrads {
                step,
                shard,
                members,
                active,
                grads,
            } => {
                let mut b = Buf(Vec::new());
                b.u32(*step);
                b.u32(*shard);
                b.ids(members);
                b.ids(active);
                b.u32(grads.len() as u32);
                for g in grads {
                    b.grad(g);
                }
                (TAG_ALL_SHARD, b.0)
            }
            Msg::LeaderGrad { step, group, grad } => {
                let mut b = Buf(Vec::with_capacity(28 + grad.bytes.len()));
                b.u32(*step);
                b.u32(*group);
                b.grad(grad);
                (TAG_LEADER, b.0)
            }
            Msg::AllLeaderGrads {
                step,
                groups,
                members,
                active,
                grads,
            } => {
                let mut b = Buf(Vec::new());
                b.u32(*step);
                b.ids(groups);
                b.ids(members);
                b.ids(active);
                b.u32(grads.len() as u32);
                for g in grads {
                    b.grad(g);
                }
                (TAG_ALL_LEADER, b.0)
            }
            Msg::Skip { step, worker } => {
                let mut b = Buf(Vec::with_capacity(8));
                b.u32(*step);
                b.u32(*worker);
                (TAG_SKIP, b.0)
            }
            Msg::Done => (TAG_DONE, Vec::new()),
        };
        w.write_all(&[tag])?;
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Msg> {
        let mut hdr = [0u8; 5];
        r.read_exact(&mut hdr)?;
        let tag = hdr[0];
        let len = u32::from_le_bytes(hdr[1..5].try_into()?) as usize;
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        let mut c = Cur { b: &payload, i: 0 };
        let msg = match tag {
            TAG_HELLO => Msg::Hello {
                worker: c.u32()?,
                world: c.u32()?,
                join: c.u32()?,
            },
            TAG_GRAD => Msg::Grad {
                step: c.u32()?,
                grad: c.grad()?,
            },
            TAG_ALL => {
                let step = c.u32()?;
                let members = c.ids()?;
                let active = c.ids()?;
                let n = c.u32()? as usize;
                let mut grads = Vec::with_capacity(n);
                for _ in 0..n {
                    grads.push(c.grad()?);
                }
                Msg::AllGrads {
                    step,
                    members,
                    active,
                    grads,
                }
            }
            TAG_SHARD => Msg::ShardGrad {
                step: c.u32()?,
                shard: c.u32()?,
                grad: c.grad()?,
            },
            TAG_ALL_SHARD => {
                let step = c.u32()?;
                let shard = c.u32()?;
                let members = c.ids()?;
                let active = c.ids()?;
                let n = c.u32()? as usize;
                let mut grads = Vec::with_capacity(n);
                for _ in 0..n {
                    grads.push(c.grad()?);
                }
                Msg::AllShardGrads {
                    step,
                    shard,
                    members,
                    active,
                    grads,
                }
            }
            TAG_LEADER => Msg::LeaderGrad {
                step: c.u32()?,
                group: c.u32()?,
                grad: c.grad()?,
            },
            TAG_ALL_LEADER => {
                let step = c.u32()?;
                let groups = c.ids()?;
                let members = c.ids()?;
                let active = c.ids()?;
                let n = c.u32()? as usize;
                let mut grads = Vec::with_capacity(n);
                for _ in 0..n {
                    grads.push(c.grad()?);
                }
                Msg::AllLeaderGrads {
                    step,
                    groups,
                    members,
                    active,
                    grads,
                }
            }
            TAG_SKIP => Msg::Skip {
                step: c.u32()?,
                worker: c.u32()?,
            },
            TAG_DONE => Msg::Done,
            t => bail!("unknown frame tag {t}"),
        };
        if c.i != payload.len() {
            bail!("frame has {} trailing bytes", payload.len() - c.i);
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Msg) {
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let got = Msg::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(got, m);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Msg::Hello {
            worker: 3,
            world: 8,
            join: 0,
        });
        roundtrip(Msg::Hello {
            worker: 5,
            world: 8,
            join: 12,
        });
        roundtrip(Msg::Done);
        let g = WireGrad {
            bits: 12345,
            n_full: 128,
            n_tail: 5,
            bucket: 64,
            width: 3,
            bytes: vec![1, 2, 3, 255, 0],
        };
        roundtrip(Msg::Grad { step: 7, grad: g.clone() });
        roundtrip(Msg::AllGrads {
            step: 9,
            members: vec![0, 2],
            active: vec![0, 2, 3],
            grads: vec![g.clone(), g.clone()],
        });
        roundtrip(Msg::ShardGrad {
            step: 3,
            shard: 2,
            grad: g.clone(),
        });
        roundtrip(Msg::AllShardGrads {
            step: 4,
            shard: 1,
            members: vec![0, 1, 3],
            active: vec![0, 1, 3],
            grads: vec![g.clone(), g.clone(), g.clone()],
        });
        roundtrip(Msg::LeaderGrad {
            step: 5,
            group: 1,
            grad: g.clone(),
        });
        roundtrip(Msg::AllLeaderGrads {
            step: 6,
            groups: vec![0, 1],
            members: vec![0, 1, 2, 3],
            active: vec![0, 1, 2, 3],
            grads: vec![g.clone(), g],
        });
        roundtrip(Msg::Skip { step: 11, worker: 2 });
    }

    #[test]
    fn skip_marker_frame_is_thirteen_bytes() {
        // SKIP_MARKER_BITS = 104 charges exactly this frame:
        // [tag u8][len u32][step u32][worker u32].
        let mut buf = Vec::new();
        Msg::Skip { step: 42, worker: 7 }.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), 13);
        assert_eq!(
            buf.len() as u64 * 8,
            crate::exchange::SKIP_MARKER_BITS
        );
    }

    #[test]
    fn leader_broadcast_members_can_be_a_strict_subset_of_active() {
        // Under --lazy, a tree broadcast's `members` (the global
        // senders) may exclude active-but-silent workers.
        let g = WireGrad {
            bits: 16,
            n_full: 2,
            n_tail: 0,
            bucket: 2,
            width: 2,
            bytes: vec![4, 5],
        };
        roundtrip(Msg::AllLeaderGrads {
            step: 8,
            groups: vec![0],
            members: vec![0, 3],
            active: vec![0, 1, 2, 3],
            grads: vec![g],
        });
    }

    #[test]
    fn membership_lists_survive_the_wire_empty_and_nonempty() {
        // A shrunken broadcast (one survivor) and a degenerate empty
        // member list both roundtrip — the partial-aggregation contract
        // is carried entirely by these lists.
        roundtrip(Msg::AllGrads {
            step: 2,
            members: vec![1],
            active: vec![1],
            grads: vec![WireGrad {
                bits: 8,
                n_full: 1,
                n_tail: 0,
                bucket: 1,
                width: 0,
                bytes: vec![0, 0, 128, 63],
            }],
        });
        roundtrip(Msg::AllGrads {
            step: 3,
            members: Vec::new(),
            active: Vec::new(),
            grads: Vec::new(),
        });
    }

    #[test]
    fn multiple_messages_stream() {
        let mut buf = Vec::new();
        Msg::Hello {
            worker: 0,
            world: 2,
            join: 0,
        }
        .write_to(&mut buf)
        .unwrap();
        Msg::Done.write_to(&mut buf).unwrap();
        let mut r = buf.as_slice();
        assert!(matches!(Msg::read_from(&mut r).unwrap(), Msg::Hello { .. }));
        assert!(matches!(Msg::read_from(&mut r).unwrap(), Msg::Done));
    }

    #[test]
    fn rejects_bad_tag() {
        let buf = vec![99u8, 0, 0, 0, 0];
        assert!(Msg::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn encoded_grad_conversion() {
        let e = EncodedGrad {
            bytes: vec![9, 8, 7],
            bits: 21,
            n_full: 10,
            n_tail: 2,
            bucket: 5,
        };
        let w = WireGrad::from_view(e.view(), 4);
        assert_eq!(w.width, 4);
        let back = w.to_encoded();
        assert_eq!(back.bytes, e.bytes);
        assert_eq!(back.bits, e.bits);
        assert_eq!(back.n_full, e.n_full);
        let v = w.view();
        assert_eq!((v.bytes, v.bits, v.n_full, v.n_tail, v.bucket), (&e.bytes[..], 21, 10, 2, 5));
        // The width survives a wire roundtrip on every frame kind.
        let mut buf = Vec::new();
        Msg::Grad { step: 1, grad: w.clone() }.write_to(&mut buf).unwrap();
        match Msg::read_from(&mut buf.as_slice()).unwrap() {
            Msg::Grad { grad, .. } => assert_eq!(grad.width, 4),
            other => panic!("unexpected {other:?}"),
        }
    }
}
