//! The worker: local compute + codec, lockstep replica of the model.
//!
//! The codec path — quantizer, codebook lifecycle, encode/decode buffers,
//! level adaptation — is the same [`CodecSession`] + [`ExchangeLane`]
//! the in-process simulation drives; only the transport differs (the
//! leader relays wire frames instead of the engine looping back lanes).

use super::messages::{Msg, WireGrad};
use crate::exchange::{CodecSession, ExchangeLane};
use crate::model::{EvalResult, TrainTask};
use crate::opt::{LrSchedule, Optimizer, Sgd, Umsgd, UpdateSchedule};
use crate::quant::Method;
use crate::util::{hash_params, Rng};
use anyhow::{bail, Context, Result};
use std::io::BufReader;
use std::net::TcpStream;

#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub addr: String,
    pub worker: usize,
    pub world: usize,
    pub method: Method,
    pub bits: u32,
    pub bucket: usize,
    pub iters: usize,
    pub lr: LrSchedule,
    pub updates: UpdateSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub final_eval: EvalResult,
    /// FNV-1a over the final parameter bytes: replicas must agree.
    pub params_hash: u64,
    pub sent_bits: u64,
    pub final_levels: Option<Vec<f64>>,
    pub level_updates: usize,
}

/// Run one worker to completion against the leader at `cfg.addr`.
pub fn run_worker(cfg: &WorkerConfig, task: &mut dyn TrainTask) -> Result<WorkerReport> {
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("connecting to leader {}", cfg.addr))?;
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    Msg::Hello {
        worker: cfg.worker as u32,
        world: cfg.world as u32,
    }
    .write_to(&mut writer)?;

    let d = task.param_count();
    // All replicas must initialize identically.
    let mut params = task.init_params(cfg.seed ^ 0xA5A5);
    let mut optimizer: Box<dyn Optimizer> = if cfg.momentum > 0.0 {
        Box::new(Umsgd::heavy_ball(cfg.momentum, cfg.weight_decay))
    } else {
        Box::new(Sgd::new(cfg.weight_decay))
    };

    let mut session = CodecSession::new(cfg.method, cfg.bits, cfg.bucket);
    // Uniform initial codebook: identical on every replica by
    // construction (no replica may depend on another's first batch).
    session.init_uniform_book();
    let mut lane = ExchangeLane::new(cfg.bucket);

    // Per-worker quantization randomness (replicas need not share this —
    // only the ciphertext is shared).
    let mut qrng = Rng::new(cfg.seed ^ (cfg.worker as u64).wrapping_mul(0x9E3779B97F4A7C15));

    let mut grad = vec![0.0f32; d];
    let mut agg = vec![0.0f32; d];
    let mut prev_decoded: Vec<Vec<f32>> = Vec::new();
    let mut sent_bits = 0u64;
    let mut level_updates = 0usize;

    for step in 0..cfg.iters {
        task.grad(&params, cfg.worker, step, &mut grad);

        // Adapt from last exchange's decoded gradients (identical on all
        // replicas ⇒ identical levels + codebook).
        if cfg.updates.is_update_step(step) && !prev_decoded.is_empty() {
            // Deterministic subsample seed shared by all replicas.
            let mut rng = Rng::new(cfg.seed ^ step as u64);
            if session.adapt(prev_decoded.iter().map(|g| g.as_slice()), &mut rng) {
                level_updates += 1;
            }
        }

        // Quantize + encode into the lane's reusable buffers (full
        // precision rides as a raw fp32 frame).
        let bits = if session.is_quantized() {
            lane.quantize(&session, &grad, &mut qrng);
            lane.encode(&session)
        } else {
            lane.encode_raw(&grad)
        };
        sent_bits += bits;
        Msg::Grad {
            step: step as u32,
            grad: WireGrad::from_view(lane.encoded()),
        }
        .write_to(&mut writer)?;

        // Receive everyone's gradient; decode; aggregate.
        let grads = match Msg::read_from(&mut reader)? {
            Msg::AllGrads { step: s, grads } => {
                if s as usize != step {
                    bail!("leader sent step {s}, expected {step}");
                }
                grads
            }
            other => bail!("expected AllGrads, got {other:?}"),
        };
        agg.fill(0.0);
        if prev_decoded.len() != grads.len() {
            prev_decoded = vec![vec![0.0f32; d]; grads.len()];
        }
        for (w, wire) in grads.iter().enumerate() {
            let ghat = lane.decode_to_ghat(&session, wire.view());
            for (a, &g) in agg.iter_mut().zip(ghat) {
                *a += g / cfg.world as f32;
            }
            prev_decoded[w].copy_from_slice(ghat);
        }

        optimizer.step(&mut params, &agg, cfg.lr.lr(step));
    }

    match Msg::read_from(&mut reader)? {
        Msg::Done => {}
        other => bail!("expected Done, got {other:?}"),
    }

    Ok(WorkerReport {
        final_eval: task.eval(&params),
        params_hash: hash_params(&params),
        sent_bits,
        final_levels: session.final_levels(),
        level_updates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::run_leader_on;
    use crate::data::Blobs;
    use crate::model::{Mlp, MlpTask};
    use std::net::TcpListener;

    fn spawn_cluster(method: Method, iters: usize, world: usize) -> Vec<WorkerReport> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let leader = std::thread::spawn(move || run_leader_on(listener, world, iters).unwrap());

        let mut handles = Vec::new();
        for w in 0..world {
            let addr = addr.clone();
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world,
                method,
                bits: 3,
                bucket: 128,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::at(vec![3, 20], 50, 20),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 42,
            };
            handles.push(std::thread::spawn(move || {
                // Same dataset seed on every worker: shards differ by
                // worker id inside the task.
                let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, 7);
                let mut task = MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, world, 7);
                run_worker(&cfg, &mut task).unwrap()
            }));
        }
        let reports: Vec<WorkerReport> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        leader.join().unwrap();
        reports
    }

    #[test]
    fn replicas_stay_bit_identical_alq() {
        let reports = spawn_cluster(Method::Alq, 60, 4);
        let h0 = reports[0].params_hash;
        for r in &reports {
            assert_eq!(r.params_hash, h0, "replica divergence!");
        }
        // Levels adapted identically everywhere.
        let l0 = reports[0].final_levels.clone().unwrap();
        for r in &reports {
            assert_eq!(r.final_levels.as_ref().unwrap(), &l0);
        }
        assert!(reports[0].level_updates >= 1);
    }

    #[test]
    fn replicas_stay_bit_identical_supersgd() {
        let reports = spawn_cluster(Method::SuperSgd, 30, 3);
        let h0 = reports[0].params_hash;
        for r in &reports {
            assert_eq!(r.params_hash, h0);
            assert!(r.final_levels.is_none());
        }
    }

    #[test]
    fn distributed_training_learns() {
        let reports = spawn_cluster(Method::QsgdInf, 300, 4);
        assert!(
            reports[0].final_eval.accuracy > 0.65,
            "acc {}",
            reports[0].final_eval.accuracy
        );
        // Quantized workers sent far fewer bits than fp32 would need.
        let d = Mlp::new(vec![8, 32, 4]).param_count() as u64;
        assert!(reports[0].sent_bits < 300 * 32 * d / 4);
    }
}
