//! The worker: local compute + codec, lockstep replica of the model.
//!
//! The codec path — quantizer, codebook lifecycle, encode/decode buffers,
//! level adaptation — is the same [`CodecSession`] + [`ExchangeLane`]
//! the in-process simulation drives; only the transport differs (the
//! leader relays wire frames instead of the engine looping back lanes).
//!
//! Topology modes (`--topology`, mirroring `exchange::topology`):
//!
//! * **flat** — send the whole encoded frame, decode all M peers.
//! * **sharded:S** — encode the quantized gradient as S bucket-aligned
//!   shard frames (which concatenate to exactly the whole-frame bits),
//!   send each to its shard relay lane, decode every peer's shards.
//!   Replicas remain bit-identical to the flat run: same symbols, same
//!   per-coordinate reduction order.
//! * **tree:G** — send the frame up; group leaders decode their
//!   members' frames, re-quantize the group partial aggregate with
//!   their own RNG stream, and send it up; everyone aggregates the G
//!   decoded partials. Replicas are bit-identical to *each other* (they
//!   decode identical leader frames); the re-quantized partials make
//!   the trajectory a distinct per-seed golden from flat.

use super::messages::{Msg, WireGrad, WIDTH_FP32};
use crate::exchange::budget::select_width;
use crate::exchange::topology::{group_of, shard_buckets, TopologySpec};
use crate::exchange::{
    BitsPolicy, CodecSession, ErrorFeedback, ExchangeLane, LazyPolicy, LazyWorker, PipelineMode,
    SKIP_MARKER_BITS,
};
use crate::model::{EvalResult, TrainTask};
use crate::opt::{LrSchedule, Optimizer, Sgd, Umsgd, UpdateSchedule};
use crate::quant::bitio::BitWriter;
use crate::quant::{Codec, EncodedView, Method, QuantizeImpl};
use crate::sim::FaultPlan;
use crate::trace::{Level, Tracer};
use crate::util::json::Json;
use crate::util::{hash_params, Rng};
use anyhow::{bail, Context, Result};
use std::io::BufReader;
use std::net::TcpStream;

#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub addr: String,
    pub worker: usize,
    pub world: usize,
    pub method: Method,
    /// Bit-budget policy (must be the same on every replica; each frame
    /// self-describes its width on the wire, so replicas only need to
    /// *hold* every reachable width, not agree per step).
    pub bits: BitsPolicy,
    pub bucket: usize,
    pub iters: usize,
    pub lr: LrSchedule,
    pub updates: UpdateSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Exchange topology (must match the leader's relay mode).
    pub topology: TopologySpec,
    /// Entropy coder (must match every replica).
    pub codec: Codec,
    /// Lane quantization implementation. Replicas may differ here freely:
    /// scalar and fast are bit-identical, and only the encoded frames
    /// cross the wire.
    pub quantize_impl: QuantizeImpl,
    /// Pipeline schedule for the send path. `Overlap` double-buffers the
    /// sharded sender: a dedicated thread drains finished frames onto the
    /// wire in FIFO order while the main thread encodes the next shard,
    /// so encode(k+1) overlaps the write of frame k. Frames, their order,
    /// and every decoded bit stay identical to `Off` — only wall clock
    /// moves. Replicas may disagree on this knob freely. `Stale` is a
    /// simulation-only schedule and is rejected by the CLI for workers.
    pub pipeline: PipelineMode,
    /// Deterministic fault plan (the same `--faults` spec every process
    /// in the run gets). Each worker applies only its own entries:
    /// `kill:W@S` exits cleanly at the top of step S, `join:W@S` stays
    /// a silent standby replica until step S, `delay:W@S:MS` sleeps
    /// before sending at step S.
    pub faults: FaultPlan,
    /// Error-feedback residual memory (`--error-feedback`): the
    /// residual changes this worker's outgoing frames, so every replica
    /// must run the same setting (like `--bits-policy`).
    pub error_feedback: bool,
    /// Lazy-aggregation skip policy (`--lazy`; must match the fleet —
    /// receivers renormalize over the broadcast's senders).
    pub lazy: LazyPolicy,
}

/// Per-step worker-side projection for the fault-parity tests: the
/// broadcast active set, the step's wire width, and the post-update
/// replica fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerStepRecord {
    pub step: u32,
    /// Bit w set ⇔ worker w was in the broadcast `active` list.
    pub active_mask: u64,
    /// Bit w set ⇔ worker w shipped a frame this step (== `active_mask`
    /// unless `--lazy` skipped it); part of the sim ≡ TCP projection.
    pub sent_mask: u64,
    /// Wire width this step (32 for full precision, matching the sim's
    /// `StepStats::width` convention).
    pub width: u32,
    /// FNV-1a over the parameter bits after this step's update.
    pub params_hash: u64,
}

#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub final_eval: EvalResult,
    /// FNV-1a over the final parameter bytes: replicas must agree.
    pub params_hash: u64,
    pub sent_bits: u64,
    pub final_levels: Option<Vec<f64>>,
    pub level_updates: usize,
    /// One record per completed step (a killed worker stops early).
    pub step_records: Vec<WorkerStepRecord>,
}

/// Run one worker to completion against the leader at `cfg.addr`.
pub fn run_worker(cfg: &WorkerConfig, task: &mut dyn TrainTask) -> Result<WorkerReport> {
    run_worker_traced(cfg, task, &Tracer::disabled())
}

/// [`run_worker`] with structured telemetry: run lifecycle, connect,
/// per-step width decisions, per-frame wire events (`--trace`).
pub fn run_worker_traced(
    cfg: &WorkerConfig,
    task: &mut dyn TrainTask,
    tracer: &Tracer,
) -> Result<WorkerReport> {
    tracer.event(Level::Info, "run_start", |o| {
        o.insert("runtime", Json::Str("worker".into()));
        o.insert("worker", Json::Num(cfg.worker as f64));
        o.insert("world", Json::Num(cfg.world as f64));
        o.insert("method", Json::Str(cfg.method.name().into()));
        o.insert("topology", Json::Str(cfg.topology.name()));
        o.insert("policy", Json::Str(cfg.bits.name()));
        o.insert("codec", Json::Str(cfg.codec.name().into()));
        o.insert("pipeline", Json::Str(cfg.pipeline.name().into()));
        o.insert("error_feedback", Json::Bool(cfg.error_feedback));
        o.insert("lazy", Json::Str(cfg.lazy.name()));
        o.insert("seed", Json::Num(cfg.seed as f64));
    });
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("connecting to leader {}", cfg.addr))?;
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let my_join = cfg.faults.join_step(cfg.worker).unwrap_or(0);
    Msg::Hello {
        worker: cfg.worker as u32,
        world: cfg.world as u32,
        join: my_join as u32,
    }
    .write_to(&mut writer)?;
    tracer.event(Level::Info, "connect", |o| {
        o.insert("worker", Json::Num(cfg.worker as f64));
        o.insert("world", Json::Num(cfg.world as f64));
    });

    let d = task.param_count();
    // All replicas must initialize identically.
    let mut params = task.init_params(cfg.seed ^ 0xA5A5);
    let mut optimizer: Box<dyn Optimizer> = if cfg.momentum > 0.0 {
        Box::new(Umsgd::heavy_ball(cfg.momentum, cfg.weight_decay))
    } else {
        Box::new(Sgd::new(cfg.weight_decay))
    };

    let mut session = CodecSession::with_policy(cfg.method, &cfg.bits, cfg.bucket)
        .with_codec(cfg.codec)
        .with_quantize_impl(cfg.quantize_impl);
    // Uniform initial codebooks (one per reachable width): identical on
    // every replica by construction (no replica may depend on another's
    // first batch).
    session.init_uniform_book();
    // Per-worker bit controller. Replicas need not pick the same width:
    // every frame carries the width it was encoded at, so receivers
    // always decode with the sender's bank slot.
    let mut bitctl = cfg.bits.controller();
    let mut lane = ExchangeLane::new(cfg.bucket);
    let mut shard_writer = BitWriter::new();

    // Per-worker quantization randomness (replicas need not share this —
    // only the ciphertext is shared).
    let mut qrng = Rng::new(cfg.seed ^ (cfg.worker as u64).wrapping_mul(0x9E3779B97F4A7C15));

    // Error-feedback residual (slot 0 — this process is exactly one
    // worker) and this worker's private lazy skip-rule state.
    let mut feedback = if cfg.error_feedback {
        Some(ErrorFeedback::new(1))
    } else {
        None
    };
    let mut lazy_worker = LazyWorker::default();
    let mut ghat_scratch: Vec<f32> = Vec::new();

    let mut grad = vec![0.0f32; d];
    let mut agg = vec![0.0f32; d];
    let mut partial = vec![0.0f32; d];
    let mut prev_decoded: Vec<Vec<f32>> = Vec::new();
    let mut sent_bits = 0u64;
    let mut level_updates = 0usize;
    let mut step_records: Vec<WorkerStepRecord> = Vec::with_capacity(cfg.iters);
    // Local view of the active set, diffed against every broadcast to
    // surface churn in this worker's trace. Founding members are
    // everyone without a scheduled join.
    let mut known_active: Vec<u32> = (0..cfg.world as u32)
        .filter(|&w| cfg.faults.join_step(w as usize).unwrap_or(0) == 0)
        .collect();

    for step in 0..cfg.iters {
        // kill:W@S — exit cleanly at the top of step S, before sending
        // anything; the leader sees EOF at its barrier and drops us.
        if cfg.faults.kill_step(cfg.worker) == Some(step) {
            crate::trace::warn(
                "worker",
                &format!("worker {} killed by fault plan at step {step}", cfg.worker),
            );
            tracer.event(Level::Info, "run_end", |o| {
                o.insert("steps", Json::Num(step as f64));
                o.insert("total_bits", Json::Num(sent_bits as f64));
            });
            return Ok(WorkerReport {
                final_eval: task.eval(&params),
                params_hash: hash_params(&params),
                sent_bits,
                final_levels: session.final_levels(),
                level_updates,
                step_records,
            });
        }
        // join:W@S — a standby replica computes, adapts, and decodes
        // every broadcast (staying bit-identical to the survivors) but
        // sends nothing until its join step.
        let sending = step >= my_join;
        task.grad(&params, cfg.worker, step, &mut grad);

        // Adapt from last exchange's decoded gradients — M frames under
        // flat/sharded, G partials under tree, identical on all replicas
        // either way ⇒ identical levels + codebook.
        if cfg.updates.is_update_step(step) && !prev_decoded.is_empty() {
            // Deterministic subsample seed shared by all replicas.
            let mut rng = Rng::new(cfg.seed ^ step as u64);
            let updated = session.adapt(prev_decoded.iter().map(|g| g.as_slice()), &mut rng);
            if updated {
                level_updates += 1;
                bitctl.observe_width_profile(session.width_profile());
            }
            tracer.event(Level::Info, "adapt", |o| {
                o.insert("step", Json::Num(step as f64));
                o.insert("updated", Json::Bool(updated));
                o.insert("width", Json::Num(f64::from(wire_width(&session))));
            });
        }

        // Per-step width selection (a no-op for fixed:B): the shared
        // controller protocol, observing this worker's own gradient.
        if session.is_quantized() {
            select_width(bitctl.as_mut(), &mut session, step, &grad, tracer);
        }

        // delay:W@S:MS — a real straggler: sleep before sending so the
        // leader's per-frame deadline machinery gets exercised.
        if sending {
            if let Some(ms) = cfg.faults.delay_ms(cfg.worker, step) {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }

        // Error-feedback + lazy planning, mirroring the sim's serial
        // planning path: correct with the residual, gate the *corrected*
        // message, absorb a skipped message back into the residual. A
        // skipped step consumes no quantization randomness, so the skip
        // decisions are bit-reproducible against the sim.
        if sending {
            if let Some(fb) = feedback.as_mut() {
                fb.correct(0, &grad);
            }
        }
        let send_frame = sending && {
            let msg: &[f32] = match feedback.as_ref() {
                Some(fb) => fb.corrected(0),
                None => &grad,
            };
            lazy_worker.decide(&cfg.lazy, msg)
        };
        if sending && !send_frame {
            if let Some(fb) = feedback.as_mut() {
                fb.absorb(0);
            }
        }
        if sending {
            if let Some(fb) = feedback.as_ref() {
                let norm = fb.residual_norm(0);
                tracer.event(Level::Debug, "feedback_norm", |o| {
                    o.insert("step", Json::Num(step as f64));
                    o.insert("worker", Json::Num(cfg.worker as f64));
                    o.insert("norm", Json::Num(norm));
                });
            }
        }

        let step_sent_before = sent_bits;

        let (sent_members, active) = match cfg.topology {
            TopologySpec::Flat => exchange_flat(
                cfg,
                step,
                sending,
                send_frame,
                &grad,
                &session,
                &mut lane,
                &mut qrng,
                &mut writer,
                &mut reader,
                &mut agg,
                &mut prev_decoded,
                &mut sent_bits,
                feedback.as_mut(),
                &mut ghat_scratch,
                tracer,
            )?,
            TopologySpec::Sharded(shards) => exchange_sharded(
                cfg,
                step,
                shards,
                sending,
                send_frame,
                &grad,
                &session,
                &mut lane,
                &mut shard_writer,
                &mut qrng,
                &mut writer,
                &mut reader,
                &mut agg,
                &mut prev_decoded,
                &mut sent_bits,
                feedback.as_mut(),
                &mut ghat_scratch,
                tracer,
            )?,
            TopologySpec::Tree(groups) => exchange_tree(
                cfg,
                step,
                groups,
                sending,
                send_frame,
                &grad,
                &session,
                &mut lane,
                &mut partial,
                &mut qrng,
                &mut writer,
                &mut reader,
                &mut agg,
                &mut prev_decoded,
                &mut sent_bits,
                feedback.as_mut(),
                &mut ghat_scratch,
                tracer,
            )?,
            TopologySpec::Ring => {
                bail!("ring is a simulation schedule; TCP workers support flat|sharded:S|tree:G")
            }
        };

        // Surface churn in this replica's own trace by diffing the
        // broadcast active set against our last view of it.
        for &w in &known_active {
            if !active.contains(&w) {
                crate::trace::warn(
                    "worker",
                    &format!("worker {w} left the active set at step {step}"),
                );
                tracer.event(Level::Info, "member_drop", |o| {
                    o.insert("step", Json::Num(step as f64));
                    o.insert("worker", Json::Num(f64::from(w)));
                    o.insert("active", Json::Num(active.len() as f64));
                    o.insert("weight_sum", Json::Num(1.0));
                });
            }
        }
        for &w in &active {
            if !known_active.contains(&w) {
                tracer.event(Level::Info, "member_join", |o| {
                    o.insert("step", Json::Num(step as f64));
                    o.insert("worker", Json::Num(f64::from(w)));
                    o.insert("active", Json::Num(active.len() as f64));
                    o.insert("weight_sum", Json::Num(1.0));
                });
            }
        }
        known_active.clone_from(&active);

        tracer.event(Level::Info, "step", |o| {
            o.insert("step", Json::Num(step as f64));
            o.insert("bits", Json::Num((sent_bits - step_sent_before) as f64));
            o.insert("width", Json::Num(f64::from(wire_width(&session))));
        });

        optimizer.step(&mut params, &agg, cfg.lr.lr(step));
        step_records.push(WorkerStepRecord {
            step: step as u32,
            active_mask: active.iter().fold(0u64, |m, &w| m | (1u64 << w)),
            sent_mask: sent_members.iter().fold(0u64, |m, &w| m | (1u64 << w)),
            width: {
                let w = wire_width(&session);
                if w == WIDTH_FP32 {
                    32
                } else {
                    w
                }
            },
            params_hash: hash_params(&params),
        });
    }

    match Msg::read_from(&mut reader)? {
        Msg::Done => {}
        other => bail!("expected Done, got {other:?}"),
    }

    tracer.event(Level::Info, "run_end", |o| {
        o.insert("steps", Json::Num(cfg.iters as f64));
        o.insert("total_bits", Json::Num(sent_bits as f64));
    });

    Ok(WorkerReport {
        final_eval: task.eval(&params),
        params_hash: hash_params(&params),
        sent_bits,
        final_levels: session.final_levels(),
        level_updates,
        step_records,
    })
}

/// The width stamp for frames this session currently encodes
/// ([`WIDTH_FP32`] when nothing quantizes).
fn wire_width(s: &CodecSession) -> u32 {
    s.active_bits().unwrap_or(WIDTH_FP32)
}

/// `frame_send` wire event: one outgoing payload frame.
fn trace_send(tracer: &Tracer, step: usize, kind: &str, bytes: usize, width: u32) {
    tracer.event(Level::Debug, "frame_send", |o| {
        o.insert("step", Json::Num(step as f64));
        o.insert("kind", Json::Str(kind.to_string()));
        o.insert("bytes", Json::Num(bytes as f64));
        o.insert("width", Json::Num(f64::from(width)));
    });
}

/// `frame_recv` wire event: one relay broadcast (frame count + total
/// payload bytes).
fn trace_recv(tracer: &Tracer, step: usize, kind: &str, grads: &[WireGrad]) {
    tracer.event(Level::Debug, "frame_recv", |o| {
        o.insert("step", Json::Num(step as f64));
        o.insert("kind", Json::Str(kind.to_string()));
        o.insert("frames", Json::Num(grads.len() as f64));
        let bytes: usize = grads.iter().map(|g| g.bytes.len()).sum();
        o.insert("bytes", Json::Num(bytes as f64));
    });
}

/// Decode one received wire frame with the bank slot the frame names
/// (peers under a dynamic `--bits-policy` may encode at a different
/// width than ours this step). Fails cleanly when the frame names a
/// width our policy never declared — a job misconfiguration, not a
/// codec bug.
fn decode_wire<'a>(
    lane: &'a mut ExchangeLane,
    s: &CodecSession,
    wire: &WireGrad,
) -> Result<&'a [f32]> {
    if s.is_quantized() {
        if !s.has_width(wire.width) {
            bail!(
                "peer frame encoded at width {} which this worker's --bits-policy never \
                 declares (all replicas must run the same policy)",
                wire.width
            );
        }
        Ok(lane.decode_to_ghat_at(s, wire.width, wire.view()))
    } else {
        Ok(lane.decode_to_ghat(s, wire.view()))
    }
}

/// After a sent frame, update the error-feedback residual with what the
/// wire failed to carry: `corrected − ĝ` for quantized sessions (ĝ is
/// decoded from our own lane's symbols — the entropy coder is lossless
/// over them, so this equals what every peer decodes), exactly zero for
/// fp32 frames.
fn settle_feedback(
    feedback: Option<&mut ErrorFeedback>,
    session: &CodecSession,
    lane: &ExchangeLane,
    ghat_scratch: &mut Vec<f32>,
    d: usize,
) {
    let Some(fb) = feedback else { return };
    if session.is_quantized() {
        ghat_scratch.resize(d, 0.0);
        session
            .quantizer()
            .expect("quantized session has an active quantizer")
            .dequantize(lane.quantized(), ghat_scratch);
        fb.settle(0, ghat_scratch);
    } else {
        fb.clear_residual(0);
    }
}

/// Write the lazy skip marker for this step: 13 wire bytes, charged as
/// [`SKIP_MARKER_BITS`]. The residual (if any) was already absorbed on
/// the planning path.
fn send_skip(
    cfg: &WorkerConfig,
    step: usize,
    writer: &mut TcpStream,
    sent_bits: &mut u64,
    tracer: &Tracer,
) -> Result<()> {
    *sent_bits += SKIP_MARKER_BITS;
    trace_send(tracer, step, "skip", 8, WIDTH_FP32);
    Msg::Skip {
        step: step as u32,
        worker: cfg.worker as u32,
    }
    .write_to(writer)
}

/// Flat all-to-all over the relay: one frame up (when active and not
/// lazily skipped), one frame per surviving sender down. Returns the
/// broadcast senders and active set.
#[allow(clippy::too_many_arguments)]
fn exchange_flat(
    cfg: &WorkerConfig,
    step: usize,
    sending: bool,
    send_frame: bool,
    grad: &[f32],
    session: &CodecSession,
    lane: &mut ExchangeLane,
    qrng: &mut Rng,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    agg: &mut [f32],
    prev_decoded: &mut Vec<Vec<f32>>,
    sent_bits: &mut u64,
    mut feedback: Option<&mut ErrorFeedback>,
    ghat_scratch: &mut Vec<f32>,
    tracer: &Tracer,
) -> Result<(Vec<u32>, Vec<u32>)> {
    let d = grad.len();
    if sending && !send_frame {
        send_skip(cfg, step, writer, sent_bits, tracer)?;
    } else if sending {
        let msg: &[f32] = match feedback.as_deref() {
            Some(fb) => fb.corrected(0),
            None => grad,
        };
        let bits = if session.is_quantized() {
            lane.quantize(session, msg, qrng);
            lane.encode(session)
        } else {
            lane.encode_raw(msg)
        };
        *sent_bits += bits;
        trace_send(tracer, step, "grad", lane.encoded().bytes.len(), wire_width(session));
        Msg::Grad {
            step: step as u32,
            grad: WireGrad::from_view(lane.encoded(), wire_width(session)),
        }
        .write_to(writer)?;
        settle_feedback(feedback.as_deref_mut(), session, lane, ghat_scratch, d);
    }

    let (members, active, grads) = match Msg::read_from(reader)? {
        Msg::AllGrads {
            step: s,
            members,
            active,
            grads,
        } => {
            if s as usize != step {
                bail!("leader sent step {s}, expected {step}");
            }
            (members, active, grads)
        }
        other => bail!("expected AllGrads, got {other:?}"),
    };
    trace_recv(tracer, step, "all_grads", &grads);
    if grads.len() != members.len() {
        bail!("broadcast has {} frames for {} members", grads.len(), members.len());
    }
    // Weighted partial aggregation: each *sender* contributes
    // 1/members.len() — the senders-renormalized rule the in-process
    // sim applies, and identical to the old active-set weighting
    // whenever --lazy is off (post-barrier, members == active).
    let n = members.len().max(1);
    agg.fill(0.0);
    if prev_decoded.len() != grads.len() {
        *prev_decoded = vec![vec![0.0f32; d]; grads.len()];
    }
    for (i, wire) in grads.iter().enumerate() {
        let ghat = decode_wire(lane, session, wire)?;
        for (a, &g) in agg.iter_mut().zip(ghat) {
            *a += g / n as f32;
        }
        prev_decoded[i].copy_from_slice(ghat);
    }
    Ok((members, active))
}

/// Encode one bucket-aligned shard of the already-quantized lane into
/// an owned wire frame. Shared by the serial and overlapped sharded
/// senders so the two paths cannot drift: same symbols, same bits, same
/// frame metadata.
fn encode_shard_frame(
    shard: usize,
    shards: usize,
    nb: usize,
    session: &CodecSession,
    lane: &mut ExchangeLane,
    shard_writer: &mut BitWriter,
) -> (u64, WireGrad) {
    let bucket = session.bucket();
    let buckets = shard_buckets(nb, shards, shard);
    let include_tail = shard + 1 == shards;
    shard_writer.clear();
    let bits = lane.encode_shard_into(session, buckets.clone(), include_tail, shard_writer);
    shard_writer.finish_ref();
    let view = EncodedView {
        bytes: shard_writer.bytes(),
        bits,
        n_full: buckets.len() * bucket,
        n_tail: if include_tail { lane.tail_len() } else { 0 },
        bucket,
    };
    (bits, WireGrad::from_view(view, wire_width(session)))
}

/// Sharded leader lanes over the relay: S shard frames up (when
/// active), survivors' shard frames down, reassembled per peer.
/// Bit-identical to the flat mode. Returns the broadcast active set.
///
/// Under `--pipeline overlap` the quantized send loop double-buffers:
/// a scoped sender thread owns the TCP writer and drains an in-order
/// channel of finished frames while the main thread encodes the next
/// shard. The FIFO channel preserves the exact serial frame order, so
/// the leader relay and every receiver see byte-identical traffic.
#[allow(clippy::too_many_arguments)]
fn exchange_sharded(
    cfg: &WorkerConfig,
    step: usize,
    shards: usize,
    sending: bool,
    send_frame: bool,
    grad: &[f32],
    session: &CodecSession,
    lane: &mut ExchangeLane,
    shard_writer: &mut BitWriter,
    qrng: &mut Rng,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    agg: &mut [f32],
    prev_decoded: &mut Vec<Vec<f32>>,
    sent_bits: &mut u64,
    mut feedback: Option<&mut ErrorFeedback>,
    ghat_scratch: &mut Vec<f32>,
    tracer: &Tracer,
) -> Result<(Vec<u32>, Vec<u32>)> {
    let d = grad.len();
    let quantized = session.is_quantized();
    let bucket = session.bucket();
    let nb = if quantized { d / bucket } else { 0 };

    // Send our S shard frames (bucket-aligned for quantized payloads,
    // coordinate-even fp32 slices otherwise). A lazy skipper ships ONE
    // marker in place of its whole shard set.
    if sending && !send_frame {
        send_skip(cfg, step, writer, sent_bits, tracer)?;
    } else if sending && quantized {
        let msg: &[f32] = match feedback.as_deref() {
            Some(fb) => fb.corrected(0),
            None => grad,
        };
        lane.quantize(session, msg, qrng);
        if cfg.pipeline == PipelineMode::Overlap && shards > 1 {
            // Double-buffered send: the sender thread writes frame k to
            // the wire while we encode shard k+1. Joining before any
            // receive keeps the step lockstep with the serial path.
            let writer = &mut *writer;
            std::thread::scope(|scope| -> Result<()> {
                let (tx, rx) = std::sync::mpsc::channel::<Msg>();
                let sender = scope.spawn(move || -> Result<()> {
                    for msg in rx {
                        msg.write_to(writer)?;
                    }
                    Ok(())
                });
                for shard in 0..shards {
                    let (bits, frame) =
                        encode_shard_frame(shard, shards, nb, session, lane, shard_writer);
                    *sent_bits += bits;
                    trace_send(tracer, step, "shard", frame.bytes.len(), frame.width);
                    let msg = Msg::ShardGrad {
                        step: step as u32,
                        shard: shard as u32,
                        grad: frame,
                    };
                    if tx.send(msg).is_err() {
                        // Sender died mid-step; its join reports the
                        // underlying io error below.
                        break;
                    }
                }
                drop(tx);
                match sender.join() {
                    Ok(res) => res,
                    Err(_) => bail!("overlap sender thread panicked"),
                }
            })?;
        } else {
            for shard in 0..shards {
                let (bits, frame) =
                    encode_shard_frame(shard, shards, nb, session, lane, shard_writer);
                *sent_bits += bits;
                trace_send(tracer, step, "shard", frame.bytes.len(), frame.width);
                Msg::ShardGrad {
                    step: step as u32,
                    shard: shard as u32,
                    grad: frame,
                }
                .write_to(writer)?;
            }
        }
    } else if sending {
        let msg: &[f32] = match feedback.as_deref() {
            Some(fb) => fb.corrected(0),
            None => grad,
        };
        for shard in 0..shards {
            let lo = shard * d / shards;
            let hi = (shard + 1) * d / shards;
            let bits = lane.encode_raw(&msg[lo..hi]);
            *sent_bits += bits;
            trace_send(tracer, step, "shard", lane.encoded().bytes.len(), WIDTH_FP32);
            Msg::ShardGrad {
                step: step as u32,
                shard: shard as u32,
                grad: WireGrad::from_view(lane.encoded(), WIDTH_FP32),
            }
            .write_to(writer)?;
        }
    }
    if sending && send_frame {
        // The shard frames encode the lane's one quantization pass, so
        // the residual settles from the same symbols every peer decodes.
        settle_feedback(feedback.as_deref_mut(), session, lane, ghat_scratch, d);
    }

    // Receive each shard's relay broadcast and reassemble per peer.
    agg.fill(0.0);
    let mut members_out: Vec<u32> = Vec::new();
    let mut active_out: Vec<u32> = Vec::new();
    for shard in 0..shards {
        let (coord_lo, coord_hi) = if quantized {
            let buckets = shard_buckets(nb, shards, shard);
            let lo = buckets.start * bucket;
            let hi = if shard + 1 == shards {
                d
            } else {
                buckets.end * bucket
            };
            (lo, hi)
        } else {
            (shard * d / shards, (shard + 1) * d / shards)
        };
        let (members, active, grads) = match Msg::read_from(reader)? {
            Msg::AllShardGrads {
                step: s,
                shard: sh,
                members,
                active,
                grads,
            } => {
                if s as usize != step || sh as usize != shard {
                    bail!("leader sent step {s} shard {sh}, expected {step}/{shard}");
                }
                (members, active, grads)
            }
            other => bail!("expected AllShardGrads, got {other:?}"),
        };
        trace_recv(tracer, step, "all_shard_grads", &grads);
        if grads.len() != members.len() {
            bail!(
                "shard broadcast has {} frames for {} members",
                grads.len(),
                members.len()
            );
        }
        // The member list is the same for every shard of a step, so
        // resizing at the first shard keeps peer rows consistent; each
        // coordinate range is fully rewritten below.
        if prev_decoded.len() != members.len() {
            *prev_decoded = vec![vec![0.0f32; d]; members.len()];
        }
        // Senders-renormalized weighting (== active-set weighting when
        // --lazy is off; see exchange_flat).
        let n = members.len().max(1);
        for (i, wire) in grads.iter().enumerate() {
            let ghat = decode_wire(lane, session, wire)?;
            for (a, &g) in agg[coord_lo..coord_hi].iter_mut().zip(ghat) {
                *a += g / n as f32;
            }
            prev_decoded[i][coord_lo..coord_hi].copy_from_slice(ghat);
        }
        members_out = members;
        active_out = active;
    }
    Ok((members_out, active_out))
}

/// Two-level tree over the relay: frame up (when active), elected
/// group leaders re-quantize their group's partial, everyone aggregates
/// the surviving groups' partials. Returns the broadcast active set.
///
/// Leadership is *reactive*: the relay elects the first active member
/// of each group every step (so leadership fails over when a leader is
/// killed) and we learn we lead by receiving the group's `AllGrads`
/// before the `AllLeaderGrads` broadcast.
#[allow(clippy::too_many_arguments)]
fn exchange_tree(
    cfg: &WorkerConfig,
    step: usize,
    groups: usize,
    sending: bool,
    send_frame: bool,
    grad: &[f32],
    session: &CodecSession,
    lane: &mut ExchangeLane,
    partial: &mut [f32],
    qrng: &mut Rng,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    agg: &mut [f32],
    prev_decoded: &mut Vec<Vec<f32>>,
    sent_bits: &mut u64,
    mut feedback: Option<&mut ErrorFeedback>,
    ghat_scratch: &mut Vec<f32>,
    tracer: &Tracer,
) -> Result<(Vec<u32>, Vec<u32>)> {
    let d = grad.len();
    let my_group = group_of(cfg.worker, cfg.world, groups);

    // 1. Active members send their frame up (or a skip marker — a
    // skipper is never elected group leader, since the relay elects
    // among the step's senders). The residual settles here, before the
    // leader path below reuses the lane for the partial.
    if sending && !send_frame {
        send_skip(cfg, step, writer, sent_bits, tracer)?;
    } else if sending {
        let msg: &[f32] = match feedback.as_deref() {
            Some(fb) => fb.corrected(0),
            None => grad,
        };
        let bits = if session.is_quantized() {
            lane.quantize(session, msg, qrng);
            lane.encode(session)
        } else {
            lane.encode_raw(msg)
        };
        *sent_bits += bits;
        trace_send(tracer, step, "grad", lane.encoded().bytes.len(), wire_width(session));
        Msg::Grad {
            step: step as u32,
            grad: WireGrad::from_view(lane.encoded(), wire_width(session)),
        }
        .write_to(writer)?;
        settle_feedback(feedback.as_deref_mut(), session, lane, ghat_scratch, d);
    }

    // 2. If the relay elected us group leader this step, it sends our
    // group's frames first: reduce them into the partial mean
    // contribution (Σ ĝ_w / n_active) and send it back up.
    let first = Msg::read_from(reader)?;
    let down = match first {
        Msg::AllGrads {
            step: s,
            members,
            active,
            grads,
        } => {
            if s as usize != step {
                bail!("leader sent step {s}, expected {step}");
            }
            trace_recv(tracer, step, "all_grads", &grads);
            if grads.len() != members.len() {
                bail!(
                    "group broadcast has {} frames for {} members",
                    grads.len(),
                    members.len()
                );
            }
            partial.fill(0.0);
            // `active` on this hop carries the step's *global* senders
            // (the relay's repurposing under --lazy; == the active set
            // when lazy is off), so the partial is already scaled for a
            // plain sum at the bottom of the tree.
            let inv = 1.0 / active.len().max(1) as f32;
            for wire in grads.iter() {
                let ghat = decode_wire(lane, session, wire)?;
                for (p, &g) in partial.iter_mut().zip(ghat) {
                    *p += g * inv;
                }
            }
            let bits = if session.is_quantized() {
                lane.quantize(session, partial, qrng);
                lane.encode(session)
            } else {
                lane.encode_raw(partial)
            };
            *sent_bits += bits;
            trace_send(tracer, step, "leader", lane.encoded().bytes.len(), wire_width(session));
            Msg::LeaderGrad {
                step: step as u32,
                group: my_group as u32,
                grad: WireGrad::from_view(lane.encoded(), wire_width(session)),
            }
            .write_to(writer)?;
            Msg::read_from(reader)?
        }
        other => other,
    };

    // 3. Everyone aggregates the surviving groups' decoded partials.
    let (group_ids, members, active, leads) = match down {
        Msg::AllLeaderGrads {
            step: s,
            groups: group_ids,
            members,
            active,
            grads,
        } => {
            if s as usize != step {
                bail!("leader sent step {s}, expected {step}");
            }
            (group_ids, members, active, grads)
        }
        other => bail!("expected AllLeaderGrads, got {other:?}"),
    };
    trace_recv(tracer, step, "all_leader_grads", &leads);
    if leads.len() != group_ids.len() {
        bail!(
            "leader broadcast has {} frames for {} groups",
            leads.len(),
            group_ids.len()
        );
    }
    agg.fill(0.0);
    if prev_decoded.len() != leads.len() {
        *prev_decoded = vec![vec![0.0f32; d]; leads.len()];
    }
    for (i, wire) in leads.iter().enumerate() {
        let ghat = decode_wire(lane, session, wire)?;
        for (a, &x) in agg.iter_mut().zip(ghat) {
            *a += x;
        }
        prev_decoded[i].copy_from_slice(ghat);
    }
    Ok((members, active))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::run_leader_topo;
    use crate::data::Blobs;
    use crate::model::{Mlp, MlpTask};
    use std::net::TcpListener;

    fn spawn_cluster_policy(
        method: Method,
        iters: usize,
        world: usize,
        topology: TopologySpec,
        codec: Codec,
        bits: BitsPolicy,
    ) -> Vec<WorkerReport> {
        spawn_cluster_pipeline(method, iters, world, topology, codec, bits, PipelineMode::Off)
    }

    #[allow(clippy::too_many_arguments)]
    fn spawn_cluster_pipeline(
        method: Method,
        iters: usize,
        world: usize,
        topology: TopologySpec,
        codec: Codec,
        bits: BitsPolicy,
        pipeline: PipelineMode,
    ) -> Vec<WorkerReport> {
        spawn_cluster_feedback(
            method,
            iters,
            world,
            topology,
            codec,
            bits,
            pipeline,
            false,
            LazyPolicy::Off,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn spawn_cluster_feedback(
        method: Method,
        iters: usize,
        world: usize,
        topology: TopologySpec,
        codec: Codec,
        bits: BitsPolicy,
        pipeline: PipelineMode,
        error_feedback: bool,
        lazy: LazyPolicy,
    ) -> Vec<WorkerReport> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let leader =
            std::thread::spawn(move || run_leader_topo(listener, world, iters, topology).unwrap());

        let mut handles = Vec::new();
        for w in 0..world {
            let addr = addr.clone();
            let bits = bits.clone();
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world,
                method,
                bits,
                bucket: 128,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::at(vec![3, 20], 50, 20),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 42,
                topology,
                codec,
                quantize_impl: QuantizeImpl::default(),
                pipeline,
                faults: FaultPlan::default(),
                error_feedback,
                lazy,
            };
            handles.push(std::thread::spawn(move || {
                // Same dataset seed on every worker: shards differ by
                // worker id inside the task.
                let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, 7);
                let mut task = MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, world, 7);
                run_worker(&cfg, &mut task).unwrap()
            }));
        }
        let reports: Vec<WorkerReport> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        leader.join().unwrap();
        reports
    }

    fn spawn_cluster_topo(
        method: Method,
        iters: usize,
        world: usize,
        topology: TopologySpec,
        codec: Codec,
    ) -> Vec<WorkerReport> {
        spawn_cluster_policy(method, iters, world, topology, codec, BitsPolicy::Fixed(3))
    }

    fn spawn_cluster(method: Method, iters: usize, world: usize) -> Vec<WorkerReport> {
        spawn_cluster_topo(method, iters, world, TopologySpec::Flat, Codec::Huffman)
    }

    #[test]
    fn replicas_stay_bit_identical_alq() {
        let reports = spawn_cluster(Method::Alq, 60, 4);
        let h0 = reports[0].params_hash;
        for r in &reports {
            assert_eq!(r.params_hash, h0, "replica divergence!");
        }
        // Levels adapted identically everywhere.
        let l0 = reports[0].final_levels.clone().unwrap();
        for r in &reports {
            assert_eq!(r.final_levels.as_ref().unwrap(), &l0);
        }
        assert!(reports[0].level_updates >= 1);
    }

    #[test]
    fn replicas_stay_bit_identical_supersgd() {
        let reports = spawn_cluster(Method::SuperSgd, 30, 3);
        let h0 = reports[0].params_hash;
        for r in &reports {
            assert_eq!(r.params_hash, h0);
            assert!(r.final_levels.is_none());
        }
    }

    #[test]
    fn distributed_training_learns() {
        let reports = spawn_cluster(Method::QsgdInf, 300, 4);
        assert!(
            reports[0].final_eval.accuracy > 0.65,
            "acc {}",
            reports[0].final_eval.accuracy
        );
        // Quantized workers sent far fewer bits than fp32 would need.
        let d = Mlp::new(vec![8, 32, 4]).param_count() as u64;
        assert!(reports[0].sent_bits < 300 * 32 * d / 4);
    }

    #[test]
    fn sharded_relay_is_bit_identical_to_flat() {
        let flat = spawn_cluster_topo(Method::Alq, 40, 4, TopologySpec::Flat, Codec::Huffman);
        let sharded =
            spawn_cluster_topo(Method::Alq, 40, 4, TopologySpec::Sharded(2), Codec::Huffman);
        // Replicas agree within each mode…
        for r in &sharded {
            assert_eq!(r.params_hash, sharded[0].params_hash);
        }
        // …and sharded routing reproduces the flat run exactly: same
        // params, same levels, same payload bits (shards concatenate to
        // the whole frame).
        assert_eq!(flat[0].params_hash, sharded[0].params_hash);
        assert_eq!(flat[0].final_levels, sharded[0].final_levels);
        for (f, s) in flat.iter().zip(&sharded) {
            assert_eq!(f.sent_bits, s.sent_bits);
        }
    }

    /// The overlapped sharded sender is a wall-clock change only: the
    /// sender thread drains the same frames in the same order the
    /// serial loop writes, so every replica's trajectory, payload bits,
    /// step records, and adapted levels match `--pipeline off` exactly.
    #[test]
    fn overlap_sharded_sender_is_bit_identical_to_off() {
        let off = spawn_cluster_pipeline(
            Method::Alq,
            40,
            4,
            TopologySpec::Sharded(3),
            Codec::Huffman,
            BitsPolicy::Fixed(3),
            PipelineMode::Off,
        );
        let overlap = spawn_cluster_pipeline(
            Method::Alq,
            40,
            4,
            TopologySpec::Sharded(3),
            Codec::Huffman,
            BitsPolicy::Fixed(3),
            PipelineMode::Overlap,
        );
        for (o, p) in off.iter().zip(&overlap) {
            assert_eq!(o.params_hash, p.params_hash, "overlap diverged from off");
            assert_eq!(o.sent_bits, p.sent_bits);
            assert_eq!(o.final_levels, p.final_levels);
            assert_eq!(o.step_records, p.step_records);
        }
    }

    #[test]
    fn tree_relay_replicas_identical_and_deterministic() {
        let a = spawn_cluster_topo(Method::QsgdInf, 30, 4, TopologySpec::Tree(2), Codec::Huffman);
        for r in &a {
            assert_eq!(r.params_hash, a[0].params_hash, "replica divergence!");
        }
        // Deterministic golden per seed.
        let b = spawn_cluster_topo(Method::QsgdInf, 30, 4, TopologySpec::Tree(2), Codec::Huffman);
        assert_eq!(a[0].params_hash, b[0].params_hash);
        // Leaders sent an extra partial frame on top of their gradient.
        let flat = spawn_cluster_topo(Method::QsgdInf, 30, 4, TopologySpec::Flat, Codec::Huffman);
        assert!(a[0].sent_bits > flat[0].sent_bits, "worker 0 leads group 0");
        assert_ne!(a[0].params_hash, flat[0].params_hash);
    }

    /// Dynamic bit budgets over the relay: every frame self-describes
    /// its width (piggybacked, no extra round-trip), so replicas stay
    /// bit-identical even while the width moves mid-run — under the
    /// deterministic schedule policy and the signal-driven variance
    /// policy, for flat and tree relays alike.
    #[test]
    fn dynamic_bits_policies_keep_replicas_identical_over_the_wire() {
        let schedule = BitsPolicy::parse("schedule:3@0,2@20").unwrap();
        let variance = BitsPolicy::parse("variance:2-4").unwrap();
        for (policy, topology) in [
            (schedule.clone(), TopologySpec::Flat),
            (variance.clone(), TopologySpec::Flat),
            (variance.clone(), TopologySpec::Tree(2)),
        ] {
            let reports = spawn_cluster_policy(
                Method::Alq,
                40,
                4,
                topology,
                Codec::Huffman,
                policy.clone(),
            );
            for r in &reports {
                assert_eq!(
                    r.params_hash, reports[0].params_hash,
                    "replica divergence under {} over {}",
                    policy.name(),
                    topology.name()
                );
                assert!(r.sent_bits > 0);
            }
        }
        // The schedule's narrow tail really sends fewer payload bits
        // than a fixed run at the starting width.
        let fixed = spawn_cluster_policy(
            Method::Alq,
            40,
            4,
            TopologySpec::Flat,
            Codec::Huffman,
            BitsPolicy::Fixed(3),
        );
        let scheduled = spawn_cluster_policy(
            Method::Alq,
            40,
            4,
            TopologySpec::Flat,
            Codec::Huffman,
            schedule,
        );
        assert!(
            scheduled[0].sent_bits < fixed[0].sent_bits,
            "schedule:3@0,2@20 should undercut fixed:3 ({} vs {})",
            scheduled[0].sent_bits,
            fixed[0].sent_bits
        );
    }

    /// Error-feedback over the relay: every replica runs the residual
    /// loop on its own uplink, frames stay self-describing, and the
    /// trajectory remains bit-identical across replicas — over both the
    /// flat relay and the re-quantizing tree.
    #[test]
    fn error_feedback_replicas_stay_bit_identical_over_the_wire() {
        for topology in [TopologySpec::Flat, TopologySpec::Tree(2)] {
            let reports = spawn_cluster_feedback(
                Method::Alq,
                40,
                4,
                topology,
                Codec::Huffman,
                BitsPolicy::Fixed(2),
                PipelineMode::Off,
                true,
                LazyPolicy::Off,
            );
            let h0 = reports[0].params_hash;
            for r in &reports {
                assert_eq!(r.params_hash, h0, "feedback divergence over {topology:?}");
            }
            // Feedback changes the frames, so the trajectory differs
            // from the plain run at the same width.
            let plain = spawn_cluster_feedback(
                Method::Alq,
                40,
                4,
                topology,
                Codec::Huffman,
                BitsPolicy::Fixed(2),
                PipelineMode::Off,
                false,
                LazyPolicy::Off,
            );
            assert_ne!(plain[0].params_hash, h0);
        }
    }

    /// An unreachable threshold makes every worker ship only 13-byte
    /// skip markers: zero frames move, replicas stay identical, and the
    /// step records expose the empty sent-mask next to the full active
    /// mask. A reachable threshold reduces to the plain run.
    #[test]
    fn lazy_threshold_skips_frames_over_the_wire() {
        let skipping = spawn_cluster_feedback(
            Method::QsgdInf,
            6,
            4,
            TopologySpec::Flat,
            Codec::Huffman,
            BitsPolicy::Fixed(3),
            PipelineMode::Off,
            false,
            LazyPolicy::Thresh(1e30),
        );
        for r in &skipping {
            assert_eq!(r.sent_bits, 6 * SKIP_MARKER_BITS, "only markers should move");
            for rec in &r.step_records {
                assert_eq!(rec.sent_mask, 0, "no frames at step {}", rec.step);
                assert_eq!(rec.active_mask, 0b1111, "skippers stay active");
            }
            assert_eq!(r.params_hash, skipping[0].params_hash);
        }
        // A tiny threshold never skips: the sent mask tracks the active
        // mask and the run matches --lazy off bit for bit.
        let always = spawn_cluster_feedback(
            Method::QsgdInf,
            6,
            4,
            TopologySpec::Flat,
            Codec::Huffman,
            BitsPolicy::Fixed(3),
            PipelineMode::Off,
            false,
            LazyPolicy::Thresh(1e-30),
        );
        let off = spawn_cluster_feedback(
            Method::QsgdInf,
            6,
            4,
            TopologySpec::Flat,
            Codec::Huffman,
            BitsPolicy::Fixed(3),
            PipelineMode::Off,
            false,
            LazyPolicy::Off,
        );
        assert_eq!(always[0].params_hash, off[0].params_hash);
        assert_eq!(always[0].sent_bits, off[0].sent_bits);
        for rec in &always[0].step_records {
            assert_eq!(rec.sent_mask, rec.active_mask);
        }
    }

    /// Feedback composes with the LAQ gate over the sharded relay: a
    /// skipped step's whole corrected message survives in the residual,
    /// and replicas agree bit for bit.
    #[test]
    fn feedback_with_laq_gate_stays_identical_over_sharded_relay() {
        let reports = spawn_cluster_feedback(
            Method::Alq,
            30,
            4,
            TopologySpec::Sharded(2),
            Codec::Huffman,
            BitsPolicy::Fixed(3),
            PipelineMode::Off,
            true,
            LazyPolicy::parse("laq:1.0@4").unwrap(),
        );
        for r in &reports {
            assert_eq!(r.params_hash, reports[0].params_hash);
            assert!(r.sent_bits > 0);
        }
        // The LAQ patience bound (K=4) forces each worker to ship a
        // frame at least every fifth step.
        for r in &reports {
            let mut streak = [0u32; 4];
            for rec in &r.step_records {
                for (w, s) in streak.iter_mut().enumerate() {
                    if rec.sent_mask & (1u64 << w) != 0 {
                        *s = 0;
                    } else {
                        *s += 1;
                        assert!(*s <= 4, "worker {w} patience violated at step {}", rec.step);
                    }
                }
            }
        }
    }

    #[test]
    fn elias_codec_runs_over_the_wire() {
        let reports = spawn_cluster_topo(Method::NuqSgd, 30, 3, TopologySpec::Flat, Codec::Elias);
        for r in &reports {
            assert_eq!(r.params_hash, reports[0].params_hash);
            assert!(r.sent_bits > 0);
        }
    }
}
