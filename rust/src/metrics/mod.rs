//! Run metrics: CSV recording and markdown table rendering for the
//! experiment harness (results land in `runs/` and EXPERIMENTS.md).

use std::io::Write;
use std::path::Path;

/// A rectangular table of named columns; renders to CSV or markdown.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.columns.join(","));
        s.push('\n');
        for r in &self.rows {
            let escaped: Vec<String> = r
                .iter()
                .map(|c| {
                    // RFC 4180: quote fields containing separators,
                    // quotes, or line breaks (LF *and* CR — a bare CR
                    // also corrupts the record framing).
                    if c.contains(',') || c.contains('"') || c.contains('\n') || c.contains('\r') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            s.push_str(&escaped.join(","));
            s.push('\n');
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let mut s = String::new();
        if !self.title.is_empty() {
            s.push_str(&format!("### {}\n\n", self.title));
        }
        s.push_str(&fmt_row(&self.columns));
        s.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        s.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for r in &self.rows {
            s.push_str(&fmt_row(r));
            s.push('\n');
        }
        s
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// mean ± std over a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() == 1 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// Format "93.24% ± 0.06" like the paper's tables.
pub fn pct(mean: f64, std: f64) -> String {
    format!("{:.2}% ± {:.2}", 100.0 * mean, 100.0 * std)
}

/// A simple time-series logger: (step, value) pairs per named series.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(usize, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Series {
            name: name.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, step: usize, v: f64) {
        self.points.push((step, v));
    }

    /// Render several series into one long-format CSV
    /// (`series,step,value`).
    pub fn to_csv(series: &[Series]) -> String {
        let mut s = String::from("series,step,value\n");
        for sr in series {
            for (step, v) in &sr.points {
                s.push_str(&format!("{},{},{}\n", sr.name, step, v));
            }
        }
        s
    }

    pub fn save_csv(series: &[Series], path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, Self::to_csv(series))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn csv_escapes_embedded_line_breaks() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["multi\nline".into(), "carriage\rreturn".into()]);
        t.row(vec!["crlf\r\nboth".into(), "plain".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"multi\nline\""));
        assert!(csv.contains("\"carriage\rreturn\""));
        assert!(csv.contains("\"crlf\r\nboth\""));
        assert!(csv.contains(",plain\n"));
        // Quoted line breaks leave exactly header + 2 records once the
        // quoted segments are accounted for: the file still ends in one
        // trailing newline per record.
        assert_eq!(csv.matches("\"multi").count(), 1);
    }

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("Title", &["method", "acc"]);
        t.row(vec!["ALQ".into(), "93.2".into()]);
        t.row(vec!["QSGDinf".into(), "91.5".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("### Title"));
        assert!(md.contains("| method  | acc  |"));
        assert!(md.contains("| QSGDinf | 91.5 |"));
    }

    #[test]
    fn stats() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[5.0]).1, 0.0);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.9324, 0.0006), "93.24% ± 0.06");
    }

    #[test]
    fn series_csv() {
        let mut a = Series::new("loss");
        a.push(0, 2.0);
        a.push(10, 1.5);
        let csv = Series::to_csv(&[a]);
        assert!(csv.contains("loss,10,1.5"));
    }
}
