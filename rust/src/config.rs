//! Experiment configuration: CLI-style `--key value` overrides over
//! Table 3-shaped defaults. Dependency-free (no TOML/serde in the image's
//! vendored crate set); values are validated on parse.

use crate::exchange::{BitsPolicy, LazyPolicy, ParallelMode, PipelineMode, TopologySpec};
use crate::quant::{Codec, Method, QuantizeImpl};
use crate::sim::FaultPlan;
use crate::trace::TraceSpec;
use anyhow::{bail, Context, Result};

/// One training-run configuration (Table 3, scaled).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub method: Method,
    pub workers: usize,
    /// Constant bit width (`--bits B`, shorthand for `fixed:B`).
    /// Superseded by `--bits-policy` when one is given.
    pub bits: u32,
    /// Dynamic bit-budget policy
    /// (`--bits-policy fixed:B|schedule:B1@s1,...|variance[:MIN-MAX[@T]]`).
    pub bits_policy: Option<BitsPolicy>,
    pub bucket: usize,
    pub iters: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    pub seeds: usize,
    /// Model selector: "mlp" (pure-Rust blobs task) or a manifest model
    /// name ("mlp_tiny", "lm_small", …) for the PJRT path.
    pub model: String,
    pub out_dir: String,
    /// Lane scheduling in the exchange backend (auto|on|off) — fans out
    /// flat worker lanes, sharded shard-leader lanes, and tree group
    /// reductions; bit-identical to serial (ring is inherently serial).
    pub parallel: ParallelMode,
    /// Pipeline schedule (off|overlap|stale:1) — overlap hides wire
    /// time behind encode bit-identically; stale:1 overlaps compute
    /// with the previous step's exchange, applying aggregates one step
    /// late.
    pub pipeline: PipelineMode,
    /// Exchange schedule (flat|sharded:S|tree:G|ring).
    pub topology: TopologySpec,
    /// Entropy coder (huffman|elias — the QSGD-style coding ablation).
    pub codec: Codec,
    /// Lane quantization implementation (scalar|fast|pallas — the ISSUE 6
    /// hot-loop ablation; pallas downgrades to fast when unavailable).
    pub quantize_impl: QuantizeImpl,
    /// Structured-telemetry sink (`--trace PATH[:warn|info|debug]`);
    /// `None` keeps tracing compiled out of the hot path entirely.
    pub trace: Option<TraceSpec>,
    /// Deterministic mid-run churn
    /// (`--faults kill:W@S,delay:W@S:MS,join:W@S` or `none`).
    pub faults: FaultPlan,
    /// Error-feedback residual memory (`--error-feedback on|off`).
    /// Incompatible with `--topology ring` (partials are re-quantized
    /// per ring stage, so no per-worker decode error exists).
    pub error_feedback: bool,
    /// Lazy skip-round policy (`--lazy off|thresh:T|laq:C@K`).
    pub lazy: LazyPolicy,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            method: Method::Alq,
            workers: 4,
            bits: 3,
            bits_policy: None,
            bucket: 8192,
            iters: 3000,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 1,
            seeds: 3,
            model: "mlp".to_string(),
            out_dir: "runs".to_string(),
            parallel: ParallelMode::Auto,
            pipeline: PipelineMode::Off,
            topology: TopologySpec::Flat,
            codec: Codec::Huffman,
            quantize_impl: QuantizeImpl::default(),
            trace: None,
            faults: FaultPlan::default(),
            error_feedback: false,
            lazy: LazyPolicy::Off,
        }
    }
}

impl RunConfig {
    /// Parse `--key value` pairs; unknown keys are an error.
    pub fn from_args(args: &[String]) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        let mut it = args.iter();
        while let Some(key) = it.next() {
            let Some(name) = key.strip_prefix("--") else {
                bail!("expected --key, got {key:?}");
            };
            let val = it
                .next()
                .with_context(|| format!("missing value for --{name}"))?;
            match name {
                "method" => {
                    self.method = Method::parse(val)
                        .with_context(|| format!("unknown method {val:?}"))?
                }
                "workers" | "m" => self.workers = val.parse()?,
                "bits" => self.bits = val.parse()?,
                "bits-policy" => {
                    self.bits_policy = Some(BitsPolicy::parse(val).with_context(|| {
                        format!(
                            "bad --bits-policy {val:?} \
                             (fixed:B | schedule:B1@s1,B2@s2,... | variance[:MIN-MAX[@T]])"
                        )
                    })?)
                }
                "bucket" => self.bucket = val.parse()?,
                "iters" => self.iters = val.parse()?,
                "lr" => self.lr = val.parse()?,
                "momentum" => self.momentum = val.parse()?,
                "weight-decay" => self.weight_decay = val.parse()?,
                "seed" => self.seed = val.parse()?,
                "seeds" => self.seeds = val.parse()?,
                "model" => self.model = val.clone(),
                "out" => self.out_dir = val.clone(),
                "parallel" => {
                    self.parallel = ParallelMode::parse(val)
                        .with_context(|| format!("bad --parallel {val:?} (auto|on|off)"))?
                }
                "pipeline" => {
                    self.pipeline = PipelineMode::parse(val)
                        .with_context(|| format!("bad --pipeline {val:?} (off|overlap|stale:1)"))?
                }
                "topology" => {
                    self.topology = TopologySpec::parse(val).with_context(|| {
                        format!("bad --topology {val:?} (flat|sharded:S|tree:G|ring)")
                    })?
                }
                "codec" => {
                    self.codec = Codec::parse(val)
                        .with_context(|| format!("bad --codec {val:?} (huffman|elias)"))?
                }
                "quantize-impl" => {
                    self.quantize_impl = QuantizeImpl::parse(val).with_context(|| {
                        format!("bad --quantize-impl {val:?} (scalar|fast|pallas)")
                    })?
                }
                "trace" => {
                    self.trace = Some(TraceSpec::parse(val).with_context(|| {
                        format!("bad --trace {val:?} (PATH[:warn|info|debug])")
                    })?)
                }
                "faults" => {
                    self.faults = FaultPlan::parse(val).map_err(|e| {
                        anyhow::anyhow!(
                            "bad --faults {val:?}: {e} \
                             (kill:W@S | delay:W@S:MS | join:W@S, comma-separated, or 'none')"
                        )
                    })?
                }
                "error-feedback" => {
                    self.error_feedback = match val.to_ascii_lowercase().as_str() {
                        "on" | "true" | "1" => true,
                        "off" | "false" | "0" => false,
                        _ => bail!("bad --error-feedback {val:?} (on|off)"),
                    }
                }
                "lazy" => {
                    self.lazy = LazyPolicy::parse_strict(val)
                        .map_err(|e| anyhow::anyhow!("bad --lazy: {e}"))?
                }
                other => bail!("unknown option --{other}"),
            }
        }
        self.validate()
    }

    /// The effective bit-budget policy: `--bits-policy` when given,
    /// otherwise `fixed:--bits`.
    pub fn effective_bits_policy(&self) -> BitsPolicy {
        self.bits_policy
            .clone()
            .unwrap_or(BitsPolicy::Fixed(self.bits))
    }

    pub fn validate(&self) -> Result<()> {
        if !(2..=8).contains(&self.bits) {
            bail!("bits must be in [2, 8], got {}", self.bits);
        }
        if self.workers == 0 || self.iters == 0 || self.bucket == 0 {
            bail!("workers, iters, bucket must be positive");
        }
        if let Some(policy) = &self.bits_policy {
            // A dynamic budget over a width-insensitive level family
            // (TRN is ternary at every width) would report fictitious
            // width moves with zero payload effect — reject up front.
            if !policy.is_fixed()
                && self.method.is_quantized()
                && self.method.effective_bits(2) == self.method.effective_bits(8)
            {
                bail!(
                    "--bits-policy {} has no effect for {}: its level family ignores the \
                     bit width (always ternary); use --bits B / fixed:B",
                    policy.name(),
                    self.method
                );
            }
        }
        if let TopologySpec::Tree(g) = self.topology {
            if g > self.workers {
                bail!(
                    "--topology tree:{g} needs at most {} groups (one per worker)",
                    self.workers
                );
            }
        }
        if let Err(e) = self.faults.validate(self.workers) {
            bail!("bad --faults: {e}");
        }
        if self.codec == Codec::Elias {
            if let Some(levels) = self.method.initial_levels(self.bits) {
                if !levels.has_zero() {
                    bail!(
                        "--codec elias needs a zero level to run-length over; \
                         {} uses a no-zero level family (keep --codec huffman)",
                        self.method
                    );
                }
            }
        }
        if self.error_feedback && self.topology == TopologySpec::Ring {
            bail!(
                "--error-feedback is unsupported over --topology ring: ring re-quantizes \
                 partial sums at every stage, so no per-worker decode error exists to feed \
                 back (use flat, sharded:S, or tree:G, or keep --error-feedback off)"
            );
        }
        validate_pipeline_transport(self.pipeline, false).map_err(|e| anyhow::anyhow!(e))?;
        Ok(())
    }

    /// Lower into a cluster config.
    pub fn cluster(&self) -> crate::sim::ClusterConfig {
        use crate::opt::{LrSchedule, UpdateSchedule};
        crate::sim::ClusterConfig {
            method: self.method,
            workers: self.workers,
            bits: self.effective_bits_policy(),
            bucket: self.bucket,
            iters: self.iters,
            lr: LrSchedule::paper_default(self.lr, self.iters),
            updates: UpdateSchedule::paper_default(self.iters),
            momentum: self.momentum,
            weight_decay: self.weight_decay,
            seed: self.seed,
            eval_every: (self.iters / 20).max(1),
            variance_every: 0,
            network: crate::sim::NetworkModel::paper_testbed(),
            parallel: self.parallel,
            pipeline: self.pipeline,
            topology: self.topology,
            codec: self.codec,
            quantize_impl: self.quantize_impl,
            faults: self.faults.clone(),
            error_feedback: self.error_feedback,
            lazy: self.lazy,
        }
    }
}

/// Validate a `--pipeline` mode against the runtime that will execute it
/// — the single parse-time check both the simulation (`tcp = false`) and
/// the TCP worker (`tcp = true`) call, instead of a rejection buried in
/// the worker's runtime setup. `stale:1` is a simulation schedule: the
/// sim's training loop double-buffers the aggregate, which has no wire
/// equivalent in the current worker protocol.
pub fn validate_pipeline_transport(pipeline: PipelineMode, tcp: bool) -> Result<(), String> {
    if tcp && pipeline == PipelineMode::Stale {
        return Err(
            "--pipeline stale:1 is a simulation schedule (aqsgd train); the TCP worker \
             supports off|overlap"
                .to_string(),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn defaults_mirror_table3() {
        let c = RunConfig::default();
        assert_eq!(c.lr, 0.1);
        assert_eq!(c.momentum, 0.9);
        assert_eq!(c.weight_decay, 1e-4);
        assert_eq!(c.bits, 3);
    }

    #[test]
    fn parses_overrides() {
        let c = RunConfig::from_args(&args(
            "--method qsgdinf --workers 16 --bits 4 --bucket 1024 --iters 100",
        ))
        .unwrap();
        assert_eq!(c.method, Method::QsgdInf);
        assert_eq!(c.workers, 16);
        assert_eq!(c.bits, 4);
        assert_eq!(c.bucket, 1024);
    }

    #[test]
    fn rejects_unknown_and_invalid() {
        assert!(RunConfig::from_args(&args("--bogus 1")).is_err());
        assert!(RunConfig::from_args(&args("--bits 9")).is_err());
        assert!(RunConfig::from_args(&args("--method nope")).is_err());
        assert!(RunConfig::from_args(&args("--iters")).is_err());
        assert!(RunConfig::from_args(&args("iters 5")).is_err());
        assert!(RunConfig::from_args(&args("--parallel sideways")).is_err());
    }

    #[test]
    fn parses_topology_and_codec() {
        let c = RunConfig::from_args(&args("--topology sharded:4 --codec elias --method nuqsgd"))
            .unwrap();
        assert_eq!(c.topology, TopologySpec::Sharded(4));
        assert_eq!(c.codec, Codec::Elias);
        assert_eq!(c.cluster().topology, TopologySpec::Sharded(4));
        assert_eq!(c.cluster().codec, Codec::Elias);
        let c = RunConfig::from_args(&args("--topology tree:2")).unwrap();
        assert_eq!(c.topology, TopologySpec::Tree(2));
        let c = RunConfig::from_args(&args("--topology ring")).unwrap();
        assert_eq!(c.topology, TopologySpec::Ring);
        // Rejections: unknown shapes, zero shards, too many tree groups,
        // Elias over a no-zero level family.
        assert!(RunConfig::from_args(&args("--topology mesh")).is_err());
        assert!(RunConfig::from_args(&args("--topology sharded:0")).is_err());
        assert!(RunConfig::from_args(&args("--topology tree:9 --workers 4")).is_err());
        assert!(RunConfig::from_args(&args("--codec elias --method amq")).is_err());
        assert!(RunConfig::from_args(&args("--codec morse")).is_err());
    }

    #[test]
    fn parses_bits_policy() {
        // Default: fixed at --bits.
        let c = RunConfig::from_args(&args("--bits 4")).unwrap();
        assert_eq!(c.effective_bits_policy(), BitsPolicy::Fixed(4));
        assert_eq!(c.cluster().bits, BitsPolicy::Fixed(4));
        // Explicit policies flow through to the cluster config.
        let c = RunConfig::from_args(&args("--bits-policy schedule:4@0,2@100")).unwrap();
        assert_eq!(
            c.cluster().bits,
            BitsPolicy::parse("schedule:4@0,2@100").unwrap()
        );
        let c = RunConfig::from_args(&args("--bits-policy variance:2-4@0.2")).unwrap();
        assert!(matches!(c.cluster().bits, BitsPolicy::Variance(_)));
        // --bits-policy wins over --bits.
        let c = RunConfig::from_args(&args("--bits 8 --bits-policy fixed:2")).unwrap();
        assert_eq!(c.cluster().bits, BitsPolicy::Fixed(2));
        // Malformed policies are CLI errors.
        assert!(RunConfig::from_args(&args("--bits-policy fixed:9")).is_err());
        assert!(RunConfig::from_args(&args("--bits-policy schedule:3@5")).is_err());
        assert!(RunConfig::from_args(&args("--bits-policy variance:4-2")).is_err());
        assert!(RunConfig::from_args(&args("--bits-policy sometimes")).is_err());
        // TRN's levels ignore the width: dynamic budgets are rejected,
        // fixed is fine.
        assert!(RunConfig::from_args(&args("--method trn --bits-policy variance:2-4")).is_err());
        assert!(RunConfig::from_args(&args("--method trn --bits-policy schedule:3@0,2@5")).is_err());
        assert!(RunConfig::from_args(&args("--method trn --bits-policy fixed:3")).is_ok());
    }

    #[test]
    fn parses_quantize_impl() {
        assert_eq!(RunConfig::default().quantize_impl, QuantizeImpl::Fast);
        let c = RunConfig::from_args(&args("--quantize-impl scalar")).unwrap();
        assert_eq!(c.quantize_impl, QuantizeImpl::Scalar);
        assert_eq!(c.cluster().quantize_impl, QuantizeImpl::Scalar);
        let c = RunConfig::from_args(&args("--quantize-impl pallas")).unwrap();
        assert_eq!(c.quantize_impl, QuantizeImpl::Pallas);
        assert!(RunConfig::from_args(&args("--quantize-impl gpu")).is_err());
    }

    #[test]
    fn parses_parallel_mode() {
        assert_eq!(RunConfig::default().parallel, ParallelMode::Auto);
        let c = RunConfig::from_args(&args("--parallel on")).unwrap();
        assert_eq!(c.parallel, ParallelMode::Parallel);
        let c = RunConfig::from_args(&args("--parallel off")).unwrap();
        assert_eq!(c.parallel, ParallelMode::Serial);
        assert_eq!(c.cluster().parallel, ParallelMode::Serial);
    }

    #[test]
    fn parses_pipeline_mode() {
        assert_eq!(RunConfig::default().pipeline, PipelineMode::Off);
        let c = RunConfig::from_args(&args("--pipeline overlap")).unwrap();
        assert_eq!(c.pipeline, PipelineMode::Overlap);
        assert_eq!(c.cluster().pipeline, PipelineMode::Overlap);
        let c = RunConfig::from_args(&args("--pipeline stale:1")).unwrap();
        assert_eq!(c.pipeline, PipelineMode::Stale);
        assert_eq!(c.cluster().pipeline, PipelineMode::Stale);
        // Unknown modes and unsupported staleness depths are CLI errors.
        assert!(RunConfig::from_args(&args("--pipeline async")).is_err());
        assert!(RunConfig::from_args(&args("--pipeline stale:2")).is_err());
    }

    #[test]
    fn parses_trace_spec() {
        use crate::trace::Level;
        assert!(RunConfig::default().trace.is_none());
        let c = RunConfig::from_args(&args("--trace out/run.jsonl")).unwrap();
        let spec = c.trace.unwrap();
        assert_eq!(spec.path, "out/run.jsonl");
        assert_eq!(spec.level, Level::Debug);
        let c = RunConfig::from_args(&args("--trace out/run.jsonl:info")).unwrap();
        assert_eq!(c.trace.unwrap().level, Level::Info);
        assert!(RunConfig::from_args(&args("--trace :debug")).is_err());
    }

    #[test]
    fn parses_faults() {
        assert!(RunConfig::default().faults.is_empty());
        let c = RunConfig::from_args(&args("--faults none")).unwrap();
        assert!(c.faults.is_empty());
        let c = RunConfig::from_args(&args("--faults kill:1@3,join:2@8")).unwrap();
        assert_eq!(c.faults, FaultPlan::parse("kill:1@3,join:2@8").unwrap());
        assert_eq!(c.cluster().faults, c.faults);
        // Malformed specs and out-of-world targets are CLI errors.
        assert!(RunConfig::from_args(&args("--faults zap:1@3")).is_err());
        assert!(RunConfig::from_args(&args("--faults kill:9@3 --workers 4")).is_err());
    }

    #[test]
    fn parses_error_feedback_and_lazy() {
        let c = RunConfig::default();
        assert!(!c.error_feedback);
        assert_eq!(c.lazy, LazyPolicy::Off);
        let c = RunConfig::from_args(&args("--error-feedback on --lazy thresh:0.5")).unwrap();
        assert!(c.error_feedback);
        assert_eq!(c.lazy, LazyPolicy::Thresh(0.5));
        assert!(c.cluster().error_feedback);
        assert_eq!(c.cluster().lazy, LazyPolicy::Thresh(0.5));
        let c = RunConfig::from_args(&args("--lazy laq:0.8@5")).unwrap();
        assert_eq!(c.lazy, LazyPolicy::Laq { c: 0.8, k: 5 });
        // Rejections carry the grammar.
        let err = RunConfig::from_args(&args("--lazy sometimes")).unwrap_err();
        assert!(err.to_string().contains("thresh:T"), "{err}");
        assert!(RunConfig::from_args(&args("--error-feedback maybe")).is_err());
        assert!(RunConfig::from_args(&args("--lazy thresh:-1")).is_err());
        assert!(RunConfig::from_args(&args("--lazy laq:0.5@0")).is_err());
        // Ring × feedback is a config-time error; ring × lazy is fine.
        let err =
            RunConfig::from_args(&args("--error-feedback on --topology ring")).unwrap_err();
        assert!(err.to_string().contains("unsupported over --topology ring"), "{err}");
        assert!(RunConfig::from_args(&args("--lazy thresh:2 --topology ring")).is_ok());
    }

    #[test]
    fn pipeline_transport_validation_is_parse_time() {
        // The sim accepts every pipeline mode; the TCP worker rejects
        // stale:1 with a pointer at the sim — one shared check for both.
        for p in [PipelineMode::Off, PipelineMode::Overlap, PipelineMode::Stale] {
            assert!(validate_pipeline_transport(p, false).is_ok());
        }
        assert!(validate_pipeline_transport(PipelineMode::Off, true).is_ok());
        assert!(validate_pipeline_transport(PipelineMode::Overlap, true).is_ok());
        let err = validate_pipeline_transport(PipelineMode::Stale, true).unwrap_err();
        assert!(err.contains("simulation schedule"), "{err}");
        assert!(err.contains("off|overlap"), "{err}");
    }

    #[test]
    fn lowers_to_cluster_config() {
        let c = RunConfig::from_args(&args("--iters 1000 --method trn")).unwrap();
        let cc = c.cluster();
        assert_eq!(cc.iters, 1000);
        assert_eq!(cc.method, Method::Trn);
        assert!(cc.lr.lr(0) > cc.lr.lr(999));
    }
}
