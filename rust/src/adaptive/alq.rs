//! ALQ: coordinate descent on individual levels (Section 3.1).
//!
//! Theorem 1: with neighbours (a, c) fixed, the optimal middle level is
//! `ℓ* = β(a, c) = F⁻¹( F(c) − ∫_a^c (r−a)/(c−a) dF )` — solved here by
//! bisection of `F(x) = target` restricted to `[a, c]` (Eq. 33). A full CD
//! sweep updates ℓ_1…ℓ_s in order; the paper observes convergence in < 10
//! sweeps from either uniform or exponential initialization.

use super::objective::psi;
use crate::quant::Levels;
use crate::stats::Dist;
use crate::util::bisect;

/// Options for the CD solver.
#[derive(Clone, Copy, Debug)]
pub struct AlqOptions {
    /// Max CD sweeps (paper: < 10 suffices).
    pub max_sweeps: usize,
    /// Stop when the max level movement in a sweep is below this.
    pub tol: f64,
    /// Bisection tolerance.
    pub bisect_tol: f64,
}

impl Default for AlqOptions {
    fn default() -> Self {
        AlqOptions {
            max_sweeps: 12,
            tol: 1e-7,
            bisect_tol: 1e-10,
        }
    }
}

/// One optimal-level solve: β(a, c) under `dist`.
pub fn beta<D: Dist>(dist: &D, a: f64, c: f64, bisect_tol: f64) -> f64 {
    debug_assert!(a < c);
    // target = F(c) − ∫_a^c (r−a)/(c−a) dF
    let df = dist.cdf(c) - dist.cdf(a);
    let ramp = (dist.partial_mean(a, c) - a * df) / (c - a);
    let target = dist.cdf(c) - ramp;
    bisect(|x| dist.cdf(x) - target, a, c, bisect_tol, 200)
}

/// Run ALQ coordinate descent from `levels`, returning the adapted levels
/// and the number of sweeps used.
pub fn optimize<D: Dist>(dist: &D, levels: &Levels, opts: AlqOptions) -> (Levels, usize) {
    assert!(
        levels.has_zero(),
        "ALQ coordinate descent operates on levels with a zero symbol"
    );
    let mut m = levels.mags().to_vec();
    let k = m.len();
    if k <= 2 {
        return (levels.clone(), 0); // nothing adaptable (e.g. ternary)
    }
    let mut sweeps = 0;
    for _ in 0..opts.max_sweeps {
        sweeps += 1;
        let mut max_move = 0.0f64;
        for j in 1..k - 1 {
            let new = beta(dist, m[j - 1], m[j + 1], opts.bisect_tol);
            // Keep strictly interior to preserve 𝓛; guard against two
            // levels collapsing onto one point under very concentrated
            // distributions (lo can exceed hi by rounding otherwise).
            let lo = m[j - 1] + 1e-12;
            let hi = (m[j + 1] - 1e-12).max(lo);
            let new = new.clamp(lo, hi);
            max_move = max_move.max((new - m[j]).abs());
            m[j] = new;
        }
        if max_move < opts.tol {
            break;
        }
    }
    (Levels::from_mags(m, true), sweeps)
}

/// Trace of Ψ across CD sweeps (for the Fig. 8 convergence experiment).
pub fn optimize_traced<D: Dist>(
    dist: &D,
    levels: &Levels,
    opts: AlqOptions,
) -> (Levels, Vec<f64>) {
    let mut cur = levels.clone();
    let mut trace = vec![psi(dist, &cur)];
    for _ in 0..opts.max_sweeps {
        let (next, _) = optimize(
            dist,
            &cur,
            AlqOptions {
                max_sweeps: 1,
                ..opts
            },
        );
        trace.push(psi(dist, &next));
        let moved = next
            .mags()
            .iter()
            .zip(cur.mags())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        cur = next;
        if moved < opts.tol {
            break;
        }
    }
    (cur, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Histogram, Mixture, TruncNormal};

    fn gradient_like_dist() -> Mixture {
        // Normalized gradient coords concentrate near zero.
        Mixture::new(
            vec![TruncNormal::unit(0.005, 0.01), TruncNormal::unit(0.03, 0.03)],
            vec![3.0, 1.0],
        )
    }

    #[test]
    fn beta_satisfies_first_order_condition() {
        // Proposition 2: at b*, ∫_a^b (r−a) dF = ∫_b^c (c−r) dF.
        let d = gradient_like_dist();
        let (a, c) = (0.0, 0.2);
        let b = beta(&d, a, c, 1e-12);
        assert!(a < b && b < c);
        let left = d.partial_mean(a, b) - a * (d.cdf(b) - d.cdf(a));
        let right = c * (d.cdf(c) - d.cdf(b)) - d.partial_mean(b, c);
        assert!((left - right).abs() < 1e-8, "{left} vs {right}");
    }

    #[test]
    fn cd_decreases_psi_monotonically() {
        let d = gradient_like_dist();
        let init = Levels::uniform(8);
        let (_, trace) = optimize_traced(&d, &init, AlqOptions::default());
        for w in trace.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-12,
                "Ψ must not increase under CD: {trace:?}"
            );
        }
        assert!(
            trace.last().unwrap() < &(trace[0] * 0.9),
            "CD should improve noticeably from uniform init: {trace:?}"
        );
    }

    #[test]
    fn cd_converges_fast_from_both_inits() {
        // Paper: "starting from either initialization CD converges in
        // small number of steps (less than 10)".
        let d = gradient_like_dist();
        for init in [Levels::uniform(4), Levels::exponential(4, 0.5)] {
            let (levels, sweeps) = optimize(&d, &init, AlqOptions::default());
            assert!(sweeps <= 12, "sweeps = {sweeps}");
            assert!(levels.mags().windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn fixed_point_is_stationary() {
        let d = gradient_like_dist();
        let (levels, _) = optimize(&d, &Levels::exponential(8, 0.5), AlqOptions::default());
        let g = super::super::objective::psi_grad(&d, &levels);
        for (j, gj) in g.iter().enumerate() {
            assert!(gj.abs() < 1e-4, "grad[{j}] = {gj} at CD fixed point");
        }
    }

    #[test]
    fn adapted_levels_concentrate_near_zero_for_concentrated_dist() {
        // Fig. 6's qualitative claim: adaptive levels bunch near 0 when
        // the coordinate distribution is concentrated near 0.
        let d = gradient_like_dist();
        let (adapted, _) = optimize(&d, &Levels::uniform(8), AlqOptions::default());
        let uni = Levels::uniform(8);
        // Compare the median interior level.
        let mid_a = adapted.mags()[4];
        let mid_u = uni.mags()[4];
        assert!(
            mid_a < mid_u * 0.5,
            "adapted median level {mid_a} should sit well below uniform {mid_u}"
        );
    }

    #[test]
    fn ternary_is_noop() {
        let d = gradient_like_dist();
        let (l, sweeps) = optimize(&d, &Levels::ternary(), AlqOptions::default());
        assert_eq!(l.mags(), &[0.0, 1.0]);
        assert_eq!(sweeps, 0);
    }

    #[test]
    fn works_on_histogram_distribution() {
        let mut h = Histogram::new(128);
        let mut rng = crate::util::Rng::new(31);
        for _ in 0..50_000 {
            h.add((rng.normal().abs() * 0.02).min(1.0));
        }
        let (levels, _) = optimize(&h, &Levels::uniform(4), AlqOptions::default());
        assert!(levels.mags().windows(2).all(|w| w[0] < w[1]));
        assert!(
            psi(&h, &levels) <= psi(&h, &Levels::uniform(4)) + 1e-12,
            "CD should not do worse than its init"
        );
    }
}
