//! The expected-variance objective Ψ(ℓ) and its derivatives, in closed
//! form over any `Dist` (truncated normal, mixture, or histogram).
//!
//! All pieces reduce to the partial moments `ΔF`, `M1 = ∫ r dF`,
//! `M2 = ∫ r² dF` of sub-intervals:
//!
//! * bin variance `∫_a^b (b−r)(r−a) dF = −M2 + (a+b) M1 − ab ΔF`
//! * AMQ first bin `∫_0^{ℓ₁} (ℓ₁²−r²) dF = ℓ₁² ΔF − M2`
//! * ramp `∫_a^c (r−a)/(c−a) dF = (M1 − a ΔF)/(c−a)` (Prop. 6)

use crate::quant::Levels;
use crate::stats::Dist;

/// `∫_a^b (b−r)(r−a) dF` — the variance mass of one bin (Eq. 2 integrated).
#[inline]
pub fn bin_variance<D: Dist>(dist: &D, a: f64, b: f64) -> f64 {
    if b <= a {
        return 0.0;
    }
    let df = dist.cdf(b) - dist.cdf(a);
    let m1 = dist.partial_mean(a, b);
    let m2 = dist.partial_mean_sq(a, b);
    (-m2 + (a + b) * m1 - a * b * df).max(0.0)
}

/// Ψ(ℓ): expected per-coordinate quantization variance under `dist`
/// (Eq. 3 with the mixture of Eq. 10 folded into `dist`).
pub fn psi<D: Dist>(dist: &D, levels: &Levels) -> f64 {
    let m = levels.mags();
    let mut total = 0.0;
    if levels.has_zero() {
        for w in m.windows(2) {
            total += bin_variance(dist, w[0], w[1]);
        }
    } else {
        // AMQ-style symmetric first bin: ∫_0^{ℓ₁} (ℓ₁² − r²) dF.
        let l1 = m[0];
        let df = dist.cdf(l1) - dist.cdf(0.0);
        let m2 = dist.partial_mean_sq(0.0, l1);
        total += (l1 * l1 * df - m2).max(0.0);
        for w in m.windows(2) {
            total += bin_variance(dist, w[0], w[1]);
        }
    }
    total
}

/// ∂Ψ/∂ℓ_j for an interior level (Eq. 36):
/// `∫_{a}^{ℓ_j} (r−a) dF − ∫_{ℓ_j}^{c} (c−r) dF`.
#[inline]
pub fn psi_grad_level<D: Dist>(dist: &D, a: f64, lj: f64, c: f64) -> f64 {
    let left = dist.partial_mean(a, lj) - a * (dist.cdf(lj) - dist.cdf(a));
    let right = c * (dist.cdf(c) - dist.cdf(lj)) - dist.partial_mean(lj, c);
    left - right
}

/// ∂Ψ/∂ℓ₁ for zero-free symmetric levels (Eq. 30, halved):
/// `2ℓ₁ (F(ℓ₁) − F(0)) − ∫_{ℓ₁}^{ℓ₂} (ℓ₂ − r) dF`.
#[inline]
pub fn psi_grad_first_symmetric<D: Dist>(dist: &D, l1: f64, l2: f64) -> f64 {
    let first = 2.0 * l1 * (dist.cdf(l1) - dist.cdf(0.0));
    let right = l2 * (dist.cdf(l2) - dist.cdf(l1)) - dist.partial_mean(l1, l2);
    first - right
}

/// Full gradient vector over the adaptable levels.
pub fn psi_grad<D: Dist>(dist: &D, levels: &Levels) -> Vec<f64> {
    let m = levels.mags();
    let k = m.len();
    if levels.has_zero() {
        (1..k - 1)
            .map(|j| psi_grad_level(dist, m[j - 1], m[j], m[j + 1]))
            .collect()
    } else {
        let mut g = Vec::with_capacity(k - 1);
        g.push(psi_grad_first_symmetric(dist, m[0], m[1]));
        for j in 1..k - 1 {
            g.push(psi_grad_level(dist, m[j - 1], m[j], m[j + 1]));
        }
        g
    }
}

/// Symbol probabilities of Proposition 6 (has-zero) / Proposition 8
/// (zero-free), used to build the Huffman codebook without observing data.
pub fn symbol_probs<D: Dist>(dist: &D, levels: &Levels) -> Vec<f64> {
    let m = levels.mags();
    let k = m.len();
    let ramp_up = |a: f64, c: f64| -> f64 {
        // ∫_a^c (r − a)/(c − a) dF
        if c <= a {
            return 0.0;
        }
        (dist.partial_mean(a, c) - a * (dist.cdf(c) - dist.cdf(a))) / (c - a)
    };
    let ramp_down = |a: f64, c: f64| -> f64 {
        // ∫_a^c (c − r)/(c − a) dF
        if c <= a {
            return 0.0;
        }
        (c * (dist.cdf(c) - dist.cdf(a)) - dist.partial_mean(a, c)) / (c - a)
    };
    let mut probs = vec![0.0f64; k];
    if levels.has_zero() {
        probs[0] = ramp_down(0.0, m[1]);
        for j in 1..k {
            probs[j] += ramp_up(m[j - 1], m[j]);
            if j + 1 < k {
                probs[j] += ramp_down(m[j], m[j + 1]);
            }
        }
    } else {
        // Whole first bin maps to ±ℓ₁ plus the down-ramp from bin 2.
        probs[0] = (dist.cdf(m[0]) - dist.cdf(0.0)) + ramp_down(m[0], m[1]);
        for j in 1..k {
            probs[j] += ramp_up(m[j - 1], m[j]);
            if j + 1 < k {
                probs[j] += ramp_down(m[j], m[j + 1]);
            }
        }
    }
    // Clamp rounding slack (nearly-collapsed levels can yield tiny
    // negative ramps) and normalize.
    for p in probs.iter_mut() {
        if !p.is_finite() || *p < 0.0 {
            *p = 0.0;
        }
    }
    let total: f64 = probs.iter().sum();
    if total > 0.0 {
        for p in probs.iter_mut() {
            *p /= total;
        }
    }
    probs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Mixture, TruncNormal};
    use crate::util::simpson;

    fn dist() -> Mixture {
        Mixture::new(
            vec![TruncNormal::unit(0.02, 0.02), TruncNormal::unit(0.08, 0.05)],
            vec![2.0, 1.0],
        )
    }

    #[test]
    fn bin_variance_matches_quadrature() {
        let d = dist();
        let (a, b) = (0.05, 0.3);
        let got = bin_variance(&d, a, b);
        let want = simpson(|r| (b - r) * (r - a) * d.pdf(r), a, b, 4000);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn psi_matches_quadrature_has_zero() {
        let d = dist();
        let levels = Levels::exponential(4, 0.5);
        let got = psi(&d, &levels);
        let m = levels.mags();
        let mut want = 0.0;
        for w in m.windows(2) {
            want += simpson(|r| (w[1] - r) * (r - w[0]) * d.pdf(r), w[0], w[1], 4000);
        }
        assert!((got - want).abs() < 1e-8, "{got} vs {want}");
    }

    #[test]
    fn psi_matches_quadrature_amq() {
        let d = dist();
        let levels = Levels::amq(4, 0.5);
        let got = psi(&d, &levels);
        let m = levels.mags();
        let mut want = simpson(|r| (m[0] * m[0] - r * r) * d.pdf(r), 0.0, m[0], 4000);
        for w in m.windows(2) {
            want += simpson(|r| (w[1] - r) * (r - w[0]) * d.pdf(r), w[0], w[1], 4000);
        }
        assert!((got - want).abs() < 1e-8, "{got} vs {want}");
    }

    #[test]
    fn grad_matches_finite_difference() {
        let d = dist();
        let levels = Levels::exponential(5, 0.5);
        let g = psi_grad(&d, &levels);
        let eps = 1e-6;
        let m = levels.mags().to_vec();
        for (gi, j) in g.iter().zip(1..m.len() - 1) {
            let mut hi = m.clone();
            hi[j] += eps;
            let mut lo = m.clone();
            lo[j] -= eps;
            let fd = (psi(&d, &Levels::from_mags(hi, true))
                - psi(&d, &Levels::from_mags(lo, true)))
                / (2.0 * eps);
            assert!((gi - fd).abs() < 1e-6, "level {j}: {gi} vs fd {fd}");
        }
    }

    #[test]
    fn grad_amq_first_level_matches_fd() {
        let d = dist();
        let levels = Levels::amq(4, 0.5);
        let g = psi_grad(&d, &levels);
        let m = levels.mags().to_vec();
        let eps = 1e-6;
        for (gi, j) in g.iter().zip(0..m.len() - 1) {
            let mut hi = m.clone();
            hi[j] += eps;
            let mut lo = m.clone();
            lo[j] -= eps;
            let fd = (psi(&d, &Levels::from_mags(hi, false))
                - psi(&d, &Levels::from_mags(lo, false)))
                / (2.0 * eps);
            assert!((gi - fd).abs() < 1e-6, "level {j}: {gi} vs fd {fd}");
        }
    }

    #[test]
    fn symbol_probs_sum_to_one_and_match_simulation() {
        use crate::quant::{NormType, Quantizer};
        use crate::util::Rng;
        // Simulate: draw magnitudes from the mixture directly by feeding a
        // synthetic bucket whose normalized coords are mixture samples.
        let levels = Levels::exponential(4, 0.5);
        let d = TruncNormal::unit(0.15, 0.1);
        let probs = symbol_probs(&d, &levels);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);

        // Empirical check: quantize coords with r drawn from d, Linf norm 1
        // (embed a 1.0 coordinate to pin the norm).
        let mut rng = Rng::new(30);
        let n = 40_000;
        let mut counts = vec![0f64; levels.num_symbols()];
        let quant = Quantizer::new(levels.clone(), NormType::Linf, n);
        let mut v: Vec<f32> = (0..n).map(|_| d.inv_cdf(rng.f64()) as f32).collect();
        v[0] = 1.0; // pins Linf norm to 1 so r_i = v_i
        let q = quant.quantize(&v, &mut rng);
        for &s in &q.qidx {
            counts[s.unsigned_abs() as usize] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        for (j, (&c, &p)) in counts.iter().zip(&probs).enumerate() {
            let emp = c / total;
            assert!(
                (emp - p).abs() < 0.01,
                "symbol {j}: empirical {emp} vs Prop.6 {p}"
            );
        }
    }

    #[test]
    fn grad_zero_at_uniform_for_uniform_dist() {
        // For the uniform distribution the uniform levels are stationary:
        // ∫(r−a) over left bin equals ∫(c−r) over right bin by symmetry.
        let u = crate::stats::Histogram::new(4); // empty = uniform
        let levels = Levels::uniform(5);
        for g in psi_grad(&u, &levels) {
            assert!(g.abs() < 1e-12, "{g}");
        }
    }
}
