//! Gradient → distribution estimator (Section 3.4, Appendix K).
//!
//! At update steps the workers sample per-bucket sufficient statistics
//! (μ_n, σ_n², ‖v_n‖) of the normalized coordinates — via the L1 `stats`
//! Pallas kernel on device, or the host path here — subsample to keep the
//! component count bounded (paper: 20 for CIFAR-scale, 350 for ImageNet),
//! and fit a mixture of truncated normals `F̄ = Σ γ_n F_n` with
//! `γ_n ∝ ‖v_n‖²` (expected variance) or `γ_n = 1/N` (normalized).

use crate::quant::NormType;
use crate::stats::{BucketStats, Histogram, Mixture, TruncNormal};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Estimator {
    pub bucket: usize,
    pub norm_type: NormType,
    /// Max mixture components after subsampling (App. K: 20 / 350).
    pub max_components: usize,
    /// σ floor guarding the CDF math against degenerate buckets (App. K
    /// "the value of the statistics, especially the variance, can become
    /// very small. This makes PDF and CDF calculations challenging.").
    pub sigma_floor: f64,
    accum: Vec<BucketStats>,
}

impl Estimator {
    pub fn new(bucket: usize, norm_type: NormType, max_components: usize) -> Self {
        Estimator {
            bucket,
            norm_type,
            max_components,
            sigma_floor: 1e-5,
            accum: Vec::new(),
        }
    }

    /// Ingest one gradient vector's full buckets.
    pub fn observe(&mut self, grad: &[f32]) {
        let nb = grad.len() / self.bucket;
        for b in 0..nb {
            let s = BucketStats::from_bucket(
                &grad[b * self.bucket..(b + 1) * self.bucket],
                self.norm_type,
            );
            if s.norm > 0.0 {
                self.accum.push(s);
            }
        }
    }

    /// Ingest precomputed stats (e.g. from the Pallas stats artifact).
    pub fn observe_stats(&mut self, stats: &[BucketStats]) {
        self.accum
            .extend(stats.iter().filter(|s| s.norm > 0.0).copied());
    }

    pub fn n_observed(&self) -> usize {
        self.accum.len()
    }

    pub fn clear(&mut self) {
        self.accum.clear();
    }

    /// Fit the mixture. `weighted`: γ_n ∝ ‖v_n‖² (ALQ/AMQ) vs uniform
    /// (`-N` variants). Subsamples uniformly to `max_components`.
    pub fn fit(&self, weighted: bool, rng: &mut Rng) -> Option<Mixture> {
        if self.accum.is_empty() {
            return None;
        }
        let chosen: Vec<&BucketStats> = if self.accum.len() <= self.max_components {
            self.accum.iter().collect()
        } else {
            let mut idx: Vec<usize> = (0..self.accum.len()).collect();
            // Partial Fisher–Yates for the first max_components slots.
            for i in 0..self.max_components {
                let j = i + rng.below(idx.len() - i);
                idx.swap(i, j);
            }
            idx[..self.max_components]
                .iter()
                .map(|&i| &self.accum[i])
                .collect()
        };
        let comps: Vec<TruncNormal> = chosen
            .iter()
            .map(|s| TruncNormal::unit(s.mu, s.sigma2.sqrt().max(self.sigma_floor)))
            .collect();
        let weights: Vec<f64> = if weighted {
            chosen.iter().map(|s| s.norm * s.norm).collect()
        } else {
            vec![1.0; chosen.len()]
        };
        Some(Mixture::new(comps, weights))
    }

    /// Nonparametric alternative: histogram of all normalized coordinates
    /// (subsampled), usable directly as a `Dist` for ALQ.
    pub fn fit_histogram(&self, grad: &[f32], bins: usize) -> Histogram {
        let mut h = Histogram::new(bins);
        let nb = grad.len() / self.bucket;
        for b in 0..nb {
            let bucket = &grad[b * self.bucket..(b + 1) * self.bucket];
            let norm = crate::quant::bucket_norm(bucket, self.norm_type);
            if norm == 0.0 {
                continue;
            }
            let inv = 1.0 / norm as f64;
            for &x in bucket {
                h.add((x.abs() as f64 * inv).clamp(0.0, 1.0));
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Dist;

    fn gaussian_grad(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.normal() * 0.01) as f32).collect()
    }

    #[test]
    fn observes_and_fits() {
        let mut e = Estimator::new(256, NormType::L2, 20);
        e.observe(&gaussian_grad(4096, 1));
        assert_eq!(e.n_observed(), 16);
        let mut rng = Rng::new(2);
        let m = e.fit(true, &mut rng).unwrap();
        assert_eq!(m.len(), 16);
        // For iid normal coords with bucket 256, E[r] ~ sqrt(2/pi)/16 ~ 0.05.
        let mean = m.partial_mean(0.0, 1.0);
        assert!((mean - 0.0498).abs() < 0.01, "mixture mean {mean}");
    }

    #[test]
    fn subsampling_caps_components() {
        let mut e = Estimator::new(64, NormType::L2, 10);
        e.observe(&gaussian_grad(6400, 3)); // 100 buckets
        assert_eq!(e.n_observed(), 100);
        let mut rng = Rng::new(4);
        let m = e.fit(false, &mut rng).unwrap();
        assert_eq!(m.len(), 10);
    }

    #[test]
    fn weighted_vs_uniform_weights_differ() {
        let mut e = Estimator::new(32, NormType::L2, 50);
        // Two populations with very different norms.
        let mut g = gaussian_grad(320, 5);
        for x in g.iter_mut().take(160) {
            *x *= 100.0;
        }
        e.observe(&g);
        let mut rng = Rng::new(6);
        let w = e.fit(true, &mut rng).unwrap();
        let u = e.fit(false, &mut rng).unwrap();
        // Under γ ∝ ‖v‖² the large-norm half takes ~all the mass (its
        // norm² is 10⁴× larger); under uniform every bucket gets 1/10.
        let big_mass_w: f64 = w.weights().iter().filter(|&&x| x > 0.01).sum();
        let max_u = u.weights().iter().cloned().fold(0.0, f64::max);
        assert!(big_mass_w > 0.999, "weighted mass on large buckets: {big_mass_w}");
        assert!((max_u - 0.1).abs() < 1e-12, "uniform weights: {max_u}");
    }

    #[test]
    fn empty_estimator_returns_none() {
        let e = Estimator::new(64, NormType::L2, 10);
        let mut rng = Rng::new(7);
        assert!(e.fit(true, &mut rng).is_none());
    }

    #[test]
    fn zero_buckets_skipped() {
        let mut e = Estimator::new(64, NormType::L2, 10);
        e.observe(&vec![0.0f32; 256]);
        assert_eq!(e.n_observed(), 0);
    }

    #[test]
    fn histogram_fit_matches_mixture_shape() {
        let mut e = Estimator::new(256, NormType::L2, 64);
        let g = gaussian_grad(16384, 8);
        e.observe(&g);
        let h = e.fit_histogram(&g, 256);
        let mut rng = Rng::new(9);
        let m = e.fit(false, &mut rng).unwrap();
        // Medians should roughly agree between parametric + nonparametric.
        let med_h = h.inv_cdf(0.5);
        let med_m = m.inv_cdf(0.5);
        assert!(
            (med_h - med_m).abs() < 0.02,
            "hist median {med_h} vs mixture {med_m}"
        );
    }
}
