//! Adaptive quantization (Section 3) — the paper's core contribution.
//!
//! * [`objective`] — Ψ(ℓ) (Eq. 3/10), its gradient (Eq. 25/36), symbol
//!   probabilities (Prop. 6), all in closed form over any [`crate::stats::Dist`].
//! * [`alq`] — ALQ coordinate descent (Theorem 1, Eq. 33).
//! * [`gd`] — safeguarded projection-free gradient descent (Eq. 7).
//! * [`amq`] — AMQ multiplier descent (Eq. 8 / Appendix C.3).
//! * [`estimator`] — gradient → sufficient statistics → truncated-normal
//!   mixture (Section 3.4 / Appendix K).
//! * [`policy`] — per-method dispatch used by the training loop.

pub mod alq;
pub mod amq;
pub mod estimator;
pub mod gd;
pub mod objective;
pub mod policy;
pub mod zipml;

pub use estimator::Estimator;
pub use policy::update_levels;
