//! ZipML-style offline-optimal levels (Zhang et al., ICML'17) — the
//! paper's related-work comparison point.
//!
//! ZipML solves for globally optimal quantization levels by dynamic
//! programming over the *empirical points* — O(B²s) in the number of
//! candidate positions B, which is why the paper calls it impractical
//! for on-the-fly gradient quantization (§1.2). We implement the DP over
//! a histogram grid as the **offline-optimal reference**: ALQ's
//! coordinate descent should land within a few percent of it at a tiny
//! fraction of the cost (asserted in tests; surfaced in `exp fig8`'s
//! random-restart analysis).

use super::objective::psi;
use crate::quant::Levels;
use crate::stats::Dist;

/// Globally optimal (to grid resolution) has-zero levels with `s`
/// interior levels over candidate grid points in (0, 1).
///
/// dp[m][i] = min cost of the bins left of candidate i when the m-th
/// interior level sits at candidate i; cost(a, b) of one bin is the
/// closed-form `∫_a^b (b−r)(r−a) dF` from the `Dist`.
pub fn optimal_levels<D: Dist>(dist: &D, s: usize, grid: usize) -> Levels {
    assert!(grid >= s + 2);
    if s == 0 {
        return Levels::uniform(2); // only the pinned {0, 1}
    }
    // Candidates: grid points including the pinned endpoints 0 and 1.
    let cand: Vec<f64> = (0..=grid).map(|i| i as f64 / grid as f64).collect();
    let n = cand.len();
    let bin = |a: usize, b: usize| -> f64 {
        super::objective::bin_variance(dist, cand[a], cand[b])
    };

    // dp[c][i]: minimal cost of [0, cand[i]] with exactly c interior
    // levels placed, the c-th at candidate i (0 < i < n-1).
    let mut dp = vec![vec![f64::INFINITY; n]; s + 1];
    let mut parent = vec![vec![0usize; n]; s + 1];
    for i in 1..n - 1 {
        dp[1][i] = bin(0, i);
    }
    for c in 2..=s {
        for i in c..n - 1 {
            let mut best = (f64::INFINITY, 0usize);
            for j in (c - 1)..i {
                let cost = dp[c - 1][j] + bin(j, i);
                if cost < best.0 {
                    best = (cost, j);
                }
            }
            dp[c][i] = best.0;
            parent[c][i] = best.1;
        }
    }
    // Close with the final bin up to 1.0.
    let mut best = (f64::INFINITY, s);
    for i in s..n - 1 {
        let cost = dp[s][i] + bin(i, n - 1);
        if cost < best.0 {
            best = (cost, i);
        }
    }
    // Walk parents: exactly s interior levels.
    let mut interior = Vec::with_capacity(s);
    let mut i = best.1;
    interior.push(cand[i]);
    for c in (2..=s).rev() {
        i = parent[c][i];
        interior.push(cand[i]);
    }
    interior.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Grid collisions could merge levels; rebuild strictly increasing.
    let mut mags = vec![0.0f64];
    for &l in interior.iter().filter(|&&l| l > 0.0 && l < 1.0) {
        if l > *mags.last().unwrap() + 1e-12 {
            mags.push(l);
        }
    }
    mags.push(1.0);
    Levels::from_mags(mags, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptive::alq;
    use crate::stats::{Mixture, TruncNormal};

    fn dist() -> Mixture {
        Mixture::new(
            vec![TruncNormal::unit(0.02, 0.02), TruncNormal::unit(0.10, 0.06)],
            vec![3.0, 1.0],
        )
    }

    #[test]
    fn dp_beats_or_matches_fixed_baselines() {
        let d = dist();
        let opt = optimal_levels(&d, 2, 200);
        let psi_opt = psi(&d, &opt);
        for fixed in [Levels::uniform(4), Levels::exponential(4, 0.5)] {
            assert!(
                psi_opt <= psi(&d, &fixed) + 1e-9,
                "DP {psi_opt} worse than fixed {}",
                psi(&d, &fixed)
            );
        }
    }

    #[test]
    fn alq_lands_near_offline_optimum() {
        // The paper's pitch: ALQ ≈ optimal at a fraction of ZipML's cost.
        let d = dist();
        let opt = optimal_levels(&d, 2, 400);
        let psi_opt = psi(&d, &opt);
        let (cd, _) = alq::optimize(&d, &Levels::exponential(4, 0.5), alq::AlqOptions::default());
        let psi_cd = psi(&d, &cd);
        assert!(
            psi_cd <= psi_opt * 1.10 + 1e-12,
            "ALQ {psi_cd} should be within 10% of offline optimum {psi_opt}"
        );
    }

    #[test]
    fn dp_respects_level_budget() {
        let d = dist();
        for s in [1usize, 2, 6] {
            let l = optimal_levels(&d, s, 150);
            assert!(l.k() <= s + 2);
            assert!(l.has_zero());
            assert!(l.mags().windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn finer_grid_does_not_hurt() {
        let d = dist();
        let coarse = psi(&d, &optimal_levels(&d, 2, 50));
        let fine = psi(&d, &optimal_levels(&d, 2, 400));
        assert!(fine <= coarse + 1e-9);
    }
}
