//! AMQ: gradient descent on the exponential multiplier p (Section 3.3).
//!
//! Levels are `±[p^s, …, p, 1]` (no zero), so the whole set is one scalar.
//! Eq. (8) gives the derivative of Ψ(p) in closed form over partial
//! moments; we descend with backtracking and clamp p ∈ (p_min, p_max).

use super::objective::psi;
use crate::quant::Levels;
use crate::stats::Dist;

#[derive(Clone, Copy, Debug)]
pub struct AmqOptions {
    pub steps: usize,
    pub eta0: f64,
    pub decay: f64,
    pub p_min: f64,
    pub p_max: f64,
}

impl Default for AmqOptions {
    fn default() -> Self {
        AmqOptions {
            steps: 300,
            eta0: 0.5,
            decay: 0.05,
            p_min: 0.05,
            p_max: 0.95,
        }
    }
}

/// Ψ(p) for `k` magnitudes (s = k − 1), Eq. (32) adapted to the magnitude
/// distribution on [0, 1].
pub fn psi_p<D: Dist>(dist: &D, k: usize, p: f64) -> f64 {
    psi(dist, &Levels::amq(k, p))
}

/// dΨ/dp in closed form (Eq. 8): with s = k − 1,
///
/// ½ dΨ/dp = ∫_0^{p^s} 2s p^{2s−1} dF
///         + Σ_{j=0}^{s−1} ∫_{p^{j+1}}^{p^j} ((j p^{j−1} + (j+1) p^j) r − (2j+1) p^{2j}) dF
pub fn dpsi_dp<D: Dist>(dist: &D, k: usize, p: f64) -> f64 {
    let s = (k - 1) as i32;
    let ps = p.powi(s);
    let mut g = 2.0 * s as f64 * p.powi(2 * s - 1) * (dist.cdf(ps) - dist.cdf(0.0));
    for j in 0..s {
        let hi = p.powi(j); // p^j
        let lo = p.powi(j + 1); // p^{j+1}
        let jf = j as f64;
        let coef_r = jf * p.powi(j - 1) + (jf + 1.0) * p.powi(j);
        let coef_c = (2.0 * jf + 1.0) * p.powi(2 * j);
        let m1 = dist.partial_mean(lo, hi);
        let df = dist.cdf(hi) - dist.cdf(lo);
        g += coef_r * m1 - coef_c * df;
    }
    g
}

/// Descend p from `p0`; returns (p*, Ψ trace).
pub fn optimize_traced<D: Dist>(
    dist: &D,
    k: usize,
    p0: f64,
    opts: AmqOptions,
) -> (f64, Vec<f64>) {
    let mut p = p0.clamp(opts.p_min, opts.p_max);
    let mut trace = vec![psi_p(dist, k, p)];
    for t in 0..opts.steps {
        let g = dpsi_dp(dist, k, p);
        let mut eta = opts.eta0 / (1.0 + t as f64 * opts.decay);
        // Backtracking: halve until Ψ does not increase.
        let cur = *trace.last().unwrap();
        let mut next_p = (p - eta * g).clamp(opts.p_min, opts.p_max);
        let mut next_v = psi_p(dist, k, next_p);
        let mut tries = 0;
        while next_v > cur && tries < 20 {
            eta *= 0.5;
            next_p = (p - eta * g).clamp(opts.p_min, opts.p_max);
            next_v = psi_p(dist, k, next_p);
            tries += 1;
        }
        if (next_p - p).abs() < 1e-10 {
            break;
        }
        p = next_p;
        trace.push(next_v);
    }
    (p, trace)
}

/// Convenience: optimized AMQ levels.
pub fn optimize<D: Dist>(dist: &D, k: usize, p0: f64, opts: AmqOptions) -> Levels {
    let (p, _) = optimize_traced(dist, k, p0, opts);
    Levels::amq(k, p)
}

/// Grid-scan reference optimum (tests + Fig. 8 ground truth).
pub fn scan_optimum<D: Dist>(dist: &D, k: usize, grid: usize) -> (f64, f64) {
    let mut best = (0.5, f64::INFINITY);
    for i in 1..grid {
        let p = i as f64 / grid as f64;
        if p <= 0.01 || p >= 0.99 {
            continue;
        }
        let v = psi_p(dist, k, p);
        if v < best.1 {
            best = (p, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Mixture, TruncNormal};

    fn dist() -> Mixture {
        Mixture::new(
            vec![TruncNormal::unit(0.01, 0.02), TruncNormal::unit(0.06, 0.05)],
            vec![2.0, 1.0],
        )
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let d = dist();
        for p in [0.2, 0.4, 0.5, 0.7, 0.9] {
            let g = dpsi_dp(&d, 4, p);
            let eps = 1e-6;
            let fd = (psi_p(&d, 4, p + eps) - psi_p(&d, 4, p - eps)) / (2.0 * eps);
            // Eq. 8 is stated as ½ dΨ/dp in the paper; our psi over the
            // magnitude distribution absorbs the factor 2, so g == fd.
            assert!((g - fd).abs() < 1e-5, "p={p}: {g} vs {fd}");
        }
    }

    #[test]
    fn gd_finds_scan_optimum() {
        let d = dist();
        let (p_gd, trace) = optimize_traced(&d, 4, 0.5, AmqOptions::default());
        let (p_scan, v_scan) = scan_optimum(&d, 4, 400);
        let v_gd = psi_p(&d, 4, p_gd);
        assert!(
            v_gd <= v_scan * 1.02 + 1e-12,
            "GD Ψ {v_gd} (p={p_gd}) vs scan Ψ {v_scan} (p={p_scan}); trace {trace:?}"
        );
    }

    #[test]
    fn trace_monotone_nonincreasing() {
        let d = dist();
        let (_, trace) = optimize_traced(&d, 4, 0.9, AmqOptions::default());
        for w in trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "{trace:?}");
        }
    }

    #[test]
    fn concentrated_distribution_pushes_p_down() {
        // Coordinates near zero → small levels help → smaller p… note
        // smaller p puts p^s closer to 0. Compare optima for concentrated
        // vs diffuse distributions.
        let tight = TruncNormal::unit(0.005, 0.005);
        let wide = TruncNormal::unit(0.4, 0.3);
        let (p_tight, _) = scan_optimum(&tight, 4, 400);
        let (p_wide, _) = scan_optimum(&wide, 4, 400);
        assert!(
            p_tight < p_wide,
            "tight {p_tight} should be below wide {p_wide}"
        );
    }

    #[test]
    fn respects_clamp() {
        let d = dist();
        let opts = AmqOptions {
            p_min: 0.3,
            p_max: 0.6,
            ..Default::default()
        };
        let (p, _) = optimize_traced(&d, 4, 0.9, opts);
        assert!((0.3..=0.6).contains(&p));
    }
}
