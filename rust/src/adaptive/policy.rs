//! Per-method adaptation dispatch (Algorithm 1, line 4).
//!
//! Given a method, its current levels, and the fitted mixture, produce the
//! updated levels. Non-adaptive methods are identity.

use super::{alq, amq, gd};
use crate::quant::{schemes::AdaptKind, Levels, Method};
use crate::stats::Dist;

/// Update a method's levels against the fitted distribution.
pub fn update_levels<D: Dist>(method: Method, levels: &Levels, dist: &D) -> Levels {
    match method.adapt_kind() {
        AdaptKind::None => levels.clone(),
        AdaptKind::Cd => alq::optimize(dist, levels, alq::AlqOptions::default()).0,
        AdaptKind::Gd => gd::optimize(dist, levels, gd::GdOptions::default()),
        AdaptKind::Multiplier => {
            let k = levels.k();
            // Recover the current multiplier from the second-smallest /
            // smallest ratio (levels are exactly geometric by construction).
            let p0 = if k >= 2 {
                (levels.mags()[0] / levels.mags()[1]).clamp(0.05, 0.95)
            } else {
                0.5
            };
            amq::optimize(dist, k, p0, amq::AmqOptions::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptive::objective::psi;
    use crate::stats::TruncNormal;

    #[test]
    fn non_adaptive_identity() {
        let d = TruncNormal::unit(0.05, 0.05);
        for m in [Method::QsgdInf, Method::Trn, Method::NuqSgd] {
            let l = m.initial_levels(3).unwrap();
            assert_eq!(update_levels(m, &l, &d).mags(), l.mags());
        }
    }

    #[test]
    fn all_adaptive_methods_improve_psi() {
        let d = TruncNormal::unit(0.02, 0.03);
        for m in [
            Method::Alq,
            Method::AlqN,
            Method::AlqG,
            Method::AlqGN,
            Method::Amq,
            Method::AmqN,
        ] {
            let init = m.initial_levels(3).unwrap();
            let adapted = update_levels(m, &init, &d);
            let before = psi(&d, &init);
            let after = psi(&d, &adapted);
            assert!(
                after <= before + 1e-12,
                "{m}: psi {before} -> {after} should not increase"
            );
        }
    }

    #[test]
    fn amq_stays_geometric() {
        let d = TruncNormal::unit(0.02, 0.03);
        let init = Method::Amq.initial_levels(3).unwrap();
        let adapted = update_levels(Method::Amq, &init, &d);
        let m = adapted.mags();
        let p = m[0] / m[1];
        for w in m.windows(2) {
            assert!((w[0] / w[1] - p).abs() < 1e-9, "not geometric: {m:?}");
        }
    }
}
