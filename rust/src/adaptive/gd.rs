//! Safeguarded, projection-free gradient descent on levels (Section 3.2).
//!
//! Eq. (7): each step moves level j by at most δ_j(t)/2 where δ_j is the
//! distance to the nearest neighbouring level, which keeps ℓ ∈ 𝓛 without
//! a projection. Used by the ALQ-G/ALQ-GN variants and by the Fig. 8
//! convergence comparison.

use super::objective::{psi, psi_grad};
use crate::quant::Levels;
use crate::stats::Dist;

#[derive(Clone, Copy, Debug)]
pub struct GdOptions {
    pub steps: usize,
    /// Learning rate η(t) = eta0 / (1 + t * decay).
    pub eta0: f64,
    pub decay: f64,
}

impl Default for GdOptions {
    fn default() -> Self {
        GdOptions {
            steps: 200,
            eta0: 40.0,
            decay: 0.02,
        }
    }
}

/// One safeguarded GD step (Eq. 7). Returns the max level movement.
pub fn step<D: Dist>(dist: &D, levels: &mut Vec<f64>, has_zero: bool, eta: f64) -> f64 {
    let k = levels.len();
    let grad = psi_grad(
        dist,
        &Levels::from_mags(levels.clone(), has_zero),
    );
    let adapt_start = if has_zero { 1 } else { 0 };
    let mut max_move = 0.0f64;
    // Compute all deltas against the *current* iterate (synchronous update
    // like Eq. 7), then apply.
    let mut new = levels.clone();
    for (gi, j) in grad.iter().zip(adapt_start..k - 1) {
        let left = if j == 0 { 0.0 } else { levels[j - 1] };
        let delta_j = (levels[j] - left).min(levels[j + 1] - levels[j]);
        let raw = eta * gi.abs();
        let mv = raw.min(delta_j / 2.0);
        new[j] = levels[j] - gi.signum() * mv;
        max_move = max_move.max(mv);
    }
    *levels = new;
    max_move
}

/// Run GD, returning adapted levels.
pub fn optimize<D: Dist>(dist: &D, init: &Levels, opts: GdOptions) -> Levels {
    let (l, _) = optimize_traced(dist, init, opts);
    l
}

/// Run GD and record Ψ after every step (Fig. 8).
///
/// Eq. (7) alone does not guarantee descent (only feasibility); a simple
/// backtracking scale keeps the trace monotone — if a step increases Ψ it
/// is reverted and the step size halved (restored slowly on success).
pub fn optimize_traced<D: Dist>(dist: &D, init: &Levels, opts: GdOptions) -> (Levels, Vec<f64>) {
    let has_zero = init.has_zero();
    let mut m = init.mags().to_vec();
    let mut trace = vec![psi(dist, init)];
    let mut scale = 1.0f64;
    for t in 0..opts.steps {
        let eta = scale * opts.eta0 / (1.0 + t as f64 * opts.decay);
        let prev = m.clone();
        let moved = step(dist, &mut m, has_zero, eta);
        let cur = psi(dist, &Levels::from_mags(m.clone(), has_zero));
        let last = *trace.last().unwrap();
        if cur > last {
            // Revert and shrink.
            m = prev;
            scale *= 0.5;
            trace.push(last);
            if scale < 1e-6 {
                break;
            }
            continue;
        }
        scale = (scale * 1.2).min(1.0);
        trace.push(cur);
        if moved < 1e-9 {
            break;
        }
    }
    (Levels::from_mags(m, has_zero), trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Mixture, TruncNormal};

    fn dist() -> Mixture {
        Mixture::new(
            vec![TruncNormal::unit(0.01, 0.015), TruncNormal::unit(0.05, 0.04)],
            vec![2.0, 1.0],
        )
    }

    #[test]
    fn gd_improves_psi() {
        let d = dist();
        let init = Levels::uniform(4);
        let (adapted, trace) = optimize_traced(&d, &init, GdOptions::default());
        assert!(
            trace.last().unwrap() < &(trace[0] * 0.8),
            "GD should improve: {trace:?}"
        );
        assert!(adapted.mags().windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn gd_maintains_feasibility_every_step() {
        let d = dist();
        let mut m = Levels::uniform(8).mags().to_vec();
        for t in 0..100 {
            step(&d, &mut m, true, 50.0 / (1.0 + t as f64 * 0.1));
            assert!(
                m.windows(2).all(|w| w[0] < w[1]),
                "infeasible at t={t}: {m:?}"
            );
            assert_eq!(m[0], 0.0);
            assert_eq!(*m.last().unwrap(), 1.0);
        }
    }

    #[test]
    fn gd_approaches_cd_fixed_point() {
        let d = dist();
        let init = Levels::exponential(4, 0.5);
        let gd = optimize(
            &d,
            &init,
            GdOptions {
                steps: 5000,
                eta0: 100.0,
                decay: 0.002,
            },
        );
        let (cd, _) = super::super::alq::optimize(&d, &init, Default::default());
        let psi_gd = psi(&d, &gd);
        let psi_cd = psi(&d, &cd);
        // Same local basin from the same init; GD's safeguarded steps
        // converge more slowly (exactly the Fig. 8 observation), so allow
        // a modest remaining gap.
        assert!(
            (psi_gd - psi_cd).abs() / psi_cd < 0.15,
            "GD {psi_gd} vs CD {psi_cd}"
        );
    }

    #[test]
    fn gd_works_on_amq_levels() {
        let d = dist();
        let init = Levels::amq(4, 0.5);
        let (adapted, trace) = optimize_traced(&d, &init, GdOptions::default());
        assert!(!adapted.has_zero());
        assert!(trace.last().unwrap() <= &trace[0]);
    }
}
