//! PJRT-backed training tasks: the L2 JAX models (AOT HLO artifacts)
//! driven through the [`TrainTask`] interface, so the same cluster
//! simulation and coordinator run either the pure-Rust MLP or the
//! compiled transformer LM / MLP with zero Python on the path.

use super::{init, EvalResult, TrainTask};
use crate::data::Corpus;
use crate::runtime::{EvalStep, Manifest, ModelEntry, Runtime, TrainStep};
use crate::util::Rng;
use anyhow::Result;

/// Transformer LM on the synthetic Markov corpus, executed via PJRT.
pub struct HloLmTask {
    entry: ModelEntry,
    step: TrainStep,
    eval: EvalStep,
    corpus: Corpus,
    batch: usize,
    seq: usize,
    /// Fixed eval batches (worker-independent).
    eval_batches: Vec<Vec<i32>>,
}

impl HloLmTask {
    pub fn load(rt: &Runtime, manifest: &Manifest, model: &str, corpus_seed: u64) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        anyhow::ensure!(entry.kind == "lm", "{model} is not an lm");
        let batch = entry.cfg("batch");
        let seq = entry.cfg("seq_len");
        let vocab = entry.cfg("vocab");
        let corpus = Corpus::new(vocab, 4, corpus_seed);
        let eval_batches = (0..4)
            .map(|i| corpus.batch(usize::MAX - 1, 1_000_000 + i, batch, seq))
            .collect();
        Ok(HloLmTask {
            step: TrainStep::load(rt, &entry)?,
            eval: EvalStep::load(rt, &entry)?,
            entry,
            corpus,
            batch,
            seq,
            eval_batches,
        })
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }
}

impl TrainTask for HloLmTask {
    fn param_count(&self) -> usize {
        self.entry.param_count
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        init::init_flat(&self.entry.layout, seed)
    }

    fn grad(&mut self, params: &[f32], worker: usize, step: usize, out: &mut [f32]) -> f32 {
        let tokens = self.corpus.batch(worker, step, self.batch, self.seq);
        let (loss, grads) = self
            .step
            .run_lm(params, &tokens)
            .expect("lm train step failed");
        out.copy_from_slice(&grads);
        loss
    }

    fn eval(&mut self, params: &[f32]) -> EvalResult {
        let mut loss = 0.0f64;
        for b in &self.eval_batches {
            loss += self.eval.run_lm(params, b).expect("lm eval failed") as f64;
        }
        EvalResult {
            loss: loss / self.eval_batches.len() as f64,
            accuracy: 0.0,
        }
    }
}

/// HLO MLP on synthetic blobs (cross-checks the pure-Rust path).
pub struct HloMlpTask {
    entry: ModelEntry,
    step: TrainStep,
    eval: EvalStep,
    blobs: crate::data::Blobs,
    batch: usize,
    workers: usize,
    seed: u64,
    xbuf: Vec<f32>,
    ybuf: Vec<u32>,
}

impl HloMlpTask {
    pub fn load(
        rt: &Runtime,
        manifest: &Manifest,
        model: &str,
        workers: usize,
        data_seed: u64,
    ) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        anyhow::ensure!(entry.kind == "mlp", "{model} is not an mlp");
        let batch = entry.cfg("batch");
        let blobs = crate::data::Blobs::generate(
            entry.cfg("input_dim"),
            entry.cfg("classes"),
            8192,
            entry.cfg("batch"), // eval set size = one device batch
            0.8,
            data_seed,
        );
        Ok(HloMlpTask {
            step: TrainStep::load(rt, &entry)?,
            eval: EvalStep::load(rt, &entry)?,
            entry,
            blobs,
            batch,
            workers,
            seed: data_seed ^ 0x51ED,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        })
    }
}

impl TrainTask for HloMlpTask {
    fn param_count(&self) -> usize {
        self.entry.param_count
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        init::init_flat(&self.entry.layout, seed)
    }

    fn grad(&mut self, params: &[f32], worker: usize, step: usize, out: &mut [f32]) -> f32 {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (step as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        self.blobs.sample_train_shard(
            worker,
            self.workers,
            self.batch,
            &mut rng,
            &mut self.xbuf,
            &mut self.ybuf,
        );
        let y: Vec<i32> = self.ybuf.iter().map(|&v| v as i32).collect();
        let (loss, grads) = self
            .step
            .run_mlp(params, &self.xbuf, &y)
            .expect("mlp train step failed");
        out.copy_from_slice(&grads);
        loss
    }

    fn eval(&mut self, params: &[f32]) -> EvalResult {
        let (x, y) = self.blobs.val_set();
        let y: Vec<i32> = y.iter().map(|&v| v as i32).collect();
        let (loss, acc) = self
            .eval
            .run_mlp(params, &x[..self.batch * self.entry.cfg("input_dim")], &y[..self.batch])
            .expect("mlp eval failed");
        EvalResult {
            loss: loss as f64,
            accuracy: acc as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::trace::warn("artifacts", "skipping: no artifacts");
            return None;
        }
        Some((Runtime::cpu().unwrap(), Manifest::load(&dir).unwrap()))
    }

    #[test]
    fn hlo_mlp_task_trains() {
        let Some((rt, m)) = setup() else { return };
        let mut task = HloMlpTask::load(&rt, &m, "mlp_tiny", 2, 3).unwrap();
        let mut params = task.init_params(1);
        let mut g = vec![0.0f32; task.param_count()];
        let l0 = task.grad(&params, 0, 0, &mut g);
        assert!(l0.is_finite());
        for step in 0..40 {
            task.grad(&params, 0, step, &mut g);
            for (p, gv) in params.iter_mut().zip(&g) {
                *p -= 0.1 * gv;
            }
        }
        let l1 = task.grad(&params, 0, 999, &mut g);
        assert!(l1 < l0, "{l0} -> {l1}");
    }

    #[test]
    fn hlo_mlp_matches_rust_mlp_gradients() {
        // The HLO MLP and the pure-Rust MLP share layout + math: same
        // params + batch must give (near-)identical loss and gradients.
        let Some((rt, m)) = setup() else { return };
        let entry = m.model("mlp_tiny").unwrap();
        let dims = vec![
            entry.cfg("input_dim"),
            32,
            32,
            entry.cfg("classes"),
        ];
        let rust_mlp = crate::model::Mlp::new(dims);
        assert_eq!(rust_mlp.param_count(), entry.param_count);

        let params = init::init_flat(&entry.layout, 5);
        let mut rng = Rng::new(6);
        let b = entry.cfg("batch");
        let d = entry.cfg("input_dim");
        let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let y: Vec<u32> = (0..b).map(|_| rng.below(entry.cfg("classes")) as u32).collect();
        let yi: Vec<i32> = y.iter().map(|&v| v as i32).collect();

        let step = TrainStep::load(&rt, entry).unwrap();
        let (hlo_loss, hlo_grads) = step.run_mlp(&params, &x, &yi).unwrap();

        let mut scratch = crate::model::mlp::Scratch::default();
        let mut rust_grads = vec![0.0f32; rust_mlp.param_count()];
        let rust_loss = rust_mlp.loss_grad(&params, &x, &y, &mut rust_grads, &mut scratch);

        assert!(
            (hlo_loss - rust_loss).abs() < 1e-5,
            "loss {hlo_loss} vs {rust_loss}"
        );
        let max_err = hlo_grads
            .iter()
            .zip(&rust_grads)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "max grad err {max_err}");
    }

    #[test]
    fn hlo_lm_task_grad_and_eval() {
        let Some((rt, m)) = setup() else { return };
        let mut task = HloLmTask::load(&rt, &m, "lm_tiny", 11).unwrap();
        let params = task.init_params(2);
        let mut g = vec![0.0f32; task.param_count()];
        let loss = task.grad(&params, 0, 0, &mut g);
        // Fresh LM ≈ uniform over vocab.
        let vocab = task.entry().cfg("vocab") as f64;
        assert!((loss as f64 - vocab.ln()).abs() < 1.0, "loss {loss}");
        assert!(g.iter().any(|&x| x != 0.0));
        let ev = task.eval(&params);
        assert!(ev.loss.is_finite());
    }
}
