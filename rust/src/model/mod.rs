//! Host-side models.
//!
//! * [`init`] — flat-parameter initialization over a manifest layout
//!   (mirrors `python/compile/model.py::init_flat` semantics).
//! * [`mlp`] — a pure-Rust MLP with manual backprop. This is the fast
//!   substrate behind the many sweep experiments (Table 1/2, Figs. 3–7
//!   run hundreds of training jobs — far too many for CPU-PJRT), and it
//!   is cross-checked against the HLO MLP on identical params/batches.
//!
//! The [`TrainTask`] trait is what the cluster simulation and the
//! coordinator drive; both the Rust MLP and the PJRT-backed models
//! implement it.

pub mod hlo_task;
pub mod init;
pub mod mlp;

pub use hlo_task::{HloLmTask, HloMlpTask};
pub use mlp::{Mlp, MlpTask};

/// Evaluation summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
}

/// A training workload: supplies per-worker gradients and evaluation.
///
/// `grad` must be deterministic in `(params, worker, step)` so distributed
/// replicas stay in lockstep (the coordinator relies on this).
pub trait TrainTask {
    fn param_count(&self) -> usize;
    /// Initialize a fresh flat parameter vector.
    fn init_params(&self, seed: u64) -> Vec<f32>;
    /// Compute the stochastic gradient of worker `worker` at `step` into
    /// `out`; returns the minibatch loss.
    fn grad(&mut self, params: &[f32], worker: usize, step: usize, out: &mut [f32]) -> f32;
    /// Evaluate on the held-out set.
    fn eval(&mut self, params: &[f32]) -> EvalResult;
}
