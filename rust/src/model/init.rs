//! Flat-parameter initialization over a layout (manifest or native).

use crate::runtime::LayoutEntry;
use crate::util::Rng;

/// Initialize a flat parameter vector for a layout. Kinds mirror
/// `python/compile/model.py`: zeros | ones | normal(std) | he(fan_in).
pub fn init_flat(layout: &[LayoutEntry], seed: u64) -> Vec<f32> {
    let total: usize = layout.iter().map(|e| e.size()).sum();
    let mut out = Vec::with_capacity(total);
    for (i, e) in layout.iter().enumerate() {
        let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        match e.init.as_str() {
            "zeros" => out.extend(std::iter::repeat_n(0.0f32, e.size())),
            "ones" => out.extend(std::iter::repeat_n(1.0f32, e.size())),
            "normal" => {
                for _ in 0..e.size() {
                    out.push((rng.normal() * e.std) as f32);
                }
            }
            "he" => {
                let fan_in = e.shape.first().copied().unwrap_or(1) as f64;
                let std = (2.0 / fan_in).sqrt();
                for _ in 0..e.size() {
                    out.push((rng.normal() * std) as f32);
                }
            }
            other => panic!("unknown init kind {other:?} for {}", e.name),
        }
    }
    out
}

/// Native layout for the pure-Rust MLP (same shape conventions as the
/// JAX model so the flat vectors are interchangeable).
pub fn mlp_layout(dims: &[usize]) -> Vec<LayoutEntry> {
    assert!(dims.len() >= 2);
    let mut layout = Vec::new();
    for i in 0..dims.len() - 1 {
        layout.push(LayoutEntry {
            name: format!("fc{i}.w"),
            shape: vec![dims[i], dims[i + 1]],
            init: "he".into(),
            std: 0.0,
        });
        layout.push(LayoutEntry {
            name: format!("fc{i}.b"),
            shape: vec![dims[i + 1]],
            init: "zeros".into(),
            std: 0.0,
        });
    }
    layout
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_determinism() {
        let layout = mlp_layout(&[4, 8, 3]);
        let total: usize = layout.iter().map(|e| e.size()).sum();
        assert_eq!(total, 4 * 8 + 8 + 8 * 3 + 3);
        let a = init_flat(&layout, 7);
        let b = init_flat(&layout, 7);
        let c = init_flat(&layout, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), total);
    }

    #[test]
    fn he_scale() {
        let layout = vec![LayoutEntry {
            name: "w".into(),
            shape: vec![1000, 100],
            init: "he".into(),
            std: 0.0,
        }];
        let v = init_flat(&layout, 1);
        let var: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64;
        let want = 2.0 / 1000.0;
        assert!((var - want).abs() / want < 0.05, "var {var} want {want}");
    }

    #[test]
    fn biases_zero_gains_one() {
        let layout = vec![
            LayoutEntry { name: "b".into(), shape: vec![5], init: "zeros".into(), std: 0.0 },
            LayoutEntry { name: "g".into(), shape: vec![5], init: "ones".into(), std: 0.0 },
        ];
        let v = init_flat(&layout, 0);
        assert_eq!(&v[..5], &[0.0; 5]);
        assert_eq!(&v[5..], &[1.0; 5]);
    }
}
