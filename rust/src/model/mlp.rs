//! Pure-Rust MLP classifier with manual backprop on flat parameters.
//!
//! Matches the JAX `mlp` family layer-for-layer (ReLU hidden layers,
//! softmax cross-entropy, He init, identical flat layout) so parameter
//! vectors are interchangeable with the HLO path; `rust/tests/` pins the
//! two against each other on the same params/batch.

use super::{EvalResult, TrainTask};
use crate::data::synth::Blobs;
use crate::model::init;
use crate::util::Rng;

/// MLP architecture: dims = [input, hidden…, classes].
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
}

impl Mlp {
    pub fn new(dims: Vec<usize>) -> Self {
        assert!(dims.len() >= 2);
        Mlp { dims }
    }

    pub fn param_count(&self) -> usize {
        self.dims
            .windows(2)
            .map(|w| w[0] * w[1] + w[1])
            .sum()
    }

    pub fn layout(&self) -> Vec<crate::runtime::LayoutEntry> {
        init::mlp_layout(&self.dims)
    }

    /// Forward pass into reusable activation buffers.
    /// `acts[l]` holds layer l's post-activation output, `acts[0]` = x.
    fn forward(&self, params: &[f32], x: &[f32], batch: usize, acts: &mut Vec<Vec<f32>>) {
        let nl = self.dims.len() - 1;
        acts.resize(nl + 1, Vec::new());
        acts[0].clear();
        acts[0].extend_from_slice(x);
        let mut off = 0;
        for l in 0..nl {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = &params[off..off + din * dout];
            let b = &params[off + din * dout..off + din * dout + dout];
            off += din * dout + dout;
            let (prev, rest) = acts.split_at_mut(l + 1);
            let inp = &prev[l];
            let out = &mut rest[0];
            out.clear();
            out.resize(batch * dout, 0.0);
            matmul_bias(inp, w, b, out, batch, din, dout);
            if l < nl - 1 {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
    }

    /// Loss + gradient on one minibatch. `grads` is accumulated into
    /// (caller zeroes it); returns mean cross-entropy loss.
    pub fn loss_grad(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
        grads: &mut [f32],
        scratch: &mut Scratch,
    ) -> f32 {
        let batch = y.len();
        let nl = self.dims.len() - 1;
        debug_assert_eq!(x.len(), batch * self.dims[0]);
        debug_assert_eq!(grads.len(), self.param_count());
        self.forward(params, x, batch, &mut scratch.acts);

        // Softmax CE on logits (last activation).
        let c = self.dims[nl];
        let logits = &scratch.acts[nl];
        let delta = &mut scratch.delta;
        delta.clear();
        delta.resize(batch * c, 0.0);
        let mut loss = 0.0f64;
        for i in 0..batch {
            let row = &logits[i * c..(i + 1) * c];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut z = 0.0f64;
            for &v in row {
                z += ((v - max) as f64).exp();
            }
            let logz = z.ln() + max as f64;
            loss += logz - row[y[i] as usize] as f64;
            for j in 0..c {
                let p = (((row[j] - max) as f64).exp() / z) as f32;
                delta[i * c + j] = (p - if j == y[i] as usize { 1.0 } else { 0.0 })
                    / batch as f32;
            }
        }

        // Backward.
        let mut offsets = Vec::with_capacity(nl);
        let mut off = 0;
        for l in 0..nl {
            offsets.push(off);
            off += self.dims[l] * self.dims[l + 1] + self.dims[l + 1];
        }
        for l in (0..nl).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let off = offsets[l];
            let inp = &scratch.acts[l];
            // dW = inp^T @ delta ; db = sum over batch of delta.
            let (gw, gb) = grads[off..off + din * dout + dout].split_at_mut(din * dout);
            for i in 0..batch {
                let d = &scratch.delta[i * dout..(i + 1) * dout];
                let xi = &inp[i * din..(i + 1) * din];
                for a in 0..din {
                    let xa = xi[a];
                    if xa == 0.0 {
                        continue;
                    }
                    let gwa = &mut gw[a * dout..(a + 1) * dout];
                    for (g, &dv) in gwa.iter_mut().zip(d) {
                        *g += xa * dv;
                    }
                }
                for (g, &dv) in gb.iter_mut().zip(d) {
                    *g += dv;
                }
            }
            if l > 0 {
                // delta_prev = (delta @ W^T) * relu'(act_prev)
                let w = &params[off..off + din * dout];
                let prev = &mut scratch.delta_prev;
                prev.clear();
                prev.resize(batch * din, 0.0);
                for i in 0..batch {
                    let d = &scratch.delta[i * dout..(i + 1) * dout];
                    let pr = &mut prev[i * din..(i + 1) * din];
                    for a in 0..din {
                        let mut acc = 0.0f32;
                        let wa = &w[a * dout..(a + 1) * dout];
                        for (wv, dv) in wa.iter().zip(d) {
                            acc += wv * dv;
                        }
                        pr[a] = acc;
                    }
                }
                // ReLU mask from forward activations.
                let act = &scratch.acts[l];
                for (p, &a) in prev.iter_mut().zip(act) {
                    if a == 0.0 {
                        *p = 0.0;
                    }
                }
                std::mem::swap(&mut scratch.delta, &mut scratch.delta_prev);
            }
        }
        (loss / batch as f64) as f32
    }

    /// Predicted class per row.
    pub fn predict(&self, params: &[f32], x: &[f32], batch: usize, scratch: &mut Scratch) -> Vec<u32> {
        self.forward(params, x, batch, &mut scratch.acts);
        let c = *self.dims.last().unwrap();
        let logits = &scratch.acts[self.dims.len() - 1];
        (0..batch)
            .map(|i| {
                let row = &logits[i * c..(i + 1) * c];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as u32
            })
            .collect()
    }

    /// Mean loss + accuracy over a dataset.
    pub fn evaluate(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
        scratch: &mut Scratch,
    ) -> EvalResult {
        let batch = y.len();
        self.forward(params, x, batch, &mut scratch.acts);
        let c = *self.dims.last().unwrap();
        let logits = &scratch.acts[self.dims.len() - 1];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..batch {
            let row = &logits[i * c..(i + 1) * c];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut z = 0.0f64;
            let mut argmax = 0;
            for (j, &v) in row.iter().enumerate() {
                z += ((v - max) as f64).exp();
                if v > row[argmax] {
                    argmax = j;
                }
            }
            loss += z.ln() + max as f64 - row[y[i] as usize] as f64;
            if argmax == y[i] as usize {
                correct += 1;
            }
        }
        EvalResult {
            loss: loss / batch as f64,
            accuracy: correct as f64 / batch as f64,
        }
    }
}

fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], batch: usize, din: usize, dout: usize) {
    // out[i, j] = sum_a x[i, a] w[a, j] + b[j]; ikj loop order for locality.
    for i in 0..batch {
        let o = &mut out[i * dout..(i + 1) * dout];
        o.copy_from_slice(b);
        let xi = &x[i * din..(i + 1) * din];
        for (a, &xa) in xi.iter().enumerate() {
            if xa == 0.0 {
                continue;
            }
            let wa = &w[a * dout..(a + 1) * dout];
            for (ov, &wv) in o.iter_mut().zip(wa) {
                *ov += xa * wv;
            }
        }
    }
}

/// Reusable backprop buffers (no allocation in the training loop).
#[derive(Default)]
pub struct Scratch {
    acts: Vec<Vec<f32>>,
    delta: Vec<f32>,
    delta_prev: Vec<f32>,
}

// ---------------------------------------------------------------------------
// MlpTask: MLP + synthetic blobs dataset as a TrainTask.
// ---------------------------------------------------------------------------

/// The CIFAR-stand-in workload: MLP on Gaussian blobs, sharded over M
/// workers (each worker draws batches from its own shard, mirroring
/// data-parallel training).
pub struct MlpTask {
    pub mlp: Mlp,
    pub blobs: Blobs,
    pub batch: usize,
    pub workers: usize,
    seed: u64,
    scratch: Scratch,
    xbuf: Vec<f32>,
    ybuf: Vec<u32>,
}

impl MlpTask {
    pub fn new(mlp: Mlp, blobs: Blobs, batch: usize, workers: usize, seed: u64) -> Self {
        assert_eq!(mlp.dims[0], blobs.dim);
        assert_eq!(*mlp.dims.last().unwrap(), blobs.classes);
        MlpTask {
            mlp,
            blobs,
            batch,
            workers,
            seed,
            scratch: Scratch::default(),
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        }
    }
}

impl TrainTask for MlpTask {
    fn param_count(&self) -> usize {
        self.mlp.param_count()
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        init::init_flat(&self.mlp.layout(), seed)
    }

    fn grad(&mut self, params: &[f32], worker: usize, step: usize, out: &mut [f32]) -> f32 {
        out.fill(0.0);
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (step as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        self.blobs.sample_train_shard(
            worker,
            self.workers,
            self.batch,
            &mut rng,
            &mut self.xbuf,
            &mut self.ybuf,
        );
        self.mlp
            .loss_grad(params, &self.xbuf, &self.ybuf, out, &mut self.scratch)
    }

    fn eval(&mut self, params: &[f32]) -> EvalResult {
        let (x, y) = self.blobs.val_set();
        self.mlp.evaluate(params, x, y, &mut self.scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Mlp, Vec<f32>, Vec<f32>, Vec<u32>) {
        let mlp = Mlp::new(vec![6, 10, 4]);
        let mut rng = Rng::new(1);
        let params = init::init_flat(&mlp.layout(), 2);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 6).map(|_| rng.normal() as f32).collect();
        let y: Vec<u32> = (0..batch).map(|_| rng.below(4) as u32).collect();
        (mlp, params, x, y)
    }

    #[test]
    fn param_count() {
        let mlp = Mlp::new(vec![6, 10, 4]);
        assert_eq!(mlp.param_count(), 6 * 10 + 10 + 10 * 4 + 4);
    }

    #[test]
    fn grads_match_finite_difference() {
        let (mlp, mut params, x, y) = tiny();
        let mut scratch = Scratch::default();
        let mut grads = vec![0.0f32; mlp.param_count()];
        mlp.loss_grad(&params, &x, &y, &mut grads, &mut scratch);
        let mut rng = Rng::new(3);
        for _ in 0..12 {
            let i = rng.below(params.len());
            let eps = 1e-3f32;
            let orig = params[i];
            params[i] = orig + eps;
            let mut g = vec![0.0f32; mlp.param_count()];
            let lp = mlp.loss_grad(&params, &x, &y, &mut g, &mut scratch);
            params[i] = orig - eps;
            let lm = mlp.loss_grad(&params, &x, &y, &mut g, &mut scratch);
            params[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grads[i]).abs() < 2e-3,
                "param {i}: fd {fd} vs grad {}",
                grads[i]
            );
        }
    }

    #[test]
    fn loss_decreases_under_sgd() {
        let (mlp, mut params, x, y) = tiny();
        let mut scratch = Scratch::default();
        let mut grads = vec![0.0f32; mlp.param_count()];
        grads.fill(0.0);
        let l0 = mlp.loss_grad(&params, &x, &y, &mut grads, &mut scratch);
        for _ in 0..60 {
            grads.fill(0.0);
            mlp.loss_grad(&params, &x, &y, &mut grads, &mut scratch);
            for (p, g) in params.iter_mut().zip(&grads) {
                *p -= 0.2 * g;
            }
        }
        grads.fill(0.0);
        let l1 = mlp.loss_grad(&params, &x, &y, &mut grads, &mut scratch);
        assert!(l1 < 0.3 * l0, "{l0} -> {l1}");
    }

    #[test]
    fn evaluate_consistent_with_predict() {
        let (mlp, params, x, y) = tiny();
        let mut scratch = Scratch::default();
        let ev = mlp.evaluate(&params, &x, &y, &mut scratch);
        let preds = mlp.predict(&params, &x, y.len(), &mut scratch);
        let acc = preds
            .iter()
            .zip(&y)
            .filter(|(a, b)| a == b)
            .count() as f64
            / y.len() as f64;
        assert!((ev.accuracy - acc).abs() < 1e-12);
        assert!(ev.loss > 0.0);
    }

    #[test]
    fn task_runs_and_workers_get_distinct_batches() {
        let blobs = Blobs::generate(6, 4, 400, 100, 0.5, 11);
        let mlp = Mlp::new(vec![6, 16, 4]);
        let mut task = MlpTask::new(mlp, blobs, 8, 4, 5);
        let params = task.init_params(1);
        let mut g0 = vec![0.0f32; task.param_count()];
        let mut g1 = vec![0.0f32; task.param_count()];
        let l0 = task.grad(&params, 0, 0, &mut g0);
        let l1 = task.grad(&params, 1, 0, &mut g1);
        assert!(l0.is_finite() && l1.is_finite());
        assert_ne!(g0, g1, "different workers → different shards");
        // Determinism in (worker, step).
        let mut g0b = vec![0.0f32; task.param_count()];
        task.grad(&params, 0, 0, &mut g0b);
        assert_eq!(g0, g0b);
    }

    #[test]
    fn training_improves_validation_accuracy() {
        let blobs = Blobs::generate(8, 4, 2000, 400, 1.0, 13);
        let mlp = Mlp::new(vec![8, 32, 4]);
        let mut task = MlpTask::new(mlp, blobs, 32, 1, 7);
        let mut params = task.init_params(3);
        let before = task.eval(&params).accuracy;
        let mut grads = vec![0.0f32; task.param_count()];
        for step in 0..300 {
            task.grad(&params, 0, step, &mut grads);
            for (p, g) in params.iter_mut().zip(&grads) {
                *p -= 0.1 * g;
            }
        }
        let after = task.eval(&params).accuracy;
        assert!(
            after > before + 0.2 && after > 0.7,
            "val acc {before} -> {after}"
        );
    }
}
