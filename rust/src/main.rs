//! `aqsgd` — CLI for the Adaptive Gradient Quantization reproduction.
//!
//! Subcommands:
//!   train   — one data-parallel training run (simulated cluster)
//!   exp     — regenerate a paper table/figure (see `aqsgd exp list`)
//!   leader  — start a distributed leader (TCP)
//!   worker  — start a distributed worker (TCP)
//!   inspect — validate + describe the AOT artifacts
//!
//! Hand-rolled argument parsing: the offline image vendors only the `xla`
//! crate closure, so no clap.

use anyhow::{bail, Context, Result};
use aqsgd::config::RunConfig;
use aqsgd::coordinator::{run_leader_traced, run_worker_traced, LeaderConfig, WorkerConfig};
use aqsgd::exp;
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::runtime::{Manifest, Runtime};
use aqsgd::sim::Cluster;
use aqsgd::trace::{self, summary::TraceSummary, TraceSpec, Tracer};

const USAGE: &str = "\
aqsgd — Adaptive Gradient Quantization for Data-Parallel SGD (NeurIPS 2020)

USAGE:
  aqsgd train [--method ALQ] [--workers 4] [--bits 3] [--bucket 8192]
              [--iters 3000] [--seed 1] [--model mlp] [--parallel auto|on|off]
              [--pipeline off|overlap|stale:1]
              [--topology flat|sharded:S|tree:G|ring] [--codec huffman|elias]
              [--bits-policy fixed:B|schedule:B1@s1,B2@s2,...|variance[:MIN-MAX[@T]]]
              [--quantize-impl scalar|fast|pallas]
              [--faults kill:W@S,delay:W@S:MS,join:W@S|none]
              [--error-feedback on|off] [--lazy off|thresh:T|laq:C@K]
              [--trace PATH[:warn|info|debug]]
              (--parallel fans out flat/sharded/tree lanes, bit-identical
               to serial; the ring schedule is inherently serial.
               --pipeline overlaps communication: overlap hides wire time
               behind encode inside a step (bit-identical to off);
               stale:1 computes step t+1 while step t's exchange lands,
               applying aggregates one step late.
               --bits-policy moves the quantization width per step:
               fixed:B ≡ --bits B, schedule switches at the listed steps,
               variance tracks the quantization-variance estimate.
               --quantize-impl picks the lane quantizer: scalar reference,
               the bit-identical vectorized fast path (default), or the
               Pallas kernel via PJRT, falling back to fast when absent.
               --error-feedback keeps each worker's decode error as a
               residual added before the next quantization (not over
               ring, whose stages re-quantize partials); --lazy lets a
               worker send a 104-bit skip marker instead of a frame:
               thresh:T skips while ‖msg‖₂ < T, laq:C@K skips while the
               change against the last-sent reference stays under C×
               its norm², at most K skips in a row)
  aqsgd exp <id> [--full] [--seeds N] [--iters N]     (exp list → all ids)
  aqsgd leader --bind 127.0.0.1:7700 --world 4 --iters 500
              [--topology flat|sharded:S|tree:G]
              [--deadline-ms 5000] [--retries 3]
              [--trace PATH[:warn|info|debug]]
              (--deadline-ms/--retries tune timeout-and-drop: a worker
               missing its per-frame deadline is retried with doubled
               deadlines, then dropped; survivors renormalize to a
               weighted partial aggregate. --deadline-ms 0 blocks forever.
               skip markers from --lazy workers need no leader flag:
               the relay counts them, renormalizes the senders' weights,
               and emits a `skip` trace event per marker)
  aqsgd worker --addr 127.0.0.1:7700 --worker 0 --world 4 --iters 500
              [--method ALQ --bits 3 --bucket 512 --seed 42]
              [--topology flat|sharded:S|tree:G] [--codec huffman|elias]
              [--pipeline off|overlap]
              [--bits-policy ...] [--quantize-impl scalar|fast|pallas]
              [--faults kill:W@S,delay:W@S:MS,join:W@S|none]
              [--error-feedback on|off] [--lazy off|thresh:T|laq:C@K]
              [--trace PATH[:warn|info|debug]]
              (frames carry their width, so the leader relay needs no
               flag and no extra round-trip; --pipeline overlap hands
               frame k to a sender thread while shard k+1 encodes —
               byte-identical frames in identical order; --faults is the
               shared deterministic churn script — each worker acts only
               on its own entries)
  aqsgd trace-summarize FILE [--json PATH]
              (validate a --trace JSONL file against the event schema
               and fold it into per-phase/per-hop/per-width tables;
               --json writes the machine-readable summary document)
  aqsgd inspect [--artifacts DIR]
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args[1..]),
        Some("exp") => cmd_exp(&args[1..]),
        Some("leader") => cmd_leader(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("trace-summarize") => cmd_trace_summarize(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    println!(
        "training: method={} workers={} bits-policy={} bucket={} iters={} model={} exchange={} \
         topology={} codec={}",
        cfg.method,
        cfg.workers,
        cfg.effective_bits_policy(),
        cfg.bucket,
        cfg.iters,
        cfg.model,
        cfg.parallel.name(),
        cfg.topology.name(),
        cfg.codec.name()
    );
    if cfg.quantize_impl != aqsgd::quant::QuantizeImpl::default() {
        println!("  quantize-impl={}", cfg.quantize_impl.name());
    }
    if cfg.pipeline != aqsgd::exchange::PipelineMode::Off {
        println!("  pipeline={}", cfg.pipeline.name());
    }
    if cfg.error_feedback || !cfg.lazy.is_off() {
        println!(
            "  error-feedback={} lazy={}",
            if cfg.error_feedback { "on" } else { "off" },
            cfg.lazy.name()
        );
    }
    if cfg.model != "mlp" {
        bail!("`train` runs the pure-Rust blobs task; for HLO models see examples/train_lm.rs");
    }
    // One tracer shared across the seed loop: each seed's run_start
    // event marks the run boundary in the JSONL stream.
    let tracer = open_tracer(cfg.trace.as_ref())?;
    let spec = aqsgd::exp::common::ModelSpec::resnet32_standin();
    let mut accs = Vec::new();
    for seed in 0..cfg.seeds as u64 {
        let mut ccfg = cfg.cluster();
        ccfg.seed = cfg.seed + seed;
        ccfg.bucket = cfg.bucket.min(spec.param_count() / 2);
        let mut task = spec.task(cfg.workers, cfg.seed + seed);
        let mut cluster = Cluster::new(ccfg);
        cluster.set_tracer(tracer.clone());
        let rec = cluster.train(&mut task);
        println!(
            "  seed {}: val acc {:.4}, val loss {:.4}, bits/step {:.0}, levels {:?}",
            seed,
            rec.final_eval.accuracy,
            rec.final_eval.loss,
            rec.comm_bits as f64 / rec.steps.len() as f64,
            rec.final_levels
                .as_ref()
                .map(|l| l.iter().map(|x| (x * 1e4).round() / 1e4).collect::<Vec<_>>())
        );
        if rec.skipped_frames > 0 {
            println!(
                "    skipped frames: {} ({} marker bits)",
                rec.skipped_frames,
                rec.skipped_frames * aqsgd::exchange::SKIP_MARKER_BITS
            );
        }
        accs.push(rec.final_eval.accuracy);
    }
    let (m, s) = aqsgd::metrics::mean_std(&accs);
    println!("mean val acc: {}", aqsgd::metrics::pct(m, s));
    Ok(())
}

fn cmd_exp(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        None | Some("list") => {
            println!("experiments:");
            for (id, desc) in exp::EXPERIMENTS {
                println!("  {id:<8} {desc}");
            }
            Ok(())
        }
        Some(id) => exp::run(id, &args[1..]),
    }
}

fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Open the `--trace` sink (disabled when absent) and install it as the
/// process-global warning tracer so library degradations land in the
/// trace file too.
fn open_tracer(spec: Option<&TraceSpec>) -> Result<Tracer> {
    match spec {
        Some(spec) => {
            let t = spec.tracer()?;
            trace::install_global(t.clone());
            println!("  tracing → {} (level {})", spec.path, spec.level.name());
            Ok(t)
        }
        None => Ok(Tracer::disabled()),
    }
}

/// Parse an optional `--trace PATH[:level]` flag (leader/worker CLIs).
fn parse_trace_flag(args: &[String]) -> Result<Option<TraceSpec>> {
    match flag(args, "--trace") {
        Some(v) => Ok(Some(TraceSpec::parse(v).with_context(|| {
            format!("bad --trace {v:?} (PATH[:warn|info|debug])")
        })?)),
        None => Ok(None),
    }
}

fn cmd_trace_summarize(args: &[String]) -> Result<()> {
    let Some(file) = args.first().filter(|a| !a.starts_with("--")) else {
        bail!("usage: aqsgd trace-summarize FILE [--json PATH]");
    };
    let text = std::fs::read_to_string(file).with_context(|| format!("reading {file:?}"))?;
    let summary = TraceSummary::from_jsonl(&text)
        .map_err(|e| anyhow::anyhow!("invalid trace {file:?}: {e}"))?;
    println!(
        "{file}: {} events, {} steps, {} warnings",
        summary.events,
        summary.steps.len(),
        summary.warnings.len()
    );
    for table in summary.tables() {
        println!("\n{}", table.to_markdown());
    }
    if !summary.hop_bits_mismatches.is_empty() {
        for m in &summary.hop_bits_mismatches {
            eprintln!("hop/step bit mismatch: {m}");
        }
        bail!(
            "{} step(s) whose hop bits do not sum to the step total",
            summary.hop_bits_mismatches.len()
        );
    }
    if let Some(path) = flag(args, "--json") {
        std::fs::write(path, format!("{}\n", summary.to_json()))
            .with_context(|| format!("writing {path:?}"))?;
        println!("summary JSON → {path}");
    }
    Ok(())
}

fn parse_wire_topology(args: &[String]) -> Result<aqsgd::exchange::TopologySpec> {
    use aqsgd::exchange::TopologySpec;
    let topology = match flag(args, "--topology") {
        Some(v) => TopologySpec::parse(v)
            .with_context(|| format!("bad --topology {v:?} (flat|sharded:S|tree:G)"))?,
        None => TopologySpec::Flat,
    };
    if topology == TopologySpec::Ring {
        bail!("--topology ring is a simulation schedule; the TCP runtime supports flat|sharded:S|tree:G");
    }
    Ok(topology)
}

fn cmd_leader(args: &[String]) -> Result<()> {
    let defaults = aqsgd::coordinator::ElasticPolicy::default();
    let elastic = aqsgd::coordinator::ElasticPolicy {
        deadline_ms: match flag(args, "--deadline-ms") {
            Some(v) => v.parse().context("bad --deadline-ms")?,
            None => defaults.deadline_ms,
        },
        retries: match flag(args, "--retries") {
            Some(v) => v.parse().context("bad --retries")?,
            None => defaults.retries,
        },
    };
    let cfg = LeaderConfig {
        bind: flag(args, "--bind").unwrap_or("127.0.0.1:7700").to_string(),
        world: flag(args, "--world").unwrap_or("4").parse()?,
        steps: flag(args, "--iters").unwrap_or("500").parse()?,
        topology: parse_wire_topology(args)?,
        elastic,
    };
    println!(
        "leader on {} (world {}, {} steps, topology {}, deadline {}ms × {} retries)",
        cfg.bind,
        cfg.world,
        cfg.steps,
        cfg.topology.name(),
        cfg.elastic.deadline_ms,
        cfg.elastic.retries
    );
    let tracer = open_tracer(parse_trace_flag(args)?.as_ref())?;
    let bits = run_leader_traced(&cfg, &tracer)?;
    println!("relayed {bits} payload bits");
    Ok(())
}

fn cmd_worker(args: &[String]) -> Result<()> {
    let iters: usize = flag(args, "--iters").unwrap_or("500").parse()?;
    let method = aqsgd::quant::Method::parse(flag(args, "--method").unwrap_or("ALQ"))
        .context("bad --method")?;
    let codec = match flag(args, "--codec") {
        Some(v) => aqsgd::quant::Codec::parse(v)
            .with_context(|| format!("bad --codec {v:?} (huffman|elias)"))?,
        None => aqsgd::quant::Codec::Huffman,
    };
    let quantize_impl = match flag(args, "--quantize-impl") {
        Some(v) => aqsgd::quant::QuantizeImpl::parse(v)
            .with_context(|| format!("bad --quantize-impl {v:?} (scalar|fast|pallas)"))?,
        None => aqsgd::quant::QuantizeImpl::default(),
    };
    let bits: u32 = flag(args, "--bits").unwrap_or("3").parse()?;
    let bits_policy = match flag(args, "--bits-policy") {
        Some(v) => aqsgd::exchange::BitsPolicy::parse(v).with_context(|| {
            format!(
                "bad --bits-policy {v:?} \
                 (fixed:B | schedule:B1@s1,B2@s2,... | variance[:MIN-MAX[@T]])"
            )
        })?,
        None => aqsgd::exchange::BitsPolicy::Fixed(bits),
    };
    // Same validations the train path applies in RunConfig::validate —
    // fail before connecting rather than panicking mid-handshake. (The
    // zero level is a property of the method's level family, so one
    // width answers for every width the policy can reach.)
    if !bits_policy.is_fixed()
        && method.is_quantized()
        && method.effective_bits(2) == method.effective_bits(8)
    {
        bail!(
            "--bits-policy {} has no effect for {method}: its level family ignores the \
             bit width (always ternary); use --bits B / fixed:B",
            bits_policy.name()
        );
    }
    if codec == aqsgd::quant::Codec::Elias {
        if let Some(levels) = method.initial_levels(bits_policy.initial_bits()) {
            if !levels.has_zero() {
                bail!(
                    "--codec elias needs a zero level to run-length over; \
                     {method} uses a no-zero level family (keep --codec huffman)"
                );
            }
        }
    }
    let pipeline = match flag(args, "--pipeline") {
        Some(v) => {
            let p = aqsgd::exchange::PipelineMode::parse(v)
                .with_context(|| format!("bad --pipeline {v:?} (off|overlap)"))?;
            // Same parse-time transport check RunConfig::validate runs
            // for the sim (tcp = false there).
            aqsgd::config::validate_pipeline_transport(p, true)
                .map_err(|e| anyhow::anyhow!(e))?;
            p
        }
        None => aqsgd::exchange::PipelineMode::Off,
    };
    let error_feedback = match flag(args, "--error-feedback") {
        Some(v) => match v.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            _ => bail!("bad --error-feedback {v:?} (on|off)"),
        },
        None => false,
    };
    let lazy = match flag(args, "--lazy") {
        Some(v) => aqsgd::exchange::LazyPolicy::parse_strict(v)
            .map_err(|e| anyhow::anyhow!("bad --lazy: {e}"))?,
        None => aqsgd::exchange::LazyPolicy::Off,
    };
    let faults = match flag(args, "--faults") {
        Some(v) => aqsgd::sim::FaultPlan::parse(v).map_err(|e| {
            anyhow::anyhow!(
                "bad --faults {v:?}: {e} \
                 (kill:W@S | delay:W@S:MS | join:W@S, comma-separated, or 'none')"
            )
        })?,
        None => aqsgd::sim::FaultPlan::default(),
    };
    let cfg = WorkerConfig {
        addr: flag(args, "--addr").unwrap_or("127.0.0.1:7700").to_string(),
        worker: flag(args, "--worker").unwrap_or("0").parse()?,
        world: flag(args, "--world").unwrap_or("4").parse()?,
        method,
        bits: bits_policy,
        bucket: flag(args, "--bucket").unwrap_or("512").parse()?,
        iters,
        lr: LrSchedule::paper_default(0.1, iters),
        updates: UpdateSchedule::paper_default(iters),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: flag(args, "--seed").unwrap_or("42").parse()?,
        topology: parse_wire_topology(args)?,
        codec,
        quantize_impl,
        pipeline,
        faults,
        error_feedback,
        lazy,
    };
    if let Err(e) = cfg.faults.validate(cfg.world) {
        bail!("bad --faults: {e}");
    }
    let spec = aqsgd::exp::common::ModelSpec::resnet32_standin();
    let mut task = spec.task(cfg.world, 7);
    println!("worker {}/{} → {}", cfg.worker, cfg.world, cfg.addr);
    if cfg.error_feedback || !cfg.lazy.is_off() {
        println!(
            "  error-feedback={} lazy={}",
            if cfg.error_feedback { "on" } else { "off" },
            cfg.lazy.name()
        );
    }
    let tracer = open_tracer(parse_trace_flag(args)?.as_ref())?;
    let report = run_worker_traced(&cfg, &mut task, &tracer)?;
    println!(
        "done: val acc {:.4}, params hash {:016x}, sent {} bits, {} level updates",
        report.final_eval.accuracy, report.params_hash, report.sent_bits, report.level_updates
    );
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let dir = flag(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let m = Manifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    println!("artifacts in {dir:?} (PJRT platform: {})", rt.platform());
    println!("\nmodels:");
    for (name, e) in &m.models {
        println!(
            "  {name:<10} kind={} params={} layout tensors={} goldens={}",
            e.kind,
            e.param_count,
            e.layout.len(),
            e.goldens.is_some()
        );
    }
    println!("\nkernel ops:");
    for (name, op) in m.quantize.iter().chain(m.stats.iter()) {
        println!(
            "  {name:<20} n={} bucket={} k={} norm={}",
            op.n, op.bucket, op.k, op.norm_type
        );
    }
    // Compile the tiny ones as a health check.
    let tiny = m.model("mlp_tiny")?;
    rt.compile_hlo_text(&tiny.train_hlo)?;
    println!("\nmlp_tiny.train compiles OK — runtime healthy");
    Ok(())
}
