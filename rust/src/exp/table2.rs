//! Table 2 — scaling to 16 and 32 workers (ResNet-32 stand-in, 3 bits,
//! large bucket). Expected shape: adaptive methods keep tracking
//! SuperSGD; TRN closes much of its gap at M = 32 because the variance of
//! unbiased quantizers averages away with M (paper's observation).

use super::common::{out_dir, run_one, ExpArgs, ModelSpec};
use crate::metrics::{mean_std, pct, Table};
use crate::quant::Method;
use anyhow::Result;

const METHODS: [Method; 7] = [
    Method::SuperSgd,
    Method::NuqSgd,
    Method::QsgdInf,
    Method::Trn,
    Method::Alq,
    Method::AlqN,
    Method::Amq,
];

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 2400 } else { 1200 });
    let bits = 3;
    let spec = ModelSpec::resnet32_standin();
    // Paper uses bucket 16384 here (scaled → 1024).
    let bucket = 1024;
    let worker_counts = [16usize, 32];

    println!(
        "Table 2 — scaling: {} / model {}, {bits} bits, bucket {bucket}, {iters} iters, {} seeds",
        "16/32 workers", spec.name, a.seeds
    );
    let mut table = Table::new(
        "Table 2: validation accuracy at scale (paper: Tab. 2)",
        &["Method", "16 workers", "32 workers"],
    );
    let mut csv = Table::new("", &["method", "workers", "seed", "val_acc"]);

    for method in METHODS {
        let mut cells = vec![method.name().to_string()];
        for &m in &worker_counts {
            let mut accs = Vec::new();
            for seed in 0..a.seeds as u64 {
                let rec = run_one(method, &spec, iters, m, bits, bucket, 21 + seed, 0);
                accs.push(rec.final_eval.accuracy);
                csv.row(vec![
                    method.name().into(),
                    m.to_string(),
                    seed.to_string(),
                    format!("{:.4}", rec.final_eval.accuracy),
                ]);
            }
            let (mean, std) = mean_std(&accs);
            cells.push(pct(mean, std));
            println!("  {method:<10} M={m:<3} {}", pct(mean, std));
        }
        table.row(cells);
    }

    println!("\n{}", table.to_markdown());
    let path = out_dir().join("table2.csv");
    csv.save_csv(&path)?;
    println!("per-run rows written to {path:?}");
    Ok(())
}
