//! Fig. 6 — the quantization levels each method ends training with.
//! Adaptive levels concentrate near zero (where normalized gradient
//! coordinates live); the fixed baselines stay where they started.

use super::common::{out_dir, run_one, ExpArgs, ModelSpec};
use crate::metrics::Table;
use anyhow::Result;

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 2000 } else { 1000 });
    let spec = ModelSpec::resnet32_standin();
    let bits = 3;

    println!("Fig. 6 — final levels (model {}, {iters} iters)", spec.name);
    let mut table = Table::new(
        "Fig. 6: final magnitude levels after training",
        &["Method", "levels (magnitudes)"],
    );
    let mut csv = Table::new("", &["method", "level_index", "value"]);
    for method in crate::quant::Method::QUANTIZED {
        let rec = run_one(method, &spec, iters, 4, bits, spec.bucket, 8, 0);
        let levels = rec.final_levels.unwrap();
        let pretty: Vec<String> = levels.iter().map(|l| format!("{l:.4}")).collect();
        table.row(vec![method.name().into(), pretty.join("  ")]);
        for (i, l) in levels.iter().enumerate() {
            csv.row(vec![method.name().into(), i.to_string(), format!("{l}")]);
        }
    }
    println!("{}", table.to_markdown());
    let path = out_dir().join("fig6_levels.csv");
    csv.save_csv(&path)?;
    println!("levels written to {path:?}");
    println!("\nPaper shape: ALQ/AMQ levels bunch toward 0; QSGDinf stays uniform;");
    println!("NUQSGD stays at powers of 1/2.");
    Ok(())
}
