//! Fig. 6 — the quantization levels each method ends training with,
//! plus the per-step bit-width trajectory under each `--bits-policy`.
//! Adaptive levels concentrate near zero (where normalized gradient
//! coordinates live); the fixed baselines stay where they started; and
//! with a dynamic bit budget the *width* trajectory is plottable
//! alongside the adaptive levels (the DQ-SGD-style companion curve).

use super::common::{out_dir, run_one, run_policy, ExpArgs, ModelSpec};
use crate::exchange::BitsPolicy;
use crate::metrics::Table;
use anyhow::Result;

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 2000 } else { 1000 });
    let spec = ModelSpec::resnet32_standin();
    let bits = 3;

    println!("Fig. 6 — final levels (model {}, {iters} iters)", spec.name);
    let mut table = Table::new(
        "Fig. 6: final magnitude levels after training",
        &["Method", "levels (magnitudes)"],
    );
    let mut csv = Table::new("", &["method", "level_index", "value"]);
    for method in crate::quant::Method::QUANTIZED {
        let rec = run_one(method, &spec, iters, 4, bits, spec.bucket, 8, 0);
        let levels = rec.final_levels.unwrap();
        let pretty: Vec<String> = levels.iter().map(|l| format!("{l:.4}")).collect();
        table.row(vec![method.name().into(), pretty.join("  ")]);
        for (i, l) in levels.iter().enumerate() {
            csv.row(vec![method.name().into(), i.to_string(), format!("{l}")]);
        }
    }
    println!("{}", table.to_markdown());
    let path = out_dir().join("fig6_levels.csv");
    csv.save_csv(&path)?;
    println!("levels written to {path:?}");
    println!("\nPaper shape: ALQ/AMQ levels bunch toward 0; QSGDinf stays uniform;");
    println!("NUQSGD stays at powers of 1/2.");

    // Adaptive-bits trajectory: the per-step width each bit policy
    // selects for ALQ, recorded next to the adaptive levels so both
    // adaptation axes plot from one CSV pair.
    println!("\nBit-width trajectories (ALQ, per --bits-policy):");
    let (s1, s2) = ((iters / 4).max(1), (iters / 2).max(2));
    let policies = [
        BitsPolicy::Fixed(bits),
        BitsPolicy::parse(&format!("schedule:4@0,3@{s1},2@{s2}")).unwrap(),
        BitsPolicy::parse("variance:2-4").unwrap(),
    ];
    let mut wtable = Table::new(
        "Fig. 6b: per-step bit-width by policy",
        &["policy", "mean width", "total Mbits", "final loss"],
    );
    let mut wcsv = Table::new("", &["policy", "step", "width", "bits"]);
    for policy in policies {
        // Same task/seed derivation as the Fig. 6a runs (run_one), so
        // the two CSVs pair step for step.
        let rec = run_policy(
            crate::quant::Method::Alq,
            &spec,
            iters,
            4,
            spec.bucket,
            8,
            0,
            policy.clone(),
        );
        let mean_width: f64 = rec.steps.iter().map(|s| s.width as f64).sum::<f64>()
            / rec.steps.len().max(1) as f64;
        wtable.row(vec![
            policy.name(),
            format!("{mean_width:.2}"),
            format!("{:.2}", rec.comm_bits as f64 / 1e6),
            format!("{:.4}", rec.final_eval.loss),
        ]);
        for s in &rec.steps {
            wcsv.row(vec![
                policy.name(),
                s.step.to_string(),
                s.width.to_string(),
                s.bits.to_string(),
            ]);
        }
    }
    println!("{}", wtable.to_markdown());
    let wpath = out_dir().join("fig6_bits_trajectory.csv");
    wcsv.save_csv(&wpath)?;
    println!("per-step widths written to {wpath:?}");
    Ok(())
}
