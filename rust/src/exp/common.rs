//! Shared experiment plumbing: model scales, task construction, runners.

use crate::data::Blobs;
use crate::exchange::{BitsPolicy, ParallelMode};
use crate::model::{Mlp, MlpTask};
use crate::opt::{LrSchedule, UpdateSchedule};
use crate::quant::Method;
use crate::sim::{Cluster, ClusterConfig, NetworkModel, TrainRecord};
use std::path::PathBuf;

/// A scaled-down stand-in for one of the paper's model/dataset pairs
/// (DESIGN.md §3: bucket sizes scale with the ~22× parameter reduction).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Which paper workload this stands in for.
    pub paper_name: &'static str,
    pub name: &'static str,
    pub dims: Vec<usize>,
    pub batch: usize,
    pub bucket: usize,
    pub data_dim: usize,
    pub classes: usize,
    pub n_train: usize,
    pub n_val: usize,
    pub noise: f64,
}

impl ModelSpec {
    /// ResNet-32 on CIFAR-10 → 3-layer MLP, bucket 512 (≈ 8192 / 22).
    pub fn resnet32_standin() -> Self {
        ModelSpec {
            paper_name: "ResNet-32 on CIFAR-10",
            name: "mlp32",
            dims: vec![32, 128, 128, 10],
            batch: 16,
            bucket: 512,
            data_dim: 32,
            classes: 10,
            n_train: 16384,
            n_val: 1024,
            noise: 0.8,
        }
    }

    /// ResNet-110 on CIFAR-10 → deeper MLP, bucket 1024 (≈ 16384 / 22).
    pub fn resnet110_standin() -> Self {
        ModelSpec {
            paper_name: "ResNet-110 on CIFAR-10",
            name: "mlp110",
            dims: vec![32, 128, 128, 128, 128, 10],
            batch: 16,
            bucket: 1024,
            data_dim: 32,
            classes: 10,
            n_train: 16384,
            n_val: 1024,
            noise: 0.8,
        }
    }

    /// ResNet-8 on CIFAR-10 (the Fig. 7 sweep model) → small MLP.
    pub fn resnet8_standin() -> Self {
        ModelSpec {
            paper_name: "ResNet-8 on CIFAR-10",
            name: "mlp8",
            dims: vec![32, 64, 10],
            batch: 16,
            bucket: 256,
            data_dim: 32,
            classes: 10,
            n_train: 16384,
            n_val: 1024,
            noise: 0.8,
        }
    }

    pub fn param_count(&self) -> usize {
        Mlp::new(self.dims.clone()).param_count()
    }

    pub fn task(&self, workers: usize, data_seed: u64) -> MlpTask {
        let blobs = Blobs::generate(
            self.data_dim,
            self.classes,
            self.n_train,
            self.n_val,
            self.noise,
            data_seed,
        );
        MlpTask::new(
            Mlp::new(self.dims.clone()),
            blobs,
            self.batch,
            workers,
            data_seed ^ 0x51ED,
        )
    }
}

/// Build the cluster config for one run.
pub fn cluster_config(
    method: Method,
    _spec: &ModelSpec,
    iters: usize,
    workers: usize,
    bits: u32,
    bucket: usize,
    seed: u64,
) -> ClusterConfig {
    ClusterConfig {
        method,
        workers,
        bits: BitsPolicy::Fixed(bits),
        bucket,
        iters,
        lr: LrSchedule::paper_default(0.1, iters),
        updates: UpdateSchedule::paper_default(iters),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed,
        eval_every: (iters / 25).max(1),
        variance_every: 0,
        network: NetworkModel::paper_testbed(),
        parallel: ParallelMode::Auto,
        topology: crate::exchange::TopologySpec::Flat,
        codec: crate::quant::Codec::Huffman,
        quantize_impl: crate::quant::QuantizeImpl::default(),
        pipeline: crate::exchange::PipelineMode::Off,
        faults: crate::sim::FaultPlan::default(),
        error_feedback: false,
        lazy: crate::exchange::LazyPolicy::Off,
    }
}

/// Run one (method, seed) training job end to end.
pub fn run_one(
    method: Method,
    spec: &ModelSpec,
    iters: usize,
    workers: usize,
    bits: u32,
    bucket: usize,
    seed: u64,
    variance_every: usize,
) -> TrainRecord {
    run_policy(
        method,
        spec,
        iters,
        workers,
        bucket,
        seed,
        variance_every,
        BitsPolicy::Fixed(bits),
    )
}

/// Run one training job under an explicit bit-budget policy (the same
/// task/seed derivation as [`run_one`], so policy sweeps pair with the
/// fixed-width runs step for step).
#[allow(clippy::too_many_arguments)]
pub fn run_policy(
    method: Method,
    spec: &ModelSpec,
    iters: usize,
    workers: usize,
    bucket: usize,
    seed: u64,
    variance_every: usize,
    policy: BitsPolicy,
) -> TrainRecord {
    let mut cfg = cluster_config(
        method,
        spec,
        iters,
        workers,
        policy.initial_bits(),
        bucket,
        seed,
    );
    cfg.bits = policy;
    cfg.variance_every = variance_every;
    let mut task = spec.task(workers, seed.wrapping_mul(31).wrapping_add(7));
    Cluster::new(cfg).train(&mut task)
}

/// Output directory for experiment CSVs.
pub fn out_dir() -> PathBuf {
    std::env::var("AQSGD_RUNS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/runs")))
}

/// Common flag parsing for experiment drivers.
pub struct ExpArgs {
    pub full: bool,
    pub long: bool,
    pub clip: bool,
    pub seeds: usize,
    pub iters: Option<usize>,
}

impl ExpArgs {
    pub fn parse(args: &[String]) -> Self {
        let mut out = ExpArgs {
            full: false,
            long: false,
            clip: false,
            seeds: 3,
            iters: None,
        };
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--full" => out.full = true,
                "--long" => out.long = true,
                "--clip" => out.clip = true,
                "--seeds" => out.seeds = it.next().and_then(|v| v.parse().ok()).unwrap_or(3),
                "--iters" => out.iters = it.next().and_then(|v| v.parse().ok()),
                _ => {}
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_sane() {
        for spec in [
            ModelSpec::resnet32_standin(),
            ModelSpec::resnet110_standin(),
            ModelSpec::resnet8_standin(),
        ] {
            assert!(spec.param_count() > spec.bucket, "{}", spec.name);
            let mut task = spec.task(4, 1);
            use crate::model::TrainTask;
            let p = task.init_params(0);
            let mut g = vec![0.0; p.len()];
            let loss = task.grad(&p, 0, 0, &mut g);
            assert!(loss.is_finite() && loss > 0.0);
        }
    }

    #[test]
    fn run_one_smoke() {
        let spec = ModelSpec::resnet8_standin();
        let rec = run_one(Method::QsgdInf, &spec, 20, 2, 3, 128, 1, 10);
        assert_eq!(rec.steps.len(), 20);
        assert!(!rec.variance.is_empty());
    }

    #[test]
    fn exp_args_parse() {
        let a = ExpArgs::parse(&["--full".into(), "--seeds".into(), "5".into()]);
        assert!(a.full);
        assert_eq!(a.seeds, 5);
        assert!(!a.clip);
    }
}
