//! Fig. 7 — robustness sweeps on the ResNet-8 stand-in:
//! (a) bucket size at 3 bits, (b) bit width at fixed bucket.
//! `--clip` turns on 2.5σ gradient clipping for every method — the
//! Appendix K.2 / Fig. 14 ablation.

use super::common::{out_dir, ExpArgs, ModelSpec};
use crate::metrics::{mean_std, Table};
use crate::quant::Method;
use crate::sim::Cluster;
use anyhow::Result;

const METHODS: [Method; 6] = [
    Method::NuqSgd,
    Method::QsgdInf,
    Method::Trn,
    Method::Alq,
    Method::AlqN,
    Method::Amq,
];

fn run_cell(
    method: Method,
    spec: &ModelSpec,
    iters: usize,
    bits: u32,
    bucket: usize,
    seeds: usize,
    clip: bool,
) -> (f64, f64) {
    let mut accs = Vec::new();
    for seed in 0..seeds as u64 {
        let mut cfg = super::common::cluster_config(method, spec, iters, 4, bits, bucket, 31 + seed);
        cfg.eval_every = 0;
        let mut cluster = Cluster::new(cfg);
        if clip {
            // Force the K.2 ablation clip onto every quantized method
            // (TRN already clips by definition).
            cluster.force_clip(2.5);
        }
        let mut task = spec.task(4, 1000 + seed);
        let rec = cluster.train(&mut task);
        accs.push(rec.final_eval.accuracy);
    }
    mean_std(&accs)
}

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 1600 } else { 800 });
    let spec = ModelSpec::resnet8_standin();
    let seeds = a.seeds.min(3);
    let clip_tag = if a.clip { " (2.5σ clipping — Fig. 14)" } else { "" };

    // (a) bucket-size sweep at 3 bits.
    let buckets = if a.full {
        vec![64usize, 256, 1024, 4096, 8192]
    } else {
        vec![64usize, 256, 1024, 4096]
    };
    println!(
        "Fig. 7a — bucket sweep{clip_tag}: model {}, 3 bits, {iters} iters, {seeds} seeds",
        spec.name
    );
    let mut cols: Vec<String> = vec!["Method".into()];
    cols.extend(buckets.iter().map(|b| b.to_string()));
    let mut t_bucket = Table::new(
        "Fig. 7a: val accuracy vs bucket size (3 bits)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for method in METHODS {
        let mut cells = vec![method.name().to_string()];
        for &bucket in &buckets {
            let (m, s) = run_cell(method, &spec, iters, 3, bucket, seeds, a.clip);
            cells.push(format!("{:.1}±{:.1}", 100.0 * m, 100.0 * s));
            println!("  {method:<8} bucket {bucket:<6} {:.1}%", 100.0 * m);
        }
        t_bucket.row(cells);
    }
    println!("\n{}", t_bucket.to_markdown());

    // (b) bit-width sweep at fixed bucket.
    let bit_list = if a.full {
        vec![2u32, 3, 4, 5, 6, 8]
    } else {
        vec![2u32, 3, 4, 6]
    };
    println!(
        "Fig. 7b — bit sweep{clip_tag}: bucket {}, {iters} iters",
        spec.bucket
    );
    let mut cols: Vec<String> = vec!["Method".into()];
    cols.extend(bit_list.iter().map(|b| format!("{b} bits")));
    let mut t_bits = Table::new(
        "Fig. 7b: val accuracy vs bits",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for method in METHODS {
        let mut cells = vec![method.name().to_string()];
        for &bits in &bit_list {
            let (m, s) = run_cell(method, &spec, iters, bits, spec.bucket, seeds, a.clip);
            cells.push(format!("{:.1}±{:.1}", 100.0 * m, 100.0 * s));
            println!("  {method:<8} {bits} bits {:.1}%", 100.0 * m);
        }
        t_bits.row(cells);
    }
    println!("\n{}", t_bits.to_markdown());

    let tag = if a.clip { "fig14" } else { "fig7" };
    let path = out_dir().join(format!("{tag}_bucket.csv"));
    t_bucket.save_csv(&path)?;
    let path2 = out_dir().join(format!("{tag}_bits.csv"));
    t_bits.save_csv(&path2)?;
    println!("tables written to {path:?}, {path2:?}");
    println!("\nPaper shape: adaptive methods flat across both sweeps; NUQSGD good");
    println!("only near bucket ≈ 100; QSGDinf degrades at the extremes; 2 bits hurts AMQ.");
    Ok(())
}
