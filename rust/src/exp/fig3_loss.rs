//! Fig. 3 — validation loss curves for every method (both stand-ins).

use super::common::{out_dir, run_one, ExpArgs, ModelSpec};
use crate::metrics::{Series, Table};
use anyhow::Result;

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 3000 } else { 1500 });
    let workers = 4;
    let bits = 3;
    let specs = [ModelSpec::resnet110_standin(), ModelSpec::resnet32_standin()];

    for spec in &specs {
        println!("Fig. 3 — validation loss, model {} ({iters} iters)", spec.name);
        let mut series = Vec::new();
        let mut summary = Table::new(
            &format!("Fig. 3 ({}): validation loss", spec.name),
            &["Method", "final", "min"],
        );
        for method in super::table1::METHODS {
            let rec = run_one(method, spec, iters, workers, bits, spec.bucket, 3, 0);
            let mut s = Series::new(method.name());
            for (step, ev) in &rec.evals {
                s.push(*step, ev.loss);
            }
            let final_loss = rec.final_eval.loss;
            let min_loss = rec
                .evals
                .iter()
                .map(|(_, e)| e.loss)
                .fold(f64::INFINITY, f64::min);
            summary.row(vec![
                method.name().into(),
                format!("{final_loss:.4}"),
                format!("{min_loss:.4}"),
            ]);
            series.push(s);
        }
        let path = out_dir().join(format!("fig3_loss_{}.csv", spec.name));
        Series::save_csv(&series, &path)?;
        println!("{}", summary.to_markdown());
        println!("curves written to {path:?}\n");
    }
    println!("Paper shape: adaptive methods track SuperSGD's curve; QSGDinf/TRN sit above;");
    println!("NUQSGD plateaus highest.");
    Ok(())
}
