//! Tables 5–7 — per-step timing vs bits/bucket and the level-update cost.
//!
//! The paper wall-clocked 4 V100 nodes on a 1 Gbit/s network; we have no
//! V100s, so (DESIGN.md §3) the tables are regenerated as
//!
//!   step(bits, bucket) = compute_base + ring_allreduce(encoded bits)
//!                      + measured_codec(bits, bucket)
//!
//! with the codec cost *measured on this CPU* and reported per phase
//! (quantize | Huffman encode | decode+dequantize per coordinate — the
//! same split `TrainRecord.codec_phase` attributes on real runs),
//! encoded sizes measured exactly,
//! the α-β ring model at 1 Gbit/s, and compute_base calibrated from the
//! paper's fp32 step time. Absolute numbers differ from V100s; the shape
//! (ratios to FP32/FP16, monotonicity in bits, weak bucket dependence)
//! is the reproduction target.
//!
//! The tables also charge the `--pipeline` schedules (ISSUE 9): a
//! depth-1 pipeline hides step t's wire seconds behind step t+1's
//! gradient compute, so each cell reports its compute/comm split, the
//! hidden share `min(compute, comm)`, and the pipelined step
//! `max(compute, comm) + codec` — the same ledger the simulator's
//! `Meter` keeps (`wall = compute + comm − hidden`).

use super::common::{out_dir, ExpArgs};
use crate::adaptive::{update_levels, Estimator};
use crate::metrics::Table;
use crate::quant::{encode, symbol_counts, HuffmanBook, Levels, Method, Quantizer};
use crate::sim::{NetworkModel, Topology};
use crate::util::Rng;
use anyhow::Result;
use std::time::Instant;

/// Measured codec cost + encoded size for one (bits, bucket) cell,
/// split into the three codec phases (the same quantize/encode/decode
/// attribution `TrainRecord.codec_phase` reports for real runs).
struct CodecProfile {
    quantize_ns_per_coord: f64,
    encode_ns_per_coord: f64,
    decode_ns_per_coord: f64,
    bits_per_coord: f64,
}

impl CodecProfile {
    fn ns_per_coord(&self) -> f64 {
        self.quantize_ns_per_coord + self.encode_ns_per_coord + self.decode_ns_per_coord
    }
}

fn profile_codec(bits: u32, bucket: usize, n: usize) -> CodecProfile {
    let levels = Levels::exponential(Levels::mags_for_bits(bits), 0.5);
    let quant = Quantizer::new(levels.clone(), crate::quant::NormType::L2, bucket);
    let mut rng = Rng::new(42);
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    // Warm codebook from one pass.
    let q0 = quant.quantize(&v, &mut rng);
    let book = HuffmanBook::from_weights(
        &symbol_counts(&q0, &levels)
            .iter()
            .map(|c| c + 1.0)
            .collect::<Vec<_>>(),
    );
    let mut out = vec![0.0f32; n];
    let reps = 3;
    let mut total_bits = 0u64;
    let (mut t_quantize, mut t_encode, mut t_decode) = (0.0f64, 0.0f64, 0.0f64);
    for _ in 0..reps {
        let t0 = Instant::now();
        let q = quant.quantize(&v, &mut rng);
        t_quantize += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let e = encode(&q, &levels, &book);
        t_encode += t0.elapsed().as_secs_f64();
        total_bits += e.bits;
        // Decode covers entropy-decode + dequantize (the receive side).
        let t0 = Instant::now();
        let d = crate::quant::decode(&e, &levels, &book);
        quant.dequantize(&d, &mut out);
        t_decode += t0.elapsed().as_secs_f64();
    }
    let per_coord = 1e9 / (reps * n) as f64;
    CodecProfile {
        quantize_ns_per_coord: t_quantize * per_coord,
        encode_ns_per_coord: t_encode * per_coord,
        decode_ns_per_coord: t_decode * per_coord,
        bits_per_coord: total_bits as f64 / (reps * n) as f64,
    }
}

/// One paper model row: (name, parameter count, paper fp32/fp16 step s).
const PAPER_MODELS: [(&str, usize, f64, f64); 2] = [
    ("ResNet18/ImageNet", 11_690_000, 0.57, 0.28),
    ("ResNet50/ImageNet", 25_560_000, 1.20, 0.61),
];

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    // `--topology flat|ring` picks the analytical interconnect charged
    // for the comm term (ring is the paper's Appendix K.3 default; the
    // *executable* schedules live in exchange::topology and are measured
    // by benches/topology.rs).
    let topology = match args
        .iter()
        .position(|x| x == "--topology")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
    {
        None | Some("ring") => Topology::Ring,
        Some("flat") => Topology::FlatAllToAll,
        Some(other) => anyhow::bail!(
            "bad --topology {other:?}: the timing tables use the analytical flat|ring \
             closed forms (executable schedules are measured by `cargo bench --bench topology`)"
        ),
    };
    let net = NetworkModel {
        alpha: 50e-6,
        beta: 1e9,
        topology,
    };
    let m = 4; // 4 nodes, as in Appendix K.3
    let bits_list: Vec<u32> = if a.full {
        vec![2, 3, 4, 5, 6, 7, 8]
    } else {
        vec![2, 3, 4, 6, 8]
    };
    let buckets: Vec<usize> = vec![64, 256, 1024, 8192, 16384];
    let probe_n = 1 << 20;

    for (model, d, fp32_step, fp16_step) in PAPER_MODELS {
        // Compute base: the paper's fp32 step minus its (modelled) fp32 comm.
        let fp32_comm = net.fp32_step_time(d, m);
        let compute = (fp32_step - fp32_comm).max(0.01);
        println!(
            "\nTables 5–6 — {model}: d={d}, fp32 step {fp32_step}s \
             (comm model {fp32_comm:.3}s, compute base {compute:.3}s)"
        );
        let mut t = Table::new(
            &format!("Per-step time, {model} (paper: Tables 5–6)"),
            &[
                "Bits",
                "Bucket",
                "Time/step (s)",
                "Compute (s)",
                "Comm (s)",
                "Hidden (s)",
                "Pipelined (s)",
                "Quantize (ms)",
                "Encode (ms)",
                "Decode (ms)",
                "Ratio FP32",
                "Ratio FP16",
            ],
        );
        for &bits in &bits_list {
            for &bucket in &buckets {
                let prof = profile_codec(bits, bucket, probe_n);
                let enc_bits = (prof.bits_per_coord * d as f64) as u64;
                let comm = net.step_time(&vec![enc_bits; m]);
                let codec = prof.ns_per_coord() * 1e-9 * d as f64;
                let step = compute + comm + codec;
                // Depth-1 pipeline ledger: the wire transfer runs while
                // the next step's gradients compute, so the hidden share
                // is bounded by both phases and the pipelined step is
                // max(compute, comm) + codec.
                let hidden = comm.min(compute);
                let pipelined = step - hidden;
                let phase_ms = |ns: f64| format!("{:.1}", ns * 1e-6 * d as f64);
                t.row(vec![
                    bits.to_string(),
                    bucket.to_string(),
                    format!("{step:.3}"),
                    format!("{compute:.3}"),
                    format!("{comm:.3}"),
                    format!("{hidden:.3}"),
                    format!("{pipelined:.3}"),
                    phase_ms(prof.quantize_ns_per_coord),
                    phase_ms(prof.encode_ns_per_coord),
                    phase_ms(prof.decode_ns_per_coord),
                    format!("{:.2}", step / fp32_step),
                    format!("{:.2}", step / fp16_step),
                ]);
            }
        }
        println!("{}", t.to_markdown());
        let path = out_dir().join(format!(
            "timing_{}.csv",
            model.split('/').next().unwrap().to_lowercase()
        ));
        t.save_csv(&path)?;
        println!("written to {path:?}");
    }

    // Table 7 — level-update cost for ALQ and ALQ-N.
    println!("\nTable 7 — adaptive level-update cost");
    let mut t7 = Table::new(
        "Level-update time (paper: Table 7)",
        &["Bits", "Bucket", "Method", "Time per update (ms)", "3 updates / fp32 training (%)"],
    );
    // Paper: 60-epoch fp32 run = 95 h; 3 updates total.
    let fp32_training_secs = 95.0 * 3600.0;
    for &bits in &bits_list {
        for &bucket in &[64usize, 1024, 8192] {
            for method in [Method::Alq, Method::AlqN] {
                let dt = profile_update(method, bits, bucket);
                t7.row(vec![
                    bits.to_string(),
                    bucket.to_string(),
                    method.name().into(),
                    format!("{:.2}", dt * 1e3),
                    format!("{:.5}", 100.0 * 3.0 * dt / fp32_training_secs),
                ]);
            }
        }
    }
    println!("{}", t7.to_markdown());
    let path = out_dir().join("timing_update.csv");
    t7.save_csv(&path)?;
    println!("written to {path:?}");
    println!("\nPaper shape: per-step ratio to FP32 in the 0.2–0.4 band, rising gently");
    println!("with bits and barely with bucket; update cost seconds-scale, a ~1e-4");
    println!("fraction of training (\"negligible computational overhead\").");
    Ok(())
}

/// Time one full adaptive update: stats → mixture → optimize → codebook.
fn profile_update(method: Method, bits: u32, bucket: usize) -> f64 {
    let n = 1 << 20;
    let mut rng = Rng::new(7);
    let grad: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    // ImageNet-scale estimator: 350 components (Appendix K).
    let mut est = Estimator::new(bucket, crate::quant::NormType::L2, 350);
    let levels = method.initial_levels(bits).unwrap();
    let reps = 3;
    let t0 = Instant::now();
    for _ in 0..reps {
        est.clear();
        est.observe(&grad);
        let mix = est.fit(method.weighted_mixture(), &mut rng).unwrap();
        let new_levels = update_levels(method, &levels, &mix);
        let probs = crate::adaptive::objective::symbol_probs(&mix, &new_levels);
        let _book = HuffmanBook::from_weights(&probs.iter().map(|p| p + 1e-6).collect::<Vec<_>>());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}
