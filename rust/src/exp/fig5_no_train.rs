//! Fig. 5 — variance on the *frozen* single-worker SGD trajectory: every
//! method quantizes the same gradients, decoupling quantization error
//! from its feedback on optimization.

use super::common::{out_dir, ExpArgs, ModelSpec};
use crate::metrics::{Series, Table};
use crate::model::TrainTask;
use crate::opt::{LrSchedule, Optimizer, Umsgd};
use crate::quant::{Method, Quantizer};
use anyhow::Result;

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 3000 } else { 1200 });
    let workers = 4; // M used for the SuperSGD = SGD/M line + quant average
    let bits = 3;
    let spec = ModelSpec::resnet32_standin();
    let every = (iters / 50).max(1);
    let lr = LrSchedule::paper_default(0.1, iters);

    println!("Fig. 5 — variance (no train), model {}, {iters} iters", spec.name);

    // Train the reference trajectory with full-precision single SGD.
    let mut task = spec.task(workers, 400);
    let d = task.param_count();
    let mut params = task.init_params(9);
    let mut opt = Umsgd::heavy_ball(0.9, 1e-4);
    let mut grads: Vec<Vec<f32>> = vec![vec![0.0f32; d]; workers];

    let methods: Vec<Method> = Method::QUANTIZED
        .iter()
        .copied()
        .filter(|m| !matches!(m, Method::AlqGN))
        .collect();
    let mut quantizers: Vec<(Method, Quantizer)> = methods
        .iter()
        .map(|&m| {
            let mut q = Quantizer::new(
                m.initial_levels(bits).unwrap(),
                m.norm_type(),
                spec.bucket,
            );
            if let Some(c) = m.clip_factor() {
                q = q.with_clip(c);
            }
            (m, q)
        })
        .collect();
    let updates = crate::opt::UpdateSchedule::paper_default(iters);

    let mut series: Vec<Series> = methods.iter().map(|m| Series::new(m.name())).collect();
    let mut sgd_series = Series::new("SGD");
    let mut super_series = Series::new("SuperSGD");
    let mut means: Vec<f64> = vec![0.0; methods.len()];
    let mut nsamples = 0usize;

    for step in 0..iters {
        // M gradients at the *same* parameter point.
        for (w, g) in grads.iter_mut().enumerate() {
            task.grad(&params, w, step, g);
        }

        // Adaptive methods re-fit on the frozen gradients at 𝒰 steps.
        if updates.is_update_step(step) {
            for (m, q) in quantizers.iter_mut() {
                if !m.is_adaptive() {
                    continue;
                }
                let mut est =
                    crate::adaptive::Estimator::new(spec.bucket, q.norm_type(), 20);
                for g in &grads {
                    est.observe(g);
                }
                let mut rng = crate::util::Rng::new(77 ^ step as u64);
                if let Some(mix) = est.fit(m.weighted_mixture(), &mut rng) {
                    q.set_levels(crate::adaptive::update_levels(*m, q.levels(), &mix));
                }
            }
        }

        if step % every == 0 {
            // Sampling variance across the M same-point gradients.
            let mut sgd_var = 0.0f64;
            for i in 0..d {
                let mean: f64 =
                    grads.iter().map(|g| g[i] as f64).sum::<f64>() / workers as f64;
                sgd_var += grads
                    .iter()
                    .map(|g| (g[i] as f64 - mean).powi(2))
                    .sum::<f64>()
                    / (workers as f64 - 1.0);
            }
            sgd_var /= d as f64;
            sgd_series.push(step, sgd_var);
            super_series.push(step, sgd_var / workers as f64);
            for (k, (_m, q)) in quantizers.iter().enumerate() {
                let qv: f64 = grads.iter().map(|g| q.exact_variance(g)).sum::<f64>()
                    / (workers as f64).powi(2)
                    / d as f64;
                let total = sgd_var / workers as f64 + qv;
                series[k].push(step, total);
                means[k] += total;
            }
            nsamples += 1;
        }

        // Advance the trajectory with the *unquantized* single gradient.
        let g0 = grads[0].clone();
        opt.step(&mut params, &g0, lr.lr(step));
    }

    let mut all = vec![sgd_series, super_series];
    all.extend(series);
    let path = out_dir().join("fig5_no_train.csv");
    Series::save_csv(&all, &path)?;

    let mut summary = Table::new(
        "Fig. 5: mean variance on the frozen SGD trajectory",
        &["Method", "mean total var"],
    );
    let sgd_mean: f64 =
        all[0].points.iter().map(|&(_, v)| v).sum::<f64>() / nsamples.max(1) as f64;
    summary.row(vec!["SGD".into(), format!("{sgd_mean:.4e}")]);
    summary.row(vec![
        "SuperSGD".into(),
        format!("{:.4e}", sgd_mean / workers as f64),
    ]);
    for (k, m) in methods.iter().enumerate() {
        summary.row(vec![
            m.name().into(),
            format!("{:.4e}", means[k] / nsamples.max(1) as f64),
        ]);
    }
    println!("{}", summary.to_markdown());
    println!("curves written to {path:?}");
    println!("\nPaper shape: SuperSGD = SGD/M exactly; ALQ lowest among quantizers");
    println!("(can approach SuperSGD); QSGDinf ≈ TRN early; NUQSGD worst.");
    Ok(())
}
