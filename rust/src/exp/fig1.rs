//! Fig. 1 — the premise: the variance of normalized gradient coordinates
//! changes during training, with jumps at the LR drops.
//!
//! We train the ResNet-32 stand-in with single-worker SGD and record the
//! mean per-bucket variance of normalized coordinates (the exact statistic
//! the estimator feeds ALQ) every few steps, across several seeds.

use super::common::{out_dir, ExpArgs, ModelSpec};
use crate::metrics::Series;
use crate::model::TrainTask;
use crate::opt::{LrSchedule, Optimizer, Umsgd};
use crate::quant::NormType;
use crate::stats::BucketStats;
use anyhow::Result;

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let spec = ModelSpec::resnet32_standin();
    let iters = a.iters.unwrap_or(if a.full { 6000 } else { 2000 });
    let every = (iters / 100).max(1);
    let lr = LrSchedule::paper_default(0.1, iters);

    println!("Fig. 1 — variance of normalized coordinates (model {}, {iters} iters)", spec.name);
    println!("LR drops at {:?}\n", lr.drops);

    let mut all_series = Vec::new();
    for seed in 0..a.seeds as u64 {
        let mut task = spec.task(1, 100 + seed);
        let mut params = task.init_params(seed);
        let mut opt = Umsgd::heavy_ball(0.9, 1e-4);
        let mut grad = vec![0.0f32; task.param_count()];
        let mut series = Series::new(&format!("seed{seed}"));
        for step in 0..iters {
            task.grad(&params, 0, step, &mut grad);
            if step % every == 0 {
                // Mean per-bucket variance of normalized coordinates.
                let nb = grad.len() / spec.bucket;
                let mut acc = 0.0;
                for b in 0..nb {
                    let s = BucketStats::from_bucket(
                        &grad[b * spec.bucket..(b + 1) * spec.bucket],
                        NormType::L2,
                    );
                    acc += s.sigma2;
                }
                series.push(step, acc / nb as f64);
            }
            opt.step(&mut params, &grad, lr.lr(step));
        }
        all_series.push(series);
    }

    let path = out_dir().join("fig1_variance.csv");
    Series::save_csv(&all_series, &path)?;
    println!("series written to {path:?}\n");

    // Print the qualitative check the figure makes: variance at the start,
    // before/after each LR drop.
    let probe = |s: &Series, step: usize| -> f64 {
        s.points
            .iter()
            .min_by_key(|(st, _)| st.abs_diff(step))
            .map(|&(_, v)| v)
            .unwrap_or(0.0)
    };
    println!("{:<8} {:>12} {:>12} {:>12} {:>12}", "seed", "step~0", "pre-drop1", "post-drop1", "end");
    for (i, s) in all_series.iter().enumerate() {
        let d1 = lr.drops[0];
        println!(
            "{:<8} {:>12.4e} {:>12.4e} {:>12.4e} {:>12.4e}",
            i,
            probe(s, 0),
            probe(s, d1.saturating_sub(every)),
            probe(s, d1 + 2 * every),
            s.points.last().map(|&(_, v)| v).unwrap_or(0.0),
        );
    }
    println!("\nPaper shape: rapid change over the first epoch, then a visible");
    println!("shift after each LR drop — compare the columns above.");
    Ok(())
}
