//! Fig. 8 — convergence of the level-update methods (CD vs GD vs AMQ's
//! multiplier descent) on a fixed distribution snapshot, from uniform and
//! exponential initializations. Also demonstrates the nonconvexity claim
//! of Theorem 1: different inits can land in different local minima.

use super::common::{out_dir, ExpArgs, ModelSpec};
use crate::adaptive::{alq, amq, gd, objective};
use crate::metrics::{Series, Table};
use crate::model::TrainTask;
use crate::quant::{Levels, NormType};
use crate::stats::Mixture;
use anyhow::Result;

/// Build a realistic mixture: brief training, then fit the estimator on
/// the gradients (exactly what ALQ sees at an update step).
fn snapshot_mixture(spec: &ModelSpec, steps: usize) -> Mixture {
    let mut task = spec.task(4, 777);
    let mut params = task.init_params(3);
    let mut grad = vec![0.0f32; task.param_count()];
    let mut opt = crate::opt::Umsgd::heavy_ball(0.9, 1e-4);
    use crate::opt::Optimizer;
    for step in 0..steps {
        task.grad(&params, 0, step, &mut grad);
        opt.step(&mut params, &grad, 0.05);
    }
    let mut est = crate::adaptive::Estimator::new(spec.bucket, NormType::L2, 20);
    for w in 0..4 {
        task.grad(&params, w, steps, &mut grad);
        est.observe(&grad);
    }
    let mut rng = crate::util::Rng::new(5);
    est.fit(true, &mut rng).expect("nonzero gradients")
}

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let spec = ModelSpec::resnet32_standin();
    let mix = snapshot_mixture(&spec, a.iters.unwrap_or(100));
    let k = 4; // 3 bits

    println!("Fig. 8 — level-update convergence on a gradient-distribution snapshot\n");
    let mut series = Vec::new();
    let mut summary = Table::new(
        "Fig. 8: Ψ after convergence per optimizer / init",
        &["Optimizer", "init", "Ψ(init)", "Ψ(final)", "iters"],
    );

    for (init_name, init) in [
        ("uniform", Levels::uniform(k)),
        ("exp(p=0.5)", Levels::exponential(k, 0.5)),
    ] {
        // ALQ (CD).
        let (_, trace) = alq::optimize_traced(&mix, &init, alq::AlqOptions::default());
        let mut s = Series::new(&format!("ALQ-CD[{init_name}]"));
        for (i, v) in trace.iter().enumerate() {
            s.push(i, *v);
        }
        summary.row(vec![
            "ALQ (CD)".into(),
            init_name.into(),
            format!("{:.4e}", trace[0]),
            format!("{:.4e}", trace.last().unwrap()),
            (trace.len() - 1).to_string(),
        ]);
        series.push(s);

        // ALQ-G (safeguarded GD).
        let (_, trace) = gd::optimize_traced(&mix, &init, gd::GdOptions::default());
        let mut s = Series::new(&format!("ALQ-GD[{init_name}]"));
        for (i, v) in trace.iter().enumerate() {
            s.push(i, *v);
        }
        summary.row(vec![
            "ALQ-G (GD)".into(),
            init_name.into(),
            format!("{:.4e}", trace[0]),
            format!("{:.4e}", trace.last().unwrap()),
            (trace.len() - 1).to_string(),
        ]);
        series.push(s);
    }

    // AMQ: multiplier descent from p ∈ {0.2, 0.5, 0.8}.
    for p0 in [0.2, 0.5, 0.8] {
        let (p, trace) = amq::optimize_traced(&mix, k, p0, amq::AmqOptions::default());
        let mut s = Series::new(&format!("AMQ[p0={p0}]"));
        for (i, v) in trace.iter().enumerate() {
            s.push(i, *v);
        }
        summary.row(vec![
            "AMQ".into(),
            format!("p0={p0}"),
            format!("{:.4e}", trace[0]),
            format!("{:.4e} (p*={p:.3})", trace.last().unwrap()),
            (trace.len() - 1).to_string(),
        ]);
        series.push(s);
    }

    // Nonconvexity probe: Ψ from many random restarts of CD.
    let mut rng = crate::util::Rng::new(9);
    let mut finals = Vec::new();
    for _ in 0..20 {
        let init = Levels::uniform(k).jitter(&mut rng, 0.6);
        let (l, _) = alq::optimize(&mix, &init, alq::AlqOptions::default());
        finals.push(objective::psi(&mix, &l));
    }
    let min = finals.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = finals.iter().cloned().fold(0.0, f64::max);

    println!("{}", summary.to_markdown());
    println!(
        "Random-restart CD finals: min {min:.4e}, max {max:.4e} (spread {:.1}% — the\n\
         objective is nonconvex per Theorem 1; distinct basins exist when spread > 0)",
        100.0 * (max - min) / min
    );
    let path = out_dir().join("fig8_convergence.csv");
    Series::save_csv(&series, &path)?;
    println!("traces written to {path:?}");
    Ok(())
}
