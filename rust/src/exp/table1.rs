//! Table 1 (and Table 4 via `--long`) — validation accuracy of every
//! method at 3 bits with M = 4 workers, mean ± std over seeds.

use super::common::{out_dir, run_one, ExpArgs, ModelSpec};
use crate::metrics::{mean_std, pct, Table};
use crate::quant::Method;
use anyhow::Result;

pub const METHODS: [Method; 9] = [
    Method::SuperSgd,
    Method::NuqSgd,
    Method::QsgdInf,
    Method::Trn,
    Method::Alq,
    Method::AlqN,
    Method::AlqG,
    Method::Amq,
    Method::AmqN,
];

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.long {
        6000
    } else if a.full {
        3000
    } else {
        1500
    });
    let workers = 4;
    let bits = 3;
    let specs = [ModelSpec::resnet110_standin(), ModelSpec::resnet32_standin()];

    println!(
        "Table 1 — validation accuracy, {workers} workers, {bits} bits, {iters} iters, {} seeds",
        a.seeds
    );
    let mut table = Table::new(
        "Table 1: validation accuracy (paper: Tab. 1)",
        &["Method", specs[0].name, specs[1].name],
    );
    let mut csv = Table::new(
        "",
        &[
            "method",
            "model",
            "seed",
            "val_acc",
            "val_loss",
            "bits_per_step",
            "quantize_s",
            "encode_s",
            "decode_s",
        ],
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    for method in METHODS {
        let mut cells = vec![method.name().to_string()];
        for spec in &specs {
            let mut accs = Vec::new();
            for seed in 0..a.seeds as u64 {
                let rec = run_one(method, spec, iters, workers, bits, spec.bucket, 1 + seed, 0);
                accs.push(rec.final_eval.accuracy);
                let bits_per_step = rec.comm_bits as f64 / rec.steps.len() as f64;
                csv.row(vec![
                    method.name().into(),
                    spec.name.into(),
                    seed.to_string(),
                    format!("{:.4}", rec.final_eval.accuracy),
                    format!("{:.4}", rec.final_eval.loss),
                    format!("{bits_per_step:.0}"),
                    format!("{:.4}", rec.codec_phase.quantize),
                    format!("{:.4}", rec.codec_phase.encode),
                    format!("{:.4}", rec.codec_phase.decode),
                ]);
            }
            let (m, s) = mean_std(&accs);
            cells.push(pct(m, s));
            println!("  {method:<10} {:<8} {}", spec.name, pct(m, s));
        }
        rows.push(cells);
    }
    for r in rows {
        table.row(r);
    }

    println!("\n{}", table.to_markdown());
    let path = out_dir().join(if a.long { "table4.csv" } else { "table1.csv" });
    csv.save_csv(&path)?;
    println!("per-run rows written to {path:?}");
    println!("\nPaper shape to check: ALQ/AMQ within noise of SuperSGD; QSGDinf/TRN");
    println!("1–2 points behind; NUQSGD far behind at these bucket sizes.");
    Ok(())
}
