//! Fig. 4 — mean gradient variance *during training* (each method on its
//! own trajectory). Adaptive methods should hold the lowest variance.

use super::common::{out_dir, run_one, ExpArgs, ModelSpec};
use crate::metrics::{Series, Table};
use anyhow::Result;

pub fn run(args: &[String]) -> Result<()> {
    let a = ExpArgs::parse(args);
    let iters = a.iters.unwrap_or(if a.full { 3000 } else { 1200 });
    let workers = 4;
    let bits = 3;
    let spec = ModelSpec::resnet32_standin();
    let every = (iters / 60).max(1);

    println!("Fig. 4 — variance during training (model {}, {iters} iters)", spec.name);
    let mut series = Vec::new();
    let mut summary = Table::new(
        "Fig. 4: mean per-coordinate variance of the update estimate",
        &["Method", "mean(total var)", "mean(quant var)"],
    );
    for method in super::table1::METHODS {
        let rec = run_one(method, &spec, iters, workers, bits, spec.bucket, 5, every);
        let mut s = Series::new(method.name());
        let mut tot = 0.0;
        let mut q = 0.0;
        for v in &rec.variance {
            s.push(v.step, v.total_var);
            tot += v.total_var;
            q += v.quant_var;
        }
        let n = rec.variance.len().max(1) as f64;
        summary.row(vec![
            method.name().into(),
            format!("{:.4e}", tot / n),
            format!("{:.4e}", q / n),
        ]);
        series.push(s);
    }
    let path = out_dir().join("fig4_variance.csv");
    Series::save_csv(&series, &path)?;
    println!("{}", summary.to_markdown());
    println!("curves written to {path:?}");
    println!("\nPaper shape: SuperSGD lowest (= SGD/M); ALQ/AMQ close behind;");
    println!("QSGDinf/TRN higher; NUQSGD highest.");
    Ok(())
}
